"""ActiBA's C-LUT fitting: error bounds, tails, and python<->rust parity
expectations (the rust `plu::` module duplicates this construction)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import plu


def silu(x):
    return x / (1.0 + np.exp(-x))


def softplus(x):
    return np.logaddexp(0.0, x)


class TestFit:
    def test_silu_32_is_negligible(self):
        t = plu.silu_table(32)
        assert plu.max_abs_error(t, silu) < 0.02

    def test_softplus_32_is_negligible(self):
        t = plu.softplus_table(32)
        assert plu.max_abs_error(t, softplus) < 0.02

    @settings(max_examples=12, deadline=None)
    @given(segments=st.sampled_from([4, 8, 16, 32, 64, 128]))
    def test_error_scales_down_with_segments(self, segments):
        err = plu.max_abs_error(plu.silu_table(segments), silu)
        # secant error ~ O(step^2), plus the fixed floor from the analytic
        # tail overrides (|silu(-8)| ~ 2.7e-3 is forced to 0 at the edge)
        step = 16.0 / segments
        assert err < 0.15 * step * step + 3.2e-3, f"{segments}: {err}"

    def test_monotone_improvement(self):
        errs = [plu.max_abs_error(plu.silu_table(k), silu)
                for k in (4, 8, 16, 32, 64)]
        assert all(a >= b for a, b in zip(errs, errs[1:])), errs

    def test_tails_are_asymptotes(self):
        t = plu.silu_table(16)
        assert t(np.float32(-50.0)) == 0.0
        np.testing.assert_allclose(t(np.float32(50.0)), 50.0, rtol=1e-6)
        s = plu.softplus_table(16)
        assert s(np.float32(-50.0)) == 0.0
        np.testing.assert_allclose(s(np.float32(50.0)), 50.0, rtol=1e-6)

    def test_rejects_tiny_segment_count(self):
        with pytest.raises(ValueError):
            plu.fit_plu(silu, -8, 8, 1)

    def test_eval_vectorized_matches_scalar(self):
        t = plu.silu_table(32)
        xs = np.linspace(-12, 12, 301, dtype=np.float32)
        batch = t(xs)
        single = np.asarray([t(np.asarray([v], np.float32))[0] for v in xs])
        np.testing.assert_array_equal(batch, single)

    def test_to_dict_round_trips_values(self):
        t = plu.silu_table(8)
        d = t.to_dict()
        assert d["lo"] == t.lo and len(d["slopes"]) == 8

    @settings(max_examples=10, deadline=None)
    @given(x=st.floats(-100, 100))
    def test_everywhere_finite(self, x):
        t = plu.softplus_table(32)
        y = t(np.asarray([x], np.float32))[0]
        assert np.isfinite(y)
