"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

This is the core correctness signal of the compile path: the kernels are
exactly what gets lowered into the AOT artifacts the rust runtime serves.
Hypothesis sweeps shapes; tolerances are tight because interpret-mode
Pallas and the oracle share numerics.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import plu as pluf
from compile.kernels import actiba, cumba, reduba, ref, scan, ssd

RNG = np.random.default_rng(0)


def norm(shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32) * scale)


# --- CumBA -----------------------------------------------------------------


class TestCumba:
    def test_matches_cumsum_paper_shape(self):
        # the 256x256 CumSum_b of Mamba-2 130M
        x = norm((256, 256))
        np.testing.assert_allclose(
            cumba.cumba_cumsum(x), ref.cumsum_ref(x), rtol=2e-5, atol=2e-4)

    def test_mask_semantics(self):
        m = np.asarray(ref.cumba_mask(4))
        expect = np.tril(np.ones((4, 4), np.float32))
        np.testing.assert_array_equal(m, expect)

    def test_cumba_ref_equals_cumsum(self):
        x = norm((32, 8))
        np.testing.assert_allclose(
            ref.cumba_ref(x), ref.cumsum_ref(x), rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        m=st.integers(2, 96),
        n=st.integers(1, 40),
        seed=st.integers(0, 2**31),
    )
    def test_shape_sweep(self, m, n, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(size=(m, n)).astype(np.float32))
        np.testing.assert_allclose(
            cumba.cumba_cumsum(x), ref.cumsum_ref(x), rtol=2e-5, atol=2e-4)

    def test_last_axis_variant(self):
        x = norm((16, 24))
        np.testing.assert_allclose(
            cumba.cumba_cumsum_last(x), jnp.cumsum(x, axis=-1),
            rtol=2e-5, atol=2e-4)

    def test_rejects_bad_rank(self):
        with pytest.raises(ValueError):
            cumba.cumba_cumsum(norm((2, 3, 4)))


# --- ReduBA ----------------------------------------------------------------


class TestReduba:
    def test_matches_reducesum(self):
        x = norm((128, 96))
        np.testing.assert_allclose(
            reduba.reduba_reducesum(x), ref.reducesum_ref(x),
            rtol=2e-5, atol=2e-4)

    def test_reducesum_is_last_cumsum_row(self):
        # paper §2.1: R_j = C_{m,j}
        x = norm((24, 12))
        np.testing.assert_allclose(
            ref.reducesum_ref(x), ref.cumsum_ref(x)[-1], rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        m=st.integers(1, 80),
        n=st.integers(1, 48),
        seed=st.integers(0, 2**31),
    )
    def test_shape_sweep(self, m, n, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(size=(m, n)).astype(np.float32))
        np.testing.assert_allclose(
            reduba.reduba_reducesum(x), ref.reducesum_ref(x),
            rtol=2e-5, atol=3e-4)


# --- ActiBA / PLU ------------------------------------------------------------


class TestActiba:
    @pytest.mark.parametrize("table_fn,exact", [
        (pluf.silu_table, lambda x: x / (1 + np.exp(-x))),
        (pluf.softplus_table, lambda x: np.logaddexp(0, x)),
    ])
    def test_plu_apply_matches_ref_and_exact(self, table_fn, exact):
        t = table_fn(32)
        x = norm((2048,), scale=3.0)
        sl, ic = jnp.asarray(t.slopes), jnp.asarray(t.intercepts)
        got = actiba.plu_apply(x, sl, ic, t.lo, t.hi)
        want = ref.plu_ref(x, sl, ic, t.lo, t.hi)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        err = np.max(np.abs(np.asarray(got) - exact(np.asarray(x))))
        assert err < 0.02, f"PLU-32 error {err} not negligible"

    def test_matmul_plu_fused_drain(self):
        t = pluf.silu_table(32)
        a, w = norm((32, 48)), norm((48, 64))
        sl, ic = jnp.asarray(t.slopes), jnp.asarray(t.intercepts)
        got = actiba.matmul_plu(a, w, sl, ic, t.lo, t.hi, bm=16, bn=32, bk=16)
        want = ref.plu_ref(a @ w, sl, ic, t.lo, t.hi)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    @settings(max_examples=8, deadline=None)
    @given(
        n=st.integers(8, 1024),
        segments=st.sampled_from([8, 16, 32, 64]),
        seed=st.integers(0, 2**31),
    )
    def test_plu_sweep(self, n, segments, seed):
        r = np.random.default_rng(seed)
        t = pluf.silu_table(segments)
        x = jnp.asarray(r.normal(size=n).astype(np.float32) * 5)
        sl, ic = jnp.asarray(t.slopes), jnp.asarray(t.intercepts)
        got = actiba.plu_apply(x, sl, ic, t.lo, t.hi)
        np.testing.assert_allclose(
            got, ref.plu_ref(x, sl, ic, t.lo, t.hi), rtol=1e-5, atol=1e-5)

    def test_out_of_range_uses_tails(self):
        t = pluf.silu_table(16)
        sl, ic = jnp.asarray(t.slopes), jnp.asarray(t.intercepts)
        x = jnp.asarray([-100.0, 100.0], jnp.float32)
        got = np.asarray(actiba.plu_apply(x, sl, ic, t.lo, t.hi))
        assert got[0] == 0.0
        np.testing.assert_allclose(got[1], 100.0, rtol=1e-5)


# --- selective scan (Mamba-1) -------------------------------------------------


class TestScan:
    def _args(self, t, d, n, seed=0):
        r = np.random.default_rng(seed)
        return (
            jnp.asarray(r.normal(size=(t, d)).astype(np.float32)),
            jnp.asarray(r.uniform(0.01, 0.2, size=(t, d)).astype(np.float32)),
            jnp.asarray(-r.uniform(0.5, 2.0, size=(d, n)).astype(np.float32)),
            jnp.asarray(r.normal(size=(t, n)).astype(np.float32)),
            jnp.asarray(r.normal(size=(t, n)).astype(np.float32)),
            jnp.asarray(r.normal(size=(d,)).astype(np.float32)),
        )

    def test_matches_oracle(self):
        x, dt, a, b, c, d = self._args(24, 64, 16)
        h0 = jnp.zeros((64, 16), jnp.float32)
        y1, h1 = scan.selective_scan(x, dt, a, b, c, d, h0, bd=32)
        y2, h2 = ref.selective_scan_ref(x, dt, a, b, c, d)
        np.testing.assert_allclose(y1, y2, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(h1, h2, rtol=2e-5, atol=2e-5)

    def test_state_carry_equals_concatenation(self):
        # scanning [x1; x2] == scan x1 then scan x2 from its final state
        x, dt, a, b, c, d = self._args(16, 32, 8, seed=3)
        h0 = jnp.zeros((32, 8), jnp.float32)
        y_full, h_full = scan.selective_scan(x, dt, a, b, c, d, h0, bd=16)
        y1, h1 = scan.selective_scan(x[:8], dt[:8], a, b[:8], c[:8], d, h0, bd=16)
        y2, h2 = scan.selective_scan(x[8:], dt[8:], a, b[8:], c[8:], d, h1, bd=16)
        np.testing.assert_allclose(
            np.concatenate([y1, y2]), y_full, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(h2, h_full, rtol=2e-4, atol=2e-4)

    def test_scan_equals_stepwise(self):
        x, dt, a, b, c, d = self._args(12, 16, 4, seed=5)
        h = jnp.zeros((16, 4), jnp.float32)
        ys = []
        for t in range(12):
            y_t, h = ref.selective_step_ref(h, x[t], dt[t], a, b[t], c[t], d)
            ys.append(y_t)
        y_ref, h_ref = ref.selective_scan_ref(x, dt, a, b, c, d)
        np.testing.assert_allclose(jnp.stack(ys), y_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(h, h_ref, rtol=1e-4, atol=1e-4)

    @settings(max_examples=6, deadline=None)
    @given(
        t=st.integers(1, 20),
        d=st.sampled_from([8, 16, 48]),
        n=st.sampled_from([4, 8]),
        seed=st.integers(0, 2**31),
    )
    def test_shape_sweep(self, t, d, n, seed):
        x, dt, a, b, c, dd = self._args(t, d, n, seed=seed)
        h0 = jnp.zeros((d, n), jnp.float32)
        y1, h1 = scan.selective_scan(x, dt, a, b, c, dd, h0, bd=8)
        y2, h2 = ref.selective_scan_ref(x, dt, a, b, c, dd)
        np.testing.assert_allclose(y1, y2, rtol=5e-5, atol=5e-5)
        np.testing.assert_allclose(h1, h2, rtol=5e-5, atol=5e-5)


# --- SSD (Mamba-2) ---------------------------------------------------------------


class TestSsd:
    def _args(self, t, h, p, n, seed=0):
        r = np.random.default_rng(seed)
        return (
            jnp.asarray(r.normal(size=(t, h, p)).astype(np.float32)),
            jnp.asarray(r.uniform(0.01, 0.2, size=(t, h)).astype(np.float32)),
            jnp.asarray(-r.uniform(0.5, 2.0, size=(h,)).astype(np.float32)),
            jnp.asarray(r.normal(size=(t, n)).astype(np.float32)),
            jnp.asarray(r.normal(size=(t, n)).astype(np.float32)),
        )

    def test_single_chunk_matches_oracle(self):
        x, dt, a, b, c = self._args(32, 4, 16, 8)
        h0 = jnp.zeros((4, 16, 8), jnp.float32)
        y1, s1 = ssd.ssd_chunk(x, dt, a, b, c, h0)
        y2, s2 = ref.ssd_chunk_ref(x, dt, a, b, c, h0=h0)
        np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)

    def test_multi_chunk_state_carry(self):
        x, dt, a, b, c = self._args(64, 2, 8, 16, seed=2)
        y1, s1 = ssd.ssd(x, dt, a, b, c, chunk=16)
        y2, s2 = ref.ssd_ref(x, dt, a, b, c, chunk=16)
        np.testing.assert_allclose(y1, y2, rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(s1, s2, rtol=3e-4, atol=3e-4)

    def test_chunked_equals_stepwise(self):
        # chunked SSD == token-by-token recurrence (duality check)
        x, dt, a, b, c = self._args(16, 2, 4, 8, seed=7)
        y_c, s_c = ref.ssd_ref(x, dt, a, b, c, chunk=8)
        state = jnp.zeros((2, 4, 8), jnp.float32)
        ys = []
        for t in range(16):
            y_t, state = ref.ssd_step_ref(state, x[t], dt[t], a, b[t], c[t])
            ys.append(y_t)
        np.testing.assert_allclose(jnp.stack(ys), y_c, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(state, s_c, rtol=2e-3, atol=2e-3)

    def test_chunk_size_invariance(self):
        x, dt, a, b, c = self._args(32, 2, 8, 8, seed=9)
        y8, s8 = ssd.ssd(x, dt, a, b, c, chunk=8)
        y16, s16 = ssd.ssd(x, dt, a, b, c, chunk=16)
        np.testing.assert_allclose(y8, y16, rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(s8, s16, rtol=3e-4, atol=3e-4)

    def test_rejects_indivisible_chunk(self):
        x, dt, a, b, c = self._args(10, 2, 4, 4)
        with pytest.raises(ValueError):
            ssd.ssd(x, dt, a, b, c, chunk=4)

    @settings(max_examples=5, deadline=None)
    @given(
        chunk=st.sampled_from([4, 8, 16]),
        h=st.sampled_from([1, 2, 4]),
        seed=st.integers(0, 2**31),
    )
    def test_shape_sweep(self, chunk, h, seed):
        x, dt, a, b, c = self._args(2 * chunk, h, 8, 8, seed=seed)
        y1, s1 = ssd.ssd(x, dt, a, b, c, chunk=chunk)
        y2, s2 = ref.ssd_ref(x, dt, a, b, c, chunk=chunk)
        np.testing.assert_allclose(y1, y2, rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(s1, s2, rtol=5e-4, atol=5e-4)


# --- segsum oracle ------------------------------------------------------------


def test_segsum_definition():
    a = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    s = np.asarray(ref.segsum_ref(a))
    # S[i,j] = sum_{k in (j, i]} a[k]
    assert s[2, 0] == pytest.approx(2.0 + 3.0)
    assert s[3, 1] == pytest.approx(3.0 + 4.0)
    assert s[1, 1] == pytest.approx(0.0)
    assert np.isneginf(s[0, 2])
