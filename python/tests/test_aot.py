"""AOT export path: HLO-text interchange invariants and block programs.

The three interchange gotchas this suite guards (each cost a real debugging
session against xla_extension 0.5.1 — see DESIGN.md):
  1. text, not serialized protos (64-bit instruction ids);
  2. no rank-1 dot operands in kernels (miscompiled to zeros);
  3. print_large_constants=True (elided constants parse as zeros).
"""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, configs, model


class TestHloText:
    def test_no_elided_constants(self):
        """Gotcha #3: `constant({...})` placeholders must never appear."""
        cfg = configs.TINY_MAMBA
        spec = model.build_spec(cfg)
        w = jnp.asarray(spec.pack(model.init_params(cfg)))
        toks = jnp.zeros((8,), jnp.int32)
        c0, s0 = model.zero_states(cfg)
        import functools
        fn = functools.partial(model.prefill, cfg, "xamba")
        lowered = jax.jit(fn).lower(w, toks, c0, s0)
        text = aot.to_hlo_text(lowered)
        assert "constant({...})" not in text, "large constants were elided"
        assert text.startswith("HloModule")

    def test_artifacts_hlo_files_clean(self):
        """If artifacts exist, they must all satisfy the invariant too."""
        if not os.path.exists("../artifacts/manifest.json"):
            pytest.skip("artifacts not built")
        import json
        man = json.load(open("../artifacts/manifest.json"))
        for m in man["models"]:
            text = open(f"../artifacts/{m['hlo']}").read()
            assert "constant({...})" not in text, m["hlo"]


class TestBlockPrograms:
    def test_block_fwd_matches_model_block(self):
        """The exported single-block program equals the in-model block."""
        cfg = configs.TINY_MAMBA2
        wbuf = jnp.asarray(aot.block_init(cfg, seed=3))
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(32, cfg.d_model)).astype(np.float32))
        conv0 = jnp.zeros((cfg.d_conv - 1, cfg.conv_dim), jnp.float32)
        ssm0 = jnp.zeros((cfg.n_heads, cfg.headdim, cfg.d_state), jnp.float32)
        y, c, s = aot.block_fwd(cfg, "baseline", wbuf, x, conv0, ssm0)
        assert y.shape == (32, cfg.d_model)
        assert c.shape == conv0.shape and s.shape == ssm0.shape
        # xamba variant numerically close
        y2, _, _ = aot.block_fwd(cfg, "xamba", wbuf, x, conv0, ssm0)
        assert float(jnp.max(jnp.abs(y - y2))) < 0.5

    def test_block_spec_totals_match_rust(self):
        # asserted against aot.py's printed sizes in rust params.rs tests
        assert aot.block_spec(configs.BLOCK_130M_MAMBA).total == 3_771_648
        assert aot.block_spec(configs.BLOCK_130M_MAMBA2).total == 3_765_320


class TestManifest:
    def test_manifest_covers_all_programs(self):
        if not os.path.exists("../artifacts/manifest.json"):
            pytest.skip("artifacts not built")
        import json
        man = json.load(open("../artifacts/manifest.json"))
        kinds = {(m["name"], m["variant"], m["kind"]) for m in man["models"]}
        for name in ["tiny-mamba", "tiny-mamba2"]:
            for variant in ["baseline", "xamba"]:
                assert (name, variant, "prefill") in kinds
                for b in [1, 2, 4, 8]:
                    assert (name, variant, f"decode_b{b}") in kinds
        # every referenced file exists with plausible size
        for m in man["models"]:
            p = f"../artifacts/{m['hlo']}"
            assert os.path.getsize(p) > 1000, p
            wp = f"../artifacts/{m['weights']}"
            assert os.path.getsize(wp) == 4 * m["weights_len"], wp

    def test_golden_has_prefill_entries(self):
        if not os.path.exists("../artifacts/golden.json"):
            pytest.skip("artifacts not built")
        import json
        g = json.load(open("../artifacts/golden.json"))
        e = g["tiny-mamba.baseline.prefill"]
        assert len(e["tokens"]) == 64
        assert len(e["outputs"][0]["head"]) == 16
        assert np.isfinite(e["outputs"][0]["sum"])
