"""Build-time training path: corpus, batching, and loss descent."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import configs, model, train


class TestCorpus:
    def test_deterministic(self):
        assert train.make_corpus(100, seed=7) == train.make_corpus(100, seed=7)
        assert train.make_corpus(100, seed=7) != train.make_corpus(100, seed=8)

    def test_ascii_byte_range(self):
        c = train.make_corpus(200)
        assert all(b < 128 for b in c)
        assert len(c) > 2000

    def test_batches_shape_and_range(self):
        corpus = train.make_corpus(500)
        for toks in train.batches(corpus, batch=4, steps=3):
            assert toks.shape == (4, train.WINDOW + 1)
            assert toks.dtype == np.int32
            assert toks.min() >= 0 and toks.max() < 256


class TestLoss:
    def test_initial_loss_near_uniform(self):
        cfg = configs.TINY_MAMBA
        spec = model.build_spec(cfg)
        w = jnp.asarray(spec.pack(model.init_params(cfg)))
        toks = next(iter(train.batches(train.make_corpus(300), 2, 1)))
        loss = float(train.loss_fn(cfg, w, jnp.asarray(toks)))
        # random init: close to ln(256) = 5.545
        assert 4.5 < loss < 6.5

    @pytest.mark.slow
    def test_few_steps_decrease_loss(self):
        cfg = configs.TINY_MAMBA
        spec = model.build_spec(cfg)
        w = jnp.asarray(spec.pack(model.init_params(cfg)))
        m = jnp.zeros_like(w)
        v = jnp.zeros_like(w)
        corpus = train.make_corpus(500)
        losses = []
        for i, toks in enumerate(train.batches(corpus, 8, 10), start=1):
            loss, w, m, v = train.train_step(cfg, w, m, v, float(i),
                                             jnp.asarray(toks))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.8, losses

    def test_adam_moves_toward_gradient(self):
        g = jnp.asarray([1.0, -1.0])
        m = jnp.zeros(2)
        v = jnp.zeros(2)
        w = jnp.zeros(2)
        _, _, w2 = train.adam_update(g, m, v, w, step=1.0, lr=0.1)
        assert float(w2[0]) < 0 < float(w2[1])
