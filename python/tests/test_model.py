"""L2 model correctness: prefill/decode state consistency, variant
equivalence, and the flat-buffer parameter ABI shared with rust."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import configs, model

TOKS = jnp.asarray((np.arange(64) * 7 + 3) % 256, jnp.int32)


def _weights(cfg, seed=0):
    spec = model.build_spec(cfg)
    return spec, jnp.asarray(spec.pack(model.init_params(cfg, seed)))


class TestParamSpec:
    def test_totals_match_rust_mirror(self):
        # these constants are asserted on the rust side too (params.rs)
        assert model.build_spec(configs.TINY_MAMBA).total == 266_112
        assert model.build_spec(configs.TINY_MAMBA2).total == 251_952

    def test_pack_unpack_round_trip(self):
        cfg = configs.TINY_MAMBA
        spec = model.build_spec(cfg)
        params = model.init_params(cfg, seed=1)
        buf = spec.pack(params)
        back = spec.unpack(jnp.asarray(buf))
        for name, shape in spec.entries:
            np.testing.assert_array_equal(np.asarray(back[name]), params[name])
            assert back[name].shape == tuple(shape)

    def test_duplicate_name_rejected(self):
        from compile.layers import ParamSpec
        s = ParamSpec()
        s.add("w", (2,))
        with pytest.raises(ValueError):
            s.add("w", (3,))

    def test_pack_shape_mismatch_rejected(self):
        from compile.layers import ParamSpec
        s = ParamSpec()
        s.add("w", (2, 2))
        with pytest.raises(ValueError):
            s.pack({"w": np.zeros((2, 3), np.float32)})


@pytest.mark.parametrize("cfg", [configs.TINY_MAMBA, configs.TINY_MAMBA2],
                         ids=["mamba", "mamba2"])
class TestConsistency:
    def test_prefill_equals_decode_chain(self, cfg):
        """XAMBA Step-1 invariant: the fixed-window prefill model and the
        cached-state decode model implement the same recurrence."""
        _, w = _weights(cfg)
        c0, s0 = model.zero_states(cfg)
        lg_p, c_p, s_p = model.prefill(cfg, "baseline", w, TOKS, c0, s0)
        lg_d, c_d, s_d = None, c0, s0
        for t in range(TOKS.shape[0]):
            lg_d, c_d, s_d = model.decode(cfg, "baseline", w, TOKS[t], c_d, s_d)
        np.testing.assert_allclose(lg_p, lg_d, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(c_p, c_d, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s_p, s_d, rtol=2e-3, atol=2e-3)

    def test_xamba_variant_close_to_baseline(self, cfg):
        """The Pallas/PLU variant must stay within ActiBA's error budget."""
        _, w = _weights(cfg)
        c0, s0 = model.zero_states(cfg)
        lg_b, _, _ = model.prefill(cfg, "baseline", w, TOKS, c0, s0)
        lg_x, _, _ = model.prefill(cfg, "xamba", w, TOKS, c0, s0)
        diff = float(jnp.max(jnp.abs(lg_b - lg_x)))
        assert 0.0 < diff < 1.0, f"variant drift {diff}"

    def test_xamba_mat_variant_is_exact(self, cfg):
        """CumBA/ReduBA kernels without PLU must match baseline exactly
        (they are mathematically identical reformulations)."""
        _, w = _weights(cfg)
        c0, s0 = model.zero_states(cfg)
        lg_b, _, s_b = model.prefill(cfg, "baseline", w, TOKS, c0, s0)
        lg_m, _, s_m = model.prefill(cfg, "xamba-mat", w, TOKS, c0, s0)
        np.testing.assert_allclose(lg_b, lg_m, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s_b, s_m, rtol=1e-3, atol=1e-3)

    def test_state_shapes_match_config(self, cfg):
        ss = model.state_shapes(cfg)
        assert ss["conv"] == (cfg.n_layers, cfg.d_conv - 1, cfg.conv_dim)
        if cfg.arch == "mamba":
            assert ss["ssm"] == (cfg.n_layers, cfg.d_inner, cfg.d_state)
        else:
            assert ss["ssm"] == (
                cfg.n_layers, cfg.n_heads, cfg.headdim, cfg.d_state)

    def test_decode_depends_on_state(self, cfg):
        """Same token, different state -> different logits (the cache is
        actually consulted)."""
        _, w = _weights(cfg)
        c0, s0 = model.zero_states(cfg)
        lg1, c1, s1 = model.decode(cfg, "baseline", w, jnp.int32(5), c0, s0)
        lg2, _, _ = model.decode(cfg, "baseline", w, jnp.int32(5), c1, s1)
        assert float(jnp.max(jnp.abs(lg1 - lg2))) > 1e-3


class TestConfigs:
    def test_presets_consistent(self):
        c = configs.BLOCK_130M_MAMBA2
        assert c.d_inner == 1536
        assert c.n_heads == 24
        assert c.chunk == 256  # the 256x256 CumSum_b
        assert configs.BLOCK_130M_MAMBA.resolved_dt_rank == 48

    def test_conv_dim_covers_xbc(self):
        c = configs.TINY_MAMBA2
        assert c.conv_dim == c.d_inner + 2 * c.d_state
        assert configs.TINY_MAMBA.conv_dim == configs.TINY_MAMBA.d_inner

    def test_unknown_variant_rejected(self):
        with pytest.raises(ValueError):
            model.make_ops(configs.TINY_MAMBA, "nope")
