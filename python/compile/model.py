"""Full-model assembly: embedding, block stack, variant op tables, and the
prefill / decode entrypoints that ``aot.py`` lowers to HLO.

XAMBA Step-1 (paper §2): NPUs want static shapes, so serving uses two
fixed-shape programs — a *prefill* model over a fixed token window (the
coordinator left-pads shorter prompts) that emits last-position logits plus
the recurrent states, and a *decode* model that advances one token from
cached states. Python never runs at serving time; these functions exist
only to be AOT-lowered.

Variants:
  * ``baseline`` — exact SiLU/Softplus, pure-jnp sequential scan / SSD
    with ``jnp.cumsum`` + ``einsum`` (the unoptimized graph of Fig 1).
  * ``xamba``    — ActiBA PLU activations, Pallas scan / SSD kernels with
    the CumBA masked-matmul and ReduBA contraction rewrites inside.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import layers, mamba, mamba2, plu
from .configs import ModelConfig
from .kernels import actiba, ref, scan, ssd


# --- variant op tables --------------------------------------------------------


def _plu_ops(cfg: ModelConfig) -> dict:
    seg, r = cfg.plu_segments, cfg.plu_range
    silu_t = plu.silu_table(seg, -r, r)
    sp_t = plu.softplus_table(seg, -r, r)
    silu_m = jnp.asarray(silu_t.slopes)
    silu_c = jnp.asarray(silu_t.intercepts)
    sp_m = jnp.asarray(sp_t.slopes)
    sp_c = jnp.asarray(sp_t.intercepts)

    def silu_plu(x):
        return actiba.plu_apply(x, silu_m, silu_c, silu_t.lo, silu_t.hi)

    def softplus_plu(x):
        return actiba.plu_apply(x, sp_m, sp_c, sp_t.lo, sp_t.hi)

    return {"silu": silu_plu, "softplus": softplus_plu}


def make_ops(cfg: ModelConfig, variant: str) -> dict:
    """Build the pluggable op table for a model variant."""
    if variant == "baseline":
        return {
            "silu": layers.silu_exact,
            "softplus": layers.softplus_exact,
            "scan": ref.selective_scan_ref,
            "ssd": ref.ssd_ref,
        }
    if variant == "xamba":
        ops = _plu_ops(cfg)
        ops["scan"] = scan.selective_scan
        ops["ssd"] = ssd.ssd
        return ops
    # ablations: activations-only or matrix-rewrites-only
    if variant == "xamba-acti":
        ops = _plu_ops(cfg)
        ops["scan"] = ref.selective_scan_ref
        ops["ssd"] = ref.ssd_ref
        return ops
    if variant == "xamba-mat":
        return {
            "silu": layers.silu_exact,
            "softplus": layers.softplus_exact,
            "scan": scan.selective_scan,
            "ssd": ssd.ssd,
        }
    raise ValueError(f"unknown variant {variant!r}")


# --- parameter layout ---------------------------------------------------------


def build_spec(cfg: ModelConfig) -> layers.ParamSpec:
    spec = layers.ParamSpec()
    spec.add("emb", (cfg.vocab_size, cfg.d_model))
    blk = mamba if cfg.arch == "mamba" else mamba2
    for j in range(cfg.n_layers):
        blk.add_block_params(spec, cfg, j)
    spec.add("final_norm_w", (cfg.d_model,))
    return spec


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    params = {
        "emb": (rng.normal(size=(cfg.vocab_size, cfg.d_model)) * 0.02
                ).astype(np.float32),
        "final_norm_w": np.ones((cfg.d_model,), np.float32),
    }
    blk = mamba if cfg.arch == "mamba" else mamba2
    for j in range(cfg.n_layers):
        params.update(blk.init_block_params(cfg, j, rng))
    return params


def state_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    """Recurrent-state tensor shapes (the serving layer's 'KV cache')."""
    conv = (cfg.n_layers, cfg.d_conv - 1, cfg.conv_dim)
    if cfg.arch == "mamba":
        ssm = (cfg.n_layers, cfg.d_inner, cfg.d_state)
    else:
        ssm = (cfg.n_layers, cfg.n_heads, cfg.headdim, cfg.d_state)
    return {"conv": conv, "ssm": ssm}


# --- forward -------------------------------------------------------------------


def _backbone(cfg: ModelConfig, ops: dict, p: dict, x: jax.Array,
              conv0: jax.Array, ssm0: jax.Array, *, step: bool):
    """Shared block-stack walk for prefill (T, d) and decode (d,)."""
    blk = mamba if cfg.arch == "mamba" else mamba2
    f = blk.block_step if step else blk.block_prefill
    convs, ssms = [], []
    for j in range(cfg.n_layers):
        xn = layers.rmsnorm(x, p[f"l{j}.norm_w"])
        y, c_j, s_j = f(cfg, ops, p, j, xn, conv0[j], ssm0[j])
        x = x + y
        convs.append(c_j)
        ssms.append(s_j)
    x = layers.rmsnorm(x, p["final_norm_w"])
    return x, jnp.stack(convs), jnp.stack(ssms)


def prefill(cfg: ModelConfig, variant: str, wbuf: jax.Array,
            tokens: jax.Array, conv0: jax.Array, ssm0: jax.Array):
    """Fixed-window prefill. tokens: (T,) int32.

    Returns (last_logits (V,), conv' (L,K-1,C), ssm').
    """
    spec = build_spec(cfg)
    p = spec.unpack(wbuf)
    ops = make_ops(cfg, variant)
    x = p["emb"][tokens]  # (T, d_model)
    x, convs, ssms = _backbone(cfg, ops, p, x, conv0, ssm0, step=False)
    logits = x[-1] @ p["emb"].T  # tied head, last position only
    return logits, convs, ssms


def prefill_all_logits(cfg: ModelConfig, variant: str, wbuf: jax.Array,
                       tokens: jax.Array, conv0: jax.Array, ssm0: jax.Array):
    """Prefill that keeps logits at every position (training / eval)."""
    spec = build_spec(cfg)
    p = spec.unpack(wbuf)
    ops = make_ops(cfg, variant)
    x = p["emb"][tokens]
    x, convs, ssms = _backbone(cfg, ops, p, x, conv0, ssm0, step=False)
    return x @ p["emb"].T, convs, ssms


def decode(cfg: ModelConfig, variant: str, wbuf: jax.Array,
           token: jax.Array, conv0: jax.Array, ssm0: jax.Array):
    """Single-token decode step. token: () int32.

    Returns (logits (V,), conv', ssm').
    """
    spec = build_spec(cfg)
    p = spec.unpack(wbuf)
    ops = make_ops(cfg, variant)
    x = p["emb"][token]  # (d_model,)
    x, convs, ssms = _backbone(cfg, ops, p, x, conv0, ssm0, step=True)
    logits = x @ p["emb"].T
    return logits, convs, ssms


def zero_states(cfg: ModelConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    ss = state_shapes(cfg)
    return (jnp.zeros(ss["conv"], jnp.float32),
            jnp.zeros(ss["ssm"], jnp.float32))


def jit_prefill(cfg: ModelConfig, variant: str):
    return jax.jit(functools.partial(prefill, cfg, variant))


def jit_decode(cfg: ModelConfig, variant: str):
    return jax.jit(functools.partial(decode, cfg, variant))
