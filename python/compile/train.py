"""Build-time training of the tiny char-LMs served by the demo.

The paper benchmarks pretrained HuggingFace checkpoints; with no network
access we instead train the same architectures (tiny preset) as byte-level
LMs on a synthetic-but-structured corpus for a few hundred steps, so the
served model has real predictive behaviour (greedy decode completes corpus
patterns) and the quality experiments (Table-1 substitute) have a signal
to degrade. The loss curve lands in ``artifacts/train_log_<name>.txt`` and
EXPERIMENTS.md.

Training uses the ``baseline`` variant (exact activations, pure-jnp scan:
fast to differentiate); the exported weights are shared by all variants —
exactly the paper's setting, where ActiBA approximates a model trained
with exact activations.

Usage: python -m compile.train [--arch mamba|mamba2] [--steps N] [--out DIR]
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model
from .configs import PRESETS, ModelConfig

WINDOW = 64  # training window == serving prefill window


# --- synthetic corpus ---------------------------------------------------------

_WORDS = [
    "state", "space", "models", "scan", "mamba", "npu", "kernel", "mask",
    "cumsum", "matmul", "vector", "chunk", "drain", "tile", "gate", "token",
]

_TEMPLATES = [
    "the {a} {b} runs on the {c} .",
    "a {a} maps the {b} to the {c} .",
    "every {a} needs a {b} and a {c} .",
    "{a} plus {b} gives {c} .",
    "fast {a} , slow {b} , tiny {c} .",
]


def make_corpus(n_sentences: int = 3000, seed: int = 7) -> bytes:
    """Deterministic synthetic corpus with heavy n-gram structure."""
    rng = np.random.default_rng(seed)
    parts = []
    for _ in range(n_sentences):
        t = _TEMPLATES[rng.integers(len(_TEMPLATES))]
        a, b, c = rng.choice(_WORDS, size=3)
        parts.append(t.format(a=a, b=b, c=c))
    return (" ".join(parts)).encode("ascii")


def batches(corpus: bytes, batch: int, steps: int, seed: int = 11):
    """Yield (tokens (B, W+1) int32) training windows."""
    data = np.frombuffer(corpus, dtype=np.uint8).astype(np.int32)
    rng = np.random.default_rng(seed)
    hi = len(data) - WINDOW - 1
    for _ in range(steps):
        idx = rng.integers(0, hi, size=batch)
        yield np.stack([data[i:i + WINDOW + 1] for i in idx])


# --- loss / optimizer ---------------------------------------------------------


def loss_fn(cfg: ModelConfig, wbuf, tokens):
    """Mean next-byte cross-entropy over a (B, W+1) batch."""
    conv0, ssm0 = model.zero_states(cfg)

    def one(seq):
        logits, _, _ = model.prefill_all_logits(
            cfg, "baseline", wbuf, seq[:-1], conv0, ssm0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(
            logp, seq[1:, None], axis=-1))

    return jnp.mean(jax.vmap(one)(tokens))


def adam_update(g, m, v, w, step, lr=2e-3, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * jnp.square(g)
    mhat = m / (1 - b1 ** step)
    vhat = v / (1 - b2 ** step)
    return m, v, w - lr * mhat / (jnp.sqrt(vhat) + eps)


@functools.partial(jax.jit, static_argnums=0)
def train_step(cfg: ModelConfig, wbuf, m, v, step, tokens):
    loss, g = jax.value_and_grad(lambda w: loss_fn(cfg, w, tokens))(wbuf)
    m, v, wbuf = adam_update(g, m, v, wbuf, step)
    return loss, wbuf, m, v


# --- driver --------------------------------------------------------------------


def train(cfg: ModelConfig, steps: int, batch: int, out_dir: str,
          seed: int = 0) -> np.ndarray:
    spec = model.build_spec(cfg)
    wbuf = jnp.asarray(spec.pack(model.init_params(cfg, seed)))
    m = jnp.zeros_like(wbuf)
    v = jnp.zeros_like(wbuf)
    corpus = make_corpus()
    log = []
    t0 = time.time()
    for i, toks in enumerate(batches(corpus, batch, steps), start=1):
        loss, wbuf, m, v = train_step(cfg, wbuf, m, v, float(i),
                                      jnp.asarray(toks))
        if i == 1 or i % 20 == 0 or i == steps:
            log.append((i, float(loss)))
            print(f"[{cfg.name}] step {i:4d}  loss {float(loss):.4f}  "
                  f"({time.time() - t0:.1f}s)")
    w_np = np.asarray(wbuf, dtype=np.float32)
    w_path = f"{out_dir}/weights_{cfg.name}.bin"
    w_np.tofile(w_path)
    with open(f"{out_dir}/train_log_{cfg.name}.txt", "w") as f:
        f.write("step\tloss\n")
        for s, l in log:
            f.write(f"{s}\t{l:.6f}\n")
    print(f"[{cfg.name}] wrote {w_path} ({w_np.size} f32)")
    return w_np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=["mamba", "mamba2", "both"],
                    default="both")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    names = {"mamba": ["tiny-mamba"], "mamba2": ["tiny-mamba2"],
             "both": ["tiny-mamba", "tiny-mamba2"]}[args.arch]
    for name in names:
        train(PRESETS[name], args.steps, args.batch, args.out)


if __name__ == "__main__":
    main()
