"""Piecewise-linear (PLU / C-LUT) fitting of activation functions.

This is the build-time half of ActiBA (paper §2.2): the NPU's Piecewise
Linear Unit evaluates ``f(x) ~= m_k * x + c_k`` over intervals
``[x_k, x_{k+1}]`` using a Configurable Lookup Table (C-LUT) of slopes and
intercepts. We fit the C-LUT here (mirrored bit-for-bit by the rust
``plu::`` module so the simulator and the AOT artifacts agree) and bake the
resulting constants into the ``xamba`` model variants.

Both SiLU and Softplus are non-linear only near the origin and become
linear in the tails (SiLU -> 0 / x, Softplus -> 0 / x), so a modest number
of uniform segments over a clipped core range plus two analytic tail
segments gives max-error well below 1e-2 -- the "negligible quality loss"
regime Table 1 of the paper reports.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PluTable:
    """A C-LUT: ``K`` uniform segments on ``[lo, hi]`` plus linear tails.

    Segment ``k`` covers ``[lo + k*step, lo + (k+1)*step)``. Inputs below
    ``lo`` use segment 0 and inputs at/above ``hi`` use segment ``K-1``;
    the fitters choose tail slopes/intercepts analytically so the clamped
    segments are exact in the limit (not just at the knots).
    """

    lo: float
    hi: float
    slopes: np.ndarray  # (K,) float32
    intercepts: np.ndarray  # (K,) float32

    @property
    def num_segments(self) -> int:
        return int(self.slopes.shape[0])

    @property
    def step(self) -> float:
        return (self.hi - self.lo) / self.num_segments

    def __call__(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float32)
        k = np.clip(
            np.floor((x - self.lo) / self.step).astype(np.int32),
            0,
            self.num_segments - 1,
        )
        return self.slopes[k] * x + self.intercepts[k]

    def as_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        return self.slopes, self.intercepts

    def to_dict(self) -> dict:
        return {
            "lo": self.lo,
            "hi": self.hi,
            "slopes": self.slopes.tolist(),
            "intercepts": self.intercepts.tolist(),
        }


def _secant_fit(f, lo: float, hi: float, segments: int) -> tuple[np.ndarray, np.ndarray]:
    """Slope/intercept per segment from secants through the knots."""
    knots = np.linspace(lo, hi, segments + 1, dtype=np.float64)
    fk = f(knots)
    m = (fk[1:] - fk[:-1]) / (knots[1:] - knots[:-1])
    c = fk[:-1] - m * knots[:-1]
    return m.astype(np.float32), c.astype(np.float32)


def fit_plu(
    f,
    lo: float,
    hi: float,
    segments: int,
    tail_lo: tuple[float, float] | None = None,
    tail_hi: tuple[float, float] | None = None,
) -> PluTable:
    """Fit a C-LUT for ``f`` on ``[lo, hi]`` with uniform ``segments``.

    ``tail_lo`` / ``tail_hi`` are optional analytic ``(slope, intercept)``
    pairs overriding the first / last segment so out-of-range inputs follow
    the function's asymptote instead of extrapolating a secant.
    """
    if segments < 2:
        raise ValueError(f"need >= 2 segments, got {segments}")
    m, c = _secant_fit(f, lo, hi, segments)
    if tail_lo is not None:
        m[0], c[0] = tail_lo
    if tail_hi is not None:
        m[-1], c[-1] = tail_hi
    return PluTable(lo=float(lo), hi=float(hi), slopes=m, intercepts=c)


def silu_table(segments: int = 32, lo: float = -8.0, hi: float = 8.0) -> PluTable:
    """C-LUT for SiLU(x) = x * sigmoid(x). Tails: 0 below, identity above."""

    def silu(x):
        return x / (1.0 + np.exp(-x))

    return fit_plu(
        silu, lo, hi, segments, tail_lo=(0.0, 0.0), tail_hi=(1.0, 0.0)
    )


def softplus_table(
    segments: int = 32, lo: float = -8.0, hi: float = 8.0, beta: float = 1.0
) -> PluTable:
    """C-LUT for Softplus(x) = log(1 + e^{beta x}) / beta."""

    def softplus(x):
        # numerically-stable log1p(exp(.))
        z = beta * x
        return (np.maximum(z, 0.0) + np.log1p(np.exp(-np.abs(z)))) / beta

    return fit_plu(
        softplus, lo, hi, segments, tail_lo=(0.0, 0.0), tail_hi=(1.0, 0.0)
    )


def max_abs_error(table: PluTable, f, n: int = 200_001, span: float = 4.0) -> float:
    """Max |f - plu| over a dense grid extending ``span`` beyond the range."""
    xs = np.linspace(table.lo - span, table.hi + span, n, dtype=np.float64)
    exact = f(xs)
    approx = table(xs.astype(np.float32)).astype(np.float64)
    return float(np.max(np.abs(exact - approx)))
