"""Mamba-2 block (Dao & Gu 2024 SSD framework), prefill and decode paths.

Architecture (per HF ``Mamba2Block``, ngroups=1): a single in_proj emits
[z, x, B, C, dt] at once (the "simultaneous projection" the paper's
appendix A.1 contrasts with Mamba-1's staged projections); depthwise
causal conv + SiLU over the concatenated (x, B, C); Softplus on dt with a
learned bias; chunked SSD with per-head scalar decay; gated RMSNorm;
out_proj.

The ops the paper's Fig 1 flags as Mamba-2's NPU bottlenecks — CumSum
(inside SSD's segsum) and ReduceSum (the chunk-state contractions) — are
inside the pluggable ``ops["ssd"]``: the baseline variant uses the pure-jnp
``jnp.cumsum``/``einsum`` oracle, the xamba variant the Pallas kernel with
CumBA/ReduBA rewrites baked in.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from .configs import ModelConfig
from .kernels import ref


# --- parameters ---------------------------------------------------------------


def add_block_params(spec: layers.ParamSpec, cfg: ModelConfig, j: int) -> None:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    h, k, cd = cfg.n_heads, cfg.d_conv, cfg.conv_dim
    p = f"l{j}."
    spec.add(p + "norm_w", (d,))
    spec.add(p + "in_proj", (d, 2 * di + 2 * n + h))
    spec.add(p + "conv_w", (k, cd))
    spec.add(p + "conv_b", (cd,))
    spec.add(p + "dt_bias", (h,))
    spec.add(p + "a_log", (h,))
    spec.add(p + "d_skip", (h,))
    spec.add(p + "gnorm_w", (di,))
    spec.add(p + "out_proj", (di, d))


def init_block_params(cfg: ModelConfig, j: int,
                      rng: np.random.Generator) -> dict[str, np.ndarray]:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    h, k, cd = cfg.n_heads, cfg.d_conv, cfg.conv_dim
    p = f"l{j}."
    # A init: log-uniform over [1, 16) per head (mamba2 default)
    a_log = np.log(rng.uniform(1.0, 16.0, size=h)).astype(np.float32)
    return {
        p + "norm_w": np.ones((d,), np.float32),
        p + "in_proj": layers.uniform_init(rng, (d, 2 * di + 2 * n + h),
                                           d ** -0.5),
        p + "conv_w": layers.uniform_init(rng, (k, cd), (k) ** -0.5),
        p + "conv_b": np.zeros((cd,), np.float32),
        p + "dt_bias": layers.dt_init(rng, h),
        p + "a_log": a_log,
        p + "d_skip": np.ones((h,), np.float32),
        p + "gnorm_w": np.ones((di,), np.float32),
        p + "out_proj": layers.uniform_init(rng, (di, d), di ** -0.5),
    }


def _split_zxbcdt(cfg: ModelConfig, zxbcdt: jax.Array):
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _split_xbc(cfg: ModelConfig, xbc: jax.Array):
    di, n = cfg.d_inner, cfg.d_state
    return xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]


# --- prefill -------------------------------------------------------------------


def block_prefill(cfg: ModelConfig, ops: dict, p: dict, j: int,
                  x: jax.Array, conv_state: jax.Array, ssm_state: jax.Array):
    """One Mamba-2 block over (T, d_model). Returns (y, conv', ssm')."""
    w = lambda name: p[f"l{j}.{name}"]
    t = x.shape[0]
    h, pd = cfg.n_heads, cfg.headdim

    zxbcdt = x @ w("in_proj")
    z, xbc, dt_raw = _split_zxbcdt(cfg, zxbcdt)

    xbc, conv_state = layers.causal_conv1d(xbc, w("conv_w"), w("conv_b"),
                                           conv_state)
    xbc = ops["silu"](xbc)
    xi, b, c = _split_xbc(cfg, xbc)

    dt = ops["softplus"](dt_raw + w("dt_bias"))  # (T, H)
    a = -jnp.exp(w("a_log"))  # (H,)

    xh = xi.reshape(t, h, pd)
    y, ssm_state = ops["ssd"](xh, dt, a, b, c, cfg.chunk, ssm_state)
    y = y + w("d_skip")[None, :, None] * xh
    y = y.reshape(t, cfg.d_inner)

    y = layers.rmsnorm_gated(y, ops["silu"](z), w("gnorm_w"))
    return y @ w("out_proj"), conv_state, ssm_state


# --- decode --------------------------------------------------------------------


def block_step(cfg: ModelConfig, ops: dict, p: dict, j: int,
               x_t: jax.Array, conv_state: jax.Array, ssm_state: jax.Array):
    """One Mamba-2 block for a single token (d_model,)."""
    w = lambda name: p[f"l{j}.{name}"]
    h, pd = cfg.n_heads, cfg.headdim

    zxbcdt = x_t @ w("in_proj")
    z, xbc, dt_raw = _split_zxbcdt(cfg, zxbcdt)

    xbc, conv_state = layers.causal_conv1d_step(xbc, w("conv_w"),
                                                w("conv_b"), conv_state)
    xbc = ops["silu"](xbc)
    xi, b_t, c_t = _split_xbc(cfg, xbc)

    dt_t = ops["softplus"](dt_raw + w("dt_bias"))  # (H,)
    a = -jnp.exp(w("a_log"))

    xh = xi.reshape(h, pd)
    y_t, ssm_state = ref.ssd_step_ref(ssm_state, xh, dt_t, a, b_t, c_t)
    y_t = y_t + w("d_skip")[:, None] * xh
    y_t = y_t.reshape(cfg.d_inner)

    y_t = layers.rmsnorm_gated(y_t, ops["silu"](z), w("gnorm_w"))
    return y_t @ w("out_proj"), conv_state, ssm_state
