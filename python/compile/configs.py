"""Model configurations for the Mamba / Mamba-2 reproductions.

Shape conventions follow the HuggingFace ``mamba-130m-hf`` /
``mamba2-130m-hf`` checkpoints the paper benchmarks (d_model=768,
expand=2, Mamba-1: d_state=16, dt_rank=48; Mamba-2: d_state=128,
headdim=64, chunk=256 — the 256x256 CumSum_b of paper §2.1 comes from
chunk=256). The ``tiny`` presets keep every architectural knob but shrink
widths so the end-to-end serving demo trains and runs in seconds on CPU.

These configs are mirrored by ``rust/src/config/presets.rs``; the AOT
manifest carries them across the language boundary.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch: str                # "mamba" | "mamba2"
    vocab_size: int
    d_model: int
    n_layers: int
    d_state: int             # N
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0         # mamba-1 only; 0 = d_model // 16
    headdim: int = 64        # mamba-2 only (P)
    chunk: int = 64          # mamba-2 SSD chunk length
    plu_segments: int = 32   # ActiBA C-LUT size for the xamba variant
    plu_range: float = 8.0   # C-LUT core range [-r, r]

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.headdim == 0
        return self.d_inner // self.headdim

    @property
    def conv_dim(self) -> int:
        """Channels through the causal conv (mamba2 convs x, B, C together)."""
        if self.arch == "mamba2":
            return self.d_inner + 2 * self.d_state
        return self.d_inner

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["d_inner"] = self.d_inner
        d["dt_rank_resolved"] = self.resolved_dt_rank
        if self.arch == "mamba2":
            d["n_heads"] = self.n_heads
        d["conv_dim"] = self.conv_dim
        return d


# --- presets ----------------------------------------------------------------

#: Tiny char-LM used by the end-to-end serving demo (trains in ~a minute).
TINY_MAMBA = ModelConfig(
    name="tiny-mamba", arch="mamba", vocab_size=256, d_model=128,
    n_layers=2, d_state=16, dt_rank=8,
)

TINY_MAMBA2 = ModelConfig(
    name="tiny-mamba2", arch="mamba2", vocab_size=256, d_model=128,
    n_layers=2, d_state=32, headdim=32, chunk=16,
)

#: Single-block 130M shapes — the exact tensor dimensions the paper
#: profiles (CumSum_b on 256x256 comes from chunk=256 at seq 256).
BLOCK_130M_MAMBA = ModelConfig(
    name="block130m-mamba", arch="mamba", vocab_size=50280, d_model=768,
    n_layers=1, d_state=16, dt_rank=48,
)

BLOCK_130M_MAMBA2 = ModelConfig(
    name="block130m-mamba2", arch="mamba2", vocab_size=50280, d_model=768,
    n_layers=1, d_state=128, headdim=64, chunk=256,
)

PRESETS: dict[str, ModelConfig] = {
    c.name: c
    for c in [TINY_MAMBA, TINY_MAMBA2, BLOCK_130M_MAMBA, BLOCK_130M_MAMBA2]
}
