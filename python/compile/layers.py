"""Shared layer primitives and the flat-buffer parameter convention.

All model parameters live in ONE flat f32 buffer. The AOT-lowered
functions take ``(wbuf, inputs...)`` so the rust runtime feeds a single
weights literal loaded straight from ``artifacts/weights_<name>.bin`` —
no pytree marshalling crosses the language boundary. ``ParamSpec`` defines
the layout; ``unpack`` turns the buffer back into named arrays with static
slices (free at HLO level: they lower to views).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


# --- flat parameter buffers -------------------------------------------------


class ParamSpec:
    """Ordered (name -> shape) layout of the flat weight buffer."""

    def __init__(self):
        self.entries: list[tuple[str, tuple[int, ...]]] = []
        self._offsets: dict[str, tuple[int, tuple[int, ...]]] = {}
        self._total = 0

    def add(self, name: str, shape: tuple[int, ...]) -> None:
        if name in self._offsets:
            raise ValueError(f"duplicate param {name}")
        size = math.prod(shape)
        self.entries.append((name, shape))
        self._offsets[name] = (self._total, shape)
        self._total += size

    @property
    def total(self) -> int:
        return self._total

    def unpack(self, wbuf: jax.Array) -> dict[str, jax.Array]:
        """Static-slice the flat buffer into named arrays."""
        out = {}
        for name, (off, shape) in self._offsets.items():
            size = math.prod(shape)
            out[name] = jax.lax.dynamic_slice(wbuf, (off,), (size,)).reshape(shape)
        return out

    def pack(self, params: dict[str, np.ndarray]) -> np.ndarray:
        """Concatenate named numpy arrays into the flat buffer."""
        bufs = []
        for name, shape in self.entries:
            arr = np.asarray(params[name], dtype=np.float32)
            if arr.shape != tuple(shape):
                raise ValueError(f"{name}: expected {shape}, got {arr.shape}")
            bufs.append(arr.reshape(-1))
        return np.concatenate(bufs) if bufs else np.zeros((0,), np.float32)

    def manifest(self) -> list[dict]:
        return [
            {"name": n, "shape": list(s), "offset": self._offsets[n][0]}
            for n, s in self.entries
        ]


# --- primitives --------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rmsnorm_gated(x: jax.Array, z_act: jax.Array, w: jax.Array,
                  eps: float = 1e-5) -> jax.Array:
    """Mamba-2's gated norm: rmsnorm(x * act(z)) (act applied by caller)."""
    return rmsnorm(x * z_act, w, eps)


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over (T, C) with carried state.

    ``w``: (K, C) depthwise taps, ``state``: (K-1, C) trailing context of
    the previous segment. Returns (out (T, C), new_state (K-1, C)).
    """
    k = w.shape[0]
    t = x.shape[0]
    xp = jnp.concatenate([state, x], axis=0)  # (K-1+T, C)
    out = b + sum(w[i] * jax.lax.dynamic_slice_in_dim(xp, i, t, 0)
                  for i in range(k))
    new_state = jax.lax.dynamic_slice_in_dim(xp, t, k - 1, 0)
    return out, new_state


def causal_conv1d_step(x_t: jax.Array, w: jax.Array, b: jax.Array,
                       state: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single-token depthwise conv step. state: (K-1, C), x_t: (C,)."""
    window = jnp.concatenate([state, x_t[None, :]], axis=0)  # (K, C)
    out = b + jnp.sum(w * window, axis=0)
    return out, window[1:]


def softplus_exact(x: jax.Array) -> jax.Array:
    return jax.nn.softplus(x)


def silu_exact(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


# --- initialization ----------------------------------------------------------


def uniform_init(rng: np.random.Generator, shape, scale: float) -> np.ndarray:
    return rng.uniform(-scale, scale, size=shape).astype(np.float32)


def dt_init(rng: np.random.Generator, n: int, dt_min: float = 1e-3,
            dt_max: float = 0.1) -> np.ndarray:
    """Mamba's dt bias init: softplus^{-1} of log-uniform samples."""
    dt = np.exp(rng.uniform(np.log(dt_min), np.log(dt_max), size=n))
    # inverse softplus: log(e^x - 1)
    return np.log(np.expm1(dt)).astype(np.float32)
