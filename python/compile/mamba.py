"""Mamba-1 block (Gu & Dao 2024), prefill and single-token decode paths.

Architecture (per HF ``MambaBlock``): in_proj -> (x, z); depthwise causal
conv + SiLU on x; data-dependent (dt, B, C) via x_proj/dt_proj with
Softplus on dt; diagonal selective SSM scan; SiLU(z) gate; out_proj.

The three ops the paper's Fig 1 flags as Mamba-1's NPU bottlenecks — SiLU,
Softplus (DSP-sequential) — enter through the ``ops`` table, so the
``baseline`` variant uses exact activations and the ``xamba`` variant the
ActiBA PLU approximations; the scan itself is likewise pluggable
(pure-jnp sequential oracle vs the Pallas kernel).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from .configs import ModelConfig
from .kernels import ref


# --- parameters ---------------------------------------------------------------


def add_block_params(spec: layers.ParamSpec, cfg: ModelConfig, j: int) -> None:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    r, k = cfg.resolved_dt_rank, cfg.d_conv
    p = f"l{j}."
    spec.add(p + "norm_w", (d,))
    spec.add(p + "in_proj", (d, 2 * di))
    spec.add(p + "conv_w", (k, di))
    spec.add(p + "conv_b", (di,))
    spec.add(p + "x_proj", (di, r + 2 * n))
    spec.add(p + "dt_proj_w", (r, di))
    spec.add(p + "dt_proj_b", (di,))
    spec.add(p + "a_log", (di, n))
    spec.add(p + "d_skip", (di,))
    spec.add(p + "out_proj", (di, d))


def init_block_params(cfg: ModelConfig, j: int,
                      rng: np.random.Generator) -> dict[str, np.ndarray]:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    r, k = cfg.resolved_dt_rank, cfg.d_conv
    p = f"l{j}."
    # S4D-real initialization for A: a_log[c, i] = log(i + 1)
    a_log = np.log(np.tile(np.arange(1, n + 1, dtype=np.float32), (di, 1)))
    return {
        p + "norm_w": np.ones((d,), np.float32),
        p + "in_proj": layers.uniform_init(rng, (d, 2 * di), d ** -0.5),
        p + "conv_w": layers.uniform_init(rng, (k, di), (k * di) ** -0.5 * di ** 0.5),
        p + "conv_b": np.zeros((di,), np.float32),
        p + "x_proj": layers.uniform_init(rng, (di, r + 2 * n), di ** -0.5),
        p + "dt_proj_w": layers.uniform_init(rng, (r, di), r ** -0.5),
        p + "dt_proj_b": layers.dt_init(rng, di),
        p + "a_log": a_log,
        p + "d_skip": np.ones((di,), np.float32),
        p + "out_proj": layers.uniform_init(rng, (di, d), di ** -0.5),
    }


def _split_xdbc(cfg: ModelConfig, xdbc: jax.Array):
    r, n = cfg.resolved_dt_rank, cfg.d_state
    dt = xdbc[..., :r]
    b = xdbc[..., r:r + n]
    c = xdbc[..., r + n:r + 2 * n]
    return dt, b, c


# --- prefill -------------------------------------------------------------------


def block_prefill(cfg: ModelConfig, ops: dict, p: dict, j: int,
                  x: jax.Array, conv_state: jax.Array, ssm_state: jax.Array):
    """One Mamba-1 block over (T, d_model). Returns (y, conv', ssm')."""
    w = lambda name: p[f"l{j}.{name}"]
    xz = x @ w("in_proj")
    xi, z = jnp.split(xz, 2, axis=-1)

    xc, conv_state = layers.causal_conv1d(xi, w("conv_w"), w("conv_b"),
                                          conv_state)
    xc = ops["silu"](xc)

    xdbc = xc @ w("x_proj")
    dt_raw, b, c = _split_xdbc(cfg, xdbc)
    dt = ops["softplus"](dt_raw @ w("dt_proj_w") + w("dt_proj_b"))

    a = -jnp.exp(w("a_log"))
    y, ssm_state = ops["scan"](xc, dt, a, b, c, w("d_skip"), ssm_state)

    y = y * ops["silu"](z)
    return y @ w("out_proj"), conv_state, ssm_state


# --- decode --------------------------------------------------------------------


def block_step(cfg: ModelConfig, ops: dict, p: dict, j: int,
               x_t: jax.Array, conv_state: jax.Array, ssm_state: jax.Array):
    """One Mamba-1 block for a single token (d_model,)."""
    w = lambda name: p[f"l{j}.{name}"]
    xz = x_t @ w("in_proj")
    xi, z = jnp.split(xz, 2, axis=-1)

    xc, conv_state = layers.causal_conv1d_step(xi, w("conv_w"), w("conv_b"),
                                               conv_state)
    xc = ops["silu"](xc)

    xdbc = xc @ w("x_proj")
    dt_raw, b_t, c_t = _split_xdbc(cfg, xdbc)
    dt_t = ops["softplus"](dt_raw @ w("dt_proj_w") + w("dt_proj_b"))

    a = -jnp.exp(w("a_log"))
    y_t, ssm_state = ref.selective_step_ref(ssm_state, xc, dt_t, a, b_t,
                                            c_t, w("d_skip"))
    y_t = y_t * ops["silu"](z)
    return y_t @ w("out_proj"), conv_state, ssm_state
