"""CumBA: CumSum as a tiled masked matmul (paper §2.1).

The paper's observation: on an NPU, CumSum over a (m, n) matrix executes
sequentially on the DSP (m vector-adds plus SRAM round-trips). Multiplying
by a constant lower-triangular mask ``M (m x m), M[i,j] = 1 iff j <= i``
computes the same thing as one dense matmul, ``C = M @ X``, which the MPU's
MAC array executes in parallel with tiled data reuse.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the mask is *constant*, so
we never ship it from HBM at all — each (i, k) tile of it is rematerialized
in VMEM from ``broadcasted_iota``, the Pallas analogue of the paper's
ZVC-compressed mask (zero HBM traffic for the mask beats 50 % compression).
Tiles that are entirely above the diagonal (k-block strictly right of the
i-block) are skipped outright — the "compute skip on the sparsity bitmap"
of Fig 3 — and tiles entirely below it skip mask generation and degenerate
to a plain accumulate-add.

The grid is (m/bm, n/bn, m/bk) with the k axis innermost ("arbitrary"
semantics: sequential accumulation into the output tile, which stays
resident in VMEM across the k sweep — the output-stationary MPU dataflow of
Fig 2(a)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cumba_kernel(x_ref, o_ref, *, bm: int, bk: int):
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    row0 = i * bm
    col0 = k * bk

    # Compute-skip: the whole (bm, bk) mask tile is zero when every column
    # index exceeds every row index (strictly-upper tile). Mirrors the
    # sparsity-bitmap skip of paper Fig 3.
    @pl.when(col0 <= row0 + bm - 1)
    def _compute():
        x_tile = x_ref[...]
        if bk <= bm:
            # Tiles fully on/below the diagonal are all-ones: the matmul
            # degenerates to a running column-sum (no mask materialized).
            rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 0)
            cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
            dense = col0 + bk - 1 <= row0
            mask = jnp.where(dense, jnp.ones((bm, bk), x_tile.dtype),
                             (cols <= rows).astype(x_tile.dtype))
        else:
            rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 0)
            cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
            mask = (cols <= rows).astype(x_tile.dtype)
        o_ref[...] += jax.lax.dot(
            mask, x_tile, precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=o_ref.dtype,
        )


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (VMEM-friendly tiles)."""
    for cand in range(min(target, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def cumba_cumsum(x: jax.Array, *, bm: int = 64, bn: int = 128,
                 bk: int = 64) -> jax.Array:
    """CumSum along axis -2 of a (m, n) matrix via the CumBA masked matmul.

    Equivalent to ``jnp.cumsum(x, axis=-2)`` (oracle: ``ref.cumba_ref``).
    Block sizes are clamped to divisors of the problem shape.
    """
    if x.ndim != 2:
        raise ValueError(f"cumba_cumsum expects (m, n), got {x.shape}")
    m, n = x.shape
    bm = _pick_block(m, bm)
    bk = _pick_block(m, bk)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn, m // bk)
    return pl.pallas_call(
        functools.partial(_cumba_kernel, bm=bm, bk=bk),
        grid=grid,
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x)


def cumba_cumsum_last(x: jax.Array, **kw) -> jax.Array:
    """CumSum along the last axis (transpose-wrapped CumBA)."""
    if x.ndim == 1:
        return cumba_cumsum(x[:, None], **kw)[:, 0]
    if x.ndim != 2:
        raise ValueError(f"expects rank<=2, got {x.shape}")
    return cumba_cumsum(x.T, **kw).T
