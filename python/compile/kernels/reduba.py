"""ReduBA: ReduceSum as a ones-vector MVM (paper §2.1).

ReduceSum over the rows of a (m, n) matrix is ``R = 1_m @ X`` — a
matrix-vector multiply against an all-ones mask vector. On the NPU this
moves the reduction off the sequential DSP onto the MPU's MAC array, and —
unlike CumBA's (m x m) mask — the *same* length-m mask vector is reused by
every output element, so mask traffic is O(m) once, not O(m^2).

TPU adaptation: the ones vector never exists at all; the kernel is a
grid-level reduction where each (bk, bn) input tile folds into a
VMEM-resident (1, bn) accumulator (output-stationary along the reduction
axis, exactly the reuse argument of the paper).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cumba import _pick_block


def _reduba_kernel(x_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # ones(1, bk) @ X(bk, bn) == column-sum of the tile, accumulated.
    o_ref[...] += jnp.sum(x_ref[...], axis=0, keepdims=True)


def reduba_reducesum(x: jax.Array, *, bn: int = 256, bk: int = 128) -> jax.Array:
    """ReduceSum along axis -2 of a (m, n) matrix via the ReduBA MVM.

    Equivalent to ``jnp.sum(x, axis=-2)`` (oracle: ``ref.reduba_ref``).
    """
    if x.ndim != 2:
        raise ValueError(f"reduba_reducesum expects (m, n), got {x.shape}")
    m, n = x.shape
    bk = _pick_block(m, bk)
    bn = _pick_block(n, bn)
    grid = (n // bn, m // bk)
    out = pl.pallas_call(
        _reduba_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bk, bn), lambda j, k: (k, j))],
        out_specs=pl.BlockSpec((1, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype),
        interpret=True,
    )(x)
    return out[0]


def reduba_reducesum_last(x: jax.Array, **kw) -> jax.Array:
    """ReduceSum along the last axis (transpose-wrapped ReduBA)."""
    if x.ndim != 2:
        raise ValueError(f"expects rank 2, got {x.shape}")
    return reduba_reducesum(x.T, **kw)
