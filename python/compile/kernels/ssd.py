"""Mamba-2 SSD intra-chunk kernel (structured state-space duality).

One Pallas program instance per head computes Listing-1 of Dao & Gu (2024)
for a single chunk, with the two XAMBA rewrites applied *inside* the
kernel:

* the chunk cumsum (CumSum_b, >99.9 % of Mamba-2's CumSum time per the
  paper) is computed as a lower-triangular masked matmul — CumBA — so it
  lands on the MXU instead of a sequential loop;
* the chunk-state contraction (the ReduceSum of step 2) is expressed as a
  dense (P, T) @ (T, N) matmul — the batched generalization of ReduBA's
  ones-MVM (the "mask" here carries the decay weights).

Everything for one (head, chunk) fits in VMEM at the paper's shapes
(T=chunk=256, P=64, N=128: ~0.5 MB of f32), so the kernel is single-pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # used instead of -inf: exp(NEG_INF) == 0 without nan risk


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                      y_ref, hout_ref, *, t_len: int):
    x = x_ref[:, 0, :]     # (T, P)
    dt = dt_ref[:, 0]      # (T,)
    a = a_ref[0]           # scalar
    b = b_ref[...]         # (T, N)
    c = c_ref[...]         # (T, N)
    h0 = h0_ref[0]         # (P, N)

    da = dt * a  # (T,)

    # --- CumBA: cumsum(da) as tril-mask @ da (runs on the MXU) ----------
    rows = jax.lax.broadcasted_iota(jnp.int32, (t_len, t_len), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t_len, t_len), 1)
    tril = (cols <= rows).astype(x.dtype)  # (T, T), constant, VMEM-only
    da_cs = jax.lax.dot(
        tril, da[:, None], precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=x.dtype,
    )[:, 0]  # (T,)

    # --- step 1: intra-chunk outputs ------------------------------------
    seg = da_cs[:, None] - da_cs[None, :]  # (T, T)
    seg = jnp.where(cols <= rows, seg, NEG_INF)
    l_mat = jnp.exp(seg)
    scores = jax.lax.dot(c, b.T, precision=jax.lax.Precision.HIGHEST) * l_mat
    xdt = x * dt[:, None]  # (T, P)
    y = jax.lax.dot(scores, xdt, precision=jax.lax.Precision.HIGHEST)

    # --- step 3: contribution of the incoming state ---------------------
    y = y + jax.lax.dot(c, h0.T, precision=jax.lax.Precision.HIGHEST) \
        * jnp.exp(da_cs)[:, None]

    # --- step 2 (ReduBA-style dense contraction): chunk output state ----
    decay = jnp.exp(da_cs[t_len - 1] - da_cs) * dt  # (T,)
    state = jax.lax.dot(
        (x * decay[:, None]).T, b, precision=jax.lax.Precision.HIGHEST,
    )  # (P, N)

    # --- step 4: carry the incoming state through the chunk -------------
    state = state + h0 * jnp.exp(da_cs[t_len - 1])

    y_ref[:, 0, :] = y
    hout_ref[0] = state


def ssd_chunk(
    x: jax.Array,   # (T, H, P)
    dt: jax.Array,  # (T, H)
    a: jax.Array,   # (H,)
    b: jax.Array,   # (T, N)
    c: jax.Array,   # (T, N)
    h0: jax.Array,  # (H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Single-chunk SSD over all heads. Oracle: ``ref.ssd_chunk_ref``.

    Returns ``(y: (T, H, P), state: (H, P, N))``.
    """
    t_len, h, p = x.shape
    n = b.shape[-1]
    y, state = pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, t_len=t_len),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((t_len, 1, p), lambda i: (0, i, 0)),  # x
            pl.BlockSpec((t_len, 1), lambda i: (0, i)),        # dt
            pl.BlockSpec((1,), lambda i: (i,)),                # a
            pl.BlockSpec((t_len, n), lambda i: (0, 0)),        # b (shared)
            pl.BlockSpec((t_len, n), lambda i: (0, 0)),        # c (shared)
            pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),      # h0
        ],
        out_specs=[
            pl.BlockSpec((t_len, 1, p), lambda i: (0, i, 0)),
            pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_len, h, p), x.dtype),
            jax.ShapeDtypeStruct((h, p, n), x.dtype),
        ],
        interpret=True,
    )(x, dt, a, b, c, h0)
    return y, state


def ssd(
    x: jax.Array,   # (T, H, P)
    dt: jax.Array,  # (T, H)
    a: jax.Array,   # (H,)
    b: jax.Array,   # (T, N)
    c: jax.Array,   # (T, N)
    chunk: int,
    h0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Multi-chunk SSD (python loop over chunks; state carried through).

    Oracle: ``ref.ssd_ref``.
    """
    t = x.shape[0]
    if t % chunk:
        raise ValueError(f"T={t} not divisible by chunk={chunk}")
    h, p = x.shape[1], x.shape[2]
    n = b.shape[-1]
    state = jnp.zeros((h, p, n), x.dtype) if h0 is None else h0
    ys = []
    for s in range(0, t, chunk):
        y_c, state = ssd_chunk(
            x[s:s + chunk], dt[s:s + chunk], a, b[s:s + chunk],
            c[s:s + chunk], state,
        )
        ys.append(y_c)
    return jnp.concatenate(ys, axis=0), state
