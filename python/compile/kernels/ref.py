"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: python/tests/ asserts each Pallas
kernel (run under interpret=True) matches its oracle to tight tolerances,
and the rust interpreter's golden tests are generated from the same
functions. Keep these boring and obviously-correct; no Pallas, no tricks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# The two sequential bottleneck ops (paper Fig 1) in their naive form.
# ---------------------------------------------------------------------------


def cumsum_ref(x: jax.Array, axis: int = -2) -> jax.Array:
    """Standard CumSum: C[i, j] = sum_{k<=i} X[k, j] (paper §2.1)."""
    return jnp.cumsum(x, axis=axis)


def reducesum_ref(x: jax.Array, axis: int = -2) -> jax.Array:
    """Standard ReduceSum: R[j] = sum_i X[i, j] = C[m, j] (paper §2.1)."""
    return jnp.sum(x, axis=axis)


# ---------------------------------------------------------------------------
# The XAMBA reformulations, still in pure jnp (mask semantics oracle).
# ---------------------------------------------------------------------------


def cumba_mask(m: int, dtype=jnp.float32) -> jax.Array:
    """Lower-triangular CumBA mask: M[i, j] = 1 if j <= i else 0."""
    return jnp.tril(jnp.ones((m, m), dtype=dtype))


def cumba_ref(x: jax.Array) -> jax.Array:
    """CumSum over the leading axis of a (m, n) matrix as M @ X."""
    m = x.shape[-2]
    return cumba_mask(m, x.dtype) @ x


def reduba_ref(x: jax.Array) -> jax.Array:
    """ReduceSum over the leading axis of a (m, n) matrix as ones @ X."""
    m = x.shape[-2]
    return jnp.ones((m,), x.dtype) @ x


# ---------------------------------------------------------------------------
# Activations: exact + PLU-approximated (ActiBA oracle).
# ---------------------------------------------------------------------------


def silu_ref(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def softplus_ref(x: jax.Array) -> jax.Array:
    return jax.nn.softplus(x)


def plu_ref(x: jax.Array, slopes: jax.Array, intercepts: jax.Array,
            lo: float, hi: float) -> jax.Array:
    """Evaluate a C-LUT: segment k = clip(floor((x-lo)/step)), m_k*x + c_k."""
    k_total = slopes.shape[0]
    step = (hi - lo) / k_total
    k = jnp.clip(jnp.floor((x - lo) / step).astype(jnp.int32), 0, k_total - 1)
    return slopes[k] * x + intercepts[k]


# ---------------------------------------------------------------------------
# Mamba-1 selective scan (sequential oracle, paper appendix A.1).
# ---------------------------------------------------------------------------


def selective_scan_ref(
    x: jax.Array,  # (T, D)       input sequence
    dt: jax.Array,  # (T, D)      post-softplus step sizes
    a: jax.Array,  # (D, N)       state matrix (negative, continuous-time)
    b: jax.Array,  # (T, N)       input projection (selective)
    c: jax.Array,  # (T, N)       output projection (selective)
    d: jax.Array,  # (D,)         skip connection
    h0: jax.Array | None = None,  # (D, N) initial state
) -> tuple[jax.Array, jax.Array]:
    """Sequential selective scan. Returns (y: (T, D), h_T: (D, N)).

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) outer B_t
    y_t = (h_t @ C_t) + D * x_t
    """
    t_len, d_model = x.shape
    n = a.shape[1]
    h = jnp.zeros((d_model, n), x.dtype) if h0 is None else h0

    def step(h, inputs):
        x_t, dt_t, b_t, c_t = inputs
        da = jnp.exp(dt_t[:, None] * a)  # (D, N)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = h @ c_t + d * x_t
        return h, y_t

    h_final, ys = jax.lax.scan(step, h, (x, dt, b, c))
    return ys, h_final


# ---------------------------------------------------------------------------
# Mamba-2 SSD (structured state-space duality), chunked oracle.
# Follows Listing 1 of Dao & Gu (2024), which is what the paper profiles:
# CumSum_b is the segsum cumsum at the start of step 1.
# ---------------------------------------------------------------------------


def segsum_ref(a: jax.Array) -> jax.Array:
    """Segment-sum: S[i, j] = sum_{k in (j, i]} a[k], -inf for j > i.

    This is where CumSum_b lives: a (..., T) vector becomes a (..., T, T)
    matrix through a cumsum and a broadcasted difference.
    """
    t = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    s = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool), k=0)
    return jnp.where(mask, s, -jnp.inf)


def ssd_chunk_ref(
    x: jax.Array,  # (T, H, P)   inputs (heads x headdim)
    dt: jax.Array,  # (T, H)     post-softplus step sizes
    a: jax.Array,  # (H,)        per-head scalar decay (negative)
    b: jax.Array,  # (T, N)      shared-across-heads input proj (ngroups=1)
    c: jax.Array,  # (T, N)      output proj
    h0: jax.Array | None = None,  # (H, P, N) initial state
) -> tuple[jax.Array, jax.Array]:
    """Single-chunk SSD. Returns (y: (T, H, P), state: (H, P, N)).

    Step 1 (intra-chunk):  L = exp(segsum(dt * a)),
                           Y_diag = ((C @ B^T) * L) @ (dt * x)
    Step 2 (chunk state):  decay_states = exp(A_last - A_cumsum),
                           state = (B * decay * dt * x) summed over T
    Steps 3/4: initial-state contribution to outputs + final state carry.
    """
    t, h, p = x.shape
    da = dt * a[None, :]  # (T, H)
    da_cs = jnp.cumsum(da, axis=0)  # (T, H) CumSum_b analogue

    # -- step 1: intra-chunk (assumes zero initial state)
    l_mat = jnp.exp(segsum_ref(da.T))  # (H, T, T)
    cb = c @ b.T  # (T, T)
    scores = cb[None, :, :] * l_mat  # (H, T, T)
    xdt = x * dt[:, :, None]  # (T, H, P)
    y_diag = jnp.einsum("hts,shp->thp", scores, xdt)

    # -- step 2: per-chunk output state
    decay_states = jnp.exp(da_cs[-1, :][None, :] - da_cs)  # (T, H)
    state = jnp.einsum("tn,th,thp->hpn", b, decay_states * dt, x)

    # -- steps 3/4: initial-state contribution to outputs and final state
    if h0 is not None:
        state_decay_out = jnp.exp(da_cs)  # (T, H)
        y_off = jnp.einsum("tn,hpn,th->thp", c, h0, state_decay_out)
        y_diag = y_diag + y_off
        chunk_decay = jnp.exp(da_cs[-1, :])  # (H,)
        state = state + h0 * chunk_decay[:, None, None]

    return y_diag, state


def ssd_ref(
    x: jax.Array,  # (T, H, P)
    dt: jax.Array,  # (T, H)
    a: jax.Array,  # (H,)
    b: jax.Array,  # (T, N)
    c: jax.Array,  # (T, N)
    chunk: int,
    h0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Multi-chunk SSD: split T into chunks, carry state between them."""
    t = x.shape[0]
    assert t % chunk == 0, f"T={t} not divisible by chunk={chunk}"
    h, p = x.shape[1], x.shape[2]
    n = b.shape[-1]
    state = jnp.zeros((h, p, n), x.dtype) if h0 is None else h0
    ys = []
    for s in range(0, t, chunk):
        y_c, state = ssd_chunk_ref(
            x[s : s + chunk], dt[s : s + chunk], a,
            b[s : s + chunk], c[s : s + chunk], h0=state,
        )
        ys.append(y_c)
    return jnp.concatenate(ys, axis=0), state


# ---------------------------------------------------------------------------
# Single-token recurrent steps (decode path) — used to check prefill/decode
# state consistency: prefill(T) must equal T successive decode steps.
# ---------------------------------------------------------------------------


def selective_step_ref(h, x_t, dt_t, a, b_t, c_t, d):
    """One recurrent step of the Mamba-1 SSM. h: (D, N) -> (y_t, h')."""
    da = jnp.exp(dt_t[:, None] * a)
    h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
    return h @ c_t + d * x_t, h


def ssd_step_ref(state, x_t, dt_t, a, b_t, c_t):
    """One recurrent step of the Mamba-2 SSM.

    state: (H, P, N) -> (y_t: (H, P), state').
    """
    da = jnp.exp(dt_t * a)  # (H,)
    state = state * da[:, None, None] + jnp.einsum(
        "hp,n->hpn", x_t * dt_t[:, None], b_t
    )
    y = jnp.einsum("hpn,n->hp", state, c_t)
    return y, state
