"""Mamba-1 selective scan as a Pallas kernel.

The recurrence h_t = exp(dt_t A) h_{t-1} + (dt_t x_t) B_t, y_t = h_t C_t
is inherently sequential in t, but fully parallel across the channel axis
D. The kernel therefore grids over D-tiles; each program instance walks the
whole sequence with its (bd, N) state slice held in the output-state block
(VMEM-resident for the entire walk — zero state traffic to HBM until the
final drain, which is what makes decode cheap on the NPU too).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cumba import _pick_block


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                 y_ref, hout_ref, *, t_len: int):
    a = a_ref[...]          # (bd, N)
    d_skip = d_ref[...]     # (bd,)
    hout_ref[...] = h0_ref[...]

    def step(t, _):
        x_t = x_ref[t, :]    # (bd,)
        dt_t = dt_ref[t, :]  # (bd,)
        b_t = b_ref[t, :]    # (N,)
        c_t = c_ref[t, :]    # (N,)
        h = hout_ref[...]
        da = jnp.exp(dt_t[:, None] * a)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        hout_ref[...] = h
        y_ref[t, :] = h @ c_t + d_skip * x_t
        return ()

    jax.lax.fori_loop(0, t_len, step, ())


def selective_scan(
    x: jax.Array,   # (T, D)
    dt: jax.Array,  # (T, D)
    a: jax.Array,   # (D, N)
    b: jax.Array,   # (T, N)
    c: jax.Array,   # (T, N)
    d: jax.Array,   # (D,)
    h0: jax.Array,  # (D, N)
    *, bd: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Selective scan over (T, D). Oracle: ``ref.selective_scan_ref``.

    Returns ``(y: (T, D), h_T: (D, N))``.
    """
    t_len, d_model = x.shape
    n = a.shape[1]
    bd = _pick_block(d_model, bd)
    grid = (d_model // bd,)
    y, h_t = pl.pallas_call(
        functools.partial(_scan_kernel, t_len=t_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_len, bd), lambda i: (0, i)),  # x
            pl.BlockSpec((t_len, bd), lambda i: (0, i)),  # dt
            pl.BlockSpec((bd, n), lambda i: (i, 0)),      # a
            pl.BlockSpec((t_len, n), lambda i: (0, 0)),   # b (shared)
            pl.BlockSpec((t_len, n), lambda i: (0, 0)),   # c (shared)
            pl.BlockSpec((bd,), lambda i: (i,)),          # d
            pl.BlockSpec((bd, n), lambda i: (i, 0)),      # h0
        ],
        out_specs=[
            pl.BlockSpec((t_len, bd), lambda i: (0, i)),
            pl.BlockSpec((bd, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_len, d_model), x.dtype),
            jax.ShapeDtypeStruct((d_model, n), x.dtype),
        ],
        interpret=True,
    )(x, dt, a, b, c, d, h0)
    return y, h_t
