"""ActiBA: activation functions on the drain-path PLU (paper §2.2).

Swish/SiLU and Softplus dominate Mamba-1's NPU latency because they run
sequentially on the DSP (Fig 1). The NPU's Arithmetic Unit carries a
Piecewise Linear Unit fed by a Configurable LUT of (slope, intercept)
pairs; evaluating ``f(x) ~= m_k x + c_k`` there costs one multiply-add per
element *during the drain phase of the producing matmul* — the intermediate
tensor never round-trips through SRAM ("vertical fusion").

Two kernels:

* ``plu_apply`` — standalone elementwise PLU evaluation (the C-LUT lives
  whole in VMEM; segment index is a clamped affine bucketing, the gather
  stays on-chip).
* ``matmul_plu`` — a tiled matmul whose epilogue applies the PLU to the
  output tile before it is written back: the Pallas rendering of the
  paper's drain-phase fusion (Fig 2(e)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cumba import _pick_block


def _plu_eval(x, slopes, intercepts, lo: float, hi: float):
    """Vectorized C-LUT evaluation: k = clip(floor((x-lo)/step)); m_k x + c_k.

    Segment select is a one-hot contraction, not a gather: (a) it maps onto
    the MAC array exactly like the hardware C-LUT mux does, and (b) the
    gather that ``jnp.take`` lowers to is miscompiled to zeros by the
    xla_extension 0.5.1 backend the rust runtime links (see DESIGN.md
    §Interchange-gotchas).
    """
    k_total = slopes.shape[0]
    step = (hi - lo) / k_total
    k = jnp.clip(jnp.floor((x - lo) * (1.0 / step)).astype(jnp.int32),
                 0, k_total - 1)
    seg = jax.lax.broadcasted_iota(jnp.int32, (k_total,), 0)
    onehot = (k[..., None] == seg).astype(x.dtype)  # (..., K)
    # keep the dot rank-2 on both sides: xla_extension 0.5.1 miscompiles
    # dot_general with a rank-1 rhs to zeros (second interchange gotcha)
    m = (onehot @ slopes.reshape(k_total, 1))[..., 0]
    c = (onehot @ intercepts.reshape(k_total, 1))[..., 0]
    return m * x + c


def _plu_kernel(x_ref, m_ref, c_ref, o_ref, *, lo: float, hi: float):
    o_ref[...] = _plu_eval(x_ref[...], m_ref[...], c_ref[...], lo, hi)


def plu_apply(x: jax.Array, slopes: jax.Array, intercepts: jax.Array,
              lo: float, hi: float, *, block: int = 512) -> jax.Array:
    """Apply a C-LUT piecewise-linear approximation elementwise.

    Oracle: ``ref.plu_ref``. ``slopes``/``intercepts`` are the (K,) C-LUT
    contents (see ``compile.plu``); they are small and block-resident.
    """
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    blk = _pick_block(n, block)
    k_total = slopes.shape[0]
    out = pl.pallas_call(
        functools.partial(_plu_kernel, lo=lo, hi=hi),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((k_total,), lambda i: (0,)),  # whole LUT, every tile
            pl.BlockSpec((k_total,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(flat, slopes, intercepts)
    return out.reshape(shape)


def _matmul_plu_kernel(x_ref, w_ref, m_ref, c_ref, o_ref,
                       *, lo: float, hi: float, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot(
        x_ref[...], w_ref[...], precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=o_ref.dtype,
    )

    # Drain phase: the final k-step applies the PLU as the accumulator tile
    # leaves VMEM — the pre-activation never round-trips through memory.
    @pl.when(k == nk - 1)
    def _drain():
        o_ref[...] = _plu_eval(o_ref[...], m_ref[...], c_ref[...], lo, hi)


def matmul_plu(x: jax.Array, w: jax.Array, slopes: jax.Array,
               intercepts: jax.Array, lo: float, hi: float, *,
               bm: int = 64, bn: int = 128, bk: int = 128) -> jax.Array:
    """``plu(x @ w)`` with the PLU fused into the matmul drain.

    Oracle: ``ref.plu_ref(x @ w, ...)``.
    """
    if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0]:
        raise ValueError(f"bad matmul shapes {x.shape} @ {w.shape}")
    m, kdim = x.shape
    n = w.shape[1]
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(kdim, bk)
    nk = kdim // bk
    k_total = slopes.shape[0]
    return pl.pallas_call(
        functools.partial(_matmul_plu_kernel, lo=lo, hi=hi, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((k_total,), lambda i, j, k: (0,)),
            pl.BlockSpec((k_total,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, slopes, intercepts)
