//! Fig 4(c) reproduction: first-inference latency of the full Mamba 130M
//! model with ActiBA, on the simulated NPU.
//!
//! Paper: Softplus->PLU gives 1.2x; adding SiLU->PLU reaches 2.6x total,
//! with negligible quality loss (quality side: table1_quality bench).

use xamba::config::{npu_series2, presets};
use xamba::npu::Profile;
use xamba::passes::{actiba::ActibaPass, Pass};
use xamba::util::Table;

fn main() {
    let cfg = npu_series2();
    // full 24-layer model: first inference = prefill at T=4
    let g = xamba::models::build_prefill(&presets::mamba130m(), 4);
    let base = Profile::of(&cfg, &g);
    let sp = Profile::of(&cfg, &ActibaPass::softplus_only(32).apply(&g));
    let full = Profile::of(&cfg, &ActibaPass::default().apply(&g));

    let mut t = Table::new(&["variant", "latency", "speedup", "paper"])
        .with_title("Fig 4(c): Mamba 130M first-inference latency with ActiBA");
    for (name, p, paper) in [
        ("baseline", &base, "1.0x"),
        ("SoftPlus→PLU", &sp, "1.2x"),
        ("SoftPlus+SiLU→PLU", &full, "2.6x"),
    ] {
        t.row(&[
            name.to_string(),
            xamba::util::table::fmt_ns(p.total_ns),
            format!("{:.2}x", base.total_ns / p.total_ns),
            paper.to_string(),
        ]);
    }
    println!("{t}");
    println!("breakdown after full ActiBA:");
    println!("{}", full.breakdown_table());

    let s_sp = base.total_ns / sp.total_ns;
    let s_full = base.total_ns / full.total_ns;
    assert!(s_full > s_sp, "adding SiLU must help further");
    assert!((1.05..1.6).contains(&s_sp), "softplus-only {s_sp:.2}x vs paper 1.2x");
    assert!((1.8..3.6).contains(&s_full), "full {s_full:.2}x vs paper 2.6x");
    println!("fig4c_actiba: OK");
}
