//! Speculative-decoding bench: decode throughput with drafts verified
//! in one batched step vs plain one-token-per-step decode.
//!
//! Three legs over the SAME planned-backend nano model and prompt set:
//!
//! * **plain** — `speculate = 0` baseline; its outputs also become the
//!   oracle streams for the next leg.
//! * **high acceptance** — `speculate = K` with an oracle proposer that
//!   drafts the continuation of the recorded stream, so every draft is
//!   accepted (the upper bound a repetitive workload approaches). The
//!   outputs are asserted bitwise equal to the plain leg, and the
//!   speedup over it is the recorded, gated metric.
//! * **low acceptance** — an always-wrong proposer: every verify step
//!   rolls back and re-advances, the worst case. Reported so the cost
//!   of mis-speculation stays visible; outputs again bitwise equal.
//!
//! Run: `cargo bench --bench serve_speculate`
//!
//! CI (`bench-smoke`) runs it with `XAMBA_BENCH_QUICK=1` and
//! `XAMBA_BENCH_JSON=...`, appending throughput, speedup, and
//! acceptance rate to the artifact `xamba bench-check` gates against
//! the committed baseline.

use std::time::{Duration, Instant};

use xamba::config::{ModelShape, ServeConfig};
use xamba::coordinator::{
    FinishReason, GenParams, Metrics, PlannedServeModel, Proposer, ServeModel, Server,
};
use xamba::util::{bench, Table};

/// Small block shapes: the subject is step-rate amortization, not GEMM
/// throughput.
fn nano() -> ModelShape {
    ModelShape {
        name: "nano-mamba".into(),
        arch: "mamba".into(),
        vocab_size: 256,
        d_model: 32,
        n_layers: 2,
        d_state: 8,
        d_conv: 3,
        expand: 2,
        dt_rank: 4,
        headdim: 16,
        chunk: 8,
    }
}

/// Drafts the continuation of a recorded token stream whenever the
/// row's history is a prefix of it: deterministic 100% acceptance.
struct OracleProposer {
    streams: Vec<Vec<i32>>,
}
impl Proposer for OracleProposer {
    fn propose(&mut self, history: &[i32], k: usize) -> Vec<i32> {
        for s in &self.streams {
            if s.len() > history.len() && s[..history.len()] == *history {
                return s[history.len()..(history.len() + k).min(s.len())].to_vec();
            }
        }
        Vec::new()
    }
}

/// Always drafts a fixed wrong token: deterministic 0% acceptance.
struct WrongProposer;
impl Proposer for WrongProposer {
    fn propose(&mut self, history: &[i32], k: usize) -> Vec<i32> {
        // provably never the greedy choice: one past the true token
        // would need the stream itself, so draft a constant and accept
        // whatever rare collisions occur — they only help acceptance
        let _ = history;
        vec![3; k]
    }
}

struct LegResult {
    outs: Vec<Vec<u8>>,
    tok_per_s: f64,
    metrics: Metrics,
}

/// One serving leg: start a fresh server, replay the prompt set once
/// as warmup (compiling every plan the workload demands), then time a
/// second identical replay.
#[allow(clippy::too_many_arguments)]
fn leg(
    shape: &ModelShape,
    weights: &[f32],
    window: usize,
    speculate: i64,
    proposer: Option<Box<dyn Proposer>>,
    prompts: &[Vec<u8>],
    max_new: usize,
) -> LegResult {
    let cfg = ServeConfig {
        max_slots: prompts.len().max(2),
        queue_cap: 64,
        batch_wait_us: 100,
        prefill_window: window,
        // the compile gauge must be deterministic, and the timed replay
        // must NOT resume from the warmup replay's promoted states
        prefix_cache_mb: 0,
        speculate,
        ..Default::default()
    };
    let shape = shape.clone();
    let weights = weights.to_vec();
    let factory = move || {
        Ok(Box::new(PlannedServeModel::new(
            &shape,
            &weights,
            window,
            &[1, 2, 4],
            2,
            "baseline",
        )?) as Box<dyn ServeModel>)
    };
    let server = match proposer {
        Some(p) => Server::start_with_proposer(factory, cfg, p),
        None => Server::start(factory, cfg),
    }
    .expect("start speculate server");

    let run = |timed: bool| -> (Vec<Vec<u8>>, f64) {
        let t0 = Instant::now();
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| {
                server.submit(p, GenParams { max_new_tokens: max_new, ..Default::default() })
            })
            .collect();
        let outs: Vec<Vec<u8>> = rxs
            .into_iter()
            .map(|rx| {
                let r = rx.recv_timeout(Duration::from_secs(300)).expect("response");
                assert_eq!(r.finish, FinishReason::Length);
                r.generated
            })
            .collect();
        let secs = t0.elapsed().as_secs_f64();
        let tokens: usize = outs.iter().map(|o| o.len()).sum();
        (outs, if timed { tokens as f64 / secs } else { 0.0 })
    };
    let (warm_outs, _) = run(false);
    let warm_compiles = server.metrics().plan_compiles;
    let (outs, tok_per_s) = run(true);
    assert_eq!(outs, warm_outs, "replay must be deterministic");
    let metrics = server.shutdown();
    assert_eq!(
        metrics.plan_compiles, warm_compiles,
        "the timed replay demanded a plan the warmup replay did not"
    );
    LegResult { outs, tok_per_s, metrics }
}

fn main() {
    let quick = bench::quick_mode();
    let shape = nano();
    let window = 8usize;
    let weights = PlannedServeModel::random_weights(&shape, 42);
    let n_prompts = if quick { 4 } else { 8 };
    let max_new = if quick { 24 } else { 48 };
    let spec_k = 4i64;
    // distinct window-length prompts (the serving window is 8 bytes)
    let prompts: Vec<Vec<u8>> = (0..n_prompts)
        .map(|i| format!("p{i:02}ababa").into_bytes())
        .collect();
    assert!(prompts.iter().all(|p| p.len() == window));

    // --- plain baseline (also records the oracle streams) --------------
    let plain = leg(&shape, &weights, window, 0, None, &prompts, max_new);

    // --- high acceptance: oracle drafts, every window fully accepted ---
    let streams: Vec<Vec<i32>> = prompts
        .iter()
        .zip(&plain.outs)
        .map(|(p, o)| {
            // byte tokenizer + window-length prompts: bytes are tokens
            p.iter().chain(o.iter()).map(|&b| b as i32).collect()
        })
        .collect();
    let high = leg(
        &shape,
        &weights,
        window,
        spec_k,
        Some(Box::new(OracleProposer { streams })),
        &prompts,
        max_new,
    );
    assert_eq!(
        high.outs, plain.outs,
        "speculative outputs must be bitwise the plain outputs"
    );
    let acceptance = high.metrics.spec_acceptance_rate();
    assert!(
        acceptance > 0.99,
        "oracle drafts must all be accepted (rate {acceptance:.3})"
    );

    // --- low acceptance: every step mis-speculates and rolls back ------
    let low = leg(
        &shape,
        &weights,
        window,
        spec_k,
        Some(Box::new(WrongProposer)),
        &prompts,
        max_new,
    );
    assert_eq!(
        low.outs, plain.outs,
        "mis-speculated outputs must be bitwise the plain outputs"
    );

    let speedup = high.tok_per_s / plain.tok_per_s.max(1e-9);
    let low_ratio = low.tok_per_s / plain.tok_per_s.max(1e-9);
    let mut table = Table::new(&[
        "leg", "tok/s", "vs plain", "accept rate", "tokens/step",
    ])
    .with_title(&format!(
        "serve_speculate: planned backend, K={spec_k} drafts, {n_prompts} x {max_new} tokens"
    ));
    table.row(&[
        "plain (speculate 0)".into(),
        format!("{:.1}", plain.tok_per_s),
        "1.00".into(),
        "-".into(),
        format!("{:.2}", plain.metrics.decode_tokens_per_step()),
    ]);
    table.row(&[
        "high acceptance (oracle)".into(),
        format!("{:.1}", high.tok_per_s),
        format!("{speedup:.2}"),
        format!("{acceptance:.2}"),
        format!("{:.2}", high.metrics.decode_tokens_per_step()),
    ]);
    table.row(&[
        "low acceptance (always wrong)".into(),
        format!("{:.1}", low.tok_per_s),
        format!("{low_ratio:.2}"),
        format!("{:.2}", low.metrics.spec_acceptance_rate()),
        format!("{:.2}", low.metrics.decode_tokens_per_step()),
    ]);
    println!("{table}");

    if let Some(path) = bench::metrics_path() {
        bench::record(
            &path,
            &[
                ("serve_speculate_tok_per_s".to_string(), high.tok_per_s),
                ("serve_speculate_speedup_ratio".to_string(), speedup),
                ("serve_speculate_acceptance_rate".to_string(), acceptance),
            ],
        )
        .expect("record bench metrics");
    }
}
