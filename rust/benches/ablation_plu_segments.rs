//! Ablation (paper §2.2 closing remark): "increasing the number of linear
//! segments ... can further reduce this loss without significantly
//! impacting performance".
//!
//! Sweeps the C-LUT segment count: approximation error falls fast while
//! the simulated latency of the ActiBA-optimized model stays flat (the
//! PLU evaluates one multiply-add regardless of LUT size); the adaptive
//! (Flex-SFU-style) fitter buys extra accuracy at equal budget.

use xamba::config::{npu_series2, presets};
use xamba::npu::Profile;
use xamba::passes::{actiba::ActibaPass, Pass};
use xamba::plu;
use xamba::util::Table;

fn main() {
    let cfg = npu_series2();
    let g = xamba::models::build_block(&presets::block130m_mamba(), 4);
    let base = Profile::of(&cfg, &g).total_ns;

    let mut t = Table::new(&[
        "segments",
        "silu max|err| (uniform)",
        "silu max|err| (adaptive)",
        "block speedup",
    ])
    .with_title("Ablation: PLU segment count — accuracy vs performance");

    let mut errs = Vec::new();
    for segments in [4usize, 8, 16, 32, 64, 128] {
        let uni = plu::silu_table(segments, -8.0, 8.0).max_abs_error(plu::silu_exact, 4.0);
        let ada = plu::fit_adaptive(plu::silu_exact, -8.0, 8.0, segments)
            .max_abs_error(plu::silu_exact);
        let p = Profile::of(&cfg, &ActibaPass::with_segments(segments).apply(&g));
        t.row(&[
            segments.to_string(),
            format!("{uni:.2e}"),
            format!("{ada:.2e}"),
            format!("{:.2}x", base / p.total_ns),
        ]);
        errs.push((segments, uni, ada, base / p.total_ns));
    }
    println!("{t}");

    // error monotone decreasing; speedup flat (paper's claim)
    for w in errs.windows(2) {
        assert!(w[1].1 <= w[0].1 * 1.01, "uniform error not decreasing");
    }
    let speedups: Vec<f64> = errs.iter().map(|e| e.3).collect();
    let spread = speedups.iter().cloned().fold(f64::MIN, f64::max)
        / speedups.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread < 1.05, "latency should be ~flat across LUT sizes: {speedups:?}");
    // adaptive at least matches uniform at every budget
    for &(seg, uni, ada, _) in &errs {
        assert!(ada <= uni * 1.05, "adaptive worse than uniform at {seg}");
    }
    println!("ablation_plu_segments: OK");
}
