//! End-to-end wallclock benchmark on the REAL runtime (PJRT-CPU): prefill
//! and decode latency of the AOT artifacts, baseline vs xamba variants,
//! plus the 130M-shape block programs.
//!
//! This is the liveness measurement plane (DESIGN.md §1): absolute
//! numbers are CPU wallclock, not NPU latency — the paper-shape claims
//! live in the simulator benches. What must hold here is *correct
//! execution at serving speed* and sane batching scaling.

use std::time::Instant;

use xamba::runtime::{Engine, HostTensor, Manifest};
use xamba::util::{Summary, Table};

fn bench<F: FnMut()>(mut f: F, iters: usize) -> Summary {
    // warmup
    f();
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3); // ms
    }
    Summary::of(&samples)
}

fn main() {
    let manifest = Manifest::load("artifacts").expect("run `make artifacts`");
    let mut engine = Engine::cpu().expect("pjrt cpu");
    let mut t = Table::new(&["program", "p50 ms", "mean ms", "p99 ms"])
        .with_title("e2e PJRT-CPU wallclock");

    for model in ["tiny-mamba", "tiny-mamba2"] {
        for variant in ["baseline", "xamba"] {
            // prefill
            let e = manifest.find(model, variant, "prefill").unwrap();
            engine.prepare(&manifest, e).unwrap();
            let tok = HostTensor::I32(vec![64], (0..64).map(|i| i % 256).collect());
            let conv = HostTensor::zeros(&e.inputs[2].shape);
            let ssm = HostTensor::zeros(&e.inputs[3].shape);
            let s = bench(
                || {
                    engine
                        .execute_cached(e, &[tok.clone(), conv.clone(), ssm.clone()])
                        .unwrap();
                },
                10,
            );
            t.row(&[
                format!("{model}.{variant}.prefill"),
                format!("{:.2}", s.p50),
                format!("{:.2}", s.mean),
                format!("{:.2}", s.p99),
            ]);

            // decode buckets: per-sequence cost must IMPROVE with batching
            let mut per_seq = Vec::new();
            for b in manifest.decode_buckets(model, variant) {
                let e = manifest
                    .find(model, variant, &format!("decode_b{b}"))
                    .unwrap();
                engine.prepare(&manifest, e).unwrap();
                let tokb = HostTensor::I32(vec![b, 1], vec![7; b]);
                let convb = HostTensor::zeros(&e.inputs[2].shape);
                let ssmb = HostTensor::zeros(&e.inputs[3].shape);
                let s = bench(
                    || {
                        engine
                            .execute_cached(
                                e,
                                &[tokb.clone(), convb.clone(), ssmb.clone()],
                            )
                            .unwrap();
                    },
                    20,
                );
                per_seq.push((b, s.p50 / b as f64));
                t.row(&[
                    format!("{model}.{variant}.decode_b{b}"),
                    format!("{:.2}", s.p50),
                    format!("{:.2}", s.mean),
                    format!("{:.2}", s.p99),
                ]);
            }
            let first = per_seq.first().unwrap().1;
            let last = per_seq.last().unwrap().1;
            println!(
                "{model}.{variant}: per-seq decode cost b1 {first:.2} ms -> b8 {last:.2} ms ({:.1}x batching gain)",
                first / last
            );
        }
    }

    // 130M-shape block programs (paper shapes through the real runtime)
    for model in ["block130m-mamba", "block130m-mamba2"] {
        for variant in ["baseline", "xamba"] {
            let e = manifest.find(model, variant, "block").unwrap();
            engine.prepare(&manifest, e).unwrap();
            let x = HostTensor::zeros(&e.inputs[1].shape);
            let conv = HostTensor::zeros(&e.inputs[2].shape);
            let ssm = HostTensor::zeros(&e.inputs[3].shape);
            let s = bench(
                || {
                    engine
                        .execute_cached(e, &[x.clone(), conv.clone(), ssm.clone()])
                        .unwrap();
                },
                5,
            );
            t.row(&[
                format!("{model}.{variant}.block(T=256)"),
                format!("{:.2}", s.p50),
                format!("{:.2}", s.mean),
                format!("{:.2}", s.p99),
            ]);
        }
    }
    println!("{t}");
    println!("e2e_pjrt: OK");
}
