//! Per-kernel microbench for the blocked/fused kernel core: GEMM at
//! decode and prefill shapes (f32 / f16-storage / i8), the sequential
//! scan, softmax, and a fused elementwise chain, each against its scalar
//! reference.
//!
//! The scalar columns exist for the printed speedup ratio only; the
//! gated metrics are the blocked kernels' absolute throughputs, with
//! deliberately loose committed floors (machine-independent sanity, not
//! a perf lock — the serve benches own the end-to-end numbers).
//!
//! Run: `cargo bench --bench kernel_micro`
//!
//! CI (`bench-smoke`) runs it with `XAMBA_BENCH_QUICK=1` (smaller shapes,
//! fewer reps) and `XAMBA_BENCH_JSON=BENCH_pr.json`, appending
//! `kernel_micro_*_per_s` keys to the artifact `xamba bench-check`
//! gates against the committed baseline.

use std::time::Instant;

use xamba::exec::{kernels, naive, ExecutionPlan};
use xamba::graph::{Graph, Tensor};
use xamba::util::f16::f32_to_f16;
use xamba::util::{bench, Table};

/// Deterministic pseudo-data in [-0.5, 0.5) — no RNG state to carry.
fn fill(len: usize, salt: usize) -> Vec<f32> {
    (0..len)
        .map(|i| ((i.wrapping_add(salt).wrapping_mul(2654435761)) % 1000) as f32 / 1000.0 - 0.5)
        .collect()
}

/// Repetitions per second of `f` (one untimed warmup call first).
fn reps_per_sec(reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    reps as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

fn gemm_section(metrics: &mut Vec<(String, f64)>) {
    let quick = bench::quick_mode();
    let reps = if quick { 2usize } else { 10 };
    // 130M-class projection shapes: (m, k) x (k, n)
    let (k, n) = if quick { (256usize, 512usize) } else { (768, 1536) };
    let m_prefill = if quick { 64usize } else { 256 };

    let mut table = Table::new(&["shape", "scalar ref", "blocked", "speedup"])
        .with_title("kernel_micro: GEMM (MFLOP/s)");

    for (label, m, key) in [
        ("decode  m=1", 1usize, "kernel_micro_gemm_decode_f32_mflop_per_s"),
        ("prefill", m_prefill, "kernel_micro_gemm_prefill_f32_mflop_per_s"),
    ] {
        let a = fill(m * k, 1);
        let b = fill(k * n, 2);
        let mut out_ref = vec![0.0f32; m * n];
        let mut out_blk = vec![0.0f32; m * n];
        let mflop = (2 * m * k * n) as f64 / 1e6;
        let r_ref = reps_per_sec(reps, || {
            kernels::matmul_ref(&a, &b, &mut out_ref, 1, m, k, n, 0, 0);
        }) * mflop;
        let r_blk = reps_per_sec(reps, || {
            kernels::matmul_out(&a, &b, &mut out_blk, 1, m, k, n, 0, 0);
        }) * mflop;
        assert_eq!(out_ref, out_blk, "{label}: blocked GEMM diverged from reference");
        table.row(&[
            format!("{label} ({m}x{k}x{n})"),
            format!("{r_ref:10.1}"),
            format!("{r_blk:10.1}"),
            format!("{:.2}x", r_blk / r_ref),
        ]);
        metrics.push((key.to_string(), r_blk));
    }

    // f16-storage GEMM: widen once, f32 accumulate, round at store
    {
        let m = m_prefill;
        let af = fill(m * k, 3);
        let bf = fill(k * n, 4);
        let a: Vec<u16> = af.iter().map(|&v| f32_to_f16(v)).collect();
        let b: Vec<u16> = bf.iter().map(|&v| f32_to_f16(v)).collect();
        let mut out = vec![0u16; m * n];
        let mflop = (2 * m * k * n) as f64 / 1e6;
        let r = reps_per_sec(reps, || {
            kernels::matmul_out_g::<u16>(&a, &b, &mut out, 1, m, k, n, 0, 0);
        }) * mflop;
        table.row(&[
            format!("prefill f16 ({m}x{k}x{n})"),
            "-".into(),
            format!("{r:10.1}"),
            "-".into(),
        ]);
        metrics.push(("kernel_micro_gemm_prefill_f16_mflop_per_s".into(), r));
    }

    // i8 GEMM: exact i32 dot products, dequantized by the scale product
    {
        let m = m_prefill;
        let af = fill(m * k, 5);
        let bf = fill(k * n, 6);
        let mut a = vec![0i8; m * k];
        let mut b = vec![0i8; k * n];
        let sa = kernels::quantize_i8_out(&af, &mut a);
        let sb = kernels::quantize_i8_out(&bf, &mut b);
        let mut out = vec![0.0f32; m * n];
        let mflop = (2 * m * k * n) as f64 / 1e6;
        let r = reps_per_sec(reps, || {
            kernels::matmul_i8_out(&a, sa, &b, sb, &mut out, 1, m, k, n, 0, 0);
        }) * mflop;
        table.row(&[
            format!("prefill i8 ({m}x{k}x{n})"),
            "-".into(),
            format!("{r:10.1}"),
            "-".into(),
        ]);
        metrics.push(("kernel_micro_gemm_prefill_i8_mflop_per_s".into(), r));
    }
    println!("{table}");
}

/// In-place reference scan: `out[j] += out[j - 1]` along the axis.
fn cumsum_ref(x: &[f32], out: &mut [f32], outer: usize, n_axis: usize, inner: usize) {
    out.copy_from_slice(x);
    for o in 0..outer {
        for j in 1..n_axis {
            for i in 0..inner {
                out[(o * n_axis + j) * inner + i] += out[(o * n_axis + j - 1) * inner + i];
            }
        }
    }
}

fn scan_softmax_section(metrics: &mut Vec<(String, f64)>) {
    let quick = bench::quick_mode();
    let reps = if quick { 4usize } else { 20 };
    let (rows, cols) = if quick { (256usize, 256usize) } else { (1024, 1024) };
    let melem = (rows * cols) as f64 / 1e6;
    let x = fill(rows * cols, 7);

    let mut table = Table::new(&["kernel", "scalar ref", "lane-chunked", "speedup"])
        .with_title("kernel_micro: scan / softmax (Melem/s)");

    {
        let mut out_ref = vec![0.0f32; rows * cols];
        let mut out = vec![0.0f32; rows * cols];
        let r_ref = reps_per_sec(reps, || {
            cumsum_ref(&x, &mut out_ref, rows, cols, 1);
        }) * melem;
        let r = reps_per_sec(reps, || {
            kernels::cumsum_out(&x, &mut out, rows, cols, 1);
        }) * melem;
        assert_eq!(out_ref, out, "scan diverged from reference");
        table.row(&[
            format!("cumsum ({rows}x{cols})"),
            format!("{r_ref:10.1}"),
            format!("{r:10.1}"),
            format!("{:.2}x", r / r_ref),
        ]);
        metrics.push(("kernel_micro_scan_melem_per_s".into(), r));
    }

    {
        let mut out = vec![0.0f32; rows * cols];
        let r = reps_per_sec(reps, || {
            kernels::softmax_out(&x, &mut out, rows, cols, 1);
        }) * melem;
        table.row(&[
            format!("softmax ({rows}x{cols})"),
            "-".into(),
            format!("{r:10.1}"),
            "-".into(),
        ]);
        metrics.push(("kernel_micro_softmax_melem_per_s".into(), r));
    }
    println!("{table}");
}

fn fused_chain_section(metrics: &mut Vec<(String, f64)>) {
    let quick = bench::quick_mode();
    let reps = if quick { 4usize } else { 20 };
    let len = if quick { 1usize << 16 } else { 1 << 20 };
    let melem = len as f64 / 1e6;

    // add -> silu -> exp: the planner collapses this to ONE fused step
    // (single pass, no intermediate arena round-trips); the naive walker
    // materializes every node
    let mut g = Graph::new("kernel_micro-fused");
    let x = g.input("x", vec![len]);
    let y = g.input("y", vec![len]);
    let h = g.add(x, y, "h");
    let s = g.silu(h, "s");
    let e = g.exp(s, "e");
    g.output(e);

    let inputs = [
        Tensor::f32(vec![len], fill(len, 8)),
        Tensor::f32(vec![len], fill(len, 9)),
    ];
    let mut plan = ExecutionPlan::compile(&g).expect("compile fused chain");
    let fused_out = plan.run(&inputs).expect("fused run");
    let naive_out = naive::run(&g, &inputs).expect("naive run");
    assert_eq!(
        fused_out[0].as_f32(),
        naive_out[0].as_f32(),
        "fused chain diverged from the naive walker"
    );

    let r_naive = reps_per_sec(reps, || {
        naive::run(&g, &inputs).expect("naive run");
    }) * melem;
    let r_fused = reps_per_sec(reps, || {
        plan.run(&inputs).expect("fused run");
    }) * melem;

    let mut table = Table::new(&["chain", "naive walker", "fused", "speedup"])
        .with_title("kernel_micro: fused elementwise chain (Melem/s)");
    table.row(&[
        format!("add+silu+exp ({len} elems)"),
        format!("{r_naive:10.1}"),
        format!("{r_fused:10.1}"),
        format!("{:.2}x", r_fused / r_naive),
    ]);
    println!("{table}");
    metrics.push(("kernel_micro_fused_chain_melem_per_s".into(), r_fused));
}

fn main() {
    let mut metrics: Vec<(String, f64)> = Vec::new();
    gemm_section(&mut metrics);
    scan_softmax_section(&mut metrics);
    fused_chain_section(&mut metrics);
    if let Some(path) = bench::metrics_path() {
        bench::record(&path, &metrics).expect("record bench metrics");
    }
    println!(
        "kernel_micro: blocked kernels verified bitwise against their scalar \
         references before timing."
    );
}
