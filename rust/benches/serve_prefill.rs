//! Serving-prefill micro-bench: batched vs serial admission prefill TTFT
//! on the 130M-class block shapes of BOTH model families at admission
//! rates 1/4/8.
//!
//! Under concurrent admissions the serial path prefills one request at a
//! time, so request i's first token waits for i earlier prefills — mean
//! TTFT grows linearly with the admission rate. The batched path runs
//! one compiled prefill graph per bucket; per-sequence outputs are
//! asserted bitwise-identical to the serial path before timing.
//!
//! Run: `cargo bench --bench serve_prefill`
//!
//! CI (`bench-smoke`) runs it with `XAMBA_BENCH_QUICK=1` (shorter window,
//! one timed iteration) and `XAMBA_BENCH_JSON=BENCH_pr.json`, appending
//! the batched mean TTFT per (family, admission rate) to the artifact
//! `xamba bench-check` gates against the committed baseline.

use std::time::Instant;

use xamba::config::{presets, ModelShape};
use xamba::coordinator::{PlannedServeModel, ServeModel};
use xamba::graph::DType;
use xamba::util::{bench, Table};

fn bench_family(key: &str, label: &str, shape: &ModelShape) {
    let quick = bench::quick_mode();
    let window = if quick { 8usize } else { 16 };
    let iters = if quick { 1usize } else { 3 };
    let rates = [1usize, 4, 8];

    let weights = PlannedServeModel::random_weights(shape, 42);
    let mut model =
        PlannedServeModel::new(shape, &weights, window, &[1], 1, "baseline")
            .expect("model")
            .with_prefill_buckets(&[1, 2, 4, 8])
            .expect("prefill buckets");

    let mut table = Table::new(&[
        "admissions",
        "serial mean TTFT",
        "batched mean TTFT",
        "speedup",
    ])
    .with_title(
        format!("serve_prefill: serial vs batched admission prefill ({label})").as_str(),
    );

    let mut metrics: Vec<(String, f64)> = Vec::new();
    for &r in &rates {
        let prompts: Vec<Vec<i32>> = (0..r)
            .map(|i| (0..window).map(|t| ((i * 13 + t * 7) % 256) as i32).collect())
            .collect();
        let refs: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();

        // correctness gate: batched must reproduce serial bitwise
        {
            let singles: Vec<_> =
                refs.iter().map(|s| model.prefill(s).expect("prefill")).collect();
            let batched = model.prefill_batched(&refs).expect("batched prefill");
            for (i, (a, b)) in singles.iter().zip(&batched).enumerate() {
                assert_eq!(a.0, b.0, "admission {i}: batched logits diverged");
                assert_eq!(a.1, b.1, "admission {i}: batched state diverged");
            }
        }

        // serial: request i's TTFT is the prefix sum of the i+1 prefills
        let mut serial_mean_ms = 0.0f64;
        for _ in 0..iters {
            let mut elapsed = 0.0f64;
            let mut ttft_sum = 0.0f64;
            for s in &refs {
                let t0 = Instant::now();
                model.prefill(s).expect("prefill");
                elapsed += t0.elapsed().as_secs_f64() * 1e3;
                ttft_sum += elapsed;
            }
            serial_mean_ms += ttft_sum / r as f64;
        }
        serial_mean_ms /= iters as f64;

        // batched: every request's first token lands when the round ends
        let mut batched_mean_ms = 0.0f64;
        for _ in 0..iters {
            let t0 = Instant::now();
            model.prefill_batched(&refs).expect("batched prefill");
            batched_mean_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        batched_mean_ms /= iters as f64;

        table.row(&[
            r.to_string(),
            format!("{serial_mean_ms:8.2} ms"),
            format!("{batched_mean_ms:8.2} ms"),
            format!("{:.2}x", serial_mean_ms / batched_mean_ms),
        ]);
        metrics.push((
            format!("serve_prefill_{key}_r{r}_ttft_ms"),
            batched_mean_ms,
        ));
    }
    println!("{table}");
    drop(model);

    // reduced-precision prefill: one batched admission round (rate 4)
    // per serving dtype, against the f32 batched round above
    let qrate = 4usize;
    let prompts: Vec<Vec<i32>> = (0..qrate)
        .map(|i| (0..window).map(|t| ((i * 13 + t * 7) % 256) as i32).collect())
        .collect();
    let refs: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
    let mut qtable = Table::new(&["dtype", "batched mean TTFT (r=4)"]).with_title(
        format!("serve_prefill: quantized admission prefill ({label})").as_str(),
    );
    for dtype in [DType::F16, DType::I8] {
        let mut qmodel = PlannedServeModel::new_dtyped(
            shape,
            &weights,
            window,
            &[1],
            1,
            "baseline",
            dtype,
        )
        .expect("quantized model")
        .with_prefill_buckets(&[1, 4])
        .expect("prefill buckets");
        {
            // sanity gate: quantized batched prefill emits finite logits
            let out = qmodel.prefill_batched(&refs).expect("quantized prefill");
            assert!(
                out.iter().all(|(l, _)| l.iter().all(|v| v.is_finite())),
                "{}: non-finite prefill logits",
                dtype.name()
            );
        }
        let mut ms = 0.0f64;
        for _ in 0..iters {
            let t0 = Instant::now();
            qmodel.prefill_batched(&refs).expect("quantized prefill");
            ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        ms /= iters as f64;
        qtable.row(&[dtype.name().into(), format!("{ms:8.2} ms")]);
        metrics.push((
            format!("serve_prefill_{key}_{}_r{qrate}_ttft_ms", dtype.name()),
            ms,
        ));
    }
    println!("{qtable}");

    if let Some(path) = bench::metrics_path() {
        bench::record(&path, &metrics).expect("record bench metrics");
    }
}

fn main() {
    bench_family("mamba1", "Mamba-1 130M block", &presets::block130m_mamba());
    bench_family("mamba2", "Mamba-2 130M block", &presets::block130m_mamba2());
    println!(
        "serve_prefill: batched prefill is bitwise-identical per sequence to the \
         serial path for both families; TTFT deltas are wall-clock only."
    );
}
