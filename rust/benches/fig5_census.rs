//! Fig 5 (appendix A.1) reproduction: operator census of Mamba vs Mamba-2
//! after conversion.
//!
//! Paper trends: Mamba-2 introduces CumSum/ReduceSum, reduces Gathers and
//! MatMuls (single projection vs staged), and overall shifts away from
//! MPU-friendly ops — which is *why* it is slower on the NPU (Fig 1).

use xamba::config::presets;
use xamba::graph::Census;

fn main() {
    let t = 4;
    let g1 = xamba::models::build_block(&presets::block130m_mamba(), t);
    let g2 = xamba::models::build_block(&presets::block130m_mamba2(), t);
    let c1 = Census::of(&g1);
    let c2 = Census::of(&g2);
    println!(
        "{}",
        Census::comparison_table(&[
            (&format!("mamba 130M block (T={t})"), &c1),
            (&format!("mamba2 130M block (T={t})"), &c2),
        ])
    );

    // full-model census too (gathers appear at the embedding level)
    let f1 = Census::of(&xamba::models::build_prefill(&presets::mamba130m(), t));
    let f2 = Census::of(&xamba::models::build_prefill(&presets::mamba2_130m(), t));
    println!(
        "{}",
        Census::comparison_table(&[
            ("mamba 130M full", &f1),
            ("mamba2 130M full", &f2),
        ])
    );

    // paper's direction-of-change claims
    assert_eq!(c1.get("CumSum"), 0);
    assert!(c2.get("CumSum") >= 2, "mamba2 introduces CumSum");
    assert!(c2.get("ReduceSum") >= 1, "mamba2 introduces ReduceSum");
    assert!(
        c2.get("MatMul") < c1.get("MatMul"),
        "mamba2 has fewer MatMuls (single projection)"
    );
    println!("fig5_census: OK (operator-shift direction matches paper)");
}
