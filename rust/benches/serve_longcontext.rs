//! Long-context serving bench: chunked streaming prefill + the
//! prompt-prefix state cache.
//!
//! Two claims get numbers (and correctness gates) here:
//!
//! * **Cold long prompts** stream through fixed-size resume-chunk graphs,
//!   so arena memory is bounded by the chunk — the chunk plan's arena is
//!   asserted strictly below a monolithic window plan's — while outputs
//!   stay bitwise identical to monolithic prefill (gated per family
//!   before timing).
//! * **Multi-turn chat** resumes the previous turn's cached state: turn
//!   2 prefills only its new suffix instead of re-prefilling the whole
//!   history. The prefix-cache hit counter is asserted, and in full mode
//!   the resume TTFT must beat a cold re-prefill of the same prompt by
//!   >= 3x at a 4k-token history.
//!
//! Run: `cargo bench --bench serve_longcontext`
//!
//! CI (`bench-smoke`) runs it with `XAMBA_BENCH_QUICK=1` (smaller
//! window / history, ratio assert relaxed) and `XAMBA_BENCH_JSON=...`,
//! appending the chunked cold TTFT and the turn-2 resume TTFT to the
//! artifact `xamba bench-check` gates against the committed baseline.

use std::time::{Duration, Instant};

use xamba::config::{ModelShape, ServeConfig};
use xamba::coordinator::{
    FinishReason, GenParams, PlannedServeModel, ServeModel, Server,
};
use xamba::util::{bench, Table};

/// Small block shapes: the subject here is scheduling + state reuse,
/// not GEMM throughput, so token counts scale up instead of widths.
fn nano(arch: &str) -> ModelShape {
    ModelShape {
        name: format!("nano-{arch}"),
        arch: arch.into(),
        vocab_size: 256,
        d_model: 32,
        n_layers: 2,
        d_state: 8,
        d_conv: 3,
        expand: 2,
        dt_rank: 4,
        headdim: 16,
        chunk: 8,
    }
}

fn tokens(len: usize, seed: usize) -> Vec<i32> {
    (0..len).map(|t| ((seed * 31 + t * 7) % 256) as i32).collect()
}

/// Printable chat-history bytes (byte-level tokenizer: identity on these).
fn history_bytes(len: usize) -> Vec<u8> {
    (0..len).map(|i| ((i * 7 + 11) % 94 + 32) as u8).collect()
}

fn main() {
    let quick = bench::quick_mode();
    // (compiled window = bitwise-gate length, chunk, cold prompt, history)
    let (window, chunk, cold_len, history) =
        if quick { (32usize, 16usize, 384usize, 96usize) } else { (256, 128, 32768, 4096) };
    let mut metrics: Vec<(String, f64)> = Vec::new();
    let mut table = Table::new(&["case", "value"]).with_title(
        format!(
            "serve_longcontext: chunked prefill + prefix-cache resume \
             (window {window}, chunk {chunk})"
        )
        .as_str(),
    );

    // --- cold long-context prefill (bitwise-gated, arena-bounded) ------------
    for shape in [nano("mamba"), nano("mamba2")] {
        let weights = PlannedServeModel::random_weights(&shape, 42);
        let mut mono =
            PlannedServeModel::new(&shape, &weights, window, &[1], 1, "baseline")
                .expect("monolithic model");
        let mut chunked =
            PlannedServeModel::new(&shape, &weights, window, &[1], 1, "baseline")
                .expect("chunked model")
                .with_prefill_chunk(chunk)
                .expect("prefill chunk");

        // correctness gate: chunked must reproduce monolithic bitwise
        let p = tokens(window, 1);
        let (want_logits, want_state) = mono.prefill(&p).expect("monolithic prefill");
        let (logits, state) =
            chunked.prefill_resume(&p, None, &mut |_, _| {}).expect("chunked prefill");
        assert!(
            want_logits.iter().zip(&logits).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{}: chunked prefill logits diverged from monolithic",
            shape.name
        );
        assert_eq!(want_state, state, "{}: chunked prefill state diverged", shape.name);

        if shape.arch == "mamba" {
            let long = tokens(cold_len, 2);
            let t0 = Instant::now();
            chunked.prefill_resume(&long, None, &mut |_, _| {}).expect("cold prefill");
            let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
            // arena bound: however long the prompt, the streaming path
            // only ever runs window/chunk-sized plans
            let chunk_arena = chunked
                .plan_arena_bytes(&format!("prefill_resume_t{chunk}"))
                .expect("resume-chunk plan compiled");
            let mono_arena =
                mono.plan_arena_bytes("prefill").expect("monolithic plan compiled");
            assert!(
                chunk_arena < mono_arena,
                "chunk arena {chunk_arena} B not below monolithic window arena \
                 {mono_arena} B"
            );
            table.row(&[
                format!("cold {cold_len}-token chunked prefill"),
                format!("{cold_ms:8.2} ms"),
            ]);
            table.row(&[
                "chunk arena / window arena".into(),
                format!("{chunk_arena} B / {mono_arena} B"),
            ]);
            metrics
                .push(("serve_longcontext_mamba1_cold_chunked_ttft_ms".into(), cold_ms));
        }
    }

    // --- 3-turn chat: resume vs cold re-prefill ------------------------------
    let shape = nano("mamba");
    let weights = PlannedServeModel::random_weights(&shape, 7);
    let serve_cfg = |cache_mb: usize| ServeConfig {
        max_slots: 2,
        queue_cap: 8,
        batch_wait_us: 100,
        prefill_window: window,
        prefix_cache_mb: cache_mb,
        prefill_chunk: chunk,
        ..Default::default()
    };
    let start = |cfg: ServeConfig| {
        let (shape, weights) = (shape.clone(), weights.clone());
        Server::start(
            move || {
                Ok(Box::new(
                    PlannedServeModel::new(&shape, &weights, window, &[1], 1, "baseline")?
                        .with_prefill_chunk(chunk)?,
                ) as Box<dyn ServeModel>)
            },
            cfg,
        )
        .expect("server")
    };
    let gen = || GenParams { max_new_tokens: 4, ..Default::default() };
    let timeout = Duration::from_secs(600);

    let cached = start(serve_cfg(64));
    let p1 = history_bytes(history);
    let r1 = cached.submit(&p1, gen()).recv_timeout(timeout).expect("turn 1");
    assert_eq!(r1.finish, FinishReason::Length);
    let mut p2 = p1.clone();
    p2.extend_from_slice(&r1.generated);
    p2.extend_from_slice(b" tell me more about it");
    let r2 = cached.submit(&p2, gen()).recv_timeout(timeout).expect("turn 2");
    let mut p3 = p2.clone();
    p3.extend_from_slice(&r2.generated);
    p3.extend_from_slice(b" go on");
    let r3 = cached.submit(&p3, gen()).recv_timeout(timeout).expect("turn 3");
    assert_eq!(r3.finish, FinishReason::Length);
    let m = cached.shutdown();
    assert!(
        m.prefix_hits >= 2,
        "turns 2 and 3 must hit the prefix cache (hits {}, misses {})",
        m.prefix_hits,
        m.prefix_misses
    );
    assert!(
        m.resumed_tokens >= history as u64,
        "turn 2 must resume the whole history, resumed only {}",
        m.resumed_tokens
    );

    // control: an identical server with the prefix cache disabled pays a
    // full chunked re-prefill of the same turn-2 prompt
    let control = start(serve_cfg(0));
    let rc = control.submit(&p2, gen()).recv_timeout(timeout).expect("cold turn 2");
    assert_eq!(rc.finish, FinishReason::Length);
    control.shutdown();

    let resume_ms = r2.ttft_us / 1e3;
    let cold_ms = rc.ttft_us / 1e3;
    table.row(&[
        format!("turn-2 TTFT, resumed ({history}-token history)"),
        format!("{resume_ms:8.2} ms"),
    ]);
    table.row(&["turn-2 TTFT, cold re-prefill".into(), format!("{cold_ms:8.2} ms")]);
    table.row(&["resume speedup".into(), format!("{:.2}x", cold_ms / resume_ms)]);
    if !quick {
        assert!(
            cold_ms >= 3.0 * resume_ms,
            "resume speedup below 3x at a {history}-token history: \
             cold {cold_ms:.2} ms vs resumed {resume_ms:.2} ms"
        );
    }
    metrics.push(("serve_longcontext_mamba1_resume_turn2_ttft_ms".into(), resume_ms));

    println!("{table}");
    println!(
        "serve_longcontext: chunked prefill is bitwise-identical to monolithic for \
         both families; turn-2 hits resume cached state in O(new tokens)."
    );
    if let Some(path) = bench::metrics_path() {
        bench::record(&path, &metrics).expect("record bench metrics");
    }
}
