//! Fig 4(a) reproduction: single-block Mamba-2 130M latency with CumBA,
//! ReduBA, and both, vs the unoptimized baseline.
//!
//! Paper: CumBA 2.7x, ReduBA 1.2x, CumBA+ReduBA 4.8x.

use xamba::config::{npu_series2, presets};
use xamba::npu::Profile;
use xamba::passes::{cumba::CumbaPass, reduba::RedubaPass, Pass};
use xamba::util::Table;

fn main() {
    let cfg = npu_series2();
    let g = xamba::models::build_block(&presets::block130m_mamba2(), 4);
    let base = Profile::of(&cfg, &g);
    let cumba = Profile::of(&cfg, &CumbaPass.apply(&g));
    let reduba = Profile::of(&cfg, &RedubaPass.apply(&g));
    let both = Profile::of(&cfg, &RedubaPass.apply(&CumbaPass.apply(&g)));

    let mut t = Table::new(&["variant", "latency", "speedup", "paper"])
        .with_title("Fig 4(a): Mamba-2 130M single block, T=4 (simulated NPU)");
    let rows = [
        ("baseline", base.total_ns, 1.0, "1.0x"),
        ("CumBA", cumba.total_ns, base.total_ns / cumba.total_ns, "2.7x"),
        ("ReduBA", reduba.total_ns, base.total_ns / reduba.total_ns, "1.2x"),
        ("CumBA+ReduBA", both.total_ns, base.total_ns / both.total_ns, "4.8x"),
    ];
    for (name, ns, speedup, paper) in rows {
        t.row(&[
            name.to_string(),
            xamba::util::table::fmt_ns(ns),
            format!("{speedup:.2}x"),
            paper.to_string(),
        ]);
    }
    println!("{t}");

    // shape assertions: ordering and rough factors must match the paper
    let s_cumba = base.total_ns / cumba.total_ns;
    let s_reduba = base.total_ns / reduba.total_ns;
    let s_both = base.total_ns / both.total_ns;
    assert!(s_cumba > s_reduba, "CumBA must beat ReduBA");
    assert!(s_both > s_cumba, "combined must beat each alone");
    assert!((2.0..4.5).contains(&s_cumba), "CumBA {s_cumba:.2}x vs paper 2.7x");
    assert!((1.02..1.6).contains(&s_reduba), "ReduBA {s_reduba:.2}x vs paper 1.2x");
    assert!((3.5..6.5).contains(&s_both), "both {s_both:.2}x vs paper 4.8x");
    println!("fig4a_speedup: OK (who-wins and factors in paper range)");
}
