//! Replicated-serving bench: multi-turn session traffic through the
//! `coordinator::router` front-end over a 2-replica planned-backend
//! fleet.
//!
//! The workload is the router's reason to exist: concurrent
//! conversations whose follow-up turns carry a `session_id`. Affinity
//! routes each follow-up to the replica holding the conversation's
//! recurrent state, so it resumes from the prefix cache in O(new
//! tokens) — the numbers here put fleet throughput and TTFT behind CI's
//! regression gate, and the run asserts the residency actually
//! happened (`affinity_hits`, `resumed_tokens`) rather than trusting
//! the topology.
//!
//! Run: `cargo bench --bench serve_router`
//!
//! CI (`bench-smoke`) runs it with `XAMBA_BENCH_QUICK=1` and
//! `XAMBA_BENCH_JSON=...`, appending fleet throughput and TTFT p95 to
//! the artifact `xamba bench-check` gates against the committed
//! baseline.

use std::time::{Duration, Instant};

use xamba::config::{ModelShape, ServeConfig};
use xamba::coordinator::{
    EngineReplica, FinishReason, GenParams, PlannedServeModel, ReplicaHandle, Router,
    ServeModel,
};
use xamba::util::{bench, Table};

/// Small block shapes: the subject is fleet scheduling, not GEMM
/// throughput.
fn nano() -> ModelShape {
    ModelShape {
        name: "nano-mamba".into(),
        arch: "mamba".into(),
        vocab_size: 256,
        d_model: 32,
        n_layers: 2,
        d_state: 8,
        d_conv: 3,
        expand: 2,
        dt_rank: 4,
        headdim: 16,
        chunk: 8,
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

fn main() {
    let quick = bench::quick_mode();
    let sessions = if quick { 3 } else { 6 };
    let turns = if quick { 2 } else { 4 };

    let shape = nano();
    let window = 8usize;
    let weights = PlannedServeModel::random_weights(&shape, 42);
    let router = Router::start(2, 32, move |i| {
        let shape = shape.clone();
        let weights = weights.clone();
        let cfg = ServeConfig {
            max_slots: 8,
            queue_cap: 64,
            batch_wait_us: 100,
            prefill_window: window,
            ..Default::default()
        };
        let replica = EngineReplica::start(
            move || {
                Ok(Box::new(
                    PlannedServeModel::new(
                        &shape,
                        &weights,
                        window,
                        &[1, 2, 4],
                        1,
                        "baseline",
                    )?
                    .with_prefill_chunk(4)?,
                ) as Box<dyn ServeModel>)
            },
            cfg,
            format!("replica{i}:nano-mamba:baseline:f32"),
        )?;
        Ok(Box::new(replica) as Box<dyn ReplicaHandle>)
    })
    .expect("start replicated fleet");

    // warmup: concurrent no-session requests spread across both replicas
    // compile the chunk-prefill and small decode plans off the clock
    let warm: Vec<_> = (0..4)
        .map(|_| {
            router.submit(
                b"warmup prompt bytes",
                GenParams { max_new_tokens: 4, ..Default::default() },
            )
        })
        .collect();
    for rx in warm {
        let r = rx.recv_timeout(Duration::from_secs(300)).expect("warmup");
        assert_eq!(r.finish, FinishReason::Length);
    }

    // measured phase: every session submits each turn concurrently;
    // follow-up prompts extend the conversation (history ++ reply ++
    // new text), so affinity + prefix residency are on the clocked path
    let mut histories: Vec<Vec<u8>> =
        (0..sessions).map(|i| format!("session{i:02}: hello").into_bytes()).collect();
    let t0 = Instant::now();
    let mut tokens = 0usize;
    let mut ttfts_ms: Vec<f64> = Vec::new();
    for _turn in 0..turns {
        let rxs: Vec<_> = histories
            .iter()
            .enumerate()
            .map(|(i, h)| {
                router.submit(
                    h,
                    GenParams {
                        max_new_tokens: 4,
                        session_id: Some(i as u64),
                        ..Default::default()
                    },
                )
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(300)).expect("turn response");
            assert_eq!(r.finish, FinishReason::Length);
            tokens += r.generated.len();
            ttfts_ms.push(r.ttft_us / 1e3);
            histories[i].extend_from_slice(&r.generated);
            histories[i].extend_from_slice(b" and more");
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let m = router.shutdown();

    // the topology must have done its job, not just finished
    assert_eq!(m.failed, 0, "fleet dropped requests");
    assert_eq!(m.router_rebalanced, 0, "steady-state traffic rebalanced");
    let follow_ups = (sessions * (turns - 1)) as u64;
    assert!(
        m.affinity_hits >= follow_ups,
        "only {} of {} follow-ups rode their session pin",
        m.affinity_hits,
        follow_ups
    );
    assert!(m.resumed_tokens > 0, "no follow-up resumed from the prefix cache");

    ttfts_ms.sort_by(|a, b| a.total_cmp(b));
    let tok_per_s = tokens as f64 / elapsed;
    let p95 = percentile(&ttfts_ms, 0.95);
    let mut table = Table::new(&["metric", "value"])
        .with_title("serve_router: 2-replica fleet, multi-turn session traffic");
    table.row(&["replicas".into(), "2".into()]);
    table.row(&["sessions x turns".into(), format!("{sessions} x {turns}")]);
    table.row(&["tokens out".into(), tokens.to_string()]);
    table.row(&["throughput".into(), format!("{tok_per_s:.1} tok/s")]);
    table.row(&["ttft p95".into(), format!("{p95:.1} ms")]);
    table.row(&["affinity hits".into(), m.affinity_hits.to_string()]);
    table.row(&["resumed tokens".into(), m.resumed_tokens.to_string()]);
    println!("{table}");

    if let Some(path) = bench::metrics_path() {
        bench::record(
            &path,
            &[
                ("serve_router_tok_per_s".to_string(), tok_per_s),
                ("serve_router_ttft_p95_ms".to_string(), p95),
            ],
        )
        .expect("record bench metrics");
    }
}
