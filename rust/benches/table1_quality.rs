//! Table 1 reproduction (substituted, DESIGN.md §1): quality of the
//! ActiBA PLU variants vs exact activations on the trained tiny models.
//!
//! Paper: avg accuracy drop < 1.5% for 130M, ~0 at larger sizes; PLU-32
//! is the shipped configuration. Here: next-byte PPL / top-1 accuracy on
//! held-out synthetic corpus via the rust interpreter.

use xamba::config::presets;
use xamba::models::{self, params};
use xamba::passes::{actiba::ActibaPass, Pass};
use xamba::quality::eval_lm;
use xamba::util::{corpus, Table};

fn main() {
    let window = 64usize;
    let max_windows = 8; // bench-sized; examples/quality_eval.rs runs more
    let workers = 4; // pooled window eval; bitwise-identical to serial
    let text = corpus::corpus(1200, 1234);
    let mut table = Table::new(&["model", "PPL ↓", "ACC ↑", "Δacc vs exact"])
        .with_title("Table 1 (substitute): PLU quality on held-out corpus");

    for name in ["tiny-mamba", "tiny-mamba2"] {
        let shape = presets::model_by_name(name).unwrap();
        let weights = params::load_f32_bin(&format!("artifacts/weights_{name}.bin"))
            .expect("run `make artifacts` first");
        let g = models::build_prefill(&shape, window);
        let (exact, _) =
            eval_lm(&shape, &g, &weights, &text, window, max_windows, None, workers)
                .expect("exact eval");
        table.row(&[
            format!("{name} (exact)"),
            format!("{:.3}", exact.ppl),
            format!("{:.4}", exact.top1),
            "-".into(),
        ]);
        let gp = ActibaPass::with_segments(32).apply(&g);
        let (plu, _) =
            eval_lm(&shape, &gp, &weights, &text, window, max_windows, None, workers)
                .expect("plu eval");
        let dacc = plu.top1 - exact.top1;
        table.row(&[
            format!("{name} PLU-32"),
            format!("{:.3}", plu.ppl),
            format!("{:.4}", plu.top1),
            format!("{:+.4}", dacc),
        ]);
        // paper's claim: negligible loss at the shipped 32-segment config
        assert!(
            dacc.abs() < 0.015,
            "{name}: PLU-32 accuracy delta {dacc} exceeds paper's <1.5% bound"
        );
        assert!(
            (plu.ppl - exact.ppl).abs() / exact.ppl < 0.02,
            "{name}: PPL drifted more than 2%"
        );
    }
    println!("{table}");
    println!("table1_quality: OK (PLU-32 within the paper's negligible-loss bound)");
}
