//! Serving ablation: bucketed dynamic batching vs single-stream decode.
//!
//! XAMBA Step-1 compiles fixed shapes, so batching must be bucketed; this
//! bench measures what the coordinator's largest-fitting-bucket policy
//! buys on the REAL runtime (PJRT-CPU) under a bursty arrival trace:
//! buckets {1} (no batching) vs {1,2,4,8}.

use std::time::{Duration, Instant};

use xamba::config::ServeConfig;
use xamba::coordinator::{start_pjrt, FinishReason, GenParams};
use xamba::util::{corpus, Prng, Summary};

fn run(buckets: Vec<usize>, n_requests: usize) -> (f64, f64, f64, f64) {
    let cfg = ServeConfig {
        model: "tiny-mamba".into(),
        variant: "baseline".into(),
        decode_buckets: buckets,
        max_slots: 16,
        ..Default::default()
    };
    let server = std::sync::Arc::new(start_pjrt(&cfg).expect("make artifacts first"));
    let t0 = Instant::now();
    // burst: all requests arrive nearly at once (worst case for b=1)
    let mut handles = Vec::new();
    for c in 0..4u64 {
        let s = server.clone();
        let n = n_requests / 4;
        handles.push(std::thread::spawn(move || {
            let mut rng = Prng::new(7 + c);
            let rxs: Vec<_> = (0..n)
                .map(|_| {
                    s.submit(
                        &corpus::prompt(&mut rng),
                        GenParams { max_new_tokens: 24, ..Default::default() },
                    )
                })
                .collect();
            rxs.into_iter()
                .filter_map(|rx| rx.recv_timeout(Duration::from_secs(120)).ok())
                .collect::<Vec<_>>()
        }));
    }
    let mut responses = Vec::new();
    for h in handles {
        responses.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    let ok: Vec<_> = responses
        .iter()
        .filter(|r| r.finish != FinishReason::Rejected)
        .collect();
    let tokens: usize = ok.iter().map(|r| r.generated.len()).sum();
    let e2es: Vec<f64> = ok.iter().map(|r| r.e2e_us / 1e3).collect();
    let m = server.metrics();
    (
        tokens as f64 / wall,
        Summary::of(&e2es).p50,
        Summary::of(&e2es).p99,
        m.mean_decode_batch(),
    )
}

fn main() {
    let n = 32;
    let (tps1, p50_1, p99_1, mb1) = run(vec![1], n);
    let (tps8, p50_8, p99_8, mb8) = run(vec![1, 2, 4, 8], n);
    println!("== batch-policy ablation: burst of {n} requests (PJRT-CPU) ==");
    println!(
        "buckets {{1}}        : {tps1:7.1} tok/s  e2e p50 {p50_1:7.1} ms  p99 {p99_1:7.1} ms  mean batch {mb1:.2}"
    );
    println!(
        "buckets {{1,2,4,8}}  : {tps8:7.1} tok/s  e2e p50 {p50_8:7.1} ms  p99 {p99_8:7.1} ms  mean batch {mb8:.2}"
    );
    println!("throughput gain: {:.2}x", tps8 / tps1);
    assert!(mb8 > mb1, "bucketed policy never batched");
    assert!(
        tps8 > tps1 * 1.2,
        "batching should raise burst throughput: {tps1:.1} -> {tps8:.1}"
    );
    println!("batch_policy: OK");
}
