//! Fig 1 reproduction: execution bottlenecks for Mamba and Mamba-2 on the
//! simulated Series-2 NPU (130M shapes, T=4 fixed input tokens).
//!
//! Paper claim: Mamba is limited by sequential DSP execution of Swish and
//! SoftPlus; Mamba-2 by CumSum and ReduceSum.

use xamba::config::{npu_series2, presets};
use xamba::npu::{Engine, Profile};

fn main() {
    let cfg = npu_series2();
    let t = 4;
    println!("=== Fig 1: op-level bottlenecks (130M block shapes, T={t}) ===\n");
    for shape in [presets::block130m_mamba(), presets::block130m_mamba2()] {
        let g = xamba::models::build_block(&shape, t);
        let p = Profile::of(&cfg, &g);
        println!("{}", p.breakdown_table());
        println!(
            "engine shares: DSP {:.1}%  MPU {:.1}%\n",
            100.0 * p.engine_share(Engine::Dsp),
            100.0 * p.engine_share(Engine::Mpu),
        );
    }

    // machine-checkable headline claims
    let g1 = xamba::models::build_block(&presets::block130m_mamba(), t);
    let p1 = Profile::of(&cfg, &g1);
    let act_share = p1.op_share("Swish") + p1.op_share("SoftPlus");
    println!("Mamba-1 Swish+SoftPlus share: {:.1}%  (paper: dominant)", 100.0 * act_share);
    assert!(act_share > 0.4, "activation share regressed: {act_share}");

    let g2 = xamba::models::build_block(&presets::block130m_mamba2(), t);
    let p2 = Profile::of(&cfg, &g2);
    let seq_share = p2.op_share("CumSum") + p2.op_share("ReduceSum");
    println!(
        "Mamba-2 CumSum share: {:.1}%, CumSum+ReduceSum: {:.1}%  (paper: CumSum >50%)",
        100.0 * p2.op_share("CumSum"),
        100.0 * seq_share
    );
    assert!(p2.op_share("CumSum") > 0.5, "CumSum share regressed");
    println!("\nfig1_bottlenecks: OK");
}
