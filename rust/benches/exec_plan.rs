//! Planned-executor micro-bench: naive HashMap walk vs compiled
//! `ExecutionPlan` on the Mamba-1 130M block graph at three sequence
//! lengths.
//!
//! The walker re-derives topo order + liveness per call, clones every
//! tensor through a HashMap and allocates per node; the plan compiles
//! that analysis once, reuses a liveness-sized buffer arena and runs
//! fused elementwise chains in a single pass. The speedup printed here
//! is the bench-trajectory number for the exec/ subsystem.
//!
//! Run: `cargo bench --bench exec_plan`

use std::time::Instant;

use xamba::config::presets;
use xamba::exec::{naive, ExecutionPlan};
use xamba::passes::verify;
use xamba::util::{Prng, Table};

fn time_ms(iters: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e3 / iters as f64
}

fn main() {
    let shape = presets::block130m_mamba();
    let iters = 5;
    let mut t = Table::new(&[
        "T",
        "naive walk",
        "planned",
        "speedup",
        "steps",
        "fused nodes",
        "arena KiB",
    ])
    .with_title("exec_plan: naive walker vs compiled ExecutionPlan (Mamba-1 130M block)");

    let mut speedups: Vec<(usize, f64)> = Vec::new();
    for seq in [4usize, 8, 16] {
        let g = xamba::models::build_block(&shape, seq);
        let mut rng = Prng::new(42);
        let inputs = verify::random_inputs(&g, &mut rng, 0.3);

        let naive_ms = time_ms(iters, || {
            naive::run(&g, &inputs).expect("naive run");
        });

        let mut plan = ExecutionPlan::compile(&g).expect("plan compiles");
        let planned_ms = time_ms(iters, || {
            plan.run(&inputs).expect("planned run");
        });

        // sanity: the two executors agree on what they computed
        let a = naive::run(&g, &inputs).unwrap();
        let b = plan.run(&inputs).unwrap();
        assert_eq!(a[0].as_f32(), b[0].as_f32(), "T={seq}: executor divergence");

        let speedup = naive_ms / planned_ms;
        speedups.push((seq, speedup));
        t.row(&[
            seq.to_string(),
            format!("{naive_ms:8.3} ms"),
            format!("{planned_ms:8.3} ms"),
            format!("{speedup:.2}x"),
            format!("{}", plan.step_count()),
            format!(
                "{}/{}",
                plan.fused_node_count(),
                plan.compute_node_count()
            ),
            format!("{:.1}", plan.arena_bytes() as f64 / 1024.0),
        ]);
    }
    println!("{t}");

    for (seq, s) in &speedups {
        assert!(
            *s > 1.0,
            "T={seq}: planned executor ({s:.2}x) must beat the naive walk"
        );
    }
    println!("exec_plan: OK (planned beats naive at all sequence lengths)");
}
