//! Mixed-length churn bench for the token-budget continuous-batching
//! scheduler: short and long prompts keep arriving while decodes drain.
//!
//! Two claims get numbers (and correctness gates) here:
//!
//! * **Occupancy**: on the SAME deterministic arrival/length workload, a
//!   continuous decode batch (every live sequence advances each step,
//!   remapped onto the compiled buckets) sustains strictly higher mean
//!   decode-batch occupancy than the old fixed-bucket policy (one
//!   `plan()`-selected bucket per step, everyone else waits). The
//!   policy simulation is exact arithmetic — asserted, not eyeballed.
//! * **Remap, not recompile**: a real planned-backend server under
//!   membership churn (staggered arrivals, mixed prompt lengths, mixed
//!   decode maxima) keeps its plan-compile gauge FLAT after warmup —
//!   mid-flight membership changes never compile a new plan.
//!
//! Run: `cargo bench --bench serve_churn`
//!
//! CI (`bench-smoke`) runs it with `XAMBA_BENCH_QUICK=1` and
//! `XAMBA_BENCH_JSON=...`, appending churn throughput and TTFT p95 to
//! the artifact `xamba bench-check` gates against the committed
//! baseline.

use std::time::{Duration, Instant};

use xamba::config::{ModelShape, ServeConfig};
use xamba::coordinator::batcher::plan;
use xamba::coordinator::{
    FinishReason, GenParams, PlannedServeModel, ServeModel, Server,
};
use xamba::util::{bench, Table};

/// Small block shapes: the subject is scheduling, not GEMM throughput.
fn nano() -> ModelShape {
    ModelShape {
        name: "nano-mamba".into(),
        arch: "mamba".into(),
        vocab_size: 256,
        d_model: 32,
        n_layers: 2,
        d_state: 8,
        d_conv: 3,
        expand: 2,
        dt_rank: 4,
        headdim: 16,
        chunk: 8,
    }
}

/// One scheduling policy step over the simulated workload state:
/// `advance` sequences decrement their remaining decode tokens, done
/// sequences leave, queued arrivals fill free slots.
struct Workload {
    /// (arrival_step, decode_tokens) per request, arrival-ordered.
    arrivals: Vec<(usize, usize)>,
}

impl Workload {
    /// Ragged mixed-length traffic: arrivals trickle in while earlier
    /// sequences drain, decode lengths vary 3..18.
    fn mixed(n: usize) -> Workload {
        Workload {
            arrivals: (0..n).map(|i| (i / 2, 3 + (i * 5) % 16)).collect(),
        }
    }

    /// Run the workload to completion under a per-step advance policy
    /// (given the live count, how many sequences advance this step) and
    /// return mean advanced-per-step — decode-batch occupancy.
    fn occupancy(&self, slots: usize, advance: impl Fn(usize) -> usize) -> f64 {
        let mut queued: std::collections::VecDeque<usize> =
            std::collections::VecDeque::new();
        let mut active: Vec<usize> = Vec::new();
        let mut next_arrival = 0usize;
        let mut step = 0usize;
        let mut advanced_total = 0usize;
        let mut steps = 0usize;
        let mut rr = 0usize;
        while next_arrival < self.arrivals.len() || !active.is_empty() || !queued.is_empty()
        {
            while next_arrival < self.arrivals.len()
                && self.arrivals[next_arrival].0 <= step
            {
                queued.push_back(self.arrivals[next_arrival].1);
                next_arrival += 1;
            }
            while active.len() < slots {
                match queued.pop_front() {
                    Some(r) => active.push(r),
                    None => break,
                }
            }
            if !active.is_empty() {
                let k = advance(active.len()).min(active.len());
                if k > 0 {
                    for j in 0..k {
                        let i = (rr + j) % active.len();
                        active[i] -= 1;
                    }
                    rr = if active.is_empty() { 0 } else { (rr + k) % active.len() };
                    active.retain(|&r| r > 0);
                    advanced_total += k;
                    steps += 1;
                }
            }
            step += 1;
        }
        if steps == 0 {
            0.0
        } else {
            advanced_total as f64 / steps as f64
        }
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

fn main() {
    let quick = bench::quick_mode();
    let buckets = [1usize, 2, 4, 8];
    let slots = 8usize;

    // --- policy simulation: fixed-bucket vs continuous occupancy -------
    let wl = Workload::mixed(if quick { 24 } else { 48 });
    let fixed_occ = wl.occupancy(slots, |n| plan(&buckets, n).bucket);
    let cont_occ = wl.occupancy(slots, |n| n);
    assert!(
        cont_occ > fixed_occ,
        "continuous batching must beat the fixed-bucket loop's occupancy \
         ({cont_occ:.3} vs {fixed_occ:.3})"
    );
    let mut sim = Table::new(&["policy", "mean decode occupancy"])
        .with_title("serve_churn: scheduling policy occupancy (exact simulation)");
    sim.row(&["fixed bucket (plan/select)".into(), format!("{fixed_occ:.3}")]);
    sim.row(&["continuous (decode_any remap)".into(), format!("{cont_occ:.3}")]);
    println!("{sim}");

    // --- real server churn on the planned backend ----------------------
    let shape = nano();
    let window = 8usize;
    let weights = PlannedServeModel::random_weights(&shape, 42);
    let cfg = ServeConfig {
        max_slots: slots,
        queue_cap: 64,
        batch_wait_us: 100,
        prefill_window: window,
        // the compile gauge must be deterministic: the prefix tier's
        // resume plan would otherwise compile lazily on its first hit
        prefix_cache_mb: 0,
        ..Default::default()
    };
    let decode_buckets = [1usize, 2, 4];
    let server = Server::start(
        move || {
            Ok(Box::new(PlannedServeModel::new(
                &shape,
                &weights,
                window,
                &decode_buckets,
                2,
                "baseline",
            )?) as Box<dyn ServeModel>)
        },
        cfg,
    )
    .expect("start churn server");

    // mixed prompt lengths (distinct prefill length-classes); warmup
    // compiles each class once so the churn phase runs fully warm
    let prompts: [&[u8]; 3] = [b"abc", b"abcdef", b"abcdefgh"];
    for p in prompts {
        let rx = server.submit(p, GenParams { max_new_tokens: 4, ..Default::default() });
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("warmup");
        assert_eq!(r.finish, FinishReason::Length);
    }
    // overlap a pair so the multi-sequence decode buckets execute too
    let pair: Vec<_> = (0..2)
        .map(|_| {
            server.submit(
                b"abcdef",
                GenParams { max_new_tokens: 6, ..Default::default() },
            )
        })
        .collect();
    for rx in pair {
        rx.recv_timeout(Duration::from_secs(120)).expect("warmup pair");
    }
    let warm = server.metrics();
    assert!(warm.plan_compiles > 0, "compile gauge never exported");

    // churn: waves of mixed-length, mixed-max_new requests arriving
    // while earlier decodes drain
    let waves = if quick { 3 } else { 8 };
    let per_wave = if quick { 4 } else { 6 };
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for w in 0..waves {
        for i in 0..per_wave {
            let p = prompts[(w + i) % prompts.len()];
            rxs.push(server.submit(
                p,
                GenParams { max_new_tokens: 3 + (w * per_wave + i) % 10, ..Default::default() },
            ));
        }
        // stagger waves so membership churns mid-decode
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut ttfts_ms: Vec<f64> = Vec::new();
    let mut tokens = 0usize;
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(300)).expect("churn response");
        assert_eq!(r.finish, FinishReason::Length);
        tokens += r.generated.len();
        ttfts_ms.push(r.ttft_us / 1e3);
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    assert_eq!(
        m.plan_compiles, warm.plan_compiles,
        "membership churn recompiled a plan ({} -> {})",
        warm.plan_compiles, m.plan_compiles
    );

    ttfts_ms.sort_by(|a, b| a.total_cmp(b));
    let tok_per_s = tokens as f64 / elapsed;
    let p95 = percentile(&ttfts_ms, 0.95);
    let mut table = Table::new(&["metric", "value"])
        .with_title("serve_churn: planned-backend mixed-length churn");
    table.row(&["requests".into(), format!("{}", waves * per_wave)]);
    table.row(&["tokens out".into(), tokens.to_string()]);
    table.row(&["throughput".into(), format!("{tok_per_s:.1} tok/s")]);
    table.row(&["ttft p95".into(), format!("{p95:.1} ms")]);
    table.row(&["mean decode occupancy".into(), format!("{:.2}", m.mean_decode_batch())]);
    table.row(&["decode slot utilization".into(), format!("{:.2}", m.decode_slot_utilization())]);
    table.row(&["plan compiles (flat)".into(), m.plan_compiles.to_string()]);
    println!("{table}");

    if let Some(path) = bench::metrics_path() {
        bench::record(
            &path,
            &[
                ("serve_churn_tok_per_s".to_string(), tok_per_s),
                ("serve_churn_ttft_p95_ms".to_string(), p95),
            ],
        )
        .expect("record bench metrics");
    }
}
