//! Ablation (paper Fig 3): what ZVC mask compression and sparsity-bitmap
//! compute-skip each contribute to CumBA.
//!
//! The CumBA mask is ~50% zeros; ZVC halves its memory traffic and the
//! bitmap skips its zero MACs. Mamba *weights* have negligible sparsity
//! (paper §2.1), so the same machinery does nothing for them — both sides
//! are measured.

use xamba::config::{npu_series2, presets};
use xamba::npu::{zvc, Profile};
use xamba::passes::{cumba::CumbaPass, Pass};
use xamba::util::Table;

fn main() {
    let g = xamba::models::build_block(&presets::block130m_mamba2(), 4);
    let rewritten = CumbaPass.apply(&g);

    let mut t = Table::new(&["config", "block latency", "vs full"])
        .with_title("Ablation: ZVC + sparsity-skip contributions to CumBA");
    let mut full_cfg = npu_series2();
    full_cfg.zvc_enabled = true;
    full_cfg.sparsity_skip_enabled = true;
    let full = Profile::of(&full_cfg, &rewritten).total_ns;
    for (name, zvc_on, skip_on) in [
        ("ZVC + skip (shipped)", true, true),
        ("ZVC only", true, false),
        ("skip only", false, true),
        ("neither", false, false),
    ] {
        let mut cfg = npu_series2();
        cfg.zvc_enabled = zvc_on;
        cfg.sparsity_skip_enabled = skip_on;
        let p = Profile::of(&cfg, &rewritten);
        t.row(&[
            name.to_string(),
            xamba::util::table::fmt_ns(p.total_ns),
            format!("{:.3}x", p.total_ns / full),
        ]);
    }
    println!("{t}");

    // storage accounting (Fig 3's memory-savings claim)
    let n = 256usize;
    let mut mask = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..=i {
            mask[i * n + j] = 1.0;
        }
    }
    let nnz = zvc::count_nnz(&mask);
    println!(
        "CumBA mask {nxn}: raw {raw} KiB, ZVC {z} KiB (ratio {r:.3})",
        nxn = format!("{n}x{n}"),
        raw = n * n * 4 / 1024,
        z = zvc::compressed_bytes(n * n, nnz) / 1024,
        r = zvc::ratio(n * n, nnz),
    );
    // weights have ~no zeros: ZVC inflates slightly
    let dense_ratio = zvc::ratio(1_000_000, 1_000_000);
    println!(
        "dense weights ZVC ratio: {dense_ratio:.3} (>1: no benefit, matching paper §2.1)"
    );

    let mut no_opt = npu_series2();
    no_opt.zvc_enabled = false;
    no_opt.sparsity_skip_enabled = false;
    let worst = Profile::of(&no_opt, &rewritten).total_ns;
    assert!(worst > full, "ZVC+skip must help CumBA");
    assert!(zvc::ratio(n * n, nnz) < 0.56);
    assert!(dense_ratio > 1.0);
    println!("ablation_zvc: OK");
}
