//! Serving-decode micro-bench: serial vs pooled batched decode on the
//! 130M-class block shapes of BOTH model families (Mamba-1 and Mamba-2)
//! at buckets 1/4/8 — and the f32 pooled path vs the reduced-precision
//! serving dtypes (f16, i8).
//!
//! All paths run compiled per-bucket decode graphs through
//! `PlannedServeModel`; the pooled models split each bucket into chunks
//! on the pool's work-stealing queue across 4 workers. Workers own their
//! plans and arenas, while the parameter set is `Arc`-shared — one copy
//! per (model, dtype): ~170 MB at f32, half at f16, a quarter at i8.
//! f32 outputs are asserted bitwise-identical between serial and pooled
//! before timing; quantized outputs are asserted finite (their
//! correctness contract lives in the differential suites).
//!
//! Run: `cargo bench --bench serve_decode`
//!
//! CI (`bench-smoke`) runs it with `XAMBA_BENCH_QUICK=1` (one timed
//! iteration) and `XAMBA_BENCH_JSON=BENCH_pr.json`, which appends the
//! pooled tokens/sec per (family, dtype, bucket) to the artifact that
//! `xamba bench-check` gates against the committed baseline.

use std::time::Instant;

use xamba::config::{presets, ModelShape};
use xamba::coordinator::{PlannedServeModel, SeqState, ServeModel};
use xamba::graph::DType;
use xamba::util::{bench, Table};

fn argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

fn time_ms(iters: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e3 / iters as f64
}

fn decode_step(model: &mut PlannedServeModel, states: &mut [SeqState], toks: &[i32]) {
    let mut seqs: Vec<(&mut SeqState, i32)> =
        states.iter_mut().zip(toks.iter().copied()).collect();
    model.decode(&mut seqs).expect("decode");
}

/// Prefill `bucket` prompts on `model`, returning decode-ready states
/// and first tokens.
fn prefill_bucket(
    model: &mut PlannedServeModel,
    bucket: usize,
    window: usize,
) -> (Vec<SeqState>, Vec<i32>) {
    let mut states = Vec::with_capacity(bucket);
    let mut toks = Vec::with_capacity(bucket);
    for i in 0..bucket {
        let p: Vec<i32> = (0..window).map(|t| ((i * 17 + t * 5) % 256) as i32).collect();
        let (l, s) = model.prefill(&p).expect("prefill");
        states.push(s);
        toks.push(argmax(&l));
    }
    (states, toks)
}

fn bench_family(key: &str, label: &str, shape: &ModelShape) {
    let window = 8usize;
    let workers = 4usize;
    let buckets = [1usize, 2, 4, 8];
    let timed = [1usize, 4, 8];
    let iters = if bench::quick_mode() { 1usize } else { 3 };

    let weights = PlannedServeModel::random_weights(shape, 42);
    let mut serial =
        PlannedServeModel::new(shape, &weights, window, &buckets, 1, "baseline")
            .expect("serial model");
    let mut pooled =
        PlannedServeModel::new(shape, &weights, window, &buckets, workers, "baseline")
            .expect("pooled model");

    let mut table = Table::new(&["bucket", "serial", "pooled", "speedup", "tok/s pooled"])
        .with_title(
            format!(
                "serve_decode: serial vs {workers}-worker work-stealing pooled \
                 batched decode ({label}, f32)"
            )
            .as_str(),
        );

    let mut metrics: Vec<(String, f64)> = Vec::new();
    let mut pooled_f32_ms: Vec<(usize, f64)> = Vec::new();
    for &bucket in &timed {
        let (states, toks) = prefill_bucket(&mut serial, bucket, window);

        // correctness gate: one step from identical states must agree
        {
            let mut st_a = states.clone();
            let mut st_b = states.clone();
            let mut seqs_a: Vec<(&mut SeqState, i32)> =
                st_a.iter_mut().zip(toks.iter().copied()).collect();
            let la = serial.decode(&mut seqs_a).expect("serial decode");
            drop(seqs_a);
            let mut seqs_b: Vec<(&mut SeqState, i32)> =
                st_b.iter_mut().zip(toks.iter().copied()).collect();
            let lb = pooled.decode(&mut seqs_b).expect("pooled decode");
            drop(seqs_b);
            assert_eq!(la, lb, "bucket {bucket}: pooled decode diverged");
            assert_eq!(st_a, st_b, "bucket {bucket}: pooled state diverged");
        }

        let mut st_serial = states.clone();
        let serial_ms =
            time_ms(iters, || decode_step(&mut serial, &mut st_serial, &toks));
        let mut st_pooled = states.clone();
        let pooled_ms =
            time_ms(iters, || decode_step(&mut pooled, &mut st_pooled, &toks));
        let pooled_tok_per_s = bucket as f64 / (pooled_ms / 1e3);
        pooled_f32_ms.push((bucket, pooled_ms));

        table.row(&[
            bucket.to_string(),
            format!("{serial_ms:8.2} ms"),
            format!("{pooled_ms:8.2} ms"),
            format!("{:.2}x", serial_ms / pooled_ms),
            format!("{pooled_tok_per_s:.1}"),
        ]);
        metrics.push((
            format!("serve_decode_{key}_b{bucket}_tok_per_s"),
            pooled_tok_per_s,
        ));
    }
    println!("{table}");
    drop(serial);

    // reduced-precision serving dtypes: same pooled configuration, new
    // plans + converted parameters per dtype; compared against the f32
    // pooled wall clock at each bucket
    for dtype in [DType::F16, DType::I8] {
        let mut qmodel = PlannedServeModel::new_dtyped(
            shape, &weights, window, &buckets, workers, "baseline", dtype,
        )
        .expect("quantized model");
        let mut qtable =
            Table::new(&["bucket", "f32 pooled", "pooled", "speedup vs f32", "tok/s"])
                .with_title(
                    format!("serve_decode: {label} at --dtype {}", dtype.name()).as_str(),
                );
        for (ti, &bucket) in timed.iter().enumerate() {
            let (states, toks) = prefill_bucket(&mut qmodel, bucket, window);
            {
                // sanity gate: quantized decode emits finite logits
                let mut st = states.clone();
                let mut seqs: Vec<(&mut SeqState, i32)> =
                    st.iter_mut().zip(toks.iter().copied()).collect();
                let l = qmodel.decode(&mut seqs).expect("quantized decode");
                drop(seqs);
                assert!(
                    l.iter().all(|row| row.iter().all(|v| v.is_finite())),
                    "bucket {bucket}: non-finite {} logits",
                    dtype.name()
                );
            }
            let mut st = states.clone();
            let ms = time_ms(iters, || decode_step(&mut qmodel, &mut st, &toks));
            let tok_per_s = bucket as f64 / (ms / 1e3);
            let f32_ms = pooled_f32_ms[ti].1;
            qtable.row(&[
                bucket.to_string(),
                format!("{f32_ms:8.2} ms"),
                format!("{ms:8.2} ms"),
                format!("{:.2}x", f32_ms / ms),
                format!("{tok_per_s:.1}"),
            ]);
            metrics.push((
                format!("serve_decode_{key}_{}_b{bucket}_tok_per_s", dtype.name()),
                tok_per_s,
            ));
        }
        println!("{qtable}");
    }

    if let Some(path) = bench::metrics_path() {
        bench::record(&path, &metrics).expect("record bench metrics");
    }
}

fn main() {
    // the paper's two profiling blocks: the perf trajectory covers both
    // families now that the planned serving path does
    bench_family("mamba1", "Mamba-1 130M block", &presets::block130m_mamba());
    bench_family("mamba2", "Mamba-2 130M block", &presets::block130m_mamba2());
    println!(
        "serve_decode: pooled f32 decode is bitwise-identical to serial for both \
         families; f16/i8 rows run the quantized plans (differentially tested in \
         tests/exec_differential.rs). Speedups are wall-clock only."
    );
}
