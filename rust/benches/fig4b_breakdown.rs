//! Fig 4(b) reproduction: normalized latency breakdown of the Mamba-2
//! 130M block, baseline vs CumBA.
//!
//! Paper: CumSum contributes >50% of baseline latency; CumBA removes it
//! by turning it into mask matmul.

use xamba::config::{npu_series2, presets};
use xamba::npu::Profile;
use xamba::passes::{cumba::CumbaPass, Pass};
use xamba::util::Table;

fn main() {
    let cfg = npu_series2();
    let g = xamba::models::build_block(&presets::block130m_mamba2(), 4);
    let base = Profile::of(&cfg, &g);
    let opt = Profile::of(&cfg, &CumbaPass.apply(&g));

    let mut t = Table::new(&["op", "baseline %", "CumBA % (of baseline)"])
        .with_title("Fig 4(b): normalized latency breakdown, Mamba-2 130M block");
    let mut ops: Vec<&str> = base.by_op().iter().map(|(o, _)| *o).collect();
    for (o, _) in opt.by_op() {
        if !ops.contains(&o) {
            ops.push(o);
        }
    }
    for op in ops {
        t.row(&[
            op.to_string(),
            format!("{:5.1}", 100.0 * base.op_ns(op) / base.total_ns),
            format!("{:5.1}", 100.0 * opt.op_ns(op) / base.total_ns),
        ]);
    }
    t.row(&[
        "TOTAL".into(),
        "100.0".into(),
        format!("{:5.1}", 100.0 * opt.total_ns / base.total_ns),
    ]);
    println!("{t}");

    assert!(base.op_share("CumSum") > 0.5, "paper: CumSum >50% of baseline");
    assert_eq!(opt.op_ns("CumSum"), 0.0, "CumBA must eliminate CumSum");
    println!("fig4b_breakdown: OK");
}
