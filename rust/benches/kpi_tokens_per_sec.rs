//! §4 KPI reproduction: decode throughput (Tokens/s) of Mamba-130M with
//! and without ActiBA on the simulated NPU, against the 50 Tok/s target
//! (MobileLLM-125M parity).
//!
//! Paper: ActiBA lifts decoding from 100 Tokens/s to 260 Tokens/s.

use xamba::config::{npu_series2, presets};
use xamba::npu::Profile;
use xamba::passes::{actiba::ActibaPass, cumba::CumbaPass, reduba::RedubaPass, Pass};
use xamba::util::Table;

fn main() {
    let cfg = npu_series2();
    let mut t = Table::new(&["model", "variant", "step latency", "Tokens/s", "KPI 50 ok"])
        .with_title("KPI: single-stream decode throughput (simulated NPU)");

    let mut checks: Vec<(String, f64)> = Vec::new();
    for shape in [presets::mamba130m(), presets::mamba2_130m()] {
        let g = xamba::models::build_decode(&shape);
        let base = Profile::of(&cfg, &g);
        let acti = Profile::of(&cfg, &ActibaPass::default().apply(&g));
        let all = Profile::of(
            &cfg,
            &ActibaPass::default().apply(&RedubaPass.apply(&CumbaPass.apply(&g))),
        );
        for (variant, p) in
            [("baseline", &base), ("ActiBA", &acti), ("full XAMBA", &all)]
        {
            let tps = 1e9 / p.total_ns;
            t.row(&[
                shape.name.clone(),
                variant.to_string(),
                xamba::util::table::fmt_ns(p.total_ns),
                format!("{tps:.0}"),
                if tps >= 50.0 { "yes".into() } else { "NO".to_string() },
            ]);
            checks.push((format!("{}.{variant}", shape.name), tps));
        }
    }
    println!("{t}");
    println!("paper: Mamba-130M 100 -> 260 Tokens/s with ActiBA (KPI target 50)\n");

    let get = |k: &str| checks.iter().find(|(n, _)| n == k).unwrap().1;
    let base = get("mamba130m.baseline");
    let acti = get("mamba130m.ActiBA");
    assert!(base >= 50.0, "baseline must already beat the 50 Tok/s KPI");
    let lift = acti / base;
    assert!(
        (1.5..4.0).contains(&lift),
        "ActiBA decode lift {lift:.2}x vs paper 2.6x"
    );
    println!("kpi_tokens_per_sec: OK (ActiBA lift {lift:.2}x, paper 2.6x)");
}
