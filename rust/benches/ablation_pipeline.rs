//! Ablation: does engine-overlap scheduling change the paper's story?
//!
//! The headline numbers assume sequential issue (one op at a time, as a
//! simple NPU command list executes). A smarter runtime overlaps engines
//! (MPU || DSP). This bench re-evaluates Fig 4(a) under dataflow-
//! constrained list scheduling: CumBA still wins, because CumSum_b sits
//! on the critical path of every chunk — the speedups are a property of
//! the graph, not of the issue model. Energy is reported alongside
//! (paper §1 motivates NPUs by energy efficiency).

use xamba::config::{npu_series2, presets};
use xamba::npu::energy::{estimate, EnergyModel};
use xamba::npu::schedule::pipelined_latency;
use xamba::passes::{cumba::CumbaPass, reduba::RedubaPass, Pass};
use xamba::util::Table;

fn main() {
    let cfg = npu_series2();
    let em = EnergyModel::default();
    let g = xamba::models::build_block(&presets::block130m_mamba2(), 4);
    let variants: Vec<(&str, xamba::graph::Graph)> = vec![
        ("baseline", g.clone()),
        ("CumBA", CumbaPass.apply(&g)),
        ("CumBA+ReduBA", RedubaPass.apply(&CumbaPass.apply(&g))),
    ];

    let mut t = Table::new(&[
        "variant",
        "sequential",
        "pipelined",
        "overlap",
        "speedup(seq)",
        "speedup(pipe)",
        "energy uJ",
    ])
    .with_title("Ablation: sequential vs engine-overlapped issue (Mamba-2 130M block)");

    let mut seq = Vec::new();
    let mut pipe = Vec::new();
    for (name, graph) in &variants {
        let r = pipelined_latency(&cfg, graph);
        let e = estimate(&cfg, graph, &em);
        seq.push(r.sequential_ns);
        pipe.push(r.makespan_ns);
        t.row(&[
            name.to_string(),
            xamba::util::table::fmt_ns(r.sequential_ns),
            xamba::util::table::fmt_ns(r.makespan_ns),
            format!("{:.2}x", r.overlap()),
            format!("{:.2}x", seq[0] / r.sequential_ns),
            format!("{:.2}x", pipe[0] / r.makespan_ns),
            format!("{:.0}", e.total_uj()),
        ]);
    }
    println!("{t}");

    // the claim: CumBA's win survives overlapped scheduling
    let cumba_pipe_speedup = pipe[0] / pipe[1];
    let both_pipe_speedup = pipe[0] / pipe[2];
    println!(
        "pipelined speedups: CumBA {cumba_pipe_speedup:.2}x, both {both_pipe_speedup:.2}x \
         (sequential: {:.2}x / {:.2}x)",
        seq[0] / seq[1],
        seq[0] / seq[2],
    );
    assert!(
        cumba_pipe_speedup > 2.0,
        "CumBA must keep >2x under overlap, got {cumba_pipe_speedup:.2}"
    );
    assert!(both_pipe_speedup > cumba_pipe_speedup);

    // energy: the optimized graph must use less energy too
    let e_base = estimate(&cfg, &variants[0].1, &em).total_uj();
    let e_both = estimate(&cfg, &variants[2].1, &em).total_uj();
    println!(
        "energy: baseline {e_base:.0} uJ -> CumBA+ReduBA {e_both:.0} uJ ({:.2}x less)",
        e_base / e_both
    );
    assert!(e_both < e_base);
    println!("ablation_pipeline: OK");
}
