//! Integration tests of reduced-precision (f16 / i8) serving on the
//! planned backend: mixed-dtype plan caching (compile-once per
//! (program, bucket, dtype)), pool determinism at several worker counts,
//! arena-reuse re-execution parity, full streaming round trips through
//! `start_backend` with `--dtype`, and the committed quality budget of
//! the i8 path vs f32.

use std::time::Duration;

use xamba::config::{ModelShape, ServeConfig};
use xamba::coordinator::{
    start_backend, FinishReason, GenParams, PlannedServeModel, SeqState, ServeModel,
};
use xamba::graph::DType;

fn nano(arch: &str) -> ModelShape {
    ModelShape {
        name: format!("nano-{arch}"),
        arch: arch.into(),
        vocab_size: 256,
        d_model: 32,
        n_layers: 2,
        d_state: 8,
        d_conv: 3,
        expand: 2,
        dt_rank: 4,
        headdim: 16,
        chunk: 8,
    }
}

fn argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

fn prompt(i: usize, window: usize) -> Vec<i32> {
    (0..window).map(|t| ((i * 31 + t * 7) % 256) as i32).collect()
}

#[test]
fn quantized_models_serve_both_families() {
    // f16 and i8 models of BOTH families complete prefill + multi-step
    // decode with finite logits and states, no artifacts, no PJRT
    let window = 8;
    for arch in ["mamba", "mamba2"] {
        let shape = nano(arch);
        let weights = PlannedServeModel::random_weights(&shape, 5);
        for dtype in [DType::F16, DType::I8] {
            let mut model = PlannedServeModel::new_dtyped(
                &shape, &weights, window, &[1, 2], 1, "baseline", dtype,
            )
            .unwrap_or_else(|e| panic!("{arch} {}: {e}", dtype.name()));
            assert_eq!(model.dtype(), dtype);
            assert!(
                model.quantized_weight_count() > 0,
                "{arch} {}: no weight went reduced-precision",
                dtype.name()
            );
            let (logits, mut st) = model.prefill(&prompt(0, window)).unwrap();
            assert_eq!(logits.len(), shape.vocab_size);
            assert!(logits.iter().all(|v| v.is_finite()), "{arch} prefill logits");
            let mut tok = argmax(&logits);
            for step in 0..3 {
                let mut seqs = vec![(&mut st, tok)];
                let l = model.decode(&mut seqs).unwrap().remove(0);
                drop(seqs);
                assert!(
                    l.iter().all(|v| v.is_finite()),
                    "{arch} {} decode step {step}",
                    dtype.name()
                );
                tok = argmax(&l);
            }
        }
    }
}

#[test]
fn quantized_outputs_track_the_f32_model() {
    // the same weights served at f16/i8 must stay close to the f32
    // logits (8-bit projections on a nano net: loose envelope) and make
    // the SAME greedy decision most of the time; here: on the argmax of
    // the prefill logits for several prompts
    let window = 8;
    let shape = nano("mamba");
    let weights = PlannedServeModel::random_weights(&shape, 23);
    let mut f32_model =
        PlannedServeModel::new(&shape, &weights, window, &[1], 1, "baseline").unwrap();
    for dtype in [DType::F16, DType::I8] {
        let mut q_model = PlannedServeModel::new_dtyped(
            &shape, &weights, window, &[1], 1, "baseline", dtype,
        )
        .unwrap();
        let mut agree = 0usize;
        for i in 0..4 {
            let p = prompt(i, window);
            let (le, _) = f32_model.prefill(&p).unwrap();
            let (lq, _) = q_model.prefill(&p).unwrap();
            let max_abs = le
                .iter()
                .zip(&lq)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_abs < 0.5,
                "{} prompt {i}: logits drifted {max_abs}",
                dtype.name()
            );
            agree += usize::from(argmax(&le) == argmax(&lq));
        }
        // f16 drift (~1e-3) cannot plausibly flip a greedy decision on
        // these logits; i8 only gets the drift envelope above, since a
        // near-tie CAN legitimately flip under 8-bit projections
        if dtype == DType::F16 {
            assert!(agree >= 3, "f16: greedy argmax agreed only {agree}/4");
        }
    }
}

#[test]
fn i8_pooled_decode_is_bitwise_identical_across_worker_counts() {
    // quantized plans are deterministic (dynamic activation scales are a
    // pure function of the inputs), and i8 buckets deliberately never
    // split on the work-stealing pool — per-tensor scales couple the
    // batch rows, so a chunked bucket would legitimately drift from the
    // whole-bucket graph. Decode output must therefore be bitwise
    // identical at every worker count.
    let shape = nano("mamba");
    let window = 8;
    let weights = PlannedServeModel::random_weights(&shape, 9);
    let mut reference: Option<(Vec<Vec<Vec<f32>>>, Vec<SeqState>)> = None;
    for workers in [1usize, 2, 4] {
        let mut model = PlannedServeModel::new_dtyped(
            &shape,
            &weights,
            window,
            &[1, 2, 4],
            workers,
            "baseline",
            DType::I8,
        )
        .unwrap();
        assert_eq!(model.pool_workers(), workers.max(1));
        let mut states: Vec<SeqState> = Vec::new();
        let mut toks: Vec<i32> = Vec::new();
        for i in 0..4 {
            let (logits, st) = model.prefill(&prompt(i, window)).unwrap();
            toks.push(argmax(&logits));
            states.push(st);
        }
        let mut all_logits: Vec<Vec<Vec<f32>>> = Vec::new();
        for _ in 0..3 {
            let mut seqs: Vec<(&mut SeqState, i32)> =
                states.iter_mut().zip(toks.iter().copied()).collect();
            let step = model.decode(&mut seqs).unwrap();
            drop(seqs);
            toks = step.iter().map(|l| argmax(l)).collect();
            all_logits.push(step);
        }
        match &reference {
            None => reference = Some((all_logits, states)),
            Some((ref_logits, ref_states)) => {
                assert_eq!(
                    &all_logits, ref_logits,
                    "{workers} workers: i8 logits diverged from serial"
                );
                assert_eq!(
                    &states, ref_states,
                    "{workers} workers: i8 states diverged from serial"
                );
            }
        }
    }
}

#[test]
fn quantized_plans_compile_once_and_reuse_arenas() {
    // compile-once per (program, bucket, dtype): construction compiles
    // prefill + both buckets, traffic recompiles nothing, and re-running
    // identical inputs through the cached plans (arena reuse) is
    // bitwise-neutral — for both quantized dtypes
    let shape = nano("mamba2");
    let window = 8;
    let weights = PlannedServeModel::random_weights(&shape, 3);
    for dtype in [DType::F16, DType::I8] {
        let mut model = PlannedServeModel::new_dtyped(
            &shape, &weights, window, &[1, 2], 1, "baseline", dtype,
        )
        .unwrap();
        assert_eq!(model.plan_compiles(), 3, "{}: prefill + 2 buckets", dtype.name());

        let p = prompt(0, window);
        let (l1, mut s1) = model.prefill(&p).unwrap();
        let (l2, mut s2) = model.prefill(&p).unwrap();
        assert_eq!(l1, l2, "{}: prefill arena reuse drifted", dtype.name());
        assert_eq!(s1, s2);

        let out1 = {
            let mut seqs = vec![(&mut s1, 42)];
            model.decode(&mut seqs).unwrap()
        };
        let out2 = {
            let mut seqs = vec![(&mut s2, 42)];
            model.decode(&mut seqs).unwrap()
        };
        assert_eq!(out1, out2, "{}: decode arena reuse drifted", dtype.name());
        assert_eq!(s1, s2);
        // a shorter prefill length-class compiles lazily, exactly once
        let (l3, _) = model.prefill(&prompt(1, window - 2)).unwrap();
        let (l4, _) = model.prefill(&prompt(1, window - 2)).unwrap();
        assert_eq!(l3, l4);
        assert_eq!(
            model.plan_compiles(),
            4,
            "{}: length-class must compile once",
            dtype.name()
        );
    }
}

#[test]
fn quantized_streaming_round_trip_through_start_backend() {
    // the full `xamba serve --backend planned --dtype i8|f16` path:
    // config validation, engine thread, streaming prefill + decode round
    // trip — with no `artifacts/` directory
    for dtype in ["f16", "i8"] {
        for model in ["tiny-mamba", "tiny-mamba2"] {
            let cfg = ServeConfig {
                model: model.into(),
                dtype: dtype.into(),
                decode_buckets: vec![1, 2],
                prefill_buckets: vec![1, 2],
                prefill_window: 8,
                workers: 2,
                max_slots: 4,
                queue_cap: 8,
                batch_wait_us: 100,
                ..Default::default()
            };
            let server = start_backend(&cfg)
                .unwrap_or_else(|e| panic!("{model} {dtype}: {e:#}"));
            let rx = server.submit(
                b"quantized fox",
                GenParams { max_new_tokens: 4, ..Default::default() },
            );
            let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert_eq!(r.finish, FinishReason::Length, "{model} {dtype}");
            assert_eq!(r.generated.len(), 4, "{model} {dtype}");
            let m = server.shutdown();
            assert_eq!(m.completed, 1);
            assert!(m.failed == 0, "{model} {dtype}: failed requests");
        }
    }
}

#[test]
fn start_backend_rejects_bad_dtype_configs_with_actionable_errors() {
    let bad = ServeConfig { dtype: "fp16".into(), ..Default::default() };
    let msg = format!("{:#}", start_backend(&bad).unwrap_err());
    assert!(msg.contains("unknown serve dtype") && msg.contains("fp16"), "{msg}");
    assert!(
        msg.contains("f32") && msg.contains("f16") && msg.contains("i8"),
        "supported dtypes must be listed: {msg}"
    );

    let pjrt = ServeConfig {
        backend: "pjrt".into(),
        dtype: "i8".into(),
        ..Default::default()
    };
    let msg = format!("{:#}", start_backend(&pjrt).unwrap_err());
    assert!(msg.contains("planned backend"), "{msg}");
}

#[test]
fn i8_eval_lm_stays_within_the_committed_quality_budget() {
    // the committed accuracy budget of the ISSUE's acceptance criterion:
    // i8 perplexity within 5% of f32, f16 within 1% (CI additionally
    // gates this via `xamba quality --dtype i8 --budget 0.05`)
    use xamba::models::params::full_spec;
    use xamba::quality::{eval_lm, eval_lm_dtyped};

    let shape = nano("mamba");
    let window = 16usize;
    let g = xamba::models::build_prefill(&shape, window);
    let spec = full_spec(&shape);
    let mut rng = xamba::util::Prng::new(77);
    let weights = rng.range_vec(spec.total(), -0.1, 0.1);
    let text = xamba::util::corpus::corpus(300, 13);
    let (exact, logits) =
        eval_lm(&shape, &g, &weights, &text, window, 3, None, 1).unwrap();
    for (dtype, budget) in [(DType::F16, 0.01f64), (DType::I8, 0.05f64)] {
        let (rep, _) = eval_lm_dtyped(
            &shape,
            &g,
            &weights,
            dtype,
            &text,
            window,
            3,
            Some(&logits),
            1,
        )
        .unwrap();
        let rel = (rep.ppl - exact.ppl).abs() / exact.ppl;
        assert!(
            rel <= budget,
            "{}: ppl {} vs f32 {} — {:.3}% past the {:.1}% budget",
            dtype.name(),
            rep.ppl,
            exact.ppl,
            rel * 100.0,
            budget * 100.0
        );
        assert!(rep.logit_max.is_finite());
    }
}
