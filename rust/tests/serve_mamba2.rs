//! Integration tests of Mamba-2 on the planned serving path — the
//! mirror of `serve_planned.rs` for the SSD family. `PlannedServeModel`
//! resolves `arch = "mamba2"` to its serve-prefill / batched-decode
//! builders and the (H, P, N) state layout; everything here runs with no
//! `artifacts/` directory and no PJRT.

use std::time::Duration;

use xamba::config::{ModelShape, ServeConfig};
use xamba::coordinator::{
    start_backend, FinishReason, GenParams, PlannedServeModel, SeqState, ServeModel,
    Server, StreamEvent,
};

/// A deliberately small Mamba-2 so debug-mode tests stay fast. Vocab
/// stays 256 (byte tokenizer); chunk 8 so multi-chunk SSD prefill is
/// exercised at tiny windows.
fn nano2() -> ModelShape {
    ModelShape {
        name: "nano-mamba2".into(),
        arch: "mamba2".into(),
        vocab_size: 256,
        d_model: 32,
        n_layers: 2,
        d_state: 8,
        d_conv: 3,
        expand: 2,
        dt_rank: 0,
        headdim: 16,
        chunk: 8,
    }
}

fn argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

fn prompt(i: usize, window: usize) -> Vec<i32> {
    (0..window).map(|t| ((i * 29 + t * 11) % 256) as i32).collect()
}

#[test]
fn batched_decode_matches_single_step_semantics() {
    // per-sequence bitwise identity across bucket sizes: one bucket-4
    // call must reproduce four bucket-1 calls exactly
    let shape = nano2();
    let window = 8;
    let weights = PlannedServeModel::random_weights(&shape, 7);
    let mut single =
        PlannedServeModel::new(&shape, &weights, window, &[1], 1, "baseline").unwrap();
    let mut batched =
        PlannedServeModel::new(&shape, &weights, window, &[1, 2, 4], 1, "baseline")
            .unwrap();

    let mut st_single: Vec<SeqState> = Vec::new();
    let mut st_batched: Vec<SeqState> = Vec::new();
    let mut toks: Vec<i32> = Vec::new();
    for i in 0..4 {
        let p = prompt(i, window);
        let (l1, s1) = single.prefill(&p).unwrap();
        let (l2, s2) = batched.prefill(&p).unwrap();
        assert_eq!(l1, l2, "prefill logits diverge for prompt {i}");
        toks.push(argmax(&l1));
        st_single.push(s1);
        st_batched.push(s2);
    }

    let mut logits_single: Vec<Vec<f32>> = Vec::new();
    for (s, t) in st_single.iter_mut().zip(toks.iter().copied()) {
        let mut seqs = vec![(s, t)];
        logits_single.push(single.decode(&mut seqs).unwrap().remove(0));
    }
    let mut seqs: Vec<(&mut SeqState, i32)> =
        st_batched.iter_mut().zip(toks.iter().copied()).collect();
    let logits_batched = batched.decode(&mut seqs).unwrap();
    drop(seqs);
    assert_eq!(logits_batched, logits_single, "bucket-4 decode diverged");
    for (i, (a, b)) in st_single.iter().zip(&st_batched).enumerate() {
        assert_eq!(a, b, "recurrent state diverged for sequence {i}");
    }
}

#[test]
fn decode_continues_the_prefill_graph() {
    // cross-builder differential: prefill(window) + one decode step must
    // agree with the ORIGINAL `build_prefill` graph evaluated over the
    // extended token sequence. The window deliberately straddles a chunk
    // boundary (12 = 8 + 4 at chunk 8) so the serve prefill's remainder
    // chunk and carried SSD state are both on the hook. Approximate, not
    // bitwise: chunked SSD vs the decode recurrence reassociate floats.
    let shape = nano2();
    let window = 12;
    let weights = PlannedServeModel::random_weights(&shape, 17);
    let mut model =
        PlannedServeModel::new(&shape, &weights, window, &[1], 1, "baseline").unwrap();
    let p = prompt(3, window);
    let (logits, mut st) = model.prefill(&p).unwrap();
    let tok = argmax(&logits);
    let mut seqs = vec![(&mut st, tok)];
    let step = model.decode(&mut seqs).unwrap().remove(0);
    drop(seqs);

    let spec = xamba::models::params::full_spec(&shape);
    let mut inputs = xamba::quality::param_inputs(&spec, &weights);
    let mut extended = p.clone();
    extended.push(tok);
    inputs.push(xamba::graph::Tensor::i32(vec![window + 1], extended));
    let reference_graph = xamba::models::build_prefill(&shape, window + 1);
    let out = xamba::exec::run_once(&reference_graph, &inputs).unwrap();
    let v = shape.vocab_size;
    let reference = &out[0].as_f32()[window * v..(window + 1) * v];
    for (i, (a, b)) in step.iter().zip(reference).enumerate() {
        assert!(
            (a - b).abs() < 1e-3,
            "logit {i}: decode {a} vs prefill-continuation {b}"
        );
    }
    assert_eq!(argmax(&step), argmax(reference));
}

#[test]
fn pooled_decode_is_bitwise_identical_to_serial() {
    let shape = nano2();
    let window = 8;
    let weights = PlannedServeModel::random_weights(&shape, 9);
    let mut reference: Option<(Vec<Vec<Vec<f32>>>, Vec<SeqState>)> = None;
    for workers in [1usize, 2, 4] {
        let mut model = PlannedServeModel::new(
            &shape, &weights, window, &[1, 2, 4], workers, "baseline",
        )
        .unwrap();
        assert_eq!(model.pool_workers(), workers.max(1));
        let mut states: Vec<SeqState> = Vec::new();
        let mut toks: Vec<i32> = Vec::new();
        for i in 0..4 {
            let (logits, st) = model.prefill(&prompt(i, window)).unwrap();
            toks.push(argmax(&logits));
            states.push(st);
        }
        // several steps so the SSD state flows through the pool too
        let mut all_logits: Vec<Vec<Vec<f32>>> = Vec::new();
        for _ in 0..3 {
            let mut seqs: Vec<(&mut SeqState, i32)> =
                states.iter_mut().zip(toks.iter().copied()).collect();
            let step = model.decode(&mut seqs).unwrap();
            drop(seqs);
            toks = step.iter().map(|l| argmax(l)).collect();
            all_logits.push(step);
        }
        match &reference {
            None => reference = Some((all_logits, states)),
            Some((ref_logits, ref_states)) => {
                assert_eq!(
                    &all_logits, ref_logits,
                    "{workers} workers: logits diverged from serial"
                );
                assert_eq!(
                    &states, ref_states,
                    "{workers} workers: states diverged from serial"
                );
            }
        }
    }
}

#[test]
fn serve_plans_compile_once_and_reuse_arenas() {
    let shape = nano2();
    let window = 8;
    let weights = PlannedServeModel::random_weights(&shape, 3);
    let mut model =
        PlannedServeModel::new(&shape, &weights, window, &[1, 2], 1, "baseline").unwrap();
    // one plan per (program, bucket), all compiled at construction
    assert_eq!(model.plan_compiles(), 3);

    let p = prompt(0, window);
    let (l1, mut s1) = model.prefill(&p).unwrap();
    let (l2, mut s2) = model.prefill(&p).unwrap();
    assert_eq!(l1, l2, "prefill re-execution must reuse the arena cleanly");
    assert_eq!(s1, s2);

    // identical states + token through the cached decode plan twice:
    // arena reuse must be bitwise neutral
    let out1 = {
        let mut seqs = vec![(&mut s1, 42)];
        model.decode(&mut seqs).unwrap()
    };
    let out2 = {
        let mut seqs = vec![(&mut s2, 42)];
        model.decode(&mut seqs).unwrap()
    };
    assert_eq!(out1, out2);
    assert_eq!(s1, s2);
    assert_eq!(model.plan_compiles(), 3, "serving traffic must not recompile");
}

#[test]
fn planned_server_round_trip_streams_with_no_artifacts() {
    let shape = nano2();
    let window = 8;
    let weights = PlannedServeModel::random_weights(&shape, 21);
    let cfg = ServeConfig {
        max_slots: 4,
        queue_cap: 16,
        batch_wait_us: 100,
        prefill_window: window,
        ..Default::default()
    };
    let server = Server::start(
        move || {
            Ok(Box::new(PlannedServeModel::new(
                &shape, &weights, window, &[1, 2], 2, "xamba",
            )?) as Box<dyn ServeModel>)
        },
        cfg,
    )
    .unwrap();

    let rx = server.submit_streaming(
        b"the quick brown fox",
        GenParams { max_new_tokens: 6, ..Default::default() },
    );
    let mut streamed = Vec::new();
    let mut done = None;
    while let Ok(ev) = rx.recv_timeout(Duration::from_secs(60)) {
        match ev {
            StreamEvent::Token(t) => streamed.push(t),
            StreamEvent::Done(r) => {
                done = Some(r);
                break;
            }
        }
    }
    let resp = done.expect("stream never finished");
    assert_eq!(resp.finish, FinishReason::Length);
    assert_eq!(resp.generated.len(), 6);
    assert_eq!(streamed, resp.generated);

    let m = server.shutdown();
    assert_eq!(m.completed, 1);
    assert!(m.prefills >= 1, "no prefill recorded");
    assert!(m.decode_calls >= 1, "no decode recorded");
}

#[test]
fn tiny_mamba2_serves_end_to_end_through_the_config_path() {
    // the acceptance path: `ServeConfig { model: "tiny-mamba2", backend:
    // "planned" }` through `start_backend`, exactly what `xamba serve
    // --model tiny-mamba2` runs — random-initialized weights, no
    // artifacts, streaming prefill + decode round trip
    let cfg = ServeConfig {
        model: "tiny-mamba2".into(),
        backend: "planned".into(),
        variant: "baseline".into(),
        decode_buckets: vec![1, 2],
        max_slots: 2,
        queue_cap: 8,
        batch_wait_us: 100,
        prefill_window: 8,
        workers: 2,
        ..Default::default()
    };
    let server = start_backend(&cfg).unwrap();
    let rx = server.submit_streaming(
        b"hello mamba2",
        GenParams { max_new_tokens: 3, ..Default::default() },
    );
    let mut tokens = Vec::new();
    let mut done = None;
    while let Ok(ev) = rx.recv_timeout(Duration::from_secs(120)) {
        match ev {
            StreamEvent::Token(t) => tokens.push(t),
            StreamEvent::Done(r) => {
                done = Some(r);
                break;
            }
        }
    }
    let resp = done.expect("stream never finished");
    assert_eq!(resp.finish, FinishReason::Length);
    assert_eq!(tokens, resp.generated);
    let m = server.shutdown();
    assert_eq!(m.completed, 1);
}

#[test]
fn planned_server_greedy_output_is_deterministic_across_worker_counts() {
    let shape = nano2();
    let window = 8;
    let weights = PlannedServeModel::random_weights(&shape, 33);
    let mut outputs: Vec<Vec<u8>> = Vec::new();
    for workers in [1usize, 4] {
        let (shape, weights) = (shape.clone(), weights.clone());
        let cfg = ServeConfig {
            max_slots: 2,
            queue_cap: 8,
            batch_wait_us: 100,
            prefill_window: window,
            ..Default::default()
        };
        let server = Server::start(
            move || {
                Ok(Box::new(PlannedServeModel::new(
                    &shape, &weights, window, &[1, 2], workers, "baseline",
                )?) as Box<dyn ServeModel>)
            },
            cfg,
        )
        .unwrap();
        let rx = server.submit(
            b"hello",
            GenParams { max_new_tokens: 8, ..Default::default() },
        );
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.finish, FinishReason::Length);
        outputs.push(r.generated);
        server.shutdown();
    }
    assert_eq!(outputs[0], outputs[1], "worker count changed greedy output");
}

#[test]
fn unknown_model_and_backend_are_clear_config_errors() {
    // the guarded path: bad `ServeConfig.model` / `.backend` strings fail
    // fast in `start_backend` with an actionable message, never a panic
    let cfg = ServeConfig { backend: "cuda".into(), ..Default::default() };
    let err = start_backend(&cfg).err().expect("bad backend must be rejected");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("unknown serve backend") && msg.contains("cuda"),
        "{msg}"
    );
    assert!(msg.contains("planned") && msg.contains("pjrt"), "{msg}");

    let cfg = ServeConfig { model: "mamba3-9b".into(), ..Default::default() };
    let err = start_backend(&cfg).err().expect("bad model must be rejected");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("unknown serve model") && msg.contains("mamba3-9b"),
        "{msg}"
    );
    // the message lists what WOULD work, including the mamba-2 presets
    assert!(msg.contains("tiny-mamba2"), "{msg}");
}
