//! Acceptance tests of the replicated serving router: session-affinity
//! residency on the replica holding the conversation's recurrent state,
//! failover that never drops a reply channel when a replica hard-dies
//! mid-stream, heterogeneous (mixed-dtype) fleets with correct metric
//! aggregation, and rolling drain-restart under load.
//!
//! Mock-backed tests use `MockModel` (counter semantics make resume and
//! partial output trivially checkable; its `die` flag panics the engine
//! thread exactly like a real backend crash); the mixed-dtype test runs
//! real `PlannedServeModel` replicas end to end.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use xamba::config::{ModelShape, ServeConfig};
use xamba::coordinator::{
    EngineReplica, FinishReason, GenParams, MockModel, PlannedServeModel,
    ReplicaHandle, Router, ServeModel, StreamEvent,
};
use xamba::graph::DType;

fn fleet_cfg() -> ServeConfig {
    ServeConfig {
        max_slots: 8,
        queue_cap: 64,
        batch_wait_us: 100,
        ..Default::default()
    }
}

#[test]
fn session_follow_up_resumes_on_its_pinned_replica() {
    // resume-capable mocks: the fleet-level claim under test is that a
    // follow-up turn lands where the conversation's state lives and only
    // prefills its new suffix
    let router = Router::start(2, 32, move |i| {
        let replica = EngineReplica::start(
            move || {
                let mut m = MockModel::new(8, 256, vec![1, 2, 4]);
                m.resume_grain = 1;
                m.chunk = 4;
                m.decode_delay = Duration::from_millis(2);
                Ok(Box::new(m) as Box<dyn ServeModel>)
            },
            fleet_cfg(),
            format!("mock{i}"),
        )?;
        Ok(Box::new(replica) as Box<dyn ReplicaHandle>)
    })
    .unwrap();

    // turn 1 of session 42: both replicas idle, so least-loaded routing
    // picks replica 0 and the session pins there
    let p1 = b"abcdefghijklmnop";
    let r1 = router
        .submit(
            p1,
            GenParams { max_new_tokens: 4, session_id: Some(42), ..Default::default() },
        )
        .recv_timeout(Duration::from_secs(10))
        .unwrap();
    assert_eq!(r1.generated, b"qrst");

    // wait for turn 1's routing charge to drain, then park a long
    // no-session stream on replica 0 (still the least-loaded tie win):
    // plain load balancing would now send the follow-up to the idle
    // replica 1 — only session affinity keeps it with its state
    let deadline = Instant::now() + Duration::from_secs(5);
    while router.replica_status()[0].inflight_requests != 0 {
        assert!(Instant::now() < deadline, "turn 1 charge never freed");
        std::thread::sleep(Duration::from_millis(2));
    }
    let blocker = router
        .submit_streaming(b"z", GenParams { max_new_tokens: 400, ..Default::default() });
    match blocker.recv_timeout(Duration::from_secs(10)).unwrap() {
        StreamEvent::Token(_) => {}
        StreamEvent::Done(r) => panic!("blocker finished early: {r:?}"),
    }

    // turn 2: history ++ reply ++ new text. 19 of its 31 tokens are the
    // shared history (prompt ++ generated minus the unfed last sample),
    // which must RESUME from replica 0's prefix cache; only the 12-token
    // suffix prefills. Counter semantics pin decode-exactness: '!' -> "#
    let mut p2 = p1.to_vec();
    p2.extend_from_slice(&r1.generated);
    p2.extend_from_slice(b" more data!");
    let r2 = router
        .submit(
            &p2,
            GenParams { max_new_tokens: 2, session_id: Some(42), ..Default::default() },
        )
        .recv_timeout(Duration::from_secs(10))
        .unwrap();
    assert_eq!(r2.generated, b"\"#", "resume was not decode-exact");

    // replica-level residency: the hit is on the pinned replica; the
    // idle one never saw any of the conversation
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let st = router.replica_status();
        assert_eq!(st[1].metrics.admitted, 0, "work leaked to replica 1");
        if st[0].metrics.prefix_hits == 1 {
            break;
        }
        assert!(Instant::now() < deadline, "prefix hit never published");
        std::thread::sleep(Duration::from_millis(2));
    }
    // client walks away from the blocker; the relay cancels it upstream
    drop(blocker);

    let m = router.shutdown();
    assert_eq!(m.affinity_hits, 1, "turn 2 must ride the session pin");
    assert_eq!(m.router_rebalanced, 0);
    assert_eq!(m.prefix_hits, 1);
    assert_eq!(m.resumed_tokens, 19, "shared history was re-prefilled");
    assert!(m.completed >= 2);
    assert_eq!(m.failed, 0);
}

#[test]
fn replica_death_mid_stream_loses_no_reply_channels() {
    let flags: Vec<Arc<AtomicBool>> =
        (0..2).map(|_| Arc::new(AtomicBool::new(false))).collect();
    let factory_flags = flags.clone();
    let router = Router::start(2, 32, move |i| {
        let flag = factory_flags[i].clone();
        let cfg = ServeConfig {
            max_slots: 4,
            queue_cap: 64,
            batch_wait_us: 100,
            // defer admission while anything decodes: the pinned flood
            // below stays queued with zero tokens served, exercising the
            // requeue-not-started half of failover
            waiting_served_ratio: 1000.0,
            ..Default::default()
        };
        let replica = EngineReplica::start(
            move || {
                let mut m = MockModel::new(8, 256, vec![1, 2, 4]);
                m.decode_delay = Duration::from_millis(3);
                m.die = Some(flag);
                Ok(Box::new(m) as Box<dyn ServeModel>)
            },
            cfg,
            format!("mock{i}"),
        )?;
        Ok(Box::new(replica) as Box<dyn ReplicaHandle>)
    })
    .unwrap();

    // a streaming conversation starts decoding on replica 0, pinning
    // session 9 there
    let stream = router.submit_streaming(
        b"a",
        GenParams { max_new_tokens: 100, session_id: Some(9), ..Default::default() },
    );
    let mut streamed = Vec::new();
    while streamed.len() < 2 {
        match stream.recv_timeout(Duration::from_secs(10)).unwrap() {
            StreamEvent::Token(t) => streamed.push(t),
            StreamEvent::Done(r) => panic!("stream finished early: {r:?}"),
        }
    }

    // three follow-ups ride the pin onto replica 0 and queue behind the
    // stream, un-prefilled
    let followups: Vec<_> = (0..3)
        .map(|_| {
            router.submit(
                b"a",
                GenParams { max_new_tokens: 3, session_id: Some(9), ..Default::default() },
            )
        })
        .collect();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let st = router.replica_status();
        if st[0].inflight_requests == 4 {
            break;
        }
        assert_eq!(st[1].inflight_requests, 0, "follow-up dodged the session pin");
        assert!(Instant::now() < deadline, "follow-ups never dispatched");
        std::thread::sleep(Duration::from_millis(2));
    }
    let survivor_compiles = router.replica_status()[1].metrics.plan_compiles;

    // hard death: the next model call panics, unwinding the engine
    // thread and dropping every queued reply channel at once
    flags[0].store(true, Ordering::SeqCst);

    // the in-flight stream fails WITH the partial output it streamed
    let dead = loop {
        match stream.recv_timeout(Duration::from_secs(10)).unwrap() {
            StreamEvent::Token(t) => streamed.push(t),
            StreamEvent::Done(r) => break r,
        }
    };
    assert_eq!(dead.finish, FinishReason::Failed);
    assert!(!dead.generated.is_empty(), "partial output lost in the failure");
    assert_eq!(dead.generated, streamed, "failure response disagrees with the stream");

    // the queued follow-ups re-route to the survivor and complete:
    // every reply channel answers
    for rx in followups {
        let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(r.finish, FinishReason::Length);
        assert_eq!(r.generated, b"bcd");
    }
    let st = router.replica_status();
    assert_eq!(
        st[1].metrics.plan_compiles, survivor_compiles,
        "failover must not recompile the survivor"
    );

    let m = router.shutdown();
    assert_eq!(m.completed, 3);
    assert_eq!(m.failed, 1, "exactly the mid-decode casualty");
    assert_eq!(m.router_rebalanced, 3, "one requeue per not-yet-started request");
    assert_eq!(m.replica_unhealthy, 1);
    // 3 pinned follow-ups before the death; after it, the first requeue
    // re-pins the session to the survivor and the other two hit the pin
    assert_eq!(m.affinity_hits, 5);
}

#[test]
fn mixed_dtype_fleet_serves_and_aggregates_per_replica_metrics() {
    let shape = ModelShape {
        name: "nano-mamba".into(),
        arch: "mamba".into(),
        vocab_size: 256,
        d_model: 32,
        n_layers: 2,
        d_state: 8,
        d_conv: 3,
        expand: 2,
        dt_rank: 4,
        headdim: 16,
        chunk: 8,
    };
    let weights = PlannedServeModel::random_weights(&shape, 42);
    let router = Router::start(3, 32, move |i| {
        let name = ["f32", "f16", "i8"][i];
        let shape = shape.clone();
        let weights = weights.clone();
        let cfg = ServeConfig {
            max_slots: 4,
            queue_cap: 16,
            batch_wait_us: 100,
            // keep plan_compiles a pure function of the traffic shape
            prefix_cache_mb: 0,
            ..Default::default()
        };
        let replica = EngineReplica::start(
            move || {
                let dt = match name {
                    "f16" => DType::F16,
                    "i8" => DType::I8,
                    _ => DType::F32,
                };
                Ok(Box::new(PlannedServeModel::new_dtyped(
                    &shape, &weights, 8, &[1, 2], 1, "baseline", dt,
                )?) as Box<dyn ServeModel>)
            },
            cfg,
            format!("replica{i}:{name}"),
        )?;
        Ok(Box::new(replica) as Box<dyn ReplicaHandle>)
    })
    .unwrap();

    // three equal-cost requests submitted back to back spread one per
    // replica (least-loaded: each dispatch charges its target before the
    // next routes)
    let rxs: Vec<_> = (0..3)
        .map(|_| {
            router.submit(b"abcd", GenParams { max_new_tokens: 4, ..Default::default() })
        })
        .collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(r.finish, FinishReason::Length);
        assert_eq!(r.generated.len(), 4);
    }

    // every dtype replica served exactly one request
    let deadline = Instant::now() + Duration::from_secs(30);
    let st = loop {
        let st = router.replica_status();
        if st.iter().all(|s| s.metrics.completed == 1) {
            break st;
        }
        assert!(Instant::now() < deadline, "per-replica completions never published");
        std::thread::sleep(Duration::from_millis(5));
    };
    for (i, name) in ["f32", "f16", "i8"].iter().enumerate() {
        assert_eq!(st[i].descriptor, format!("replica{i}:{name}"));
        assert!(
            st[i].metrics.plan_compiles > 0,
            "{} replica compiled nothing",
            name
        );
    }
    let compiled: u64 = st.iter().map(|s| s.metrics.plan_compiles).sum();
    let served: u64 = st.iter().map(|s| s.metrics.tokens_out).sum();

    // the aggregate is exactly the per-replica sum — nothing double
    // counted, nothing dropped when the fleet shuts down
    let m = router.shutdown();
    assert_eq!(m.completed, 3);
    assert_eq!(m.failed, 0);
    assert_eq!(m.plan_compiles, compiled);
    assert_eq!(m.tokens_out, served);
}

#[test]
fn rolling_restart_under_load_causes_no_overloads() {
    let router = Router::start(2, 32, move |i| {
        let replica = EngineReplica::start(
            move || {
                let mut m = MockModel::new(8, 256, vec![1, 2, 4]);
                m.decode_delay = Duration::from_millis(1);
                Ok(Box::new(m) as Box<dyn ServeModel>)
            },
            fleet_cfg(),
            format!("mock{i}"),
        )?;
        Ok(Box::new(replica) as Box<dyn ReplicaHandle>)
    })
    .unwrap();

    let wave = |n: usize| -> Vec<_> {
        (0..n)
            .map(|_| {
                router.submit(b"ab", GenParams { max_new_tokens: 4, ..Default::default() })
            })
            .collect()
    };

    // wave 1: both replicas serving
    let wave1 = wave(8);
    // restart replica 0 in the middle of wave 2's arrivals: dispatch
    // must flow around the draining replica, and the engine swap waits
    // for its in-flight work
    let mut wave2 = wave(6);
    router.restart(0);
    wave2.extend(wave(6));
    let mut finishes = Vec::new();
    for rx in wave1.into_iter().chain(wave2) {
        finishes.push(rx.recv_timeout(Duration::from_secs(10)).unwrap().finish);
    }
    assert!(
        finishes.iter().all(|f| *f == FinishReason::Length),
        "restart disturbed the fleet: {finishes:?}"
    );

    // the fresh engine returns to rotation...
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let st = router.replica_status();
        if st[0].ready && st[0].healthy {
            break;
        }
        assert!(Instant::now() < deadline, "replica 0 never came back");
        std::thread::sleep(Duration::from_millis(2));
    }
    // ...and takes its share of wave 3
    for rx in wave(8) {
        let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(r.finish, FinishReason::Length);
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let st = router.replica_status();
        if st[0].metrics.admitted > 0 {
            break;
        }
        assert!(Instant::now() < deadline, "restarted replica took no work");
        std::thread::sleep(Duration::from_millis(2));
    }

    let m = router.shutdown();
    // nothing Overloaded, nothing failed, nothing lost across the swap:
    // the retired engine's counters fold into the aggregate
    assert_eq!(m.completed, 28);
    assert_eq!(m.admitted, 28);
    assert_eq!(m.overloaded, 0);
    assert_eq!(m.failed, 0);
    assert_eq!(m.router_rebalanced, 0);
    assert_eq!(m.replica_unhealthy, 0, "a clean restart is not a health event");
}
