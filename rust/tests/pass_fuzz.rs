//! Randomized pass-pipeline fuzzing: generate random op chains, apply the
//! full XAMBA pipeline (CumBA -> ReduBA -> ActiBA), and differentially
//! verify against the unoptimized graph. This is the machine-checked
//! version of the paper's implicit claim that the conversion-time
//! rewrites are semantics-preserving on ANY graph, not just Mamba's.

use xamba::graph::{Graph, NodeId};
use xamba::passes::{
    actiba::ActibaPass, cumba::CumbaPass, reduba::RedubaPass, verify, Pass,
};
use xamba::util::Prng;

/// Grow a random graph: start from a (m, n) input, apply a random chain
/// of shape-preserving or shape-reducing ops, output everything left.
fn random_graph(rng: &mut Prng, case: usize) -> Graph {
    let mut g = Graph::new(&format!("fuzz{case}"));
    let m = 2 + rng.below(10);
    let n = 2 + rng.below(10);
    let x = g.input("x", vec![m, n]);
    let mut frontier: Vec<NodeId> = vec![x];
    let ops = 3 + rng.below(8);
    for i in 0..ops {
        let src = frontier[rng.below(frontier.len())];
        let shape = g.shape(src).to_vec();
        let nm = format!("op{i}");
        let new = match rng.below(8) {
            0 if shape.len() == 2 => g.cumsum(src, rng.below(2), &nm),
            1 if shape.len() == 2 => {
                // reduce, then keep the result around (rank drops)
                g.reduce_sum(src, rng.below(shape.len()), &nm)
            }
            2 => g.silu(src, &nm),
            3 => g.softplus(src, &nm),
            4 => g.exp(src, &nm),
            5 => {
                let half = g.const_scalar(&format!("{nm}.c"), 0.5);
                g.mul(src, half, &nm)
            }
            6 if shape.len() == 2 => {
                // square matmul keeps things composable
                let k = shape[1];
                let w_vals: Vec<f32> =
                    (0..k * k).map(|_| rng.normal() * 0.3).collect();
                let w = g.constant(
                    &format!("{nm}.w"),
                    xamba::graph::Tensor::f32(vec![k, k], w_vals),
                );
                g.matmul(src, w, &nm)
            }
            _ => g.add(src, src, &nm),
        };
        frontier.push(new);
    }
    for (i, &f) in frontier.iter().enumerate().skip(1) {
        if i % 2 == 1 || i == frontier.len() - 1 {
            g.output(f);
        }
    }
    g
}

#[test]
fn full_pipeline_preserves_semantics_on_random_graphs() {
    let mut rng = Prng::new(0xF0_22);
    for case in 0..40 {
        let g = random_graph(&mut rng, case);
        let exact = RedubaPass.apply(&CumbaPass.apply(&g));
        let r = verify::differential(&g, &exact, 2, case as u64, 0.5)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert!(
            r.max_abs_err < 1e-2,
            "case {case}: exact rewrites drifted {:.3e}",
            r.max_abs_err
        );

        // ActiBA is approximate: just require boundedness + same shape
        let approx = ActibaPass::default().apply(&exact);
        let r2 = verify::differential(&g, &approx, 1, case as u64, 0.5)
            .unwrap_or_else(|e| panic!("case {case} actiba: {e}"));
        assert!(
            r2.max_abs_err.is_finite(),
            "case {case}: actiba produced non-finite drift"
        );
    }
}

#[test]
fn pipeline_eliminates_all_rewritable_ops() {
    let mut rng = Prng::new(42);
    for case in 0..20 {
        let g = random_graph(&mut rng, case);
        let opt = ActibaPass::default().apply(&RedubaPass.apply(&CumbaPass.apply(&g)));
        let c = xamba::graph::Census::of(&opt);
        assert_eq!(c.get("CumSum"), 0, "case {case}");
        assert_eq!(c.get("ReduceSum"), 0, "case {case}");
        assert_eq!(c.get("Swish"), 0, "case {case}");
        assert_eq!(c.get("SoftPlus"), 0, "case {case}");
    }
}

#[test]
fn pipeline_order_does_not_matter_for_exact_passes() {
    let mut rng = Prng::new(9);
    for case in 0..10 {
        let g = random_graph(&mut rng, case);
        let ab = RedubaPass.apply(&CumbaPass.apply(&g));
        let ba = CumbaPass.apply(&RedubaPass.apply(&g));
        let r = verify::differential(&ab, &ba, 2, case as u64, 0.5).unwrap();
        assert!(r.max_abs_err < 1e-4, "case {case}: order-dependent result");
    }
}
