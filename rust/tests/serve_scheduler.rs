//! Scheduler-invariant and engine-loop regression tests for the
//! token-budget continuous-batching scheduler: the budget is never
//! exceeded across admission sources, finished sequences leave the
//! decode batch the same step they end, deadline-expired and cancelled
//! requests free budget immediately, queue saturation surfaces an
//! explicit `Overloaded` response on EVERY ingress path, first
//! (prefill-sampled) tokens get finish checks, prefill failures count
//! as failures, and NaN logits can no longer kill the engine thread.

use std::time::Duration;

use anyhow::Result;
use xamba::config::{ModelShape, ServeConfig};
use xamba::coordinator::{
    FinishReason, GenParams, MockModel, PlannedServeModel, SeqState, ServeModel,
    Server, StreamEvent,
};

fn cfg(slots: usize) -> ServeConfig {
    ServeConfig {
        max_slots: slots,
        queue_cap: 16,
        batch_wait_us: 100,
        ..Default::default()
    }
}

// MockModel's prefill window is 8 and its length range is (8, 8), so
// every prompt encodes to exactly 8 tokens: a request's budget cost is
// always 8 + max_new_tokens.
const WINDOW_COST: usize = 8;

// --- satellite regressions -------------------------------------------------

#[test]
fn max_new_tokens_one_delivers_exactly_one_token() {
    // the prefill-sampled token must get a length check: before the fix
    // it was pushed into the decode batch and a second token came out
    let model = MockModel::new(8, 256, vec![1]);
    let server = Server::start(move || Ok(Box::new(model) as _), cfg(2)).unwrap();
    let rx = server.submit(b"a", GenParams { max_new_tokens: 1, ..Default::default() });
    let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(r.generated, b"b", "exactly one token");
    assert_eq!(r.finish, FinishReason::Length);
    let m = server.shutdown();
    assert_eq!(m.completed, 1);
    assert_eq!(m.tokens_out, 1);
    assert_eq!(m.decode_calls, 0, "a 1-token request never enters decode");
}

#[test]
fn stop_byte_sampled_at_prefill_finishes_immediately() {
    // prompt "c" predicts 'd'; a stop byte hit on the FIRST sample must
    // end the request without an extra decode step
    let model = MockModel::new(8, 256, vec![1]);
    let server = Server::start(move || Ok(Box::new(model) as _), cfg(2)).unwrap();
    let rx = server.submit(
        b"c",
        GenParams { max_new_tokens: 50, stop_byte: Some(b'd'), ..Default::default() },
    );
    let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(r.finish, FinishReason::Stop);
    assert_eq!(r.generated, b"d");
    let m = server.shutdown();
    assert_eq!(m.decode_calls, 0, "stop at prefill must skip decode entirely");
    assert_eq!(m.completed, 1);
}

#[test]
fn resume_path_applies_first_token_finish_check() {
    // a 16-byte prompt with an 8-token window streams through the
    // chunked-prefill (resume) admission path; its prefill-sampled
    // token hits the stop byte and must finish there too
    let mut model = MockModel::new(8, 256, vec![1]);
    model.resume_grain = 1;
    model.chunk = 4;
    let server = Server::start(move || Ok(Box::new(model) as _), cfg(2)).unwrap();
    let rx = server.submit(
        b"abcdefghijklmnop", // last token 'p' predicts 'q'
        GenParams { max_new_tokens: 50, stop_byte: Some(b'q'), ..Default::default() },
    );
    let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(r.finish, FinishReason::Stop);
    assert_eq!(r.generated, b"q");
    let m = server.shutdown();
    assert_eq!(m.decode_calls, 0);
    assert_eq!(m.completed, 1);
    assert!(m.prefill_chunks >= 2, "long prompt must have streamed in chunks");
}

#[test]
fn prefill_failure_finishes_failed_and_counts() {
    // before the fix: prefill errors finished as Rejected and NO metric
    // moved; they must surface as Failed and count as failures
    struct FailingPrefill(MockModel);
    impl ServeModel for FailingPrefill {
        fn prefill_len(&self) -> usize {
            self.0.prefill_len()
        }
        fn vocab(&self) -> usize {
            self.0.vocab()
        }
        fn decode_buckets(&self) -> &[usize] {
            self.0.decode_buckets()
        }
        fn prefill(&mut self, _tokens: &[i32]) -> Result<(Vec<f32>, SeqState)> {
            Err(anyhow::anyhow!("synthetic prefill failure"))
        }
        fn decode(
            &mut self,
            seqs: &mut [(&mut SeqState, i32)],
        ) -> Result<Vec<Vec<f32>>> {
            self.0.decode(seqs)
        }
    }

    let model = FailingPrefill(MockModel::new(8, 256, vec![1]));
    let server = Server::start(move || Ok(Box::new(model) as _), cfg(2)).unwrap();
    let rx = server.submit(b"a", GenParams { max_new_tokens: 5, ..Default::default() });
    let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(r.finish, FinishReason::Failed);
    assert!(r.generated.is_empty());
    let m = server.shutdown();
    assert_eq!(m.failed, 1, "prefill failures must count as failures");
    assert_eq!(m.rejected, 0, "prefill failures are not admission rejections");
    assert_eq!(m.completed, 0);
}

#[test]
fn nan_logits_do_not_kill_the_engine() {
    // before the fix: sample()'s partial_cmp().unwrap() panicked on the
    // first NaN logit, killing the engine thread for every request
    struct NanDecode(MockModel);
    impl ServeModel for NanDecode {
        fn prefill_len(&self) -> usize {
            self.0.prefill_len()
        }
        fn vocab(&self) -> usize {
            self.0.vocab()
        }
        fn decode_buckets(&self) -> &[usize] {
            self.0.decode_buckets()
        }
        fn prefill(&mut self, tokens: &[i32]) -> Result<(Vec<f32>, SeqState)> {
            self.0.prefill(tokens)
        }
        fn decode(
            &mut self,
            seqs: &mut [(&mut SeqState, i32)],
        ) -> Result<Vec<Vec<f32>>> {
            let vocab = self.0.vocab();
            Ok(seqs.iter().map(|_| vec![f32::NAN; vocab]).collect())
        }
    }

    let model = NanDecode(MockModel::new(8, 256, vec![1, 2]));
    let server = Server::start(move || Ok(Box::new(model) as _), cfg(4)).unwrap();
    let rx_a =
        server.submit(b"a", GenParams { max_new_tokens: 3, ..Default::default() });
    let rx_b =
        server.submit(b"b", GenParams { max_new_tokens: 3, ..Default::default() });
    let ra = rx_a.recv_timeout(Duration::from_secs(5)).expect("engine died on NaN");
    let rb = rx_b.recv_timeout(Duration::from_secs(5)).expect("engine died on NaN");
    assert_eq!(ra.finish, FinishReason::Length);
    assert_eq!(rb.finish, FinishReason::Length);
    assert_eq!(ra.generated.len(), 3);
    // the engine must still serve AFTER surviving NaN steps
    let rx_c =
        server.submit(b"c", GenParams { max_new_tokens: 2, ..Default::default() });
    assert_eq!(
        rx_c.recv_timeout(Duration::from_secs(5)).unwrap().finish,
        FinishReason::Length
    );
    let m = server.shutdown();
    assert_eq!(m.completed, 3);
    assert_eq!(m.failed, 0);
}

#[test]
fn idle_queue_saturation_still_sends_a_response() {
    // before the fix: overflow hit in the IDLE ingress branch bumped the
    // rejected counter but never replied — the client's recv() hung
    // until timeout. With queue_cap 0 every submission saturates; a
    // request arriving while the engine sleeps in recv_timeout must
    // still get an explicit Overloaded response.
    let model = MockModel::new(8, 256, vec![1]);
    let server = Server::start(
        move || Ok(Box::new(model) as _),
        ServeConfig { max_slots: 2, queue_cap: 0, batch_wait_us: 100, ..Default::default() },
    )
    .unwrap();
    // let the engine park in its idle wait before submitting
    std::thread::sleep(Duration::from_millis(50));
    let rxs: Vec<_> = (0..5)
        .map(|i| {
            if i > 0 {
                std::thread::sleep(Duration::from_millis(5));
            }
            server.submit(b"x", GenParams { max_new_tokens: 4, ..Default::default() })
        })
        .collect();
    for rx in rxs {
        let r = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("saturated request got NO response (idle-branch regression)");
        assert_eq!(r.finish, FinishReason::Overloaded);
        assert!(r.generated.is_empty());
    }
    let m = server.shutdown();
    assert_eq!(m.overloaded, 5);
    assert_eq!(m.admitted, 0);
    assert_eq!(m.rejected, 0, "saturation is Overloaded, not Rejected");
}

// --- scheduler invariants --------------------------------------------------

#[test]
fn token_budget_is_never_exceeded() {
    // budget 24, each request costs 8 (window) + 4 (max_new) = 12: at
    // most two sequences may ever be live at once, however many flood in
    let mut model = MockModel::new(8, 256, vec![1, 2, 4]);
    model.prefill_buckets = vec![1, 2, 4];
    let server = Server::start(
        move || Ok(Box::new(model) as _),
        ServeConfig {
            max_slots: 8,
            queue_cap: 16,
            batch_wait_us: 100,
            max_batch_total_tokens: 24,
            ..Default::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = (0..6)
        .map(|_| {
            server.submit(b"m", GenParams { max_new_tokens: 4, ..Default::default() })
        })
        .collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r.finish, FinishReason::Length);
        assert!(
            r.batch_trace.iter().all(|&b| b <= 2),
            "decode batch exceeded the budget cap: {:?}",
            r.batch_trace
        );
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 6);
    assert!(m.budget_peak <= 24, "budget peak {} > 24", m.budget_peak);
    assert!(m.budget_peak >= 12, "budget accounting never engaged");
    assert!(m.mean_decode_batch() <= 2.0 + 1e-9);
}

#[test]
fn oversize_request_is_rejected_at_admission() {
    // cost 8 + 4 = 12 > budget 10: the request can NEVER run and must be
    // rejected immediately (Rejected, not Overloaded)
    let model = MockModel::new(8, 256, vec![1]);
    let server = Server::start(
        move || Ok(Box::new(model) as _),
        ServeConfig {
            max_slots: 2,
            queue_cap: 16,
            batch_wait_us: 100,
            max_batch_total_tokens: 10,
            ..Default::default()
        },
    )
    .unwrap();
    let rx = server.submit(b"a", GenParams { max_new_tokens: 4, ..Default::default() });
    let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(r.finish, FinishReason::Rejected);
    let m = server.shutdown();
    assert_eq!(m.rejected, 1);
    assert_eq!(m.admitted, 0);
    assert_eq!(m.overloaded, 0);
}

#[test]
fn cancellation_frees_budget_immediately() {
    // the budget fits exactly one live request; cancelling the first
    // (receiver drop) must release its charge so the second can run
    let mut model = MockModel::new(8, 256, vec![1]);
    model.decode_delay = Duration::from_millis(1);
    let server = Server::start(
        move || Ok(Box::new(model) as _),
        ServeConfig {
            max_slots: 4,
            queue_cap: 16,
            batch_wait_us: 100,
            max_batch_total_tokens: WINDOW_COST + 10_000,
            ..Default::default()
        },
    )
    .unwrap();
    let rx_a = server.submit_streaming(
        b"a",
        GenParams { max_new_tokens: 10_000, ..Default::default() },
    );
    // wait until A is definitely live, then walk away
    let _ = rx_a.recv_timeout(Duration::from_secs(5)).unwrap();
    let _ = rx_a.recv_timeout(Duration::from_secs(5)).unwrap();
    let rx_b =
        server.submit(b"b", GenParams { max_new_tokens: 4, ..Default::default() });
    drop(rx_a);
    let rb = rx_b.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(rb.finish, FinishReason::Length, "cancel never freed the budget");
    let m = server.shutdown();
    assert_eq!(m.cancelled, 1);
    assert_eq!(m.completed, 1);
}

#[test]
fn queued_request_expires_at_its_deadline() {
    // A holds the whole budget; B's per-request deadline passes while it
    // waits and it must finish DeadlineExceeded with empty output
    let mut model = MockModel::new(8, 256, vec![1]);
    model.decode_delay = Duration::from_millis(1);
    let server = Server::start(
        move || Ok(Box::new(model) as _),
        ServeConfig {
            max_slots: 4,
            queue_cap: 16,
            batch_wait_us: 100,
            max_batch_total_tokens: WINDOW_COST + 10_000,
            ..Default::default()
        },
    )
    .unwrap();
    let rx_a = server.submit_streaming(
        b"a",
        GenParams { max_new_tokens: 10_000, ..Default::default() },
    );
    let _ = rx_a.recv_timeout(Duration::from_secs(5)).unwrap();
    let rx_b = server.submit(
        b"b",
        GenParams { max_new_tokens: 4, deadline_ms: Some(50), ..Default::default() },
    );
    let rb = rx_b.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(rb.finish, FinishReason::DeadlineExceeded);
    assert!(rb.generated.is_empty(), "expired in queue: no tokens");
    drop(rx_a);
    let m = server.shutdown();
    assert_eq!(m.deadline_expired, 1);
    assert_eq!(m.cancelled, 1);
}

#[test]
fn decoding_request_expires_with_partial_output() {
    // the server-wide default deadline interrupts a long generation
    // mid-decode: partial output comes back, and the freed budget serves
    // the next request normally
    let mut model = MockModel::new(8, 256, vec![1]);
    model.decode_delay = Duration::from_millis(2);
    let server = Server::start(
        move || Ok(Box::new(model) as _),
        ServeConfig {
            max_slots: 2,
            queue_cap: 16,
            batch_wait_us: 100,
            deadline_ms: 100,
            ..Default::default()
        },
    )
    .unwrap();
    let rx = server.submit(
        b"a",
        GenParams { max_new_tokens: 10_000, ..Default::default() },
    );
    let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(r.finish, FinishReason::DeadlineExceeded);
    assert!(
        !r.generated.is_empty() && r.generated.len() < 10_000,
        "expected partial output, got {} tokens",
        r.generated.len()
    );
    // a fresh request gets its own deadline window and completes
    let rx2 =
        server.submit(b"b", GenParams { max_new_tokens: 3, ..Default::default() });
    let r2 = rx2.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(r2.finish, FinishReason::Length);
    let m = server.shutdown();
    assert_eq!(m.deadline_expired, 1);
    assert_eq!(m.completed, 1);
}

#[test]
fn waiting_served_ratio_defers_admission() {
    // ratio 100: one queued request never outweighs a running batch, so
    // B waits until A's batch drains — decode occupancy stays exactly 1
    let mut model = MockModel::new(8, 256, vec![1, 2]);
    model.decode_delay = Duration::from_millis(1);
    let server = Server::start(
        move || Ok(Box::new(model) as _),
        ServeConfig {
            max_slots: 4,
            queue_cap: 16,
            batch_wait_us: 100,
            waiting_served_ratio: 100.0,
            ..Default::default()
        },
    )
    .unwrap();
    let rx_a = server.submit_streaming(
        b"a",
        GenParams { max_new_tokens: 20, ..Default::default() },
    );
    let _ = rx_a.recv_timeout(Duration::from_secs(5)).unwrap();
    let rx_b =
        server.submit(b"b", GenParams { max_new_tokens: 4, ..Default::default() });
    let rb = rx_b.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(rb.finish, FinishReason::Length);
    assert!(
        rb.batch_trace.iter().all(|&b| b == 1),
        "deferred admission still co-batched: {:?}",
        rb.batch_trace
    );
    while let Ok(ev) = rx_a.recv_timeout(Duration::from_secs(10)) {
        if matches!(ev, StreamEvent::Done(_)) {
            break;
        }
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 2);
    assert!(
        (m.mean_decode_batch() - 1.0).abs() < 1e-9,
        "occupancy {} != 1.0",
        m.mean_decode_batch()
    );
}

#[test]
fn finished_sequences_leave_the_batch_the_same_step() {
    // A (2 tokens) and B (10 tokens) co-decode at most ONE step: the
    // step A finishes it must already be gone from B's next batch
    let mut model = MockModel::new(8, 256, vec![1, 2]);
    model.prefill_buckets = vec![1, 2];
    let server = Server::start(move || Ok(Box::new(model) as _), cfg(4)).unwrap();
    let rx_a =
        server.submit(b"a", GenParams { max_new_tokens: 2, ..Default::default() });
    let rx_b =
        server.submit(b"b", GenParams { max_new_tokens: 10, ..Default::default() });
    let ra = rx_a.recv_timeout(Duration::from_secs(10)).unwrap();
    let rb = rx_b.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(ra.generated.len(), 2);
    assert_eq!(rb.generated.len(), 10);
    // A runs exactly one decode step, so B can see batch=2 at most once;
    // a stale member would leave a second (or later) batch-2 entry
    assert!(
        rb.batch_trace.iter().filter(|&&b| b == 2).count() <= 1,
        "finished sequence lingered in the batch: {:?}",
        rb.batch_trace
    );
    let m = server.shutdown();
    assert_eq!(m.completed, 2);
}

#[test]
fn non_bucket_membership_pads_instead_of_failing() {
    // the only compiled decode bucket is 2: a single live sequence must
    // be padded onto it (scatter/gather remap), not error out
    let model = MockModel::new(8, 256, vec![2]);
    let server = Server::start(move || Ok(Box::new(model) as _), cfg(4)).unwrap();
    let rx =
        server.submit(b"a", GenParams { max_new_tokens: 3, ..Default::default() });
    let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(r.finish, FinishReason::Length);
    assert_eq!(r.generated, b"bcd");
    let m = server.shutdown();
    assert!(m.decode_padded_slots >= 1, "pad path never exercised");
    assert!(m.decode_slot_utilization() < 1.0);
    assert_eq!(m.failed, 0);
}

// --- remap-not-recompile on the planned backend ----------------------------

fn nano() -> ModelShape {
    ModelShape {
        name: "nano-mamba".into(),
        arch: "mamba".into(),
        vocab_size: 256,
        d_model: 32,
        n_layers: 2,
        d_state: 8,
        d_conv: 3,
        expand: 2,
        dt_rank: 4,
        headdim: 32,
        chunk: 16,
    }
}

#[test]
fn membership_churn_never_recompiles_planned_buckets() {
    let shape = nano();
    let window = 8;
    let weights = PlannedServeModel::random_weights(&shape, 11);
    let server = Server::start(
        move || {
            Ok(Box::new(PlannedServeModel::new(
                &shape, &weights, window, &[1, 2], 1, "baseline",
            )?) as Box<dyn ServeModel>)
        },
        ServeConfig {
            max_slots: 4,
            queue_cap: 16,
            batch_wait_us: 100,
            prefill_window: window,
            // keep the compile gauge deterministic: no prefix tier (its
            // resume plan compiles lazily on first hit) and a single
            // prompt length-class throughout
            prefix_cache_mb: 0,
            ..Default::default()
        },
    )
    .unwrap();

    // warmup: overlap two requests so batch sizes 1 AND 2 both execute
    let w: Vec<_> = (0..2)
        .map(|_| {
            server.submit(b"warm", GenParams { max_new_tokens: 6, ..Default::default() })
        })
        .collect();
    for rx in w {
        rx.recv_timeout(Duration::from_secs(60)).unwrap();
    }
    let warm = server.metrics();
    assert!(warm.plan_compiles > 0, "gauge never exported");

    // churn: staggered decode maxima force joins/leaves every few steps;
    // same prompt length as warmup = same (already compiled) class
    let rxs: Vec<_> = (0..6)
        .map(|i| {
            server.submit(
                b"warm",
                GenParams { max_new_tokens: 2 + (i % 4), ..Default::default() },
            )
        })
        .collect();
    for rx in rxs {
        assert_eq!(
            rx.recv_timeout(Duration::from_secs(60)).unwrap().finish,
            FinishReason::Length
        );
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 8);
    assert_eq!(
        m.plan_compiles, warm.plan_compiles,
        "membership churn triggered a plan recompile"
    );
}
