//! Differential property testing of the planned executor.
//!
//! Randomized graphs — mixed ops, broadcasts, and their CumBA / ReduBA /
//! ActiBA-rewritten variants — run through both the naive reference
//! walker (`exec::naive`, the original interpreter) and the compiled
//! `ExecutionPlan`. Outputs must agree *bitwise*: the planned kernels
//! mirror the reference loops op-for-op, and fusion composes the exact
//! same scalar functions. Every plan is also executed repeatedly (same
//! and different inputs) to catch arena-reuse bugs — stale buffers, slot
//! aliasing, cross-call state leaks.

use xamba::exec::{naive, Backend, Plan, PlannedBackend};
use xamba::graph::{Graph, NodeId, Tensor};
use xamba::passes::{
    actiba::ActibaPass, cumba::CumbaPass, reduba::RedubaPass, verify, Pass,
};
use xamba::util::Prng;

/// Grow a random graph over a (m, n) input: elementwise chains (fusion
/// fodder), scalar-left/right binaries, broadcast adds, scans,
/// reductions, layout ops, matmuls, softmax, rmsnorm.
fn random_graph(rng: &mut Prng, case: usize) -> Graph {
    let mut g = Graph::new(&format!("exec_fuzz{case}"));
    let m = 2 + rng.below(6);
    let n = 2 + rng.below(6);
    let x = g.input("x", vec![m, n]);
    let mut frontier: Vec<NodeId> = vec![x];
    let ops = 4 + rng.below(10);
    for i in 0..ops {
        let src = frontier[rng.below(frontier.len())];
        let shape = g.shape(src).to_vec();
        let nm = format!("op{i}");
        let new = match rng.below(15) {
            0 if shape.len() == 2 => g.cumsum(src, rng.below(2), &nm),
            1 if !shape.is_empty() => g.reduce_sum(src, rng.below(shape.len()), &nm),
            2 => g.silu(src, &nm),
            3 => g.softplus(src, &nm),
            4 => g.exp(src, &nm),
            5 => {
                let c = g.const_scalar(&format!("{nm}.c"), 0.5);
                g.mul(src, c, &nm)
            }
            6 => {
                // scalar-on-left, non-commutative: exercises the new
                // ScalarLeft fast path on both executors
                let c = g.const_scalar(&format!("{nm}.c"), 1.5);
                g.sub(c, src, &nm)
            }
            7 if shape.len() == 2 => {
                let row = Tensor::f32(vec![1, shape[1]], rng.normal_vec(shape[1]));
                let c = g.constant(&format!("{nm}.row"), row);
                g.add(src, c, &nm)
            }
            8 if shape.len() == 2 => g.transpose(src, vec![1, 0], &nm),
            9 if shape.len() == 2 && shape[1] >= 2 => {
                let len = 1 + rng.below(shape[1] - 1);
                let start = rng.below(shape[1] - len + 1);
                g.slice(src, 1, start, len, &nm)
            }
            10 if shape.len() == 2 => {
                let k = shape[1];
                let w: Vec<f32> = rng.normal_vec(k * k).iter().map(|v| v * 0.3).collect();
                let c = g.constant(&format!("{nm}.w"), Tensor::f32(vec![k, k], w));
                g.matmul(src, c, &nm)
            }
            11 if shape.len() == 2 => g.softmax(src, rng.below(2), &nm),
            12 if !shape.is_empty() => {
                let d = *shape.last().unwrap();
                let w = g.constant(
                    &format!("{nm}.w"),
                    Tensor::f32(vec![d], rng.range_vec(d, 0.5, 1.5)),
                );
                g.rmsnorm(src, w, &nm)
            }
            13 if shape.len() == 2 => g.concat(&[src, src], rng.below(2), &nm),
            14 if !shape.is_empty() => {
                // reshape mid-graph: fusion must see through it (pure
                // row-major identity) without perturbing results
                let n: usize = shape.iter().product();
                g.reshape(src, vec![n], &nm)
            }
            _ => g.add(src, src, &nm),
        };
        frontier.push(new);
    }
    for (i, &f) in frontier.iter().enumerate() {
        if i % 2 == 0 || i + 1 == frontier.len() {
            g.output(f);
        }
    }
    g
}

fn assert_bitwise(label: &str, want: &[Tensor], got: &[Tensor]) {
    assert_eq!(want.len(), got.len(), "{label}: output arity");
    for (o, (w, t)) in want.iter().zip(got).enumerate() {
        assert_eq!(w.shape, t.shape, "{label}: output {o} shape");
        assert_eq!(w.dtype(), t.dtype(), "{label}: output {o} dtype");
        match w.dtype() {
            xamba::graph::DType::F32 => {
                for (i, (&a, &b)) in w.as_f32().iter().zip(t.as_f32()).enumerate() {
                    assert!(
                        a.to_bits() == b.to_bits(),
                        "{label}: output {o}[{i}]: naive {a} ({:08x}) vs planned {b} ({:08x})",
                        a.to_bits(),
                        b.to_bits()
                    );
                }
            }
            xamba::graph::DType::I32 => {
                assert_eq!(w.as_i32(), t.as_i32(), "{label}: output {o} payload");
            }
            xamba::graph::DType::F16 => {
                assert_eq!(w.as_f16(), t.as_f16(), "{label}: output {o} f16 bits");
            }
            xamba::graph::DType::I8 => {
                let (wq, ws) = w.as_i8();
                let (tq, ts) = t.as_i8();
                assert_eq!(wq, tq, "{label}: output {o} i8 payload");
                assert_eq!(
                    ws.to_bits(),
                    ts.to_bits(),
                    "{label}: output {o} i8 scale {ws} vs {ts}"
                );
            }
        }
    }
}

/// One plan, several input sets, every input set executed twice — the
/// second run must match the first exactly (arena-reuse determinism) and
/// both must match a fresh naive walk.
fn check_graph(g: &Graph, label: &str, rng: &mut Prng) {
    let mut plan = PlannedBackend
        .plan(g)
        .unwrap_or_else(|e| panic!("{label}: plan failed: {e}"));
    for trial in 0..3 {
        let inputs = verify::random_inputs(g, rng, 0.5);
        let want = naive::run(g, &inputs)
            .unwrap_or_else(|e| panic!("{label} trial {trial}: naive: {e}"));
        let got = plan
            .execute(&inputs)
            .unwrap_or_else(|e| panic!("{label} trial {trial}: planned: {e}"));
        assert_bitwise(&format!("{label} trial {trial}"), &want, &got);
        let again = plan
            .execute(&inputs)
            .unwrap_or_else(|e| panic!("{label} trial {trial}: re-execute: {e}"));
        assert_bitwise(&format!("{label} trial {trial} (arena reuse)"), &got, &again);
    }
}

#[test]
fn planned_matches_naive_on_random_graphs() {
    let mut rng = Prng::new(0xEC5_EC);
    for case in 0..50 {
        let g = random_graph(&mut rng, case);
        check_graph(&g, &format!("case {case} base"), &mut rng);

        // the XAMBA rewrites introduce tril-mask matmuls (CumBA),
        // ones-mask MVMs (ReduBA) and PLU nodes (ActiBA) — all must
        // execute identically under the plan
        let exact = RedubaPass.apply(&CumbaPass.apply(&g));
        check_graph(&exact, &format!("case {case} cumba+reduba"), &mut rng);
        let approx = ActibaPass::default().apply(&exact);
        check_graph(&approx, &format!("case {case} actiba"), &mut rng);
    }
}

#[test]
fn planned_matches_naive_on_gather_graphs() {
    let mut rng = Prng::new(7);
    for case in 0..8 {
        let mut g = Graph::new(&format!("gather{case}"));
        let v = 4 + rng.below(12);
        let d = 2 + rng.below(6);
        let t = 3 + rng.below(9);
        let emb = g.input("emb", vec![v, d]);
        let toks = g.input_i32("tokens", vec![t]);
        let e = g.gather(emb, toks, "embed");
        let s = g.silu(e, "act");
        let r = g.reduce_sum(s, 0, "pool");
        g.output(r);
        g.output(e);
        check_graph(&g, &format!("gather case {case}"), &mut rng);
    }
}

#[test]
fn plan_state_does_not_leak_across_differing_inputs() {
    // same plan, alternating input sets — results must always equal a
    // fresh naive run (no stale arena contents bleeding through)
    let mut g = Graph::new("leak");
    let x = g.input("x", vec![4, 4]);
    let c = g.cumsum(x, 0, "c");
    let sm = g.softmax(c, 1, "sm");
    let mm = g.matmul(sm, x, "mm");
    g.output(mm);
    let mut plan = PlannedBackend.plan(&g).unwrap();
    let mut rng = Prng::new(11);
    let sets: Vec<Vec<Tensor>> =
        (0..4).map(|_| verify::random_inputs(&g, &mut rng, 1.0)).collect();
    for round in 0..3 {
        for (si, inputs) in sets.iter().enumerate() {
            let want = naive::run(&g, inputs).unwrap();
            let got = plan.execute(inputs).unwrap();
            assert_bitwise(&format!("round {round} set {si}"), &want, &got);
        }
    }
}

#[test]
fn full_model_prefill_graph_matches_naive() {
    // the big one: a tiny-mamba full prefill graph (gather, conv, scan
    // unroll, rmsnorm, tied lm head) with random weights
    use xamba::config::presets;
    let shape = presets::tiny_mamba();
    let g = xamba::models::build_prefill(&shape, 6);
    let mut rng = Prng::new(3);
    check_graph(&g, "tiny-mamba prefill", &mut rng);
}

#[test]
fn full_model_mamba2_prefill_graph_matches_naive() {
    // the mamba-2 counterpart: chunked SSD (segsum CumSum_b, broadcast-Mul
    // + ReduceSum einsum decomposition), grouped conv, gated RMSNorm
    use xamba::config::presets;
    let shape = presets::tiny_mamba2();
    let g = xamba::models::build_prefill(&shape, 6);
    let mut rng = Prng::new(4);
    check_graph(&g, "tiny-mamba2 prefill", &mut rng);
}

/// Small shapes for the serving-graph corpus (debug-mode friendly).
fn nano_shape(arch: &str) -> xamba::config::ModelShape {
    xamba::config::ModelShape {
        name: format!("nano-{arch}"),
        arch: arch.into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 2,
        d_state: 8,
        d_conv: 3,
        expand: 2,
        dt_rank: 4,
        headdim: 16,
        chunk: 8,
    }
}

#[test]
fn batched_prefill_is_bitwise_identical_per_sequence_for_both_families() {
    // the admission scheduler's core invariant: a bucket-b batched
    // prefill reproduces b single-sequence serve prefills bitwise —
    // logits AND every per-layer state row — for BOTH model families,
    // on the base graphs and their CumBA/ReduBA/ActiBA rewrites. The
    // batched graph itself is also held to planned-vs-naive parity.
    use xamba::models::params::full_spec;
    use xamba::quality::param_inputs;

    let mut rng = Prng::new(0xBA7C);
    let (b, t) = (3usize, 10usize); // t=10, chunk 8: mamba-2 remainder chunk
    for shape in [nano_shape("mamba"), nano_shape("mamba2")] {
        let label = shape.name.clone();
        let single = xamba::models::build_prefill_serve(&shape, t);
        let batched = xamba::models::build_prefill_batched(&shape, b, t);
        check_graph(&batched, &format!("{label} batched-prefill"), &mut rng);

        let spec = full_spec(&shape);
        let weights = rng.range_vec(spec.total(), -0.1, 0.1);
        let params = param_inputs(&spec, &weights);
        let tokens: Vec<Vec<i32>> = (0..b)
            .map(|s| {
                (0..t)
                    .map(|i| ((s * 23 + i * 11) % shape.vocab_size) as i32)
                    .collect()
            })
            .collect();

        let variants: [(&str, Box<dyn Fn(&Graph) -> Graph>); 3] = [
            ("base", Box::new(|g: &Graph| g.clone())),
            (
                "cumba+reduba",
                Box::new(|g: &Graph| RedubaPass.apply(&CumbaPass.apply(g))),
            ),
            (
                "actiba",
                Box::new(|g: &Graph| ActibaPass::default().apply(g)),
            ),
        ];
        for (vname, rewrite) in &variants {
            let s_g = rewrite(&single);
            let b_g = rewrite(&batched);
            let mut singles = Vec::with_capacity(b);
            for toks in &tokens {
                let mut inputs = params.clone();
                inputs.push(Tensor::i32(vec![t], toks.clone()));
                singles.push(
                    xamba::exec::run_once(&s_g, &inputs)
                        .unwrap_or_else(|e| panic!("{label} {vname} single: {e}")),
                );
            }
            let mut inputs = params.clone();
            let flat: Vec<i32> = tokens.iter().flatten().copied().collect();
            inputs.push(Tensor::i32(vec![b, t], flat));
            let stacked = xamba::exec::run_once(&b_g, &inputs)
                .unwrap_or_else(|e| panic!("{label} {vname} batched: {e}"));

            let v = shape.vocab_size;
            for s in 0..b {
                assert_eq!(
                    &stacked[0].as_f32()[s * v..(s + 1) * v],
                    singles[s][0].as_f32(),
                    "{label} {vname}: logits diverge for sequence {s}"
                );
                for j in 0..shape.n_layers {
                    for (o, what) in [(1 + 2 * j, "conv"), (2 + 2 * j, "ssm")] {
                        let row: usize = stacked[o].shape[1..].iter().product();
                        assert_eq!(
                            &stacked[o].as_f32()[s * row..(s + 1) * row],
                            singles[s][o].as_f32(),
                            "{label} {vname}: {what} state diverges (seq {s}, layer {j})"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn reshape_fusion_cases_match_naive() {
    // chains interrupted (or started, or ended) by reshapes: fusion sees
    // through them; results stay bitwise-equal to the walker, which
    // materializes every reshape as a copy
    let mut rng = Prng::new(0xF0_5E);

    // silu -> reshape -> exp -> reshape -> *0.5 (reshape sandwich)
    let mut g1 = Graph::new("sandwich");
    let x = g1.input("x", vec![3, 4]);
    let a = g1.silu(x, "a");
    let r1 = g1.reshape(a, vec![12], "r1");
    let b = g1.exp(r1, "b");
    let r2 = g1.reshape(b, vec![2, 6], "r2");
    let c = g1.const_scalar("half", 0.5);
    let m = g1.mul(r2, c, "m");
    g1.output(m);
    check_graph(&g1, "reshape sandwich", &mut rng);

    // binary head feeding a reshape-then-unary tail
    let mut g2 = Graph::new("head");
    let p = g2.input("p", vec![2, 3]);
    let q = g2.input("q", vec![2, 3]);
    let s = g2.add(p, q, "s");
    let r = g2.reshape(s, vec![6], "r");
    let t = g2.softplus(r, "t");
    g2.output(t);
    check_graph(&g2, "binary head through reshape", &mut rng);

    // reshape whose producer is multi-consumer must NOT fuse away
    let mut g3 = Graph::new("pinned");
    let u = g3.input("u", vec![4]);
    let a3 = g3.silu(u, "a");
    let r3 = g3.reshape(a3, vec![2, 2], "r");
    let b3 = g3.exp(r3, "b");
    g3.output(a3); // `a` externally visible: chain may not absorb it
    g3.output(b3);
    check_graph(&g3, "output-pinned reshape", &mut rng);

    // back-to-back reshapes collapse to one fused copy
    let mut g4 = Graph::new("reshapes");
    let v = g4.input("v", vec![2, 6]);
    let ra = g4.reshape(v, vec![12], "ra");
    let rb = g4.reshape(ra, vec![3, 4], "rb");
    let rc = g4.reshape(rb, vec![4, 3], "rc");
    g4.output(rc);
    check_graph(&g4, "reshape-only chain", &mut rng);
}

#[test]
fn quantized_serve_graphs_match_naive_bitwise_and_f32_within_budget() {
    // the quantized differential corpus: serve-prefill + batched-decode
    // graphs of BOTH families through passes::quantize at f16 and i8
    // (base and ActiBA-rewritten), held to (a) planned-vs-naive bitwise
    // equality — the same contract as the f32 corpus — and (b) a loose
    // numeric envelope around the exact f32 results
    use xamba::graph::DType;
    use xamba::models::params::full_spec;
    use xamba::passes::quantize::{plan_weight_dtypes, quantize_graph};

    let mut rng = Prng::new(0xD7_17);
    for shape in [nano_shape("mamba"), nano_shape("mamba2")] {
        let spec = full_spec(&shape);
        let n_weights = spec.entries.len();
        let graphs: Vec<(&str, Graph)> = vec![
            ("serve-prefill", xamba::models::build_prefill_serve(&shape, 10)),
            ("decode b2", xamba::models::build_decode_batched(&shape, 2)),
        ];
        for (gname, base) in &graphs {
            let variants: Vec<(&str, Graph)> = vec![
                ("base", base.clone()),
                ("actiba", ActibaPass::default().apply(base)),
            ];
            for (vname, g) in &variants {
                let inputs_f32 = verify::random_inputs(g, &mut rng, 0.3);
                let exact = xamba::exec::run_once(g, &inputs_f32)
                    .unwrap_or_else(|e| panic!("{} {gname} {vname} f32: {e}", shape.name));
                // loose envelopes: bitwise correctness is carried by the
                // planned-vs-naive assertion; these only rule out
                // catastrophic numeric breakage (wrong kernel, wrong
                // scale) without flaking on legitimate rounding
                for (dtype, tol) in [(DType::F16, 0.1f32), (DType::I8, 0.6f32)] {
                    let label = format!(
                        "{} {gname} {vname} {}",
                        shape.name,
                        dtype.name()
                    );
                    let wd = plan_weight_dtypes(g, n_weights, dtype);
                    let qg = quantize_graph(g, dtype, &wd)
                        .unwrap_or_else(|e| panic!("{label}: quantize: {e}"));
                    if dtype == DType::I8 {
                        assert!(
                            qg.nodes.iter().any(|n| matches!(
                                n.op,
                                xamba::graph::Op::Quantize { .. }
                            )),
                            "{label}: i8 policy quantized no matmul"
                        );
                    }
                    let inputs_q: Vec<Tensor> = inputs_f32
                        .iter()
                        .enumerate()
                        .map(|(i, t)| {
                            if i < n_weights {
                                t.to_dtype(wd[i])
                            } else {
                                t.clone()
                            }
                        })
                        .collect();
                    // bitwise: planned vs naive, plus arena-reuse re-run
                    let want = naive::run(&qg, &inputs_q)
                        .unwrap_or_else(|e| panic!("{label}: naive: {e}"));
                    let mut plan = PlannedBackend
                        .plan(&qg)
                        .unwrap_or_else(|e| panic!("{label}: plan: {e}"));
                    let got = plan
                        .execute(&inputs_q)
                        .unwrap_or_else(|e| panic!("{label}: planned: {e}"));
                    assert_bitwise(&label, &want, &got);
                    let again = plan.execute(&inputs_q).unwrap();
                    assert_bitwise(&format!("{label} (arena reuse)"), &got, &again);
                    // envelope: quantized outputs track the f32 outputs
                    for (o, (qo, eo)) in got.iter().zip(&exact).enumerate() {
                        assert_eq!(qo.shape, eo.shape, "{label}: output {o} shape");
                        assert_eq!(
                            qo.dtype(),
                            DType::F32,
                            "{label}: quantized graphs emit f32 outputs"
                        );
                        for (a, b) in qo.as_f32().iter().zip(eo.as_f32()) {
                            assert!(
                                (a - b).abs() <= tol * (1.0 + b.abs()),
                                "{label}: output {o}: quantized {a} vs exact {b}"
                            );
                        }
                    }
                }
            }
        }
    }
}

// --- ULP-budget tier ------------------------------------------------------------
//
// The bitwise tier above is the primary contract. This tier is the
// fallback contract for the blocked GEMM specifically: if the blocking
// ever reassociates its k-loop (packed panels with split-k, SIMD
// horizontal sums), the bitwise GEMM assertions move here and the budget
// below becomes the committed bound. Today the blocked GEMM reproduces
// the scalar reference bitwise, so these pass with distance 0 — the test
// exists so the budget is already pinned and checkable.

/// Committed ULP budget for blocked-GEMM results vs the scalar
/// reference (`kernels::matmul_ref`).
const GEMM_ULP_BUDGET: i64 = 8;

/// Monotone integer order on f32 bit patterns (negative floats map below
/// positive ones), so ULP distance is a plain subtraction.
fn ulp_order(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        -((b & 0x7fff_ffff) as i64)
    } else {
        b as i64
    }
}

fn assert_within_ulp(label: &str, got: &[f32], want: &[f32], budget: i64) {
    assert_eq!(got.len(), want.len(), "{label}: length");
    for (i, (&a, &b)) in got.iter().zip(want).enumerate() {
        if a.to_bits() == b.to_bits() {
            continue; // covers equal NaN payloads and signed zeros
        }
        let d = (ulp_order(a) - ulp_order(b)).abs();
        assert!(
            d <= budget,
            "{label}[{i}]: planned {a} vs reference {b} is {d} ULP (budget {budget})"
        );
    }
}

#[test]
fn blocked_gemm_stays_within_the_committed_ulp_budget() {
    use xamba::exec::kernels;
    use xamba::graph::UnKind;

    let mut rng = Prng::new(0x01B_0C);
    // (batch, m, k, n): register-tile remainders (non-multiples of the
    // 4x16 tile), a decode-shaped row, and a broadcast-batched case
    for (batch, m, k, n) in
        [(1usize, 5usize, 7usize, 9usize), (1, 33, 17, 65), (1, 1, 64, 48), (3, 6, 8, 10)]
    {
        let label = format!("gemm {batch}x{m}x{k}x{n}");
        let mut g = Graph::new(&label);
        let xshape =
            if batch == 1 { vec![m, k] } else { vec![batch, m, k] };
        let x = g.input("x", xshape);
        let w = g.input("w", vec![k, n]);
        let mm = g.matmul(x, w, "mm"); // output-pinned: plain GEMM step
        // second identical GEMM consumed only by the activation, so the
        // epilogue fuses into the GEMM step and is covered here too
        let mm2 = g.matmul(x, w, "mm2");
        let act = g.silu(mm2, "act");
        g.output(mm);
        g.output(act);

        let inputs = verify::random_inputs(&g, &mut rng, 1.0);
        let got = xamba::exec::run_once(&g, &inputs)
            .unwrap_or_else(|e| panic!("{label}: planned: {e}"));

        let a = inputs[0].as_f32();
        let b = inputs[1].as_f32();
        let mut want_mm = vec![0.0f32; batch * m * n];
        kernels::matmul_ref(a, b, &mut want_mm, batch, m, k, n, m * k, 0);
        let want_act: Vec<f32> =
            want_mm.iter().map(|&v| kernels::apply_unary(UnKind::SiLU, v)).collect();

        assert_within_ulp(&format!("{label} mm"), got[0].as_f32(), &want_mm, GEMM_ULP_BUDGET);
        assert_within_ulp(
            &format!("{label} act"),
            got[1].as_f32(),
            &want_act,
            GEMM_ULP_BUDGET,
        );
    }
}

#[test]
fn intra_op_worker_count_is_bitwise_deterministic_across_dtypes() {
    // chunk boundaries depend only on shape and grain, never the worker
    // count — so 1, 2, and 4 intra-op workers must produce identical bits
    // for f32, f16, and i8 graphs, including across arena-reuse re-runs.
    // The matmul exceeds the FLOP threshold (row-panel split) and the
    // elementwise nodes sit at the element threshold (slab split).
    use xamba::exec::ExecutionPlan;
    use xamba::graph::DType;
    use xamba::passes::quantize::{plan_weight_dtypes, quantize_graph};

    let mut g = Graph::new("workers");
    let w = g.input("w", vec![128, 128]); // weight prefix (quantizable)
    let x = g.input("x", vec![256, 128]);
    let mm = g.matmul(x, w, "mm");
    let s = g.silu(mm, "s");
    let sm = g.softmax(s, 1, "sm");
    let cs = g.cumsum(sm, 0, "cs");
    let r = g.reduce_sum(cs, 1, "r");
    g.output(sm);
    g.output(r);

    let mut rng = Prng::new(0x3EAD);
    let f32_inputs = verify::random_inputs(&g, &mut rng, 1.0);
    let mut corpus: Vec<(String, Graph, Vec<Tensor>)> =
        vec![("f32".into(), g.clone(), f32_inputs.clone())];
    for dtype in [DType::F16, DType::I8] {
        let wd = plan_weight_dtypes(&g, 1, dtype);
        let qg = quantize_graph(&g, dtype, &wd)
            .unwrap_or_else(|e| panic!("{}: quantize: {e}", dtype.name()));
        let inputs: Vec<Tensor> = f32_inputs
            .iter()
            .enumerate()
            .map(|(i, t)| if i < 1 { t.to_dtype(wd[i]) } else { t.clone() })
            .collect();
        corpus.push((dtype.name().to_string(), qg, inputs));
    }

    for (label, graph, inputs) in &corpus {
        let mut base_plan = ExecutionPlan::compile(graph)
            .unwrap_or_else(|e| panic!("{label}: compile: {e}"))
            .with_intra_workers(1);
        let baseline = base_plan
            .run(inputs)
            .unwrap_or_else(|e| panic!("{label}: workers=1: {e}"));
        for workers in [2usize, 4] {
            let mut plan = ExecutionPlan::compile(graph)
                .unwrap_or_else(|e| panic!("{label}: compile: {e}"))
                .with_intra_workers(workers);
            for trial in 0..2 {
                let got = plan.run(inputs).unwrap_or_else(|e| {
                    panic!("{label}: workers={workers} trial {trial}: {e}")
                });
                assert_bitwise(
                    &format!("{label} workers={workers} trial {trial}"),
                    &baseline,
                    &got,
                );
            }
        }
        // arena reuse at workers=1 closes the loop
        let again = base_plan.run(inputs).unwrap();
        assert_bitwise(&format!("{label} workers=1 (arena reuse)"), &baseline, &again);
    }
}

#[test]
fn resumed_serve_prefill_matches_monolithic_for_both_families_and_dtypes() {
    // the prefix cache's numeric contract: prefill(prefix) through the
    // serve graph, then the RESUME graph over the suffix seeded with the
    // captured per-layer states, must reproduce one monolithic serve
    // prefill of the whole sequence bitwise — logits and every state —
    // at f32 AND f16 (weights quantized, state inputs stay f32). The
    // mamba-2 split sits on an SSD chunk boundary (its resume grain);
    // mamba-1 splits anywhere. The resume graph is also held to
    // planned-vs-naive parity like every other serving graph.
    use xamba::graph::DType;
    use xamba::models::params::full_spec;
    use xamba::passes::quantize::{plan_weight_dtypes, quantize_graph};

    let mut rng = Prng::new(0x2E5_37E);
    for (shape, k, t) in
        [(nano_shape("mamba"), 5usize, 12usize), (nano_shape("mamba2"), 8, 16)]
    {
        let label = shape.name.clone();
        let full_g = xamba::models::build_prefill_serve(&shape, t);
        let part_g = xamba::models::build_prefill_serve(&shape, k);
        let res_g = xamba::models::build_prefill_resume(&shape, t - k);
        check_graph(&res_g, &format!("{label} resume-prefill"), &mut rng);

        let spec = full_spec(&shape);
        let n_weights = spec.entries.len();
        let weights = rng.range_vec(spec.total(), -0.1, 0.1);
        let params = xamba::quality::param_inputs(&spec, &weights);
        let tokens: Vec<i32> =
            (0..t).map(|i| ((i * 11 + 3) % shape.vocab_size) as i32).collect();

        for dtype in [DType::F32, DType::F16] {
            let dlabel = format!("{label} {}", dtype.name());
            // quantize each graph with its own structural weight plan;
            // state inputs sit past the weight prefix and stay f32
            let prep = |g: &Graph| -> (Graph, Vec<Tensor>) {
                if dtype == DType::F32 {
                    return (g.clone(), params.clone());
                }
                let wd = plan_weight_dtypes(g, n_weights, dtype);
                let qg = quantize_graph(g, dtype, &wd)
                    .unwrap_or_else(|e| panic!("{dlabel}: quantize: {e}"));
                let qparams = params
                    .iter()
                    .zip(&wd)
                    .map(|(p, &d)| if p.dtype() == d { p.clone() } else { p.to_dtype(d) })
                    .collect();
                (qg, qparams)
            };
            let (full_q, full_params) = prep(&full_g);
            let (part_q, part_params) = prep(&part_g);
            let (res_q, res_params) = prep(&res_g);

            let mut inputs = full_params;
            inputs.push(Tensor::i32(vec![t], tokens.clone()));
            let want = xamba::exec::run_once(&full_q, &inputs)
                .unwrap_or_else(|e| panic!("{dlabel} monolithic: {e}"));

            let mut inputs = part_params;
            inputs.push(Tensor::i32(vec![k], tokens[..k].to_vec()));
            let part = xamba::exec::run_once(&part_q, &inputs)
                .unwrap_or_else(|e| panic!("{dlabel} prefix: {e}"));

            let mut inputs = res_params;
            inputs.push(Tensor::i32(vec![t - k], tokens[k..].to_vec()));
            for j in 0..shape.n_layers {
                inputs.push(part[1 + 2 * j].clone());
                inputs.push(part[2 + 2 * j].clone());
            }
            let got = xamba::exec::run_once(&res_q, &inputs)
                .unwrap_or_else(|e| panic!("{dlabel} resume: {e}"));
            assert_bitwise(&format!("{dlabel} resume-vs-monolithic"), &want, &got);
        }
    }
}

#[test]
fn serve_and_decode_graphs_match_naive_for_both_families() {
    // the planned serving path's graphs — serve prefill (last-position
    // logits + per-layer state outputs) and per-bucket batched decode —
    // differentially covered for BOTH model families, plus their
    // pass-rewritten variants (CumBA tril matmuls, ReduBA ones-mask MVMs,
    // ActiBA PLUs all execute on the serving hot path)
    let mut rng = Prng::new(0x5E_B5);
    for shape in [nano_shape("mamba"), nano_shape("mamba2")] {
        let label = shape.name.clone();
        // t = 10 with chunk 8: mamba-2 runs a carried remainder chunk
        let serve = xamba::models::build_prefill_serve(&shape, 10);
        check_graph(&serve, &format!("{label} serve-prefill"), &mut rng);
        let exact = RedubaPass.apply(&CumbaPass.apply(&serve));
        check_graph(&exact, &format!("{label} serve-prefill cumba+reduba"), &mut rng);
        let approx = ActibaPass::default().apply(&exact);
        check_graph(&approx, &format!("{label} serve-prefill actiba"), &mut rng);
        for b in [1usize, 2] {
            let dec = xamba::models::build_decode_batched(&shape, b);
            check_graph(&dec, &format!("{label} decode b{b}"), &mut rng);
            let exact = RedubaPass.apply(&CumbaPass.apply(&dec));
            check_graph(&exact, &format!("{label} decode b{b} cumba+reduba"), &mut rng);
            let approx = ActibaPass::default().apply(&exact);
            check_graph(&approx, &format!("{label} decode b{b} actiba"), &mut rng);
        }
    }
}
