//! Integration tests of the batched-admission serving path: batched
//! prefill buckets (per-sequence bitwise identical to single-sequence
//! prefill), variable-length length-classes (no pad token ever touches
//! SSM state), and the pool's work-stealing decode split (bitwise
//! identical to serial at any worker count and chunk size).

use std::time::Duration;

use xamba::config::{ModelShape, ServeConfig};
use xamba::coordinator::{
    FinishReason, GenParams, PlannedServeModel, SeqState, ServeModel, Server,
};

/// Deliberately small shapes so debug-mode tests stay fast; vocab stays
/// 256 (byte tokenizer).
fn nano(arch: &str) -> ModelShape {
    ModelShape {
        name: format!("nano-{arch}"),
        arch: arch.into(),
        vocab_size: 256,
        d_model: 32,
        n_layers: 2,
        d_state: 8,
        d_conv: 3,
        expand: 2,
        dt_rank: 4,
        headdim: 16,
        chunk: 8,
    }
}

fn prompt(i: usize, len: usize) -> Vec<i32> {
    (0..len).map(|t| ((i * 31 + t * 7) % 256) as i32).collect()
}

#[test]
fn batched_prefill_is_bitwise_identical_per_sequence() {
    // both families, both variants, at the full window AND a shorter
    // length-class (t = 6 < window = 8, exercising the lazily compiled
    // graphs); every logits row and state must be bitwise equal to a
    // lone prefill of the same tokens
    for shape in [nano("mamba"), nano("mamba2")] {
        for variant in ["baseline", "xamba"] {
            let window = 8;
            let weights = PlannedServeModel::random_weights(&shape, 7);
            let mut model =
                PlannedServeModel::new(&shape, &weights, window, &[1], 1, variant)
                    .unwrap()
                    .with_prefill_buckets(&[1, 2, 4])
                    .unwrap();
            for t in [window, 6usize] {
                let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(i, t)).collect();
                let refs: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
                let singles: Vec<(Vec<f32>, SeqState)> =
                    refs.iter().map(|s| model.prefill(s).unwrap()).collect();
                let batched = model.prefill_batched(&refs).unwrap();
                assert_eq!(batched.len(), 4);
                for (i, (single, got)) in singles.iter().zip(&batched).enumerate() {
                    assert_eq!(
                        single.0, got.0,
                        "{} {variant} t={t}: logits diverge for sequence {i}",
                        shape.arch
                    );
                    assert_eq!(
                        single.1, got.1,
                        "{} {variant} t={t}: state diverges for sequence {i}",
                        shape.arch
                    );
                }
            }
        }
    }
}

#[test]
fn prefill_length_classes_compile_once_and_reject_ragged_batches() {
    let shape = nano("mamba");
    let window = 8;
    let weights = PlannedServeModel::random_weights(&shape, 11);
    let mut model = PlannedServeModel::new(&shape, &weights, window, &[1], 1, "baseline")
        .unwrap()
        .with_prefill_buckets(&[1, 2])
        .unwrap();
    let base_compiles = model.plan_compiles();

    // a ragged batch is the scheduler's bug, not a silent pad
    let a = prompt(0, 8);
    let b = prompt(1, 6);
    let err = model
        .prefill_batched(&[a.as_slice(), b.as_slice()])
        .unwrap_err()
        .to_string();
    assert!(err.contains("equal-length"), "{err}");

    // out-of-range lengths are clear errors (min = d_conv - 1 = 2)
    assert!(model.prefill(&prompt(0, 1)).is_err());
    assert!(model.prefill(&prompt(0, 9)).is_err());

    // each (bucket, length-class) pair compiles exactly once
    let c = prompt(2, 6);
    for _ in 0..3 {
        model
            .prefill_batched(&[b.as_slice(), c.as_slice()])
            .unwrap();
    }
    let after_bucket2_t6 = model.plan_compiles();
    assert_eq!(after_bucket2_t6, base_compiles + 1, "bucket-2/t-6 compiles once");
    for _ in 0..2 {
        model.prefill(&b).unwrap();
    }
    assert_eq!(
        model.plan_compiles(),
        after_bucket2_t6 + 1,
        "single/t-6 length-class compiles once"
    );

    // non-bucket batch sizes fall back to the serial loop, no new plans
    let d = prompt(3, 6);
    model
        .prefill_batched(&[b.as_slice(), c.as_slice(), d.as_slice()])
        .unwrap();
    assert_eq!(model.plan_compiles(), after_bucket2_t6 + 1);
}

#[test]
fn work_stealing_pooled_decode_is_bitwise_identical_at_any_worker_count() {
    // buckets [1, 2, 3, 4] make the auto and explicit chunkings uneven
    // (e.g. bucket 4 with steal_chunk 3 -> [3, 1]); every combination
    // must reproduce the serial reference bitwise, states included
    let shape = nano("mamba2");
    let window = 8;
    let weights = PlannedServeModel::random_weights(&shape, 9);
    let buckets = [1usize, 2, 3, 4];

    let decode_rounds = |model: &mut PlannedServeModel| {
        let mut states: Vec<SeqState> = Vec::new();
        let mut toks: Vec<i32> = Vec::new();
        for i in 0..4 {
            let (logits, st) = model.prefill(&prompt(i, window)).unwrap();
            let top = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap();
            toks.push(top);
            states.push(st);
        }
        let mut all_logits: Vec<Vec<Vec<f32>>> = Vec::new();
        for _ in 0..3 {
            let mut seqs: Vec<(&mut SeqState, i32)> =
                states.iter_mut().zip(toks.iter().copied()).collect();
            let step = model.decode(&mut seqs).unwrap();
            drop(seqs);
            toks = step
                .iter()
                .map(|l| {
                    l.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i as i32)
                        .unwrap()
                })
                .collect();
            all_logits.push(step);
        }
        (all_logits, states)
    };

    let mut serial =
        PlannedServeModel::new(&shape, &weights, window, &buckets, 1, "baseline").unwrap();
    let reference = decode_rounds(&mut serial);

    for workers in [2usize, 4] {
        for steal in [0usize, 1, 2, 3] {
            let mut model = PlannedServeModel::new(
                &shape, &weights, window, &buckets, workers, "baseline",
            )
            .unwrap()
            .with_steal_chunk(steal)
            .unwrap();
            let got = decode_rounds(&mut model);
            assert_eq!(
                got, reference,
                "{workers} workers / steal_chunk {steal} diverged from serial"
            );
        }
    }
}

#[test]
fn batched_admissions_serve_end_to_end_with_mixed_prompt_lengths() {
    // the full loop: concurrent requests in DIFFERENT length-classes
    // (prompts shorter than, equal to, and longer than the window) are
    // admitted in batches, decode interleaves, and everyone completes
    let shape = nano("mamba");
    let window = 8;
    let weights = PlannedServeModel::random_weights(&shape, 21);
    let cfg = ServeConfig {
        max_slots: 8,
        queue_cap: 32,
        batch_wait_us: 100,
        prefill_window: window,
        ..Default::default()
    };
    let server = Server::start(
        move || {
            Ok(Box::new(
                PlannedServeModel::new(&shape, &weights, window, &[1, 2, 4], 2, "xamba")?
                    .with_prefill_buckets(&[1, 2, 4])?,
            ) as Box<dyn ServeModel>)
        },
        cfg,
    )
    .unwrap();

    let prompts: [&[u8]; 6] = [
        b"hi",                        // shorter than the window
        b"hello",                     //   (another class)
        b"exactly8",                  // the full window
        b"exactly8",                  //   (same class, batches together)
        b"longer than the window",    // truncated to the trailing window
        b"also longer than window!!", //   (same class)
    ];
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| server.submit(p, GenParams { max_new_tokens: 4, ..Default::default() }))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.finish, FinishReason::Length, "request {i}");
        assert_eq!(r.generated.len(), 4, "request {i}");
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 6);
    assert_eq!(m.prefills, 6);
    assert!(
        m.prefill_calls >= 3,
        "three length-classes cannot share a prefill round: {} rounds",
        m.prefill_calls
    );
    assert!(m.prefill_batch_us.count() >= 1);
}

#[test]
fn server_output_is_deterministic_across_workers_and_prefill_buckets() {
    // greedy output must not depend on worker count, steal chunk, or
    // whether admissions were batched — the bitwise invariants end-to-end
    let shape = nano("mamba2");
    let window = 8;
    let weights = PlannedServeModel::random_weights(&shape, 33);
    let mut outputs: Vec<Vec<Vec<u8>>> = Vec::new();
    for (workers, steal, prefill_buckets) in
        [(1usize, 0usize, vec![1usize]), (4, 1, vec![1, 2, 4])]
    {
        let (shape, weights) = (shape.clone(), weights.clone());
        let cfg = ServeConfig {
            max_slots: 4,
            queue_cap: 16,
            batch_wait_us: 100,
            prefill_window: window,
            ..Default::default()
        };
        let server = Server::start(
            move || {
                Ok(Box::new(
                    PlannedServeModel::new(
                        &shape, &weights, window, &[1, 2, 4], workers, "baseline",
                    )?
                    .with_prefill_buckets(&prefill_buckets)?
                    .with_steal_chunk(steal)?,
                ) as Box<dyn ServeModel>)
            },
            cfg,
        )
        .unwrap();
        let rxs: Vec<_> = (0..3)
            .map(|i| {
                server.submit(
                    &[b'a' + i as u8; 5],
                    GenParams { max_new_tokens: 6, ..Default::default() },
                )
            })
            .collect();
        let mut generated = Vec::new();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(r.finish, FinishReason::Length);
            generated.push(r.generated);
        }
        outputs.push(generated);
        server.shutdown();
    }
    assert_eq!(
        outputs[0], outputs[1],
        "worker count / steal chunk / prefill buckets changed greedy output"
    );
}
