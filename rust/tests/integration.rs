//! Cross-language integration tests: the rust IR executor and the PJRT
//! runtime must reproduce the numbers python recorded in golden.json for
//! the trained tiny models. Requires `make artifacts` to have run; on a
//! checkout without the trained artifacts every test here skips itself
//! (prints a note and returns) instead of failing, so `cargo test -q`
//! stays green in artifact-less CI.

use xamba::config::presets;
use xamba::graph::Tensor;
use xamba::models::{self, params};
use xamba::passes::{actiba::ActibaPass, cumba::CumbaPass, reduba::RedubaPass, Pass};
use xamba::runtime::{Engine, Golden, HostTensor, Manifest};

const DIR: &str = "artifacts";

/// True when the trained artifacts exist. Tests guard on this and skip
/// (not fail) otherwise — the artifacts are a build product of the
/// python layer, not something a fresh checkout has.
fn artifacts_available(test: &str) -> bool {
    let ok = std::path::Path::new(DIR).join("manifest.json").exists()
        && std::path::Path::new(DIR).join("golden.json").exists();
    if !ok {
        eprintln!("skipping {test}: {DIR}/ not built (run `make artifacts`)");
    }
    ok
}

/// PJRT-dependent tests additionally need a working XLA runtime: the
/// offline checkout vendors an API stub whose PJRT client reports
/// unavailable (see ARCHITECTURE.md §Offline dependency shims), so those
/// tests skip even when artifacts exist.
fn pjrt_available(test: &str) -> bool {
    if !artifacts_available(test) {
        return false;
    }
    match Engine::cpu() {
        Ok(_) => true,
        Err(e) => {
            eprintln!("skipping {test}: PJRT runtime unavailable ({e})");
            false
        }
    }
}

fn golden() -> Golden {
    Golden::load(DIR).expect("golden.json missing — run `make artifacts`")
}

fn manifest() -> Manifest {
    Manifest::load(DIR).expect("manifest.json missing — run `make artifacts`")
}

/// Assemble interpreter inputs for a full-LM prefill graph: parameters
/// sliced from the weights bin (spec order), then the token ids.
fn interp_inputs(shape: &xamba::config::ModelShape, tokens: &[i32]) -> Vec<Tensor> {
    let spec = params::full_spec(shape);
    let buf = params::load_f32_bin(&format!("{DIR}/weights_{}.bin", shape.name))
        .expect("weights bin");
    assert_eq!(buf.len(), spec.total());
    let mut inputs: Vec<Tensor> = spec
        .entries
        .iter()
        .map(|e| params::extract_or_panic(&spec, &buf, &e.name))
        .collect();
    inputs.push(Tensor::i32(vec![tokens.len()], tokens.to_vec()));
    inputs
}

/// The rust interpreter running the IR graph must match python's jax
/// output for the same trained weights (last-position logits).
fn check_interp_matches_golden(model: &str) {
    let shape = presets::model_by_name(model).unwrap();
    let g = golden();
    let key = format!("{model}.baseline.prefill");
    let outs = g.outputs(&key).expect("golden entry");
    let tokens = g.tokens(&key).expect("golden tokens");
    let graph = models::build_prefill(&shape, tokens.len());
    let results = xamba::interp::run(&graph, &interp_inputs(&shape, &tokens)).unwrap();
    // graph emits (T, V); golden recorded the last position (V,)
    let logits = results[0].as_f32();
    let v = shape.vocab_size;
    let last = &logits[(tokens.len() - 1) * v..];
    let want = &outs[0];
    for (i, (&got, &exp)) in last.iter().zip(&want.head).enumerate() {
        assert!(
            (got - exp).abs() < 2e-2 + 2e-3 * exp.abs(),
            "{model} logit[{i}]: rust {got} vs python {exp}"
        );
    }
    let sum: f64 = last.iter().map(|&x| x as f64).sum();
    assert!(
        (sum - want.sum).abs() < 0.05 * want.sum.abs().max(10.0),
        "{model} logit sum: rust {sum} vs python {}",
        want.sum
    );
}

#[test]
fn interp_matches_python_tiny_mamba() {
    if !artifacts_available("interp_matches_python_tiny_mamba") {
        return;
    }
    check_interp_matches_golden("tiny-mamba");
}

#[test]
fn interp_matches_python_tiny_mamba2() {
    if !artifacts_available("interp_matches_python_tiny_mamba2") {
        return;
    }
    check_interp_matches_golden("tiny-mamba2");
}

/// The XAMBA passes must preserve full-model semantics on the trained
/// weights (CumBA/ReduBA exactly; ActiBA within PLU tolerance).
#[test]
fn passes_preserve_full_model_logits() {
    if !artifacts_available("passes_preserve_full_model_logits") {
        return;
    }
    let shape = presets::tiny_mamba2();
    let g = golden();
    let key = "tiny-mamba2.baseline.prefill";
    let tokens = g.tokens(key).expect("tokens");
    let graph = models::build_prefill(&shape, tokens.len());
    let inputs = interp_inputs(&shape, &tokens);
    let base = xamba::interp::run(&graph, &inputs).unwrap();

    let exact = RedubaPass.apply(&CumbaPass.apply(&graph));
    let r = xamba::interp::run(&exact, &inputs).unwrap();
    for (a, b) in base[0].as_f32().iter().zip(r[0].as_f32()) {
        assert!((a - b).abs() < 1e-3, "cumba+reduba drift: {a} vs {b}");
    }

    let approx = ActibaPass::default().apply(&exact);
    let r2 = xamba::interp::run(&approx, &inputs).unwrap();
    let max: f32 = base[0]
        .as_f32()
        .iter()
        .zip(r2[0].as_f32())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(max < 1.0, "actiba logit drift too large: {max}");
    assert!(max > 0.0, "actiba suspiciously exact");
}

/// PJRT execution of the AOT artifacts must match python's outputs —
/// the full L2 -> HLO text -> rust runtime path.
fn check_pjrt_matches_golden(model: &str, variant: &str) {
    let m = manifest();
    let g = golden();
    let entry = m.find(model, variant, "prefill").expect("manifest entry");
    let key = format!("{model}.{variant}.prefill");
    let want = &g.outputs(&key).expect("golden")[0];
    let tokens = g.tokens(&key).expect("tokens");
    let shape = &entry.shape;

    let mut engine = Engine::cpu().expect("pjrt cpu client");
    let conv = HostTensor::zeros(&entry.inputs[2].shape);
    let ssm = HostTensor::zeros(&entry.inputs[3].shape);
    let tok = HostTensor::I32(vec![tokens.len()], tokens.clone());
    let outs = engine
        .run_with_weights(&m, entry, &[tok, conv, ssm])
        .expect("execute");
    assert_eq!(outs[0].shape(), &[shape.vocab_size]);
    for (i, (&got, &exp)) in outs[0].f32_data().iter().zip(&want.head).enumerate() {
        assert!(
            (got - exp).abs() < 1e-3 + 1e-4 * exp.abs(),
            "{key} logit[{i}]: pjrt {got} vs python {exp}"
        );
    }
    let sum: f64 = outs[0].f32_data().iter().map(|&x| x as f64).sum();
    assert!((sum - want.sum).abs() < 0.01 * want.sum.abs().max(10.0));
}

#[test]
fn pjrt_matches_python_baseline() {
    if !pjrt_available("pjrt_matches_python_baseline") {
        return;
    }
    check_pjrt_matches_golden("tiny-mamba", "baseline");
}

#[test]
fn pjrt_matches_python_xamba_variant() {
    if !pjrt_available("pjrt_matches_python_xamba_variant") {
        return;
    }
    // the Pallas-kernel variant (CumBA/ReduBA/ActiBA inside the HLO)
    check_pjrt_matches_golden("tiny-mamba", "xamba");
    check_pjrt_matches_golden("tiny-mamba2", "xamba");
}

/// Decode must continue exactly from prefill state: run prefill via PJRT,
/// feed its states into decode_b1, and check the step against golden.
#[test]
fn pjrt_prefill_then_decode_roundtrip() {
    if !pjrt_available("pjrt_prefill_then_decode_roundtrip") {
        return;
    }
    let m = manifest();
    let g = golden();
    let model = "tiny-mamba";
    let pre = m.find(model, "baseline", "prefill").unwrap();
    let dec = m.find(model, "baseline", "decode_b1").unwrap();
    let tokens = g.tokens(&format!("{model}.baseline.prefill")).unwrap();

    let mut engine = Engine::cpu().unwrap();
    let conv = HostTensor::zeros(&pre.inputs[2].shape);
    let ssm = HostTensor::zeros(&pre.inputs[3].shape);
    let tok = HostTensor::I32(vec![tokens.len()], tokens.clone());
    let outs = engine.run_with_weights(&m, pre, &[tok, conv, ssm]).unwrap();
    let (logits, conv1, ssm1) = (&outs[0], &outs[1], &outs[2]);

    // greedy next token from prefill logits
    let next = logits
        .f32_data()
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as i32;

    // decode_b1 expects batch-leading shapes (1, ...)
    let with_batch = |t: &HostTensor| -> HostTensor {
        let mut s = vec![1usize];
        s.extend_from_slice(t.shape());
        HostTensor::F32(s, t.f32_data().to_vec())
    };
    let outs2 = engine
        .run_with_weights(
            &m,
            dec,
            &[
                HostTensor::I32(vec![1, 1], vec![next]),
                with_batch(conv1),
                with_batch(ssm1),
            ],
        )
        .unwrap();
    assert_eq!(outs2[0].shape(), &[1, 256]);
    // the decoded distribution must be finite and non-degenerate
    let l = outs2[0].f32_data();
    assert!(l.iter().all(|x| x.is_finite()));
    let mx = l.iter().cloned().fold(f32::MIN, f32::max);
    let mn = l.iter().cloned().fold(f32::MAX, f32::min);
    assert!(mx - mn > 1.0, "flat logits");
}

/// Full serving stack smoke test: coordinator -> PJRT -> trained model,
/// concurrent requests with batching, streaming included.
#[test]
fn serving_stack_end_to_end() {
    if !pjrt_available("serving_stack_end_to_end") {
        return;
    }
    use xamba::config::ServeConfig;
    use xamba::coordinator::{start_pjrt, GenParams, StreamEvent};

    let cfg = ServeConfig {
        model: "tiny-mamba".into(),
        variant: "xamba".into(),
        max_slots: 8,
        ..Default::default()
    };
    let server = start_pjrt(&cfg).expect("start server");

    // concurrent final-mode requests
    let rxs: Vec<_> = (0..4)
        .map(|i| {
            server.submit(
                b"the state space ",
                GenParams {
                    max_new_tokens: 12,
                    temperature: if i % 2 == 0 { 0.0 } else { 0.7 },
                    seed: i,
                    ..Default::default()
                },
            )
        })
        .collect();
    for rx in rxs {
        let r = rx
            .recv_timeout(std::time::Duration::from_secs(60))
            .expect("response");
        assert_eq!(r.generated.len(), 12);
        assert!(r.generated.iter().all(|&b| b.is_ascii()));
    }

    // streaming request: incremental tokens then Done
    let rx = server.submit_streaming(
        b"every kernel ",
        GenParams { max_new_tokens: 6, ..Default::default() },
    );
    let mut streamed = Vec::new();
    loop {
        match rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap() {
            StreamEvent::Token(t) => streamed.push(t),
            StreamEvent::Done(r) => {
                assert_eq!(r.generated, streamed);
                break;
            }
        }
    }
    assert_eq!(streamed.len(), 6);

    let m = server.shutdown();
    assert_eq!(m.completed, 5);
    assert!(m.tokens_out >= 4 * 12 + 6);
}
