//! API-compatible stub for the `xla` (xla-rs) crate.
//!
//! The offline build image does not carry the XLA C++ dependency closure,
//! so this stub keeps the crate compiling and the *host-side* `Literal`
//! type fully functional (construction, reshape, readback — what
//! `runtime::HostTensor` round-trips through). Anything that would need a
//! real PJRT client — compiling HLO, executing on a device — returns
//! [`Error`] with an explanatory message at runtime.
//!
//! Replacing the `xla` path dependency in `rust/Cargo.toml` with a real
//! vendored xla-rs checkout restores full PJRT execution; no call site
//! changes are needed.

use std::fmt;

const UNAVAILABLE: &str = "XLA PJRT runtime is unavailable in this offline build \
     (rust/vendor/xla is an API stub; vendor the real xla-rs crate to enable it)";

/// Stub error type (string message).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Element types we can represent host-side (plus a few placeholders so
/// `match ... other => ...` arms in callers stay reachable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    F64,
    Pred,
    U8,
}

/// Internal payload representation (public only because [`NativeType`]
/// mentions it; treat as opaque).
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host-side array literal: dims + row-major payload.
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: LiteralData,
}

/// Shape (dims + element type) of an array literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Rust scalar types that map onto XLA element types.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn wrap(v: Vec<Self>) -> LiteralData;
    fn unwrap(d: &LiteralData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::F32(v)
    }
    fn unwrap(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::I32(v)
    }
    fn unwrap(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Same payload under new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = match &self.data {
            LiteralData::F32(v) => v.len() as i64,
            LiteralData::I32(v) => v.len() as i64,
        };
        if want != have {
            return Err(Error(format!("reshape {dims:?}: {want} elements vs {have}")));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            LiteralData::F32(_) => ElementType::F32,
            LiteralData::I32(_) => ElementType::S32,
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    /// Copy the payload out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| Error(format!("to_vec: literal is not {:?}", T::TY)))
    }

    /// Stub literals are never tuples (tuples only come from execution).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// Parsed HLO module (opaque; parsing needs the real XLA).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle. `cpu()` always fails in the stub.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Compiled executable handle (unreachable in the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer handle (unreachable in the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        let s = r.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nope").is_err());
    }
}
