//! Minimal offline re-implementation of the `anyhow` API surface xamba
//! uses: `Error`, `Result`, the `anyhow!` macro, and the `Context`
//! extension trait for `Result` and `Option`.
//!
//! The build environment has no crates.io access, so the real crate is
//! replaced by this shim. Errors are a message string with the source
//! chain flattened in at construction time — enough for every call site
//! in the crate (display, `{e:#}` formatting, propagation via `?`).

use std::fmt;

/// An error message with its cause chain flattened into the text.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Any std error converts via `?` (the real anyhow's blanket impl). The
// source chain is flattened into the message with ": " separators.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result` — `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error of a `Result` or the absence of an `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{ctx}: {}", e.into())))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {}", f(), e.into())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let a: Error = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let b: Error = anyhow!("x = {}", 3);
        assert_eq!(b.to_string(), "x = 3");
        let s = String::from("owned");
        let c: Error = anyhow!(s);
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: gone");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }
}
