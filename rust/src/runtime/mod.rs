//! Runtime layer: AOT manifest parsing + PJRT execution engine.
//!
//! The serving coordinator and the integration tests go through this
//! module; nothing above it touches the `xla` crate directly.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, HostTensor};
pub use manifest::{ArgDType, ArgSpec, Golden, GoldenOutput, Manifest, ProgramEntry};
