//! AOT artifact manifest + golden-vector loading.
//!
//! `python/compile/aot.py` writes `manifest.json` (every lowered program:
//! HLO file, weights file, arg shapes, model config) and `golden.json`
//! (python-side outputs for fixed inputs). This module parses both so the
//! runtime can compile/execute programs and the integration tests can
//! compare numerics across the language boundary.

use std::path::{Path, PathBuf};

use crate::config::ModelShape;
use crate::util::json::Json;

/// Dtype of a program argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgDType {
    F32,
    I32,
}

/// Shape/dtype of one program argument.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: ArgDType,
}

/// One AOT-lowered program.
#[derive(Clone, Debug)]
pub struct ProgramEntry {
    pub name: String,
    pub arch: String,
    pub variant: String,
    /// "prefill" | "decode_b{B}" | "block"
    pub kind: String,
    pub batch: usize,
    pub hlo_file: String,
    pub weights_file: String,
    pub weights_len: usize,
    pub inputs: Vec<ArgSpec>,
    pub shape: ModelShape,
}

impl ProgramEntry {
    /// Unique key for executable caching.
    pub fn key(&self) -> String {
        format!("{}.{}.{}", self.name, self.variant, self.kind)
    }
}

/// The parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub prefill_len: usize,
    pub programs: Vec<ProgramEntry>,
}

fn parse_shape(cfg: &Json) -> Result<ModelShape, String> {
    let us =
        |k: &str| -> Result<usize, String> { Ok(cfg.req(k)?.as_usize().ok_or(k)?) };
    Ok(ModelShape {
        name: cfg.req("name")?.as_str().ok_or("name")?.to_string(),
        arch: cfg.req("arch")?.as_str().ok_or("arch")?.to_string(),
        vocab_size: us("vocab_size")?,
        d_model: us("d_model")?,
        n_layers: us("n_layers")?,
        d_state: us("d_state")?,
        d_conv: us("d_conv")?,
        expand: us("expand")?,
        dt_rank: us("dt_rank")?,
        headdim: us("headdim")?,
        chunk: us("chunk")?,
    })
}

fn parse_args(j: &Json) -> Result<Vec<ArgSpec>, String> {
    j.as_arr()
        .ok_or("inputs not array")?
        .iter()
        .map(|a| {
            let shape = a
                .req("shape")?
                .as_arr()
                .ok_or("shape")?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| "dim".to_string()))
                .collect::<Result<Vec<_>, _>>()?;
            let dtype = match a.req("dtype")?.as_str() {
                Some("f32") => ArgDType::F32,
                Some("i32") => ArgDType::I32,
                other => return Err(format!("bad dtype {other:?}")),
            };
            Ok(ArgSpec {
                name: a.req("name")?.as_str().ok_or("name")?.to_string(),
                shape,
                dtype,
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Self, String> {
        let path = Path::new(dir).join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&src)?;
        let prefill_len = j.req("prefill_len")?.as_usize().ok_or("prefill_len")?;
        let mut programs = Vec::new();
        for p in j.req("models")?.as_arr().ok_or("models")? {
            programs.push(ProgramEntry {
                name: p.req("name")?.as_str().ok_or("name")?.to_string(),
                arch: p.req("arch")?.as_str().ok_or("arch")?.to_string(),
                variant: p.req("variant")?.as_str().ok_or("variant")?.to_string(),
                kind: p.req("kind")?.as_str().ok_or("kind")?.to_string(),
                batch: p.req("batch")?.as_usize().ok_or("batch")?,
                hlo_file: p.req("hlo")?.as_str().ok_or("hlo")?.to_string(),
                weights_file: p.req("weights")?.as_str().ok_or("weights")?.to_string(),
                weights_len: p.req("weights_len")?.as_usize().ok_or("weights_len")?,
                inputs: parse_args(p.req("inputs")?)?,
                shape: parse_shape(p.req("config")?)?,
            });
        }
        Ok(Self { dir: PathBuf::from(dir), prefill_len, programs })
    }

    /// Find a program by (model, variant, kind).
    pub fn find(&self, name: &str, variant: &str, kind: &str) -> Option<&ProgramEntry> {
        self.programs
            .iter()
            .find(|p| p.name == name && p.variant == variant && p.kind == kind)
    }

    /// All decode batch buckets available for (model, variant), ascending.
    pub fn decode_buckets(&self, name: &str, variant: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .programs
            .iter()
            .filter(|p| {
                p.name == name && p.variant == variant && p.kind.starts_with("decode_b")
            })
            .map(|p| p.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// Absolute path of an artifact file.
    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

/// One golden output record: shape + first values + full sum.
#[derive(Clone, Debug)]
pub struct GoldenOutput {
    pub shape: Vec<usize>,
    pub head: Vec<f32>,
    pub sum: f64,
}

/// Golden vectors for cross-language numeric checks.
#[derive(Clone, Debug)]
pub struct Golden {
    j: Json,
}

impl Golden {
    pub fn load(dir: &str) -> Result<Self, String> {
        let path = Path::new(dir).join("golden.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Ok(Self { j: Json::parse(&src)? })
    }

    /// Outputs recorded for a program key ("<name>.<variant>.<kind>").
    pub fn outputs(&self, key: &str) -> Option<Vec<GoldenOutput>> {
        let outs = self.j.get(key)?.get("outputs")?.as_arr()?;
        let mut v = Vec::new();
        for o in outs {
            v.push(GoldenOutput {
                shape: o
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect(),
                head: o
                    .get("head")?
                    .as_arr()?
                    .iter()
                    .filter_map(|x| x.as_f64())
                    .map(|x| x as f32)
                    .collect(),
                sum: o.get("sum")?.as_f64()?,
            });
        }
        Some(v)
    }

    /// The token sequence a prefill golden record used.
    pub fn tokens(&self, key: &str) -> Option<Vec<i32>> {
        Some(
            self.j
                .get(key)?
                .get("tokens")?
                .as_arr()?
                .iter()
                .filter_map(|t| t.as_f64())
                .map(|t| t as i32)
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // parsing the real artifacts is covered by rust/tests/; here we parse
    // a synthetic manifest to keep unit tests hermetic.
    fn sample_json() -> String {
        r#"{
  "version": 1, "prefill_len": 64,
  "models": [{
    "name": "tiny-mamba", "arch": "mamba", "variant": "baseline",
    "kind": "prefill", "batch": 1, "hlo": "m.hlo.txt",
    "weights": "w.bin", "weights_len": 100, "prefill_len": 64,
    "config": {"name": "tiny-mamba", "arch": "mamba", "vocab_size": 256,
               "d_model": 128, "n_layers": 2, "d_state": 16, "d_conv": 4,
               "expand": 2, "dt_rank": 8, "headdim": 64, "chunk": 64,
               "plu_segments": 32, "plu_range": 8.0},
    "inputs": [{"name": "wbuf", "shape": [100], "dtype": "f32"},
               {"name": "tokens", "shape": [64], "dtype": "i32"}]
  }]
}"#
        .to_string()
    }

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join("xamba_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_json()).unwrap();
        let m = Manifest::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.prefill_len, 64);
        let p = m.find("tiny-mamba", "baseline", "prefill").unwrap();
        assert_eq!(p.inputs.len(), 2);
        assert_eq!(p.inputs[1].dtype, ArgDType::I32);
        assert_eq!(p.shape.d_model, 128);
        assert!(m.find("tiny-mamba", "xamba", "prefill").is_none());
        assert!(m.decode_buckets("tiny-mamba", "baseline").is_empty());
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let e = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(e.contains("make artifacts"), "{e}");
    }
}
