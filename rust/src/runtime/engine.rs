//! PJRT execution engine: compile AOT HLO artifacts once, run them from
//! the serving hot path.
//!
//! Python never runs here — artifacts are HLO *text* (see aot.py for why
//! text, not serialized protos) compiled by the in-process XLA CPU backend
//! via the `xla` crate, then executed with `Literal` inputs. Weight
//! literals are uploaded once per model and shared across programs.

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use super::manifest::{ArgDType, ArgSpec, Manifest, ProgramEntry};
use crate::models::params::load_f32_bin;

/// A host-side tensor crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(s, _) | HostTensor::I32(s, _) => s,
        }
    }

    pub fn f32_data(&self) -> &[f32] {
        match self {
            HostTensor::F32(_, d) => d,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor::F32(shape.to_vec(), vec![0.0; shape.iter().product()])
    }

    pub(crate) fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(_, d) => xla::Literal::vec1(d.as_slice()),
            HostTensor::I32(_, d) => xla::Literal::vec1(d.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(HostTensor::I32(dims, lit.to_vec::<i32>()?)),
            other => Err(anyhow!("unsupported output element type {other:?}")),
        }
    }

    /// Validate against an ArgSpec (shape + dtype).
    pub fn matches(&self, spec: &ArgSpec) -> bool {
        let dt_ok = matches!(
            (self, spec.dtype),
            (HostTensor::F32(..), ArgDType::F32) | (HostTensor::I32(..), ArgDType::I32)
        );
        dt_ok && self.shape() == spec.shape.as_slice()
    }
}

/// Compiled-executable cache on a PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    weights: HashMap<String, HostTensor>,
    /// Pre-converted weights literals — rebuilding a literal costs a
    /// multi-MB copy per call, which dominated the decode hot path
    /// (EXPERIMENTS.md §Perf iteration 4).
    weight_literals: HashMap<String, xla::Literal>,
}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu()?,
            executables: HashMap::new(),
            weights: HashMap::new(),
            weight_literals: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) a manifest program.
    pub fn prepare(&mut self, manifest: &Manifest, entry: &ProgramEntry) -> Result<()> {
        let key = entry.key();
        if !self.executables.contains_key(&key) {
            let path = manifest.path(&entry.hlo_file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse HLO {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {}", entry.hlo_file))?;
            self.executables.insert(key, exe);
        }
        if !self.weights.contains_key(&entry.weights_file) {
            let path = manifest.path(&entry.weights_file);
            let data = load_f32_bin(path.to_str().context("non-utf8 path")?)
                .map_err(|e| anyhow!(e))?;
            if data.len() != entry.weights_len {
                return Err(anyhow!(
                    "{}: {} f32 on disk, manifest says {}",
                    entry.weights_file,
                    data.len(),
                    entry.weights_len
                ));
            }
            let host = HostTensor::F32(vec![data.len()], data);
            self.weight_literals
                .insert(entry.weights_file.clone(), host.to_literal()?);
            self.weights.insert(entry.weights_file.clone(), host);
        }
        Ok(())
    }

    /// The loaded flat weight buffer for a program.
    pub fn weights_for(&self, entry: &ProgramEntry) -> Result<&HostTensor> {
        self.weights
            .get(&entry.weights_file)
            .ok_or_else(|| anyhow!("weights not prepared for {}", entry.key()))
    }

    /// Execute a prepared program. `args` must match `entry.inputs`
    /// (including the leading weights buffer).
    pub fn execute(
        &self,
        entry: &ProgramEntry,
        args: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let exe = self
            .executables
            .get(&entry.key())
            .ok_or_else(|| anyhow!("program {} not prepared", entry.key()))?;
        if args.len() != entry.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} args, got {}",
                entry.key(),
                entry.inputs.len(),
                args.len()
            ));
        }
        for (a, spec) in args.iter().zip(&entry.inputs) {
            if !a.matches(spec) {
                return Err(anyhow!(
                    "{}: arg {} expects {:?} {:?}, got {:?}",
                    entry.key(),
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    a.shape()
                ));
            }
        }
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        unpack_result(exe.execute::<xla::Literal>(&literals)?)
    }

    /// Hot-path execute: the weights literal comes from the prepared
    /// cache (no per-call conversion); only `rest` is converted.
    pub fn execute_cached(
        &self,
        entry: &ProgramEntry,
        rest: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let exe = self
            .executables
            .get(&entry.key())
            .ok_or_else(|| anyhow!("program {} not prepared", entry.key()))?;
        if rest.len() + 1 != entry.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} non-weight args, got {}",
                entry.key(),
                entry.inputs.len() - 1,
                rest.len()
            ));
        }
        for (a, spec) in rest.iter().zip(&entry.inputs[1..]) {
            if !a.matches(spec) {
                return Err(anyhow!(
                    "{}: arg {} expects {:?} {:?}, got {:?}",
                    entry.key(),
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    a.shape()
                ));
            }
        }
        let wlit = self
            .weight_literals
            .get(&entry.weights_file)
            .ok_or_else(|| anyhow!("weights not prepared for {}", entry.key()))?;
        let mut literals: Vec<&xla::Literal> = Vec::with_capacity(rest.len() + 1);
        let rest_lits: Vec<xla::Literal> =
            rest.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        literals.push(wlit);
        literals.extend(rest_lits.iter());
        unpack_result(exe.execute::<&xla::Literal>(&literals)?)
    }

    /// Compile an HLO text file that is NOT part of a manifest and run it
    /// once on raw host tensors (kernel debugging harnesses). Keeps the
    /// `xla` types out of everything above this module.
    pub fn run_hlo_file(&self, path: &str, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO {path}"))?;
        let exe = self
            .client
            .compile(&xla::XlaComputation::from_proto(&proto))
            .with_context(|| format!("compile {path}"))?;
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        unpack_result(exe.execute::<xla::Literal>(&literals)?)
    }

    /// Convenience: prepare + execute with the cached weights literal.
    pub fn run_with_weights(
        &mut self,
        manifest: &Manifest,
        entry: &ProgramEntry,
        rest: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        self.prepare(manifest, entry)?;
        self.execute_cached(entry, rest)
    }
}

/// Unpack an executed program's result into host tensors. aot.py lowers
/// with return_tuple=True, so every program returns one tuple literal.
fn unpack_result(result: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<HostTensor>> {
    let lit = result[0][0].to_literal_sync()?;
    let parts = lit.to_tuple()?;
    parts.iter().map(HostTensor::from_literal).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_literal_round_trip() {
        let t = HostTensor::F32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
        let ti = HostTensor::I32(vec![3], vec![7, 8, 9]);
        let back = HostTensor::from_literal(&ti.to_literal().unwrap()).unwrap();
        assert_eq!(back, ti);
    }

    #[test]
    fn matches_checks_shape_and_dtype() {
        let spec = ArgSpec {
            name: "x".into(),
            shape: vec![2, 2],
            dtype: ArgDType::F32,
        };
        assert!(HostTensor::zeros(&[2, 2]).matches(&spec));
        assert!(!HostTensor::zeros(&[2, 3]).matches(&spec));
        assert!(!HostTensor::I32(vec![2, 2], vec![0; 4]).matches(&spec));
    }
}
