//! PJRT adapter: the AOT-compiled manifest programs behind the
//! [`Backend`] seam.
//!
//! The python build path compiles each (model, variant, stage) to an HLO
//! artifact; this backend maps a graph named `"model.variant.stage"`
//! onto the matching manifest program, converts `Tensor` ↔ `HostTensor`
//! at the boundary, and executes through the cached
//! [`Engine`](crate::runtime::Engine) executables. Unlike the planned
//! executor it does not interpret the graph body — the graph is the
//! *name and ABI* of an already-compiled program.

use std::cell::RefCell;
use std::rc::Rc;

use crate::graph::tensor::Data;
use crate::graph::{Graph, Tensor};
use crate::runtime::{Engine, HostTensor, Manifest, ProgramEntry};

use super::{Backend, Plan};

/// Backend over a PJRT engine + AOT manifest.
pub struct PjrtBackend {
    engine: Rc<RefCell<Engine>>,
    manifest: Rc<Manifest>,
}

impl PjrtBackend {
    /// Load the manifest from `artifacts_dir` and start a PJRT CPU
    /// client. Fails cleanly when the runtime is unavailable (offline
    /// stub build) or the artifacts are missing.
    pub fn new(artifacts_dir: &str) -> Result<Self, String> {
        let manifest = Manifest::load(artifacts_dir)?;
        let engine = Engine::cpu().map_err(|e| e.to_string())?;
        Ok(Self {
            engine: Rc::new(RefCell::new(engine)),
            manifest: Rc::new(manifest),
        })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// `graph.name` must be `"model.variant.stage"` (the manifest
    /// program key, e.g. `"tiny-mamba.xamba.prefill"`).
    fn plan(&self, graph: &Graph) -> Result<Box<dyn Plan>, String> {
        let mut parts = graph.name.splitn(3, '.');
        let (model, variant, stage) = match (parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(v), Some(s)) => (m, v, s),
            _ => {
                return Err(format!(
                    "pjrt backend: graph name {:?} is not model.variant.stage",
                    graph.name
                ))
            }
        };
        let entry = self
            .manifest
            .find(model, variant, stage)
            .ok_or_else(|| format!("no manifest program for {}", graph.name))?
            .clone();
        self.engine
            .borrow_mut()
            .prepare(&self.manifest, &entry)
            .map_err(|e| e.to_string())?;
        Ok(Box::new(PjrtPlan { engine: self.engine.clone(), entry }))
    }
}

struct PjrtPlan {
    engine: Rc<RefCell<Engine>>,
    entry: ProgramEntry,
}

impl Plan for PjrtPlan {
    /// `inputs` are the program's non-weight arguments (the weights
    /// literal is cached engine-side at prepare time).
    fn execute(&mut self, inputs: &[Tensor]) -> Result<Vec<Tensor>, String> {
        let args: Vec<HostTensor> = inputs.iter().map(to_host).collect();
        let outs = self
            .engine
            .borrow()
            .execute_cached(&self.entry, &args)
            .map_err(|e| e.to_string())?;
        Ok(outs.iter().map(from_host).collect())
    }
}

/// `Tensor` → `HostTensor` at the PJRT boundary. PJRT programs are
/// compiled for f32/i32 ABIs, so reduced-precision tensors widen to f32
/// here (quantized serving is a planned-backend feature).
pub fn to_host(t: &Tensor) -> HostTensor {
    match &t.data {
        Data::F32(v) => HostTensor::F32(t.shape.clone(), v.clone()),
        Data::I32(v) => HostTensor::I32(t.shape.clone(), v.clone()),
        Data::F16(_) | Data::I8 { .. } => {
            HostTensor::F32(t.shape.clone(), t.to_f32_vec())
        }
    }
}

/// `HostTensor` → `Tensor` at the PJRT boundary.
pub fn from_host(h: &HostTensor) -> Tensor {
    match h {
        HostTensor::F32(s, v) => Tensor::f32(s.clone(), v.clone()),
        HostTensor::I32(s, v) => Tensor::i32(s.clone(), v.clone()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_conversion_round_trips() {
        let t = Tensor::f32(vec![2, 2], vec![1., 2., 3., 4.]);
        assert_eq!(from_host(&to_host(&t)), t);
        let i = Tensor::i32(vec![3], vec![7, 8, 9]);
        assert_eq!(from_host(&to_host(&i)), i);
    }

    #[test]
    fn backend_construction_fails_cleanly_without_artifacts() {
        // no artifacts dir in unit-test CWD — must error, not panic
        assert!(PjrtBackend::new("definitely-not-a-dir").is_err());
    }
}
