//! Graph execution — the unified `Backend` seam.
//!
//! Everything that evaluates an IR [`Graph`] on concrete tensors goes
//! through [`Backend::plan`] → [`Plan::execute`]:
//!
//! * [`PlannedBackend`] — the production path: a one-time compilation
//!   into an [`ExecutionPlan`] (cached live-set schedule, liveness-based
//!   buffer arena with slot reuse, precomputed broadcast strides, fused
//!   elementwise chains). Zero per-node heap allocation in steady state.
//! * [`NaiveBackend`] — the original HashMap walker, kept verbatim as an
//!   independent reference for differential testing.
//! * [`PjrtBackend`] — a thin adapter over the PJRT
//!   [`runtime::Engine`](crate::runtime::Engine), mapping graphs onto
//!   AOT-compiled manifest programs.
//!
//! On top of the seam sit two serving-side building blocks:
//!
//! * [`PlanCache`] — compile-once storage of plans keyed by
//!   (program, bucket), with the constant input prefix (model
//!   parameters) bound into a reusable template.
//! * [`WorkerPool`] — persistent threads for data-parallel
//!   [`pool::ExecJob`] batches; each worker owns a private `PlanCache`
//!   (plans are cheap to compile, arenas are single-threaded), and
//!   batch results are bitwise-independent of the worker count.
//!
//! `passes::verify`, `quality::eval_lm`, the coordinator's
//! `PlannedServeModel`, the figure benches, and the examples all consume
//! this seam; future backends (quantized eval) plug in here.

pub mod arena;
pub mod cache;
pub mod fuse;
pub mod kernels;
pub mod naive;
pub mod pjrt;
pub mod plan;
pub mod pool;

pub use cache::{plan_key, plan_key_dtyped, PlanCache};
pub use naive::NaiveBackend;
pub use pjrt::PjrtBackend;
pub use plan::{ExecutionPlan, PlannedBackend, Schedule};
pub use pool::{ExecJob, WorkerPool};

use crate::graph::{Graph, Tensor};

/// A way of turning graphs into executable plans.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Analyze `graph` once, producing a plan that can run many times.
    fn plan(&self, graph: &Graph) -> Result<Box<dyn Plan>, String>;
}

/// A compiled graph, ready for repeated execution. `execute` takes
/// `&mut self` so plans may reuse internal buffers across calls.
pub trait Plan {
    /// Run on `inputs` (graph input order); returns tensors in graph
    /// output order.
    fn execute(&mut self, inputs: &[Tensor]) -> Result<Vec<Tensor>, String>;
}

/// One-shot convenience: compile an [`ExecutionPlan`] and run it once.
/// Callers that execute a graph more than once should plan explicitly.
pub fn run_once(graph: &Graph, inputs: &[Tensor]) -> Result<Vec<Tensor>, String> {
    ExecutionPlan::compile(graph)?.run(inputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backends_are_interchangeable_behind_the_trait() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![4]);
        let y = g.silu(x, "y");
        g.output(y);
        let inputs = [Tensor::f32(vec![4], vec![-1., 0., 1., 2.])];
        let backends: Vec<Box<dyn Backend>> =
            vec![Box::new(PlannedBackend), Box::new(NaiveBackend)];
        let mut results = Vec::new();
        for b in &backends {
            let mut plan = b.plan(&g).unwrap();
            results.push(plan.execute(&inputs).unwrap());
        }
        assert_eq!(results[0][0].as_f32(), results[1][0].as_f32());
    }

    #[test]
    fn run_once_matches_planned() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![3]);
        let y = g.exp(x, "y");
        g.output(y);
        let t = [Tensor::f32(vec![3], vec![0., 1., 2.])];
        let a = run_once(&g, &t).unwrap();
        let b = naive::run(&g, &t).unwrap();
        assert_eq!(a[0].as_f32(), b[0].as_f32());
    }
}
