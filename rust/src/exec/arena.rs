//! Liveness-driven, byte-addressed buffer arena for the planned executor.
//!
//! Plan compilation assigns every intermediate value to a numbered slot
//! via [`SlotAlloc`]; slots are released at a value's last use and reused
//! by later values, so the arena footprint tracks the graph's *live-range
//! width*, not its node count. Slots are sized in BYTES and backed by
//! 8-byte-aligned buffers, so liveness reuse works across dtypes: an f32
//! value's slot can later hold an i8 or f16 value of any numel that fits
//! (mixed-precision plans share one slot pool instead of one pool per
//! dtype). Each slot also carries a dynamic per-tensor scale — written by
//! whichever kernel last produced an i8 value there, read by its
//! consumers. The [`Arena`] itself is allocated once per plan and reused
//! across every `execute` call — steady-state execution touches the heap
//! zero times per node.

/// Compile-time slot assignment: first-fit reuse off a free list, with
/// each slot's capacity (in bytes) grown to the largest value ever
/// placed in it.
pub(crate) struct SlotAlloc {
    pub sizes: Vec<usize>,
    free: Vec<usize>,
}

impl SlotAlloc {
    pub fn new() -> Self {
        Self { sizes: Vec::new(), free: Vec::new() }
    }

    /// Assign a slot able to hold `bytes` bytes.
    pub fn alloc(&mut self, bytes: usize) -> usize {
        if let Some(s) = self.free.pop() {
            self.sizes[s] = self.sizes[s].max(bytes);
            s
        } else {
            self.sizes.push(bytes);
            self.sizes.len() - 1
        }
    }

    /// Return a slot to the free list (the value's last use has passed).
    pub fn release(&mut self, slot: usize) {
        debug_assert!(!self.free.contains(&slot), "double release of slot {slot}");
        self.free.push(slot);
    }
}

/// Marker for element types the arena may reinterpret its byte buffers
/// as. Everything here is plain-old-data with alignment <= 8 (the
/// `u64`-backed buffers' alignment), which is what makes the casts in
/// [`cast_slice`] / [`cast_slice_mut`] sound.
pub(crate) trait Pod: Copy {}
impl Pod for f32 {}
impl Pod for i32 {}
impl Pod for u16 {}
impl Pod for i8 {}

/// Reinterpret an 8-byte-aligned buffer as `n` elements of `T`. The
/// length bound is a real assert (not debug-only): it is the entire
/// memory-safety argument, and its cost is nothing next to the kernel
/// loop behind every call.
pub(crate) fn cast_slice<T: Pod>(buf: &[u64], n: usize) -> &[T] {
    assert!(n * std::mem::size_of::<T>() <= buf.len() * 8, "slot too small");
    // SAFETY: T is Pod (any bit pattern valid, no drop), align_of::<T>()
    // <= 8 == align_of::<u64>(), and the length is asserted above.
    unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const T, n) }
}

/// Mutable variant of [`cast_slice`].
pub(crate) fn cast_slice_mut<T: Pod>(buf: &mut [u64], n: usize) -> &mut [T] {
    assert!(n * std::mem::size_of::<T>() <= buf.len() * 8, "slot too small");
    // SAFETY: as in `cast_slice`, plus exclusive access via `&mut`.
    unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut T, n) }
}

/// The runtime buffers backing the slots — owned by the plan, reused
/// across `execute` calls.
pub struct Arena {
    /// 8-byte-aligned backing storage, `sizes[i].div_ceil(8)` words each.
    pub(crate) bufs: Vec<Vec<u64>>,
    /// Per-slot dynamic i8 scale: set when an i8 value is produced into
    /// the slot, read when it is consumed. Meaningless for other dtypes.
    pub(crate) scales: Vec<f32>,
}

impl Arena {
    pub(crate) fn from_sizes(byte_sizes: &[usize]) -> Self {
        Self {
            bufs: byte_sizes.iter().map(|&b| vec![0u64; b.div_ceil(8)]).collect(),
            scales: vec![1.0; byte_sizes.len()],
        }
    }

    /// Move a slot's buffer out (so the kernel can hold `&mut` to it
    /// while reading other slots); pair with [`Arena::put`].
    pub(crate) fn take(&mut self, slot: usize) -> Vec<u64> {
        std::mem::take(&mut self.bufs[slot])
    }

    pub(crate) fn put(&mut self, slot: usize, buf: Vec<u64>) {
        self.bufs[slot] = buf;
    }

    /// Borrow `n` elements of slot `slot` as `T`.
    pub(crate) fn view<T: Pod>(&self, slot: usize, n: usize) -> &[T] {
        cast_slice(&self.bufs[slot], n)
    }

    /// Number of slots.
    pub(crate) fn slots(&self) -> usize {
        self.bufs.len()
    }

    /// Total bytes held by the arena (footprint reporting).
    pub fn bytes(&self) -> usize {
        self.bufs.iter().map(|b| b.len() * 8).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_are_reused_after_release() {
        let mut a = SlotAlloc::new();
        let s0 = a.alloc(16);
        let s1 = a.alloc(8);
        assert_ne!(s0, s1);
        a.release(s0);
        let s2 = a.alloc(32); // reuses s0, growing it
        assert_eq!(s2, s0);
        assert_eq!(a.sizes[s0], 32);
        assert_eq!(a.sizes.len(), 2);
    }

    #[test]
    fn cross_dtype_reuse_shares_one_slot_pool() {
        // 16 f32 elements (64 B) release, then 60 i8 elements (60 B)
        // fit in the same slot — the byte arena does not care what the
        // bits mean
        let mut a = SlotAlloc::new();
        let s0 = a.alloc(16 * 4);
        a.release(s0);
        let s1 = a.alloc(60);
        assert_eq!(s1, s0);
        assert_eq!(a.sizes[s0], 64);
    }

    #[test]
    fn arena_buffers_round_up_to_words() {
        let a = Arena::from_sizes(&[16, 7, 3]);
        assert_eq!(a.bufs.len(), 3);
        assert_eq!(a.bufs[0].len(), 2);
        assert_eq!(a.bufs[1].len(), 1);
        assert_eq!(a.bytes(), 16 + 8 + 8);
        assert_eq!(a.scales.len(), 3);
    }

    #[test]
    fn typed_views_read_what_was_written() {
        let mut a = Arena::from_sizes(&[12]);
        {
            let mut buf = a.take(0);
            let f = cast_slice_mut::<f32>(&mut buf, 3);
            f.copy_from_slice(&[1.5, -2.0, 3.25]);
            a.put(0, buf);
        }
        assert_eq!(a.view::<f32>(0, 3), &[1.5, -2.0, 3.25]);
        // the same bytes reinterpreted as i8 see the f32 bit patterns,
        // which is exactly what cross-dtype slot reuse relies on
        {
            let mut buf = a.take(0);
            let q = cast_slice_mut::<i8>(&mut buf, 5);
            q.copy_from_slice(&[1, -2, 3, -4, 5]);
            a.put(0, buf);
        }
        assert_eq!(a.view::<i8>(0, 5), &[1, -2, 3, -4, 5]);
        // f16 bits in the same slot
        {
            let mut buf = a.take(0);
            let hsl = cast_slice_mut::<u16>(&mut buf, 2);
            hsl.copy_from_slice(&[0x3c00, 0xc000]); // 1.0, -2.0
            a.put(0, buf);
        }
        assert_eq!(a.view::<u16>(0, 2), &[0x3c00, 0xc000]);
    }
}
