//! Liveness-driven buffer arena for the planned executor.
//!
//! Plan compilation assigns every intermediate value to a numbered slot
//! via [`SlotAlloc`]; slots are released at a value's last use and reused
//! by later values, so the arena footprint tracks the graph's *live-range
//! width*, not its node count. The [`Arena`] itself is allocated once per
//! plan and reused across every `execute` call — steady-state execution
//! touches the heap zero times per node.

/// Compile-time slot assignment: first-fit reuse off a free list, with
/// each slot's capacity grown to the largest value ever placed in it.
pub(crate) struct SlotAlloc {
    pub sizes: Vec<usize>,
    free: Vec<usize>,
}

impl SlotAlloc {
    pub fn new() -> Self {
        Self { sizes: Vec::new(), free: Vec::new() }
    }

    /// Assign a slot able to hold `numel` elements.
    pub fn alloc(&mut self, numel: usize) -> usize {
        if let Some(s) = self.free.pop() {
            self.sizes[s] = self.sizes[s].max(numel);
            s
        } else {
            self.sizes.push(numel);
            self.sizes.len() - 1
        }
    }

    /// Return a slot to the free list (the value's last use has passed).
    pub fn release(&mut self, slot: usize) {
        debug_assert!(!self.free.contains(&slot), "double release of slot {slot}");
        self.free.push(slot);
    }
}

/// The runtime buffers backing the slots — owned by the plan, reused
/// across `execute` calls.
pub struct Arena {
    pub(crate) f: Vec<Vec<f32>>,
    pub(crate) i: Vec<Vec<i32>>,
}

impl Arena {
    pub(crate) fn from_sizes(f_sizes: &[usize], i_sizes: &[usize]) -> Self {
        Self {
            f: f_sizes.iter().map(|&n| vec![0.0f32; n]).collect(),
            i: i_sizes.iter().map(|&n| vec![0i32; n]).collect(),
        }
    }

    /// Move an f32 buffer out (so the kernel can hold `&mut` to it while
    /// reading other slots); pair with [`Arena::put_f`].
    pub(crate) fn take_f(&mut self, slot: usize) -> Vec<f32> {
        std::mem::take(&mut self.f[slot])
    }

    pub(crate) fn put_f(&mut self, slot: usize, buf: Vec<f32>) {
        self.f[slot] = buf;
    }

    pub(crate) fn take_i(&mut self, slot: usize) -> Vec<i32> {
        std::mem::take(&mut self.i[slot])
    }

    pub(crate) fn put_i(&mut self, slot: usize, buf: Vec<i32>) {
        self.i[slot] = buf;
    }

    /// Total bytes held by the arena (footprint reporting).
    pub fn bytes(&self) -> usize {
        self.f.iter().map(|b| b.len() * 4).sum::<usize>()
            + self.i.iter().map(|b| b.len() * 4).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_are_reused_after_release() {
        let mut a = SlotAlloc::new();
        let s0 = a.alloc(16);
        let s1 = a.alloc(8);
        assert_ne!(s0, s1);
        a.release(s0);
        let s2 = a.alloc(32); // reuses s0, growing it
        assert_eq!(s2, s0);
        assert_eq!(a.sizes[s0], 32);
        assert_eq!(a.sizes.len(), 2);
    }

    #[test]
    fn arena_buffers_match_sizes() {
        let a = Arena::from_sizes(&[4, 2], &[3]);
        assert_eq!(a.f.len(), 2);
        assert_eq!(a.f[0].len(), 4);
        assert_eq!(a.i[0].len(), 3);
        assert_eq!(a.bytes(), (4 + 2 + 3) * 4);
    }
}
