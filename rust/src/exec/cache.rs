//! Compile-once plan cache with an `Arc`-shared input prefix.
//!
//! The coordinator's serving path and the worker pool both execute the
//! same graphs over and over with a large, constant input prefix (the
//! model parameters) and a small per-call tail (token + recurrent
//! states). A [`PlanCache`] compiles each graph exactly once under a
//! caller-chosen key and holds ONE `Arc` to the shared prefix for the
//! whole cache — every key in a cache must share the same prefix (they
//! do: one cache serves one model). Execution goes through
//! [`ExecutionPlan::run_with_prefix`], so neither insertion nor a
//! steady-state call copies a single parameter tensor: the parameters
//! exist once per process however many caches (pool workers) share the
//! `Arc`.

use std::collections::HashMap;
use std::sync::Arc;

use crate::graph::{DType, Graph, Tensor};

use super::plan::ExecutionPlan;

/// Canonical cache key for a (model family, program) pair — e.g.
/// `plan_key("mamba2", "decode_b4")` → `"mamba2.decode_b4"`. Serving
/// callers qualify every key with the family so a cache (or a pool
/// worker's private cache) can never conflate same-named programs of
/// different model families. Returned as `Arc<str>` because the decode
/// hot path clones refcounts, not strings.
pub fn plan_key(family: &str, program: &str) -> Arc<str> {
    format!("{family}.{program}").into()
}

/// [`plan_key`] qualified by serving dtype — `mamba2.decode_b4.i8`.
/// f32 keeps the unsuffixed key, so pre-quantization cache keys (and
/// everything logging them) are unchanged. Mixed-precision serving
/// compiles once per (program, bucket, dtype): the same program at two
/// dtypes is two different plans with different kernels and arenas.
pub fn plan_key_dtyped(family: &str, program: &str, dtype: DType) -> Arc<str> {
    match dtype {
        DType::F32 => plan_key(family, program),
        d => format!("{family}.{program}.{}", d.name()).into(),
    }
}

/// Keyed store of compiled [`ExecutionPlan`]s. Keys identify a
/// (model family, program, bucket) triple — e.g. `"mamba.prefill"`,
/// `"mamba2.decode_b4"` (see [`plan_key`]) — and each key is compiled at
/// most once for the cache's lifetime.
#[derive(Default)]
pub struct PlanCache {
    plans: HashMap<String, ExecutionPlan>,
    /// Input prefix shared by every plan in the cache; bound (by `Arc`
    /// clone) at first insert.
    shared: Arc<Vec<Tensor>>,
    compiles: usize,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Compile `graph` under `key`. The first insert binds `shared` as
    /// the cache-wide input prefix; later inserts must pass the same
    /// prefix (one cache serves one model's parameter set). A second
    /// insert under an existing key is a no-op (the existing plan wins),
    /// preserving compile-once semantics.
    pub fn insert_with(
        &mut self,
        key: &str,
        graph: &Graph,
        shared: &Arc<Vec<Tensor>>,
    ) -> Result<(), String> {
        if self.plans.contains_key(key) {
            return Ok(());
        }
        if self.plans.is_empty() {
            self.shared = shared.clone();
        } else if !Arc::ptr_eq(&self.shared, shared) {
            // one cache <=> one prefix Arc; a different allocation would
            // silently execute later keys against the wrong parameters
            return Err(format!(
                "PlanCache is bound to a {}-tensor shared prefix; key {key:?} \
                 brought a different prefix ({} tensors)",
                self.shared.len(),
                shared.len()
            ));
        }
        let plan = ExecutionPlan::compile(graph)?;
        self.compiles += 1;
        self.plans.insert(key.to_string(), plan);
        Ok(())
    }

    /// Like [`PlanCache::insert_with`] followed by [`PlanCache::run`] —
    /// the get-or-compile entry point the pool workers use.
    pub fn run_or_compile(
        &mut self,
        key: &str,
        graph: &Graph,
        shared: &Arc<Vec<Tensor>>,
        tail: Vec<Tensor>,
    ) -> Result<Vec<Tensor>, String> {
        self.insert_with(key, graph, shared)?;
        self.run(key, tail)
    }

    /// [`PlanCache::run_or_compile`] with a *deferred* graph: `make_graph`
    /// runs only on a cache miss, so callers with many lazily-materialized
    /// programs (the serving path's per-(bucket, length-class) prefill
    /// graphs) pay graph construction exactly once per key — a steady-state
    /// hit is a pure lookup.
    pub fn run_or_compile_with(
        &mut self,
        key: &str,
        make_graph: impl FnOnce() -> Result<Graph, String>,
        shared: &Arc<Vec<Tensor>>,
        tail: Vec<Tensor>,
    ) -> Result<Vec<Tensor>, String> {
        if !self.plans.contains_key(key) {
            let graph = make_graph()?;
            self.insert_with(key, &graph, shared)?;
        }
        self.run(key, tail)
    }

    /// Execute the cached plan for `key` on `shared ++ tail`.
    pub fn run(&mut self, key: &str, tail: Vec<Tensor>) -> Result<Vec<Tensor>, String> {
        let plan = self
            .plans
            .get_mut(key)
            .ok_or_else(|| format!("no cached plan for key {key:?}"))?;
        plan.run_with_prefix(&self.shared, &tail)
    }

    /// Direct access to a cached plan (introspection: step/slot counts).
    pub fn plan(&self, key: &str) -> Option<&ExecutionPlan> {
        self.plans.get(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.plans.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    /// How many plan compilations this cache has performed — stays flat
    /// under serving traffic once every (program, bucket) is inserted.
    pub fn compile_count(&self) -> usize {
        self.compiles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn add_graph() -> Graph {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2]);
        let b = g.input("b", vec![2]);
        let c = g.add(a, b, "c");
        g.output(c);
        g
    }

    #[test]
    fn compiles_once_per_key() {
        let g = add_graph();
        let shared = Arc::new(vec![Tensor::f32(vec![2], vec![1.0, 2.0])]);
        let mut cache = PlanCache::new();
        cache.insert_with("k", &g, &shared).unwrap();
        cache.insert_with("k", &g, &shared).unwrap();
        assert_eq!(cache.compile_count(), 1);
        assert_eq!(cache.len(), 1);
        let r = cache.run("k", vec![Tensor::f32(vec![2], vec![10.0, 20.0])]).unwrap();
        assert_eq!(r[0].as_f32(), &[11.0, 22.0]);
    }

    #[test]
    fn tail_swaps_between_runs() {
        let g = add_graph();
        let shared = Arc::new(vec![Tensor::f32(vec![2], vec![1.0, 1.0])]);
        let mut cache = PlanCache::new();
        for v in [0.0f32, 5.0, -3.0] {
            let r = cache
                .run_or_compile("k", &g, &shared, vec![Tensor::f32(vec![2], vec![v, v])])
                .unwrap();
            assert_eq!(r[0].as_f32(), &[1.0 + v, 1.0 + v]);
        }
        assert_eq!(cache.compile_count(), 1);
    }

    #[test]
    fn keys_share_one_prefix_binding() {
        // two keys, one Arc'd prefix: the parameters are never copied
        let g = add_graph();
        let shared = Arc::new(vec![Tensor::f32(vec![2], vec![3.0, 4.0])]);
        let mut cache = PlanCache::new();
        cache.insert_with("k1", &g, &shared).unwrap();
        cache.insert_with("k2", &g, &shared).unwrap();
        let r1 = cache.run("k1", vec![Tensor::f32(vec![2], vec![1.0, 1.0])]).unwrap();
        let r2 = cache.run("k2", vec![Tensor::f32(vec![2], vec![2.0, 2.0])]).unwrap();
        assert_eq!(r1[0].as_f32(), &[4.0, 5.0]);
        assert_eq!(r2[0].as_f32(), &[5.0, 6.0]);
        // ANY other prefix allocation is rejected, not silently rebound —
        // even one with identical length/content
        let err = cache.insert_with("k3", &g, &Arc::new(Vec::new()));
        assert!(err.unwrap_err().contains("shared prefix"));
        let same_content = Arc::new(vec![Tensor::f32(vec![2], vec![3.0, 4.0])]);
        assert!(cache.insert_with("k4", &g, &same_content).is_err());
    }

    #[test]
    fn missing_key_is_an_error() {
        let mut cache = PlanCache::new();
        assert!(cache.run("nope", vec![]).is_err());
    }

    #[test]
    fn deferred_graph_builds_only_on_miss() {
        let shared = Arc::new(vec![Tensor::f32(vec![2], vec![1.0, 1.0])]);
        let mut cache = PlanCache::new();
        let mut builds = 0usize;
        for v in [2.0f32, 3.0] {
            let r = cache
                .run_or_compile_with(
                    "lazy",
                    || {
                        builds += 1;
                        Ok(add_graph())
                    },
                    &shared,
                    vec![Tensor::f32(vec![2], vec![v, v])],
                )
                .unwrap();
            assert_eq!(r[0].as_f32(), &[1.0 + v, 1.0 + v]);
        }
        assert_eq!(builds, 1, "graph must be constructed once, on the miss");
        assert_eq!(cache.compile_count(), 1);
        // a failing builder surfaces its error and caches nothing
        let err = cache.run_or_compile_with(
            "broken",
            || Err("no such graph".into()),
            &shared,
            vec![],
        );
        assert!(err.unwrap_err().contains("no such graph"));
        assert!(!cache.contains("broken"));
    }

    #[test]
    fn plan_keys_carry_the_model_family() {
        assert_eq!(&*plan_key("mamba", "prefill"), "mamba.prefill");
        assert_eq!(&*plan_key("mamba2", "decode_b4"), "mamba2.decode_b4");
        assert_ne!(plan_key("mamba", "decode_b1"), plan_key("mamba2", "decode_b1"));
    }

    #[test]
    fn dtyped_plan_keys_separate_precisions() {
        assert_eq!(
            &*plan_key_dtyped("mamba2", "decode_b4", DType::F32),
            "mamba2.decode_b4",
            "f32 keeps the legacy unsuffixed key"
        );
        assert_eq!(
            &*plan_key_dtyped("mamba2", "decode_b4", DType::I8),
            "mamba2.decode_b4.i8"
        );
        assert_eq!(
            &*plan_key_dtyped("mamba", "prefill_t8", DType::F16),
            "mamba.prefill_t8.f16"
        );
    }
}
