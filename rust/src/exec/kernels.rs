//! Out-buffer operator kernels for the planned executor.
//!
//! Every kernel writes into a caller-provided slice (an arena slot), so
//! steady-state execution performs no heap allocation. Loop structures
//! deliberately mirror the reference evaluator in [`super::naive`]
//! operation-for-operation, so planned and naive execution agree
//! *bitwise* — the differential suite in `tests/exec_differential.rs`
//! holds them to that.

use crate::graph::op::{BinKind, UnKind};
use crate::graph::tensor::{amax_abs, dequantize_i8_one, i8_scale, quantize_i8_one};
use crate::plu::{self, PluTable};
use crate::util::f16::{f16_to_f32, f32_to_f16};

/// Scalar unary application — shared by the naive evaluator, the planned
/// unary kernel, and fused-chain stages (identity of results by
/// construction).
#[inline]
pub fn apply_unary(kind: UnKind, v: f32) -> f32 {
    match kind {
        UnKind::Neg => -v,
        UnKind::Exp => v.exp(),
        UnKind::Log => v.ln(),
        UnKind::Sqrt => v.sqrt(),
        UnKind::Abs => v.abs(),
        UnKind::Recip => 1.0 / v,
        UnKind::Relu => v.max(0.0),
        UnKind::Sigmoid => plu::sigmoid_f32(v),
        UnKind::SiLU => v * plu::sigmoid_f32(v),
        UnKind::Softplus => plu::softplus_f32(v),
        UnKind::Tanh => v.tanh(),
    }
}

/// Scalar binary application — shared like [`apply_unary`].
#[inline]
pub fn apply_binary(kind: BinKind, x: f32, y: f32) -> f32 {
    match kind {
        BinKind::Add => x + y,
        BinKind::Sub => x - y,
        BinKind::Mul => x * y,
        BinKind::Div => x / y,
        BinKind::Max => x.max(y),
    }
}

// --- storage element types ------------------------------------------------------

/// A storage element the dtype-generic kernels load/store through: every
/// value widens to f32 for arithmetic and narrows on store. `f32` is the
/// identity instance (the generic loops then compile to the plain f32
/// loops), `u16` holds raw IEEE-754 half bits.
pub trait Elem: Copy {
    fn to_f32(self) -> f32;
    fn from_f32(v: f32) -> Self;
}

impl Elem for f32 {
    #[inline]
    fn to_f32(self) -> f32 {
        self
    }
    #[inline]
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// Raw IEEE-754 half bits (the `Data::F16` payload type).
impl Elem for u16 {
    #[inline]
    fn to_f32(self) -> f32 {
        f16_to_f32(self)
    }
    #[inline]
    fn from_f32(v: f32) -> Self {
        f32_to_f16(v)
    }
}

// --- argument views -------------------------------------------------------------

/// Borrowed, dtype-tagged tensor payload.
#[derive(Clone, Copy)]
pub enum DataRef<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    /// Raw half bits.
    F16(&'a [u16]),
    /// Quantized values + their per-tensor symmetric scale.
    I8(&'a [i8], f32),
}

/// Borrowed tensor: shape + payload. What planned kernels consume.
#[derive(Clone, Copy)]
pub struct View<'a> {
    pub shape: &'a [usize],
    pub data: DataRef<'a>,
}

impl<'a> View<'a> {
    pub fn f32(&self) -> &'a [f32] {
        match self.data {
            DataRef::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn i32(&self) -> &'a [i32] {
        match self.data {
            DataRef::I32(v) => v,
            _ => panic!("expected i32 tensor"),
        }
    }

    pub fn f16(&self) -> &'a [u16] {
        match self.data {
            DataRef::F16(v) => v,
            _ => panic!("expected f16 tensor"),
        }
    }

    pub fn i8(&self) -> (&'a [i8], f32) {
        match self.data {
            DataRef::I8(v, s) => (v, s),
            _ => panic!("expected i8 tensor"),
        }
    }
}

// --- elementwise ----------------------------------------------------------------

/// Precomputed broadcast classification of a binary op (compile-time).
#[derive(Clone, Debug)]
pub enum BinMode {
    /// Both operands already have the output shape.
    Elementwise,
    /// `tensor op scalar` — right operand has one element.
    ScalarRight,
    /// `scalar op tensor` — left operand has one element.
    ScalarLeft,
    /// General broadcast: per-output-dim input strides (0 on broadcast
    /// dims), precomputed at plan time.
    Strided { sa: Vec<usize>, sb: Vec<usize> },
}

/// Per-output-dim strides of a broadcast input: 0 where the input dim is
/// 1 (or missing), the row-major stride otherwise. Matches the reference
/// evaluator's `bcast_index` arithmetic exactly.
pub fn bcast_strides(out_shape: &[usize], in_shape: &[usize]) -> Vec<usize> {
    let st = crate::graph::tensor::strides(in_shape);
    let off = out_shape.len() - in_shape.len();
    let mut r = vec![0usize; out_shape.len()];
    for (d, &s) in in_shape.iter().enumerate() {
        r[off + d] = if s == 1 { 0 } else { st[d] };
    }
    r
}

/// The f32 binary kernel is the `Elem`-generic one at its identity
/// instance (`to_f32`/`from_f32` compile away), so the two can never
/// drift apart.
pub fn binary_out(
    kind: BinKind,
    mode: &BinMode,
    a: &[f32],
    b: &[f32],
    out_shape: &[usize],
    out: &mut [f32],
    idx: &mut Vec<usize>,
) {
    binary_out_g::<f32>(kind, mode, a, b, out_shape, out, idx);
}

pub fn unary_out(kind: UnKind, x: &[f32], out: &mut [f32]) {
    unary_out_g::<f32>(kind, x, out);
}

pub fn plu_out(table: &PluTable, x: &[f32], out: &mut [f32]) {
    table.eval_slice(x, out);
}

// --- matmul ---------------------------------------------------------------------

/// Batched matmul into a zeroed output. `a_step`/`b_step` are the
/// per-batch element offsets (0 when the operand is not batched).
#[allow(clippy::too_many_arguments)]
pub fn matmul_out(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a_step: usize,
    b_step: usize,
) {
    out.fill(0.0);
    for bi in 0..batch {
        let ao = bi * a_step;
        let bo = bi * b_step;
        let oo = bi * m * n;
        for i in 0..m {
            for kk in 0..k {
                let av_ik = a[ao + i * k + kk];
                if av_ik == 0.0 {
                    continue;
                }
                let brow = bo + kk * n;
                let orow = oo + i * n;
                for j in 0..n {
                    out[orow + j] += av_ik * b[brow + j];
                }
            }
        }
    }
}

// --- scans / reductions ---------------------------------------------------------

/// Delegates to the generic scan (identical f32 addition sequence: the
/// running accumulator IS the previously stored element at f32).
pub fn cumsum_out(x: &[f32], out: &mut [f32], outer: usize, n_axis: usize, inner: usize) {
    cumsum_out_g::<f32>(x, out, outer, n_axis, inner);
}

pub fn reduce_sum_out(
    x: &[f32],
    out: &mut [f32],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    out.fill(0.0);
    for o in 0..outer {
        for j in 0..n_axis {
            let base = (o * n_axis + j) * inner;
            let obase = o * inner;
            for i in 0..inner {
                out[obase + i] += x[base + i];
            }
        }
    }
}

// --- gather / conv / norms ------------------------------------------------------

pub fn gather_out<T: Copy>(
    data: &[T],
    indices: &[i32],
    out: &mut [T],
    row: usize,
    vocab: usize,
) -> Result<(), String> {
    for (r, &i) in indices.iter().enumerate() {
        if i < 0 || i >= vocab as i32 {
            return Err(format!("gather index {i} out of range 0..{vocab}"));
        }
        out[r * row..(r + 1) * row]
            .copy_from_slice(&data[i as usize * row..(i as usize + 1) * row]);
    }
    Ok(())
}

pub fn conv1d_out(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    t: usize,
    c: usize,
    k: usize,
) {
    conv1d_out_g::<f32>(x, w, b, out, t, c, k);
}

pub fn rmsnorm_out(x: &[f32], w: &[f32], out: &mut [f32], rows: usize, d: usize, eps: f32) {
    rmsnorm_out_g::<f32>(x, w, out, rows, d, eps);
}

pub fn softmax_out(x: &[f32], out: &mut [f32], outer: usize, n_axis: usize, inner: usize) {
    for o in 0..outer {
        for i in 0..inner {
            let at = |j: usize| (o * n_axis + j) * inner + i;
            let mut mx = f32::NEG_INFINITY;
            for j in 0..n_axis {
                mx = mx.max(x[at(j)]);
            }
            let mut z = 0.0;
            for j in 0..n_axis {
                let e = (x[at(j)] - mx).exp();
                out[at(j)] = e;
                z += e;
            }
            for j in 0..n_axis {
                out[at(j)] /= z;
            }
        }
    }
}

// --- layout ---------------------------------------------------------------------

pub fn slice_out<T: Copy>(
    x: &[T],
    out: &mut [T],
    outer: usize,
    n_axis: usize,
    inner: usize,
    start: usize,
    len: usize,
) {
    for o in 0..outer {
        let src = (o * n_axis + start) * inner;
        let dst = o * len * inner;
        out[dst..dst + len * inner].copy_from_slice(&x[src..src + len * inner]);
    }
}

/// Row-major copy (reshape).
pub fn copy_out<T: Copy>(x: &[T], out: &mut [T]) {
    out.copy_from_slice(x);
}

/// Strided gather copy: walks the output row-major, reading the input at
/// the precomputed per-output-dim strides (transpose and broadcast).
pub fn strided_copy_out<T: Copy>(
    x: &[T],
    out: &mut [T],
    out_shape: &[usize],
    strides: &[usize],
    idx: &mut Vec<usize>,
) {
    idx.clear();
    idx.resize(out_shape.len(), 0);
    for o in out.iter_mut() {
        let mut lin = 0;
        for (d, &i) in idx.iter().enumerate() {
            lin += i * strides[d];
        }
        *o = x[lin];
        for d in (0..out_shape.len()).rev() {
            idx[d] += 1;
            if idx[d] < out_shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

// --- dtype-generic (f16) kernels ------------------------------------------------
//
// Mirrors of the f32 kernels above over any `Elem` storage type: load →
// widen to f32 → identical arithmetic → narrow on store. Loop structure
// and evaluation order match the f32 kernels exactly, so the naive
// walker's widen-evaluate-narrow reference produces bitwise-identical
// halves (all rounding happens at stores, never inside accumulators).

pub fn unary_out_g<T: Elem>(kind: UnKind, x: &[T], out: &mut [T]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = T::from_f32(apply_unary(kind, v.to_f32()));
    }
}

pub fn plu_out_g<T: Elem>(table: &PluTable, x: &[T], out: &mut [T]) {
    // eval_premul is the same inner evaluation eval_slice uses, so the
    // f16 PLU picks identical segments to the f32 path for equal inputs
    let inv_step = 1.0 / table.step();
    let kmax = table.num_segments() as i64 - 1;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = T::from_f32(table.eval_premul(v.to_f32(), inv_step, kmax));
    }
}

pub fn binary_out_g<T: Elem>(
    kind: BinKind,
    mode: &BinMode,
    a: &[T],
    b: &[T],
    out_shape: &[usize],
    out: &mut [T],
    idx: &mut Vec<usize>,
) {
    match mode {
        BinMode::Elementwise => {
            for i in 0..out.len() {
                out[i] = T::from_f32(apply_binary(kind, a[i].to_f32(), b[i].to_f32()));
            }
        }
        BinMode::ScalarRight => {
            let s = b[0].to_f32();
            for i in 0..out.len() {
                out[i] = T::from_f32(apply_binary(kind, a[i].to_f32(), s));
            }
        }
        BinMode::ScalarLeft => {
            let s = a[0].to_f32();
            for i in 0..out.len() {
                out[i] = T::from_f32(apply_binary(kind, s, b[i].to_f32()));
            }
        }
        BinMode::Strided { sa, sb } => {
            idx.clear();
            idx.resize(out_shape.len(), 0);
            for o in out.iter_mut() {
                let mut ia = 0;
                let mut ib = 0;
                for (d, &i) in idx.iter().enumerate() {
                    ia += i * sa[d];
                    ib += i * sb[d];
                }
                *o = T::from_f32(apply_binary(kind, a[ia].to_f32(), b[ib].to_f32()));
                for d in (0..idx.len()).rev() {
                    idx[d] += 1;
                    if idx[d] < out_shape[d] {
                        break;
                    }
                    idx[d] = 0;
                }
            }
        }
    }
}

/// Batched matmul with f32 accumulation, storage-rounded output.
#[allow(clippy::too_many_arguments)]
pub fn matmul_out_g<T: Elem>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a_step: usize,
    b_step: usize,
) {
    let mut row = vec![0.0f32; n]; // f32 accumulator row (rounding only at store)
    for bi in 0..batch {
        let ao = bi * a_step;
        let bo = bi * b_step;
        let oo = bi * m * n;
        for i in 0..m {
            row.fill(0.0);
            for kk in 0..k {
                let av_ik = a[ao + i * k + kk].to_f32();
                if av_ik == 0.0 {
                    continue;
                }
                let brow = bo + kk * n;
                for (j, r) in row.iter_mut().enumerate() {
                    *r += av_ik * b[brow + j].to_f32();
                }
            }
            let orow = oo + i * n;
            for (j, &r) in row.iter().enumerate() {
                out[orow + j] = T::from_f32(r);
            }
        }
    }
}

/// CumSum with an f32 running accumulator; each prefix rounds at store
/// only, so precision does not decay along the scan. The first element
/// is a copy and later sums are `x[j] + acc` — the exact value sequence
/// of the in-place reference scan (`out[j] += out[j-1]`), including
/// signed zeros and NaN-payload propagation order.
pub fn cumsum_out_g<T: Elem>(
    x: &[T],
    out: &mut [T],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    if n_axis == 0 {
        return;
    }
    for o in 0..outer {
        for i in 0..inner {
            let base = o * n_axis * inner + i;
            let mut acc = x[base].to_f32();
            out[base] = T::from_f32(acc);
            for j in 1..n_axis {
                acc = x[base + j * inner].to_f32() + acc;
                out[base + j * inner] = T::from_f32(acc);
            }
        }
    }
}

pub fn reduce_sum_out_g<T: Elem>(
    x: &[T],
    out: &mut [T],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    // accumulate the whole output in f32, store rounded once at the end
    // (mirrors the f32 kernel's accumulation order exactly)
    for o in 0..outer {
        for i in 0..inner {
            let mut acc = 0.0f32;
            for j in 0..n_axis {
                acc += x[(o * n_axis + j) * inner + i].to_f32();
            }
            out[o * inner + i] = T::from_f32(acc);
        }
    }
}

pub fn conv1d_out_g<T: Elem>(
    x: &[T],
    w: &[T],
    b: &[T],
    out: &mut [T],
    t: usize,
    c: usize,
    k: usize,
) {
    for ti in 0..t {
        for ci in 0..c {
            let mut acc = b[ci].to_f32();
            for ki in 0..k {
                // causal: tap ki reads position ti - (k - 1 - ki)
                let src = ti as isize - (k - 1 - ki) as isize;
                if src >= 0 {
                    acc += w[ki * c + ci].to_f32() * x[src as usize * c + ci].to_f32();
                }
            }
            out[ti * c + ci] = T::from_f32(acc);
        }
    }
}

pub fn rmsnorm_out_g<T: Elem>(
    x: &[T],
    w: &[T],
    out: &mut [T],
    rows: usize,
    d: usize,
    eps: f32,
) {
    for r in 0..rows {
        let mut ms = 0.0f32;
        for i in 0..d {
            let v = x[r * d + i].to_f32();
            ms += v * v;
        }
        let ms = ms / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for i in 0..d {
            out[r * d + i] = T::from_f32(x[r * d + i].to_f32() * inv * w[i].to_f32());
        }
    }
}

pub fn softmax_out_g<T: Elem>(
    x: &[T],
    out: &mut [T],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    // two passes recompute exp instead of staging rounded intermediates,
    // so every stored value is round(e_j / z) with e_j and z in f32 —
    // identical to narrowing an f32 softmax after the fact
    for o in 0..outer {
        for i in 0..inner {
            let at = |j: usize| (o * n_axis + j) * inner + i;
            let mut mx = f32::NEG_INFINITY;
            for j in 0..n_axis {
                mx = mx.max(x[at(j)].to_f32());
            }
            let mut z = 0.0f32;
            for j in 0..n_axis {
                z += (x[at(j)].to_f32() - mx).exp();
            }
            for j in 0..n_axis {
                out[at(j)] = T::from_f32((x[at(j)].to_f32() - mx).exp() / z);
            }
        }
    }
}

// --- precision conversion kernels ----------------------------------------------

/// f32 -> f16, round-to-nearest-even per element.
pub fn quantize_f16_out(x: &[f32], out: &mut [u16]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = f32_to_f16(v);
    }
}

/// f32 -> i8 with a dynamically computed per-tensor symmetric scale.
/// Returns the scale (the caller owns where it lives: `Data::I8` for
/// tensors, the arena's per-slot scale table for planned execution).
pub fn quantize_i8_out(x: &[f32], out: &mut [i8]) -> f32 {
    requantize_i8(x, out)
}

pub fn dequantize_f16_out(x: &[u16], out: &mut [f32]) {
    for (o, &b) in out.iter_mut().zip(x) {
        *o = f16_to_f32(b);
    }
}

pub fn dequantize_i8_out(q: &[i8], scale: f32, out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(q) {
        *o = dequantize_i8_one(v, scale);
    }
}

// --- i8 kernels -----------------------------------------------------------------
//
// Elementwise / scan / reduce i8 kernels follow one shape: dequantize on
// load, run the EXACT f32 arithmetic of the reference kernels into an
// f32 scratch, then requantize the whole result with a dynamic
// per-tensor scale (`requantize_i8`). The naive walker reaches bitwise-
// identical results by construction: widen → f32 eval → same
// requantize. MatMul is the exception — it consumes i8 operands
// directly with exact i32 accumulation (the real int8-GEMM datapath).

/// Quantize `src` into `out` with a fresh per-tensor scale; returns it.
pub fn requantize_i8(src: &[f32], out: &mut [i8]) -> f32 {
    let scale = i8_scale(amax_abs(src));
    for (o, &v) in out.iter_mut().zip(src) {
        *o = quantize_i8_one(v, scale);
    }
    scale
}

// local shorthand over the ONE shared i8 mapping in `graph::tensor`
// (planned, naive, and `Tensor::to_dtype` must stay bit-identical)
#[inline]
fn deq(q: i8, scale: f32) -> f32 {
    dequantize_i8_one(q, scale)
}

/// i8 unary into an f32 staging slice (requantized by the caller).
pub fn unary_i8_into(kind: UnKind, q: &[i8], scale: f32, scratch: &mut [f32]) {
    for (o, &v) in scratch.iter_mut().zip(q) {
        *o = apply_unary(kind, deq(v, scale));
    }
}

/// i8 binary into an f32 staging slice, all broadcast modes.
#[allow(clippy::too_many_arguments)]
pub fn binary_i8_into(
    kind: BinKind,
    mode: &BinMode,
    a: &[i8],
    sa_q: f32,
    b: &[i8],
    sb_q: f32,
    out_shape: &[usize],
    scratch: &mut [f32],
    idx: &mut Vec<usize>,
) {
    match mode {
        BinMode::Elementwise => {
            for i in 0..scratch.len() {
                scratch[i] = apply_binary(kind, deq(a[i], sa_q), deq(b[i], sb_q));
            }
        }
        BinMode::ScalarRight => {
            let s = deq(b[0], sb_q);
            for i in 0..scratch.len() {
                scratch[i] = apply_binary(kind, deq(a[i], sa_q), s);
            }
        }
        BinMode::ScalarLeft => {
            let s = deq(a[0], sa_q);
            for i in 0..scratch.len() {
                scratch[i] = apply_binary(kind, s, deq(b[i], sb_q));
            }
        }
        BinMode::Strided { sa, sb } => {
            idx.clear();
            idx.resize(out_shape.len(), 0);
            for o in scratch.iter_mut() {
                let mut ia = 0;
                let mut ib = 0;
                for (d, &i) in idx.iter().enumerate() {
                    ia += i * sa[d];
                    ib += i * sb[d];
                }
                *o = apply_binary(kind, deq(a[ia], sa_q), deq(b[ib], sb_q));
                for d in (0..idx.len()).rev() {
                    idx[d] += 1;
                    if idx[d] < out_shape[d] {
                        break;
                    }
                    idx[d] = 0;
                }
            }
        }
    }
}

/// i8 cumsum into an f32 staging slice: the running accumulator stays
/// f32 end to end (the scan never requantizes mid-prefix).
pub fn cumsum_i8_into(
    q: &[i8],
    scale: f32,
    scratch: &mut [f32],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    for o in 0..outer {
        for i in 0..inner {
            let base = o * n_axis * inner + i;
            let mut acc = 0.0f32;
            for j in 0..n_axis {
                acc += deq(q[base + j * inner], scale);
                scratch[base + j * inner] = acc;
            }
        }
    }
}

/// i8 reduce-sum into an f32 staging slice (f32 accumulation).
pub fn reduce_sum_i8_into(
    q: &[i8],
    scale: f32,
    scratch: &mut [f32],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    for o in 0..outer {
        for i in 0..inner {
            let mut acc = 0.0f32;
            for j in 0..n_axis {
                acc += deq(q[(o * n_axis + j) * inner + i], scale);
            }
            scratch[o * inner + i] = acc;
        }
    }
}

/// i8 x i8 batched matmul: exact i32 accumulation per dot product,
/// dequantized into f32 by the product of the operand scales.
#[allow(clippy::too_many_arguments)]
pub fn matmul_i8_out(
    a: &[i8],
    sa: f32,
    b: &[i8],
    sb: f32,
    out: &mut [f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a_step: usize,
    b_step: usize,
) {
    let s = sa * sb;
    let mut row = vec![0i32; n];
    for bi in 0..batch {
        let ao = bi * a_step;
        let bo = bi * b_step;
        let oo = bi * m * n;
        for i in 0..m {
            row.fill(0);
            for kk in 0..k {
                let av_ik = a[ao + i * k + kk];
                if av_ik == 0 {
                    continue;
                }
                let av = i32::from(av_ik);
                let brow = bo + kk * n;
                for (j, r) in row.iter_mut().enumerate() {
                    *r += av * i32::from(b[brow + j]);
                }
            }
            let orow = oo + i * n;
            for (j, &r) in row.iter().enumerate() {
                out[orow + j] = r as f32 * s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_out_2d() {
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        let mut out = [0.0f32; 4];
        matmul_out(&a, &b, &mut out, 1, 2, 3, 2, 0, 0);
        assert_eq!(out, [58., 64., 139., 154.]);
    }

    #[test]
    fn binary_out_strided_matches_scalar_path() {
        // (2,2) * scalar via Strided must equal the ScalarRight fast path
        let a = [1., 2., 3., 4.];
        let b = [10.0f32];
        let mut fast = [0.0f32; 4];
        let mut slow = [0.0f32; 4];
        let mut idx = Vec::new();
        binary_out(BinKind::Mul, &BinMode::ScalarRight, &a, &b, &[2, 2], &mut fast, &mut idx);
        let mode = BinMode::Strided {
            sa: bcast_strides(&[2, 2], &[2, 2]),
            sb: bcast_strides(&[2, 2], &[]),
        };
        binary_out(BinKind::Mul, &mode, &a, &b, &[2, 2], &mut slow, &mut idx);
        assert_eq!(fast, slow);
        assert_eq!(fast, [10., 20., 30., 40.]);
    }

    #[test]
    fn scalar_left_is_not_commuted() {
        // scalar - tensor must compute s - x, not x - s
        let a = [10.0f32];
        let b = [1., 2., 3., 4.];
        let mut out = [0.0f32; 4];
        let mut idx = Vec::new();
        binary_out(BinKind::Sub, &BinMode::ScalarLeft, &a, &b, &[4], &mut out, &mut idx);
        assert_eq!(out, [9., 8., 7., 6.]);
    }

    #[test]
    fn cumsum_out_axis0() {
        let x = [1., 10., 2., 20., 3., 30.];
        let mut out = [0.0f32; 6];
        cumsum_out(&x, &mut out, 1, 3, 2);
        assert_eq!(out, [1., 10., 3., 30., 6., 60.]);
    }

    #[test]
    fn strided_copy_transposes() {
        let x = [1., 2., 3., 4., 5., 6.];
        let mut out = [0.0f32; 6];
        let mut idx = Vec::new();
        // (2,3) -> (3,2): out dim 0 walks input columns (stride 1), out
        // dim 1 walks input rows (stride 3)
        strided_copy_out(&x, &mut out, &[3, 2], &[1, 3], &mut idx);
        assert_eq!(out, [1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn gather_out_checks_range() {
        let data = [0., 1., 10., 11., 20., 21.];
        let mut out = [0.0f32; 4];
        assert!(gather_out(&data, &[2, 0], &mut out, 2, 3).is_ok());
        assert_eq!(out, [20., 21., 0., 1.]);
        assert!(gather_out(&data, &[5], &mut out[..2], 2, 3).is_err());
    }

    fn h(v: f32) -> u16 {
        f32_to_f16(v)
    }

    #[test]
    fn generic_kernels_instantiated_at_f32_match_the_f32_kernels() {
        let x = [0.5f32, -1.25, 2.0, -3.5];
        let mut a = [0.0f32; 4];
        let mut b = [0.0f32; 4];
        unary_out(UnKind::SiLU, &x, &mut a);
        unary_out_g::<f32>(UnKind::SiLU, &x, &mut b);
        assert_eq!(a, b);
        let mut ma = [0.0f32; 4];
        let mut mb = [0.0f32; 4];
        let p = [1.0f32, 2., 3., 4., 5., 6.];
        let q = [1.0f32, 0., 0., 1., 1., 1.];
        matmul_out(&p, &q, &mut ma, 1, 2, 3, 2, 0, 0);
        matmul_out_g::<f32>(&p, &q, &mut mb, 1, 2, 3, 2, 0, 0);
        assert_eq!(ma, mb);
        let mut ca = [0.0f32; 6];
        let mut cb = [0.0f32; 6];
        let cx = [1.0f32, 10., 2., 20., 3., 30.];
        cumsum_out(&cx, &mut ca, 1, 3, 2);
        cumsum_out_g::<f32>(&cx, &mut cb, 1, 3, 2);
        assert_eq!(ca, cb);
    }

    #[test]
    fn f16_matmul_accumulates_in_f32() {
        // 1024 + 1 is not representable in f16; a dot of [1024-as-one-
        // product, then 1, then -1024] only survives if the accumulator
        // stays f32 between taps
        let a = [h(1.0), h(1.0), h(1.0)];
        let b = [h(1024.0), h(1.0), h(-1024.0)];
        let mut out = [0u16; 1];
        matmul_out_g::<u16>(&a, &b, &mut out, 1, 1, 3, 1, 0, 0);
        assert_eq!(f16_to_f32(out[0]), 1.0);
    }

    #[test]
    fn f16_cumsum_rounds_only_at_stores() {
        // acc in f32: 1024 + 0.5 + 0.5 = 1025 (exact in f16: 1024+1);
        // a rounded-accumulator scan would stick at 1024
        let x = [h(1024.0), h(0.5), h(0.5)];
        let mut out = [0u16; 3];
        cumsum_out_g::<u16>(&x, &mut out, 1, 3, 1);
        assert_eq!(f16_to_f32(out[2]), 1025.0);
        // intermediate prefix rounds at its store: 1024.5 -> 1024 (RNE)
        assert_eq!(f16_to_f32(out[1]), 1024.0);
    }

    #[test]
    fn i8_matmul_is_exact_int_accumulation() {
        // q values well inside range; result must be (sum qa*qb) * sa*sb
        let a = [10i8, -20, 30];
        let b = [1i8, 2, 3];
        let (sa, sb) = (0.5f32, 0.25f32);
        let mut out = [0.0f32; 1];
        matmul_i8_out(&a, sa, &b, sb, &mut out, 1, 1, 3, 1, 0, 0);
        let acc = (10 * 1 - 20 * 2 + 30 * 3) as f32;
        assert_eq!(out[0], acc * sa * sb);
    }

    #[test]
    fn requantize_round_trips_within_half_a_step() {
        let src = [0.9f32, -0.3, 0.0, 1.27];
        let mut q = [0i8; 4];
        let scale = requantize_i8(&src, &mut q);
        assert_eq!(scale, 1.27 / 127.0);
        let mut back = [0.0f32; 4];
        dequantize_i8_out(&q, scale, &mut back);
        for (a, b) in back.iter().zip(&src) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn quantize_f16_kernel_matches_scalar_conversion() {
        let x = [0.1f32, -2.5, 65504.0, 1e-9];
        let mut out = [0u16; 4];
        quantize_f16_out(&x, &mut out);
        for (o, &v) in out.iter().zip(&x) {
            assert_eq!(*o, f32_to_f16(v));
        }
        let mut wide = [0.0f32; 4];
        dequantize_f16_out(&out, &mut wide);
        for (w, o) in wide.iter().zip(&out) {
            assert_eq!(*w, f16_to_f32(*o));
        }
    }
}
