//! Out-buffer operator kernels for the planned executor.
//!
//! Every kernel writes into a caller-provided slice (an arena slot), so
//! steady-state execution performs no heap allocation. Loop structures
//! deliberately mirror the reference evaluator in [`super::naive`]
//! operation-for-operation, so planned and naive execution agree
//! *bitwise* — the differential suite in `tests/exec_differential.rs`
//! holds them to that.

use crate::graph::op::{BinKind, UnKind};
use crate::graph::tensor::{amax_abs, dequantize_i8_one, i8_scale, quantize_i8_one};
use crate::plu::{self, PluTable};
use crate::util::f16::{f16_to_f32, f32_to_f16};

use super::pool::parallel_chunks_mut;

// --- intra-op threading thresholds ----------------------------------------------
//
// The `*_mt` kernel variants split one large node across scoped worker
// threads. Chunk boundaries depend on the node's shape and a fixed grain
// only — NEVER on the worker count — and every chunk is a disjoint
// output region computed with the serial kernel's exact per-element
// order, so results are bitwise identical at any worker count by
// construction. The thresholds are sized so per-decode-step nodes stay
// serial (no spawn overhead on the latency path) while prefill-scale
// nodes parallelize.

/// Below this many flops (2·batch·m·k·n) a GEMM never splits.
pub const INTRA_GEMM_MIN_FLOPS: usize = 1 << 21;
/// Flop grain of one intra-op GEMM row chunk.
const INTRA_GEMM_GRAIN_FLOPS: usize = 1 << 19;
/// Below this many elements an elementwise/scan/norm kernel never splits.
pub const INTRA_ELEM_MIN: usize = 1 << 15;
/// Element grain of one elementwise/scan/norm chunk.
pub const INTRA_ELEM_GRAIN: usize = 1 << 14;

/// Scalar unary application — shared by the naive evaluator, the planned
/// unary kernel, and fused-chain stages (identity of results by
/// construction).
#[inline]
pub fn apply_unary(kind: UnKind, v: f32) -> f32 {
    match kind {
        UnKind::Neg => -v,
        UnKind::Exp => v.exp(),
        UnKind::Log => v.ln(),
        UnKind::Sqrt => v.sqrt(),
        UnKind::Abs => v.abs(),
        UnKind::Recip => 1.0 / v,
        UnKind::Relu => v.max(0.0),
        UnKind::Sigmoid => plu::sigmoid_f32(v),
        UnKind::SiLU => v * plu::sigmoid_f32(v),
        UnKind::Softplus => plu::softplus_f32(v),
        UnKind::Tanh => v.tanh(),
    }
}

/// Scalar binary application — shared like [`apply_unary`].
#[inline]
pub fn apply_binary(kind: BinKind, x: f32, y: f32) -> f32 {
    match kind {
        BinKind::Add => x + y,
        BinKind::Sub => x - y,
        BinKind::Mul => x * y,
        BinKind::Div => x / y,
        BinKind::Max => x.max(y),
    }
}

// --- storage element types ------------------------------------------------------

/// A storage element the dtype-generic kernels load/store through: every
/// value widens to f32 for arithmetic and narrows on store. `f32` is the
/// identity instance (the generic loops then compile to the plain f32
/// loops), `u16` holds raw IEEE-754 half bits.
pub trait Elem: Copy {
    fn to_f32(self) -> f32;
    fn from_f32(v: f32) -> Self;
}

impl Elem for f32 {
    #[inline]
    fn to_f32(self) -> f32 {
        self
    }
    #[inline]
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// Raw IEEE-754 half bits (the `Data::F16` payload type).
impl Elem for u16 {
    #[inline]
    fn to_f32(self) -> f32 {
        f16_to_f32(self)
    }
    #[inline]
    fn from_f32(v: f32) -> Self {
        f32_to_f16(v)
    }
}

// --- argument views -------------------------------------------------------------

/// Borrowed, dtype-tagged tensor payload.
#[derive(Clone, Copy)]
pub enum DataRef<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    /// Raw half bits.
    F16(&'a [u16]),
    /// Quantized values + their per-tensor symmetric scale.
    I8(&'a [i8], f32),
}

/// Borrowed tensor: shape + payload. What planned kernels consume.
#[derive(Clone, Copy)]
pub struct View<'a> {
    pub shape: &'a [usize],
    pub data: DataRef<'a>,
}

impl<'a> View<'a> {
    pub fn f32(&self) -> &'a [f32] {
        match self.data {
            DataRef::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn i32(&self) -> &'a [i32] {
        match self.data {
            DataRef::I32(v) => v,
            _ => panic!("expected i32 tensor"),
        }
    }

    pub fn f16(&self) -> &'a [u16] {
        match self.data {
            DataRef::F16(v) => v,
            _ => panic!("expected f16 tensor"),
        }
    }

    pub fn i8(&self) -> (&'a [i8], f32) {
        match self.data {
            DataRef::I8(v, s) => (v, s),
            _ => panic!("expected i8 tensor"),
        }
    }
}

// --- elementwise ----------------------------------------------------------------

/// Precomputed broadcast classification of a binary op (compile-time).
#[derive(Clone, Debug)]
pub enum BinMode {
    /// Both operands already have the output shape.
    Elementwise,
    /// `tensor op scalar` — right operand has one element.
    ScalarRight,
    /// `scalar op tensor` — left operand has one element.
    ScalarLeft,
    /// General broadcast: per-output-dim input strides (0 on broadcast
    /// dims), precomputed at plan time.
    Strided { sa: Vec<usize>, sb: Vec<usize> },
}

/// Per-output-dim strides of a broadcast input: 0 where the input dim is
/// 1 (or missing), the row-major stride otherwise. Matches the reference
/// evaluator's `bcast_index` arithmetic exactly.
pub fn bcast_strides(out_shape: &[usize], in_shape: &[usize]) -> Vec<usize> {
    let st = crate::graph::tensor::strides(in_shape);
    let off = out_shape.len() - in_shape.len();
    let mut r = vec![0usize; out_shape.len()];
    for (d, &s) in in_shape.iter().enumerate() {
        r[off + d] = if s == 1 { 0 } else { st[d] };
    }
    r
}

/// The f32 binary kernel is the `Elem`-generic one at its identity
/// instance (`to_f32`/`from_f32` compile away), so the two can never
/// drift apart.
pub fn binary_out(
    kind: BinKind,
    mode: &BinMode,
    a: &[f32],
    b: &[f32],
    out_shape: &[usize],
    out: &mut [f32],
    idx: &mut Vec<usize>,
) {
    binary_out_g::<f32>(kind, mode, a, b, out_shape, out, idx);
}

pub fn unary_out(kind: UnKind, x: &[f32], out: &mut [f32]) {
    unary_out_g::<f32>(kind, x, out);
}

pub fn plu_out(table: &PluTable, x: &[f32], out: &mut [f32]) {
    table.eval_slice(x, out);
}

// --- matmul ---------------------------------------------------------------------
//
// The GEMM core is one register-tiled f32 micro-kernel shared by the
// f32, f16-storage, and (structurally) i8 paths. An MR x NR tile holds
// one accumulator per output element in registers for the whole k loop,
// so the inner j-lane loop autovectorizes and each loaded B row is
// reused across MR A rows. Every output element is still accumulated
// k-ascending into a single f32 (or i32) accumulator with zero-valued A
// entries skipped — the exact value sequence of [`matmul_ref`] — so the
// blocked kernels stay bitwise identical to the scalar reference and the
// naive evaluator (which routes through [`matmul_out`] itself).

/// Register-tile height: one loaded B row is reused across this many
/// A rows.
const GEMM_MR: usize = 4;
/// Register-tile width: the j-lane block the inner loop vectorizes over.
const GEMM_NR: usize = 16;

/// Scalar reference GEMM — the pre-blocking loop shape, kept as the
/// comparison point for the differential suite's ULP tier and the kernel
/// microbenches. The blocked kernels reproduce its per-element
/// accumulation order exactly (k-ascending, one accumulator per output
/// element, exact-zero A entries skipped), so today they match it
/// bitwise; the ULP tier is the contract that stays checkable if the
/// blocking ever reassociates.
#[allow(clippy::too_many_arguments)]
pub fn matmul_ref(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a_step: usize,
    b_step: usize,
) {
    out.fill(0.0);
    for bi in 0..batch {
        let ao = bi * a_step;
        let bo = bi * b_step;
        let oo = bi * m * n;
        for i in 0..m {
            for kk in 0..k {
                let av_ik = a[ao + i * k + kk];
                if av_ik == 0.0 {
                    continue;
                }
                let brow = bo + kk * n;
                let orow = oo + i * n;
                for j in 0..n {
                    out[orow + j] += av_ik * b[brow + j];
                }
            }
        }
    }
}

/// Rows `[i0, i1)` of one batch slice of the `(m, k) x (k, n)` product,
/// written to `out_rows[(i - i0) * n + j]` (the caller passes the
/// sub-slice holding exactly those rows). `ao`/`bo` are the operands'
/// batch-slice element offsets.
#[allow(clippy::too_many_arguments)]
fn matmul_panel<T: Elem>(
    a: &[f32],
    b: &[f32],
    out_rows: &mut [T],
    ao: usize,
    bo: usize,
    i0: usize,
    i1: usize,
    k: usize,
    n: usize,
) {
    let mut i = i0;
    while i < i1 {
        let rows = GEMM_MR.min(i1 - i);
        let mut j = 0;
        while j < n {
            let jw = GEMM_NR.min(n - j);
            let mut acc = [[0.0f32; GEMM_NR]; GEMM_MR];
            for kk in 0..k {
                let brow = &b[bo + kk * n + j..bo + kk * n + j + jw];
                for (r, acc_r) in acc.iter_mut().enumerate().take(rows) {
                    // zero-skip: exact zeros (tril masks, ZVC-style
                    // sparsity) contribute no adds — matching the
                    // reference even when B holds inf/NaN
                    let av = a[ao + (i + r) * k + kk];
                    if av == 0.0 {
                        continue;
                    }
                    for (l, &bv) in brow.iter().enumerate() {
                        acc_r[l] += av * bv;
                    }
                }
            }
            for (r, acc_r) in acc.iter().enumerate().take(rows) {
                let orow = (i - i0 + r) * n + j;
                for (o, &v) in out_rows[orow..orow + jw].iter_mut().zip(acc_r.iter()) {
                    *o = T::from_f32(v);
                }
            }
            j += jw;
        }
        i += rows;
    }
}

/// Rows `[r0, r0 + rows)` of the flat `(batch * m, n)` output, spanning
/// batch boundaries; `chunk` holds exactly those rows.
#[allow(clippy::too_many_arguments)]
fn matmul_rows<T: Elem>(
    a: &[f32],
    b: &[f32],
    chunk: &mut [T],
    r0: usize,
    rows: usize,
    m: usize,
    k: usize,
    n: usize,
    a_step: usize,
    b_step: usize,
) {
    let mut done = 0;
    while done < rows {
        let r = r0 + done;
        let bi = r / m;
        let i_local = r % m;
        let take = (m - i_local).min(rows - done);
        matmul_panel(
            a,
            b,
            &mut chunk[done * n..(done + take) * n],
            bi * a_step,
            bi * b_step,
            i_local,
            i_local + take,
            k,
            n,
        );
        done += take;
    }
}

/// Row grain for intra-op GEMM splitting: sized by per-row flops so
/// chunk boundaries depend on the shape only (never the worker count),
/// rounded to the register-tile height.
fn gemm_grain_rows(k: usize, n: usize) -> usize {
    let per_row = (2 * k * n).max(1);
    (INTRA_GEMM_GRAIN_FLOPS / per_row)
        .max(GEMM_MR)
        .next_multiple_of(GEMM_MR)
}

/// Batched blocked matmul. `a_step`/`b_step` are the per-batch element
/// offsets (0 when the operand is not batched). The output needs no
/// pre-zeroing: tile accumulators start at zero and every element is
/// stored exactly once.
#[allow(clippy::too_many_arguments)]
pub fn matmul_out(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a_step: usize,
    b_step: usize,
) {
    for bi in 0..batch {
        matmul_panel(
            a,
            b,
            &mut out[bi * m * n..(bi + 1) * m * n],
            bi * a_step,
            bi * b_step,
            0,
            m,
            k,
            n,
        );
    }
}

/// [`matmul_out`] split across `workers` intra-op threads by row panels.
#[allow(clippy::too_many_arguments)]
pub fn matmul_out_mt(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a_step: usize,
    b_step: usize,
    workers: usize,
) {
    if workers <= 1 || 2 * batch * m * k * n < INTRA_GEMM_MIN_FLOPS {
        matmul_out(a, b, out, batch, m, k, n, a_step, b_step);
        return;
    }
    let grain = gemm_grain_rows(k, n);
    parallel_chunks_mut(out, grain * n, workers, |off, chunk| {
        matmul_rows(a, b, chunk, off / n, chunk.len() / n, m, k, n, a_step, b_step);
    });
}

// --- scans / reductions ---------------------------------------------------------

/// Delegates to the generic scan (identical f32 addition sequence: the
/// running accumulator IS the previously stored element at f32).
pub fn cumsum_out(x: &[f32], out: &mut [f32], outer: usize, n_axis: usize, inner: usize) {
    cumsum_out_g::<f32>(x, out, outer, n_axis, inner);
}

pub fn reduce_sum_out(
    x: &[f32],
    out: &mut [f32],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    out.fill(0.0);
    for o in 0..outer {
        for j in 0..n_axis {
            let base = (o * n_axis + j) * inner;
            let obase = o * inner;
            for i in 0..inner {
                out[obase + i] += x[base + i];
            }
        }
    }
}

/// [`cumsum_out_g`] split across intra-op workers by outer slabs (each
/// scan runs along the axis inside one slab, so slabs are independent).
pub fn cumsum_out_mt<T: Elem>(
    x: &[T],
    out: &mut [T],
    outer: usize,
    n_axis: usize,
    inner: usize,
    workers: usize,
) {
    let slab = n_axis * inner;
    if workers <= 1 || out.len() < INTRA_ELEM_MIN || slab == 0 {
        cumsum_out_g(x, out, outer, n_axis, inner);
        return;
    }
    let grain = (INTRA_ELEM_GRAIN / slab).max(1);
    parallel_chunks_mut(out, grain * slab, workers, |off, chunk| {
        cumsum_out_g(&x[off..off + chunk.len()], chunk, chunk.len() / slab, n_axis, inner);
    });
}

/// [`reduce_sum_out_g`] split across intra-op workers by outer slabs.
pub fn reduce_sum_out_mt<T: Elem>(
    x: &[T],
    out: &mut [T],
    outer: usize,
    n_axis: usize,
    inner: usize,
    workers: usize,
) {
    let _ = outer;
    if workers <= 1 || x.len() < INTRA_ELEM_MIN || inner == 0 || n_axis == 0 {
        reduce_sum_out_g(x, out, outer, n_axis, inner);
        return;
    }
    let grain = (INTRA_ELEM_GRAIN / (n_axis * inner)).max(1);
    parallel_chunks_mut(out, grain * inner, workers, |off, chunk| {
        let o0 = off / inner;
        let co = chunk.len() / inner;
        reduce_sum_out_g(
            &x[o0 * n_axis * inner..(o0 + co) * n_axis * inner],
            chunk,
            co,
            n_axis,
            inner,
        );
    });
}

// --- fused Binary -> ReduceSum reduction epilogue -------------------------------
//
// Accumulates `binary(a, b)` straight into the reduction output without
// materializing the (often much larger) binary intermediate in the
// arena. Loop order and per-element arithmetic mirror the unfused
// `binary_out_g` store followed by `reduce_sum_out` / `reduce_sum_out_g`
// exactly — each output element sums axis-ascending rounded-per-stage
// stage values — so fusing is bitwise neutral.

/// Advance a row-major odometer over `shape` one step, updating both
/// operands' strided offsets.
#[inline]
fn bump2(
    idx: &mut [usize],
    shape: &[usize],
    sa: &[usize],
    sb: &[usize],
    ia: &mut usize,
    ib: &mut usize,
) {
    for d in (0..shape.len()).rev() {
        idx[d] += 1;
        if idx[d] < shape[d] {
            *ia += sa[d];
            *ib += sb[d];
            return;
        }
        idx[d] = 0;
        *ia -= sa[d] * (shape[d] - 1);
        *ib -= sb[d] * (shape[d] - 1);
    }
}

/// f32 fused binary+reduce: `out[o, i] = sum_j binary(a, b)[o, j, i]`
/// where `shape` is the binary's (virtual) output shape, reduced along
/// `axis`, and `sa`/`sb` are the operands' broadcast strides over it.
#[allow(clippy::too_many_arguments)]
pub fn binary_reduce_sum_out(
    kind: BinKind,
    a: &[f32],
    b: &[f32],
    sa: &[usize],
    sb: &[usize],
    shape: &[usize],
    axis: usize,
    out: &mut [f32],
    idx: &mut Vec<usize>,
) {
    let n_axis = shape[axis];
    let inner: usize = shape[axis + 1..].iter().product();
    let outer: usize = shape[..axis].iter().product();
    idx.clear();
    idx.resize(shape.len(), 0);
    out.fill(0.0);
    let (mut ia, mut ib) = (0usize, 0usize);
    for o in 0..outer {
        let obase = o * inner;
        for _ in 0..n_axis {
            for i in 0..inner {
                out[obase + i] += apply_binary(kind, a[ia], b[ib]);
                bump2(idx, shape, sa, sb, &mut ia, &mut ib);
            }
        }
    }
}

/// Storage-generic fused binary+reduce: each virtual stage value rounds
/// to the storage type (as the unfused binary store would) and the
/// reduction accumulates those widened values in f32, rounding once at
/// the final store (as `reduce_sum_out_g` would).
#[allow(clippy::too_many_arguments)]
pub fn binary_reduce_sum_out_g<T: Elem>(
    kind: BinKind,
    a: &[T],
    b: &[T],
    sa: &[usize],
    sb: &[usize],
    shape: &[usize],
    axis: usize,
    out: &mut [T],
    idx: &mut Vec<usize>,
) {
    let n_axis = shape[axis];
    let inner: usize = shape[axis + 1..].iter().product();
    let outer: usize = shape[..axis].iter().product();
    idx.clear();
    idx.resize(shape.len(), 0);
    let mut row = vec![0.0f32; inner];
    let (mut ia, mut ib) = (0usize, 0usize);
    for o in 0..outer {
        row.fill(0.0);
        for _ in 0..n_axis {
            for r in row.iter_mut() {
                let v = apply_binary(kind, a[ia].to_f32(), b[ib].to_f32());
                *r += T::from_f32(v).to_f32();
                bump2(idx, shape, sa, sb, &mut ia, &mut ib);
            }
        }
        let obase = o * inner;
        for (o_el, &r) in out[obase..obase + inner].iter_mut().zip(row.iter()) {
            *o_el = T::from_f32(r);
        }
    }
}

// --- gather / conv / norms ------------------------------------------------------

pub fn gather_out<T: Copy>(
    data: &[T],
    indices: &[i32],
    out: &mut [T],
    row: usize,
    vocab: usize,
) -> Result<(), String> {
    for (r, &i) in indices.iter().enumerate() {
        if i < 0 || i >= vocab as i32 {
            return Err(format!("gather index {i} out of range 0..{vocab}"));
        }
        out[r * row..(r + 1) * row]
            .copy_from_slice(&data[i as usize * row..(i as usize + 1) * row]);
    }
    Ok(())
}

pub fn conv1d_out(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    batch: usize,
    t: usize,
    c: usize,
    k: usize,
) {
    conv1d_out_g::<f32>(x, w, b, out, batch, t, c, k);
}

/// [`conv1d_out_g`] split across intra-op workers by (batch, t) rows —
/// taps read backward into the shared input, writes are per-row disjoint.
#[allow(clippy::too_many_arguments)]
pub fn conv1d_out_mt<T: Elem>(
    x: &[T],
    w: &[T],
    b: &[T],
    out: &mut [T],
    batch: usize,
    t: usize,
    c: usize,
    k: usize,
    workers: usize,
) {
    if workers <= 1 || out.len() < INTRA_ELEM_MIN || c == 0 {
        conv1d_out_g(x, w, b, out, batch, t, c, k);
        return;
    }
    let _ = batch;
    let grain = (INTRA_ELEM_GRAIN / c).max(1);
    parallel_chunks_mut(out, grain * c, workers, |off, chunk| {
        let r0 = off / c;
        for (li, orow) in chunk.chunks_mut(c).enumerate() {
            let r = r0 + li;
            let (bi, ti) = (r / t, r % t);
            conv1d_row(&x[bi * t * c..(bi + 1) * t * c], w, b, orow, ti, c, k);
        }
    });
}

pub fn rmsnorm_out(x: &[f32], w: &[f32], out: &mut [f32], rows: usize, d: usize, eps: f32) {
    rmsnorm_out_g::<f32>(x, w, out, rows, d, eps);
}

/// [`rmsnorm_out_g`] split across intra-op workers by rows.
pub fn rmsnorm_out_mt<T: Elem>(
    x: &[T],
    w: &[T],
    out: &mut [T],
    rows: usize,
    d: usize,
    eps: f32,
    workers: usize,
) {
    if workers <= 1 || out.len() < INTRA_ELEM_MIN || d == 0 {
        rmsnorm_out_g(x, w, out, rows, d, eps);
        return;
    }
    let _ = rows;
    let grain = (INTRA_ELEM_GRAIN / d).max(1);
    parallel_chunks_mut(out, grain * d, workers, |off, chunk| {
        rmsnorm_out_g(&x[off..off + chunk.len()], w, chunk, chunk.len() / d, d, eps);
    });
}

/// [`softmax_out_g`] split across intra-op workers by outer slabs.
pub fn softmax_out_mt<T: Elem>(
    x: &[T],
    out: &mut [T],
    outer: usize,
    n_axis: usize,
    inner: usize,
    workers: usize,
) {
    let slab = n_axis * inner;
    if workers <= 1 || out.len() < INTRA_ELEM_MIN || slab == 0 {
        softmax_out_g(x, out, outer, n_axis, inner);
        return;
    }
    let _ = outer;
    let grain = (INTRA_ELEM_GRAIN / slab).max(1);
    parallel_chunks_mut(out, grain * slab, workers, |off, chunk| {
        softmax_out_g(&x[off..off + chunk.len()], chunk, chunk.len() / slab, n_axis, inner);
    });
}

/// [`unary_out_g`] split across intra-op workers.
pub fn unary_out_mt<T: Elem>(kind: UnKind, x: &[T], out: &mut [T], workers: usize) {
    if workers <= 1 || out.len() < INTRA_ELEM_MIN {
        unary_out_g(kind, x, out);
        return;
    }
    parallel_chunks_mut(out, INTRA_ELEM_GRAIN, workers, |off, chunk| {
        unary_out_g(kind, &x[off..off + chunk.len()], chunk);
    });
}

/// [`plu_out_g`] split across intra-op workers.
pub fn plu_out_mt<T: Elem>(table: &PluTable, x: &[T], out: &mut [T], workers: usize) {
    if workers <= 1 || out.len() < INTRA_ELEM_MIN {
        plu_out_g(table, x, out);
        return;
    }
    parallel_chunks_mut(out, INTRA_ELEM_GRAIN, workers, |off, chunk| {
        plu_out_g(table, &x[off..off + chunk.len()], chunk);
    });
}

/// [`binary_out_g`] split across intra-op workers. The Elementwise and
/// scalar modes chunk trivially (per-element independent); the general
/// strided mode stays serial (its odometer is a running state).
#[allow(clippy::too_many_arguments)]
pub fn binary_out_mt<T: Elem>(
    kind: BinKind,
    mode: &BinMode,
    a: &[T],
    b: &[T],
    out_shape: &[usize],
    out: &mut [T],
    idx: &mut Vec<usize>,
    workers: usize,
) {
    if workers <= 1 || out.len() < INTRA_ELEM_MIN {
        binary_out_g(kind, mode, a, b, out_shape, out, idx);
        return;
    }
    match mode {
        BinMode::Elementwise => {
            parallel_chunks_mut(out, INTRA_ELEM_GRAIN, workers, |off, chunk| {
                let mut scratch = Vec::new();
                binary_out_g(
                    kind,
                    &BinMode::Elementwise,
                    &a[off..off + chunk.len()],
                    &b[off..off + chunk.len()],
                    out_shape,
                    chunk,
                    &mut scratch,
                );
            });
        }
        BinMode::ScalarRight => {
            parallel_chunks_mut(out, INTRA_ELEM_GRAIN, workers, |off, chunk| {
                let mut scratch = Vec::new();
                binary_out_g(
                    kind,
                    &BinMode::ScalarRight,
                    &a[off..off + chunk.len()],
                    b,
                    out_shape,
                    chunk,
                    &mut scratch,
                );
            });
        }
        BinMode::ScalarLeft => {
            parallel_chunks_mut(out, INTRA_ELEM_GRAIN, workers, |off, chunk| {
                let mut scratch = Vec::new();
                binary_out_g(
                    kind,
                    &BinMode::ScalarLeft,
                    a,
                    &b[off..off + chunk.len()],
                    out_shape,
                    chunk,
                    &mut scratch,
                );
            });
        }
        BinMode::Strided { .. } => binary_out_g(kind, mode, a, b, out_shape, out, idx),
    }
}

pub fn softmax_out(x: &[f32], out: &mut [f32], outer: usize, n_axis: usize, inner: usize) {
    for o in 0..outer {
        for i in 0..inner {
            let at = |j: usize| (o * n_axis + j) * inner + i;
            let mut mx = f32::NEG_INFINITY;
            for j in 0..n_axis {
                mx = mx.max(x[at(j)]);
            }
            let mut z = 0.0;
            for j in 0..n_axis {
                let e = (x[at(j)] - mx).exp();
                out[at(j)] = e;
                z += e;
            }
            for j in 0..n_axis {
                out[at(j)] /= z;
            }
        }
    }
}

// --- layout ---------------------------------------------------------------------

pub fn slice_out<T: Copy>(
    x: &[T],
    out: &mut [T],
    outer: usize,
    n_axis: usize,
    inner: usize,
    start: usize,
    len: usize,
) {
    for o in 0..outer {
        let src = (o * n_axis + start) * inner;
        let dst = o * len * inner;
        out[dst..dst + len * inner].copy_from_slice(&x[src..src + len * inner]);
    }
}

/// Row-major copy (reshape).
pub fn copy_out<T: Copy>(x: &[T], out: &mut [T]) {
    out.copy_from_slice(x);
}

/// Strided gather copy: walks the output row-major, reading the input at
/// the precomputed per-output-dim strides (transpose and broadcast).
pub fn strided_copy_out<T: Copy>(
    x: &[T],
    out: &mut [T],
    out_shape: &[usize],
    strides: &[usize],
    idx: &mut Vec<usize>,
) {
    idx.clear();
    idx.resize(out_shape.len(), 0);
    for o in out.iter_mut() {
        let mut lin = 0;
        for (d, &i) in idx.iter().enumerate() {
            lin += i * strides[d];
        }
        *o = x[lin];
        for d in (0..out_shape.len()).rev() {
            idx[d] += 1;
            if idx[d] < out_shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

// --- dtype-generic (f16) kernels ------------------------------------------------
//
// Mirrors of the f32 kernels above over any `Elem` storage type: load →
// widen to f32 → identical arithmetic → narrow on store. Loop structure
// and evaluation order match the f32 kernels exactly, so the naive
// walker's widen-evaluate-narrow reference produces bitwise-identical
// halves (all rounding happens at stores, never inside accumulators).

pub fn unary_out_g<T: Elem>(kind: UnKind, x: &[T], out: &mut [T]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = T::from_f32(apply_unary(kind, v.to_f32()));
    }
}

pub fn plu_out_g<T: Elem>(table: &PluTable, x: &[T], out: &mut [T]) {
    // eval_premul is the same inner evaluation eval_slice uses, so the
    // f16 PLU picks identical segments to the f32 path for equal inputs
    let inv_step = 1.0 / table.step();
    let kmax = table.num_segments() as i64 - 1;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = T::from_f32(table.eval_premul(v.to_f32(), inv_step, kmax));
    }
}

pub fn binary_out_g<T: Elem>(
    kind: BinKind,
    mode: &BinMode,
    a: &[T],
    b: &[T],
    out_shape: &[usize],
    out: &mut [T],
    idx: &mut Vec<usize>,
) {
    match mode {
        BinMode::Elementwise => {
            for i in 0..out.len() {
                out[i] = T::from_f32(apply_binary(kind, a[i].to_f32(), b[i].to_f32()));
            }
        }
        BinMode::ScalarRight => {
            let s = b[0].to_f32();
            for i in 0..out.len() {
                out[i] = T::from_f32(apply_binary(kind, a[i].to_f32(), s));
            }
        }
        BinMode::ScalarLeft => {
            let s = a[0].to_f32();
            for i in 0..out.len() {
                out[i] = T::from_f32(apply_binary(kind, s, b[i].to_f32()));
            }
        }
        BinMode::Strided { sa, sb } => {
            idx.clear();
            idx.resize(out_shape.len(), 0);
            for o in out.iter_mut() {
                let mut ia = 0;
                let mut ib = 0;
                for (d, &i) in idx.iter().enumerate() {
                    ia += i * sa[d];
                    ib += i * sb[d];
                }
                *o = T::from_f32(apply_binary(kind, a[ia].to_f32(), b[ib].to_f32()));
                for d in (0..idx.len()).rev() {
                    idx[d] += 1;
                    if idx[d] < out_shape[d] {
                        break;
                    }
                    idx[d] = 0;
                }
            }
        }
    }
}

thread_local! {
    // widened-operand scratch for the generic GEMM: narrow storage is
    // widened to f32 once per call instead of once per k-step inside the
    // inner loop, and the buffers are reused across calls on this thread
    static WIDEN_A: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
    static WIDEN_B: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

fn widen_into<T: Elem>(src: &[T], dst: &mut Vec<f32>) {
    dst.clear();
    dst.extend(src.iter().map(|v| v.to_f32()));
}

/// Batched matmul with f32 accumulation, storage-rounded output. Same
/// blocked core as [`matmul_out`] (each output element accumulates in
/// one f32 register, k-ascending), so the value sequence is identical
/// to the scalar reference widened per element.
#[allow(clippy::too_many_arguments)]
pub fn matmul_out_g<T: Elem>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a_step: usize,
    b_step: usize,
) {
    WIDEN_A.with(|wa| {
        WIDEN_B.with(|wb| {
            let (mut wa, mut wb) = (wa.borrow_mut(), wb.borrow_mut());
            widen_into(a, &mut wa);
            widen_into(b, &mut wb);
            for bi in 0..batch {
                matmul_panel(
                    &wa,
                    &wb,
                    &mut out[bi * m * n..(bi + 1) * m * n],
                    bi * a_step,
                    bi * b_step,
                    0,
                    m,
                    k,
                    n,
                );
            }
        })
    });
}

/// [`matmul_out_g`] split across intra-op workers by output row panels.
#[allow(clippy::too_many_arguments)]
pub fn matmul_out_g_mt<T: Elem>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a_step: usize,
    b_step: usize,
    workers: usize,
) {
    if workers <= 1 || 2 * batch * m * k * n < INTRA_GEMM_MIN_FLOPS {
        matmul_out_g(a, b, out, batch, m, k, n, a_step, b_step);
        return;
    }
    // owned widened copies: worker closures borrow them immutably
    let wa: Vec<f32> = a.iter().map(|v| v.to_f32()).collect();
    let wb: Vec<f32> = b.iter().map(|v| v.to_f32()).collect();
    let grain = gemm_grain_rows(k, n);
    parallel_chunks_mut(out, grain * n, workers, |off, chunk| {
        matmul_rows(&wa, &wb, chunk, off / n, chunk.len() / n, m, k, n, a_step, b_step);
    });
}

/// CumSum with an f32 running accumulator; each prefix rounds at store
/// only, so precision does not decay along the scan. The first element
/// is a copy and later sums are `x[j] + acc` — the exact value sequence
/// of the in-place reference scan (`out[j] += out[j-1]`), including
/// signed zeros and NaN-payload propagation order.
pub fn cumsum_out_g<T: Elem>(
    x: &[T],
    out: &mut [T],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    if n_axis == 0 {
        return;
    }
    for o in 0..outer {
        for i in 0..inner {
            let base = o * n_axis * inner + i;
            let mut acc = x[base].to_f32();
            out[base] = T::from_f32(acc);
            for j in 1..n_axis {
                acc = x[base + j * inner].to_f32() + acc;
                out[base + j * inner] = T::from_f32(acc);
            }
        }
    }
}

pub fn reduce_sum_out_g<T: Elem>(
    x: &[T],
    out: &mut [T],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    // accumulate the whole output in f32, store rounded once at the end
    // (mirrors the f32 kernel's accumulation order exactly)
    for o in 0..outer {
        for i in 0..inner {
            let mut acc = 0.0f32;
            for j in 0..n_axis {
                acc += x[(o * n_axis + j) * inner + i].to_f32();
            }
            out[o * inner + i] = T::from_f32(acc);
        }
    }
}

#[inline]
fn conv1d_row<T: Elem>(xb: &[T], w: &[T], b: &[T], orow: &mut [T], ti: usize, c: usize, k: usize) {
    for (ci, o) in orow.iter_mut().enumerate() {
        let mut acc = b[ci].to_f32();
        for ki in 0..k {
            // causal: tap ki reads position ti - (k - 1 - ki)
            let src = ti as isize - (k - 1 - ki) as isize;
            if src >= 0 {
                acc += w[ki * c + ci].to_f32() * xb[src as usize * c + ci].to_f32();
            }
        }
        *o = T::from_f32(acc);
    }
}

#[allow(clippy::too_many_arguments)]
pub fn conv1d_out_g<T: Elem>(
    x: &[T],
    w: &[T],
    b: &[T],
    out: &mut [T],
    batch: usize,
    t: usize,
    c: usize,
    k: usize,
) {
    for bi in 0..batch {
        let xb = &x[bi * t * c..(bi + 1) * t * c];
        for (ti, orow) in out[bi * t * c..(bi + 1) * t * c].chunks_mut(c).enumerate() {
            conv1d_row(xb, w, b, orow, ti, c, k);
        }
    }
}

pub fn rmsnorm_out_g<T: Elem>(
    x: &[T],
    w: &[T],
    out: &mut [T],
    rows: usize,
    d: usize,
    eps: f32,
) {
    for r in 0..rows {
        let mut ms = 0.0f32;
        for i in 0..d {
            let v = x[r * d + i].to_f32();
            ms += v * v;
        }
        let ms = ms / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for i in 0..d {
            out[r * d + i] = T::from_f32(x[r * d + i].to_f32() * inv * w[i].to_f32());
        }
    }
}

pub fn softmax_out_g<T: Elem>(
    x: &[T],
    out: &mut [T],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    // two passes recompute exp instead of staging rounded intermediates,
    // so every stored value is round(e_j / z) with e_j and z in f32 —
    // identical to narrowing an f32 softmax after the fact
    for o in 0..outer {
        for i in 0..inner {
            let at = |j: usize| (o * n_axis + j) * inner + i;
            let mut mx = f32::NEG_INFINITY;
            for j in 0..n_axis {
                mx = mx.max(x[at(j)].to_f32());
            }
            let mut z = 0.0f32;
            for j in 0..n_axis {
                z += (x[at(j)].to_f32() - mx).exp();
            }
            for j in 0..n_axis {
                out[at(j)] = T::from_f32((x[at(j)].to_f32() - mx).exp() / z);
            }
        }
    }
}

// --- precision conversion kernels ----------------------------------------------

/// f32 -> f16, round-to-nearest-even per element.
pub fn quantize_f16_out(x: &[f32], out: &mut [u16]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = f32_to_f16(v);
    }
}

/// f32 -> i8 with a dynamically computed per-tensor symmetric scale.
/// Returns the scale (the caller owns where it lives: `Data::I8` for
/// tensors, the arena's per-slot scale table for planned execution).
pub fn quantize_i8_out(x: &[f32], out: &mut [i8]) -> f32 {
    requantize_i8(x, out)
}

pub fn dequantize_f16_out(x: &[u16], out: &mut [f32]) {
    for (o, &b) in out.iter_mut().zip(x) {
        *o = f16_to_f32(b);
    }
}

pub fn dequantize_i8_out(q: &[i8], scale: f32, out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(q) {
        *o = dequantize_i8_one(v, scale);
    }
}

// --- i8 kernels -----------------------------------------------------------------
//
// Elementwise / scan / reduce i8 kernels follow one shape: dequantize on
// load, run the EXACT f32 arithmetic of the reference kernels into an
// f32 scratch, then requantize the whole result with a dynamic
// per-tensor scale (`requantize_i8`). The naive walker reaches bitwise-
// identical results by construction: widen → f32 eval → same
// requantize. MatMul is the exception — it consumes i8 operands
// directly with exact i32 accumulation (the real int8-GEMM datapath).

/// Quantize `src` into `out` with a fresh per-tensor scale; returns it.
pub fn requantize_i8(src: &[f32], out: &mut [i8]) -> f32 {
    let scale = i8_scale(amax_abs(src));
    for (o, &v) in out.iter_mut().zip(src) {
        *o = quantize_i8_one(v, scale);
    }
    scale
}

// local shorthand over the ONE shared i8 mapping in `graph::tensor`
// (planned, naive, and `Tensor::to_dtype` must stay bit-identical)
#[inline]
fn deq(q: i8, scale: f32) -> f32 {
    dequantize_i8_one(q, scale)
}

/// i8 unary into an f32 staging slice (requantized by the caller).
pub fn unary_i8_into(kind: UnKind, q: &[i8], scale: f32, scratch: &mut [f32]) {
    for (o, &v) in scratch.iter_mut().zip(q) {
        *o = apply_unary(kind, deq(v, scale));
    }
}

/// i8 binary into an f32 staging slice, all broadcast modes.
#[allow(clippy::too_many_arguments)]
pub fn binary_i8_into(
    kind: BinKind,
    mode: &BinMode,
    a: &[i8],
    sa_q: f32,
    b: &[i8],
    sb_q: f32,
    out_shape: &[usize],
    scratch: &mut [f32],
    idx: &mut Vec<usize>,
) {
    match mode {
        BinMode::Elementwise => {
            for i in 0..scratch.len() {
                scratch[i] = apply_binary(kind, deq(a[i], sa_q), deq(b[i], sb_q));
            }
        }
        BinMode::ScalarRight => {
            let s = deq(b[0], sb_q);
            for i in 0..scratch.len() {
                scratch[i] = apply_binary(kind, deq(a[i], sa_q), s);
            }
        }
        BinMode::ScalarLeft => {
            let s = deq(a[0], sa_q);
            for i in 0..scratch.len() {
                scratch[i] = apply_binary(kind, s, deq(b[i], sb_q));
            }
        }
        BinMode::Strided { sa, sb } => {
            idx.clear();
            idx.resize(out_shape.len(), 0);
            for o in scratch.iter_mut() {
                let mut ia = 0;
                let mut ib = 0;
                for (d, &i) in idx.iter().enumerate() {
                    ia += i * sa[d];
                    ib += i * sb[d];
                }
                *o = apply_binary(kind, deq(a[ia], sa_q), deq(b[ib], sb_q));
                for d in (0..idx.len()).rev() {
                    idx[d] += 1;
                    if idx[d] < out_shape[d] {
                        break;
                    }
                    idx[d] = 0;
                }
            }
        }
    }
}

/// i8 cumsum into an f32 staging slice: the running accumulator stays
/// f32 end to end (the scan never requantizes mid-prefix).
pub fn cumsum_i8_into(
    q: &[i8],
    scale: f32,
    scratch: &mut [f32],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    for o in 0..outer {
        for i in 0..inner {
            let base = o * n_axis * inner + i;
            let mut acc = 0.0f32;
            for j in 0..n_axis {
                acc += deq(q[base + j * inner], scale);
                scratch[base + j * inner] = acc;
            }
        }
    }
}

/// i8 reduce-sum into an f32 staging slice (f32 accumulation).
pub fn reduce_sum_i8_into(
    q: &[i8],
    scale: f32,
    scratch: &mut [f32],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    for o in 0..outer {
        for i in 0..inner {
            let mut acc = 0.0f32;
            for j in 0..n_axis {
                acc += deq(q[(o * n_axis + j) * inner + i], scale);
            }
            scratch[o * inner + i] = acc;
        }
    }
}

/// Register-tiled i8 GEMM micro-kernel; integer accumulation is exact,
/// so blocking cannot change results. Mirrors [`matmul_panel`].
#[allow(clippy::too_many_arguments)]
fn matmul_i8_panel(
    a: &[i8],
    b: &[i8],
    out_rows: &mut [f32],
    s: f32,
    ao: usize,
    bo: usize,
    i0: usize,
    i1: usize,
    k: usize,
    n: usize,
) {
    let mut i = i0;
    while i < i1 {
        let rows = GEMM_MR.min(i1 - i);
        let mut j = 0;
        while j < n {
            let jw = GEMM_NR.min(n - j);
            let mut acc = [[0i32; GEMM_NR]; GEMM_MR];
            for kk in 0..k {
                let brow = &b[bo + kk * n + j..bo + kk * n + j + jw];
                for (r, acc_r) in acc.iter_mut().enumerate().take(rows) {
                    let av = a[ao + (i + r) * k + kk];
                    if av == 0 {
                        continue;
                    }
                    let av = i32::from(av);
                    for (l, &bv) in brow.iter().enumerate() {
                        acc_r[l] += av * i32::from(bv);
                    }
                }
            }
            for (r, acc_r) in acc.iter().enumerate().take(rows) {
                let orow = (i - i0 + r) * n + j;
                for (o, &v) in out_rows[orow..orow + jw].iter_mut().zip(acc_r.iter()) {
                    *o = v as f32 * s;
                }
            }
            j += jw;
        }
        i += rows;
    }
}

/// i8 x i8 batched matmul: exact i32 accumulation per dot product,
/// dequantized into f32 by the product of the operand scales.
#[allow(clippy::too_many_arguments)]
pub fn matmul_i8_out(
    a: &[i8],
    sa: f32,
    b: &[i8],
    sb: f32,
    out: &mut [f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a_step: usize,
    b_step: usize,
) {
    let s = sa * sb;
    for bi in 0..batch {
        matmul_i8_panel(
            a,
            b,
            &mut out[bi * m * n..(bi + 1) * m * n],
            s,
            bi * a_step,
            bi * b_step,
            0,
            m,
            k,
            n,
        );
    }
}

/// [`matmul_i8_out`] split across intra-op workers by output row panels.
/// Safe to split at any worker count: the i32 accumulation is exact.
#[allow(clippy::too_many_arguments)]
pub fn matmul_i8_out_mt(
    a: &[i8],
    sa: f32,
    b: &[i8],
    sb: f32,
    out: &mut [f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a_step: usize,
    b_step: usize,
    workers: usize,
) {
    if workers <= 1 || 2 * batch * m * k * n < INTRA_GEMM_MIN_FLOPS {
        matmul_i8_out(a, sa, b, sb, out, batch, m, k, n, a_step, b_step);
        return;
    }
    let s = sa * sb;
    let grain = gemm_grain_rows(k, n);
    parallel_chunks_mut(out, grain * n, workers, |off, chunk| {
        let (r0, rows) = (off / n, chunk.len() / n);
        let mut done = 0;
        while done < rows {
            let r = r0 + done;
            let (bi, il) = (r / m, r % m);
            let take = (m - il).min(rows - done);
            matmul_i8_panel(
                a,
                b,
                &mut chunk[done * n..(done + take) * n],
                s,
                bi * a_step,
                bi * b_step,
                il,
                il + take,
                k,
                n,
            );
            done += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_out_2d() {
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        let mut out = [0.0f32; 4];
        matmul_out(&a, &b, &mut out, 1, 2, 3, 2, 0, 0);
        assert_eq!(out, [58., 64., 139., 154.]);
    }

    #[test]
    fn binary_out_strided_matches_scalar_path() {
        // (2,2) * scalar via Strided must equal the ScalarRight fast path
        let a = [1., 2., 3., 4.];
        let b = [10.0f32];
        let mut fast = [0.0f32; 4];
        let mut slow = [0.0f32; 4];
        let mut idx = Vec::new();
        binary_out(BinKind::Mul, &BinMode::ScalarRight, &a, &b, &[2, 2], &mut fast, &mut idx);
        let mode = BinMode::Strided {
            sa: bcast_strides(&[2, 2], &[2, 2]),
            sb: bcast_strides(&[2, 2], &[]),
        };
        binary_out(BinKind::Mul, &mode, &a, &b, &[2, 2], &mut slow, &mut idx);
        assert_eq!(fast, slow);
        assert_eq!(fast, [10., 20., 30., 40.]);
    }

    #[test]
    fn scalar_left_is_not_commuted() {
        // scalar - tensor must compute s - x, not x - s
        let a = [10.0f32];
        let b = [1., 2., 3., 4.];
        let mut out = [0.0f32; 4];
        let mut idx = Vec::new();
        binary_out(BinKind::Sub, &BinMode::ScalarLeft, &a, &b, &[4], &mut out, &mut idx);
        assert_eq!(out, [9., 8., 7., 6.]);
    }

    #[test]
    fn cumsum_out_axis0() {
        let x = [1., 10., 2., 20., 3., 30.];
        let mut out = [0.0f32; 6];
        cumsum_out(&x, &mut out, 1, 3, 2);
        assert_eq!(out, [1., 10., 3., 30., 6., 60.]);
    }

    #[test]
    fn strided_copy_transposes() {
        let x = [1., 2., 3., 4., 5., 6.];
        let mut out = [0.0f32; 6];
        let mut idx = Vec::new();
        // (2,3) -> (3,2): out dim 0 walks input columns (stride 1), out
        // dim 1 walks input rows (stride 3)
        strided_copy_out(&x, &mut out, &[3, 2], &[1, 3], &mut idx);
        assert_eq!(out, [1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn gather_out_checks_range() {
        let data = [0., 1., 10., 11., 20., 21.];
        let mut out = [0.0f32; 4];
        assert!(gather_out(&data, &[2, 0], &mut out, 2, 3).is_ok());
        assert_eq!(out, [20., 21., 0., 1.]);
        assert!(gather_out(&data, &[5], &mut out[..2], 2, 3).is_err());
    }

    fn h(v: f32) -> u16 {
        f32_to_f16(v)
    }

    #[test]
    fn generic_kernels_instantiated_at_f32_match_the_f32_kernels() {
        let x = [0.5f32, -1.25, 2.0, -3.5];
        let mut a = [0.0f32; 4];
        let mut b = [0.0f32; 4];
        unary_out(UnKind::SiLU, &x, &mut a);
        unary_out_g::<f32>(UnKind::SiLU, &x, &mut b);
        assert_eq!(a, b);
        let mut ma = [0.0f32; 4];
        let mut mb = [0.0f32; 4];
        let p = [1.0f32, 2., 3., 4., 5., 6.];
        let q = [1.0f32, 0., 0., 1., 1., 1.];
        matmul_out(&p, &q, &mut ma, 1, 2, 3, 2, 0, 0);
        matmul_out_g::<f32>(&p, &q, &mut mb, 1, 2, 3, 2, 0, 0);
        assert_eq!(ma, mb);
        let mut ca = [0.0f32; 6];
        let mut cb = [0.0f32; 6];
        let cx = [1.0f32, 10., 2., 20., 3., 30.];
        cumsum_out(&cx, &mut ca, 1, 3, 2);
        cumsum_out_g::<f32>(&cx, &mut cb, 1, 3, 2);
        assert_eq!(ca, cb);
    }

    #[test]
    fn f16_matmul_accumulates_in_f32() {
        // 1024 + 1 is not representable in f16; a dot of [1024-as-one-
        // product, then 1, then -1024] only survives if the accumulator
        // stays f32 between taps
        let a = [h(1.0), h(1.0), h(1.0)];
        let b = [h(1024.0), h(1.0), h(-1024.0)];
        let mut out = [0u16; 1];
        matmul_out_g::<u16>(&a, &b, &mut out, 1, 1, 3, 1, 0, 0);
        assert_eq!(f16_to_f32(out[0]), 1.0);
    }

    #[test]
    fn f16_cumsum_rounds_only_at_stores() {
        // acc in f32: 1024 + 0.5 + 0.5 = 1025 (exact in f16: 1024+1);
        // a rounded-accumulator scan would stick at 1024
        let x = [h(1024.0), h(0.5), h(0.5)];
        let mut out = [0u16; 3];
        cumsum_out_g::<u16>(&x, &mut out, 1, 3, 1);
        assert_eq!(f16_to_f32(out[2]), 1025.0);
        // intermediate prefix rounds at its store: 1024.5 -> 1024 (RNE)
        assert_eq!(f16_to_f32(out[1]), 1024.0);
    }

    #[test]
    fn i8_matmul_is_exact_int_accumulation() {
        // q values well inside range; result must be (sum qa*qb) * sa*sb
        let a = [10i8, -20, 30];
        let b = [1i8, 2, 3];
        let (sa, sb) = (0.5f32, 0.25f32);
        let mut out = [0.0f32; 1];
        matmul_i8_out(&a, sa, &b, sb, &mut out, 1, 1, 3, 1, 0, 0);
        let acc = (10 * 1 - 20 * 2 + 30 * 3) as f32;
        assert_eq!(out[0], acc * sa * sb);
    }

    #[test]
    fn requantize_round_trips_within_half_a_step() {
        let src = [0.9f32, -0.3, 0.0, 1.27];
        let mut q = [0i8; 4];
        let scale = requantize_i8(&src, &mut q);
        assert_eq!(scale, 1.27 / 127.0);
        let mut back = [0.0f32; 4];
        dequantize_i8_out(&q, scale, &mut back);
        for (a, b) in back.iter().zip(&src) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn quantize_f16_kernel_matches_scalar_conversion() {
        let x = [0.1f32, -2.5, 65504.0, 1e-9];
        let mut out = [0u16; 4];
        quantize_f16_out(&x, &mut out);
        for (o, &v) in out.iter().zip(&x) {
            assert_eq!(*o, f32_to_f16(v));
        }
        let mut wide = [0.0f32; 4];
        dequantize_f16_out(&out, &mut wide);
        for (w, o) in wide.iter().zip(&out) {
            assert_eq!(*w, f16_to_f32(*o));
        }
    }

    fn lcg_fill(buf: &mut [f32], seed: &mut u32) {
        for v in buf.iter_mut() {
            *seed = seed.wrapping_mul(1664525).wrapping_add(1013904223);
            *v = (*seed >> 8) as f32 / (1u32 << 24) as f32 - 0.5;
        }
    }

    #[test]
    fn blocked_matmul_is_bitwise_identical_to_the_scalar_reference() {
        // ragged in every dimension (no multiple of MR/NR), broadcast B,
        // zeros sprinkled into A to exercise the skip path
        let (batch, m, k, n) = (2usize, 7, 13, 19);
        let mut seed = 7u32;
        let mut a = vec![0.0f32; batch * m * k];
        let mut b = vec![0.0f32; k * n];
        lcg_fill(&mut a, &mut seed);
        lcg_fill(&mut b, &mut seed);
        for v in a.iter_mut().step_by(5) {
            *v = 0.0;
        }
        let mut rf = vec![0.0f32; batch * m * n];
        let mut bl = vec![f32::NAN; batch * m * n];
        matmul_ref(&a, &b, &mut rf, batch, m, k, n, m * k, 0);
        matmul_out(&a, &b, &mut bl, batch, m, k, n, m * k, 0);
        assert_eq!(rf, bl);
    }

    #[test]
    fn fused_binary_reduce_sum_matches_the_unfused_pair() {
        // (2,3,4) mul a broadcast (1,3,1), reduced along the last axis
        let shape = [2usize, 3, 4];
        let mut seed = 3u32;
        let mut a = vec![0.0f32; 24];
        let mut b = vec![0.0f32; 3];
        lcg_fill(&mut a, &mut seed);
        lcg_fill(&mut b, &mut seed);
        let sa = bcast_strides(&shape, &shape);
        let sb = bcast_strides(&shape, &[1, 3, 1]);
        let mode = BinMode::Strided { sa: sa.clone(), sb: sb.clone() };
        let mut idx = Vec::new();
        let mut prod = vec![0.0f32; 24];
        binary_out(BinKind::Mul, &mode, &a, &b, &shape, &mut prod, &mut idx);
        let mut red = vec![0.0f32; 6];
        reduce_sum_out(&prod, &mut red, 6, 4, 1);
        let mut fused = vec![f32::NAN; 6];
        binary_reduce_sum_out(BinKind::Mul, &a, &b, &sa, &sb, &shape, 2, &mut fused, &mut idx);
        assert_eq!(red, fused);
        // f16 storage: per-stage rounding must match the unfused stores
        let ah: Vec<u16> = a.iter().map(|&v| f32_to_f16(v)).collect();
        let bh: Vec<u16> = b.iter().map(|&v| f32_to_f16(v)).collect();
        let mut prodh = vec![0u16; 24];
        binary_out_g(BinKind::Mul, &mode, &ah, &bh, &shape, &mut prodh, &mut idx);
        let mut redh = vec![0u16; 6];
        reduce_sum_out_g(&prodh, &mut redh, 6, 4, 1);
        let mut fusedh = vec![0u16; 6];
        binary_reduce_sum_out_g(
            BinKind::Mul,
            &ah,
            &bh,
            &sa,
            &sb,
            &shape,
            2,
            &mut fusedh,
            &mut idx,
        );
        assert_eq!(redh, fusedh);
        // middle-axis reduction (inner > 1)
        let mut red1 = vec![0.0f32; 8];
        reduce_sum_out(&prod, &mut red1, 2, 3, 4);
        let mut fused1 = vec![f32::NAN; 8];
        binary_reduce_sum_out(BinKind::Mul, &a, &b, &sa, &sb, &shape, 1, &mut fused1, &mut idx);
        assert_eq!(red1, fused1);
    }

    #[test]
    fn gemm_intra_op_split_matches_serial_at_any_worker_count() {
        // above INTRA_GEMM_MIN_FLOPS so the mt path actually splits;
        // n = 129 leaves a ragged tail tile in every row panel
        let (m, k, n) = (64usize, 64, 129);
        assert!(2 * m * k * n >= INTRA_GEMM_MIN_FLOPS);
        let mut seed = 11u32;
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        lcg_fill(&mut a, &mut seed);
        lcg_fill(&mut b, &mut seed);
        let mut serial = vec![0.0f32; m * n];
        matmul_out(&a, &b, &mut serial, 1, m, k, n, 0, 0);
        for workers in [1usize, 2, 4] {
            let mut mt = vec![f32::NAN; m * n];
            matmul_out_mt(&a, &b, &mut mt, 1, m, k, n, 0, 0, workers);
            assert_eq!(serial, mt, "f32 workers={workers}");
        }
        let ah: Vec<u16> = a.iter().map(|&v| f32_to_f16(v)).collect();
        let bh: Vec<u16> = b.iter().map(|&v| f32_to_f16(v)).collect();
        let mut sh = vec![0u16; m * n];
        matmul_out_g::<u16>(&ah, &bh, &mut sh, 1, m, k, n, 0, 0);
        for workers in [2usize, 4] {
            let mut mh = vec![0u16; m * n];
            matmul_out_g_mt::<u16>(&ah, &bh, &mut mh, 1, m, k, n, 0, 0, workers);
            assert_eq!(sh, mh, "f16 workers={workers}");
        }
        let ai: Vec<i8> = (0..m * k).map(|i| (i * 37 % 255) as u8 as i8).collect();
        let bi: Vec<i8> = (0..k * n).map(|i| (i * 91 % 251) as u8 as i8).collect();
        let mut si = vec![0.0f32; m * n];
        matmul_i8_out(&ai, 0.5, &bi, 0.25, &mut si, 1, m, k, n, 0, 0);
        for workers in [2usize, 4] {
            let mut mi = vec![f32::NAN; m * n];
            matmul_i8_out_mt(&ai, 0.5, &bi, 0.25, &mut mi, 1, m, k, n, 0, 0, workers);
            assert_eq!(si, mi, "i8 workers={workers}");
        }
    }

    #[test]
    fn elementwise_intra_op_splits_match_serial() {
        let (outer, n_axis, inner) = (8usize, 64, 64);
        let len = outer * n_axis * inner;
        assert!(len >= INTRA_ELEM_MIN);
        let mut seed = 5u32;
        let mut x = vec![0.0f32; len];
        lcg_fill(&mut x, &mut seed);
        let mut cs = vec![0.0f32; len];
        cumsum_out(&x, &mut cs, outer, n_axis, inner);
        let mut sm = vec![0.0f32; len];
        softmax_out_g::<f32>(&x, &mut sm, outer, n_axis, inner);
        let mut rs = vec![0.0f32; outer * inner];
        reduce_sum_out(&x, &mut rs, outer, n_axis, inner);
        let mut y = vec![0.0f32; len];
        lcg_fill(&mut y, &mut seed);
        let mut add = vec![0.0f32; len];
        let mut idx = Vec::new();
        binary_out(BinKind::Add, &BinMode::Elementwise, &x, &y, &[len], &mut add, &mut idx);
        let mut si = vec![0.0f32; len];
        unary_out(UnKind::SiLU, &x, &mut si);
        let (cb, t, c, k) = (2usize, 128, 128, 4);
        let mut wv = vec![0.0f32; k * c];
        let mut bv = vec![0.0f32; c];
        lcg_fill(&mut wv, &mut seed);
        lcg_fill(&mut bv, &mut seed);
        let mut cv = vec![0.0f32; cb * t * c];
        conv1d_out(&x, &wv, &bv, &mut cv, cb, t, c, k);
        let mut rn = vec![0.0f32; len];
        rmsnorm_out(&x, &bv, &mut rn, len / c, c, 1e-5);
        for workers in [2usize, 4] {
            let mut o = vec![f32::NAN; len];
            cumsum_out_mt(&x, &mut o, outer, n_axis, inner, workers);
            assert_eq!(cs, o, "cumsum workers={workers}");
            softmax_out_mt(&x, &mut o, outer, n_axis, inner, workers);
            assert_eq!(sm, o, "softmax workers={workers}");
            let mut r = vec![f32::NAN; outer * inner];
            reduce_sum_out_mt(&x, &mut r, outer, n_axis, inner, workers);
            assert_eq!(rs, r, "reduce workers={workers}");
            binary_out_mt(
                BinKind::Add,
                &BinMode::Elementwise,
                &x,
                &y,
                &[len],
                &mut o,
                &mut idx,
                workers,
            );
            assert_eq!(add, o, "binary workers={workers}");
            unary_out_mt(UnKind::SiLU, &x, &mut o, workers);
            assert_eq!(si, o, "unary workers={workers}");
            conv1d_out_mt::<f32>(&x, &wv, &bv, &mut o, cb, t, c, k, workers);
            assert_eq!(cv, o, "conv workers={workers}");
            rmsnorm_out_mt(&x, &bv, &mut o, len / c, c, 1e-5, workers);
            assert_eq!(rn, o, "rmsnorm workers={workers}");
        }
    }
}
