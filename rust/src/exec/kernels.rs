//! Out-buffer operator kernels for the planned executor.
//!
//! Every kernel writes into a caller-provided slice (an arena slot), so
//! steady-state execution performs no heap allocation. Loop structures
//! deliberately mirror the reference evaluator in [`super::naive`]
//! operation-for-operation, so planned and naive execution agree
//! *bitwise* — the differential suite in `tests/exec_differential.rs`
//! holds them to that.

use crate::graph::op::{BinKind, UnKind};
use crate::plu::{self, PluTable};

/// Scalar unary application — shared by the naive evaluator, the planned
/// unary kernel, and fused-chain stages (identity of results by
/// construction).
#[inline]
pub fn apply_unary(kind: UnKind, v: f32) -> f32 {
    match kind {
        UnKind::Neg => -v,
        UnKind::Exp => v.exp(),
        UnKind::Log => v.ln(),
        UnKind::Sqrt => v.sqrt(),
        UnKind::Abs => v.abs(),
        UnKind::Recip => 1.0 / v,
        UnKind::Relu => v.max(0.0),
        UnKind::Sigmoid => plu::sigmoid_f32(v),
        UnKind::SiLU => v * plu::sigmoid_f32(v),
        UnKind::Softplus => plu::softplus_f32(v),
        UnKind::Tanh => v.tanh(),
    }
}

/// Scalar binary application — shared like [`apply_unary`].
#[inline]
pub fn apply_binary(kind: BinKind, x: f32, y: f32) -> f32 {
    match kind {
        BinKind::Add => x + y,
        BinKind::Sub => x - y,
        BinKind::Mul => x * y,
        BinKind::Div => x / y,
        BinKind::Max => x.max(y),
    }
}

// --- argument views -------------------------------------------------------------

/// Borrowed, dtype-tagged tensor payload.
#[derive(Clone, Copy)]
pub enum DataRef<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// Borrowed tensor: shape + payload. What planned kernels consume.
#[derive(Clone, Copy)]
pub struct View<'a> {
    pub shape: &'a [usize],
    pub data: DataRef<'a>,
}

impl<'a> View<'a> {
    pub fn f32(&self) -> &'a [f32] {
        match self.data {
            DataRef::F32(v) => v,
            DataRef::I32(_) => panic!("expected f32 tensor"),
        }
    }

    pub fn i32(&self) -> &'a [i32] {
        match self.data {
            DataRef::I32(v) => v,
            DataRef::F32(_) => panic!("expected i32 tensor"),
        }
    }
}

// --- elementwise ----------------------------------------------------------------

/// Precomputed broadcast classification of a binary op (compile-time).
#[derive(Clone, Debug)]
pub enum BinMode {
    /// Both operands already have the output shape.
    Elementwise,
    /// `tensor op scalar` — right operand has one element.
    ScalarRight,
    /// `scalar op tensor` — left operand has one element.
    ScalarLeft,
    /// General broadcast: per-output-dim input strides (0 on broadcast
    /// dims), precomputed at plan time.
    Strided { sa: Vec<usize>, sb: Vec<usize> },
}

/// Per-output-dim strides of a broadcast input: 0 where the input dim is
/// 1 (or missing), the row-major stride otherwise. Matches the reference
/// evaluator's `bcast_index` arithmetic exactly.
pub fn bcast_strides(out_shape: &[usize], in_shape: &[usize]) -> Vec<usize> {
    let st = crate::graph::tensor::strides(in_shape);
    let off = out_shape.len() - in_shape.len();
    let mut r = vec![0usize; out_shape.len()];
    for (d, &s) in in_shape.iter().enumerate() {
        r[off + d] = if s == 1 { 0 } else { st[d] };
    }
    r
}

pub fn binary_out(
    kind: BinKind,
    mode: &BinMode,
    a: &[f32],
    b: &[f32],
    out_shape: &[usize],
    out: &mut [f32],
    idx: &mut Vec<usize>,
) {
    match mode {
        BinMode::Elementwise => {
            for i in 0..out.len() {
                out[i] = apply_binary(kind, a[i], b[i]);
            }
        }
        BinMode::ScalarRight => {
            let s = b[0];
            for i in 0..out.len() {
                out[i] = apply_binary(kind, a[i], s);
            }
        }
        BinMode::ScalarLeft => {
            let s = a[0];
            for i in 0..out.len() {
                out[i] = apply_binary(kind, s, b[i]);
            }
        }
        BinMode::Strided { sa, sb } => {
            idx.clear();
            idx.resize(out_shape.len(), 0);
            for o in out.iter_mut() {
                let mut ia = 0;
                let mut ib = 0;
                for (d, &i) in idx.iter().enumerate() {
                    ia += i * sa[d];
                    ib += i * sb[d];
                }
                *o = apply_binary(kind, a[ia], b[ib]);
                for d in (0..idx.len()).rev() {
                    idx[d] += 1;
                    if idx[d] < out_shape[d] {
                        break;
                    }
                    idx[d] = 0;
                }
            }
        }
    }
}

pub fn unary_out(kind: UnKind, x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = apply_unary(kind, v);
    }
}

pub fn plu_out(table: &PluTable, x: &[f32], out: &mut [f32]) {
    table.eval_slice(x, out);
}

// --- matmul ---------------------------------------------------------------------

/// Batched matmul into a zeroed output. `a_step`/`b_step` are the
/// per-batch element offsets (0 when the operand is not batched).
#[allow(clippy::too_many_arguments)]
pub fn matmul_out(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    a_step: usize,
    b_step: usize,
) {
    out.fill(0.0);
    for bi in 0..batch {
        let ao = bi * a_step;
        let bo = bi * b_step;
        let oo = bi * m * n;
        for i in 0..m {
            for kk in 0..k {
                let av_ik = a[ao + i * k + kk];
                if av_ik == 0.0 {
                    continue;
                }
                let brow = bo + kk * n;
                let orow = oo + i * n;
                for j in 0..n {
                    out[orow + j] += av_ik * b[brow + j];
                }
            }
        }
    }
}

// --- scans / reductions ---------------------------------------------------------

pub fn cumsum_out(x: &[f32], out: &mut [f32], outer: usize, n_axis: usize, inner: usize) {
    out.copy_from_slice(x);
    for o in 0..outer {
        for i in 0..inner {
            let base = o * n_axis * inner + i;
            for j in 1..n_axis {
                out[base + j * inner] += out[base + (j - 1) * inner];
            }
        }
    }
}

pub fn reduce_sum_out(
    x: &[f32],
    out: &mut [f32],
    outer: usize,
    n_axis: usize,
    inner: usize,
) {
    out.fill(0.0);
    for o in 0..outer {
        for j in 0..n_axis {
            let base = (o * n_axis + j) * inner;
            let obase = o * inner;
            for i in 0..inner {
                out[obase + i] += x[base + i];
            }
        }
    }
}

// --- gather / conv / norms ------------------------------------------------------

pub fn gather_out(
    data: &[f32],
    indices: &[i32],
    out: &mut [f32],
    row: usize,
    vocab: usize,
) -> Result<(), String> {
    for (r, &i) in indices.iter().enumerate() {
        if i < 0 || i >= vocab as i32 {
            return Err(format!("gather index {i} out of range 0..{vocab}"));
        }
        out[r * row..(r + 1) * row]
            .copy_from_slice(&data[i as usize * row..(i as usize + 1) * row]);
    }
    Ok(())
}

pub fn conv1d_out(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    t: usize,
    c: usize,
    k: usize,
) {
    for ti in 0..t {
        for ci in 0..c {
            let mut acc = b[ci];
            for ki in 0..k {
                // causal: tap ki reads position ti - (k - 1 - ki)
                let src = ti as isize - (k - 1 - ki) as isize;
                if src >= 0 {
                    acc += w[ki * c + ci] * x[src as usize * c + ci];
                }
            }
            out[ti * c + ci] = acc;
        }
    }
}

pub fn rmsnorm_out(x: &[f32], w: &[f32], out: &mut [f32], rows: usize, d: usize, eps: f32) {
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for i in 0..d {
            out[r * d + i] = row[i] * inv * w[i];
        }
    }
}

pub fn softmax_out(x: &[f32], out: &mut [f32], outer: usize, n_axis: usize, inner: usize) {
    for o in 0..outer {
        for i in 0..inner {
            let at = |j: usize| (o * n_axis + j) * inner + i;
            let mut mx = f32::NEG_INFINITY;
            for j in 0..n_axis {
                mx = mx.max(x[at(j)]);
            }
            let mut z = 0.0;
            for j in 0..n_axis {
                let e = (x[at(j)] - mx).exp();
                out[at(j)] = e;
                z += e;
            }
            for j in 0..n_axis {
                out[at(j)] /= z;
            }
        }
    }
}

// --- layout ---------------------------------------------------------------------

pub fn slice_out<T: Copy>(
    x: &[T],
    out: &mut [T],
    outer: usize,
    n_axis: usize,
    inner: usize,
    start: usize,
    len: usize,
) {
    for o in 0..outer {
        let src = (o * n_axis + start) * inner;
        let dst = o * len * inner;
        out[dst..dst + len * inner].copy_from_slice(&x[src..src + len * inner]);
    }
}

/// Row-major copy (reshape).
pub fn copy_out<T: Copy>(x: &[T], out: &mut [T]) {
    out.copy_from_slice(x);
}

/// Strided gather copy: walks the output row-major, reading the input at
/// the precomputed per-output-dim strides (transpose and broadcast).
pub fn strided_copy_out(
    x: &[f32],
    out: &mut [f32],
    out_shape: &[usize],
    strides: &[usize],
    idx: &mut Vec<usize>,
) {
    idx.clear();
    idx.resize(out_shape.len(), 0);
    for o in out.iter_mut() {
        let mut lin = 0;
        for (d, &i) in idx.iter().enumerate() {
            lin += i * strides[d];
        }
        *o = x[lin];
        for d in (0..out_shape.len()).rev() {
            idx[d] += 1;
            if idx[d] < out_shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_out_2d() {
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        let mut out = [0.0f32; 4];
        matmul_out(&a, &b, &mut out, 1, 2, 3, 2, 0, 0);
        assert_eq!(out, [58., 64., 139., 154.]);
    }

    #[test]
    fn binary_out_strided_matches_scalar_path() {
        // (2,2) * scalar via Strided must equal the ScalarRight fast path
        let a = [1., 2., 3., 4.];
        let b = [10.0f32];
        let mut fast = [0.0f32; 4];
        let mut slow = [0.0f32; 4];
        let mut idx = Vec::new();
        binary_out(BinKind::Mul, &BinMode::ScalarRight, &a, &b, &[2, 2], &mut fast, &mut idx);
        let mode = BinMode::Strided {
            sa: bcast_strides(&[2, 2], &[2, 2]),
            sb: bcast_strides(&[2, 2], &[]),
        };
        binary_out(BinKind::Mul, &mode, &a, &b, &[2, 2], &mut slow, &mut idx);
        assert_eq!(fast, slow);
        assert_eq!(fast, [10., 20., 30., 40.]);
    }

    #[test]
    fn scalar_left_is_not_commuted() {
        // scalar - tensor must compute s - x, not x - s
        let a = [10.0f32];
        let b = [1., 2., 3., 4.];
        let mut out = [0.0f32; 4];
        let mut idx = Vec::new();
        binary_out(BinKind::Sub, &BinMode::ScalarLeft, &a, &b, &[4], &mut out, &mut idx);
        assert_eq!(out, [9., 8., 7., 6.]);
    }

    #[test]
    fn cumsum_out_axis0() {
        let x = [1., 10., 2., 20., 3., 30.];
        let mut out = [0.0f32; 6];
        cumsum_out(&x, &mut out, 1, 3, 2);
        assert_eq!(out, [1., 10., 3., 30., 6., 60.]);
    }

    #[test]
    fn strided_copy_transposes() {
        let x = [1., 2., 3., 4., 5., 6.];
        let mut out = [0.0f32; 6];
        let mut idx = Vec::new();
        // (2,3) -> (3,2): out dim 0 walks input columns (stride 1), out
        // dim 1 walks input rows (stride 3)
        strided_copy_out(&x, &mut out, &[3, 2], &[1, 3], &mut idx);
        assert_eq!(out, [1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn gather_out_checks_range() {
        let data = [0., 1., 10., 11., 20., 21.];
        let mut out = [0.0f32; 4];
        assert!(gather_out(&data, &[2, 0], &mut out, 2, 3).is_ok());
        assert_eq!(out, [20., 21., 0., 1.]);
        assert!(gather_out(&data, &[5], &mut out[..2], 2, 3).is_err());
    }
}
