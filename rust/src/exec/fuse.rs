//! Peephole fusion of elementwise chains.
//!
//! A chain like `Binary -> Unary -> Plu` whose intermediates have exactly
//! one consumer collapses into a single pass over the data: each output
//! element is produced by composing the per-element stages, so the
//! intermediate tensors are never materialized. Stage arithmetic reuses
//! the exact scalar helpers of the unfused kernels, so fusion is bitwise
//! neutral.
//!
//! Chains also see **through `Reshape`**: a reshape is a row-major
//! identity on the data, so it joins a chain as a transparent member
//! (contributing no stage) instead of materializing a copy —
//! `Unary -> Reshape -> Unary` is one fused pass, and a reshape between
//! a producer and its elementwise epilogue no longer breaks fusion.
//!
//! Chains form at f32 and f16 alike (a chain is dtype-homogeneous by
//! construction: every stage preserves its node's dtype). The f16
//! executor rounds to storage precision after every stage, keeping
//! fusion bitwise-identical to running the nodes one by one.

use std::sync::Arc;

use crate::graph::op::{BinKind, Op};
use crate::graph::tensor::DType;
use crate::graph::{Graph, NodeId};
use crate::plu::PluTable;
use crate::util::f16::f16_to_f32;

use super::kernels::{apply_binary, apply_unary};

/// One fused per-element stage.
#[derive(Clone, Debug)]
pub enum ElemStage {
    Unary(crate::graph::op::UnKind),
    /// PLU lookup with the reciprocal step precomputed; evaluation goes
    /// through `PluTable::eval_premul`, the same inner `eval_slice`
    /// uses, so fused and unfused stages pick identical segments.
    Plu {
        table: Arc<PluTable>,
        inv_step: f32,
        kmax: i64,
    },
    /// `x op c` with a compile-time scalar constant.
    ScalarRight(BinKind, f32),
    /// `c op x` (operand order preserved for Sub/Div).
    ScalarLeft(BinKind, f32),
}

impl ElemStage {
    fn plu(table: &Arc<PluTable>) -> ElemStage {
        ElemStage::Plu {
            table: table.clone(),
            inv_step: 1.0 / table.step(),
            kmax: table.num_segments() as i64 - 1,
        }
    }

    /// Apply the stage to one element.
    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            ElemStage::Unary(k) => apply_unary(*k, x),
            ElemStage::Plu { table, inv_step, kmax } => {
                table.eval_premul(x, *inv_step, *kmax)
            }
            ElemStage::ScalarRight(k, s) => apply_binary(*k, x, *s),
            ElemStage::ScalarLeft(k, s) => apply_binary(*k, *s, x),
        }
    }
}

/// What feeds the first fused stage.
#[derive(Clone, Debug)]
pub enum ChainHead {
    /// A single upstream value (the main input of the first stage node).
    Value(NodeId),
    /// A same-shape, no-broadcast binary combining two upstream values.
    Binary(BinKind, NodeId, NodeId),
    /// A matmul node anchoring an epilogue chain: the GEMM computes into
    /// the chain's output slot and the stages run as an in-place second
    /// pass, so the matmul's activation epilogue (SiLU/PLU/scalar ops)
    /// never materializes an intermediate.
    MatMul(NodeId),
}

/// A detected chain: `nodes` in graph order; all but the last are
/// absorbed (no slot, no step), the last carries the fused step.
#[derive(Clone, Debug)]
pub struct Chain {
    pub nodes: Vec<NodeId>,
    pub head: ChainHead,
    pub stages: Vec<ElemStage>,
}

/// A scalar constant's value, if `id` is one (f32 or f16 — an f16 graph
/// carries f16 scalar constants; the stage holds the widened value, and
/// per-stage rounding keeps the fused result equal to the unfused
/// `ScalarRight` kernel).
fn const_scalar(g: &Graph, id: NodeId) -> Option<f32> {
    let n = g.node(id);
    if let Op::Const { .. } = n.op {
        if let Some(v) = &n.value {
            if v.numel() == 1 {
                match v.dtype() {
                    DType::F32 => return Some(v.as_f32()[0]),
                    DType::F16 => return Some(f16_to_f32(v.as_f16()[0])),
                    _ => return None,
                }
            }
        }
    }
    None
}

/// Dtype at which a node may join a fused chain (f32 or f16).
fn fusable_dtype(g: &Graph, id: NodeId) -> bool {
    matches!(g.node(id).dtype, DType::F32 | DType::F16)
}

/// If `id` can ride a chain over a single main input, return the main
/// input and the stage it contributes — `None` stage for a transparent
/// member (`Reshape`: row-major identity, no arithmetic).
fn stage_of(g: &Graph, id: NodeId) -> Option<(NodeId, Option<ElemStage>)> {
    if !fusable_dtype(g, id) {
        return None;
    }
    let n = g.node(id);
    match &n.op {
        Op::Unary(k) => Some((n.inputs[0], Some(ElemStage::Unary(*k)))),
        Op::Plu { table, .. } => Some((n.inputs[0], Some(ElemStage::plu(table)))),
        Op::Reshape { .. } => Some((n.inputs[0], None)),
        Op::Binary(k) => {
            let (a, b) = (n.inputs[0], n.inputs[1]);
            if let Some(s) = const_scalar(g, b) {
                if g.shape(a) == n.shape.as_slice() {
                    return Some((a, Some(ElemStage::ScalarRight(*k, s))));
                }
            }
            if let Some(s) = const_scalar(g, a) {
                if g.shape(b) == n.shape.as_slice() {
                    return Some((b, Some(ElemStage::ScalarLeft(*k, s))));
                }
            }
            None
        }
        _ => None,
    }
}

/// A binary node whose operands both already have the output shape (no
/// broadcast, so it can anchor a fused chain as a two-input head).
fn binary_head(g: &Graph, id: NodeId) -> Option<(BinKind, NodeId, NodeId)> {
    if !fusable_dtype(g, id) {
        return None;
    }
    let n = g.node(id);
    if let Op::Binary(k) = n.op {
        let (a, b) = (n.inputs[0], n.inputs[1]);
        if g.shape(a) == n.shape.as_slice() && g.shape(b) == n.shape.as_slice() {
            return Some((k, a, b));
        }
    }
    None
}

/// A matmul that may anchor an epilogue chain. Its output dtype must be
/// f32/f16 (i8-operand matmuls emit f32, so they qualify too); whether a
/// chain actually forms depends on a fusable stage following it.
fn matmul_head(g: &Graph, id: NodeId) -> bool {
    fusable_dtype(g, id) && matches!(g.node(id).op, Op::MatMul)
}

/// Detect maximal fusable chains among the live nodes. A node joins the
/// chain after its producer only if the producer has exactly one (live)
/// consumer and is not a graph output — absorbed intermediates must be
/// invisible to the outside.
pub fn find_chains(g: &Graph, live: &[bool]) -> Vec<Chain> {
    let n = g.nodes.len();
    let mut is_output = vec![false; n];
    for &o in &g.outputs {
        is_output[o] = true;
    }
    // live-consumer counts and (when unique) the consumer id
    let mut count = vec![0usize; n];
    let mut sole = vec![usize::MAX; n];
    for node in &g.nodes {
        if !live[node.id] {
            continue;
        }
        for &i in &node.inputs {
            count[i] += 1;
            sole[i] = node.id;
        }
    }

    let mut absorbed = vec![false; n];
    let mut chains = Vec::new();
    for id in 0..n {
        if !live[id] || absorbed[id] {
            continue;
        }
        if matches!(g.node(id).op, Op::Input { .. } | Op::Const { .. }) {
            continue;
        }
        let (head, mut stages) = match stage_of(g, id) {
            Some((main, st)) => (ChainHead::Value(main), st.into_iter().collect()),
            None => match binary_head(g, id) {
                Some((k, a, b)) => (ChainHead::Binary(k, a, b), Vec::new()),
                None if matmul_head(g, id) => (ChainHead::MatMul(id), Vec::new()),
                None => continue,
            },
        };
        let mut nodes = vec![id];
        let mut cur = id;
        loop {
            if is_output[cur] || count[cur] != 1 {
                break;
            }
            let next = sole[cur];
            match stage_of(g, next) {
                Some((main, st)) if main == cur => {
                    if let Some(st) = st {
                        stages.push(st);
                    }
                    nodes.push(next);
                    cur = next;
                }
                _ => break,
            }
        }
        if nodes.len() >= 2 {
            for &m in &nodes {
                absorbed[m] = true;
            }
            chains.push(Chain { nodes, head, stages });
        }
    }
    chains
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn unary_chain_is_detected() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![4]);
        let a = g.silu(x, "a");
        let b = g.exp(a, "b");
        let half = g.const_scalar("h", 0.5);
        let c = g.mul(b, half, "c");
        g.output(c);
        let chains = find_chains(&g, &g.live_set());
        assert_eq!(chains.len(), 1);
        assert_eq!(chains[0].nodes, vec![a, b, c]);
        assert!(matches!(chains[0].head, ChainHead::Value(h) if h == x));
        assert_eq!(chains[0].stages.len(), 3);
    }

    #[test]
    fn multi_consumer_intermediate_blocks_fusion() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![4]);
        let a = g.silu(x, "a");
        let b = g.exp(a, "b");
        let c = g.add(a, b, "c"); // `a` feeds two nodes -> b cannot absorb it
        g.output(c);
        let chains = find_chains(&g, &g.live_set());
        // `c` is a valid binary head but has no stage after it; `a`/`b`
        // cannot chain because a has two consumers
        assert!(chains.iter().all(|ch| !ch.nodes.contains(&a) || ch.nodes[0] == a));
        assert!(!chains.iter().any(|ch| ch.nodes == vec![a, b]));
    }

    #[test]
    fn output_intermediate_blocks_fusion() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![4]);
        let a = g.silu(x, "a");
        let b = g.exp(a, "b");
        g.output(a); // `a` is externally visible
        g.output(b);
        let chains = find_chains(&g, &g.live_set());
        assert!(chains.is_empty());
    }

    #[test]
    fn binary_head_chain() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![2, 2]);
        let y = g.input("y", vec![2, 2]);
        let s = g.add(x, y, "s");
        let t = g.silu(s, "t");
        g.output(t);
        let chains = find_chains(&g, &g.live_set());
        assert_eq!(chains.len(), 1);
        assert!(matches!(chains[0].head, ChainHead::Binary(BinKind::Add, a, b) if a == x && b == y));
        assert_eq!(chains[0].stages.len(), 1);
    }

    #[test]
    fn broadcast_binary_does_not_head_a_chain() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![2, 2]);
        let row = g.input("row", vec![1, 2]);
        let s = g.add(x, row, "s"); // broadcast -> not fusable
        let t = g.silu(s, "t");
        g.output(t);
        let chains = find_chains(&g, &g.live_set());
        assert!(chains.is_empty());
    }

    #[test]
    fn chains_fuse_through_reshape() {
        // silu -> reshape -> exp: the reshape is a transparent member
        let mut g = Graph::new("t");
        let x = g.input("x", vec![2, 4]);
        let a = g.silu(x, "a");
        let r = g.reshape(a, vec![8], "r");
        let b = g.exp(r, "b");
        g.output(b);
        let chains = find_chains(&g, &g.live_set());
        assert_eq!(chains.len(), 1);
        assert_eq!(chains[0].nodes, vec![a, r, b]);
        assert_eq!(chains[0].stages.len(), 2, "reshape contributes no stage");
    }

    #[test]
    fn reshape_can_start_a_chain() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![2, 3]);
        let r = g.reshape(x, vec![6], "r");
        let a = g.silu(r, "a");
        g.output(a);
        let chains = find_chains(&g, &g.live_set());
        assert_eq!(chains.len(), 1);
        assert_eq!(chains[0].nodes, vec![r, a]);
        assert!(matches!(chains[0].head, ChainHead::Value(h) if h == x));
        assert_eq!(chains[0].stages.len(), 1);
    }

    #[test]
    fn matmul_heads_an_epilogue_chain() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![2, 3]);
        let w = g.input("w", vec![3, 4]);
        let m = g.matmul(x, w, "m");
        let s = g.silu(m, "s");
        g.output(s);
        let chains = find_chains(&g, &g.live_set());
        assert_eq!(chains.len(), 1);
        assert_eq!(chains[0].nodes, vec![m, s]);
        assert!(matches!(chains[0].head, ChainHead::MatMul(h) if h == m));
        assert_eq!(chains[0].stages.len(), 1);
    }

    #[test]
    fn bare_or_multi_consumer_matmul_does_not_chain() {
        // no epilogue stage -> no chain (the plain kernel path runs it)
        let mut g = Graph::new("t");
        let x = g.input("x", vec![2, 3]);
        let w = g.input("w", vec![3, 4]);
        let m = g.matmul(x, w, "m");
        g.output(m);
        assert!(find_chains(&g, &g.live_set()).is_empty());
        // output matmul with a downstream stage: the intermediate is
        // externally visible, so the epilogue must not absorb it
        let mut g2 = Graph::new("t2");
        let x2 = g2.input("x", vec![2, 3]);
        let w2 = g2.input("w", vec![3, 4]);
        let m2 = g2.matmul(x2, w2, "m");
        let s2 = g2.silu(m2, "s");
        g2.output(m2);
        g2.output(s2);
        assert!(find_chains(&g2, &g2.live_set()).is_empty());
    }

    #[test]
    fn f16_nodes_form_chains_and_i8_nodes_do_not() {
        use crate::graph::DType;
        let mut g = Graph::new("t");
        let x = g.input_dtype("x", vec![4], DType::F16);
        let a = g.silu(x, "a");
        let b = g.exp(a, "b");
        g.output(b);
        let chains = find_chains(&g, &g.live_set());
        assert_eq!(chains.len(), 1);
        assert_eq!(chains[0].nodes, vec![a, b]);

        let mut q = Graph::new("q");
        let xq = q.input_dtype("x", vec![4], DType::I8);
        let aq = q.silu(xq, "a");
        let bq = q.exp(aq, "b");
        q.output(bq);
        assert!(find_chains(&q, &q.live_set()).is_empty(), "i8 stays unfused");
    }
}
