//! Reference graph walker — the original `interp` evaluator, kept as
//! the semantic baseline the planned executor is differentially tested
//! against.
//!
//! It re-walks the graph per call, re-computes topo order and liveness,
//! allocates a fresh tensor per node, and moves values through a
//! `HashMap` — deliberately simple and allocation-heavy. Use
//! [`super::ExecutionPlan`] for anything performance-sensitive.
//!
//! Scope of the "second opinion": the per-element scalar math
//! (`apply_unary` / `apply_binary`, PLU segment select) is deliberately
//! SHARED with the planned kernels so fusion stays bitwise neutral —
//! the differential suite therefore checks scheduling, arena reuse,
//! fusion, and indexing/broadcast arithmetic, not the scalar formulas
//! themselves. Those are covered by the kernel unit tests here and the
//! artifact-gated golden tests against python.

use std::collections::HashMap;

use crate::graph::op::{BinKind, Op, UnKind};
use crate::graph::tensor::{numel, strides, Data, DType, Tensor};
use crate::graph::{Graph, NodeId};

use super::kernels::{self, apply_binary, apply_unary};
use super::{Backend, Plan};

/// The naive walker behind the [`Backend`] seam. "Planning" is a graph
/// clone; every `execute` re-walks it.
pub struct NaiveBackend;

struct NaivePlan {
    graph: Graph,
}

impl Backend for NaiveBackend {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn plan(&self, graph: &Graph) -> Result<Box<dyn Plan>, String> {
        Ok(Box::new(NaivePlan { graph: graph.clone() }))
    }
}

impl Plan for NaivePlan {
    fn execute(&mut self, inputs: &[Tensor]) -> Result<Vec<Tensor>, String> {
        run(&self.graph, inputs)
    }
}

/// Execute `graph` on the given input tensors (matched by input order).
///
/// Returns the output tensors in `graph.outputs` order.
pub fn run(graph: &Graph, inputs: &[Tensor]) -> Result<Vec<Tensor>, String> {
    if inputs.len() != graph.inputs.len() {
        return Err(format!(
            "graph {} expects {} inputs, got {}",
            graph.name,
            graph.inputs.len(),
            inputs.len()
        ));
    }
    let mut env: HashMap<NodeId, Tensor> = HashMap::with_capacity(graph.nodes.len());
    for (&id, t) in graph.inputs.iter().zip(inputs) {
        let node = graph.node(id);
        if t.shape != node.shape {
            return Err(format!(
                "input {} ({}): expected shape {:?}, got {:?}",
                id, node.name, node.shape, t.shape
            ));
        }
        if t.dtype() != node.dtype {
            return Err(format!(
                "input {} ({}): dtype mismatch (expected {}, got {})",
                id,
                node.name,
                node.dtype.name(),
                t.dtype().name()
            ));
        }
        env.insert(id, t.clone());
    }

    let live = graph.live_set();
    for id in graph.topo_order() {
        if !live[id] || env.contains_key(&id) {
            continue;
        }
        let node = graph.node(id);
        let out = match &node.op {
            Op::Input { .. } => {
                return Err(format!("unbound input node {id} ({})", node.name))
            }
            Op::Const { .. } => node
                .value
                .clone()
                .ok_or_else(|| format!("const node {id} without value"))?,
            op => {
                let args: Vec<&Tensor> = node
                    .inputs
                    .iter()
                    .map(|i| env.get(i).expect("topo order violated"))
                    .collect();
                eval(op, &args, &node.shape)
                    .map_err(|e| format!("node {id} ({}): {e}", node.name))?
            }
        };
        debug_assert_eq!(
            out.shape, node.shape,
            "node {id} ({}) shape drift",
            node.name
        );
        env.insert(id, out);
    }

    graph
        .outputs
        .iter()
        .map(|id| {
            env.get(id)
                .cloned()
                .ok_or_else(|| format!("missing output node {id}"))
        })
        .collect()
}

/// Evaluate one op on its argument tensors; `out_shape` is the shape the
/// builder inferred (layout ops rely on it).
///
/// The reference semantics for the reduced-precision dtypes live here:
/// f16 ops widen every operand to f32, evaluate the f32 reference, and
/// narrow the result (rounding exactly once per stored element — the
/// same contract the planned f16 kernels implement in one pass); i8
/// compute ops additionally requantize the f32 result with a dynamic
/// per-tensor scale through the SAME `kernels::requantize_i8` the
/// planned executor uses, while i8 MatMul accumulates exactly in i32.
/// Planned-vs-naive differential tests therefore hold quantized graphs
/// to bitwise equality, like the f32 corpus.
pub fn eval(op: &Op, args: &[&Tensor], out_shape: &[usize]) -> Result<Tensor, String> {
    match op {
        Op::Quantize { dtype } => return Ok(args[0].to_dtype(*dtype)),
        Op::Dequantize => {
            return Ok(Tensor::f32(args[0].shape.clone(), args[0].to_f32_vec()))
        }
        _ => {}
    }
    // the op's value dtype = dtype of its first non-index operand
    let vdt = args
        .iter()
        .map(|t| t.dtype())
        .find(|d| *d != DType::I32)
        .unwrap_or(DType::I32);
    match vdt {
        DType::F32 | DType::I32 => eval_f32(op, args, out_shape),
        DType::F16 => eval_f16(op, args, out_shape),
        DType::I8 => eval_i8(op, args, out_shape),
    }
}

/// Widen-evaluate-narrow f16 reference: exact for layout ops (every f16
/// value round-trips through f32), one store-rounding for compute ops.
fn eval_f16(op: &Op, args: &[&Tensor], out_shape: &[usize]) -> Result<Tensor, String> {
    let wide: Vec<Tensor> = args
        .iter()
        .map(|t| {
            if t.dtype() == DType::I32 {
                (*t).clone()
            } else {
                Tensor::f32(t.shape.clone(), t.to_f32_vec())
            }
        })
        .collect();
    let refs: Vec<&Tensor> = wide.iter().collect();
    let f = eval_f32(op, &refs, out_shape)?;
    Ok(f.to_dtype(DType::F16))
}

/// i8 reference. Layout ops move raw quantized bytes and carry the scale
/// (no requantization: data movement must be lossless); compute ops go
/// widen → f32 reference → shared requantize; MatMul is the exact-i32
/// int8 GEMM.
fn eval_i8(op: &Op, args: &[&Tensor], out_shape: &[usize]) -> Result<Tensor, String> {
    match op {
        Op::MatMul => {
            let (qa, sa) = args[0].as_i8();
            let (qb, sb) = args[1].as_i8();
            let a_shape = &args[0].shape;
            let b_shape = &args[1].shape;
            let (ra, rb) = (a_shape.len(), b_shape.len());
            let (m, k) = (a_shape[ra - 2], a_shape[ra - 1]);
            let n = b_shape[rb - 1];
            let batch = numel(out_shape) / (m * n);
            let batch_a: usize = a_shape[..ra - 2].iter().product();
            let batch_b: usize = b_shape[..rb - 2].iter().product();
            let mut out = vec![0.0f32; numel(out_shape)];
            kernels::matmul_i8_out(
                qa,
                sa,
                qb,
                sb,
                &mut out,
                batch,
                m,
                k,
                n,
                if batch_a == 1 { 0 } else { m * k },
                if batch_b == 1 { 0 } else { k * n },
            );
            Ok(Tensor::f32(out_shape.to_vec(), out))
        }
        Op::Slice { axis, start, len } => {
            let (q, scale) = args[0].as_i8();
            let shape = &args[0].shape;
            let outer: usize = shape[..*axis].iter().product();
            let n_axis = shape[*axis];
            let inner: usize = shape[*axis + 1..].iter().product();
            let mut out = Vec::with_capacity(outer * len * inner);
            for o in 0..outer {
                let base = (o * n_axis + start) * inner;
                out.extend_from_slice(&q[base..base + len * inner]);
            }
            Ok(Tensor::i8(out_shape.to_vec(), out, scale))
        }
        Op::Concat { axis } => {
            let scale = args[0].as_i8().1;
            for t in args {
                if t.as_i8().1 != scale {
                    return Err(
                        "i8 concat needs equal per-tensor scales (got a mix)".into()
                    );
                }
            }
            let shape0 = &args[0].shape;
            let outer: usize = shape0[..*axis].iter().product();
            let inner: usize = shape0[*axis + 1..].iter().product();
            let mut out = Vec::with_capacity(numel(out_shape));
            for o in 0..outer {
                for t in args {
                    let na = t.shape[*axis];
                    let q = t.as_i8().0;
                    out.extend_from_slice(&q[o * na * inner..(o + 1) * na * inner]);
                }
            }
            Ok(Tensor::i8(out_shape.to_vec(), out, scale))
        }
        Op::Reshape { shape } => Ok((*args[0]).clone().reshape(shape.clone())),
        Op::Transpose { perm } => {
            let (q, scale) = args[0].as_i8();
            let in_strides = strides(&args[0].shape);
            let st: Vec<usize> = perm.iter().map(|&p| in_strides[p]).collect();
            let mut out = vec![0i8; numel(out_shape)];
            let mut idx = Vec::new();
            kernels::strided_copy_out(q, &mut out, out_shape, &st, &mut idx);
            Ok(Tensor::i8(out_shape.to_vec(), out, scale))
        }
        Op::Broadcast { shape } => {
            let (q, scale) = args[0].as_i8();
            let st = kernels::bcast_strides(shape, &args[0].shape);
            let mut out = vec![0i8; numel(out_shape)];
            let mut idx = Vec::new();
            kernels::strided_copy_out(q, &mut out, out_shape, &st, &mut idx);
            Ok(Tensor::i8(out_shape.to_vec(), out, scale))
        }
        Op::Gather => {
            let (q, scale) = args[0].as_i8();
            let row: usize = args[0].shape[1..].iter().product();
            let vocab = args[0].shape[0];
            let mut out = vec![0i8; numel(out_shape)];
            kernels::gather_out(q, args[1].as_i32(), &mut out, row, vocab)?;
            Ok(Tensor::i8(out_shape.to_vec(), out, scale))
        }
        // compute ops: widen, evaluate the f32 reference, requantize with
        // the same dynamic-scale helper the planned kernels use
        _ => {
            let wide: Vec<Tensor> = args
                .iter()
                .map(|t| {
                    if t.dtype() == DType::I32 {
                        (*t).clone()
                    } else {
                        Tensor::f32(t.shape.clone(), t.to_f32_vec())
                    }
                })
                .collect();
            let refs: Vec<&Tensor> = wide.iter().collect();
            let f = eval_f32(op, &refs, out_shape)?;
            let mut q = vec![0i8; f.numel()];
            let scale = kernels::requantize_i8(f.as_f32(), &mut q);
            Ok(Tensor::i8(f.shape.clone(), q, scale))
        }
    }
}

/// The f32 (and i32 data-movement) reference evaluator — the original
/// walker semantics, untouched by the dtype generalization.
fn eval_f32(op: &Op, args: &[&Tensor], out_shape: &[usize]) -> Result<Tensor, String> {
    match op {
        Op::Input { .. } | Op::Const { .. } => unreachable!("handled by caller"),
        Op::Quantize { .. } | Op::Dequantize => unreachable!("handled by eval"),
        Op::MatMul => matmul(args[0], args[1]),
        Op::Binary(kind) => binary(*kind, args[0], args[1], out_shape),
        Op::Unary(kind) => Ok(unary(*kind, args[0])),
        Op::Plu { table, .. } => {
            let x = args[0];
            let mut out = vec![0.0f32; x.numel()];
            table.eval_slice(x.as_f32(), &mut out);
            Ok(Tensor::f32(x.shape.clone(), out))
        }
        Op::CumSum { axis } => Ok(cumsum(args[0], *axis)),
        Op::ReduceSum { axis } => Ok(reduce_sum(args[0], *axis)),
        Op::Gather => gather(args[0], args[1]),
        Op::Conv1dCausal { k } => Ok(conv1d_causal(args[0], args[1], args[2], *k)),
        Op::RmsNorm { eps } => Ok(rmsnorm(args[0], args[1], *eps)),
        Op::Softmax { axis } => Ok(softmax(args[0], *axis)),
        Op::Slice { axis, start, len } => Ok(slice(args[0], *axis, *start, *len)),
        Op::Concat { axis } => Ok(concat(args, *axis)),
        Op::Reshape { shape } => Ok(args[0].clone().reshape(shape.clone())),
        Op::Transpose { perm } => Ok(transpose(args[0], perm)),
        Op::Broadcast { shape } => Ok(broadcast_to(args[0], shape)),
    }
}

// --- elementwise ----------------------------------------------------------------

/// Map an output multi-index onto a broadcast input's linear index.
#[inline]
fn bcast_index(out_idx: &[usize], in_shape: &[usize], in_strides: &[usize]) -> usize {
    let off = out_idx.len() - in_shape.len();
    let mut lin = 0;
    for (d, &s) in in_shape.iter().enumerate() {
        let i = if s == 1 { 0 } else { out_idx[off + d] };
        lin += i * in_strides[d];
    }
    lin
}

fn binary(
    kind: BinKind,
    a: &Tensor,
    b: &Tensor,
    out_shape: &[usize],
) -> Result<Tensor, String> {
    let f = |x: f32, y: f32| apply_binary(kind, x, y);
    let (av, bv) = (a.as_f32(), b.as_f32());
    let n = numel(out_shape);
    let mut out = vec![0.0f32; n];
    if a.shape == out_shape && b.shape == out_shape {
        // fast path: no broadcasting
        for i in 0..n {
            out[i] = f(av[i], bv[i]);
        }
    } else if b.numel() == 1 && a.shape == out_shape {
        let s = bv[0];
        for i in 0..n {
            out[i] = f(av[i], s);
        }
    } else if a.numel() == 1 && b.shape == out_shape {
        // scalar-on-left fast path (`scalar op tensor`): same result as
        // the generic strided loop below, without the odometer
        let s = av[0];
        for i in 0..n {
            out[i] = f(s, bv[i]);
        }
    } else {
        let (sa, sb) = (strides(&a.shape), strides(&b.shape));
        let mut idx = vec![0usize; out_shape.len()];
        for o in out.iter_mut() {
            let ia = bcast_index(&idx, &a.shape, &sa);
            let ib = bcast_index(&idx, &b.shape, &sb);
            *o = f(av[ia], bv[ib]);
            // increment multi-index
            for d in (0..out_shape.len()).rev() {
                idx[d] += 1;
                if idx[d] < out_shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
    }
    Ok(Tensor::f32(out_shape.to_vec(), out))
}

fn unary(kind: UnKind, x: &Tensor) -> Tensor {
    Tensor::f32(
        x.shape.clone(),
        x.as_f32().iter().map(|&v| apply_unary(kind, v)).collect(),
    )
}

// --- matmul ---------------------------------------------------------------------

fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor, String> {
    let ra = a.rank();
    let rb = b.rank();
    if ra < 2 || rb < 2 {
        return Err("matmul needs rank >= 2".into());
    }
    let m = a.shape[ra - 2];
    let k = a.shape[ra - 1];
    let k2 = b.shape[rb - 2];
    let n = b.shape[rb - 1];
    if k != k2 {
        return Err(format!("matmul k mismatch {k} vs {k2}"));
    }
    let batch_a: usize = a.shape[..ra - 2].iter().product();
    let batch_b: usize = b.shape[..rb - 2].iter().product();
    let batch = batch_a.max(batch_b);
    if batch_a != batch && batch_a != 1 && !(ra == 2) {
        return Err("matmul batch mismatch".into());
    }
    let (av, bv) = (a.as_f32(), b.as_f32());
    let mut out = vec![0.0f32; batch * m * n];
    // routes through the same blocked GEMM the planned executor uses, so
    // planned-vs-naive stays bitwise identical by construction
    let a_step = if batch_a == 1 { 0 } else { m * k };
    let b_step = if batch_b == 1 { 0 } else { k * n };
    kernels::matmul_out(av, bv, &mut out, batch, m, k, n, a_step, b_step);
    // output shape: batch dims from the higher-rank operand
    let mut shape: Vec<usize> = if ra >= rb {
        a.shape[..ra - 2].to_vec()
    } else {
        b.shape[..rb - 2].to_vec()
    };
    shape.push(m);
    shape.push(n);
    Ok(Tensor::f32(shape, out))
}

// --- scans / reductions ---------------------------------------------------------

fn cumsum(x: &Tensor, axis: usize) -> Tensor {
    let st = x.strides();
    let shape = &x.shape;
    let n_axis = shape[axis];
    let stride = st[axis];
    let xv = x.as_f32();
    let mut out = xv.to_vec();
    // iterate over all lines along `axis`
    let outer: usize = shape[..axis].iter().product();
    let inner: usize = shape[axis + 1..].iter().product();
    for o in 0..outer {
        for i in 0..inner {
            let base = o * n_axis * inner + i;
            for j in 1..n_axis {
                out[base + j * stride] += out[base + (j - 1) * stride];
            }
        }
    }
    Tensor::f32(shape.clone(), out)
}

fn reduce_sum(x: &Tensor, axis: usize) -> Tensor {
    let shape = &x.shape;
    let n_axis = shape[axis];
    let outer: usize = shape[..axis].iter().product();
    let inner: usize = shape[axis + 1..].iter().product();
    let xv = x.as_f32();
    let mut out = vec![0.0f32; outer * inner];
    for o in 0..outer {
        for j in 0..n_axis {
            let base = (o * n_axis + j) * inner;
            let obase = o * inner;
            for i in 0..inner {
                out[obase + i] += xv[base + i];
            }
        }
    }
    let mut oshape = shape.clone();
    oshape.remove(axis);
    Tensor::f32(oshape, out)
}

// --- gather / conv / norms ------------------------------------------------------

fn gather(data: &Tensor, indices: &Tensor) -> Result<Tensor, String> {
    let idx = indices.as_i32();
    let row: usize = data.shape[1..].iter().product();
    let v = data.shape[0] as i32;
    let dv = data.as_f32();
    let mut out = Vec::with_capacity(idx.len() * row);
    for &i in idx {
        if i < 0 || i >= v {
            return Err(format!("gather index {i} out of range 0..{v}"));
        }
        out.extend_from_slice(&dv[i as usize * row..(i as usize + 1) * row]);
    }
    let mut shape = vec![idx.len()];
    shape.extend_from_slice(&data.shape[1..]);
    Ok(Tensor::f32(shape, out))
}

fn conv1d_causal(x: &Tensor, w: &Tensor, b: &Tensor, k: usize) -> Tensor {
    // (T, C) or batched (B, T, C); the causal window runs along T within
    // each batch row independently
    let (batch, t, c) = match x.shape.as_slice() {
        [t, c] => (1, *t, *c),
        [batch, t, c] => (*batch, *t, *c),
        s => panic!("conv1d_causal input must be (T, C) or (B, T, C), got {s:?}"),
    };
    let (xv, wv, bv) = (x.as_f32(), w.as_f32(), b.as_f32());
    let mut out = vec![0.0f32; batch * t * c];
    kernels::conv1d_out(xv, wv, bv, &mut out, batch, t, c, k);
    Tensor::f32(x.shape.clone(), out)
}

fn rmsnorm(x: &Tensor, w: &Tensor, eps: f32) -> Tensor {
    let d = *x.shape.last().unwrap();
    let rows = x.numel() / d;
    let (xv, wv) = (x.as_f32(), w.as_f32());
    let mut out = vec![0.0f32; x.numel()];
    for r in 0..rows {
        let row = &xv[r * d..(r + 1) * d];
        let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for i in 0..d {
            out[r * d + i] = row[i] * inv * wv[i];
        }
    }
    Tensor::f32(x.shape.clone(), out)
}

fn softmax(x: &Tensor, axis: usize) -> Tensor {
    let shape = &x.shape;
    let n_axis = shape[axis];
    let outer: usize = shape[..axis].iter().product();
    let inner: usize = shape[axis + 1..].iter().product();
    let xv = x.as_f32();
    let mut out = vec![0.0f32; x.numel()];
    for o in 0..outer {
        for i in 0..inner {
            let at = |j: usize| (o * n_axis + j) * inner + i;
            let mut mx = f32::NEG_INFINITY;
            for j in 0..n_axis {
                mx = mx.max(xv[at(j)]);
            }
            let mut z = 0.0;
            for j in 0..n_axis {
                let e = (xv[at(j)] - mx).exp();
                out[at(j)] = e;
                z += e;
            }
            for j in 0..n_axis {
                out[at(j)] /= z;
            }
        }
    }
    Tensor::f32(shape.clone(), out)
}

// --- layout ---------------------------------------------------------------------

fn slice(x: &Tensor, axis: usize, start: usize, len: usize) -> Tensor {
    let shape = &x.shape;
    let outer: usize = shape[..axis].iter().product();
    let n_axis = shape[axis];
    let inner: usize = shape[axis + 1..].iter().product();
    let mut oshape = shape.clone();
    oshape[axis] = len;
    match &x.data {
        Data::F32(v) => {
            let mut out = Vec::with_capacity(outer * len * inner);
            for o in 0..outer {
                let base = (o * n_axis + start) * inner;
                out.extend_from_slice(&v[base..base + len * inner]);
            }
            Tensor::f32(oshape, out)
        }
        Data::I32(v) => {
            let mut out = Vec::with_capacity(outer * len * inner);
            for o in 0..outer {
                let base = (o * n_axis + start) * inner;
                out.extend_from_slice(&v[base..base + len * inner]);
            }
            Tensor::i32(oshape, out)
        }
    }
}

fn concat(args: &[&Tensor], axis: usize) -> Tensor {
    let shape0 = &args[0].shape;
    let outer: usize = shape0[..axis].iter().product();
    let inner: usize = shape0[axis + 1..].iter().product();
    let total_axis: usize = args.iter().map(|t| t.shape[axis]).sum();
    let mut oshape = shape0.clone();
    oshape[axis] = total_axis;
    let mut out = Vec::with_capacity(outer * total_axis * inner);
    for o in 0..outer {
        for t in args {
            let na = t.shape[axis];
            let v = t.as_f32();
            out.extend_from_slice(&v[o * na * inner..(o + 1) * na * inner]);
        }
    }
    Tensor::f32(oshape, out)
}

fn transpose(x: &Tensor, perm: &[usize]) -> Tensor {
    let in_shape = &x.shape;
    let out_shape: Vec<usize> = perm.iter().map(|&p| in_shape[p]).collect();
    let in_strides = strides(in_shape);
    let out_n = x.numel();
    let xv = x.as_f32();
    let mut out = vec![0.0f32; out_n];
    let mut idx = vec![0usize; out_shape.len()];
    for o in out.iter_mut() {
        let mut lin = 0;
        for (d, &p) in perm.iter().enumerate() {
            lin += idx[d] * in_strides[p];
        }
        *o = xv[lin];
        for d in (0..out_shape.len()).rev() {
            idx[d] += 1;
            if idx[d] < out_shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    Tensor::f32(out_shape, out)
}

fn broadcast_to(x: &Tensor, shape: &[usize]) -> Tensor {
    let xs = strides(&x.shape);
    let xv = x.as_f32();
    let n = numel(shape);
    let mut out = vec![0.0f32; n];
    let mut idx = vec![0usize; shape.len()];
    for o in out.iter_mut() {
        *o = xv[bcast_index(&idx, &x.shape, &xs)];
        for d in (0..shape.len()).rev() {
            idx[d] += 1;
            if idx[d] < shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    Tensor::f32(shape.to_vec(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(shape: [usize; 2], v: &[f32]) -> Tensor {
        Tensor::f32(shape.to_vec(), v.to_vec())
    }

    #[test]
    fn matmul_2d() {
        let a = t2([2, 3], &[1., 2., 3., 4., 5., 6.]);
        let b = t2([3, 2], &[7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.as_f32(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_batched() {
        // (2,1,2) x (2,2,1)
        let a = Tensor::f32(vec![2, 1, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::f32(vec![2, 2, 1], vec![1., 1., 2., 2.]);
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.shape, vec![2, 1, 1]);
        assert_eq!(c.as_f32(), &[3., 14.]);
    }

    #[test]
    fn cumsum_axis0_matches_paper_def() {
        // C[i,j] = sum_{k<=i} X[k,j]
        let x = t2([3, 2], &[1., 10., 2., 20., 3., 30.]);
        let c = cumsum(&x, 0);
        assert_eq!(c.as_f32(), &[1., 10., 3., 30., 6., 60.]);
    }

    #[test]
    fn cumsum_axis1() {
        let x = t2([2, 3], &[1., 2., 3., 4., 5., 6.]);
        let c = cumsum(&x, 1);
        assert_eq!(c.as_f32(), &[1., 3., 6., 4., 9., 15.]);
    }

    #[test]
    fn cumsum_rank3_middle_axis() {
        // (2,2,2), axis 1
        let x = Tensor::f32(vec![2, 2, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let c = cumsum(&x, 1);
        assert_eq!(c.as_f32(), &[1., 2., 4., 6., 5., 6., 12., 14.]);
    }

    #[test]
    fn reduce_sum_is_last_cumsum_row() {
        // R[j] = C[m,j] (paper §2.1)
        let x = t2([3, 2], &[1., 10., 2., 20., 3., 30.]);
        let r = reduce_sum(&x, 0);
        let c = cumsum(&x, 0);
        assert_eq!(r.as_f32(), &c.as_f32()[4..6]);
        assert_eq!(r.shape, vec![2]);
    }

    #[test]
    fn conv_is_causal() {
        // identity tap on the last position only
        let x = t2([3, 1], &[1., 2., 3.]);
        let w = t2([2, 1], &[0.5, 1.0]); // out[t] = x[t] + 0.5 x[t-1]
        let b = Tensor::f32(vec![1], vec![0.0]);
        let y = conv1d_causal(&x, &w, &b, 2);
        assert_eq!(y.as_f32(), &[1., 2.5, 4.]);
    }

    #[test]
    fn gather_rows() {
        let d = t2([3, 2], &[0., 1., 10., 11., 20., 21.]);
        let i = Tensor::i32(vec![2], vec![2, 0]);
        let g = gather(&d, &i).unwrap();
        assert_eq!(g.as_f32(), &[20., 21., 0., 1.]);
        let bad = Tensor::i32(vec![1], vec![5]);
        assert!(gather(&d, &bad).is_err());
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = t2([1, 4], &[2., 2., 2., 2.]);
        let w = Tensor::f32(vec![4], vec![1.; 4]);
        let y = rmsnorm(&x, &w, 0.0);
        for &v in y.as_f32() {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = t2([2, 3], &[1., 2., 3., 0., 0., 0.]);
        let y = softmax(&x, 1);
        let v = y.as_f32();
        assert!((v[0..3].iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!((v[3] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn transpose_2d() {
        let x = t2([2, 3], &[1., 2., 3., 4., 5., 6.]);
        let y = transpose(&x, &[1, 0]);
        assert_eq!(y.shape, vec![3, 2]);
        assert_eq!(y.as_f32(), &[1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn broadcast_row_to_matrix() {
        let x = Tensor::f32(vec![1, 3], vec![1., 2., 3.]);
        let y = broadcast_to(&x, &[2, 3]);
        assert_eq!(y.as_f32(), &[1., 2., 3., 1., 2., 3.]);
    }

    #[test]
    fn binary_broadcast_scalar() {
        let a = t2([2, 2], &[1., 2., 3., 4.]);
        let s = Tensor::scalar(10.0);
        let y = binary(BinKind::Mul, &a, &s, &[2, 2]).unwrap();
        assert_eq!(y.as_f32(), &[10., 20., 30., 40.]);
    }

    #[test]
    fn binary_scalar_on_left_fast_path() {
        // `scalar op tensor` for a non-commutative op must hit the new
        // fast path and still compute s - x
        let s = Tensor::scalar(10.0);
        let b = t2([2, 2], &[1., 2., 3., 4.]);
        let y = binary(BinKind::Sub, &s, &b, &[2, 2]).unwrap();
        assert_eq!(y.as_f32(), &[9., 8., 7., 6.]);
        // and agree with the generic strided loop on a (1,1) scalar
        let s11 = Tensor::f32(vec![1, 1], vec![10.0]);
        let y2 = binary(BinKind::Sub, &s11, &b, &[2, 2]).unwrap();
        assert_eq!(y.as_f32(), y2.as_f32());
    }

    #[test]
    fn slice_middle_axis() {
        let x = Tensor::f32(vec![2, 3, 2], (0..12).map(|i| i as f32).collect());
        let y = slice(&x, 1, 1, 2);
        assert_eq!(y.shape, vec![2, 2, 2]);
        assert_eq!(y.as_f32(), &[2., 3., 4., 5., 8., 9., 10., 11.]);
    }

    #[test]
    fn concat_axis1() {
        let a = t2([2, 1], &[1., 2.]);
        let b = t2([2, 2], &[3., 4., 5., 6.]);
        let y = concat(&[&a, &b], 1);
        assert_eq!(y.shape, vec![2, 3]);
        assert_eq!(y.as_f32(), &[1., 3., 4., 2., 5., 6.]);
    }
}
