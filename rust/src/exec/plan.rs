//! Plan compilation: one-time analysis of a [`Graph`] into an
//! [`ExecutionPlan`] that executes with zero per-node heap allocation.
//!
//! Compilation produces (a) a topo schedule restricted to the live set,
//! (b) a liveness-based slot assignment into a reusable byte-addressed
//! buffer [`Arena`] (slots are dtype-agnostic: f32, f16, i8 and i32
//! values share one slot pool, so liveness reuse crosses precision
//! boundaries in mixed-precision plans), (c) per-node kernels with
//! dtypes, broadcast strides and loop bounds precomputed, and (d) fused
//! elementwise chains ([`super::fuse`]) at f32 and f16. Executing the
//! plan repeatedly reuses the same arena buffers — the steady-state heap
//! traffic is just the output materialization at the API boundary.
//!
//! Dtype rules are validated here at compile time (the walker would
//! panic at run time): matmul takes equal-dtype operands (i8 operands
//! accumulate exactly in i32 and emit f32; f16 operands accumulate in
//! f32 and round once at store), elementwise/scan/reduce ops are
//! dtype-preserving, and `Quantize`/`Dequantize` are the only precision
//! boundaries. i8 compute steps stage their f32 result in a scratch
//! buffer and requantize with a dynamic per-tensor scale kept in the
//! arena's per-slot scale table.

use std::collections::HashMap;
use std::sync::Arc;

use crate::graph::op::{BinKind, Op, UnKind};
use crate::graph::tensor::{numel, strides, Data, DType, Tensor};
use crate::graph::{Graph, Node, NodeId};
use crate::plu::PluTable;
use crate::util::f16::{f16_to_f32, f32_to_f16};

use super::arena::{cast_slice_mut, Arena, SlotAlloc};
use super::fuse::{self, ChainHead, ElemStage};
use super::kernels::{self, BinMode, DataRef, View};
use super::pool::{intra_workers_from_env, parallel_chunks_mut};
use super::{Backend, Plan};

/// Topological schedule over the live (output-reachable) nodes. Shared
/// between plan compilation and the NPU cost profiler so both price and
/// execute exactly the same node set.
pub struct Schedule {
    pub live: Vec<bool>,
    /// Live node ids in executable (ascending) order — includes Input
    /// and Const nodes.
    pub order: Vec<NodeId>,
}

impl Schedule {
    pub fn of(g: &Graph) -> Self {
        let live = g.live_set();
        let order: Vec<NodeId> = g.topo_order().filter(|&id| live[id]).collect();
        Self { live, order }
    }
}

/// Where a value lives at execution time.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Loc {
    /// Borrowed from the caller's input slice.
    Input(usize),
    /// A constant payload owned by the plan.
    Const(usize),
    /// A byte-arena slot (dtype carried by the [`ValueRef`]).
    Slot(usize),
}

/// A value reference: location plus the static dtype/shape metadata
/// kernels need (precomputed so execution never re-derives it).
#[derive(Clone, Debug)]
struct ValueRef {
    loc: Loc,
    dtype: DType,
    shape: Vec<usize>,
    numel: usize,
}

/// A compiled operator with its loop bounds / strides resolved.
#[derive(Clone, Debug)]
enum Kernel {
    MatMul { batch: usize, m: usize, k: usize, n: usize, a_step: usize, b_step: usize },
    Binary { kind: BinKind, mode: BinMode },
    Unary(UnKind),
    Plu(Arc<PluTable>),
    CumSum { outer: usize, n_axis: usize, inner: usize },
    ReduceSum { outer: usize, n_axis: usize, inner: usize },
    Gather { row: usize, vocab: usize },
    Conv1d { batch: usize, t: usize, c: usize, k: usize },
    RmsNorm { rows: usize, d: usize, eps: f32 },
    Softmax { outer: usize, n_axis: usize, inner: usize },
    Slice { outer: usize, n_axis: usize, inner: usize, start: usize, len: usize },
    Concat { outer: usize, inner: usize, parts: Vec<usize> },
    Copy,
    /// Transpose / Broadcast: per-output-dim input strides.
    StridedCopy { strides: Vec<usize> },
    /// f32 -> f16 / i8 narrowing (i8 computes its scale dynamically).
    Quantize(DType),
    /// f16 / i8 -> f32 widening.
    Dequantize,
    /// Fused `Binary -> ReduceSum` reduction epilogue: the binary's
    /// virtual output (`shape`, operand broadcast strides `sa`/`sb`) is
    /// reduced along `axis` without ever being materialized.
    BinaryReduceSum { kind: BinKind, axis: usize, shape: Vec<usize>, sa: Vec<usize>, sb: Vec<usize> },
}

/// What feeds a fused chain at execution time.
#[derive(Clone, Debug)]
enum FusedHead {
    Value(ValueRef),
    Binary(BinKind, ValueRef, ValueRef),
    /// A GEMM (its resolved `Kernel::MatMul`) computing into the chain's
    /// output slot; the stages run as an in-place epilogue pass.
    MatMul(Box<Kernel>, ValueRef, ValueRef),
}

#[derive(Clone, Debug)]
enum StepKind {
    Kernel { kernel: Kernel, args: Vec<ValueRef> },
    Fused { head: FusedHead, stages: Vec<ElemStage> },
}

#[derive(Clone, Debug)]
struct Step {
    out: Loc,
    out_dtype: DType,
    out_shape: Vec<usize>,
    out_numel: usize,
    kind: StepKind,
    /// `node <id> (<name>)` — error attribution, matches the walker.
    label: String,
}

/// A graph compiled for repeated execution.
pub struct ExecutionPlan {
    graph_name: String,
    input_ids: Vec<NodeId>,
    input_names: Vec<String>,
    input_shapes: Vec<Vec<usize>>,
    input_dtypes: Vec<DType>,
    consts: Vec<Tensor>,
    steps: Vec<Step>,
    outputs: Vec<ValueRef>,
    arena: Arena,
    /// Odometer scratch for strided kernels (capacity reserved once).
    scratch: Vec<usize>,
    /// f32 staging buffer for i8 compute steps (allocated once at
    /// compile to the largest i8 result in the plan).
    fscratch: Vec<f32>,
    fused_away: usize,
    live_compute_nodes: usize,
    /// Intra-op worker count for splitting large kernels (GEMM row
    /// panels, elementwise slabs). 1 = serial. Chunk boundaries are
    /// worker-count-independent, so results are identical at any value.
    intra_workers: usize,
}

impl ExecutionPlan {
    /// Compile `graph`. Shape/arity/dtype problems the walker would hit
    /// at run time (matmul mismatches, missing const payloads, unbound
    /// inputs, unsupported dtype combinations) surface here instead.
    pub fn compile(g: &Graph) -> Result<ExecutionPlan, String> {
        let schedule = Schedule::of(g);
        let n = g.nodes.len();

        // --- locations for inputs and constants --------------------------
        let mut loc: Vec<Option<Loc>> = vec![None; n];
        for (k, &id) in g.inputs.iter().enumerate() {
            loc[id] = Some(Loc::Input(k));
        }
        let mut consts: Vec<Tensor> = Vec::new();
        for &id in &schedule.order {
            let node = g.node(id);
            match &node.op {
                Op::Const { .. } => {
                    let v = node
                        .value
                        .clone()
                        .ok_or_else(|| format!("const node {id} without value"))?;
                    loc[id] = Some(Loc::Const(consts.len()));
                    consts.push(v);
                }
                Op::Input { .. } if loc[id].is_none() => {
                    return Err(format!("unbound input node {id} ({})", node.name));
                }
                _ => {}
            }
        }

        // --- fusion + per-node kernel selection ---------------------------
        let chains = fuse::find_chains(g, &schedule.live);
        let mut mid = vec![false; n];
        let mut chain_of_last: HashMap<NodeId, usize> = HashMap::new();
        for (ci, ch) in chains.iter().enumerate() {
            for &m in &ch.nodes[..ch.nodes.len() - 1] {
                mid[m] = true;
            }
            chain_of_last.insert(*ch.nodes.last().unwrap(), ci);
        }

        enum ProtoKind {
            Kernel(Kernel, Vec<NodeId>),
            Fused(ChainHead, Vec<ElemStage>),
        }
        struct Proto {
            out: NodeId,
            kind: ProtoKind,
        }

        let mut protos: Vec<Proto> = Vec::new();
        let mut live_compute_nodes = 0usize;
        let mut fused_away = 0usize;
        for &id in &schedule.order {
            let node = g.node(id);
            if matches!(node.op, Op::Input { .. } | Op::Const { .. }) {
                continue;
            }
            live_compute_nodes += 1;
            if mid[id] {
                continue; // absorbed into a fused chain
            }
            let kind = if let Some(&ci) = chain_of_last.get(&id) {
                let ch = &chains[ci];
                if !matches!(node.dtype, DType::F32 | DType::F16) {
                    return Err(format!(
                        "node {id} ({}): fused chain at unsupported dtype {:?}",
                        node.name, node.dtype
                    ));
                }
                // chain members get the same compile-time dtype checks
                // as standalone kernels (a malformed hand-assembled node
                // must fail here, not panic inside the fused loop)
                for &m in &ch.nodes {
                    check_dtypes(g, g.node(m))
                        .map_err(|e| format!("node {m} ({}): {e}", g.node(m).name))?;
                }
                fused_away += ch.nodes.len() - 1;
                ProtoKind::Fused(ch.head.clone(), ch.stages.clone())
            } else {
                check_dtypes(g, node).map_err(|e| format!("node {id} ({}): {e}", node.name))?;
                let kernel = kernel_for(g, node)
                    .map_err(|e| format!("node {id} ({}): {e}", node.name))?;
                if node.dtype == DType::I32
                    && !matches!(
                        kernel,
                        Kernel::Copy | Kernel::Slice { .. } | Kernel::Concat { .. }
                    )
                {
                    return Err(format!(
                        "node {id} ({}): i32 output unsupported for {}",
                        node.name,
                        node.op.census_name()
                    ));
                }
                ProtoKind::Kernel(kernel, node.inputs.clone())
            };
            protos.push(Proto { out: id, kind });
        }

        // --- Binary -> ReduceSum reduction epilogues ----------------------
        // A reduction whose sole input is a single-consumer, non-output
        // binary collapses into one fused kernel, so the (often much
        // larger) binary intermediate never gets an arena slot or a
        // store/reload round trip. Bitwise neutral: the fused kernel
        // mirrors the unfused store-then-reduce value sequence exactly
        // (see kernels::binary_reduce_sum_out).
        {
            let mut is_out = vec![false; n];
            for &o in &g.outputs {
                is_out[o] = true;
            }
            let mut cnt = vec![0usize; n];
            for p in &protos {
                match &p.kind {
                    ProtoKind::Kernel(_, args) => {
                        for &a in args {
                            cnt[a] += 1;
                        }
                    }
                    ProtoKind::Fused(head, _) => match head {
                        ChainHead::Value(x) => cnt[*x] += 1,
                        ChainHead::Binary(_, a, b) => {
                            cnt[*a] += 1;
                            cnt[*b] += 1;
                        }
                        ChainHead::MatMul(mm) => {
                            for &a in &g.node(*mm).inputs {
                                cnt[a] += 1;
                            }
                        }
                    },
                }
            }
            let produced: HashMap<NodeId, usize> =
                protos.iter().enumerate().map(|(i, p)| (p.out, i)).collect();
            let mut dead = vec![false; protos.len()];
            for ri in 0..protos.len() {
                let ProtoKind::Kernel(Kernel::ReduceSum { .. }, rargs) = &protos[ri].kind
                else {
                    continue;
                };
                let x = rargs[0];
                if is_out[x]
                    || cnt[x] != 1
                    || !matches!(g.node(x).dtype, DType::F32 | DType::F16)
                {
                    continue;
                }
                let Some(&bi) = produced.get(&x) else { continue };
                if dead[bi] {
                    continue;
                }
                let ProtoKind::Kernel(Kernel::Binary { kind, .. }, bargs) = &protos[bi].kind
                else {
                    continue;
                };
                let Op::ReduceSum { axis } = &g.node(protos[ri].out).op else {
                    continue;
                };
                let shape = g.shape(x).to_vec();
                let sa = kernels::bcast_strides(&shape, g.shape(bargs[0]));
                let sb = kernels::bcast_strides(&shape, g.shape(bargs[1]));
                let (kind, axis, bargs) = (*kind, *axis, bargs.clone());
                protos[ri].kind = ProtoKind::Kernel(
                    Kernel::BinaryReduceSum { kind, axis, shape, sa, sb },
                    bargs,
                );
                dead[bi] = true;
                fused_away += 1;
            }
            if dead.contains(&true) {
                let mut i = 0;
                protos.retain(|_| {
                    let keep = !dead[i];
                    i += 1;
                    keep
                });
            }
        }

        // --- use counts (graph outputs pinned) ----------------------------
        let mut uses = vec![0usize; n];
        for p in &protos {
            match &p.kind {
                ProtoKind::Kernel(_, args) => {
                    for &a in args {
                        uses[a] += 1;
                    }
                }
                ProtoKind::Fused(head, _) => match head {
                    ChainHead::Value(x) => uses[*x] += 1,
                    ChainHead::Binary(_, a, b) => {
                        uses[*a] += 1;
                        uses[*b] += 1;
                    }
                    ChainHead::MatMul(mm) => {
                        for &a in &g.node(*mm).inputs {
                            uses[a] += 1;
                        }
                    }
                },
            }
        }
        for &o in &g.outputs {
            uses[o] += 1; // never decremented: output slots are never reused
        }

        // --- slot assignment with last-use release ------------------------
        let mut alloc = SlotAlloc::new();
        let mut steps: Vec<Step> = Vec::with_capacity(protos.len());
        let mut fscratch_len = 0usize;

        let vref = |loc: &Vec<Option<Loc>>, id: NodeId| -> ValueRef {
            let node = g.node(id);
            ValueRef {
                loc: loc[id].expect("value location resolved in topo order"),
                dtype: node.dtype,
                shape: node.shape.clone(),
                numel: numel(&node.shape),
            }
        };

        for p in &protos {
            let node = g.node(p.out);
            let nel = numel(&node.shape);
            // the output slot is assigned BEFORE the argument slots are
            // released, so a step never aliases its own inputs
            let out_loc = Loc::Slot(alloc.alloc(nel * node.dtype.size_bytes()));
            loc[p.out] = Some(out_loc);
            if node.dtype == DType::I8 {
                fscratch_len = fscratch_len.max(nel);
            }

            let mut arg_ids: Vec<NodeId> = Vec::new();
            let kind = match &p.kind {
                ProtoKind::Kernel(kernel, args) => {
                    arg_ids.extend_from_slice(args);
                    StepKind::Kernel {
                        kernel: kernel.clone(),
                        args: args.iter().map(|&a| vref(&loc, a)).collect(),
                    }
                }
                ProtoKind::Fused(head, stages) => {
                    let fh = match head {
                        ChainHead::Value(x) => {
                            arg_ids.push(*x);
                            FusedHead::Value(vref(&loc, *x))
                        }
                        ChainHead::Binary(k, a, b) => {
                            arg_ids.push(*a);
                            arg_ids.push(*b);
                            FusedHead::Binary(*k, vref(&loc, *a), vref(&loc, *b))
                        }
                        ChainHead::MatMul(mm) => {
                            let mm_node = g.node(*mm);
                            let kernel = kernel_for(g, mm_node)
                                .map_err(|e| format!("node {mm} ({}): {e}", mm_node.name))?;
                            let (a, b) = (mm_node.inputs[0], mm_node.inputs[1]);
                            arg_ids.push(a);
                            arg_ids.push(b);
                            FusedHead::MatMul(
                                Box::new(kernel),
                                vref(&loc, a),
                                vref(&loc, b),
                            )
                        }
                    };
                    StepKind::Fused { head: fh, stages: stages.clone() }
                }
            };
            steps.push(Step {
                out: out_loc,
                out_dtype: node.dtype,
                out_shape: node.shape.clone(),
                out_numel: nel,
                kind,
                label: format!("node {} ({})", p.out, node.name),
            });

            for &a in &arg_ids {
                uses[a] -= 1;
                if uses[a] == 0 {
                    if let Some(Loc::Slot(s)) = loc[a] {
                        alloc.release(s);
                    }
                }
            }
        }

        // --- outputs ------------------------------------------------------
        let outputs: Vec<ValueRef> =
            g.outputs.iter().map(|&o| vref(&loc, o)).collect();

        let max_rank = g
            .nodes
            .iter()
            .map(|nd| nd.shape.len())
            .max()
            .unwrap_or(0);

        Ok(ExecutionPlan {
            graph_name: g.name.clone(),
            input_ids: g.inputs.clone(),
            input_names: g.inputs.iter().map(|&i| g.node(i).name.clone()).collect(),
            input_shapes: g.inputs.iter().map(|&i| g.node(i).shape.clone()).collect(),
            input_dtypes: g.inputs.iter().map(|&i| g.node(i).dtype).collect(),
            consts,
            steps,
            outputs,
            arena: Arena::from_sizes(&alloc.sizes),
            scratch: Vec::with_capacity(max_rank),
            fscratch: vec![0.0; fscratch_len],
            fused_away,
            live_compute_nodes,
            intra_workers: intra_workers_from_env(),
        })
    }

    /// Override the intra-op worker count (tests assert result identity
    /// across 1/2/4; serving respects `XAMBA_INTRA_THREADS`).
    pub fn with_intra_workers(mut self, workers: usize) -> Self {
        self.intra_workers = workers.max(1);
        self
    }

    /// Execute the plan on `inputs` (graph input order). Arena slots are
    /// reused across calls; only the returned output tensors allocate.
    pub fn run(&mut self, inputs: &[Tensor]) -> Result<Vec<Tensor>, String> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Execute on `prefix ++ tail` without materializing a contiguous
    /// input vector — the serving plan cache shares `prefix` (the model
    /// parameters) across plans and pool workers through an `Arc`, so a
    /// call costs a handful of pointer pushes instead of a parameter
    /// copy.
    pub fn run_with_prefix(
        &mut self,
        prefix: &[Tensor],
        tail: &[Tensor],
    ) -> Result<Vec<Tensor>, String> {
        let refs: Vec<&Tensor> = prefix.iter().chain(tail.iter()).collect();
        self.run_refs(&refs)
    }

    fn run_refs(&mut self, inputs: &[&Tensor]) -> Result<Vec<Tensor>, String> {
        if inputs.len() != self.input_shapes.len() {
            return Err(format!(
                "graph {} expects {} inputs, got {}",
                self.graph_name,
                self.input_shapes.len(),
                inputs.len()
            ));
        }
        for (k, t) in inputs.iter().enumerate() {
            if t.shape != self.input_shapes[k] {
                return Err(format!(
                    "input {} ({}): expected shape {:?}, got {:?}",
                    self.input_ids[k], self.input_names[k], self.input_shapes[k], t.shape
                ));
            }
            if t.dtype() != self.input_dtypes[k] {
                return Err(format!(
                    "input {} ({}): dtype mismatch (expected {}, got {})",
                    self.input_ids[k],
                    self.input_names[k],
                    self.input_dtypes[k].name(),
                    t.dtype().name()
                ));
            }
        }

        let Self { steps, arena, consts, scratch, fscratch, intra_workers, .. } = self;
        for step in steps.iter() {
            exec_step(step, arena, consts, inputs, scratch, fscratch, *intra_workers)?;
        }

        self.outputs
            .iter()
            .map(|r| {
                Ok(match r.loc {
                    Loc::Input(k) => inputs[k].clone(),
                    Loc::Const(c) => self.consts[c].clone(),
                    Loc::Slot(s) => match r.dtype {
                        DType::F32 => Tensor::f32(
                            r.shape.clone(),
                            self.arena.view::<f32>(s, r.numel).to_vec(),
                        ),
                        DType::I32 => Tensor::i32(
                            r.shape.clone(),
                            self.arena.view::<i32>(s, r.numel).to_vec(),
                        ),
                        DType::F16 => Tensor::f16(
                            r.shape.clone(),
                            self.arena.view::<u16>(s, r.numel).to_vec(),
                        ),
                        DType::I8 => Tensor::i8(
                            r.shape.clone(),
                            self.arena.view::<i8>(s, r.numel).to_vec(),
                            self.arena.scales[s],
                        ),
                    },
                })
            })
            .collect()
    }

    /// Number of executable steps (after fusion).
    pub fn step_count(&self) -> usize {
        self.steps.len()
    }

    /// How many live compute nodes were absorbed into fused chains.
    pub fn fused_node_count(&self) -> usize {
        self.fused_away
    }

    /// Live compute nodes in the source graph (pre-fusion).
    pub fn compute_node_count(&self) -> usize {
        self.live_compute_nodes
    }

    /// Number of distinct arena slots — the live-range width, typically
    /// far below the node count thanks to (cross-dtype) slot reuse.
    pub fn slot_count(&self) -> usize {
        self.arena.slots()
    }

    /// Bytes held by the reusable arena.
    pub fn arena_bytes(&self) -> usize {
        self.arena.bytes()
    }
}

impl Plan for ExecutionPlan {
    fn execute(&mut self, inputs: &[Tensor]) -> Result<Vec<Tensor>, String> {
        self.run(inputs)
    }
}

/// The planned-executor [`Backend`].
pub struct PlannedBackend;

impl Backend for PlannedBackend {
    fn name(&self) -> &'static str {
        "planned"
    }

    fn plan(&self, graph: &Graph) -> Result<Box<dyn Plan>, String> {
        Ok(Box::new(ExecutionPlan::compile(graph)?))
    }
}

// --- compile helpers ------------------------------------------------------------

/// Validate a node's dtype signature (the builder enforces these for
/// builder-built graphs; pass-rewritten and hand-assembled graphs get
/// the same rules re-checked here, where a violation is a compile error
/// instead of a kernel panic).
fn check_dtypes(g: &Graph, node: &Node) -> Result<(), String> {
    let dt = node.dtype;
    let in_dt = |i: usize| g.node(node.inputs[i]).dtype;
    let value = |d: DType| matches!(d, DType::F32 | DType::F16 | DType::I8);
    let float = |d: DType| matches!(d, DType::F32 | DType::F16);
    match &node.op {
        Op::MatMul => {
            let (a, b) = (in_dt(0), in_dt(1));
            if a != b || !value(a) {
                return Err(format!("matmul operand dtypes {a:?} x {b:?} unsupported"));
            }
            let want = if a == DType::I8 { DType::F32 } else { a };
            if dt != want {
                return Err(format!("matmul {a:?} operands must emit {want:?}, not {dt:?}"));
            }
        }
        Op::Binary(_) => {
            if in_dt(0) != dt || in_dt(1) != dt || !value(dt) {
                return Err(format!(
                    "binary needs matching value dtypes, got {:?} op {:?} -> {dt:?}",
                    in_dt(0),
                    in_dt(1)
                ));
            }
        }
        Op::Unary(_) | Op::CumSum { .. } | Op::ReduceSum { .. } => {
            if in_dt(0) != dt || !value(dt) {
                return Err(format!("dtype {:?} -> {dt:?} unsupported here", in_dt(0)));
            }
        }
        Op::Plu { .. } | Op::Softmax { .. } => {
            if in_dt(0) != dt || !float(dt) {
                return Err(format!("needs f32/f16, got {:?} -> {dt:?}", in_dt(0)));
            }
        }
        Op::Conv1dCausal { .. } => {
            if !float(dt) || in_dt(0) != dt || in_dt(1) != dt || in_dt(2) != dt {
                return Err("conv1d needs uniform f32/f16 operands".into());
            }
        }
        Op::RmsNorm { .. } => {
            if !float(dt) || in_dt(0) != dt || in_dt(1) != dt {
                return Err("rmsnorm needs uniform f32/f16 operands".into());
            }
        }
        Op::Gather => {
            if in_dt(0) != dt || !value(dt) || in_dt(1) != DType::I32 {
                return Err(format!(
                    "gather needs value-dtype data + i32 indices, got {:?}[{:?}]",
                    in_dt(0),
                    in_dt(1)
                ));
            }
        }
        Op::Quantize { dtype } => {
            if in_dt(0) != DType::F32 || dt != *dtype
                || !matches!(dtype, DType::F16 | DType::I8)
            {
                return Err(format!("quantize f32 -> {dtype:?} got {:?} -> {dt:?}", in_dt(0)));
            }
        }
        Op::Dequantize => {
            if !matches!(in_dt(0), DType::F16 | DType::I8) || dt != DType::F32 {
                return Err(format!("dequantize {:?} -> {dt:?} unsupported", in_dt(0)));
            }
        }
        Op::Slice { .. } | Op::Reshape { .. } | Op::Transpose { .. }
        | Op::Broadcast { .. } => {
            if in_dt(0) != dt {
                return Err(format!("layout op changed dtype {:?} -> {dt:?}", in_dt(0)));
            }
        }
        Op::Concat { .. } => {
            for (i, _) in node.inputs.iter().enumerate() {
                if in_dt(i) != dt {
                    return Err(format!("concat operand {i} dtype {:?} != {dt:?}", in_dt(i)));
                }
            }
        }
        Op::Input { .. } | Op::Const { .. } => {}
    }
    Ok(())
}

fn kernel_for(g: &Graph, node: &Node) -> Result<Kernel, String> {
    Ok(match &node.op {
        Op::Input { .. } | Op::Const { .. } => unreachable!("handled by caller"),
        Op::MatMul => {
            let sa = g.shape(node.inputs[0]);
            let sb = g.shape(node.inputs[1]);
            let (ra, rb) = (sa.len(), sb.len());
            if ra < 2 || rb < 2 {
                return Err("matmul needs rank >= 2".into());
            }
            let (m, k) = (sa[ra - 2], sa[ra - 1]);
            let (k2, nn) = (sb[rb - 2], sb[rb - 1]);
            if k != k2 {
                return Err(format!("matmul k mismatch {k} vs {k2}"));
            }
            let batch_a: usize = sa[..ra - 2].iter().product();
            let batch_b: usize = sb[..rb - 2].iter().product();
            let batch = batch_a.max(batch_b);
            if batch_a != batch && batch_a != 1 && ra != 2 {
                return Err("matmul batch mismatch".into());
            }
            if batch * m * nn != numel(&node.shape) {
                return Err(format!(
                    "matmul output shape {:?} does not hold {batch}x{m}x{nn}",
                    node.shape
                ));
            }
            Kernel::MatMul {
                batch,
                m,
                k,
                n: nn,
                a_step: if batch_a == 1 { 0 } else { m * k },
                b_step: if batch_b == 1 { 0 } else { k * nn },
            }
        }
        Op::Binary(kind) => {
            let sa = g.shape(node.inputs[0]);
            let sb = g.shape(node.inputs[1]);
            let out = node.shape.as_slice();
            let mode = if sa == out && sb == out {
                BinMode::Elementwise
            } else if numel(sb) == 1 && sa == out {
                BinMode::ScalarRight
            } else if numel(sa) == 1 && sb == out {
                BinMode::ScalarLeft
            } else {
                BinMode::Strided {
                    sa: kernels::bcast_strides(out, sa),
                    sb: kernels::bcast_strides(out, sb),
                }
            };
            Kernel::Binary { kind: *kind, mode }
        }
        Op::Unary(k) => Kernel::Unary(*k),
        Op::Plu { table, .. } => Kernel::Plu(table.clone()),
        Op::CumSum { axis } => {
            let s = g.shape(node.inputs[0]);
            Kernel::CumSum {
                outer: s[..*axis].iter().product(),
                n_axis: s[*axis],
                inner: s[*axis + 1..].iter().product(),
            }
        }
        Op::ReduceSum { axis } => {
            let s = g.shape(node.inputs[0]);
            Kernel::ReduceSum {
                outer: s[..*axis].iter().product(),
                n_axis: s[*axis],
                inner: s[*axis + 1..].iter().product(),
            }
        }
        Op::Gather => {
            let sd = g.shape(node.inputs[0]);
            Kernel::Gather { row: sd[1..].iter().product(), vocab: sd[0] }
        }
        Op::Conv1dCausal { k } => {
            let sx = g.shape(node.inputs[0]);
            let (batch, t, c) = match sx {
                [t, c] => (1, *t, *c),
                [batch, t, c] => (*batch, *t, *c),
                _ => unreachable!("conv1d rank checked at graph build"),
            };
            Kernel::Conv1d { batch, t, c, k: *k }
        }
        Op::RmsNorm { eps } => {
            let sx = g.shape(node.inputs[0]);
            let d = *sx.last().unwrap();
            Kernel::RmsNorm { rows: numel(sx) / d, d, eps: *eps }
        }
        Op::Softmax { axis } => {
            let s = g.shape(node.inputs[0]);
            Kernel::Softmax {
                outer: s[..*axis].iter().product(),
                n_axis: s[*axis],
                inner: s[*axis + 1..].iter().product(),
            }
        }
        Op::Slice { axis, start, len } => {
            let s = g.shape(node.inputs[0]);
            Kernel::Slice {
                outer: s[..*axis].iter().product(),
                n_axis: s[*axis],
                inner: s[*axis + 1..].iter().product(),
                start: *start,
                len: *len,
            }
        }
        Op::Concat { axis } => {
            let s0 = g.shape(node.inputs[0]);
            Kernel::Concat {
                outer: s0[..*axis].iter().product(),
                inner: s0[*axis + 1..].iter().product(),
                parts: node.inputs.iter().map(|&i| g.shape(i)[*axis]).collect(),
            }
        }
        Op::Reshape { .. } => Kernel::Copy,
        Op::Transpose { perm } => {
            let st = strides(g.shape(node.inputs[0]));
            Kernel::StridedCopy { strides: perm.iter().map(|&p| st[p]).collect() }
        }
        Op::Broadcast { .. } => Kernel::StridedCopy {
            strides: kernels::bcast_strides(&node.shape, g.shape(node.inputs[0])),
        },
        Op::Quantize { dtype } => Kernel::Quantize(*dtype),
        Op::Dequantize => Kernel::Dequantize,
    })
}

// --- execution ------------------------------------------------------------------

fn view<'a>(
    r: &'a ValueRef,
    arena: &'a Arena,
    consts: &'a [Tensor],
    inputs: &'a [&'a Tensor],
) -> View<'a> {
    let data = match r.loc {
        Loc::Input(k) => tensor_ref(inputs[k]),
        Loc::Const(c) => tensor_ref(&consts[c]),
        Loc::Slot(s) => match r.dtype {
            DType::F32 => DataRef::F32(arena.view::<f32>(s, r.numel)),
            DType::I32 => DataRef::I32(arena.view::<i32>(s, r.numel)),
            DType::F16 => DataRef::F16(arena.view::<u16>(s, r.numel)),
            DType::I8 => DataRef::I8(arena.view::<i8>(s, r.numel), arena.scales[s]),
        },
    };
    View { shape: &r.shape, data }
}

fn tensor_ref(t: &Tensor) -> DataRef<'_> {
    match &t.data {
        Data::F32(v) => DataRef::F32(v),
        Data::I32(v) => DataRef::I32(v),
        Data::F16(v) => DataRef::F16(v),
        Data::I8 { data, scale } => DataRef::I8(data, *scale),
    }
}

#[allow(clippy::too_many_arguments)]
fn exec_step(
    step: &Step,
    arena: &mut Arena,
    consts: &[Tensor],
    inputs: &[&Tensor],
    scratch: &mut Vec<usize>,
    fscratch: &mut [f32],
    workers: usize,
) -> Result<(), String> {
    let Loc::Slot(s) = step.out else {
        unreachable!("compute step writes to a slot")
    };
    let mut buf = arena.take(s);
    let res = match step.out_dtype {
        DType::F32 => run_f32(
            step,
            cast_slice_mut::<f32>(&mut buf, step.out_numel),
            arena,
            consts,
            inputs,
            scratch,
            workers,
        )
        .map(|()| None),
        DType::F16 => run_f16(
            step,
            cast_slice_mut::<u16>(&mut buf, step.out_numel),
            arena,
            consts,
            inputs,
            scratch,
            workers,
        )
        .map(|()| None),
        DType::I8 => run_i8(
            step,
            cast_slice_mut::<i8>(&mut buf, step.out_numel),
            arena,
            consts,
            inputs,
            scratch,
            fscratch,
        )
        .map(Some),
        DType::I32 => run_i32(
            step,
            cast_slice_mut::<i32>(&mut buf, step.out_numel),
            arena,
            consts,
            inputs,
        )
        .map(|()| None),
    };
    arena.put(s, buf);
    match res {
        Ok(Some(scale)) => {
            arena.scales[s] = scale;
            Ok(())
        }
        Ok(None) => Ok(()),
        Err(e) => Err(format!("{}: {e}", step.label)),
    }
}

/// Run `f(offset, chunk)` over `out`, splitting across intra-op workers
/// when the node is large enough (chunk boundaries are worker-count-
/// independent, so any split is bitwise-identical to the serial pass).
fn for_chunks<T: Send>(out: &mut [T], workers: usize, f: impl Fn(usize, &mut [T]) + Sync) {
    if workers > 1 && out.len() >= kernels::INTRA_ELEM_MIN {
        parallel_chunks_mut(out, kernels::INTRA_ELEM_GRAIN, workers, &f);
    } else {
        f(0, out);
    }
}

#[allow(clippy::too_many_arguments)]
fn run_f32(
    step: &Step,
    out: &mut [f32],
    arena: &Arena,
    consts: &[Tensor],
    inputs: &[&Tensor],
    scratch: &mut Vec<usize>,
    workers: usize,
) -> Result<(), String> {
    match &step.kind {
        StepKind::Fused { head, stages } => {
            match head {
                FusedHead::Value(x) => {
                    let xv = view(x, arena, consts, inputs).f32();
                    for_chunks(out, workers, |off, chunk| {
                        for (o, &v) in chunk.iter_mut().zip(&xv[off..off + chunk.len()]) {
                            let mut acc = v;
                            for st in stages {
                                acc = st.apply(acc);
                            }
                            *o = acc;
                        }
                    });
                }
                FusedHead::Binary(kind, a, b) => {
                    let av = view(a, arena, consts, inputs).f32();
                    let bv = view(b, arena, consts, inputs).f32();
                    for_chunks(out, workers, |off, chunk| {
                        for (i, o) in chunk.iter_mut().enumerate() {
                            let mut acc =
                                kernels::apply_binary(*kind, av[off + i], bv[off + i]);
                            for st in stages {
                                acc = st.apply(acc);
                            }
                            *o = acc;
                        }
                    });
                }
                FusedHead::MatMul(kernel, a, b) => {
                    let Kernel::MatMul { batch, m, k, n, a_step, b_step } = kernel.as_ref()
                    else {
                        unreachable!("matmul chain head carries a matmul kernel")
                    };
                    if a.dtype == DType::I8 {
                        let (qa, sa) = view(a, arena, consts, inputs).i8();
                        let (qb, sb) = view(b, arena, consts, inputs).i8();
                        kernels::matmul_i8_out_mt(
                            qa, sa, qb, sb, out, *batch, *m, *k, *n, *a_step, *b_step,
                            workers,
                        );
                    } else {
                        kernels::matmul_out_mt(
                            view(a, arena, consts, inputs).f32(),
                            view(b, arena, consts, inputs).f32(),
                            out,
                            *batch,
                            *m,
                            *k,
                            *n,
                            *a_step,
                            *b_step,
                            workers,
                        );
                    }
                    for_chunks(out, workers, |_, chunk| {
                        for o in chunk.iter_mut() {
                            let mut acc = *o;
                            for st in stages {
                                acc = st.apply(acc);
                            }
                            *o = acc;
                        }
                    });
                }
            }
            Ok(())
        }
        StepKind::Kernel { kernel, args } => {
            let v = |i: usize| view(&args[i], arena, consts, inputs);
            match kernel {
                Kernel::MatMul { batch, m, k, n, a_step, b_step } => {
                    if args[0].dtype == DType::I8 {
                        let (qa, sa) = v(0).i8();
                        let (qb, sb) = v(1).i8();
                        kernels::matmul_i8_out_mt(
                            qa, sa, qb, sb, out, *batch, *m, *k, *n, *a_step, *b_step,
                            workers,
                        );
                    } else {
                        kernels::matmul_out_mt(
                            v(0).f32(),
                            v(1).f32(),
                            out,
                            *batch,
                            *m,
                            *k,
                            *n,
                            *a_step,
                            *b_step,
                            workers,
                        );
                    }
                    Ok(())
                }
                Kernel::Binary { kind, mode } => {
                    kernels::binary_out_mt::<f32>(
                        *kind,
                        mode,
                        v(0).f32(),
                        v(1).f32(),
                        &step.out_shape,
                        out,
                        scratch,
                        workers,
                    );
                    Ok(())
                }
                Kernel::BinaryReduceSum { kind, axis, shape, sa, sb } => {
                    kernels::binary_reduce_sum_out(
                        *kind,
                        v(0).f32(),
                        v(1).f32(),
                        sa,
                        sb,
                        shape,
                        *axis,
                        out,
                        scratch,
                    );
                    Ok(())
                }
                Kernel::Unary(k) => {
                    kernels::unary_out_mt::<f32>(*k, v(0).f32(), out, workers);
                    Ok(())
                }
                Kernel::Plu(table) => {
                    kernels::plu_out_mt::<f32>(table, v(0).f32(), out, workers);
                    Ok(())
                }
                Kernel::CumSum { outer, n_axis, inner } => {
                    kernels::cumsum_out_mt::<f32>(
                        v(0).f32(),
                        out,
                        *outer,
                        *n_axis,
                        *inner,
                        workers,
                    );
                    Ok(())
                }
                Kernel::ReduceSum { outer, n_axis, inner } => {
                    kernels::reduce_sum_out_mt::<f32>(
                        v(0).f32(),
                        out,
                        *outer,
                        *n_axis,
                        *inner,
                        workers,
                    );
                    Ok(())
                }
                Kernel::Gather { row, vocab } => {
                    kernels::gather_out(v(0).f32(), v(1).i32(), out, *row, *vocab)
                }
                Kernel::Conv1d { batch, t, c, k } => {
                    kernels::conv1d_out_mt::<f32>(
                        v(0).f32(),
                        v(1).f32(),
                        v(2).f32(),
                        out,
                        *batch,
                        *t,
                        *c,
                        *k,
                        workers,
                    );
                    Ok(())
                }
                Kernel::RmsNorm { rows, d, eps } => {
                    kernels::rmsnorm_out_mt::<f32>(
                        v(0).f32(),
                        v(1).f32(),
                        out,
                        *rows,
                        *d,
                        *eps,
                        workers,
                    );
                    Ok(())
                }
                Kernel::Softmax { outer, n_axis, inner } => {
                    kernels::softmax_out_mt::<f32>(
                        v(0).f32(),
                        out,
                        *outer,
                        *n_axis,
                        *inner,
                        workers,
                    );
                    Ok(())
                }
                Kernel::Slice { outer, n_axis, inner, start, len } => {
                    kernels::slice_out(v(0).f32(), out, *outer, *n_axis, *inner, *start, *len);
                    Ok(())
                }
                Kernel::Concat { outer, inner, parts } => {
                    concat_into(out, *outer, *inner, parts, |i| v(i).f32());
                    Ok(())
                }
                Kernel::Copy => {
                    kernels::copy_out(v(0).f32(), out);
                    Ok(())
                }
                Kernel::StridedCopy { strides } => {
                    kernels::strided_copy_out(v(0).f32(), out, &step.out_shape, strides, scratch);
                    Ok(())
                }
                Kernel::Dequantize => {
                    match v(0).data {
                        DataRef::F16(x) => kernels::dequantize_f16_out(x, out),
                        DataRef::I8(q, s) => kernels::dequantize_i8_out(q, s, out),
                        _ => unreachable!("dequantize input dtype checked at compile"),
                    }
                    Ok(())
                }
                Kernel::Quantize(_) => unreachable!("quantize never emits f32"),
            }
        }
    }
}

/// One widen-round trip: the value an f16 store would produce, kept in
/// f32. Fused f16 chains round after EVERY stage, so fusion stays
/// bitwise-identical to executing the chain's nodes one at a time.
#[inline]
fn round_f16(v: f32) -> f32 {
    f16_to_f32(f32_to_f16(v))
}

#[allow(clippy::too_many_arguments)]
fn run_f16(
    step: &Step,
    out: &mut [u16],
    arena: &Arena,
    consts: &[Tensor],
    inputs: &[&Tensor],
    scratch: &mut Vec<usize>,
    workers: usize,
) -> Result<(), String> {
    match &step.kind {
        StepKind::Fused { head, stages } => {
            match head {
                FusedHead::Value(x) => {
                    let xv = view(x, arena, consts, inputs).f16();
                    for_chunks(out, workers, |off, chunk| {
                        for (o, &v) in chunk.iter_mut().zip(&xv[off..off + chunk.len()]) {
                            let mut acc = f16_to_f32(v);
                            for st in stages {
                                acc = round_f16(st.apply(acc));
                            }
                            *o = f32_to_f16(acc);
                        }
                    });
                }
                FusedHead::Binary(kind, a, b) => {
                    let av = view(a, arena, consts, inputs).f16();
                    let bv = view(b, arena, consts, inputs).f16();
                    for_chunks(out, workers, |off, chunk| {
                        for (i, o) in chunk.iter_mut().enumerate() {
                            let mut acc = round_f16(kernels::apply_binary(
                                *kind,
                                f16_to_f32(av[off + i]),
                                f16_to_f32(bv[off + i]),
                            ));
                            for st in stages {
                                acc = round_f16(st.apply(acc));
                            }
                            *o = f32_to_f16(acc);
                        }
                    });
                }
                FusedHead::MatMul(kernel, a, b) => {
                    let Kernel::MatMul { batch, m, k, n, a_step, b_step } = kernel.as_ref()
                    else {
                        unreachable!("matmul chain head carries a matmul kernel")
                    };
                    kernels::matmul_out_g_mt::<u16>(
                        view(a, arena, consts, inputs).f16(),
                        view(b, arena, consts, inputs).f16(),
                        out,
                        *batch,
                        *m,
                        *k,
                        *n,
                        *a_step,
                        *b_step,
                        workers,
                    );
                    for_chunks(out, workers, |_, chunk| {
                        for o in chunk.iter_mut() {
                            let mut acc = f16_to_f32(*o);
                            for st in stages {
                                acc = round_f16(st.apply(acc));
                            }
                            *o = f32_to_f16(acc);
                        }
                    });
                }
            }
            Ok(())
        }
        StepKind::Kernel { kernel, args } => {
            let v = |i: usize| view(&args[i], arena, consts, inputs);
            match kernel {
                Kernel::MatMul { batch, m, k, n, a_step, b_step } => {
                    kernels::matmul_out_g_mt::<u16>(
                        v(0).f16(),
                        v(1).f16(),
                        out,
                        *batch,
                        *m,
                        *k,
                        *n,
                        *a_step,
                        *b_step,
                        workers,
                    );
                    Ok(())
                }
                Kernel::Binary { kind, mode } => {
                    kernels::binary_out_mt::<u16>(
                        *kind,
                        mode,
                        v(0).f16(),
                        v(1).f16(),
                        &step.out_shape,
                        out,
                        scratch,
                        workers,
                    );
                    Ok(())
                }
                Kernel::BinaryReduceSum { kind, axis, shape, sa, sb } => {
                    kernels::binary_reduce_sum_out_g::<u16>(
                        *kind,
                        v(0).f16(),
                        v(1).f16(),
                        sa,
                        sb,
                        shape,
                        *axis,
                        out,
                        scratch,
                    );
                    Ok(())
                }
                Kernel::Unary(k) => {
                    kernels::unary_out_mt::<u16>(*k, v(0).f16(), out, workers);
                    Ok(())
                }
                Kernel::Plu(table) => {
                    kernels::plu_out_mt::<u16>(table, v(0).f16(), out, workers);
                    Ok(())
                }
                Kernel::CumSum { outer, n_axis, inner } => {
                    kernels::cumsum_out_mt::<u16>(
                        v(0).f16(),
                        out,
                        *outer,
                        *n_axis,
                        *inner,
                        workers,
                    );
                    Ok(())
                }
                Kernel::ReduceSum { outer, n_axis, inner } => {
                    kernels::reduce_sum_out_mt::<u16>(
                        v(0).f16(),
                        out,
                        *outer,
                        *n_axis,
                        *inner,
                        workers,
                    );
                    Ok(())
                }
                Kernel::Gather { row, vocab } => {
                    kernels::gather_out(v(0).f16(), v(1).i32(), out, *row, *vocab)
                }
                Kernel::Conv1d { batch, t, c, k } => {
                    kernels::conv1d_out_mt::<u16>(
                        v(0).f16(),
                        v(1).f16(),
                        v(2).f16(),
                        out,
                        *batch,
                        *t,
                        *c,
                        *k,
                        workers,
                    );
                    Ok(())
                }
                Kernel::RmsNorm { rows, d, eps } => {
                    kernels::rmsnorm_out_mt::<u16>(
                        v(0).f16(),
                        v(1).f16(),
                        out,
                        *rows,
                        *d,
                        *eps,
                        workers,
                    );
                    Ok(())
                }
                Kernel::Softmax { outer, n_axis, inner } => {
                    kernels::softmax_out_mt::<u16>(
                        v(0).f16(),
                        out,
                        *outer,
                        *n_axis,
                        *inner,
                        workers,
                    );
                    Ok(())
                }
                Kernel::Slice { outer, n_axis, inner, start, len } => {
                    kernels::slice_out(v(0).f16(), out, *outer, *n_axis, *inner, *start, *len);
                    Ok(())
                }
                Kernel::Concat { outer, inner, parts } => {
                    concat_into(out, *outer, *inner, parts, |i| v(i).f16());
                    Ok(())
                }
                Kernel::Copy => {
                    kernels::copy_out(v(0).f16(), out);
                    Ok(())
                }
                Kernel::StridedCopy { strides } => {
                    kernels::strided_copy_out(v(0).f16(), out, &step.out_shape, strides, scratch);
                    Ok(())
                }
                Kernel::Quantize(DType::F16) => {
                    kernels::quantize_f16_out(v(0).f32(), out);
                    Ok(())
                }
                other => unreachable!("f16 kernel {other:?} rejected at plan time"),
            }
        }
    }
}

/// i8 steps return the produced value's dynamic scale, recorded in the
/// arena's per-slot scale table. Compute kernels stage their exact f32
/// result in `fscratch` and requantize once; layout kernels move raw
/// quantized bytes and carry the input scale through unchanged.
#[allow(clippy::too_many_arguments)]
fn run_i8(
    step: &Step,
    out: &mut [i8],
    arena: &Arena,
    consts: &[Tensor],
    inputs: &[&Tensor],
    scratch: &mut Vec<usize>,
    fscratch: &mut [f32],
) -> Result<f32, String> {
    let StepKind::Kernel { kernel, args } = &step.kind else {
        unreachable!("i8 fused chains rejected at plan time")
    };
    let v = |i: usize| view(&args[i], arena, consts, inputs);
    let n = step.out_numel;
    match kernel {
        Kernel::Quantize(DType::I8) => Ok(kernels::quantize_i8_out(v(0).f32(), out)),
        Kernel::Unary(k) => {
            let (q, s) = v(0).i8();
            kernels::unary_i8_into(*k, q, s, &mut fscratch[..n]);
            Ok(kernels::requantize_i8(&fscratch[..n], out))
        }
        Kernel::Binary { kind, mode } => {
            let (qa, sa) = v(0).i8();
            let (qb, sb) = v(1).i8();
            kernels::binary_i8_into(
                *kind,
                mode,
                qa,
                sa,
                qb,
                sb,
                &step.out_shape,
                &mut fscratch[..n],
                scratch,
            );
            Ok(kernels::requantize_i8(&fscratch[..n], out))
        }
        Kernel::CumSum { outer, n_axis, inner } => {
            let (q, s) = v(0).i8();
            kernels::cumsum_i8_into(q, s, &mut fscratch[..n], *outer, *n_axis, *inner);
            Ok(kernels::requantize_i8(&fscratch[..n], out))
        }
        Kernel::ReduceSum { outer, n_axis, inner } => {
            let (q, s) = v(0).i8();
            kernels::reduce_sum_i8_into(q, s, &mut fscratch[..n], *outer, *n_axis, *inner);
            Ok(kernels::requantize_i8(&fscratch[..n], out))
        }
        Kernel::Gather { row, vocab } => {
            let (q, s) = v(0).i8();
            kernels::gather_out(q, v(1).i32(), out, *row, *vocab)?;
            Ok(s)
        }
        Kernel::Slice { outer, n_axis, inner, start, len } => {
            let (q, s) = v(0).i8();
            kernels::slice_out(q, out, *outer, *n_axis, *inner, *start, *len);
            Ok(s)
        }
        Kernel::Concat { outer, inner, parts } => {
            let s0 = v(0).i8().1;
            for i in 1..args.len() {
                if v(i).i8().1 != s0 {
                    return Err("i8 concat needs equal per-tensor scales (got a mix)".into());
                }
            }
            concat_into(out, *outer, *inner, parts, |i| v(i).i8().0);
            Ok(s0)
        }
        Kernel::Copy => {
            let (q, s) = v(0).i8();
            kernels::copy_out(q, out);
            Ok(s)
        }
        Kernel::StridedCopy { strides } => {
            let (q, s) = v(0).i8();
            kernels::strided_copy_out(q, out, &step.out_shape, strides, scratch);
            Ok(s)
        }
        other => unreachable!("i8 kernel {other:?} rejected at plan time"),
    }
}

/// Concatenate along the compile-time-resolved axis: `view_of(i)` yields
/// the i-th argument's payload. Shared between every dtype's path;
/// copies straight into the arena slot, no per-part staging.
fn concat_into<'a, T: Copy + 'a>(
    out: &mut [T],
    outer: usize,
    inner: usize,
    parts: &[usize],
    mut view_of: impl FnMut(usize) -> &'a [T],
) {
    let total: usize = parts.iter().sum();
    for o in 0..outer {
        let mut dst = o * total * inner;
        for (ai, &na) in parts.iter().enumerate() {
            let av = view_of(ai);
            let chunk = na * inner;
            out[dst..dst + chunk].copy_from_slice(&av[o * chunk..(o + 1) * chunk]);
            dst += chunk;
        }
    }
}

/// i32 outputs: only data-movement ops (plan compilation guarantees it).
fn run_i32(
    step: &Step,
    out: &mut [i32],
    arena: &Arena,
    consts: &[Tensor],
    inputs: &[&Tensor],
) -> Result<(), String> {
    match &step.kind {
        StepKind::Kernel { kernel, args } => {
            let v = |i: usize| view(&args[i], arena, consts, inputs);
            match kernel {
                Kernel::Copy => kernels::copy_out(v(0).i32(), out),
                Kernel::Slice { outer, n_axis, inner, start, len } => {
                    kernels::slice_out(v(0).i32(), out, *outer, *n_axis, *inner, *start, *len);
                }
                Kernel::Concat { outer, inner, parts } => {
                    concat_into(out, *outer, *inner, parts, |i| v(i).i32());
                }
                other => unreachable!("i32 kernel {other:?} rejected at plan time"),
            }
            Ok(())
        }
        StepKind::Fused { .. } => unreachable!("fused chains are f32/f16-only"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_of(g: &Graph) -> ExecutionPlan {
        ExecutionPlan::compile(g).expect("plan compiles")
    }

    #[test]
    fn plan_matches_walker_on_small_graph() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2, 2]);
        let b = g.input("b", vec![2, 2]);
        let m = g.matmul(a, b, "m");
        let two = g.const_scalar("two", 2.0);
        let out = g.add(m, two, "out");
        g.output(out);
        let inputs = [
            Tensor::f32(vec![2, 2], vec![1., 2., 3., 4.]),
            Tensor::f32(vec![2, 2], vec![1., 1., 1., 1.]),
        ];
        let mut p = plan_of(&g);
        let r = p.run(&inputs).unwrap();
        assert_eq!(r[0].as_f32(), &[5., 5., 9., 9.]);
        // repeated execution reuses the arena and stays identical
        let r2 = p.run(&inputs).unwrap();
        assert_eq!(r[0].as_f32(), r2[0].as_f32());
    }

    #[test]
    fn elementwise_chain_collapses_to_one_step() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![8]);
        let a = g.silu(x, "a");
        let b = g.exp(a, "b");
        let half = g.const_scalar("h", 0.5);
        let c = g.mul(b, half, "c");
        g.output(c);
        let mut p = plan_of(&g);
        assert_eq!(p.step_count(), 1, "chain should fuse into a single step");
        assert_eq!(p.fused_node_count(), 2);
        assert_eq!(p.slot_count(), 1, "intermediates get no slots");
        let xs = Tensor::f32(vec![8], (0..8).map(|i| i as f32 - 4.0).collect());
        let got = p.run(&[xs.clone()]).unwrap();
        let want = super::super::naive::run(&g, &[xs]).unwrap();
        // fusion must be bitwise neutral
        assert_eq!(got[0].as_f32(), want[0].as_f32());
    }

    #[test]
    fn chains_fuse_through_reshape_bitwise() {
        // silu -> reshape -> exp collapses to one step and still matches
        // the walker (which materializes the reshape) exactly
        let mut g = Graph::new("t");
        let x = g.input("x", vec![2, 4]);
        let a = g.silu(x, "a");
        let r = g.reshape(a, vec![8], "r");
        let b = g.exp(r, "b");
        g.output(b);
        let mut p = plan_of(&g);
        assert_eq!(p.step_count(), 1, "reshape must not break the chain");
        assert_eq!(p.fused_node_count(), 2);
        let xs = Tensor::f32(vec![2, 4], (0..8).map(|i| i as f32 - 3.5).collect());
        let got = p.run(&[xs.clone()]).unwrap();
        let want = super::super::naive::run(&g, &[xs]).unwrap();
        assert_eq!(got[0].as_f32(), want[0].as_f32());
        assert_eq!(got[0].shape, vec![8]);
    }

    #[test]
    fn slots_are_reused_along_a_chain() {
        // a long non-fusable chain: live-range width is 2, so the arena
        // must stay at 2 slots however deep the chain gets
        let mut g = Graph::new("t");
        let x = g.input("x", vec![4, 4]);
        let mut cur = x;
        for i in 0..10 {
            cur = g.cumsum(cur, i % 2, &format!("cs{i}"));
        }
        g.output(cur);
        let p = plan_of(&g);
        assert_eq!(p.step_count(), 10);
        assert!(p.slot_count() <= 2, "slots: {}", p.slot_count());
    }

    #[test]
    fn mixed_dtype_values_share_the_slot_pool() {
        // f32 -> quantize(i8) -> dequantize -> f32 chain: the byte arena
        // reuses released f32 slots for the narrower i8 value
        let mut g = Graph::new("t");
        let x = g.input("x", vec![16]);
        let a = g.cumsum(x, 0, "a");
        let q = g.quantize(a, DType::I8, "q");
        let d = g.dequantize(q, "d");
        let b = g.cumsum(d, 0, "b");
        g.output(b);
        let mut p = plan_of(&g);
        assert!(p.slot_count() <= 2, "slots: {}", p.slot_count());
        let xs = Tensor::f32(vec![16], (0..16).map(|i| (i as f32) * 0.25 - 2.0).collect());
        let got = p.run(&[xs.clone()]).unwrap();
        let want = super::super::naive::run(&g, &[xs]).unwrap();
        assert_eq!(got[0].as_f32(), want[0].as_f32(), "planned vs naive i8 round trip");
    }

    #[test]
    fn outputs_that_are_inputs_or_consts_pass_through() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![3]);
        let c = g.constant("c", Tensor::f32(vec![2], vec![7., 8.]));
        g.output(a);
        g.output(c);
        g.output(a);
        let mut p = plan_of(&g);
        let t = Tensor::f32(vec![3], vec![1., 2., 3.]);
        let r = p.run(&[t.clone()]).unwrap();
        assert_eq!(r[0], t);
        assert_eq!(r[1].as_f32(), &[7., 8.]);
        assert_eq!(r[2], t);
    }

    #[test]
    fn output_slots_survive_downstream_reuse() {
        // y is both an output and an intermediate consumed later; its
        // slot must not be recycled by the second cumsum
        let mut g = Graph::new("t");
        let x = g.input("x", vec![4]);
        let y = g.cumsum(x, 0, "y");
        let z = g.cumsum(y, 0, "z");
        g.output(y);
        g.output(z);
        let mut p = plan_of(&g);
        let r = p
            .run(&[Tensor::f32(vec![4], vec![1., 1., 1., 1.])])
            .unwrap();
        assert_eq!(r[0].as_f32(), &[1., 2., 3., 4.]);
        assert_eq!(r[1].as_f32(), &[1., 3., 6., 10.]);
    }

    #[test]
    fn input_validation_matches_walker() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2]);
        g.output(a);
        let mut p = plan_of(&g);
        assert!(p.run(&[]).is_err());
        assert!(p.run(&[Tensor::f32(vec![3], vec![0.0; 3])]).is_err());
        assert!(p.run(&[Tensor::i32(vec![2], vec![0, 0])]).is_err());
        // a reduced-precision tensor is also a dtype mismatch for an f32
        // input, with the dtype names in the message
        let err = p.run(&[Tensor::f16(vec![2], vec![0, 0])]).unwrap_err();
        assert!(err.contains("f16") && err.contains("f32"), "{err}");
    }

    #[test]
    fn gather_out_of_range_is_an_execute_error() {
        let mut g = Graph::new("t");
        let data = g.input("d", vec![3, 2]);
        let idx = g.input_i32("i", vec![2]);
        let e = g.gather(data, idx, "emb");
        g.output(e);
        let mut p = plan_of(&g);
        let d = Tensor::f32(vec![3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let ok = p.run(&[d.clone(), Tensor::i32(vec![2], vec![2, 0])]).unwrap();
        assert_eq!(ok[0].as_f32(), &[20., 21., 0., 1.]);
        let err = p.run(&[d, Tensor::i32(vec![2], vec![9, 0])]);
        assert!(err.unwrap_err().contains("out of range"));
    }

    #[test]
    fn dead_nodes_are_not_planned() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2]);
        let zero = g.const_scalar("z", 0.0);
        let _dead = g.div(a, zero, "dead");
        g.output(a);
        let mut p = plan_of(&g);
        assert_eq!(p.step_count(), 0);
        let r = p.run(&[Tensor::f32(vec![2], vec![1., 2.])]).unwrap();
        assert_eq!(r[0].as_f32(), &[1., 2.]);
    }

    #[test]
    fn f16_plan_matches_naive_bitwise() {
        use crate::graph::op::UnKind;
        let mut g = Graph::new("t");
        let x = g.input_dtype("x", vec![3, 4], DType::F16);
        let w = g.input_dtype("w", vec![4, 2], DType::F16);
        let m = g.matmul(x, w, "m");
        let s = g.unary(UnKind::SiLU, m, "s");
        let r = g.reduce_sum(s, 0, "r");
        g.output(r);
        let mut p = plan_of(&g);
        let xs = Tensor::f32(vec![3, 4], (0..12).map(|i| (i as f32) * 0.3 - 2.0).collect())
            .to_dtype(DType::F16);
        let ws = Tensor::f32(vec![4, 2], (0..8).map(|i| (i as f32) * 0.1 - 0.4).collect())
            .to_dtype(DType::F16);
        let got = p.run(&[xs.clone(), ws.clone()]).unwrap();
        let want = super::super::naive::run(&g, &[xs, ws]).unwrap();
        assert_eq!(got[0].as_f16(), want[0].as_f16(), "f16 planned vs naive");
    }

    #[test]
    fn i8_matmul_emits_f32_and_matches_naive() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2, 3]);
        let w = g.input_dtype("w", vec![3, 2], DType::I8);
        let aq = g.quantize(a, DType::I8, "aq");
        let m = g.matmul(aq, w, "m");
        g.output(m);
        let mut p = plan_of(&g);
        let at = Tensor::f32(vec![2, 3], vec![0.5, -1.0, 2.0, 1.5, 0.0, -0.5]);
        let wt = Tensor::f32(vec![3, 2], vec![0.1, 0.2, -0.3, 0.4, 0.5, -0.6])
            .to_dtype(DType::I8);
        let got = p.run(&[at.clone(), wt.clone()]).unwrap();
        let want = super::super::naive::run(&g, &[at.clone(), wt]).unwrap();
        assert_eq!(got[0].dtype(), DType::F32);
        assert_eq!(got[0].as_f32(), want[0].as_f32(), "i8 planned vs naive");
        // and close to the exact f32 product (per-tensor 8-bit budget)
        let mut exact = Graph::new("e");
        let ea = exact.input("a", vec![2, 3]);
        let ew = exact.input("w", vec![3, 2]);
        let em = exact.matmul(ea, ew, "m");
        exact.output(em);
        let wf = Tensor::f32(vec![3, 2], vec![0.1, 0.2, -0.3, 0.4, 0.5, -0.6]);
        let ref_out = super::super::naive::run(&exact, &[at, wf]).unwrap();
        for (q, e) in got[0].as_f32().iter().zip(ref_out[0].as_f32()) {
            assert!((q - e).abs() < 0.1, "quantized {q} vs exact {e}");
        }
    }

    #[test]
    fn i8_scale_travels_through_layout_ops() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![2, 4]);
        let q = g.quantize(x, DType::I8, "q");
        let t = g.transpose(q, vec![1, 0], "t");
        let s = g.slice(t, 0, 1, 2, "s");
        g.output(s);
        let mut p = plan_of(&g);
        let xs = Tensor::f32(vec![2, 4], vec![1., 2., 3., 4., -1., -2., -3., -4.]);
        let got = p.run(&[xs.clone()]).unwrap();
        let want = super::super::naive::run(&g, &[xs]).unwrap();
        let (gq, gs) = got[0].as_i8();
        let (wq, ws) = want[0].as_i8();
        assert_eq!(gq, wq);
        assert_eq!(gs, ws);
        assert_eq!(gs, 4.0 / 127.0, "layout ops must carry the scale unchanged");
    }

    #[test]
    fn matmul_epilogue_fuses_into_the_gemm_step() {
        // matmul -> silu -> *0.5 collapses to one step: the GEMM writes
        // the output slot and the stages run as an in-place second pass
        let mut g = Graph::new("t");
        let a = g.input("a", vec![4, 8]);
        let b = g.input("b", vec![8, 6]);
        let m = g.matmul(a, b, "m");
        let s = g.silu(m, "s");
        let half = g.const_scalar("h", 0.5);
        let c = g.mul(s, half, "c");
        g.output(c);
        let mut p = plan_of(&g);
        assert_eq!(p.step_count(), 1, "matmul + epilogue should be one step");
        assert_eq!(p.fused_node_count(), 2);
        assert_eq!(p.slot_count(), 1, "epilogue intermediates get no slots");
        let at = Tensor::f32(vec![4, 8], (0..32).map(|i| (i as f32) * 0.17 - 2.3).collect());
        let bt = Tensor::f32(vec![8, 6], (0..48).map(|i| (i as f32) * 0.09 - 1.9).collect());
        let got = p.run(&[at.clone(), bt.clone()]).unwrap();
        let want = super::super::naive::run(&g, &[at, bt]).unwrap();
        assert_eq!(got[0].as_f32(), want[0].as_f32(), "epilogue fusion must be bitwise");
    }

    #[test]
    fn f16_matmul_epilogue_is_bitwise_with_per_stage_rounding() {
        let mut g = Graph::new("t");
        let a = g.input_dtype("a", vec![3, 5], DType::F16);
        let b = g.input_dtype("b", vec![5, 4], DType::F16);
        let m = g.matmul(a, b, "m");
        let s = g.silu(m, "s");
        let e = g.exp(s, "e");
        g.output(e);
        let mut p = plan_of(&g);
        assert_eq!(p.step_count(), 1);
        let at = Tensor::f32(vec![3, 5], (0..15).map(|i| (i as f32) * 0.21 - 1.4).collect())
            .to_dtype(DType::F16);
        let bt = Tensor::f32(vec![5, 4], (0..20).map(|i| (i as f32) * 0.13 - 1.2).collect())
            .to_dtype(DType::F16);
        let got = p.run(&[at.clone(), bt.clone()]).unwrap();
        let want = super::super::naive::run(&g, &[at, bt]).unwrap();
        assert_eq!(got[0].as_f16(), want[0].as_f16(), "f16 rounds after every stage");
    }

    #[test]
    fn binary_reduce_sum_fuses_and_stays_bitwise() {
        // mul -> reduce_sum(axis=1) collapses into one reduction step, so
        // the (4,8,3) product never takes an arena slot
        let mut g = Graph::new("t");
        let a = g.input("a", vec![4, 8, 3]);
        let b = g.input("b", vec![4, 8, 3]);
        let m = g.mul(a, b, "m");
        let r = g.reduce_sum(m, 1, "r");
        g.output(r);
        let mut p = plan_of(&g);
        assert_eq!(p.step_count(), 1, "binary + reduce should be one step");
        assert_eq!(p.fused_node_count(), 1);
        assert_eq!(p.slot_count(), 1, "the product intermediate gets no slot");
        let at = Tensor::f32(vec![4, 8, 3], (0..96).map(|i| (i as f32) * 0.07 - 3.1).collect());
        let bt = Tensor::f32(vec![4, 8, 3], (0..96).map(|i| (i as f32) * 0.05 - 2.2).collect());
        let got = p.run(&[at.clone(), bt.clone()]).unwrap();
        let want = super::super::naive::run(&g, &[at, bt]).unwrap();
        assert_eq!(got[0].as_f32(), want[0].as_f32(), "reduction epilogue must be bitwise");
        assert_eq!(got[0].shape, vec![4, 3]);
    }

    #[test]
    fn broadcast_binary_reduce_sum_fuses_and_stays_bitwise() {
        // the broadcast operand reads through zero strides inside the
        // fused kernel — same values the materialized product would hold
        let mut g = Graph::new("t");
        let a = g.input("a", vec![4, 8, 3]);
        let b = g.input("b", vec![8, 3]);
        let m = g.mul(a, b, "m");
        let r = g.reduce_sum(m, 2, "r");
        g.output(r);
        let mut p = plan_of(&g);
        assert_eq!(p.step_count(), 1);
        let at = Tensor::f32(vec![4, 8, 3], (0..96).map(|i| (i as f32) * 0.03 - 1.5).collect());
        let bt = Tensor::f32(vec![8, 3], (0..24).map(|i| (i as f32) * 0.11 - 1.3).collect());
        let got = p.run(&[at.clone(), bt.clone()]).unwrap();
        let want = super::super::naive::run(&g, &[at, bt]).unwrap();
        assert_eq!(got[0].as_f32(), want[0].as_f32());
    }

    #[test]
    fn f16_binary_reduce_sum_fuses_and_stays_bitwise() {
        let mut g = Graph::new("t");
        let a = g.input_dtype("a", vec![4, 8, 3], DType::F16);
        let b = g.input_dtype("b", vec![4, 8, 3], DType::F16);
        let m = g.mul(a, b, "m");
        let r = g.reduce_sum(m, 1, "r");
        g.output(r);
        let mut p = plan_of(&g);
        assert_eq!(p.step_count(), 1);
        let at = Tensor::f32(vec![4, 8, 3], (0..96).map(|i| (i as f32) * 0.07 - 3.1).collect())
            .to_dtype(DType::F16);
        let bt = Tensor::f32(vec![4, 8, 3], (0..96).map(|i| (i as f32) * 0.05 - 2.2).collect())
            .to_dtype(DType::F16);
        let got = p.run(&[at.clone(), bt.clone()]).unwrap();
        let want = super::super::naive::run(&g, &[at, bt]).unwrap();
        assert_eq!(got[0].as_f16(), want[0].as_f16(), "per-stage f16 rounding preserved");
    }

    #[test]
    fn multi_consumer_or_output_binary_does_not_fuse_with_reduce() {
        // the product is itself a graph output, so it must still be
        // materialized and the reduction stays a separate step
        let mut g = Graph::new("t");
        let a = g.input("a", vec![4, 8]);
        let b = g.input("b", vec![4, 8]);
        let m = g.mul(a, b, "m");
        let r = g.reduce_sum(m, 0, "r");
        g.output(m);
        g.output(r);
        let mut p = plan_of(&g);
        assert_eq!(p.step_count(), 2);
        let at = Tensor::f32(vec![4, 8], (0..32).map(|i| i as f32 * 0.4 - 5.0).collect());
        let bt = Tensor::f32(vec![4, 8], (0..32).map(|i| i as f32 * 0.2 - 3.0).collect());
        let got = p.run(&[at.clone(), bt.clone()]).unwrap();
        let want = super::super::naive::run(&g, &[at, bt]).unwrap();
        assert_eq!(got[0].as_f32(), want[0].as_f32());
        assert_eq!(got[1].as_f32(), want[1].as_f32());
    }

    #[test]
    fn intra_op_worker_count_never_changes_results() {
        // prefill-scale graph exercising the threaded paths: a GEMM over
        // the FLOP threshold with a fused epilogue, plus elementwise /
        // scan / softmax nodes over the element threshold, plus a fused
        // binary->reduce. Chunk boundaries depend only on shape, so every
        // worker count must agree bitwise with the serial pass.
        let mut g = Graph::new("t");
        let x = g.input("x", vec![64, 512]);
        let w = g.input("w", vec![512, 64]);
        let m = g.matmul(x, w, "m");
        let s = g.silu(m, "s");
        let sm = g.softmax(x, 1, "sm");
        let cs = g.cumsum(x, 0, "cs");
        let sum = g.add(sm, cs, "sum");
        let red = g.reduce_sum(sum, 1, "red");
        g.output(s);
        g.output(red);
        let xs = Tensor::f32(
            vec![64, 512],
            (0..64 * 512).map(|i| ((i * 2654435761usize) % 1000) as f32 * 0.002 - 1.0).collect(),
        );
        let ws = Tensor::f32(
            vec![512, 64],
            (0..512 * 64).map(|i| ((i * 40503usize) % 997) as f32 * 0.001 - 0.5).collect(),
        );
        let mut base = plan_of(&g).with_intra_workers(1);
        let want = base.run(&[xs.clone(), ws.clone()]).unwrap();
        for workers in [2, 4] {
            let mut p = plan_of(&g).with_intra_workers(workers);
            for trial in 0..2 {
                let got = p.run(&[xs.clone(), ws.clone()]).unwrap();
                for (gt, wt) in got.iter().zip(&want) {
                    assert_eq!(
                        gt.as_f32(),
                        wt.as_f32(),
                        "workers={workers} trial={trial} must be bitwise-serial"
                    );
                }
            }
        }
    }

    #[test]
    fn unsupported_dtype_combos_fail_at_compile_time() {
        use crate::graph::op::Op;
        // softmax on i8 sneaks past the builder via add_node; the plan
        // compiler must reject it with attribution
        let mut g = Graph::new("t");
        let x = g.input_dtype("x", vec![4], DType::I8);
        let sm = g.add_node(
            Op::Softmax { axis: 0 },
            vec![x],
            vec![4],
            DType::I8,
            "sm".into(),
            None,
        );
        g.output(sm);
        let err = ExecutionPlan::compile(&g).unwrap_err();
        assert!(err.contains("sm") && err.contains("f32/f16"), "{err}");
    }
}
