//! Persistent worker-thread pool for data-parallel plan execution.
//!
//! Plans are cheap to compile and their arenas are inherently
//! single-threaded (`execute` takes `&mut self`), so the pool does NOT
//! share plans: each worker owns a private [`PlanCache`] and compiles
//! its own copy of every graph it is handed, on first use. Jobs carry an
//! `Arc<Graph>` plus a cache key, an `Arc`-shared input prefix (model
//! parameters — one allocation process-wide, never copied), and a
//! per-job tail — no mutable state crosses threads.
//!
//! [`WorkerPool::execute_batch`] is a **work-stealing chunk queue**: the
//! batch goes into one shared FIFO and every worker drains it until
//! empty, so a ragged batch (uneven job costs, uneven chunk sizes) never
//! leaves workers idle the way a static equal shard does. It is still
//! deterministic by construction: jobs never interact, a job's result
//! depends only on the job itself (every worker compiles the identical
//! plan from the identical graph), and results are reassembled in
//! submission order — so pooled output is bitwise-identical to a serial
//! loop over the same jobs, at any worker count and under any stealing
//! schedule.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::graph::{Graph, Tensor};

use super::cache::PlanCache;

/// One unit of work: run `graph` (compiled at most once per worker under
/// `key`) on `shared ++ tail`.
pub struct ExecJob {
    pub graph: Arc<Graph>,
    /// Plan-cache key; jobs with equal keys must carry the same graph
    /// and shared prefix (the worker binds both on first use). `Arc`'d
    /// so hot-path callers clone a refcount, not a string.
    pub key: Arc<str>,
    /// Constant input prefix (e.g. model parameters) — shared through
    /// the `Arc` by every worker's cache, never copied.
    pub shared: Arc<Vec<Tensor>>,
    /// Per-job inputs appended after the shared prefix.
    pub tail: Vec<Tensor>,
}

/// Shared FIFO the workers steal from. Jobs keep their submission index
/// so the caller reassembles results in order regardless of which worker
/// ran what.
struct JobQueue {
    jobs: Mutex<VecDeque<(usize, ExecJob)>>,
}

impl JobQueue {
    fn pop(&self) -> Option<(usize, ExecJob)> {
        self.jobs.lock().unwrap().pop_front()
    }
}

enum Msg {
    /// Drain `queue` until empty, reporting each job's result on `reply`.
    Drain {
        queue: Arc<JobQueue>,
        reply: Sender<(usize, Result<Vec<Tensor>, String>)>,
    },
}

/// Fixed set of worker threads, each owning its plans and arenas.
/// Dropping the pool disconnects and joins every worker.
pub struct WorkerPool {
    txs: Vec<Sender<Msg>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads (at least 1).
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = channel::<Msg>();
            let handle = std::thread::Builder::new()
                .name(format!("xamba-exec-{w}"))
                .spawn(move || worker_loop(rx))
                .expect("spawn pool worker");
            txs.push(tx);
            handles.push(handle);
        }
        WorkerPool { txs, handles }
    }

    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Run every job and return results in submission order. All workers
    /// steal from one shared queue, so uneven jobs balance themselves;
    /// a job whose worker died reports an error instead of wedging the
    /// caller.
    pub fn execute_batch(&self, jobs: Vec<ExecJob>) -> Vec<Result<Vec<Tensor>, String>> {
        let n = jobs.len();
        let mut out: Vec<Result<Vec<Tensor>, String>> =
            (0..n).map(|_| Err("pool worker died".to_string())).collect();
        if n == 0 {
            return out;
        }
        let queue = Arc::new(JobQueue {
            jobs: Mutex::new(jobs.into_iter().enumerate().collect()),
        });
        let (reply_tx, reply_rx) = channel();
        let mut notified = 0usize;
        for tx in &self.txs {
            let msg = Msg::Drain { queue: queue.clone(), reply: reply_tx.clone() };
            if tx.send(msg).is_ok() {
                notified += 1;
            }
        }
        drop(reply_tx);
        if notified == 0 {
            return out; // every worker is gone
        }
        let mut received = 0usize;
        while received < n {
            match reply_rx.recv() {
                Ok((i, r)) => {
                    out[i] = r;
                    received += 1;
                }
                // every live worker finished or died; unreported jobs
                // keep their "worker died" error
                Err(_) => break,
            }
        }
        out
    }

    /// Run exactly one job on each worker (jobs.len() must equal
    /// `workers()`), bypassing the stealing queue. Warmup uses this to
    /// guarantee EVERY worker compiles a plan — under stealing, a fast
    /// worker could otherwise grab all the warm jobs and leave its
    /// siblings cold.
    pub fn execute_per_worker(
        &self,
        jobs: Vec<ExecJob>,
    ) -> Vec<Result<Vec<Tensor>, String>> {
        assert_eq!(jobs.len(), self.txs.len(), "one warm job per worker");
        let n = jobs.len();
        let mut out: Vec<Result<Vec<Tensor>, String>> =
            (0..n).map(|_| Err("pool worker died".to_string())).collect();
        let (reply_tx, reply_rx) = channel();
        let mut notified = 0usize;
        for (i, job) in jobs.into_iter().enumerate() {
            let queue = Arc::new(JobQueue {
                jobs: Mutex::new(VecDeque::from([(i, job)])),
            });
            let msg = Msg::Drain { queue, reply: reply_tx.clone() };
            if self.txs[i].send(msg).is_ok() {
                notified += 1;
            }
        }
        drop(reply_tx);
        let mut received = 0usize;
        while received < notified {
            match reply_rx.recv() {
                Ok((i, r)) => {
                    out[i] = r;
                    received += 1;
                }
                Err(_) => break,
            }
        }
        out
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // disconnecting the channels ends each worker's recv loop
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: Receiver<Msg>) {
    let mut cache = PlanCache::new();
    while let Ok(Msg::Drain { queue, reply }) = rx.recv() {
        while let Some((idx, job)) = queue.pop() {
            let r = cache.run_or_compile(&job.key, &job.graph, &job.shared, job.tail);
            if reply.send((idx, r)).is_err() {
                break; // caller stopped listening; stop draining
            }
        }
    }
}

// --- intra-op splitting ---------------------------------------------------------

/// Intra-op worker count for splitting single large kernels (GEMM row
/// panels, elementwise slabs). Defaults to 1 — fully serial, zero
/// behavioral change — unless `XAMBA_INTRA_THREADS` asks for more.
pub fn intra_workers_from_env() -> usize {
    std::env::var("XAMBA_INTRA_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(1)
        .max(1)
}

/// Split `data` into fixed-size chunks of `chunk_elems` (the last chunk
/// may be short) and run `f(element_offset, chunk)` over all of them on
/// up to `workers` scoped threads.
///
/// Chunk boundaries depend ONLY on `data.len()` and `chunk_elems`, never
/// on `workers` — chunks are dealt round-robin to workers, so any worker
/// count computes the same chunks with the same `f`, and results are
/// bitwise-identical to the serial loop by construction. The calling
/// thread runs the first share itself; only `workers - 1` threads spawn.
pub(crate) fn parallel_chunks_mut<T, F>(data: &mut [T], chunk_elems: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk_elems = chunk_elems.max(1);
    if workers <= 1 || data.len() <= chunk_elems {
        let mut off = 0;
        for chunk in data.chunks_mut(chunk_elems) {
            let len = chunk.len();
            f(off, chunk);
            off += len;
        }
        return;
    }
    let mut parts: Vec<Vec<(usize, &mut [T])>> = (0..workers).map(|_| Vec::new()).collect();
    for (ci, chunk) in data.chunks_mut(chunk_elems).enumerate() {
        parts[ci % workers].push((ci * chunk_elems, chunk));
    }
    let f = &f;
    std::thread::scope(|s| {
        let mut own = parts.remove(0);
        for part in parts.into_iter().filter(|p| !p.is_empty()) {
            s.spawn(move || {
                for (off, chunk) in part {
                    f(off, chunk);
                }
            });
        }
        for (off, chunk) in own.drain(..) {
            f(off, chunk);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn square_graph() -> Graph {
        let mut g = Graph::new("sq");
        let x = g.input("x", vec![4]);
        let y = g.mul(x, x, "y");
        g.output(y);
        g
    }

    fn jobs_for(graph: &Arc<Graph>, count: usize) -> Vec<ExecJob> {
        let shared = Arc::new(Vec::new());
        (0..count)
            .map(|i| ExecJob {
                graph: graph.clone(),
                key: "sq".into(),
                shared: shared.clone(),
                tail: vec![Tensor::f32(
                    vec![4],
                    (0..4).map(|d| (i * 4 + d) as f32).collect(),
                )],
            })
            .collect()
    }

    #[test]
    fn batch_results_keep_submission_order() {
        let g = Arc::new(square_graph());
        let pool = WorkerPool::new(3);
        let results = pool.execute_batch(jobs_for(&g, 7));
        assert_eq!(results.len(), 7);
        for (i, r) in results.iter().enumerate() {
            let got = r.as_ref().unwrap()[0].as_f32();
            let want: Vec<f32> =
                (0..4).map(|d| ((i * 4 + d) as f32).powi(2)).collect();
            assert_eq!(got, want.as_slice(), "job {i}");
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let g = Arc::new(square_graph());
        let baseline: Vec<_> = WorkerPool::new(1)
            .execute_batch(jobs_for(&g, 8))
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        for w in [2usize, 4] {
            let got: Vec<_> = WorkerPool::new(w)
                .execute_batch(jobs_for(&g, 8))
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            assert_eq!(got, baseline, "{w} workers diverged");
        }
    }

    #[test]
    fn stealing_handles_more_jobs_than_workers_and_vice_versa() {
        let g = Arc::new(square_graph());
        let pool = WorkerPool::new(4);
        // fewer jobs than workers: idle workers drain an empty queue
        for count in [1usize, 3, 11] {
            let results = pool.execute_batch(jobs_for(&g, count));
            assert_eq!(results.len(), count);
            for (i, r) in results.iter().enumerate() {
                assert!(r.is_ok(), "job {i} of {count} failed");
            }
        }
        assert!(pool.execute_batch(Vec::new()).is_empty());
    }

    #[test]
    fn per_worker_execution_reaches_every_worker() {
        let g = Arc::new(square_graph());
        let pool = WorkerPool::new(3);
        let results = pool.execute_per_worker(jobs_for(&g, 3));
        assert_eq!(results.len(), 3);
        for (i, r) in results.iter().enumerate() {
            let got = r.as_ref().unwrap()[0].as_f32();
            let want: Vec<f32> =
                (0..4).map(|d| ((i * 4 + d) as f32).powi(2)).collect();
            assert_eq!(got, want.as_slice(), "worker {i}");
        }
    }

    #[test]
    fn parallel_chunks_cover_every_offset_at_any_worker_count() {
        for workers in [1usize, 2, 5] {
            let mut data = vec![0u32; 103]; // ragged tail chunk
            parallel_chunks_mut(&mut data, 10, workers, |off, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (off + i) as u32;
                }
            });
            assert!(
                data.iter().enumerate().all(|(i, &v)| v == i as u32),
                "workers={workers}"
            );
        }
    }

    #[test]
    fn bad_graph_reports_error_without_poisoning_pool() {
        use crate::graph::{DType, Op};
        let mut bad = Graph::new("bad");
        let a = bad.input("a", vec![2, 3]);
        let b = bad.input("b", vec![4, 5]);
        // raw append bypasses the builder's shape check; the k mismatch
        // must surface as a plan-compile error on the worker
        let m = bad.add_node(Op::MatMul, vec![a, b], vec![2, 5], DType::F32, "m".into(), None);
        bad.output(m);
        let pool = WorkerPool::new(2);
        let g = Arc::new(bad);
        let shared = Arc::new(Vec::new());
        let r = pool.execute_batch(vec![ExecJob {
            graph: g,
            key: "bad".into(),
            shared,
            tail: vec![
                Tensor::f32(vec![2, 3], vec![0.0; 6]),
                Tensor::f32(vec![4, 5], vec![0.0; 20]),
            ],
        }]);
        assert!(r[0].is_err());
        // the pool still serves good jobs afterwards
        let g2 = Arc::new(square_graph());
        let ok = pool.execute_batch(jobs_for(&g2, 2));
        assert!(ok.iter().all(|r| r.is_ok()));
    }
}
