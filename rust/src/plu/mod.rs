//! Piecewise-linear activation approximation (the ActiBA substrate).
//!
//! Rust mirror of `python/compile/plu.py`: fits a Configurable-LUT of
//! (slope, intercept) pairs over uniform segments for SiLU / Softplus,
//! evaluates it the way the NPU's drain-path PLU would, and quantifies the
//! approximation error the paper's Table 1 trades for latency. Includes a
//! greedy *adaptive* fitter (non-uniform knots, à la Flex-SFU) used by the
//! ablation bench to show how segment placement buys accuracy.

mod fit;

pub use fit::{fit_adaptive, AdaptiveTable};

/// A C-LUT: `K` uniform segments on `[lo, hi]` plus analytic linear tails.
///
/// Segment `k` covers `[lo + k*step, lo + (k+1)*step)`; inputs outside the
/// range clamp to the first/last segment, whose slope/intercept the
/// fitters set to the function's asymptote.
#[derive(Clone, Debug, PartialEq)]
pub struct PluTable {
    pub lo: f32,
    pub hi: f32,
    pub slopes: Vec<f32>,
    pub intercepts: Vec<f32>,
}

impl PluTable {
    pub fn num_segments(&self) -> usize {
        self.slopes.len()
    }

    pub fn step(&self) -> f32 {
        (self.hi - self.lo) / self.num_segments() as f32
    }

    /// Evaluate the PLU at one point: `m_k * x + c_k`.
    #[inline]
    pub fn eval(&self, x: f32) -> f32 {
        let k = (((x - self.lo) / self.step()) as i64)
            .clamp(0, self.num_segments() as i64 - 1) as usize;
        self.slopes[k] * x + self.intercepts[k]
    }

    /// One element with the reciprocal step precomputed — the shared
    /// inner of [`PluTable::eval_slice`], the planned PLU kernel, and
    /// fused PLU stages (`exec::fuse`). Keeping a single copy of the
    /// segment-select arithmetic is what makes fused and unfused PLU
    /// evaluation bitwise identical.
    #[inline]
    pub fn eval_premul(&self, x: f32, inv_step: f32, kmax: i64) -> f32 {
        let k = (((x - self.lo) * inv_step) as i64).clamp(0, kmax) as usize;
        self.slopes[k] * x + self.intercepts[k]
    }

    /// Evaluate elementwise over a slice.
    pub fn eval_slice(&self, xs: &[f32], out: &mut [f32]) {
        let inv_step = 1.0 / self.step();
        let kmax = self.num_segments() as i64 - 1;
        for (o, &x) in out.iter_mut().zip(xs) {
            *o = self.eval_premul(x, inv_step, kmax);
        }
    }

    /// Max |f - plu| over a dense grid extending `span` beyond the range.
    pub fn max_abs_error(&self, f: impl Fn(f64) -> f64, span: f32) -> f64 {
        let n = 100_001;
        let lo = (self.lo - span) as f64;
        let hi = (self.hi + span) as f64;
        let mut worst = 0.0f64;
        for i in 0..n {
            let x = lo + (hi - lo) * i as f64 / (n - 1) as f64;
            let e = (f(x) - self.eval(x as f32) as f64).abs();
            worst = worst.max(e);
        }
        worst
    }

    /// Bytes the C-LUT occupies (2 f32 per segment) — NPU config budget.
    pub fn lut_bytes(&self) -> usize {
        self.num_segments() * 8
    }
}

/// Exact SiLU in f64 (reference for error measurement).
pub fn silu_exact(x: f64) -> f64 {
    x / (1.0 + (-x).exp())
}

/// Exact Softplus in f64 (stable form).
pub fn softplus_exact(x: f64) -> f64 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

/// Exact sigmoid in f32 (used by the interpreter's exact ops).
pub fn sigmoid_f32(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Stable softplus in f32.
pub fn softplus_f32(x: f32) -> f32 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

fn secant_fit(
    f: impl Fn(f64) -> f64,
    lo: f32,
    hi: f32,
    segments: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert!(segments >= 2, "need >= 2 segments");
    let mut slopes = Vec::with_capacity(segments);
    let mut intercepts = Vec::with_capacity(segments);
    let step = (hi as f64 - lo as f64) / segments as f64;
    for k in 0..segments {
        let x0 = lo as f64 + k as f64 * step;
        let x1 = x0 + step;
        let (y0, y1) = (f(x0), f(x1));
        let m = (y1 - y0) / step;
        slopes.push(m as f32);
        intercepts.push((y0 - m * x0) as f32);
    }
    (slopes, intercepts)
}

/// Fit a uniform-segment C-LUT for SiLU with analytic tails (0 / identity).
/// Bit-for-bit the same construction as `python/compile/plu.silu_table`.
pub fn silu_table(segments: usize, lo: f32, hi: f32) -> PluTable {
    let (mut m, mut c) = secant_fit(silu_exact, lo, hi, segments);
    (m[0], c[0]) = (0.0, 0.0);
    let last = segments - 1;
    (m[last], c[last]) = (1.0, 0.0);
    PluTable { lo, hi, slopes: m, intercepts: c }
}

/// Fit a uniform-segment C-LUT for Softplus with analytic tails.
pub fn softplus_table(segments: usize, lo: f32, hi: f32) -> PluTable {
    let (mut m, mut c) = secant_fit(softplus_exact, lo, hi, segments);
    (m[0], c[0]) = (0.0, 0.0);
    let last = segments - 1;
    (m[last], c[last]) = (1.0, 0.0);
    PluTable { lo, hi, slopes: m, intercepts: c }
}

/// Default ActiBA tables (matches `ModelConfig.plu_segments/plu_range`).
pub fn default_silu() -> PluTable {
    silu_table(32, -8.0, 8.0)
}

pub fn default_softplus() -> PluTable {
    softplus_table(32, -8.0, 8.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silu_error_is_negligible_at_32_segments() {
        let t = default_silu();
        // "negligible accuracy loss" regime of the paper
        assert!(t.max_abs_error(silu_exact, 4.0) < 0.02);
    }

    #[test]
    fn softplus_error_is_negligible_at_32_segments() {
        let t = default_softplus();
        assert!(t.max_abs_error(softplus_exact, 4.0) < 0.02);
    }

    #[test]
    fn more_segments_monotonically_help() {
        let errs: Vec<f64> = [4, 8, 16, 32, 64]
            .iter()
            .map(|&k| silu_table(k, -8.0, 8.0).max_abs_error(silu_exact, 2.0))
            .collect();
        for w in errs.windows(2) {
            assert!(w[1] <= w[0] * 1.01, "errors not decreasing: {errs:?}");
        }
    }

    #[test]
    fn tails_follow_asymptotes() {
        let t = default_silu();
        assert_eq!(t.eval(-100.0), 0.0); // silu -> 0
        assert!((t.eval(100.0) - 100.0).abs() < 1e-4); // silu -> x
        let s = default_softplus();
        assert_eq!(s.eval(-50.0), 0.0);
        assert!((s.eval(50.0) - 50.0).abs() < 1e-4);
    }

    #[test]
    fn eval_slice_matches_eval() {
        let t = default_silu();
        let xs: Vec<f32> = (-40..40).map(|i| i as f32 * 0.33).collect();
        let mut out = vec![0.0; xs.len()];
        t.eval_slice(&xs, &mut out);
        for (&x, &o) in xs.iter().zip(&out) {
            assert_eq!(o, t.eval(x));
        }
    }

    #[test]
    fn knot_continuity_is_tight() {
        // secant fit is continuous at interior knots by construction —
        // except at the knots adjacent to the analytically-overridden
        // tail segments (0 and K-1), which we skip.
        let t = silu_table(16, -6.0, 6.0);
        for k in 2..14 {
            let x = t.lo + k as f32 * t.step();
            let left = t.slopes[k - 1] * x + t.intercepts[k - 1];
            let right = t.slopes[k] * x + t.intercepts[k];
            assert!((left - right).abs() < 1e-5, "knot {k}: {left} vs {right}");
        }
    }
}
