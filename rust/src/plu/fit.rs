//! Adaptive (non-uniform) piecewise-linear fitting.
//!
//! The paper cites Flex-SFU (Reggiani et al., DAC'23): non-uniform segment
//! placement buys accuracy at equal LUT size. This greedy fitter starts
//! from two knots and repeatedly splits the segment with the largest max
//! error at its worst point — simple, deterministic, and enough to power
//! the `plu-fit` CLI and the segment-count ablation bench.

/// A non-uniform piecewise-linear approximation (sorted knots).
#[derive(Clone, Debug)]
pub struct AdaptiveTable {
    /// Segment boundaries, ascending, len = segments + 1.
    pub knots: Vec<f32>,
    /// Per-segment slope (len = segments).
    pub slopes: Vec<f32>,
    /// Per-segment intercept.
    pub intercepts: Vec<f32>,
}

impl AdaptiveTable {
    /// Evaluate via binary search over the knots (the hardware analogue is
    /// a priority encoder over range comparators).
    pub fn eval(&self, x: f32) -> f32 {
        let n = self.slopes.len();
        let k = match self
            .knots
            .binary_search_by(|probe| probe.partial_cmp(&x).unwrap())
        {
            Ok(i) => i.min(n - 1),
            Err(0) => 0,
            Err(i) => (i - 1).min(n - 1),
        };
        self.slopes[k] * x + self.intercepts[k]
    }

    pub fn num_segments(&self) -> usize {
        self.slopes.len()
    }

    /// Max |f - approx| over a dense grid of the fitted range.
    pub fn max_abs_error(&self, f: impl Fn(f64) -> f64) -> f64 {
        let (lo, hi) = (self.knots[0] as f64, *self.knots.last().unwrap() as f64);
        let n = 50_001;
        let mut worst = 0.0f64;
        for i in 0..n {
            let x = lo + (hi - lo) * i as f64 / (n - 1) as f64;
            worst = worst.max((f(x) - self.eval(x as f32) as f64).abs());
        }
        worst
    }
}

fn secant(f: &impl Fn(f64) -> f64, x0: f64, x1: f64) -> (f32, f32) {
    let (y0, y1) = (f(x0), f(x1));
    let m = (y1 - y0) / (x1 - x0);
    (m as f32, (y0 - m * x0) as f32)
}

/// Worst-error point of the secant to `f` on `[x0, x1]` (grid probe).
fn worst_point(f: &impl Fn(f64) -> f64, x0: f64, x1: f64) -> (f64, f64) {
    let (m, c) = secant(f, x0, x1);
    let mut worst_x = 0.5 * (x0 + x1);
    let mut worst_e = 0.0;
    for i in 1..64 {
        let x = x0 + (x1 - x0) * i as f64 / 64.0;
        let e = (f(x) - (m as f64 * x + c as f64)).abs();
        if e > worst_e {
            worst_e = e;
            worst_x = x;
        }
    }
    (worst_x, worst_e)
}

/// Greedy adaptive fit of `f` on `[lo, hi]` with `segments` pieces.
pub fn fit_adaptive(
    f: impl Fn(f64) -> f64,
    lo: f32,
    hi: f32,
    segments: usize,
) -> AdaptiveTable {
    assert!(segments >= 1);
    let mut knots: Vec<f64> = vec![lo as f64, hi as f64];
    while knots.len() - 1 < segments {
        // find the segment with the largest worst-case error and split it
        // at its worst point
        let mut best = (0usize, 0.0f64, 0.0f64); // (idx, err, split_x)
        for i in 0..knots.len() - 1 {
            let (wx, we) = worst_point(&f, knots[i], knots[i + 1]);
            if we > best.1 {
                best = (i, we, wx);
            }
        }
        if best.1 == 0.0 {
            // function already linear everywhere; split the widest segment
            let i = (0..knots.len() - 1)
                .max_by(|&a, &b| {
                    (knots[a + 1] - knots[a])
                        .partial_cmp(&(knots[b + 1] - knots[b]))
                        .unwrap()
                })
                .unwrap();
            best = (i, 0.0, 0.5 * (knots[i] + knots[i + 1]));
        }
        knots.insert(best.0 + 1, best.2);
    }
    let mut slopes = Vec::with_capacity(segments);
    let mut intercepts = Vec::with_capacity(segments);
    for w in knots.windows(2) {
        let (m, c) = secant(&f, w[0], w[1]);
        slopes.push(m);
        intercepts.push(c);
    }
    AdaptiveTable {
        knots: knots.iter().map(|&x| x as f32).collect(),
        slopes,
        intercepts,
    }
}

#[cfg(test)]
mod tests {
    use super::super::{silu_exact, silu_table};
    use super::*;

    #[test]
    fn adaptive_beats_uniform_at_equal_budget() {
        for &k in &[8usize, 16, 32] {
            let uni = silu_table(k, -8.0, 8.0).max_abs_error(silu_exact, 0.0);
            let ada = fit_adaptive(silu_exact, -8.0, 8.0, k).max_abs_error(silu_exact);
            assert!(
                ada <= uni * 1.05,
                "k={k}: adaptive {ada} vs uniform {uni}"
            );
        }
    }

    #[test]
    fn knots_are_sorted_and_exact_count() {
        let t = fit_adaptive(silu_exact, -4.0, 4.0, 12);
        assert_eq!(t.num_segments(), 12);
        assert_eq!(t.knots.len(), 13);
        for w in t.knots.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn linear_function_fits_exactly() {
        let t = fit_adaptive(|x| 2.0 * x + 1.0, -1.0, 1.0, 4);
        assert!(t.max_abs_error(|x| 2.0 * x + 1.0) < 1e-6);
    }

    #[test]
    fn eval_clamps_out_of_range() {
        let t = fit_adaptive(silu_exact, -2.0, 2.0, 8);
        // out-of-range evaluation extrapolates the edge segments (finite)
        assert!(t.eval(-10.0).is_finite());
        assert!(t.eval(10.0).is_finite());
    }
}
