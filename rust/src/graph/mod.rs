//! Tensor-operator IR: graph, builder with shape inference, census.
//!
//! This is the repo's stand-in for the OpenVINO IR the paper's conversion
//! pipeline operates on: `models::` builds Mamba / Mamba-2 block graphs in
//! it, `passes::` applies the CumBA / ReduBA / ActiBA rewrites over it,
//! `exec::` compiles and executes it for correctness, and `npu::` costs
//! it for latency. Nodes are single-output, append-only; passes mutate
//! ops in place and run `dce` afterwards.

pub mod census;
pub mod op;
pub mod tensor;

pub use census::Census;
pub use op::{BinKind, ConstKind, Op, UnKind};
pub use tensor::{broadcast_shapes, numel, DType, Tensor};

use std::sync::Arc;

use crate::plu::PluTable;

/// Index of a node within its graph.
pub type NodeId = usize;

/// One IR node (single output).
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub op: Op,
    pub inputs: Vec<NodeId>,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub name: String,
    /// Constant payload (`Op::Const` only).
    pub value: Option<Tensor>,
}

/// An operator graph. `inputs`/`outputs` order defines the external ABI.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub inputs: Vec<NodeId>,
    pub outputs: Vec<NodeId>,
    pub name: String,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), ..Default::default() }
    }

    fn push(
        &mut self,
        op: Op,
        inputs: Vec<NodeId>,
        shape: Vec<usize>,
        dtype: DType,
        name: impl Into<String>,
    ) -> NodeId {
        let id = self.nodes.len();
        for &i in &inputs {
            assert!(i < id, "forward reference {i} in node {id}");
        }
        self.nodes.push(Node {
            id,
            op,
            inputs,
            shape,
            dtype,
            name: name.into(),
            value: None,
        });
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn shape(&self, id: NodeId) -> &[usize] {
        &self.nodes[id].shape
    }

    // --- graph inputs / constants ----------------------------------------

    /// Declare an external input of an explicit dtype (quantized serving
    /// graphs declare their weight inputs f16/i8).
    pub fn input_dtype(&mut self, name: &str, shape: Vec<usize>, dtype: DType) -> NodeId {
        let id = self.push(Op::Input { dtype }, vec![], shape, dtype, name);
        self.inputs.push(id);
        id
    }

    /// Declare an external f32 input.
    pub fn input(&mut self, name: &str, shape: Vec<usize>) -> NodeId {
        self.input_dtype(name, shape, DType::F32)
    }

    /// Declare an external i32 input (token indices).
    pub fn input_i32(&mut self, name: &str, shape: Vec<usize>) -> NodeId {
        self.input_dtype(name, shape, DType::I32)
    }

    /// Inline constant tensor.
    pub fn constant(&mut self, name: &str, t: Tensor) -> NodeId {
        self.constant_kind(name, t, ConstKind::Dense)
    }

    /// Inline constant with an explicit sparsity kind (mask constants).
    pub fn constant_kind(&mut self, name: &str, t: Tensor, kind: ConstKind) -> NodeId {
        let shape = t.shape.clone();
        let dtype = t.dtype();
        let id = self.push(Op::Const { kind }, vec![], shape, dtype, name);
        self.nodes[id].value = Some(t);
        id
    }

    /// The CumBA lower-triangular mask M[i,j] = (j <= i) as a constant.
    pub fn const_tril(&mut self, name: &str, n: usize) -> NodeId {
        self.const_tril_offset(name, n, 0)
    }

    /// Lower-triangular mask with a diagonal offset:
    /// M[i,j] = (j <= i + offset). SSD's segsum uses offset -1.
    pub fn const_tril_offset(&mut self, name: &str, n: usize, offset: i64) -> NodeId {
        let mut data = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                if (j as i64) <= i as i64 + offset {
                    data[i * n + j] = 1.0;
                }
            }
        }
        self.constant_kind(name, Tensor::f32(vec![n, n], data), ConstKind::TrilMask)
    }

    /// The ReduBA all-ones mask vector as a (1, n) constant.
    pub fn const_ones_row(&mut self, name: &str, n: usize) -> NodeId {
        self.constant_kind(
            name,
            Tensor::f32(vec![1, n], vec![1.0; n]),
            ConstKind::OnesMask,
        )
    }

    /// Scalar f32 constant.
    pub fn const_scalar(&mut self, name: &str, v: f32) -> NodeId {
        self.constant(name, Tensor::scalar(v))
    }

    // --- compute ops -------------------------------------------------------

    /// Dtype of a value-typed (non-i32) operand pair; both sides must
    /// agree — mixed-precision arithmetic goes through explicit
    /// Quantize/Dequantize nodes, never implicit promotion.
    fn value_dtype2(&self, a: NodeId, b: NodeId, name: &str) -> DType {
        let (da, db) = (self.node(a).dtype, self.node(b).dtype);
        assert_eq!(da, db, "dtype mismatch {da:?} vs {db:?} at {name}");
        assert_ne!(da, DType::I32, "i32 is an index type, not a value type, at {name}");
        da
    }

    /// Batched matmul [..., m, k] x [..., k, n]. Operand dtypes must
    /// match; i8 x i8 accumulates exactly (i32) and emits f32, f16 x f16
    /// accumulates in f32 and rounds the result back to f16.
    pub fn matmul(&mut self, a: NodeId, b: NodeId, name: &str) -> NodeId {
        let sa = self.shape(a).to_vec();
        let sb = self.shape(b).to_vec();
        let shape = matmul_shape(&sa, &sb)
            .unwrap_or_else(|| panic!("matmul shape mismatch {sa:?} x {sb:?} at {name}"));
        let dt = self.value_dtype2(a, b, name);
        let out_dt = if dt == DType::I8 { DType::F32 } else { dt };
        self.push(Op::MatMul, vec![a, b], shape, out_dt, name)
    }

    fn binary(&mut self, kind: BinKind, a: NodeId, b: NodeId, name: &str) -> NodeId {
        let sa = self.shape(a).to_vec();
        let sb = self.shape(b).to_vec();
        let shape = broadcast_shapes(&sa, &sb)
            .unwrap_or_else(|| panic!("broadcast mismatch {sa:?} vs {sb:?} at {name}"));
        let dt = self.value_dtype2(a, b, name);
        self.push(Op::Binary(kind), vec![a, b], shape, dt, name)
    }

    pub fn add(&mut self, a: NodeId, b: NodeId, name: &str) -> NodeId {
        self.binary(BinKind::Add, a, b, name)
    }

    pub fn sub(&mut self, a: NodeId, b: NodeId, name: &str) -> NodeId {
        self.binary(BinKind::Sub, a, b, name)
    }

    pub fn mul(&mut self, a: NodeId, b: NodeId, name: &str) -> NodeId {
        self.binary(BinKind::Mul, a, b, name)
    }

    pub fn div(&mut self, a: NodeId, b: NodeId, name: &str) -> NodeId {
        self.binary(BinKind::Div, a, b, name)
    }

    pub fn maximum(&mut self, a: NodeId, b: NodeId, name: &str) -> NodeId {
        self.binary(BinKind::Max, a, b, name)
    }

    pub fn unary(&mut self, kind: UnKind, x: NodeId, name: &str) -> NodeId {
        let shape = self.shape(x).to_vec();
        let dt = self.node(x).dtype;
        assert_ne!(dt, DType::I32, "unary {kind:?} needs a value dtype at {name}");
        self.push(Op::Unary(kind), vec![x], shape, dt, name)
    }

    pub fn exp(&mut self, x: NodeId, name: &str) -> NodeId {
        self.unary(UnKind::Exp, x, name)
    }

    pub fn silu(&mut self, x: NodeId, name: &str) -> NodeId {
        self.unary(UnKind::SiLU, x, name)
    }

    pub fn softplus(&mut self, x: NodeId, name: &str) -> NodeId {
        self.unary(UnKind::Softplus, x, name)
    }

    /// ActiBA PLU node (usually installed by the ActiBA pass, not by hand).
    pub fn plu(
        &mut self,
        x: NodeId,
        table: Arc<PluTable>,
        approximates: UnKind,
        name: &str,
    ) -> NodeId {
        let shape = self.shape(x).to_vec();
        let dt = self.node(x).dtype;
        assert!(
            matches!(dt, DType::F32 | DType::F16),
            "PLU needs f32/f16 input at {name}"
        );
        self.push(Op::Plu { table, approximates }, vec![x], shape, dt, name)
    }

    pub fn cumsum(&mut self, x: NodeId, axis: usize, name: &str) -> NodeId {
        let shape = self.shape(x).to_vec();
        assert!(axis < shape.len(), "cumsum axis {axis} of {shape:?}");
        let dt = self.node(x).dtype;
        assert_ne!(dt, DType::I32, "cumsum needs a value dtype at {name}");
        self.push(Op::CumSum { axis }, vec![x], shape, dt, name)
    }

    pub fn reduce_sum(&mut self, x: NodeId, axis: usize, name: &str) -> NodeId {
        let mut shape = self.shape(x).to_vec();
        assert!(axis < shape.len(), "reduce axis {axis} of {shape:?}");
        shape.remove(axis);
        let dt = self.node(x).dtype;
        assert_ne!(dt, DType::I32, "reduce_sum needs a value dtype at {name}");
        self.push(Op::ReduceSum { axis }, vec![x], shape, dt, name)
    }

    /// Row gather: `data[v, ...]` by i32 `indices[n]` -> `[n, ...]`.
    /// Pure data movement: the output keeps the table's dtype (an f16 /
    /// i8 embedding table gathers without widening).
    pub fn gather(&mut self, data: NodeId, indices: NodeId, name: &str) -> NodeId {
        let sd = self.shape(data).to_vec();
        let si = self.shape(indices).to_vec();
        assert_eq!(self.node(indices).dtype, DType::I32, "gather needs i32 idx");
        assert_eq!(si.len(), 1, "gather indices must be rank 1");
        let dt = self.node(data).dtype;
        assert_ne!(dt, DType::I32, "gather data needs a value dtype at {name}");
        let mut shape = vec![si[0]];
        shape.extend_from_slice(&sd[1..]);
        self.push(Op::Gather, vec![data, indices], shape, dt, name)
    }

    /// Depthwise causal conv over (T, C) with zero left-context.
    pub fn conv1d_causal(
        &mut self,
        x: NodeId,
        w: NodeId,
        b: NodeId,
        name: &str,
    ) -> NodeId {
        let sx = self.shape(x).to_vec();
        let sw = self.shape(w).to_vec();
        assert!(
            sx.len() == 2 || sx.len() == 3,
            "conv input must be (T, C) or (B, T, C)"
        );
        assert_eq!(sw.len(), 2, "conv weight must be (K, C)");
        let c = *sx.last().unwrap();
        assert_eq!(c, sw[1], "conv channel mismatch");
        assert_eq!(self.shape(b), &[c], "conv bias mismatch");
        let k = sw[0];
        let dt = self.value_dtype2(x, w, name);
        assert_eq!(self.node(b).dtype, dt, "conv bias dtype mismatch at {name}");
        assert!(
            matches!(dt, DType::F32 | DType::F16),
            "conv1d needs f32/f16 operands at {name}"
        );
        self.push(Op::Conv1dCausal { k }, vec![x, w, b], sx, dt, name)
    }

    pub fn rmsnorm(&mut self, x: NodeId, w: NodeId, name: &str) -> NodeId {
        let shape = self.shape(x).to_vec();
        assert_eq!(
            self.shape(w),
            &shape[shape.len() - 1..],
            "rmsnorm scale must match last dim"
        );
        let dt = self.value_dtype2(x, w, name);
        assert!(
            matches!(dt, DType::F32 | DType::F16),
            "rmsnorm needs f32/f16 operands at {name}"
        );
        self.push(Op::RmsNorm { eps: 1e-5 }, vec![x, w], shape, dt, name)
    }

    pub fn softmax(&mut self, x: NodeId, axis: usize, name: &str) -> NodeId {
        let shape = self.shape(x).to_vec();
        assert!(axis < shape.len());
        let dt = self.node(x).dtype;
        assert!(
            matches!(dt, DType::F32 | DType::F16),
            "softmax needs f32/f16 input at {name}"
        );
        self.push(Op::Softmax { axis }, vec![x], shape, dt, name)
    }

    /// Narrow f32 to `dtype` (f16 or i8; i8 computes a dynamic per-tensor
    /// symmetric scale at execution time). Installed by `passes::quantize`.
    pub fn quantize(&mut self, x: NodeId, dtype: DType, name: &str) -> NodeId {
        assert_eq!(self.node(x).dtype, DType::F32, "quantize takes f32 at {name}");
        assert!(
            matches!(dtype, DType::F16 | DType::I8),
            "quantize target must be f16/i8 at {name}"
        );
        let shape = self.shape(x).to_vec();
        self.push(Op::Quantize { dtype }, vec![x], shape, dtype, name)
    }

    /// Widen f16 / i8 back to f32.
    pub fn dequantize(&mut self, x: NodeId, name: &str) -> NodeId {
        assert!(
            matches!(self.node(x).dtype, DType::F16 | DType::I8),
            "dequantize takes f16/i8 at {name}"
        );
        let shape = self.shape(x).to_vec();
        self.push(Op::Dequantize, vec![x], shape, DType::F32, name)
    }

    // --- layout ops ---------------------------------------------------------

    pub fn slice(
        &mut self,
        x: NodeId,
        axis: usize,
        start: usize,
        len: usize,
        name: &str,
    ) -> NodeId {
        let mut shape = self.shape(x).to_vec();
        assert!(axis < shape.len(), "slice axis");
        assert!(start + len <= shape[axis], "slice out of range at {name}");
        shape[axis] = len;
        let dtype = self.node(x).dtype;
        self.push(Op::Slice { axis, start, len }, vec![x], shape, dtype, name)
    }

    pub fn concat(&mut self, xs: &[NodeId], axis: usize, name: &str) -> NodeId {
        assert!(!xs.is_empty());
        let mut shape = self.shape(xs[0]).to_vec();
        for &x in &xs[1..] {
            let s = self.shape(x);
            assert_eq!(s.len(), shape.len(), "concat rank mismatch");
            for (d, (&a, &b)) in shape.iter().zip(s).enumerate() {
                if d != axis {
                    assert_eq!(a, b, "concat dim {d} mismatch at {name}");
                }
            }
            shape[axis] += s[axis];
        }
        let dtype = self.node(xs[0]).dtype;
        self.push(Op::Concat { axis }, xs.to_vec(), shape, dtype, name)
    }

    pub fn reshape(&mut self, x: NodeId, shape: Vec<usize>, name: &str) -> NodeId {
        assert_eq!(
            numel(self.shape(x)),
            numel(&shape),
            "reshape numel mismatch at {name}"
        );
        let dtype = self.node(x).dtype;
        self.push(Op::Reshape { shape: shape.clone() }, vec![x], shape, dtype, name)
    }

    pub fn transpose(&mut self, x: NodeId, perm: Vec<usize>, name: &str) -> NodeId {
        let sx = self.shape(x).to_vec();
        assert_eq!(perm.len(), sx.len(), "perm rank mismatch");
        let shape: Vec<usize> = perm.iter().map(|&p| sx[p]).collect();
        let dtype = self.node(x).dtype;
        self.push(Op::Transpose { perm }, vec![x], shape, dtype, name)
    }

    pub fn broadcast(&mut self, x: NodeId, shape: Vec<usize>, name: &str) -> NodeId {
        let sx = self.shape(x).to_vec();
        assert_eq!(
            broadcast_shapes(&sx, &shape),
            Some(shape.clone()),
            "cannot broadcast {sx:?} to {shape:?} at {name}"
        );
        let dtype = self.node(x).dtype;
        self.push(Op::Broadcast { shape: shape.clone() }, vec![x], shape, dtype, name)
    }

    // --- graph management -----------------------------------------------------

    /// Raw node append for graph rewriters (passes): shape/dtype are the
    /// caller's responsibility, the topological (inputs < id) invariant is
    /// still enforced.
    pub fn add_node(
        &mut self,
        op: Op,
        inputs: Vec<NodeId>,
        shape: Vec<usize>,
        dtype: DType,
        name: String,
        value: Option<Tensor>,
    ) -> NodeId {
        let id = self.push(op, inputs, shape, dtype, name);
        self.nodes[id].value = value;
        id
    }

    /// Mark a node as a graph output.
    pub fn output(&mut self, id: NodeId) {
        self.outputs.push(id);
    }

    /// Nodes in executable order (nodes are append-only, so identity).
    pub fn topo_order(&self) -> impl Iterator<Item = NodeId> + '_ {
        0..self.nodes.len()
    }

    /// Count of nodes reachable from the outputs (live nodes).
    pub fn live_set(&self) -> Vec<bool> {
        let mut live = vec![false; self.nodes.len()];
        let mut stack: Vec<NodeId> = self.outputs.clone();
        while let Some(id) = stack.pop() {
            if live[id] {
                continue;
            }
            live[id] = true;
            stack.extend_from_slice(&self.nodes[id].inputs);
        }
        live
    }

    /// Number of live (reachable) nodes.
    pub fn live_count(&self) -> usize {
        self.live_set().iter().filter(|&&l| l).count()
    }
}

/// Shape of a batched matmul, or None if incompatible.
pub fn matmul_shape(a: &[usize], b: &[usize]) -> Option<Vec<usize>> {
    if a.len() < 2 || b.len() < 2 {
        return None;
    }
    let (m, ka) = (a[a.len() - 2], a[a.len() - 1]);
    let (kb, n) = (b[b.len() - 2], b[b.len() - 1]);
    if ka != kb {
        return None;
    }
    let batch_a = &a[..a.len() - 2];
    let batch_b = &b[..b.len() - 2];
    let batch: Vec<usize> = if batch_b.is_empty() {
        batch_a.to_vec()
    } else if batch_a.is_empty() {
        batch_b.to_vec()
    } else if batch_a == batch_b {
        batch_a.to_vec()
    } else {
        return None;
    };
    let mut out = batch;
    out.push(m);
    out.push(n);
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_infers_shapes() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![4, 8]);
        let b = g.input("b", vec![8, 3]);
        let m = g.matmul(a, b, "m");
        assert_eq!(g.shape(m), &[4, 3]);
        let s = g.slice(m, 1, 0, 2, "s");
        assert_eq!(g.shape(s), &[4, 2]);
        let r = g.reduce_sum(m, 0, "r");
        assert_eq!(g.shape(r), &[3]);
    }

    #[test]
    fn batched_matmul_shapes() {
        assert_eq!(matmul_shape(&[5, 2, 3], &[3, 4]), Some(vec![5, 2, 4]));
        assert_eq!(matmul_shape(&[5, 2, 3], &[5, 3, 4]), Some(vec![5, 2, 4]));
        assert_eq!(matmul_shape(&[2, 3], &[4, 5]), None);
        assert_eq!(matmul_shape(&[6, 2, 3], &[5, 3, 4]), None);
    }

    #[test]
    fn tril_mask_constant_is_correct() {
        let mut g = Graph::new("t");
        let m = g.const_tril("mask", 3);
        let t = g.node(m).value.as_ref().unwrap();
        assert_eq!(
            t.as_f32(),
            &[1., 0., 0., 1., 1., 0., 1., 1., 1.]
        );
        assert!(matches!(g.node(m).op, Op::Const { kind: ConstKind::TrilMask }));
    }

    #[test]
    fn live_set_tracks_reachability() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2, 2]);
        let b = g.input("b", vec![2, 2]);
        let dead = g.add(a, b, "dead");
        let live = g.mul(a, b, "live");
        g.output(live);
        let l = g.live_set();
        assert!(l[live] && l[a] && l[b]);
        assert!(!l[dead]);
        assert_eq!(g.live_count(), 3);
    }

    #[test]
    #[should_panic(expected = "broadcast mismatch")]
    fn bad_broadcast_panics() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2, 3]);
        let b = g.input("b", vec![2, 4]);
        g.add(a, b, "bad");
    }

    #[test]
    fn concat_shapes() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2, 3]);
        let b = g.input("b", vec![2, 5]);
        let c = g.concat(&[a, b], 1, "c");
        assert_eq!(g.shape(c), &[2, 8]);
    }

    #[test]
    fn dtypes_propagate_through_the_builder() {
        let mut g = Graph::new("t");
        let w = g.input_dtype("w", vec![4, 3], DType::I8);
        let x = g.input("x", vec![2, 4]);
        let xq = g.quantize(x, DType::I8, "xq");
        assert_eq!(g.node(xq).dtype, DType::I8);
        // i8 x i8 matmul emits f32 (exact accumulation, dequantized out)
        let m = g.matmul(xq, w, "m");
        assert_eq!(g.node(m).dtype, DType::F32);
        // f16 stays f16 through elementwise and matmul
        let h = g.input_dtype("h", vec![3, 3], DType::F16);
        let h2 = g.silu(h, "h2");
        assert_eq!(g.node(h2).dtype, DType::F16);
        let hm = g.matmul(h2, h, "hm");
        assert_eq!(g.node(hm).dtype, DType::F16);
        let hd = g.dequantize(hm, "hd");
        assert_eq!(g.node(hd).dtype, DType::F32);
        // layout ops preserve reduced precision
        let ht = g.transpose(h, vec![1, 0], "ht");
        assert_eq!(g.node(ht).dtype, DType::F16);
    }

    #[test]
    #[should_panic(expected = "dtype mismatch")]
    fn mixed_dtype_binary_panics() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2]);
        let b = g.input_dtype("b", vec![2], DType::F16);
        g.add(a, b, "bad");
    }
}
