//! Operator set of the IR.
//!
//! Mirrors the OpenVINO-level operator vocabulary the paper discusses
//! (MatMul, Add, Mul, CumSum, ReduceSum, Gather, activations, …) so the
//! operator census (Fig 5) and the NPU cost model see the same graph a
//! real conversion pipeline would produce. Everything is single-output;
//! graphs list multiple output nodes instead of tuple values.

use std::sync::Arc;

use crate::graph::tensor::DType;
use crate::plu::PluTable;

/// Binary elementwise operator kind (numpy broadcasting semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BinKind {
    Add,
    Sub,
    Mul,
    Div,
    Max,
}

/// Unary elementwise operator kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UnKind {
    Neg,
    Exp,
    Log,
    Sqrt,
    Abs,
    Recip,
    Relu,
    Sigmoid,
    /// Swish / SiLU — one of Mamba-1's two bottleneck activations (Fig 1).
    SiLU,
    /// Softplus — the other bottleneck activation.
    Softplus,
    Tanh,
}

/// How a constant was produced — the NPU datapath treats structured masks
/// specially (ZVC compression + sparsity compute-skip, paper Fig 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConstKind {
    /// Arbitrary data (weights): negligible sparsity in Mamba (paper §2.1).
    Dense,
    /// CumBA's lower-triangular mask: ~50 % zeros, ZVC-compressible.
    TrilMask,
    /// ReduBA's all-ones vector mask: reused across every output.
    OnesMask,
}

/// An IR operator.
#[derive(Clone, Debug)]
pub enum Op {
    /// External input (activations, weights, states).
    Input { dtype: DType },
    /// Constant tensor held inline; `kind` drives sparsity modeling.
    Const { kind: ConstKind },
    /// Batched matmul: [..., m, k] x [..., k, n] -> [..., m, n]
    /// (leading dims must match or be absent on either side).
    MatMul,
    Binary(BinKind),
    Unary(UnKind),
    /// ActiBA: piecewise-linear approximation evaluated in the drain-path
    /// PLU. `approximates` records the op it replaced (for reports).
    Plu { table: Arc<PluTable>, approximates: UnKind },
    /// Cumulative sum along `axis` — sequential on the DSP (paper §2.1).
    CumSum { axis: usize },
    /// Reduction sum along `axis` (keepdims=false).
    ReduceSum { axis: usize },
    /// Row gather: data [v, ...] indexed by i32 indices [n] -> [n, ...].
    Gather,
    /// Depthwise causal conv over (T, C): weights (K, C), bias (C,).
    Conv1dCausal { k: usize },
    /// RMS normalization over the last axis with learned scale.
    RmsNorm { eps: f32 },
    /// Narrow f32 to a reduced-precision dtype (f16 round-to-nearest-even
    /// or per-tensor symmetric i8 with a dynamically computed scale).
    /// Inserted by `passes::quantize`, not by model builders.
    Quantize { dtype: DType },
    /// Widen f16 / i8 back to f32 (exact for f16).
    Dequantize,
    /// Softmax along `axis` (census completeness; blocks don't use it).
    Softmax { axis: usize },
    Slice { axis: usize, start: usize, len: usize },
    Concat { axis: usize },
    Reshape { shape: Vec<usize> },
    Transpose { perm: Vec<usize> },
    /// Numpy-style broadcast to an explicit shape.
    Broadcast { shape: Vec<usize> },
}

impl Op {
    /// Census label — the operator vocabulary of paper Fig 5.
    pub fn census_name(&self) -> &'static str {
        match self {
            Op::Input { .. } => "Input",
            Op::Const { .. } => "Const",
            Op::MatMul => "MatMul",
            Op::Binary(BinKind::Add) => "Add",
            Op::Binary(BinKind::Sub) => "Subtract",
            Op::Binary(BinKind::Mul) => "Multiply",
            Op::Binary(BinKind::Div) => "Divide",
            Op::Binary(BinKind::Max) => "Maximum",
            Op::Unary(UnKind::Neg) => "Negative",
            Op::Unary(UnKind::Exp) => "Exp",
            Op::Unary(UnKind::Log) => "Log",
            Op::Unary(UnKind::Sqrt) => "Sqrt",
            Op::Unary(UnKind::Abs) => "Abs",
            Op::Unary(UnKind::Recip) => "Reciprocal",
            Op::Unary(UnKind::Relu) => "Relu",
            Op::Unary(UnKind::Sigmoid) => "Sigmoid",
            Op::Unary(UnKind::SiLU) => "Swish",
            Op::Unary(UnKind::Softplus) => "SoftPlus",
            Op::Unary(UnKind::Tanh) => "Tanh",
            Op::Plu { .. } => "PLU",
            Op::CumSum { .. } => "CumSum",
            Op::ReduceSum { .. } => "ReduceSum",
            Op::Gather => "Gather",
            Op::Conv1dCausal { .. } => "Conv1d",
            Op::RmsNorm { .. } => "RMSNorm",
            Op::Quantize { .. } => "Quantize",
            Op::Dequantize => "Dequantize",
            Op::Softmax { .. } => "Softmax",
            Op::Slice { .. } => "Slice",
            Op::Concat { .. } => "Concat",
            Op::Reshape { .. } => "Reshape",
            Op::Transpose { .. } => "Transpose",
            Op::Broadcast { .. } => "Broadcast",
        }
    }

    /// True for data-movement ops that cost no compute in the NPU model
    /// (they fold into DMA descriptors / tensor views).
    pub fn is_layout(&self) -> bool {
        matches!(
            self,
            Op::Reshape { .. }
                | Op::Transpose { .. }
                | Op::Broadcast { .. }
                | Op::Slice { .. }
                | Op::Concat { .. }
                | Op::Input { .. }
                | Op::Const { .. }
        )
    }
}
