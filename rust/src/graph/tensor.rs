//! Dense tensors for the IR interpreter (row-major, f32 or i32).

/// Element type of a tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// Tensor payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense row-major tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        Self { shape, data: Data::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        Self { shape, data: Data::I32(data) }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = numel(&shape);
        Self::f32(shape, vec![0.0; n])
    }

    pub fn scalar(v: f32) -> Self {
        Self::f32(vec![], vec![v])
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Borrow as f32 slice; panics on dtype mismatch.
    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("expected f32 tensor"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            Data::F32(_) => panic!("expected i32 tensor"),
        }
    }

    /// Row-major strides of this tensor's shape.
    pub fn strides(&self) -> Vec<usize> {
        strides(&self.shape)
    }

    /// Reshape in place (numel must match).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(numel(&shape), self.numel(), "reshape numel mismatch");
        self.shape = shape;
        self
    }
}

/// Product of dims (empty shape = scalar = 1).
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Row-major strides for a shape.
pub fn strides(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * shape[i + 1];
    }
    s
}

/// Numpy-style broadcast of two shapes; `None` when incompatible.
pub fn broadcast_shapes(a: &[usize], b: &[usize]) -> Option<Vec<usize>> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        out[i] = if da == db {
            da
        } else if da == 1 {
            db
        } else if db == 1 {
            da
        } else {
            return None;
        };
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides(&[5]), vec![1]);
        assert_eq!(strides(&[]), Vec::<usize>::new());
    }

    #[test]
    fn broadcast_rules() {
        assert_eq!(broadcast_shapes(&[3, 1], &[1, 4]), Some(vec![3, 4]));
        assert_eq!(broadcast_shapes(&[2, 3], &[3]), Some(vec![2, 3]));
        assert_eq!(broadcast_shapes(&[2, 3], &[4]), None);
        assert_eq!(broadcast_shapes(&[], &[2, 2]), Some(vec![2, 2]));
        assert_eq!(
            broadcast_shapes(&[8, 1, 6, 1], &[7, 1, 5]),
            Some(vec![8, 7, 6, 5])
        );
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn wrong_numel_panics() {
        Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar(3.5);
        assert_eq!(t.numel(), 1);
        assert_eq!(t.rank(), 0);
        assert_eq!(t.as_f32(), &[3.5]);
    }
}
