//! Dense tensors for the IR interpreter (row-major; f32, i32, and the
//! reduced-precision serving dtypes f16 + per-tensor-symmetric i8).

use crate::util::f16::{f16_to_f32, f32_to_f16};

/// Element type of a tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
    /// IEEE-754 half, stored as raw bits in `u16`.
    F16,
    /// Symmetric per-tensor int8: real value = `q * scale`.
    I8,
}

/// The serving dtypes `--dtype` accepts (i32 is an index type, not a
/// compute dtype).
pub const SERVE_DTYPES: [DType; 3] = [DType::F32, DType::F16, DType::I8];

impl DType {
    /// Bytes per element as stored.
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 => 2,
            DType::I8 => 1,
        }
    }

    /// Canonical lowercase name (`f32`/`i32`/`f16`/`i8`) — the `--dtype`
    /// flag vocabulary and the plan-key suffix.
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::F16 => "f16",
            DType::I8 => "i8",
        }
    }

    /// Parse a serving dtype name ("" = f32). `None` for anything else.
    pub fn parse_serve(s: &str) -> Option<DType> {
        match s {
            "" | "f32" => Some(DType::F32),
            "f16" => Some(DType::F16),
            "i8" => Some(DType::I8),
            _ => None,
        }
    }
}

/// Tensor payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// Raw IEEE-754 half bits.
    F16(Vec<u16>),
    /// Symmetric per-tensor quantized: real value = `data[i] * scale`.
    I8 { data: Vec<i8>, scale: f32 },
}

/// A dense row-major tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

// --- shared quantization scalar math -------------------------------------------
//
// ONE implementation of the f32 <-> i8 mapping, used by `Tensor::to_dtype`,
// the planned executor's quantize kernels, and the naive reference walker —
// so quantized planned-vs-naive differential tests can hold results to
// bitwise equality.

/// Largest |x| over a slice (non-finite values saturate the scale).
pub fn amax_abs(xs: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for &x in xs {
        let a = x.abs();
        if a > m {
            m = a;
        }
    }
    m
}

/// Per-tensor symmetric scale for a given amax. All-zero tensors map to
/// scale 1.0 so dequantization stays exact (0 * 1.0 = 0).
pub fn i8_scale(amax: f32) -> f32 {
    if amax > 0.0 {
        amax / 127.0
    } else {
        1.0
    }
}

/// Quantize one value: round-half-away-from-zero, clamped to the
/// symmetric range [-127, 127] (no -128: symmetry keeps `q*scale`
/// sign-exact).
#[inline]
pub fn quantize_i8_one(v: f32, scale: f32) -> i8 {
    let q = (v / scale).round();
    q.clamp(-127.0, 127.0) as i8
}

/// Dequantize one value.
#[inline]
pub fn dequantize_i8_one(q: i8, scale: f32) -> f32 {
    f32::from(q) * scale
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        Self { shape, data: Data::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        Self { shape, data: Data::I32(data) }
    }

    /// Half-precision tensor from raw IEEE-754 half bits.
    pub fn f16(shape: Vec<usize>, bits: Vec<u16>) -> Self {
        assert_eq!(numel(&shape), bits.len(), "shape/data mismatch");
        Self { shape, data: Data::F16(bits) }
    }

    /// Symmetric per-tensor int8 tensor.
    pub fn i8(shape: Vec<usize>, data: Vec<i8>, scale: f32) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        Self { shape, data: Data::I8 { data, scale } }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = numel(&shape);
        Self::f32(shape, vec![0.0; n])
    }

    pub fn scalar(v: f32) -> Self {
        Self::f32(vec![], vec![v])
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
            Data::F16(_) => DType::F16,
            Data::I8 { .. } => DType::I8,
        }
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Borrow as f32 slice; panics on dtype mismatch.
    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            _ => panic!("expected i32 tensor"),
        }
    }

    /// Borrow as raw half bits; panics on dtype mismatch.
    pub fn as_f16(&self) -> &[u16] {
        match &self.data {
            Data::F16(v) => v,
            _ => panic!("expected f16 tensor"),
        }
    }

    /// Borrow the quantized payload `(q, scale)`; panics on dtype mismatch.
    pub fn as_i8(&self) -> (&[i8], f32) {
        match &self.data {
            Data::I8 { data, scale } => (data, *scale),
            _ => panic!("expected i8 tensor"),
        }
    }

    /// Widen any numeric payload to an f32 vector (i32 excluded — it is
    /// an index type, not a value type).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        match &self.data {
            Data::F32(v) => v.clone(),
            Data::F16(v) => v.iter().map(|&b| f16_to_f32(b)).collect(),
            Data::I8 { data, scale } => {
                data.iter().map(|&q| dequantize_i8_one(q, *scale)).collect()
            }
            Data::I32(_) => panic!("i32 tensors do not widen to f32"),
        }
    }

    /// Convert to `dtype`. f32 <-> f16 and f32 <-> i8 (per-tensor
    /// symmetric, dynamic scale) are supported; i32 converts only to
    /// itself. Same-dtype conversion is a clone.
    pub fn to_dtype(&self, dtype: DType) -> Tensor {
        if self.dtype() == dtype {
            return self.clone();
        }
        match dtype {
            DType::F32 => Tensor::f32(self.shape.clone(), self.to_f32_vec()),
            DType::F16 => {
                let f = self.to_f32_vec();
                Tensor::f16(self.shape.clone(), f.iter().map(|&v| f32_to_f16(v)).collect())
            }
            DType::I8 => {
                let f = self.to_f32_vec();
                let scale = i8_scale(amax_abs(&f));
                Tensor::i8(
                    self.shape.clone(),
                    f.iter().map(|&v| quantize_i8_one(v, scale)).collect(),
                    scale,
                )
            }
            DType::I32 => panic!("cannot convert {:?} to i32", self.dtype()),
        }
    }

    /// Row-major strides of this tensor's shape.
    pub fn strides(&self) -> Vec<usize> {
        strides(&self.shape)
    }

    /// Reshape in place (numel must match).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(numel(&shape), self.numel(), "reshape numel mismatch");
        self.shape = shape;
        self
    }
}

/// Product of dims (empty shape = scalar = 1).
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Row-major strides for a shape.
pub fn strides(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * shape[i + 1];
    }
    s
}

/// Numpy-style broadcast of two shapes; `None` when incompatible.
pub fn broadcast_shapes(a: &[usize], b: &[usize]) -> Option<Vec<usize>> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        out[i] = if da == db {
            da
        } else if da == 1 {
            db
        } else if db == 1 {
            da
        } else {
            return None;
        };
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides(&[5]), vec![1]);
        assert_eq!(strides(&[]), Vec::<usize>::new());
    }

    #[test]
    fn broadcast_rules() {
        assert_eq!(broadcast_shapes(&[3, 1], &[1, 4]), Some(vec![3, 4]));
        assert_eq!(broadcast_shapes(&[2, 3], &[3]), Some(vec![2, 3]));
        assert_eq!(broadcast_shapes(&[2, 3], &[4]), None);
        assert_eq!(broadcast_shapes(&[], &[2, 2]), Some(vec![2, 2]));
        assert_eq!(
            broadcast_shapes(&[8, 1, 6, 1], &[7, 1, 5]),
            Some(vec![8, 7, 6, 5])
        );
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn wrong_numel_panics() {
        Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar(3.5);
        assert_eq!(t.numel(), 1);
        assert_eq!(t.rank(), 0);
        assert_eq!(t.as_f32(), &[3.5]);
    }

    #[test]
    fn dtype_sizes_and_names() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::F16.size_bytes(), 2);
        assert_eq!(DType::I8.size_bytes(), 1);
        assert_eq!(DType::I8.name(), "i8");
        assert_eq!(DType::parse_serve("f16"), Some(DType::F16));
        assert_eq!(DType::parse_serve(""), Some(DType::F32));
        assert_eq!(DType::parse_serve("int8"), None);
        assert_eq!(DType::parse_serve("i32"), None, "i32 is not a serving dtype");
    }

    #[test]
    fn f16_round_trip_through_tensor() {
        let t = Tensor::f32(vec![4], vec![1.0, -0.5, 0.0, 1024.0]);
        let h = t.to_dtype(DType::F16);
        assert_eq!(h.dtype(), DType::F16);
        let back = h.to_dtype(DType::F32);
        // all values exactly representable in f16
        assert_eq!(back.as_f32(), t.as_f32());
    }

    #[test]
    fn i8_quantization_is_symmetric_and_bounded() {
        let t = Tensor::f32(vec![5], vec![-2.0, -1.0, 0.0, 1.0, 2.0]);
        let q = t.to_dtype(DType::I8);
        let (qs, scale) = q.as_i8();
        assert_eq!(scale, 2.0 / 127.0);
        assert_eq!(qs, &[-127, -64, 0, 64, 127]);
        let back = q.to_dtype(DType::F32);
        for (a, b) in back.as_f32().iter().zip(t.as_f32()) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn all_zero_i8_dequantizes_exactly() {
        let t = Tensor::zeros(vec![3]);
        let q = t.to_dtype(DType::I8);
        assert_eq!(q.as_i8().1, 1.0);
        assert_eq!(q.to_dtype(DType::F32).as_f32(), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn same_dtype_conversion_is_identity() {
        let t = Tensor::f32(vec![2], vec![1.5, -2.5]);
        assert_eq!(t.to_dtype(DType::F32), t);
        let q = t.to_dtype(DType::I8);
        assert_eq!(q.to_dtype(DType::I8), q);
    }
}
