//! Operator census — the machinery behind paper Fig 5 (appendix A.1).
//!
//! The paper contrasts Mamba and Mamba-2 by their operator mix after
//! conversion (Mamba-2 introduces CumSum/ReduceSum, drops Gathers 18 -> 7,
//! MatMuls 8 -> 2) and argues the shift away from MPU-friendly ops is why
//! Mamba-2 is slower on NPUs. `Census` counts live ops in our IR graphs so
//! the `fig5_census` bench can print the same comparison.

use std::collections::BTreeMap;

use super::Graph;
use crate::util::Table;

/// Operator histogram of a graph.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Census {
    pub counts: BTreeMap<&'static str, usize>,
    pub total: usize,
}

impl Census {
    /// Count live (output-reachable) compute ops; Input/Const excluded.
    pub fn of(graph: &Graph) -> Self {
        let live = graph.live_set();
        let mut counts = BTreeMap::new();
        let mut total = 0;
        for node in &graph.nodes {
            if !live[node.id] {
                continue;
            }
            let name = node.op.census_name();
            if name == "Input" || name == "Const" {
                continue;
            }
            *counts.entry(name).or_insert(0) += 1;
            total += 1;
        }
        Self { counts, total }
    }

    pub fn get(&self, name: &str) -> usize {
        self.counts.get(name).copied().unwrap_or(0)
    }

    /// Side-by-side comparison table of several censuses (Fig 5 layout).
    pub fn comparison_table(labeled: &[(&str, &Census)]) -> Table {
        let mut header = vec!["op"];
        for (label, _) in labeled {
            header.push(label);
        }
        let mut table = Table::new(&header);
        let mut all_ops: Vec<&'static str> = Vec::new();
        for (_, c) in labeled {
            for &k in c.counts.keys() {
                if !all_ops.contains(&k) {
                    all_ops.push(k);
                }
            }
        }
        all_ops.sort();
        for op in all_ops {
            let mut row = vec![op.to_string()];
            for (_, c) in labeled {
                row.push(c.get(op).to_string());
            }
            table.row(&row);
        }
        let mut totals = vec!["TOTAL".to_string()];
        for (_, c) in labeled {
            totals.push(c.total.to_string());
        }
        table.row(&totals);
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn sample() -> Graph {
        let mut g = Graph::new("s");
        let a = g.input("a", vec![4, 4]);
        let b = g.input("b", vec![4, 4]);
        let m = g.matmul(a, b, "m");
        let s = g.silu(m, "act");
        let c = g.cumsum(s, 0, "cs");
        g.output(c);
        // dead op must not be counted
        g.softplus(a, "dead");
        g
    }

    #[test]
    fn counts_live_ops_only() {
        let c = Census::of(&sample());
        assert_eq!(c.get("MatMul"), 1);
        assert_eq!(c.get("Swish"), 1);
        assert_eq!(c.get("CumSum"), 1);
        assert_eq!(c.get("SoftPlus"), 0);
        assert_eq!(c.total, 3);
    }

    #[test]
    fn comparison_table_has_all_ops() {
        let g = sample();
        let c = Census::of(&g);
        let t = Census::comparison_table(&[("a", &c), ("b", &c)]);
        let s = t.render();
        assert!(s.contains("CumSum"));
        assert!(s.contains("TOTAL"));
    }
}
