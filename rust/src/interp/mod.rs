//! Reference interpreter for the IR: executes a `Graph` on concrete
//! tensors, f32, row-major, no tricks.
//!
//! Used to (a) machine-check that the CumBA / ReduBA / ActiBA passes
//! preserve semantics (`passes::verify`), and (b) run the Table-1
//! substitute quality evaluation on the trained tiny models without
//! touching PJRT. Throughput is a non-goal; clarity is.

mod ops;

use std::collections::HashMap;

use crate::graph::{Graph, NodeId, Op, Tensor};

/// Execute `graph` on the given input tensors (matched by input order).
///
/// Returns the output tensors in `graph.outputs` order.
pub fn run(graph: &Graph, inputs: &[Tensor]) -> Result<Vec<Tensor>, String> {
    if inputs.len() != graph.inputs.len() {
        return Err(format!(
            "graph {} expects {} inputs, got {}",
            graph.name,
            graph.inputs.len(),
            inputs.len()
        ));
    }
    let mut env: HashMap<NodeId, Tensor> = HashMap::with_capacity(graph.nodes.len());
    for (&id, t) in graph.inputs.iter().zip(inputs) {
        let node = graph.node(id);
        if t.shape != node.shape {
            return Err(format!(
                "input {} ({}): expected shape {:?}, got {:?}",
                id, node.name, node.shape, t.shape
            ));
        }
        if t.dtype() != node.dtype {
            return Err(format!("input {} ({}): dtype mismatch", id, node.name));
        }
        env.insert(id, t.clone());
    }

    let live = graph.live_set();
    for id in graph.topo_order() {
        if !live[id] || env.contains_key(&id) {
            continue;
        }
        let node = graph.node(id);
        let out = match &node.op {
            Op::Input { .. } => {
                return Err(format!("unbound input node {id} ({})", node.name))
            }
            Op::Const { .. } => node
                .value
                .clone()
                .ok_or_else(|| format!("const node {id} without value"))?,
            op => {
                let args: Vec<&Tensor> = node
                    .inputs
                    .iter()
                    .map(|i| env.get(i).expect("topo order violated"))
                    .collect();
                ops::eval(op, &args, &node.shape)
                    .map_err(|e| format!("node {id} ({}): {e}", node.name))?
            }
        };
        debug_assert_eq!(
            out.shape, node.shape,
            "node {id} ({}) shape drift",
            node.name
        );
        env.insert(id, out);
    }

    graph
        .outputs
        .iter()
        .map(|id| {
            env.get(id)
                .cloned()
                .ok_or_else(|| format!("missing output node {id}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn runs_a_small_graph() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2, 2]);
        let b = g.input("b", vec![2, 2]);
        let m = g.matmul(a, b, "m");
        let two = g.const_scalar("two", 2.0);
        let out = g.add(m, two, "out");
        g.output(out);
        let r = run(
            &g,
            &[
                Tensor::f32(vec![2, 2], vec![1., 2., 3., 4.]),
                Tensor::f32(vec![2, 2], vec![1., 1., 1., 1.]),
            ],
        )
        .unwrap();
        // same numbers as the /opt/xla-example smoke test
        assert_eq!(r[0].as_f32(), &[5., 5., 9., 9.]);
    }

    #[test]
    fn input_arity_checked() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![1]);
        g.output(a);
        assert!(run(&g, &[]).is_err());
    }

    #[test]
    fn input_shape_checked() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2]);
        g.output(a);
        let bad = Tensor::f32(vec![3], vec![0.0; 3]);
        assert!(run(&g, &[bad]).is_err());
    }

    #[test]
    fn dead_nodes_not_executed() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2]);
        // dead division by zero would produce inf but must not run
        let zero = g.const_scalar("z", 0.0);
        let _dead = g.div(a, zero, "dead");
        g.output(a);
        let r = run(&g, &[Tensor::f32(vec![2], vec![1., 2.])]).unwrap();
        assert_eq!(r[0].as_f32(), &[1., 2.]);
    }
}
