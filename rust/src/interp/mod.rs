//! Compatibility shim over the [`exec`](crate::exec) subsystem.
//!
//! The reference interpreter grew into a planned executor (`exec/`):
//! `interp::run` now compiles a one-shot [`ExecutionPlan`]
//! (schedule + arena + fused chains) and executes it, so every caller —
//! `passes::verify` differential testing, the quality eval, the ablation
//! benches — got faster without changing call sites. Callers that
//! evaluate one graph repeatedly should plan once via
//! [`exec::Backend`](crate::exec::Backend) instead. The original
//! HashMap walker lives on as [`exec::naive`](crate::exec::naive) for
//! differential testing (same structure and tests; scalar math is
//! shared with the planned kernels — see that module's header for the
//! exact independence boundary).

use crate::graph::{Graph, Tensor};

/// Execute `graph` on the given input tensors (matched by input order).
///
/// Returns the output tensors in `graph.outputs` order.
pub fn run(graph: &Graph, inputs: &[Tensor]) -> Result<Vec<Tensor>, String> {
    crate::exec::run_once(graph, inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn runs_a_small_graph() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2, 2]);
        let b = g.input("b", vec![2, 2]);
        let m = g.matmul(a, b, "m");
        let two = g.const_scalar("two", 2.0);
        let out = g.add(m, two, "out");
        g.output(out);
        let r = run(
            &g,
            &[
                Tensor::f32(vec![2, 2], vec![1., 2., 3., 4.]),
                Tensor::f32(vec![2, 2], vec![1., 1., 1., 1.]),
            ],
        )
        .unwrap();
        // same numbers as the /opt/xla-example smoke test
        assert_eq!(r[0].as_f32(), &[5., 5., 9., 9.]);
    }

    #[test]
    fn input_arity_checked() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![1]);
        g.output(a);
        assert!(run(&g, &[]).is_err());
    }

    #[test]
    fn input_shape_checked() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2]);
        g.output(a);
        let bad = Tensor::f32(vec![3], vec![0.0; 3]);
        assert!(run(&g, &[bad]).is_err());
    }

    #[test]
    fn dead_nodes_not_executed() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2]);
        // dead division by zero would produce inf but must not run
        let zero = g.const_scalar("z", 0.0);
        let _dead = g.div(a, zero, "dead");
        g.output(a);
        let r = run(&g, &[Tensor::f32(vec![2], vec![1., 2.])]).unwrap();
        assert_eq!(r[0].as_f32(), &[1., 2.]);
    }

    #[test]
    fn shim_agrees_with_naive_walker() {
        let mut g = Graph::new("t");
        let x = g.input("x", vec![3, 4]);
        let c = g.cumsum(x, 0, "c");
        let s = g.silu(c, "s");
        let r = g.reduce_sum(s, 1, "r");
        g.output(r);
        let t = Tensor::f32(vec![3, 4], (0..12).map(|i| i as f32 * 0.25 - 1.0).collect());
        let a = run(&g, &[t.clone()]).unwrap();
        let b = crate::exec::naive::run(&g, &[t]).unwrap();
        assert_eq!(a[0].as_f32(), b[0].as_f32());
    }
}
