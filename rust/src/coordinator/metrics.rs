//! Serving metrics: latency histograms + throughput counters.

use std::time::Instant;

use crate::util::{LatencyHistogram, Table};

/// Aggregated serving metrics (owned by the server loop; snapshot on read).
#[derive(Clone, Debug)]
pub struct Metrics {
    pub started: Instant,
    pub admitted: u64,
    pub rejected: u64,
    pub cancelled: u64,
    pub completed: u64,
    pub tokens_out: u64,
    pub prefills: u64,
    pub decode_calls: u64,
    pub decode_batched_seqs: u64,
    pub ttft_us: LatencyHistogram,
    pub e2e_us: LatencyHistogram,
    pub per_token_us: LatencyHistogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            started: Instant::now(),
            admitted: 0,
            rejected: 0,
            cancelled: 0,
            completed: 0,
            tokens_out: 0,
            prefills: 0,
            decode_calls: 0,
            decode_batched_seqs: 0,
            ttft_us: LatencyHistogram::new(),
            e2e_us: LatencyHistogram::new(),
            per_token_us: LatencyHistogram::new(),
        }
    }
}

impl Metrics {
    /// Aggregate decode throughput since start (Tokens/s — the paper's KPI).
    pub fn tokens_per_s(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64();
        if dt == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / dt
        }
    }

    /// Mean sequences per decode call (batching efficiency).
    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_calls == 0 {
            0.0
        } else {
            self.decode_batched_seqs as f64 / self.decode_calls as f64
        }
    }

    /// Render the serving report table.
    pub fn report(&self) -> Table {
        let mut t = Table::new(&["metric", "value"]).with_title("serving metrics");
        let rows = [
            ("admitted", format!("{}", self.admitted)),
            ("rejected", format!("{}", self.rejected)),
            ("cancelled", format!("{}", self.cancelled)),
            ("completed", format!("{}", self.completed)),
            ("tokens out", format!("{}", self.tokens_out)),
            ("tokens/s", format!("{:.1}", self.tokens_per_s())),
            ("prefills", format!("{}", self.prefills)),
            ("decode calls", format!("{}", self.decode_calls)),
            ("mean batch", format!("{:.2}", self.mean_decode_batch())),
            ("TTFT p50", format!("{:.2} ms", self.ttft_us.percentile_us(50.0) / 1e3)),
            ("TTFT p99", format!("{:.2} ms", self.ttft_us.percentile_us(99.0) / 1e3)),
            ("e2e p50", format!("{:.2} ms", self.e2e_us.percentile_us(50.0) / 1e3)),
            ("e2e p99", format!("{:.2} ms", self.e2e_us.percentile_us(99.0) / 1e3)),
            (
                "per-token p50",
                format!("{:.2} ms", self.per_token_us.percentile_us(50.0) / 1e3),
            ),
        ];
        for (k, v) in rows {
            t.row(&[k.to_string(), v]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batching_efficiency_math() {
        let mut m = Metrics::default();
        m.decode_calls = 4;
        m.decode_batched_seqs = 10;
        assert!((m.mean_decode_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn report_renders() {
        let m = Metrics::default();
        let s = m.report().render();
        assert!(s.contains("tokens/s"));
        assert!(s.contains("TTFT"));
    }
}
