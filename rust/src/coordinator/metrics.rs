//! Serving metrics: latency histograms + throughput counters.

use std::time::Instant;

use crate::util::{LatencyHistogram, Table};

/// Aggregated serving metrics (owned by the server loop; snapshot on read).
#[derive(Clone, Debug)]
pub struct Metrics {
    pub started: Instant,
    pub admitted: u64,
    /// Requests rejected as unschedulable (token cost beyond the whole
    /// `max_batch_total_tokens` budget).
    pub rejected: u64,
    /// Requests turned away by queue backpressure (FinishReason::Overloaded).
    pub overloaded: u64,
    pub cancelled: u64,
    /// Requests (waiting or decoding) cut off by their wall-clock
    /// deadline (FinishReason::DeadlineExceeded).
    pub deadline_expired: u64,
    /// Requests ended by a backend prefill/decode failure
    /// (FinishReason::Failed).
    pub failed: u64,
    pub completed: u64,
    pub tokens_out: u64,
    pub prefills: u64,
    /// Batched-prefill admission rounds (each covers >= 1 sequence).
    pub prefill_calls: u64,
    /// Sequences prefilled across those rounds — occupancy numerator.
    pub prefill_batched_seqs: u64,
    pub decode_calls: u64,
    pub decode_batched_seqs: u64,
    /// Pad slots executed by the decode batch remap (a non-bucket batch
    /// rounds its remainder up to the smallest compiled bucket).
    pub decode_padded_slots: u64,
    /// High-water mark of scheduler token-budget usage (prompt tokens +
    /// max_new_tokens headroom held by resident sequences).
    pub budget_peak: u64,
    /// Compiled-plan count of the backend (gauge; flat after warmup =
    /// membership churn never recompiled anything).
    pub plan_compiles: u64,
    /// Prefix-cache lookups that found a usable cached prefix.
    pub prefix_hits: u64,
    /// Prefix-cache lookups that found nothing to resume.
    pub prefix_misses: u64,
    /// Prefix-cache entries evicted under the LRU byte budget.
    pub prefix_evicted: u64,
    /// Tokens served FROM cached states instead of being re-prefilled.
    pub resumed_tokens: u64,
    /// Prefill chunk-graph invocations (resume / chunked-streaming path).
    pub prefill_chunks: u64,
    /// Router: requests whose `session_id` pinned them to the replica
    /// that already holds their conversation's prefix state.
    pub affinity_hits: u64,
    /// Router: requests re-routed off their pinned (or first-choice)
    /// replica — affinity re-pins after a drain/death, plus queued
    /// requests resubmitted off a dead replica.
    pub router_rebalanced: u64,
    /// Router: replicas observed transitioning healthy -> dead (engine
    /// thread gone); each one leaves the routing rotation.
    pub replica_unhealthy: u64,
    /// Speculative decoding: draft tokens proposed across verify steps.
    pub spec_proposed: u64,
    /// Speculative decoding: drafted tokens accepted by verification
    /// (the bonus token every step yields is NOT counted here, so
    /// acceptance rate is the proposer's true hit rate).
    pub spec_accepted: u64,
    /// Tokens emitted across decode/verify rounds — numerator of the
    /// tokens-per-step gauge (denominator `decode_calls`); > 1.0 per
    /// step is speculation paying off.
    pub decode_step_tokens: u64,
    pub ttft_us: LatencyHistogram,
    pub e2e_us: LatencyHistogram,
    pub per_token_us: LatencyHistogram,
    /// Wall latency of each whole decode batch call (all bucket sizes).
    pub decode_batch_us: LatencyHistogram,
    /// Wall latency of each batched-prefill admission round.
    pub prefill_batch_us: LatencyHistogram,
    /// Wall latency of each streaming-prefill chunk (per-chunk TTFT
    /// progress: how long each slice of a long prompt took).
    pub prefill_chunk_us: LatencyHistogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            started: Instant::now(),
            admitted: 0,
            rejected: 0,
            overloaded: 0,
            cancelled: 0,
            deadline_expired: 0,
            failed: 0,
            completed: 0,
            tokens_out: 0,
            prefills: 0,
            prefill_calls: 0,
            prefill_batched_seqs: 0,
            decode_calls: 0,
            decode_batched_seqs: 0,
            decode_padded_slots: 0,
            budget_peak: 0,
            plan_compiles: 0,
            prefix_hits: 0,
            prefix_misses: 0,
            prefix_evicted: 0,
            resumed_tokens: 0,
            prefill_chunks: 0,
            affinity_hits: 0,
            router_rebalanced: 0,
            replica_unhealthy: 0,
            spec_proposed: 0,
            spec_accepted: 0,
            decode_step_tokens: 0,
            ttft_us: LatencyHistogram::new(),
            e2e_us: LatencyHistogram::new(),
            per_token_us: LatencyHistogram::new(),
            decode_batch_us: LatencyHistogram::new(),
            prefill_batch_us: LatencyHistogram::new(),
            prefill_chunk_us: LatencyHistogram::new(),
        }
    }
}

impl Metrics {
    /// Fold another snapshot into this one (fleet aggregation): counters
    /// add, histograms merge bucket-wise (the log-bucketed histograms
    /// make cross-replica percentiles exact up to bucket resolution),
    /// `budget_peak` takes the max (each replica budgets independently,
    /// so the fleet peak is the worst single replica), and
    /// `plan_compiles` adds (each replica owns a separate plan cache).
    /// `started` keeps the earlier of the two so `tokens_per_s` spans
    /// the whole fleet's lifetime.
    pub fn merge(&mut self, other: &Metrics) {
        if other.started < self.started {
            self.started = other.started;
        }
        self.admitted += other.admitted;
        self.rejected += other.rejected;
        self.overloaded += other.overloaded;
        self.cancelled += other.cancelled;
        self.deadline_expired += other.deadline_expired;
        self.failed += other.failed;
        self.completed += other.completed;
        self.tokens_out += other.tokens_out;
        self.prefills += other.prefills;
        self.prefill_calls += other.prefill_calls;
        self.prefill_batched_seqs += other.prefill_batched_seqs;
        self.decode_calls += other.decode_calls;
        self.decode_batched_seqs += other.decode_batched_seqs;
        self.decode_padded_slots += other.decode_padded_slots;
        self.budget_peak = self.budget_peak.max(other.budget_peak);
        self.plan_compiles += other.plan_compiles;
        self.prefix_hits += other.prefix_hits;
        self.prefix_misses += other.prefix_misses;
        self.prefix_evicted += other.prefix_evicted;
        self.resumed_tokens += other.resumed_tokens;
        self.prefill_chunks += other.prefill_chunks;
        self.affinity_hits += other.affinity_hits;
        self.router_rebalanced += other.router_rebalanced;
        self.replica_unhealthy += other.replica_unhealthy;
        self.spec_proposed += other.spec_proposed;
        self.spec_accepted += other.spec_accepted;
        self.decode_step_tokens += other.decode_step_tokens;
        self.ttft_us.merge(&other.ttft_us);
        self.e2e_us.merge(&other.e2e_us);
        self.per_token_us.merge(&other.per_token_us);
        self.decode_batch_us.merge(&other.decode_batch_us);
        self.prefill_batch_us.merge(&other.prefill_batch_us);
        self.prefill_chunk_us.merge(&other.prefill_chunk_us);
    }

    /// Aggregate decode throughput since start (Tokens/s — the paper's KPI).
    pub fn tokens_per_s(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64();
        if dt == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / dt
        }
    }

    /// Mean sequences per decode call (batching efficiency).
    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_calls == 0 {
            0.0
        } else {
            self.decode_batched_seqs as f64 / self.decode_calls as f64
        }
    }

    /// Mean sequences per batched-prefill round (admission occupancy —
    /// 1.0 means every admission still prefills alone).
    pub fn mean_prefill_batch(&self) -> f64 {
        if self.prefill_calls == 0 {
            0.0
        } else {
            self.prefill_batched_seqs as f64 / self.prefill_calls as f64
        }
    }

    /// Real-sequence fraction of executed decode slots: 1.0 means every
    /// slot of every compiled bucket run carried a live sequence (no
    /// remap padding).
    pub fn decode_slot_utilization(&self) -> f64 {
        let total = self.decode_batched_seqs + self.decode_padded_slots;
        if total == 0 {
            0.0
        } else {
            self.decode_batched_seqs as f64 / total as f64
        }
    }

    /// Fraction of drafted tokens that verification accepted (0.0 when
    /// speculation never ran). The per-step bonus token is excluded, so
    /// this is the proposer's hit rate, not the speedup.
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_proposed as f64
        }
    }

    /// Mean tokens emitted per decode/verify round (1.0 = plain decode;
    /// speculation pushes it toward the verify window size).
    pub fn decode_tokens_per_step(&self) -> f64 {
        if self.decode_calls == 0 {
            0.0
        } else {
            self.decode_step_tokens as f64 / self.decode_calls as f64
        }
    }

    /// Fraction of prefix-cache lookups that resumed a cached state.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }

    /// Decode-batch latency percentiles in microseconds: (p50, p95, p99).
    pub fn decode_batch_percentiles_us(&self) -> (f64, f64, f64) {
        (
            self.decode_batch_us.percentile_us(50.0),
            self.decode_batch_us.percentile_us(95.0),
            self.decode_batch_us.percentile_us(99.0),
        )
    }

    /// Time-to-first-token percentiles in microseconds: (p50, p95, p99)
    /// — the KPI the batched admission path exists to cut.
    pub fn ttft_percentiles_us(&self) -> (f64, f64, f64) {
        (
            self.ttft_us.percentile_us(50.0),
            self.ttft_us.percentile_us(95.0),
            self.ttft_us.percentile_us(99.0),
        )
    }

    /// Render the serving report table.
    pub fn report(&self) -> Table {
        let (batch_p50, batch_p95, batch_p99) = self.decode_batch_percentiles_us();
        let (ttft_p50, ttft_p95, ttft_p99) = self.ttft_percentiles_us();
        let mut t = Table::new(&["metric", "value"]).with_title("serving metrics");
        let rows = [
            ("admitted", format!("{}", self.admitted)),
            ("rejected", format!("{}", self.rejected)),
            ("overloaded", format!("{}", self.overloaded)),
            ("cancelled", format!("{}", self.cancelled)),
            ("deadline expired", format!("{}", self.deadline_expired)),
            ("failed", format!("{}", self.failed)),
            ("completed", format!("{}", self.completed)),
            ("budget peak", format!("{}", self.budget_peak)),
            ("tokens out", format!("{}", self.tokens_out)),
            ("tokens/s", format!("{:.1}", self.tokens_per_s())),
            ("prefills", format!("{}", self.prefills)),
            ("prefill rounds", format!("{}", self.prefill_calls)),
            ("mean prefill batch", format!("{:.2}", self.mean_prefill_batch())),
            (
                "prefill batch p50",
                format!("{:.2} ms", self.prefill_batch_us.percentile_us(50.0) / 1e3),
            ),
            (
                "prefix cache hit/miss",
                format!("{}/{}", self.prefix_hits, self.prefix_misses),
            ),
            ("prefix evicted", format!("{}", self.prefix_evicted)),
            ("resumed tokens", format!("{}", self.resumed_tokens)),
            ("prefill chunks", format!("{}", self.prefill_chunks)),
            (
                "prefill chunk p50",
                format!("{:.2} ms", self.prefill_chunk_us.percentile_us(50.0) / 1e3),
            ),
            ("decode calls", format!("{}", self.decode_calls)),
            ("mean batch", format!("{:.2}", self.mean_decode_batch())),
            ("padded decode slots", format!("{}", self.decode_padded_slots)),
            (
                "decode slot utilization",
                format!("{:.2}", self.decode_slot_utilization()),
            ),
            (
                "spec proposed/accepted",
                format!("{}/{}", self.spec_proposed, self.spec_accepted),
            ),
            (
                "spec acceptance rate",
                format!("{:.2}", self.spec_acceptance_rate()),
            ),
            (
                "decode tokens/step",
                format!("{:.2}", self.decode_tokens_per_step()),
            ),
            ("plan compiles", format!("{}", self.plan_compiles)),
            ("affinity hits", format!("{}", self.affinity_hits)),
            ("router rebalanced", format!("{}", self.router_rebalanced)),
            ("replica unhealthy", format!("{}", self.replica_unhealthy)),
            ("TTFT p50", format!("{:.2} ms", ttft_p50 / 1e3)),
            ("TTFT p95", format!("{:.2} ms", ttft_p95 / 1e3)),
            ("TTFT p99", format!("{:.2} ms", ttft_p99 / 1e3)),
            ("e2e p50", format!("{:.2} ms", self.e2e_us.percentile_us(50.0) / 1e3)),
            ("e2e p99", format!("{:.2} ms", self.e2e_us.percentile_us(99.0) / 1e3)),
            (
                "per-token p50",
                format!("{:.2} ms", self.per_token_us.percentile_us(50.0) / 1e3),
            ),
            ("decode batch p50", format!("{:.2} ms", batch_p50 / 1e3)),
            ("decode batch p95", format!("{:.2} ms", batch_p95 / 1e3)),
            ("decode batch p99", format!("{:.2} ms", batch_p99 / 1e3)),
        ];
        for (k, v) in rows {
            t.row(&[k.to_string(), v]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batching_efficiency_math() {
        let mut m = Metrics::default();
        m.decode_calls = 4;
        m.decode_batched_seqs = 10;
        assert!((m.mean_decode_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn report_renders() {
        let m = Metrics::default();
        let s = m.report().render();
        assert!(s.contains("tokens/s"));
        assert!(s.contains("TTFT p95"));
        assert!(s.contains("decode batch p95"));
        assert!(s.contains("mean prefill batch"));
        assert!(s.contains("overloaded"));
        assert!(s.contains("deadline expired"));
        assert!(s.contains("budget peak"));
        assert!(s.contains("padded decode slots"));
        assert!(s.contains("plan compiles"));
        assert!(s.contains("affinity hits"));
        assert!(s.contains("router rebalanced"));
        assert!(s.contains("replica unhealthy"));
        assert!(s.contains("spec acceptance rate"));
        assert!(s.contains("decode tokens/step"));
    }

    #[test]
    fn speculation_gauges_math_and_merge() {
        let mut m = Metrics::default();
        assert_eq!(m.spec_acceptance_rate(), 0.0);
        assert_eq!(m.decode_tokens_per_step(), 0.0);
        m.spec_proposed = 8;
        m.spec_accepted = 6;
        m.decode_calls = 4;
        m.decode_step_tokens = 10;
        assert!((m.spec_acceptance_rate() - 0.75).abs() < 1e-12);
        assert!((m.decode_tokens_per_step() - 2.5).abs() < 1e-12);

        let mut other = Metrics::default();
        other.spec_proposed = 2;
        other.spec_accepted = 2;
        other.decode_calls = 1;
        other.decode_step_tokens = 3;
        m.merge(&other);
        assert_eq!(m.spec_proposed, 10);
        assert_eq!(m.spec_accepted, 8);
        assert_eq!(m.decode_step_tokens, 13);
        assert!((m.spec_acceptance_rate() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn merge_aggregates_counters_histograms_and_peaks() {
        let mut a = Metrics::default();
        a.admitted = 3;
        a.completed = 2;
        a.tokens_out = 10;
        a.budget_peak = 40;
        a.plan_compiles = 5;
        a.affinity_hits = 1;
        a.ttft_us.record_us(100.0);
        a.ttft_us.record_us(200.0);

        let mut b = Metrics::default();
        b.admitted = 4;
        b.completed = 4;
        b.tokens_out = 20;
        b.budget_peak = 25;
        b.plan_compiles = 7;
        b.router_rebalanced = 2;
        b.replica_unhealthy = 1;
        b.ttft_us.record_us(300.0);

        a.merge(&b);
        assert_eq!(a.admitted, 7);
        assert_eq!(a.completed, 6);
        assert_eq!(a.tokens_out, 30);
        // independent per-replica budgets: fleet peak is the worst ONE
        assert_eq!(a.budget_peak, 40);
        // separate plan caches: compile counts add
        assert_eq!(a.plan_compiles, 12);
        assert_eq!(a.affinity_hits, 1);
        assert_eq!(a.router_rebalanced, 2);
        assert_eq!(a.replica_unhealthy, 1);
        assert_eq!(a.ttft_us.count(), 3, "histograms merge bucket-wise");
        // merging an empty snapshot is the identity
        let snapshot = a.clone();
        a.merge(&Metrics::default());
        assert_eq!(a.admitted, snapshot.admitted);
        assert_eq!(a.ttft_us.count(), snapshot.ttft_us.count());
        assert_eq!(a.budget_peak, snapshot.budget_peak);
    }

    #[test]
    fn decode_slot_utilization_math() {
        let mut m = Metrics::default();
        assert_eq!(m.decode_slot_utilization(), 0.0);
        m.decode_batched_seqs = 9;
        m.decode_padded_slots = 3;
        assert!((m.decode_slot_utilization() - 0.75).abs() < 1e-12);
        m.decode_padded_slots = 0;
        assert_eq!(m.decode_slot_utilization(), 1.0);
    }

    #[test]
    fn prefix_hit_rate_math_and_report_rows() {
        let mut m = Metrics::default();
        assert_eq!(m.prefix_hit_rate(), 0.0);
        m.prefix_hits = 3;
        m.prefix_misses = 1;
        m.resumed_tokens = 4096;
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
        let s = m.report().render();
        assert!(s.contains("prefix cache hit/miss"));
        assert!(s.contains("resumed tokens"));
        assert!(s.contains("prefill chunks"));
    }

    #[test]
    fn prefill_occupancy_math() {
        let mut m = Metrics::default();
        assert_eq!(m.mean_prefill_batch(), 0.0);
        m.prefill_calls = 3;
        m.prefill_batched_seqs = 7;
        assert!((m.mean_prefill_batch() - 7.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn ttft_percentiles_track_recordings() {
        let mut m = Metrics::default();
        for us in 1..=1000 {
            m.ttft_us.record_us(us as f64);
        }
        let (p50, p95, p99) = m.ttft_percentiles_us();
        assert!(p50 < p95 && p95 < p99, "{p50} {p95} {p99}");
        assert!((p95 - 950.0).abs() / 950.0 < 0.06, "p95 {p95}");
    }

    #[test]
    fn decode_batch_percentiles_track_recordings() {
        let mut m = Metrics::default();
        for us in 1..=1000 {
            m.decode_batch_us.record_us(us as f64);
        }
        let (p50, p95, p99) = m.decode_batch_percentiles_us();
        assert!((p50 - 500.0).abs() / 500.0 < 0.06, "p50 {p50}");
        assert!(p50 < p95 && p95 < p99, "{p50} {p95} {p99}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.06, "p99 {p99}");
    }
}
