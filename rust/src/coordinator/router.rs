//! Replicated serving: a router that owns the ingress queue and fans
//! requests out to N independent replica engines.
//!
//! Each replica is a full token-budget engine (its own model, execution
//! pool, state-slot cache, and metrics) behind the slim [`ReplicaHandle`]
//! trait; the router adds the fleet-level control plane on one thread:
//!
//! - **Least-loaded routing** by live token cost: each replica's
//!   outstanding (estimated prompt tokens + `max_new_tokens` headroom)
//!   is the load signal, mirroring the per-engine scheduler budget.
//! - **Session affinity**: a request carrying a `session_id` pins to the
//!   replica that served the conversation's previous turn, so its O(1)
//!   recurrent state stays resident in that replica's prefix cache and
//!   the follow-up resumes in O(new tokens) — the SSM serving advantage
//!   a KV-cache fleet cannot keep without shipping the cache around.
//! - **Liveness / readiness**: a dead replica (engine thread gone) or a
//!   draining one leaves the rotation. Its queued, not-yet-started
//!   requests re-route to survivors; an in-flight decode that died with
//!   the replica is failed WITH its partial output — a reply channel is
//!   never silently dropped.
//! - **Rolling restart**: [`Router::drain`] + [`Router::restart`]
//!   replace one replica under load; dispatch flows around it while it
//!   is down and the swap waits for its in-flight work to finish.
//!
//! Every dispatched request is watched by a relay thread forwarding the
//! replica's stream to the client. The relay is where failover lives: a
//! disconnect before any token means the request never started (the
//! router re-routes it and counts `router_rebalanced`); a disconnect
//! after tokens flowed means the replica hard-died mid-decode, so the
//! relay synthesizes a `Failed` response carrying the partial output.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ServeConfig;

use super::metrics::Metrics;
use super::model::ServeModel;
use super::request::{FinishReason, GenParams, Response, StreamEvent};
use super::server::Server;

/// The seam between the router and one replica engine. Deliberately
/// slim — submit / health / drain / metrics / shutdown — so a future
/// out-of-process replica (a socket to another host) can slot in
/// without touching the routing logic.
pub trait ReplicaHandle: Send {
    /// Submit for streaming delivery. The returned channel disconnecting
    /// WITHOUT a terminal `Done` event is the hard-death signal the
    /// router's relay watches for.
    fn submit_streaming(&self, prompt: &[u8], params: GenParams)
        -> Receiver<StreamEvent>;
    /// Liveness: false once the engine is gone (clean exit or panic).
    fn healthy(&self) -> bool;
    /// Readiness: healthy AND accepting new work (false while draining).
    fn ready(&self) -> bool;
    /// Stop accepting new work; in-flight requests keep running.
    fn drain(&self);
    /// Metrics snapshot (stays readable after the engine died).
    fn metrics(&self) -> Metrics;
    /// Human-readable identity for status output (model/dtype/workers).
    fn descriptor(&self) -> String;
    /// Stop the engine (in-flight work completes) and return its final
    /// metrics.
    fn shutdown(self: Box<Self>) -> Metrics;
}

/// An in-process replica: one [`Server`] engine plus the router-facing
/// readiness latch ([`ReplicaHandle::drain`] flips it; the engine itself
/// keeps running so in-flight decodes finish).
pub struct EngineReplica {
    server: Server,
    desc: String,
    accepting: AtomicBool,
}

impl EngineReplica {
    pub fn new(server: Server, desc: String) -> Self {
        Self { server, desc, accepting: AtomicBool::new(true) }
    }

    /// Start a replica over any model factory (the model is constructed
    /// inside the engine thread, like [`Server::start`]).
    pub fn start<F>(factory: F, cfg: ServeConfig, desc: String) -> Result<Self>
    where
        F: FnOnce() -> Result<Box<dyn ServeModel>> + Send + 'static,
    {
        Ok(Self::new(Server::start(factory, cfg)?, desc))
    }

    /// Start a replica on the planned executor.
    pub fn start_planned(cfg: &ServeConfig, desc: String) -> Result<Self> {
        Ok(Self::new(super::server::start_planned(cfg)?, desc))
    }
}

impl ReplicaHandle for EngineReplica {
    fn submit_streaming(
        &self,
        prompt: &[u8],
        params: GenParams,
    ) -> Receiver<StreamEvent> {
        self.server.submit_streaming(prompt, params)
    }

    fn healthy(&self) -> bool {
        self.server.is_alive()
    }

    fn ready(&self) -> bool {
        self.healthy() && self.accepting.load(Ordering::SeqCst)
    }

    fn drain(&self) {
        self.accepting.store(false, Ordering::SeqCst);
    }

    fn metrics(&self) -> Metrics {
        self.server.metrics()
    }

    fn descriptor(&self) -> String {
        self.desc.clone()
    }

    fn shutdown(self: Box<Self>) -> Metrics {
        self.server.shutdown()
    }
}

/// How the submitting client wants its output delivered (the router's
/// mirror of the engine's private reply enum).
enum ClientReply {
    Final(Sender<Response>),
    Stream(Sender<StreamEvent>),
}

impl ClientReply {
    fn finish(&self, resp: Response) {
        match self {
            ClientReply::Final(tx) => {
                let _ = tx.send(resp);
            }
            ClientReply::Stream(tx) => {
                let _ = tx.send(StreamEvent::Done(resp));
            }
        }
    }
}

/// A request traveling through the router (queued, dispatched, or being
/// resubmitted after a replica death).
struct RouterRequest {
    id: u64,
    prompt: Vec<u8>,
    params: GenParams,
    reply: ClientReply,
    /// Estimated token cost (prompt bytes + `max_new_tokens` headroom) —
    /// the same shape as the engine scheduler's budget charge, held
    /// against the target replica while the request is outstanding.
    cost: usize,
    /// Dispatch attempts so far; a request that bounced off every
    /// replica fails loudly instead of ping-ponging forever.
    attempts: usize,
    /// Replicas that already dropped this request. Routing skips them:
    /// liveness detection (the engine thread's join state) can trail the
    /// reply-channel drop by a beat, and a resubmit must not race back
    /// onto the corpse it just bounced off.
    tried: Vec<usize>,
}

enum RouterMsg {
    Submit(RouterRequest),
    /// A relay saw its replica die before ANY token arrived: the request
    /// never started, so it is safe to run elsewhere.
    Resubmit(usize, RouterRequest),
    /// A relay resolved (delivered `Done`, synthesized a partial-output
    /// failure, or observed client cancellation): release the charge.
    Done { replica: usize, cost: usize, failed_partial: bool },
    Drain(usize),
    Restart(usize),
    Shutdown,
}

/// Router-side bookkeeping for one replica slot. The handle is `None`
/// only after a failed restart (the slot is then permanently dead).
struct ReplicaSlot {
    handle: Option<Box<dyn ReplicaHandle>>,
    /// Outstanding estimated token cost — the least-loaded signal.
    inflight_cost: usize,
    /// Outstanding dispatched requests (gates the per-replica cap and
    /// defers restarts until the replica is idle).
    inflight_reqs: usize,
    was_healthy: bool,
    restart_pending: bool,
    desc: String,
}

impl ReplicaSlot {
    fn new(handle: Box<dyn ReplicaHandle>) -> Self {
        let desc = handle.descriptor();
        let was_healthy = handle.healthy();
        Self {
            handle: Some(handle),
            inflight_cost: 0,
            inflight_reqs: 0,
            was_healthy,
            restart_pending: false,
            desc,
        }
    }

    fn healthy(&self) -> bool {
        self.handle.as_ref().map(|h| h.healthy()).unwrap_or(false)
    }

    fn ready(&self) -> bool {
        !self.restart_pending
            && self.handle.as_ref().map(|h| h.ready()).unwrap_or(false)
    }
}

/// Point-in-time view of one replica for status output.
#[derive(Clone, Debug)]
pub struct ReplicaStatus {
    pub index: usize,
    pub descriptor: String,
    pub healthy: bool,
    pub ready: bool,
    /// Requests dispatched and not yet resolved.
    pub inflight_requests: usize,
    /// Estimated token cost outstanding (the routing load signal).
    pub inflight_tokens: usize,
    pub metrics: Metrics,
}

struct RouterShared {
    aggregate: Metrics,
    replicas: Vec<ReplicaStatus>,
}

/// Front-end over a replica fleet; the client API mirrors [`Server`]
/// (`submit` / `submit_streaming` / `metrics` / `shutdown`) plus the
/// fleet control plane (`drain` / `restart` / `replica_status`).
pub struct Router {
    tx: Sender<RouterMsg>,
    worker: Option<std::thread::JoinHandle<()>>,
    shared: Arc<Mutex<RouterShared>>,
    next_id: AtomicU64,
}

impl Router {
    /// Build `replicas` engines via `factory(index)` and start the
    /// routing loop. The factory is kept for rolling restarts, so it is
    /// `Fn`, not `FnOnce`. `inflight_cap` bounds dispatched-unresolved
    /// requests per replica (0 = uncapped); keep it at or below each
    /// engine's `queue_cap` so load-balanced dispatch alone can never
    /// trip a replica's own Overloaded backpressure.
    pub fn start<F>(replicas: usize, inflight_cap: usize, factory: F) -> Result<Router>
    where
        F: Fn(usize) -> Result<Box<dyn ReplicaHandle>> + Send + 'static,
    {
        let n = replicas.max(1);
        let mut slots = Vec::with_capacity(n);
        for i in 0..n {
            slots.push(ReplicaSlot::new(factory(i)?));
        }
        let shared = Arc::new(Mutex::new(RouterShared {
            aggregate: Metrics::default(),
            replicas: Vec::new(),
        }));
        let (tx, rx) = channel::<RouterMsg>();
        let relay_tx = tx.clone();
        let shared2 = shared.clone();
        let worker = std::thread::Builder::new()
            .name("xamba-router".into())
            .spawn(move || {
                router_loop(slots, factory, inflight_cap, rx, relay_tx, shared2)
            })
            .expect("spawn router");
        Ok(Router {
            tx,
            worker: Some(worker),
            shared,
            next_id: AtomicU64::new(1),
        })
    }

    fn enqueue(&self, prompt: &[u8], params: GenParams, reply: ClientReply) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // byte-level tokenizer: prompt bytes ~ prompt tokens, so this is
        // the same cost shape the engine scheduler charges
        let cost = prompt.len().max(1) + params.max_new_tokens;
        let req = RouterRequest {
            id,
            prompt: prompt.to_vec(),
            params,
            reply,
            cost,
            attempts: 0,
            tried: Vec::new(),
        };
        // a send error means the router already shut down; the receiver
        // reports disconnection to the caller
        let _ = self.tx.send(RouterMsg::Submit(req));
    }

    /// Submit a prompt; returns a receiver for the final response.
    pub fn submit(&self, prompt: &[u8], params: GenParams) -> Receiver<Response> {
        let (reply_tx, reply_rx) = channel();
        self.enqueue(prompt, params, ClientReply::Final(reply_tx));
        reply_rx
    }

    /// Submit a prompt for streaming delivery (tokens forwarded from the
    /// serving replica as they are sampled).
    pub fn submit_streaming(
        &self,
        prompt: &[u8],
        params: GenParams,
    ) -> Receiver<StreamEvent> {
        let (reply_tx, reply_rx) = channel();
        self.enqueue(prompt, params, ClientReply::Stream(reply_tx));
        reply_rx
    }

    /// Fleet-aggregated metrics: every replica's snapshot folded through
    /// [`Metrics::merge`], plus the router's own counters
    /// (`affinity_hits`, `router_rebalanced`, `replica_unhealthy`).
    pub fn metrics(&self) -> Metrics {
        self.shared.lock().unwrap().aggregate.clone()
    }

    /// Per-replica status (health, readiness, live load, metrics).
    pub fn replica_status(&self) -> Vec<ReplicaStatus> {
        self.shared.lock().unwrap().replicas.clone()
    }

    /// Take one replica out of rotation; its in-flight work finishes.
    pub fn drain(&self, replica: usize) {
        let _ = self.tx.send(RouterMsg::Drain(replica));
    }

    /// Rolling restart: drain the replica, wait for its in-flight work,
    /// then rebuild it with the spawn factory and return it to rotation.
    pub fn restart(&self, replica: usize) {
        let _ = self.tx.send(RouterMsg::Restart(replica));
    }

    /// Stop accepting work, drain the fleet, and return the final
    /// aggregated metrics.
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.tx.send(RouterMsg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.shared.lock().unwrap().aggregate.clone()
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.tx.send(RouterMsg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Deliver an empty `Failed` response (no healthy replica could take the
/// request) — the client always hears back.
fn fail_request(req: &RouterRequest, local: &mut Metrics) {
    local.failed += 1;
    req.reply.finish(Response {
        id: req.id,
        prompt: req.prompt.clone(),
        generated: vec![],
        finish: FinishReason::Failed,
        ttft_us: 0.0,
        e2e_us: 0.0,
        batch_trace: vec![],
    });
}

/// Apply one control/ingress message; true = shutdown requested.
fn on_msg(
    msg: RouterMsg,
    pending: &mut VecDeque<RouterRequest>,
    slots: &mut [ReplicaSlot],
    sessions: &mut HashMap<u64, usize>,
    local: &mut Metrics,
) -> bool {
    match msg {
        RouterMsg::Submit(req) => pending.push_back(req),
        RouterMsg::Resubmit(from, mut req) => {
            if let Some(s) = slots.get_mut(from) {
                s.inflight_reqs = s.inflight_reqs.saturating_sub(1);
                s.inflight_cost = s.inflight_cost.saturating_sub(req.cost);
            }
            // un-pin the session from the replica that dropped it so the
            // re-route below establishes a fresh pin
            if let Some(sid) = req.params.session_id {
                if sessions.get(&sid) == Some(&from) {
                    sessions.remove(&sid);
                }
            }
            local.router_rebalanced += 1;
            req.attempts += 1;
            if req.attempts >= slots.len() {
                // bounced off every replica: give up loudly
                fail_request(&req, local);
            } else {
                pending.push_back(req);
            }
        }
        RouterMsg::Done { replica, cost, failed_partial } => {
            if let Some(s) = slots.get_mut(replica) {
                s.inflight_reqs = s.inflight_reqs.saturating_sub(1);
                s.inflight_cost = s.inflight_cost.saturating_sub(cost);
            }
            if failed_partial {
                local.failed += 1;
            }
        }
        RouterMsg::Drain(i) => {
            if let Some(s) = slots.get(i) {
                if let Some(h) = &s.handle {
                    h.drain();
                }
            }
        }
        RouterMsg::Restart(i) => {
            if let Some(s) = slots.get_mut(i) {
                if let Some(h) = &s.handle {
                    h.drain();
                }
                s.restart_pending = true;
            }
        }
        RouterMsg::Shutdown => return true,
    }
    false
}

enum RouteOutcome {
    To(usize),
    /// No replica can take the request RIGHT NOW (all at capacity or
    /// draining) but at least one is alive: keep it queued.
    Hold,
    /// Every replica is dead: the request can never run.
    NoReplica,
}

/// Pick a replica: session affinity first (the pinned replica holds the
/// conversation's recurrent state — residency beats load balance, so the
/// pin also bypasses the inflight cap), else least outstanding token
/// cost among ready, under-cap replicas.
fn route(
    slots: &[ReplicaSlot],
    sessions: &mut HashMap<u64, usize>,
    local: &mut Metrics,
    req: &RouterRequest,
    inflight_cap: usize,
) -> RouteOutcome {
    if let Some(sid) = req.params.session_id {
        if let Some(&r) = sessions.get(&sid) {
            if !req.tried.contains(&r)
                && slots.get(r).map(|s| s.ready()).unwrap_or(false)
            {
                local.affinity_hits += 1;
                return RouteOutcome::To(r);
            }
            // the pinned replica left the rotation (drained or died):
            // the conversation re-pins to a survivor and re-prefills
            sessions.remove(&sid);
            local.router_rebalanced += 1;
        }
    }
    let mut best: Option<usize> = None;
    for (i, s) in slots.iter().enumerate() {
        if !s.ready() || req.tried.contains(&i) {
            continue;
        }
        if inflight_cap > 0 && s.inflight_reqs >= inflight_cap {
            continue;
        }
        if best
            .map(|b| s.inflight_cost < slots[b].inflight_cost)
            .unwrap_or(true)
        {
            best = Some(i);
        }
    }
    match best {
        Some(r) => {
            if let Some(sid) = req.params.session_id {
                sessions.insert(sid, r);
            }
            RouteOutcome::To(r)
        }
        None if slots.iter().any(|s| s.healthy()) => RouteOutcome::Hold,
        None => RouteOutcome::NoReplica,
    }
}

/// Hand a routed request to its replica and spawn the relay thread that
/// watches the reply stream.
fn dispatch(
    replica: usize,
    req: RouterRequest,
    slots: &mut [ReplicaSlot],
    tx: &Sender<RouterMsg>,
) {
    let events = slots[replica]
        .handle
        .as_ref()
        .expect("routed to a live replica")
        .submit_streaming(&req.prompt, req.params.clone());
    slots[replica].inflight_reqs += 1;
    slots[replica].inflight_cost += req.cost;
    let tx = tx.clone();
    std::thread::Builder::new()
        .name("xamba-relay".into())
        .spawn(move || relay(replica, req, events, tx))
        .expect("spawn relay");
}

/// Forward one replica stream to the client and classify how it ended.
/// Runs on its own thread so a stalled replica never blocks the router.
fn relay(
    replica: usize,
    mut req: RouterRequest,
    events: Receiver<StreamEvent>,
    tx: Sender<RouterMsg>,
) {
    let started = Instant::now();
    let mut first_token: Option<Instant> = None;
    let mut collected: Vec<u8> = Vec::new();
    loop {
        match events.recv() {
            Ok(StreamEvent::Token(t)) => {
                if first_token.is_none() {
                    first_token = Some(Instant::now());
                }
                collected.push(t);
                if let ClientReply::Stream(ctx) = &req.reply {
                    if ctx.send(StreamEvent::Token(t)).is_err() {
                        // client walked away: dropping `events` cancels
                        // the request at the replica's next decode step
                        let _ = tx.send(RouterMsg::Done {
                            replica,
                            cost: req.cost,
                            failed_partial: false,
                        });
                        return;
                    }
                }
            }
            Ok(StreamEvent::Done(resp)) => {
                req.reply.finish(resp);
                let _ = tx.send(RouterMsg::Done {
                    replica,
                    cost: req.cost,
                    failed_partial: false,
                });
                return;
            }
            Err(_) => {
                // the replica engine died without finishing this request
                if collected.is_empty() {
                    // never started (still queued behind the engine's
                    // admission): safe to run on a survivor
                    req.tried.push(replica);
                    let _ = tx.send(RouterMsg::Resubmit(replica, req));
                } else {
                    // mid-decode: fail WITH the partial output so the
                    // client learns exactly what it got
                    req.reply.finish(Response {
                        id: req.id,
                        prompt: req.prompt.clone(),
                        generated: collected,
                        finish: FinishReason::Failed,
                        ttft_us: first_token
                            .map(|t| t.duration_since(started).as_micros() as f64)
                            .unwrap_or(0.0),
                        e2e_us: started.elapsed().as_micros() as f64,
                        batch_trace: vec![],
                    });
                    let _ = tx.send(RouterMsg::Done {
                        replica,
                        cost: req.cost,
                        failed_partial: true,
                    });
                }
                return;
            }
        }
    }
}

/// Publish the aggregated + per-replica snapshot for [`Router::metrics`]
/// and [`Router::replica_status`] (the slots live on the loop thread).
fn publish(
    slots: &[ReplicaSlot],
    local: &Metrics,
    retired: &Metrics,
    shared: &Arc<Mutex<RouterShared>>,
) {
    let mut aggregate = local.clone();
    aggregate.merge(retired);
    let mut replicas = Vec::with_capacity(slots.len());
    for (i, s) in slots.iter().enumerate() {
        let (healthy, ready, metrics) = match &s.handle {
            Some(h) => (h.healthy(), s.ready(), h.metrics()),
            None => (false, false, Metrics::default()),
        };
        aggregate.merge(&metrics);
        replicas.push(ReplicaStatus {
            index: i,
            descriptor: s.desc.clone(),
            healthy,
            ready,
            inflight_requests: s.inflight_reqs,
            inflight_tokens: s.inflight_cost,
            metrics,
        });
    }
    let mut sh = shared.lock().unwrap();
    sh.aggregate = aggregate;
    sh.replicas = replicas;
}

fn router_loop<F>(
    mut slots: Vec<ReplicaSlot>,
    factory: F,
    inflight_cap: usize,
    rx: Receiver<RouterMsg>,
    relay_tx: Sender<RouterMsg>,
    shared: Arc<Mutex<RouterShared>>,
) where
    F: Fn(usize) -> Result<Box<dyn ReplicaHandle>>,
{
    let mut pending: VecDeque<RouterRequest> = VecDeque::new();
    let mut sessions: HashMap<u64, usize> = HashMap::new();
    // the router's own counters (affinity/rebalance/health + requests it
    // failed itself); replica counters are merged in at publish time
    let mut local = Metrics::default();
    // final metrics of replicas retired by restart or shutdown
    let mut retired = Metrics::default();
    let mut shutting_down = false;

    loop {
        // --- ingress + relay resolutions --------------------------------
        loop {
            match rx.try_recv() {
                Ok(msg) => {
                    if on_msg(msg, &mut pending, &mut slots, &mut sessions, &mut local)
                    {
                        shutting_down = true;
                    }
                }
                Err(_) => break,
            }
        }

        // --- health sweep ------------------------------------------------
        for s in slots.iter_mut() {
            let h = s.healthy();
            if s.was_healthy && !h {
                // engine thread gone: out of rotation; its dispatched
                // requests resolve through their relays (resubmit or
                // partial-output failure), never a dropped channel
                local.replica_unhealthy += 1;
            }
            s.was_healthy = h;
        }

        // --- deferred restarts ------------------------------------------
        // a restart waits until the replica's outstanding requests have
        // all resolved (drain stopped new dispatch), then swaps engines
        for i in 0..slots.len() {
            if !slots[i].restart_pending || slots[i].inflight_reqs != 0 {
                continue;
            }
            if let Some(h) = slots[i].handle.take() {
                retired.merge(&h.shutdown());
            }
            match factory(i) {
                Ok(h) => {
                    slots[i].desc = h.descriptor();
                    slots[i].was_healthy = h.healthy();
                    slots[i].handle = Some(h);
                }
                Err(e) => {
                    eprintln!("replica {i} restart failed: {e:#}");
                    local.replica_unhealthy += 1;
                    slots[i].was_healthy = false;
                }
            }
            slots[i].restart_pending = false;
        }

        // --- dispatch ----------------------------------------------------
        let mut held: VecDeque<RouterRequest> = VecDeque::new();
        while let Some(req) = pending.pop_front() {
            match route(&slots, &mut sessions, &mut local, &req, inflight_cap) {
                RouteOutcome::To(r) => dispatch(r, req, &mut slots, &relay_tx),
                RouteOutcome::Hold => {
                    if shutting_down {
                        // nothing will free up once we stop: fail instead
                        // of deadlocking the drain
                        fail_request(&req, &mut local);
                    } else {
                        held.push_back(req);
                    }
                }
                RouteOutcome::NoReplica => fail_request(&req, &mut local),
            }
        }
        pending = held;

        // --- publish -----------------------------------------------------
        publish(&slots, &local, &retired, &shared);

        // --- drained shutdown -------------------------------------------
        if shutting_down
            && pending.is_empty()
            && slots.iter().all(|s| s.inflight_reqs == 0)
        {
            for s in slots.iter_mut() {
                if let Some(h) = s.handle.take() {
                    retired.merge(&h.shutdown());
                }
            }
            publish(&slots, &local, &retired, &shared);
            return;
        }

        // --- idle wait ---------------------------------------------------
        match rx.recv_timeout(Duration::from_millis(1)) {
            Ok(msg) => {
                if on_msg(msg, &mut pending, &mut slots, &mut sessions, &mut local) {
                    shutting_down = true;
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            // unreachable while the loop holds a relay sender, but the
            // defensive arm keeps the loop total
            Err(RecvTimeoutError::Disconnected) => shutting_down = true,
        }
    }
}

/// Per-replica config: the base serving config with this replica's
/// dtype / worker-count overrides applied (heterogeneous fleets:
/// `--replicas 4 --replica-dtypes f32,f16,i8,i8`).
pub fn replica_config(cfg: &ServeConfig, index: usize) -> ServeConfig {
    let mut c = cfg.clone();
    if let Some(dt) = cfg.replica_dtypes.get(index) {
        c.dtype = dt.clone();
    }
    if let Some(&w) = cfg.replica_workers.get(index) {
        c.workers = w;
    }
    c
}

/// Start a router over `cfg.replicas` planned-executor engines, each
/// configured by [`replica_config`]. Validates the base config (and each
/// per-replica dtype) up front.
pub fn start_planned_router(cfg: &ServeConfig) -> Result<Router> {
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    let base = cfg.clone();
    Router::start(cfg.replicas.max(1), cfg.replica_inflight, move |i| {
        let c = replica_config(&base, i);
        let desc = format!(
            "replica{}:{}:{}:{} workers={}",
            i, c.model, c.variant, c.dtype, c.workers
        );
        Ok(Box::new(EngineReplica::start_planned(&c, desc)?) as Box<dyn ReplicaHandle>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::model::MockModel;

    fn mock_fleet(n: usize) -> Router {
        Router::start(n, 32, move |i| {
            let cfg = ServeConfig {
                max_slots: 8,
                queue_cap: 64,
                batch_wait_us: 100,
                ..Default::default()
            };
            let server = Server::start(
                move || Ok(Box::new(MockModel::new(8, 256, vec![1, 2, 4])) as _),
                cfg,
            )?;
            Ok(Box::new(EngineReplica::new(server, format!("mock{i}")))
                as Box<dyn ReplicaHandle>)
        })
        .unwrap()
    }

    #[test]
    fn requests_complete_across_the_fleet() {
        let router = mock_fleet(2);
        let rxs: Vec<_> = (0..6)
            .map(|_| {
                router.submit(
                    b"a",
                    GenParams { max_new_tokens: 4, ..Default::default() },
                )
            })
            .collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(r.finish, FinishReason::Length);
            assert_eq!(r.generated, b"bcde");
        }
        let m = router.shutdown();
        assert_eq!(m.completed, 6);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn streaming_relays_tokens_through_the_router() {
        let router = mock_fleet(2);
        let rx = router.submit_streaming(
            b"a",
            GenParams { max_new_tokens: 4, ..Default::default() },
        );
        let mut tokens = Vec::new();
        let mut done = None;
        while let Ok(ev) = rx.recv_timeout(Duration::from_secs(10)) {
            match ev {
                StreamEvent::Token(t) => tokens.push(t),
                StreamEvent::Done(r) => {
                    done = Some(r);
                    break;
                }
            }
        }
        assert_eq!(tokens, b"bcde");
        assert_eq!(done.expect("no Done event").generated, b"bcde");
        router.shutdown();
    }

    #[test]
    fn session_requests_pin_and_count_affinity_hits() {
        let router = mock_fleet(2);
        for _ in 0..3 {
            let r = router
                .submit(
                    b"a",
                    GenParams {
                        max_new_tokens: 3,
                        session_id: Some(7),
                        ..Default::default()
                    },
                )
                .recv_timeout(Duration::from_secs(10))
                .unwrap();
            assert_eq!(r.finish, FinishReason::Length);
        }
        let m = router.shutdown();
        // turn 1 establishes the pin; turns 2 and 3 hit it
        assert_eq!(m.affinity_hits, 2);
        assert_eq!(m.router_rebalanced, 0);
        assert_eq!(m.completed, 3);
    }

    #[test]
    fn drained_replica_leaves_rotation() {
        let router = mock_fleet(2);
        router.drain(0);
        // wait for the loop to apply the drain and publish it
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let st = router.replica_status();
            if st.len() == 2 && !st[0].ready && st[1].ready {
                break;
            }
            assert!(Instant::now() < deadline, "drain never published");
            std::thread::sleep(Duration::from_millis(2));
        }
        let rxs: Vec<_> = (0..4)
            .map(|_| {
                router.submit(
                    b"a",
                    GenParams { max_new_tokens: 3, ..Default::default() },
                )
            })
            .collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(r.finish, FinishReason::Length);
        }
        // the published snapshot can trail the loop by one iteration
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let st = router.replica_status();
            assert_eq!(st[0].metrics.admitted, 0, "drained replica took work");
            if st[1].metrics.admitted == 4 {
                break;
            }
            assert!(Instant::now() < deadline, "snapshot never caught up");
            std::thread::sleep(Duration::from_millis(2));
        }
        router.shutdown();
    }

    #[test]
    fn replica_config_applies_per_replica_overrides() {
        let cfg = ServeConfig {
            replicas: 3,
            replica_dtypes: vec!["f32".into(), "f16".into(), "i8".into()],
            replica_workers: vec![1, 2],
            ..Default::default()
        };
        let c0 = replica_config(&cfg, 0);
        let c1 = replica_config(&cfg, 1);
        let c2 = replica_config(&cfg, 2);
        assert_eq!((c0.dtype.as_str(), c0.workers), ("f32", 1));
        assert_eq!((c1.dtype.as_str(), c1.workers), ("f16", 2));
        // lists shorter than the fleet fall back to the base config
        assert_eq!(c2.dtype, "i8");
        assert_eq!(c2.workers, ServeConfig::default().workers);
    }
}
