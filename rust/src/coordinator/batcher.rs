//! Bucketed dynamic batching policy.
//!
//! Static-shape NPU serving can only run the batch sizes it compiled
//! (paper Step-1: fixed shapes), so the batcher picks, each iteration, the
//! largest compiled bucket that the currently-decodable sequences fill,
//! optionally waiting a short window for stragglers to fill a bigger
//! bucket. Leftover sequences round-robin to the front next iteration so
//! no sequence starves.

/// Bucket-selection decision.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchPlan {
    /// Bucket (compiled batch size) to run now; 0 = run nothing.
    pub bucket: usize,
    /// Whether waiting `wait_us` could upgrade to a larger bucket.
    pub could_grow: bool,
}

/// Pick the largest bucket <= `ready` sequences. `buckets` ascending.
pub fn plan(buckets: &[usize], ready: usize) -> BatchPlan {
    let bucket = buckets.iter().copied().filter(|&b| b <= ready).max().unwrap_or(0);
    let could_grow = buckets.iter().any(|&b| b > ready);
    BatchPlan { bucket, could_grow }
}

/// Round-robin selector over active sequence slots: returns the next
/// `count` entries starting at the rotation cursor, advancing it.
#[derive(Debug, Default)]
pub struct RoundRobin {
    cursor: usize,
}

impl RoundRobin {
    /// Select `count` items from `items` (must satisfy count <= len).
    pub fn select<T: Copy>(&mut self, items: &[T], count: usize) -> Vec<T> {
        assert!(count <= items.len());
        let n = items.len();
        let start = if n == 0 { 0 } else { self.cursor % n };
        let picked: Vec<T> = (0..count).map(|i| items[(start + i) % n]).collect();
        self.cursor = if n == 0 { 0 } else { (start + count) % n };
        picked
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::check;

    #[test]
    fn picks_largest_fitting_bucket() {
        let buckets = [1, 2, 4, 8];
        assert_eq!(plan(&buckets, 0).bucket, 0);
        assert_eq!(plan(&buckets, 1).bucket, 1);
        assert_eq!(plan(&buckets, 3).bucket, 2);
        assert_eq!(plan(&buckets, 8).bucket, 8);
        assert_eq!(plan(&buckets, 100).bucket, 8);
    }

    #[test]
    fn growth_signal() {
        let buckets = [1, 2, 4];
        assert!(plan(&buckets, 3).could_grow);
        assert!(!plan(&buckets, 4).could_grow);
        assert!(!plan(&buckets, 9).could_grow);
    }

    #[test]
    fn round_robin_is_fair() {
        let mut rr = RoundRobin::default();
        let items = [10, 20, 30];
        // repeatedly take 2 of 3: every item must appear 2 times in 3 rounds
        let mut counts = std::collections::HashMap::new();
        for _ in 0..3 {
            for x in rr.select(&items, 2) {
                *counts.entry(x).or_insert(0) += 1;
            }
        }
        assert!(counts.values().all(|&c| c == 2), "{counts:?}");
    }

    #[test]
    fn property_bucket_never_exceeds_ready() {
        check(
            |r| (r.below(20), r.below(4)),
            |&(ready, _)| {
                let buckets = [1usize, 2, 4, 8];
                let p = plan(&buckets, ready);
                if p.bucket > ready {
                    return Err(format!("bucket {} > ready {ready}", p.bucket));
                }
                if ready >= 1 && p.bucket == 0 {
                    return Err("starved despite ready work".into());
                }
                Ok(())
            },
        );
    }
}
