//! Bucketed dynamic batching policy.
//!
//! Static-shape NPU serving can only run the batch sizes it compiled
//! (paper Step-1: fixed shapes), so the batcher picks, each iteration, the
//! largest compiled bucket that the currently-decodable sequences fill,
//! optionally waiting a short window for stragglers to fill a bigger
//! bucket. Leftover sequences round-robin to the front next iteration so
//! no sequence starves.

/// Bucket-selection decision.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchPlan {
    /// Bucket (compiled batch size) to run now; 0 = run nothing.
    pub bucket: usize,
    /// Whether waiting `wait_us` could upgrade to a larger bucket.
    pub could_grow: bool,
}

/// Pick the largest bucket <= `ready` sequences. `buckets` ascending.
pub fn plan(buckets: &[usize], ready: usize) -> BatchPlan {
    let bucket = buckets.iter().copied().filter(|&b| b <= ready).max().unwrap_or(0);
    let could_grow = buckets.iter().any(|&b| b > ready);
    BatchPlan { bucket, could_grow }
}

/// Greedy decomposition of `total` sequences into compiled bucket sizes,
/// each at most `cap` where possible: repeatedly take the largest bucket
/// that fits the remainder (preferring buckets <= `cap`, falling back to
/// any bucket that still fits). E.g. buckets [1, 2, 4], total 7, cap 4
/// -> [4, 2, 1]. Returns None when the GREEDY walk strands a remainder
/// no bucket fits — which can happen even though some non-greedy
/// combination sums to `total` (e.g. buckets [3, 4], total 10 -> greedy
/// 4, 4, stranded 2, though 4+3+3 works). That miss is deliberate: the
/// caller treats None as "run the batch unsplit", a safe fallback, and
/// any bucket set containing 1 (the serving default — bucket 1 is
/// always compiled) never misses.
///
/// Both consumers lean on the "uneven chunks are fine" property: the
/// pool's work-stealing split feeds the chunks to whichever worker is
/// free, and the admission loop runs a length-class's remainder as
/// smaller batches without padding anything.
pub fn decompose(buckets: &[usize], total: usize, cap: usize) -> Option<Vec<usize>> {
    let mut out = Vec::new();
    let mut remaining = total;
    while remaining > 0 {
        let capped = remaining.min(cap);
        let pick = buckets
            .iter()
            .copied()
            .filter(|&b| b > 0 && b <= capped)
            .max()
            .or_else(|| buckets.iter().copied().filter(|&b| b > 0 && b <= remaining).max())?;
        out.push(pick);
        remaining -= pick;
    }
    Some(out)
}

/// Round-robin selector over active sequence slots: returns the next
/// `count` entries starting at the rotation cursor, advancing it.
#[derive(Debug, Default)]
pub struct RoundRobin {
    cursor: usize,
}

impl RoundRobin {
    /// Select `count` items from `items` (must satisfy count <= len).
    pub fn select<T: Copy>(&mut self, items: &[T], count: usize) -> Vec<T> {
        assert!(count <= items.len());
        let n = items.len();
        let start = if n == 0 { 0 } else { self.cursor % n };
        let picked: Vec<T> = (0..count).map(|i| items[(start + i) % n]).collect();
        self.cursor = if n == 0 { 0 } else { (start + count) % n };
        picked
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::check;

    #[test]
    fn picks_largest_fitting_bucket() {
        let buckets = [1, 2, 4, 8];
        assert_eq!(plan(&buckets, 0).bucket, 0);
        assert_eq!(plan(&buckets, 1).bucket, 1);
        assert_eq!(plan(&buckets, 3).bucket, 2);
        assert_eq!(plan(&buckets, 8).bucket, 8);
        assert_eq!(plan(&buckets, 100).bucket, 8);
    }

    #[test]
    fn growth_signal() {
        let buckets = [1, 2, 4];
        assert!(plan(&buckets, 3).could_grow);
        assert!(!plan(&buckets, 4).could_grow);
        assert!(!plan(&buckets, 9).could_grow);
    }

    #[test]
    fn decompose_prefers_capped_buckets_and_covers_remainders() {
        let buckets = [1usize, 2, 4];
        assert_eq!(decompose(&buckets, 8, 4), Some(vec![4, 4]));
        assert_eq!(decompose(&buckets, 7, 4), Some(vec![4, 2, 1]));
        assert_eq!(decompose(&buckets, 8, 2), Some(vec![2, 2, 2, 2]));
        // uneven split: cap 3 admits bucket 2 twice, then the 1-remainder
        assert_eq!(decompose(&buckets, 5, 3), Some(vec![2, 2, 1]));
        // cap smaller than every bucket falls back to what fits at all
        assert_eq!(decompose(&[2, 4], 4, 1), Some(vec![4]));
        // no combination sums to the total -> None (caller runs unsplit)
        assert_eq!(decompose(&[2], 5, 2), None);
        assert_eq!(decompose(&[4, 8], 6, 8), None);
        // documented greedy miss: 4+3+3 would work, but greedy strands a
        // 2-remainder — None means "run unsplit", never a wrong split
        assert_eq!(decompose(&[3, 4], 10, 5), None);
        assert_eq!(decompose(&buckets, 0, 4), Some(vec![]));
    }

    #[test]
    fn property_decompose_sums_to_total() {
        check(
            |r| (1 + r.below(30), 1 + r.below(6)),
            |&(total, cap)| {
                let buckets = [1usize, 2, 4, 8];
                let chunks = decompose(&buckets, total, cap)
                    .ok_or("buckets include 1, must always decompose")?;
                if chunks.iter().sum::<usize>() != total {
                    return Err(format!("chunks {chunks:?} != total {total}"));
                }
                if chunks.iter().any(|c| !buckets.contains(c)) {
                    return Err(format!("non-bucket chunk in {chunks:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn round_robin_is_fair() {
        let mut rr = RoundRobin::default();
        let items = [10, 20, 30];
        // repeatedly take 2 of 3: every item must appear 2 times in 3 rounds
        let mut counts = std::collections::HashMap::new();
        for _ in 0..3 {
            for x in rr.select(&items, 2) {
                *counts.entry(x).or_insert(0) += 1;
            }
        }
        assert!(counts.values().all(|&c| c == 2), "{counts:?}");
    }

    #[test]
    fn property_bucket_never_exceeds_ready() {
        check(
            |r| (r.below(20), r.below(4)),
            |&(ready, _)| {
                let buckets = [1usize, 2, 4, 8];
                let p = plan(&buckets, ready);
                if p.bucket > ready {
                    return Err(format!("bucket {} > ready {ready}", p.bucket));
                }
                if ready >= 1 && p.bucket == 0 {
                    return Err("starved despite ready work".into());
                }
                Ok(())
            },
        );
    }
}
