//! Byte-level tokenizer with fixed-window left padding.
//!
//! The tiny models are byte-level LMs (vocab 256), so tokenization is
//! identity on bytes. The interesting part is XAMBA Step-1 (paper §2):
//! NPUs want static shapes, so prefill always sees exactly `window`
//! tokens — shorter prompts are LEFT-padded (leading pads wash out of the
//! causal SSM state), longer prompts keep their trailing `window` bytes
//! (the recurrent state of older bytes would have been truncated anyway).

/// Padding byte (ASCII space: in-distribution for the text corpus).
pub const PAD_BYTE: u8 = b' ';

/// Byte-level tokenizer bound to a fixed prefill window.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    pub window: usize,
    pub vocab: usize,
}

impl Tokenizer {
    pub fn new(window: usize, vocab: usize) -> Self {
        assert!(vocab >= 256, "byte tokenizer needs vocab >= 256");
        Self { window, vocab }
    }

    /// Encode a prompt into exactly `window` token ids.
    pub fn encode_window(&self, prompt: &[u8]) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.window);
        if prompt.len() >= self.window {
            let tail = &prompt[prompt.len() - self.window..];
            out.extend(tail.iter().map(|&b| b as i32));
        } else {
            out.resize(self.window - prompt.len(), PAD_BYTE as i32);
            out.extend(prompt.iter().map(|&b| b as i32));
        }
        out
    }

    /// Encode a prompt at its REAL length for variable-length prefill:
    /// prompts longer than the window keep their trailing `window` bytes
    /// (exactly like [`Tokenizer::encode_window`]), shorter prompts come
    /// back at their true length — left-padded only up to `min_len` (the
    /// backend's shortest compiled prefill, e.g. the conv-state floor),
    /// so beyond that floor no pad token ever touches SSM state. With
    /// `min_len == window` this degenerates to `encode_window` (the
    /// fixed-window backends).
    pub fn encode_ranged(&self, prompt: &[u8], min_len: usize) -> Vec<i32> {
        let min_len = min_len.max(1).min(self.window);
        if prompt.len() >= self.window {
            return self.encode_window(prompt);
        }
        let mut out = Vec::with_capacity(prompt.len().max(min_len));
        if prompt.len() < min_len {
            out.resize(min_len - prompt.len(), PAD_BYTE as i32);
        }
        out.extend(prompt.iter().map(|&b| b as i32));
        out
    }

    /// Length of the id sequence [`Tokenizer::encode_ranged`] would
    /// produce — the admission scheduler's length-class key. Kept next
    /// to the encoder so the grouping rule and the encoding rule cannot
    /// drift apart (a mismatch would make every batch look ragged).
    pub fn encoded_len(&self, prompt: &[u8], min_len: usize) -> usize {
        prompt.len().clamp(min_len.max(1).min(self.window), self.window)
    }

    /// Decode generated ids back to bytes (ids are bytes for this vocab).
    pub fn decode(&self, ids: &[i32]) -> Vec<u8> {
        ids.iter().map(|&i| i.clamp(0, 255) as u8).collect()
    }

    /// Lossy UTF-8 rendering for logs / demos.
    pub fn render(&self, ids: &[i32]) -> String {
        String::from_utf8_lossy(&self.decode(ids)).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::check;
    use crate::util::Prng;

    #[test]
    fn short_prompt_left_pads() {
        let t = Tokenizer::new(8, 256);
        let ids = t.encode_window(b"hi");
        assert_eq!(ids.len(), 8);
        assert_eq!(&ids[..6], &[32; 6]);
        assert_eq!(&ids[6..], &[104, 105]);
    }

    #[test]
    fn long_prompt_keeps_tail() {
        let t = Tokenizer::new(4, 256);
        let ids = t.encode_window(b"abcdefgh");
        assert_eq!(ids, vec![101, 102, 103, 104]); // "efgh"
    }

    #[test]
    fn ranged_encoding_keeps_true_lengths() {
        let t = Tokenizer::new(8, 256);
        // between the floor and the window: identity, no pads
        assert_eq!(t.encode_ranged(b"hello", 2), vec![104, 101, 108, 108, 111]);
        // below the floor: padded up to the floor only
        assert_eq!(t.encode_ranged(b"h", 3), vec![32, 32, 104]);
        // above the window: trailing-window truncation, like encode_window
        assert_eq!(
            t.encode_ranged(b"abcdefghij", 2),
            t.encode_window(b"abcdefghij")
        );
        // floor == window degenerates to the fixed-window encoding
        assert_eq!(t.encode_ranged(b"hi", 8), t.encode_window(b"hi"));
        // the length-class key always equals the encoded length
        for prompt in [&b""[..], b"h", b"hi", b"hello", b"exactly8", b"well past it"] {
            for min_len in [0usize, 1, 3, 8, 20] {
                assert_eq!(
                    t.encode_ranged(prompt, min_len).len(),
                    t.encoded_len(prompt, min_len),
                    "prompt {prompt:?} min {min_len}"
                );
            }
        }
    }

    #[test]
    fn exact_length_passthrough_round_trip() {
        let t = Tokenizer::new(5, 256);
        let ids = t.encode_window(b"hello");
        assert_eq!(t.decode(&ids), b"hello");
    }

    #[test]
    fn property_window_always_exact_and_tail_preserved() {
        check(
            |r: &mut Prng| {
                let len = r.below(100);
                (0..len).map(|_| r.below(256) as u8).collect::<Vec<u8>>()
            },
            |prompt| {
                let t = Tokenizer::new(16, 256);
                let ids = t.encode_window(prompt);
                if ids.len() != 16 {
                    return Err(format!("window {}", ids.len()));
                }
                let tail_len = prompt.len().min(16);
                let got = t.decode(&ids[16 - tail_len..]);
                if got != prompt[prompt.len() - tail_len..] {
                    return Err("tail not preserved".into());
                }
                Ok(())
            },
        );
    }
}
