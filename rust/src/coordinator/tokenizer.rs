//! Byte-level tokenizer with fixed-window left padding.
//!
//! The tiny models are byte-level LMs (vocab 256), so tokenization is
//! identity on bytes. The interesting part is XAMBA Step-1 (paper §2):
//! NPUs want static shapes, so prefill always sees exactly `window`
//! tokens — shorter prompts are LEFT-padded (leading pads wash out of the
//! causal SSM state), longer prompts keep their trailing `window` bytes
//! (the recurrent state of older bytes would have been truncated anyway).

/// Padding byte (ASCII space: in-distribution for the text corpus).
pub const PAD_BYTE: u8 = b' ';

/// Byte-level tokenizer bound to a fixed prefill window.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    pub window: usize,
    pub vocab: usize,
}

impl Tokenizer {
    pub fn new(window: usize, vocab: usize) -> Self {
        assert!(vocab >= 256, "byte tokenizer needs vocab >= 256");
        Self { window, vocab }
    }

    /// Encode a prompt into exactly `window` token ids.
    pub fn encode_window(&self, prompt: &[u8]) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.window);
        if prompt.len() >= self.window {
            let tail = &prompt[prompt.len() - self.window..];
            out.extend(tail.iter().map(|&b| b as i32));
        } else {
            out.resize(self.window - prompt.len(), PAD_BYTE as i32);
            out.extend(prompt.iter().map(|&b| b as i32));
        }
        out
    }

    /// Decode generated ids back to bytes (ids are bytes for this vocab).
    pub fn decode(&self, ids: &[i32]) -> Vec<u8> {
        ids.iter().map(|&i| i.clamp(0, 255) as u8).collect()
    }

    /// Lossy UTF-8 rendering for logs / demos.
    pub fn render(&self, ids: &[i32]) -> String {
        String::from_utf8_lossy(&self.decode(ids)).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::check;
    use crate::util::Prng;

    #[test]
    fn short_prompt_left_pads() {
        let t = Tokenizer::new(8, 256);
        let ids = t.encode_window(b"hi");
        assert_eq!(ids.len(), 8);
        assert_eq!(&ids[..6], &[32; 6]);
        assert_eq!(&ids[6..], &[104, 105]);
    }

    #[test]
    fn long_prompt_keeps_tail() {
        let t = Tokenizer::new(4, 256);
        let ids = t.encode_window(b"abcdefgh");
        assert_eq!(ids, vec![101, 102, 103, 104]); // "efgh"
    }

    #[test]
    fn exact_length_passthrough_round_trip() {
        let t = Tokenizer::new(5, 256);
        let ids = t.encode_window(b"hello");
        assert_eq!(t.decode(&ids), b"hello");
    }

    #[test]
    fn property_window_always_exact_and_tail_preserved() {
        check(
            |r: &mut Prng| {
                let len = r.below(100);
                (0..len).map(|_| r.below(256) as u8).collect::<Vec<u8>>()
            },
            |prompt| {
                let t = Tokenizer::new(16, 256);
                let ids = t.encode_window(prompt);
                if ids.len() != 16 {
                    return Err(format!("window {}", ids.len()));
                }
                let tail_len = prompt.len().min(16);
                let got = t.decode(&ids[16 - tail_len..]);
                if got != prompt[prompt.len() - tail_len..] {
                    return Err("tail not preserved".into());
                }
                Ok(())
            },
        );
    }
}
