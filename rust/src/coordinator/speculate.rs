//! Speculative decoding support: the draft-token proposer seam and the
//! per-slot state checkpoint ring.
//!
//! SSM state is O(1) per sequence (conv window + recurrent state, a few
//! KB), so speculation is cheap to make exactly reversible: the engine
//! snapshots each speculating sequence's state into `CheckpointRing`
//! before a verify step, and on partial acceptance rolls back and
//! re-advances only the accepted tokens, landing bitwise on the
//! non-speculative state. The default proposer is prompt-lookup
//! (n-gram match over the sequence's own token history — no draft model
//! required); `Proposer` is the seam where a tiny draft model can slot
//! in later.

use super::model::SeqState;
use crate::runtime::HostTensor;

/// Drafts up to `k` next tokens for a sequence given its full token
/// history (prompt + generated so far, in order). Returning fewer than
/// `k` tokens — or none — is always legal; the engine shrinks the
/// verify window to match (an empty draft falls back to plain decode).
pub trait Proposer: Send {
    fn propose(&mut self, history: &[i32], k: usize) -> Vec<i32>;
}

/// Prompt-lookup decoding (n-gram speculation): find the most recent
/// earlier occurrence of the history's trailing n-gram and draft the
/// tokens that followed it. Matches TGI/vLLM's "prompt lookup" scheme.
/// Repetitive or code-like continuations (and greedy decode loops) make
/// this proposer highly accurate for free.
#[derive(Clone, Debug)]
pub struct PromptLookupProposer {
    /// Longest trailing n-gram to try first (descending to `min_ngram`).
    pub max_ngram: usize,
    /// Shortest n-gram worth matching (1 = single-token recurrence).
    pub min_ngram: usize,
}

impl Default for PromptLookupProposer {
    fn default() -> Self {
        Self { max_ngram: 3, min_ngram: 1 }
    }
}

impl Proposer for PromptLookupProposer {
    fn propose(&mut self, history: &[i32], k: usize) -> Vec<i32> {
        if k == 0 {
            return Vec::new();
        }
        let len = history.len();
        let hi = self.max_ngram.max(self.min_ngram).max(1);
        let lo = self.min_ngram.max(1);
        for n in (lo..=hi).rev() {
            if len < n + 1 {
                continue;
            }
            let suffix = &history[len - n..];
            // most recent earlier occurrence wins (local repetition is
            // the strongest signal)
            for i in (0..len - n).rev() {
                if &history[i..i + n] == suffix {
                    let start = i + n;
                    let end = (start + k).min(len);
                    if start < end {
                        return history[start..end].to_vec();
                    }
                }
            }
        }
        Vec::new()
    }
}

/// Per-slot snapshots of sequence state taken immediately before a
/// verify step. Slots are reused across steps: once a slot has been
/// written at a given state shape, later checkpoints copy in place
/// instead of allocating (`allocs()` counts the exceptions, so tests
/// can assert the steady state is allocation-free).
#[derive(Default)]
pub struct CheckpointRing {
    slots: Vec<Option<SeqState>>,
    allocs: usize,
}

fn copy_tensor(dst: &mut HostTensor, src: &HostTensor, allocs: &mut usize) {
    match (dst, src) {
        (HostTensor::F32(ds, dd), HostTensor::F32(ss, sd))
            if ds == ss && dd.len() == sd.len() =>
        {
            dd.copy_from_slice(sd);
        }
        (HostTensor::I32(ds, dd), HostTensor::I32(ss, sd))
            if ds == ss && dd.len() == sd.len() =>
        {
            dd.copy_from_slice(sd);
        }
        (dst, src) => {
            *allocs += 1;
            *dst = src.clone();
        }
    }
}

impl CheckpointRing {
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot `state` into slot `i`, growing the ring on demand.
    pub fn checkpoint(&mut self, i: usize, state: &SeqState) {
        if i >= self.slots.len() {
            self.slots.resize_with(i + 1, || None);
        }
        match &mut self.slots[i] {
            Some(slot) => {
                copy_tensor(&mut slot.conv, &state.conv, &mut self.allocs);
                copy_tensor(&mut slot.ssm, &state.ssm, &mut self.allocs);
            }
            empty => {
                self.allocs += 1;
                *empty = Some(state.clone());
            }
        }
    }

    /// Restore slot `i`'s snapshot into `state`. Panics if the slot was
    /// never checkpointed — the engine only rolls back slots it just
    /// checkpointed in the same step.
    pub fn rollback(&self, i: usize, state: &mut SeqState) {
        let slot = self.slots[i]
            .as_ref()
            .expect("rollback of a slot that was never checkpointed");
        state.conv = slot.conv.clone();
        state.ssm = slot.ssm.clone();
    }

    /// Restore slot `i` in place without allocating when shapes match.
    pub fn rollback_into(&mut self, i: usize, state: &mut SeqState) {
        let slot = self.slots[i]
            .as_ref()
            .expect("rollback of a slot that was never checkpointed");
        let mut allocs = 0;
        copy_tensor(&mut state.conv, &slot.conv, &mut allocs);
        copy_tensor(&mut state.ssm, &slot.ssm, &mut allocs);
        self.allocs += allocs;
    }

    /// Snapshot allocations so far (first-touch per slot plus any
    /// shape-change reallocation; flat after warmup).
    pub fn allocs(&self) -> usize {
        self.allocs
    }

    /// Slots the ring has grown to cover.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist(toks: &[i32]) -> Vec<i32> {
        toks.to_vec()
    }

    #[test]
    fn prompt_lookup_drafts_the_continuation_of_the_latest_match() {
        let mut p = PromptLookupProposer::default();
        // trailing [7, 8] matched earlier; continuation is [9, 4, 7]
        let h = hist(&[1, 7, 8, 9, 4, 7, 8]);
        assert_eq!(p.propose(&h, 3), vec![9, 4, 7]);
        // shorter request truncates the draft
        assert_eq!(p.propose(&h, 1), vec![9]);
    }

    #[test]
    fn prompt_lookup_prefers_longer_ngrams_and_recent_matches() {
        let mut p = PromptLookupProposer::default();
        // trailing 3-gram [2, 3, 4] occurs at 0 (followed by 9) even
        // though the trailing 1-gram [4] also occurs at 5 (followed by 8)
        let h = hist(&[2, 3, 4, 9, 1, 4, 8, 2, 3, 4]);
        assert_eq!(p.propose(&h, 2), vec![9, 1]);
        // with only 1-grams available, the most recent match wins
        let h = hist(&[5, 1, 5, 2, 5]);
        assert_eq!(p.propose(&h, 1), vec![2]);
    }

    #[test]
    fn prompt_lookup_handles_no_match_and_short_history() {
        let mut p = PromptLookupProposer::default();
        assert!(p.propose(&[], 4).is_empty());
        assert!(p.propose(&[3], 4).is_empty());
        assert!(p.propose(&[1, 2, 3, 4], 4).is_empty());
        assert!(p.propose(&[1, 1, 2], 0).is_empty());
        // cycle of period 1: the continuation span reaches the end of
        // history, so the draft is the single repeated token
        assert_eq!(p.propose(&[9, 9, 9], 4), vec![9]);
    }

    #[test]
    fn checkpoint_ring_reuses_slots_without_allocating() {
        let mut ring = CheckpointRing::new();
        let mk = |v: f32| SeqState {
            conv: HostTensor::F32(vec![2, 3], vec![v; 6]),
            ssm: HostTensor::F32(vec![4], vec![v; 4]),
        };
        ring.checkpoint(0, &mk(1.0));
        ring.checkpoint(1, &mk(2.0));
        let first_touch = ring.allocs();
        assert!(first_touch >= 2);
        // steady state: same shapes, no further allocation
        for step in 0..10 {
            ring.checkpoint(0, &mk(step as f32));
            ring.checkpoint(1, &mk(-step as f32));
        }
        assert_eq!(ring.allocs(), first_touch);
        assert_eq!(ring.capacity(), 2);

        // rollback restores the snapshot exactly
        let snap = mk(7.5);
        ring.checkpoint(0, &snap);
        let mut live = mk(0.0);
        ring.rollback_into(0, &mut live);
        assert_eq!(live, snap);
        assert_eq!(ring.allocs(), first_touch, "in-place rollback is free");
        let mut live2 = mk(0.25);
        ring.rollback(0, &mut live2);
        assert_eq!(live2, snap);
    }

    #[test]
    fn checkpoint_ring_reallocates_on_shape_change_only() {
        let mut ring = CheckpointRing::new();
        let small = SeqState {
            conv: HostTensor::F32(vec![2], vec![1.0; 2]),
            ssm: HostTensor::F32(vec![2], vec![1.0; 2]),
        };
        let big = SeqState {
            conv: HostTensor::F32(vec![4], vec![2.0; 4]),
            ssm: HostTensor::F32(vec![4], vec![2.0; 4]),
        };
        ring.checkpoint(0, &small);
        let a0 = ring.allocs();
        ring.checkpoint(0, &big);
        assert!(ring.allocs() > a0, "shape change must reallocate");
        let a1 = ring.allocs();
        ring.checkpoint(0, &big);
        assert_eq!(ring.allocs(), a1);
    }
}
