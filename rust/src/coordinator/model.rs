//! Model abstraction the scheduler drives: a fixed-window prefill plus
//! bucketed batched decode. `PjrtServeModel` is the production binding to
//! the AOT artifacts; `MockModel` makes the scheduler/batcher/state-cache
//! logic unit-testable without PJRT.

use anyhow::{anyhow, Result};

use crate::runtime::{Engine, HostTensor, Manifest, ProgramEntry};

/// Recurrent state of one sequence (the serving layer's "KV cache" —
/// fixed-size per the SSM's O(1)-state property the paper leans on).
#[derive(Clone, Debug)]
pub struct SeqState {
    pub conv: HostTensor,
    pub ssm: HostTensor,
}

/// What the coordinator needs from a model backend. Constructed inside
/// the engine thread (PJRT clients are not `Send`), so no `Send` bound.
pub trait ServeModel {
    /// Static prefill window (token count).
    fn prefill_len(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Compiled decode batch sizes, ascending.
    fn decode_buckets(&self) -> &[usize];
    /// Run the fixed-window prefill; returns last-position logits + state.
    fn prefill(&mut self, tokens: &[i32]) -> Result<(Vec<f32>, SeqState)>;
    /// Advance `seqs.len()` sequences one token (len must be a bucket).
    /// Returns per-sequence logits; states are updated in place.
    fn decode(&mut self, seqs: &mut [(&mut SeqState, i32)]) -> Result<Vec<Vec<f32>>>;
}

// --- PJRT-backed implementation -----------------------------------------------

/// Production backend: executes the AOT HLO artifacts on PJRT-CPU.
pub struct PjrtServeModel {
    engine: Engine,
    manifest: Manifest,
    prefill_entry: ProgramEntry,
    decode_entries: Vec<(usize, ProgramEntry)>, // (batch, entry) ascending
    buckets: Vec<usize>,
    vocab: usize,
}

impl PjrtServeModel {
    /// Load + compile prefill and all decode buckets for (model, variant).
    pub fn load(artifacts_dir: &str, model: &str, variant: &str) -> Result<Self> {
        Self::load_with_buckets(artifacts_dir, model, variant, None)
    }

    /// Like `load`, restricted to a subset of compiled batch buckets
    /// (serving-policy experiments; None = everything in the manifest).
    pub fn load_with_buckets(
        artifacts_dir: &str,
        model: &str,
        variant: &str,
        allowed: Option<&[usize]>,
    ) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
        let mut engine = Engine::cpu()?;
        let prefill_entry = manifest
            .find(model, variant, "prefill")
            .ok_or_else(|| anyhow!("no prefill program for {model}.{variant}"))?
            .clone();
        engine.prepare(&manifest, &prefill_entry)?;
        let mut buckets = manifest.decode_buckets(model, variant);
        if let Some(allow) = allowed {
            buckets.retain(|b| allow.contains(b));
        }
        if buckets.is_empty() {
            return Err(anyhow!("no decode buckets for {model}.{variant}"));
        }
        let mut decode_entries = Vec::new();
        for &b in &buckets {
            let e = manifest
                .find(model, variant, &format!("decode_b{b}"))
                .ok_or_else(|| anyhow!("missing decode_b{b}"))?
                .clone();
            engine.prepare(&manifest, &e)?;
            decode_entries.push((b, e));
        }
        let vocab = prefill_entry.shape.vocab_size;
        Ok(Self { engine, manifest, prefill_entry, decode_entries, buckets, vocab })
    }

    fn stack(tensors: Vec<&HostTensor>) -> HostTensor {
        let one = tensors[0].shape().to_vec();
        let mut shape = vec![tensors.len()];
        shape.extend_from_slice(&one);
        let mut data = Vec::with_capacity(tensors.len() * tensors[0].f32_data().len());
        for t in &tensors {
            debug_assert_eq!(t.shape(), one.as_slice());
            data.extend_from_slice(t.f32_data());
        }
        HostTensor::F32(shape, data)
    }

    fn unstack(t: &HostTensor, b: usize) -> Vec<HostTensor> {
        let inner_shape = t.shape()[1..].to_vec();
        let inner: usize = inner_shape.iter().product();
        (0..b)
            .map(|i| {
                HostTensor::F32(
                    inner_shape.clone(),
                    t.f32_data()[i * inner..(i + 1) * inner].to_vec(),
                )
            })
            .collect()
    }
}

impl ServeModel for PjrtServeModel {
    fn prefill_len(&self) -> usize {
        self.manifest.prefill_len
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn decode_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<(Vec<f32>, SeqState)> {
        let entry = self.prefill_entry.clone();
        let tok = HostTensor::I32(vec![tokens.len()], tokens.to_vec());
        let conv = HostTensor::zeros(&entry.inputs[2].shape);
        let ssm = HostTensor::zeros(&entry.inputs[3].shape);
        let outs = self
            .engine
            .run_with_weights(&self.manifest, &entry, &[tok, conv, ssm])?;
        let logits = outs[0].f32_data().to_vec();
        Ok((logits, SeqState { conv: outs[1].clone(), ssm: outs[2].clone() }))
    }

    fn decode(&mut self, seqs: &mut [(&mut SeqState, i32)]) -> Result<Vec<Vec<f32>>> {
        let b = seqs.len();
        let entry = self
            .decode_entries
            .iter()
            .find(|(bb, _)| *bb == b)
            .ok_or_else(|| anyhow!("no decode bucket of size {b}"))?
            .1
            .clone();
        let tokens = HostTensor::I32(
            vec![b, 1],
            seqs.iter().map(|(_, t)| *t).collect(),
        );
        let conv = Self::stack(seqs.iter().map(|(s, _)| &s.conv).collect());
        let ssm = Self::stack(seqs.iter().map(|(s, _)| &s.ssm).collect());
        let outs = self
            .engine
            .run_with_weights(&self.manifest, &entry, &[tokens, conv, ssm])?;
        let v = self.vocab;
        let logits_all = outs[0].f32_data();
        let convs = Self::unstack(&outs[1], b);
        let ssms = Self::unstack(&outs[2], b);
        let mut result = Vec::with_capacity(b);
        for (i, (state, _)) in seqs.iter_mut().enumerate() {
            state.conv = convs[i].clone();
            state.ssm = ssms[i].clone();
            result.push(logits_all[i * v..(i + 1) * v].to_vec());
        }
        Ok(result)
    }
}

// --- mock backend for scheduler tests --------------------------------------------

/// Deterministic toy model: next token = (last + 1) mod vocab; the state
/// stores the running token so decode continuity is checkable.
pub struct MockModel {
    pub window: usize,
    pub vocab: usize,
    pub buckets: Vec<usize>,
    /// Every decode batch size observed (asserts batching policy).
    pub batch_log: Vec<usize>,
    /// Artificial per-call latency (scheduling tests).
    pub decode_delay: std::time::Duration,
}

impl MockModel {
    pub fn new(window: usize, vocab: usize, buckets: Vec<usize>) -> Self {
        Self {
            window,
            vocab,
            buckets,
            batch_log: Vec::new(),
            decode_delay: std::time::Duration::ZERO,
        }
    }

    fn logits_for(&self, predicted: i32) -> Vec<f32> {
        let mut l = vec![0.0f32; self.vocab];
        l[(predicted.rem_euclid(self.vocab as i32)) as usize] = 10.0;
        l
    }
}

impl ServeModel for MockModel {
    fn prefill_len(&self) -> usize {
        self.window
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn decode_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<(Vec<f32>, SeqState)> {
        let last = *tokens.last().unwrap();
        let state = SeqState {
            conv: HostTensor::F32(vec![1], vec![last as f32]),
            ssm: HostTensor::F32(vec![1], vec![0.0]),
        };
        Ok((self.logits_for(last + 1), state))
    }

    fn decode(&mut self, seqs: &mut [(&mut SeqState, i32)]) -> Result<Vec<Vec<f32>>> {
        self.batch_log.push(seqs.len());
        if !self.buckets.contains(&seqs.len()) {
            return Err(anyhow!("batch {} is not a bucket", seqs.len()));
        }
        if !self.decode_delay.is_zero() {
            std::thread::sleep(self.decode_delay);
        }
        Ok(seqs
            .iter_mut()
            .map(|(state, tok)| {
                state.conv = HostTensor::F32(vec![1], vec![*tok as f32]);
                self.logits_for(*tok + 1)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_model_is_a_counter() {
        let mut m = MockModel::new(4, 256, vec![1, 2]);
        let (logits, mut st) = m.prefill(&[5, 6, 7]).unwrap();
        let argmax = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, 8);
        let mut seqs = vec![(&mut st, 8i32)];
        let l2 = m.decode(&mut seqs).unwrap();
        let argmax2 = l2[0]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax2, 9);
        assert_eq!(m.batch_log, vec![1]);
    }

    #[test]
    fn mock_rejects_non_bucket_batches() {
        let mut m = MockModel::new(4, 16, vec![1, 2]);
        let (_, mut a) = m.prefill(&[1]).unwrap();
        let (_, mut b) = m.prefill(&[2]).unwrap();
        let (_, mut c) = m.prefill(&[3]).unwrap();
        let mut seqs = vec![(&mut a, 1), (&mut b, 2), (&mut c, 3)];
        assert!(m.decode(&mut seqs).is_err());
    }
}
