//! Model abstraction the scheduler drives: a fixed-window prefill plus
//! bucketed batched decode. `PlannedServeModel` is the production binding
//! for the planned executor (IR graphs compiled once into cached
//! `ExecutionPlan`s, no PJRT artifacts needed); `PjrtServeModel` binds to
//! the AOT artifacts; `MockModel` makes the scheduler/batcher/state-cache
//! logic unit-testable without either.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::{presets, ModelShape, ServeConfig};
use crate::exec::{plan_key_dtyped, ExecJob, PlanCache, WorkerPool};
use crate::graph::tensor::DType;
use crate::graph::{Graph, Tensor};
use crate::models::params::{full_spec, load_f32_bin};
use crate::models::ServeFamily;
use crate::passes::{actiba::ActibaPass, quantize, Pass};
use crate::quality::param_inputs;
use crate::runtime::{Engine, HostTensor, Manifest, ProgramEntry};
use crate::util::Prng;

/// Recurrent state of one sequence (the serving layer's "KV cache" —
/// fixed-size per the SSM's O(1)-state property the paper leans on).
#[derive(Clone, Debug, PartialEq)]
pub struct SeqState {
    pub conv: HostTensor,
    pub ssm: HostTensor,
}

/// What the coordinator needs from a model backend. Constructed inside
/// the engine thread (PJRT clients are not `Send`), so no `Send` bound.
pub trait ServeModel {
    /// Static prefill window (token count) — the longest prefill the
    /// backend accepts, and the tokenizer's truncation window.
    fn prefill_len(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Compiled decode batch sizes, ascending.
    fn decode_buckets(&self) -> &[usize];
    /// Inclusive (min, max) prefill lengths the backend accepts.
    /// Backends with a single compiled window report (window, window);
    /// variable-length backends let the scheduler prefill each prompt at
    /// its true length so no pad token ever touches SSM state.
    fn prefill_len_range(&self) -> (usize, usize) {
        (self.prefill_len(), self.prefill_len())
    }
    /// Batched-prefill batch sizes, ascending. `[1]` (the default) means
    /// the scheduler admits one sequence per prefill round.
    fn prefill_buckets(&self) -> &[usize] {
        &[1]
    }
    /// Run the prefill; returns last-position logits + state.
    fn prefill(&mut self, tokens: &[i32]) -> Result<(Vec<f32>, SeqState)>;
    /// Prefill several **equal-length** sequences in one round (the
    /// scheduler groups admissions into length-classes first). Default:
    /// a serial loop of single-sequence prefills — backends with batched
    /// prefill graphs override this with one graph call per bucket.
    fn prefill_batched(&mut self, seqs: &[&[i32]]) -> Result<Vec<(Vec<f32>, SeqState)>> {
        seqs.iter().map(|s| self.prefill(s)).collect()
    }
    /// Advance `seqs.len()` sequences one token (len must be a bucket).
    /// Returns per-sequence logits; states are updated in place.
    fn decode(&mut self, seqs: &mut [(&mut SeqState, i32)]) -> Result<Vec<Vec<f32>>>;
    /// Advance ANY number of sequences one step by scatter/gathering the
    /// batch over the compiled decode buckets: greedily run the largest
    /// bucket that fits the remainder; a final remainder no bucket
    /// matches exactly is padded up to the smallest sufficient bucket
    /// with clones of its first real row. Duplicated rows never change a
    /// per-tensor max-abs, so the padding is scale-neutral even for i8
    /// dynamic activation scales, and per-sequence bucket-independence
    /// (pinned by the planned differential suites) makes the pad rows
    /// numerically invisible to the real ones. Only the real rows'
    /// logits are gathered back. Returns (per-sequence logits, pad slots
    /// executed). Membership churn therefore never needs a plan the
    /// backend didn't already compile.
    fn decode_any(
        &mut self,
        seqs: &mut [(&mut SeqState, i32)],
    ) -> Result<(Vec<Vec<f32>>, usize)> {
        let b = seqs.len();
        if b == 0 {
            return Ok((Vec::new(), 0));
        }
        let buckets = self.decode_buckets().to_vec();
        if buckets.contains(&b) {
            return Ok((self.decode(seqs)?, 0));
        }
        let mut logits = Vec::with_capacity(b);
        let mut padded = 0usize;
        let mut off = 0usize;
        while off < b {
            let remaining = b - off;
            if let Some(c) =
                buckets.iter().copied().filter(|&c| c <= remaining).max()
            {
                let mut part: Vec<(&mut SeqState, i32)> = seqs
                    [off..off + c]
                    .iter_mut()
                    .map(|(s, t)| (&mut **s, *t))
                    .collect();
                logits.extend(self.decode(&mut part)?);
                off += c;
            } else {
                let c = buckets
                    .iter()
                    .copied()
                    .filter(|&c| c >= remaining)
                    .min()
                    .ok_or_else(|| {
                        anyhow!(
                            "no decode bucket covers a remainder of {remaining} \
                             (buckets {buckets:?})"
                        )
                    })?;
                let (pad_state, pad_tok) = {
                    let (s, t) = &seqs[off];
                    ((**s).clone(), *t)
                };
                let mut pad_states: Vec<SeqState> =
                    vec![pad_state; c - remaining];
                let mut part: Vec<(&mut SeqState, i32)> = seqs[off..]
                    .iter_mut()
                    .map(|(s, t)| (&mut **s, *t))
                    .collect();
                part.extend(pad_states.iter_mut().map(|s| (s, pad_tok)));
                let out = self.decode(&mut part)?;
                logits.extend(out.into_iter().take(remaining));
                padded += c - remaining;
                off = b;
            }
        }
        Ok((logits, padded))
    }
    /// Longest speculative-verify window (tokens per call) this backend
    /// can score in one step. 0 = no verify support, and the engine
    /// keeps every sequence on the plain decode path.
    fn verify_window(&self) -> usize {
        0
    }
    /// Score a speculative window for `seqs.len()` sequences (len must
    /// be a decode bucket) in ONE multi-token step. Every row carries
    /// the same number of input tokens `kw` (1..=`verify_window()`): the
    /// sequence's last emitted token followed by kw-1 drafted tokens.
    /// Returns per-sequence logits at ALL kw positions, flattened
    /// row-major (kw * vocab); states advance kw steps in place.
    ///
    /// Bitwise contract: position p's logits and the final states must
    /// be identical to kw sequential [`ServeModel::decode`] calls on the
    /// same tokens — that is what lets greedy speculative output match
    /// non-speculative decode exactly.
    fn verify(&mut self, seqs: &mut [(&mut SeqState, &[i32])]) -> Result<Vec<Vec<f32>>> {
        let _ = seqs;
        Err(anyhow!("this backend does not support speculative verify"))
    }
    /// [`ServeModel::decode_any`]'s remap for verify steps: scatter any
    /// batch size over the compiled decode buckets (greedy largest-fit,
    /// remainder padded up with clones of its first real row — the pad
    /// rows replay the same window, so they are numerically invisible).
    /// Returns (per-sequence logits, pad slots executed). Like decode,
    /// membership churn never needs a plan beyond the (bucket, window)
    /// set already in use.
    fn verify_any(
        &mut self,
        seqs: &mut [(&mut SeqState, &[i32])],
    ) -> Result<(Vec<Vec<f32>>, usize)> {
        let b = seqs.len();
        if b == 0 {
            return Ok((Vec::new(), 0));
        }
        let buckets = self.decode_buckets().to_vec();
        if buckets.contains(&b) {
            return Ok((self.verify(seqs)?, 0));
        }
        let mut logits = Vec::with_capacity(b);
        let mut padded = 0usize;
        let mut off = 0usize;
        while off < b {
            let remaining = b - off;
            if let Some(c) =
                buckets.iter().copied().filter(|&c| c <= remaining).max()
            {
                let mut part: Vec<(&mut SeqState, &[i32])> = seqs
                    [off..off + c]
                    .iter_mut()
                    .map(|(s, t)| (&mut **s, *t))
                    .collect();
                logits.extend(self.verify(&mut part)?);
                off += c;
            } else {
                let c = buckets
                    .iter()
                    .copied()
                    .filter(|&c| c >= remaining)
                    .min()
                    .ok_or_else(|| {
                        anyhow!(
                            "no decode bucket covers a remainder of {remaining} \
                             (buckets {buckets:?})"
                        )
                    })?;
                let (pad_state, pad_toks) = {
                    let (s, t) = &seqs[off];
                    ((**s).clone(), *t)
                };
                let mut pad_states: Vec<SeqState> =
                    vec![pad_state; c - remaining];
                let mut part: Vec<(&mut SeqState, &[i32])> = seqs[off..]
                    .iter_mut()
                    .map(|(s, t)| (&mut **s, *t))
                    .collect();
                part.extend(pad_states.iter_mut().map(|s| (s, pad_toks)));
                let out = self.verify(&mut part)?;
                logits.extend(out.into_iter().take(remaining));
                padded += c - remaining;
                off = b;
            }
        }
        Ok((logits, padded))
    }
    /// Compiled-plan count of this backend (0 when the notion does not
    /// apply). The scheduler exports it as a gauge so tests and benches
    /// can assert that membership churn never triggers a recompile.
    fn plan_compiles(&self) -> usize {
        0
    }
    /// Token grain at which chunked / resumed prefill stays bitwise
    /// identical to a monolithic prefill of the same sequence (mamba-1:
    /// every position; mamba-2: SSD chunk boundaries). 0 = this backend
    /// cannot continue a prefill from a saved state, and the engine
    /// keeps every request on the plain prefill paths.
    fn resume_grain(&self) -> usize {
        0
    }
    /// Longest prompt the engine may hand this backend in one request
    /// (the tokenizer's truncation window). Chunked-prefill backends
    /// accept far more than one compiled window; everyone else is
    /// window-bound.
    fn max_prompt_len(&self) -> usize {
        self.prefill_len()
    }
    /// Prefill `tokens` — the *new suffix only* — continuing from
    /// `resume` (None = from scratch), calling `checkpoint(consumed,
    /// state)` at resume-grain-aligned chunk boundaries so the engine
    /// can retain intermediate snapshots for the prefix cache. Returns
    /// last-position logits + final state, exactly like `prefill`.
    /// Default: no resume support — delegates to plain prefill and
    /// errors if a resume state is supplied.
    fn prefill_resume(
        &mut self,
        tokens: &[i32],
        resume: Option<&SeqState>,
        checkpoint: &mut dyn FnMut(usize, &SeqState),
    ) -> Result<(Vec<f32>, SeqState)> {
        let _ = checkpoint;
        if resume.is_some() {
            return Err(anyhow!("this backend cannot resume from a cached state"));
        }
        self.prefill(tokens)
    }
}

// --- PJRT-backed implementation -----------------------------------------------

/// Production backend: executes the AOT HLO artifacts on PJRT-CPU.
pub struct PjrtServeModel {
    engine: Engine,
    manifest: Manifest,
    prefill_entry: ProgramEntry,
    decode_entries: Vec<(usize, ProgramEntry)>, // (batch, entry) ascending
    buckets: Vec<usize>,
    vocab: usize,
}

impl PjrtServeModel {
    /// Load + compile prefill and all decode buckets for (model, variant).
    pub fn load(artifacts_dir: &str, model: &str, variant: &str) -> Result<Self> {
        Self::load_with_buckets(artifacts_dir, model, variant, None)
    }

    /// Like `load`, restricted to a subset of compiled batch buckets
    /// (serving-policy experiments; None = everything in the manifest).
    pub fn load_with_buckets(
        artifacts_dir: &str,
        model: &str,
        variant: &str,
        allowed: Option<&[usize]>,
    ) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
        let mut engine = Engine::cpu()?;
        let prefill_entry = manifest
            .find(model, variant, "prefill")
            .ok_or_else(|| anyhow!("no prefill program for {model}.{variant}"))?
            .clone();
        engine.prepare(&manifest, &prefill_entry)?;
        let mut buckets = manifest.decode_buckets(model, variant);
        if let Some(allow) = allowed {
            buckets.retain(|b| allow.contains(b));
        }
        if buckets.is_empty() {
            return Err(anyhow!("no decode buckets for {model}.{variant}"));
        }
        let mut decode_entries = Vec::new();
        for &b in &buckets {
            let e = manifest
                .find(model, variant, &format!("decode_b{b}"))
                .ok_or_else(|| anyhow!("missing decode_b{b}"))?
                .clone();
            engine.prepare(&manifest, &e)?;
            decode_entries.push((b, e));
        }
        let vocab = prefill_entry.shape.vocab_size;
        Ok(Self { engine, manifest, prefill_entry, decode_entries, buckets, vocab })
    }

    fn stack(tensors: Vec<&HostTensor>) -> HostTensor {
        let one = tensors[0].shape().to_vec();
        let mut shape = vec![tensors.len()];
        shape.extend_from_slice(&one);
        let mut data = Vec::with_capacity(tensors.len() * tensors[0].f32_data().len());
        for t in &tensors {
            debug_assert_eq!(t.shape(), one.as_slice());
            data.extend_from_slice(t.f32_data());
        }
        HostTensor::F32(shape, data)
    }

    fn unstack(t: &HostTensor, b: usize) -> Vec<HostTensor> {
        let inner_shape = t.shape()[1..].to_vec();
        let inner: usize = inner_shape.iter().product();
        (0..b)
            .map(|i| {
                HostTensor::F32(
                    inner_shape.clone(),
                    t.f32_data()[i * inner..(i + 1) * inner].to_vec(),
                )
            })
            .collect()
    }
}

impl ServeModel for PjrtServeModel {
    fn prefill_len(&self) -> usize {
        self.manifest.prefill_len
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn decode_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<(Vec<f32>, SeqState)> {
        let entry = self.prefill_entry.clone();
        let tok = HostTensor::I32(vec![tokens.len()], tokens.to_vec());
        let conv = HostTensor::zeros(&entry.inputs[2].shape);
        let ssm = HostTensor::zeros(&entry.inputs[3].shape);
        let outs = self
            .engine
            .run_with_weights(&self.manifest, &entry, &[tok, conv, ssm])?;
        let logits = outs[0].f32_data().to_vec();
        Ok((logits, SeqState { conv: outs[1].clone(), ssm: outs[2].clone() }))
    }

    fn decode(&mut self, seqs: &mut [(&mut SeqState, i32)]) -> Result<Vec<Vec<f32>>> {
        let b = seqs.len();
        let entry = self
            .decode_entries
            .iter()
            .find(|(bb, _)| *bb == b)
            .ok_or_else(|| anyhow!("no decode bucket of size {b}"))?
            .1
            .clone();
        let tokens = HostTensor::I32(
            vec![b, 1],
            seqs.iter().map(|(_, t)| *t).collect(),
        );
        let conv = Self::stack(seqs.iter().map(|(s, _)| &s.conv).collect());
        let ssm = Self::stack(seqs.iter().map(|(s, _)| &s.ssm).collect());
        let outs = self
            .engine
            .run_with_weights(&self.manifest, &entry, &[tokens, conv, ssm])?;
        let v = self.vocab;
        let logits_all = outs[0].f32_data();
        let convs = Self::unstack(&outs[1], b);
        let ssms = Self::unstack(&outs[2], b);
        let mut result = Vec::with_capacity(b);
        for (i, (state, _)) in seqs.iter_mut().enumerate() {
            state.conv = convs[i].clone();
            state.ssm = ssms[i].clone();
            result.push(logits_all[i * v..(i + 1) * v].to_vec());
        }
        Ok(result)
    }
}

// --- planned-executor implementation ------------------------------------------

/// Production backend for environments without PJRT artifacts: serves
/// directly off IR graphs through the planned executor.
///
/// Model-generic: the architecture string of the configured `ModelShape`
/// resolves to a [`ServeFamily`] (mamba-1 or mamba-2), which supplies the
/// serve-prefill / batched-decode graph builders and the per-layer state
/// layout — nothing below here hardcodes a family. At construction it
/// builds the serve-prefill graph plus one batched decode graph per
/// bucket and compiles each into a cached
/// [`ExecutionPlan`](crate::exec::ExecutionPlan) — compile once at server
/// start, reuse across all requests. Recurrent state travels as plain
/// host tensors (`SeqState`), stacked `(n_layers, ...)` per sequence;
/// per-layer shapes come from the family (`(K-1, C)` conv + `(d_inner,
/// N)` scan state for mamba-1, `(K-1, d_inner+2N)` conv + `(H, P, N)`
/// SSD state for mamba-2).
///
/// With `workers > 1` a [`WorkerPool`] splits decode buckets into
/// compiled chunk sizes (`steal_chunk`, auto by default; uneven chunks
/// are fine) on a work-stealing queue; every worker owns its own plans
/// and arenas (no shared mutable state), and submission-order
/// reassembly keeps pooled results bitwise-identical to the serial
/// path at any worker count.
///
/// Prefill admits in batches too: `prefill_buckets` selects the batched
/// prefill graphs, compiled lazily per (bucket, length-class) into the
/// same cache; per-sequence prefill results are bitwise identical to
/// the single-sequence graph, and variable-length prompts run at their
/// true length (no pad token ever touches SSM state).
pub struct PlannedServeModel {
    shape: ModelShape,
    family: ServeFamily,
    /// Graph rewrite selector ("baseline" | "xamba"), kept for the
    /// lazily-compiled prefill length-class / bucket graphs.
    variant: String,
    /// Serving dtype (f32 | f16 | i8): selects the quantization pass
    /// applied after the variant rewrite and the `.f16`/`.i8` plan-key
    /// suffix. The external ABI is dtype-oblivious — tokens stay i32,
    /// states stay f32 host tensors.
    dtype: DType,
    /// Per-parameter serving dtypes (planned once from the serve-prefill
    /// graph; every lazily-built graph reuses the same assignment, so
    /// the `Arc`-shared converted parameters fit all of them).
    weight_dtypes: Vec<DType>,
    /// Per-layer, per-sequence state shapes (family-dependent).
    conv_shape: Vec<usize>,
    ssm_shape: Vec<usize>,
    window: usize,
    /// Shortest accepted prefill (the conv state must fit the window).
    min_prefill: usize,
    buckets: Vec<usize>, // ascending, deduped
    /// Batched-prefill batch sizes, ascending, always containing 1;
    /// their graphs compile lazily, one per (bucket, length) on first
    /// use, into the same `cache` as everything else.
    prefill_buckets: Vec<usize>,
    /// Work-stealing decode chunk size; 0 = auto (largest compiled
    /// bucket <= ceil(bucket / workers)).
    steal_chunk: usize,
    /// Streaming-prefill chunk size (tokens), grain-aligned; 0 = off
    /// (long prompts truncate to the window as before). When set, the
    /// engine may hand prompts far longer than one window and they run
    /// as a sequence of chunk graphs with bounded arena memory.
    prefill_chunk: usize,
    vocab: usize,
    params: Arc<Vec<Tensor>>,
    cache: PlanCache,
    prefill_key: Arc<str>,
    decode_graphs: Vec<DecodeEntry>,
    pool: Option<WorkerPool>,
}

/// Apply the serving variant's graph rewrite: `"baseline"` executes
/// exact activations, `"xamba"` applies the ActiBA PLU rewrite.
fn rewrite_graph(variant: &str, g: Graph) -> Result<Graph, String> {
    match variant {
        "" | "baseline" => Ok(g),
        "xamba" => Ok(ActibaPass::default().apply(&g)),
        other => Err(format!("unknown variant {other:?} (want baseline|xamba)")),
    }
}

/// The full serving pipeline for one graph: variant rewrite first, then
/// the quantization pass (so CumBA/ReduBA/ActiBA rewrites are retyped,
/// never undone). `weight_dtypes` must be the model-wide plan — every
/// graph of the model shares one converted parameter set.
fn build_serve_graph(
    variant: &str,
    dtype: DType,
    weight_dtypes: &[DType],
    g: Graph,
) -> Result<Graph, String> {
    let g = rewrite_graph(variant, g)?;
    quantize::quantize_graph(&g, dtype, weight_dtypes)
}

/// One compiled decode bucket: size, plan-cache key (precomputed — the
/// decode hot path clones refcounts, not strings), and the IR graph the
/// pool workers compile from.
struct DecodeEntry {
    bucket: usize,
    key: Arc<str>,
    graph: Arc<Graph>,
}

impl PlannedServeModel {
    /// Compile prefill + every decode bucket for `shape` over `weights`
    /// (flat `full_spec` order). `variant` mirrors the AOT pipeline:
    /// `"baseline"` executes exact activations, `"xamba"` applies the
    /// ActiBA PLU rewrite to every graph before compilation.
    pub fn new(
        shape: &ModelShape,
        weights: &[f32],
        window: usize,
        buckets: &[usize],
        workers: usize,
        variant: &str,
    ) -> Result<Self> {
        Self::new_dtyped(shape, weights, window, buckets, workers, variant, DType::F32)
    }

    /// [`PlannedServeModel::new`] at an explicit serving dtype. f16/i8
    /// graphs come out of `passes::quantize` (applied after the variant
    /// rewrite), parameters are converted once per model to the planned
    /// per-weight dtypes and `Arc`-shared as usual, and every plan-cache
    /// key carries the dtype suffix (`mamba2.decode_b4.i8`) so one cache
    /// can hold several precisions of the same program.
    #[allow(clippy::too_many_arguments)]
    pub fn new_dtyped(
        shape: &ModelShape,
        weights: &[f32],
        window: usize,
        buckets: &[usize],
        workers: usize,
        variant: &str,
        dtype: DType,
    ) -> Result<Self> {
        let family = ServeFamily::from_arch(&shape.arch).map_err(|e| anyhow!(e))?;
        let spec = full_spec(shape);
        if spec.total() != weights.len() {
            return Err(anyhow!(
                "weights length {} does not match spec total {} for {}",
                weights.len(),
                spec.total(),
                shape.name
            ));
        }
        if window < shape.d_conv.saturating_sub(1).max(1) {
            return Err(anyhow!(
                "prefill window {window} shorter than conv state {}",
                shape.d_conv.saturating_sub(1)
            ));
        }
        let mut buckets = buckets.to_vec();
        buckets.sort_unstable();
        buckets.dedup();
        if buckets.is_empty() || buckets[0] == 0 {
            return Err(anyhow!("decode buckets must be non-empty and positive"));
        }

        // plan per-weight dtypes ONCE, from the (variant-rewritten)
        // serve-prefill graph; the decision is structural, so decode and
        // batched-prefill graphs reach the same assignment — and if one
        // ever disagreed, quantize_graph inserts an explicit widen
        // instead of corrupting the shared parameters
        let base_prefill = rewrite_graph(variant, family.build_prefill_serve(shape, window))
            .map_err(|e| anyhow!(e))?;
        let weight_dtypes =
            quantize::plan_weight_dtypes(&base_prefill, spec.entries.len(), dtype);
        let build = |g: Graph| -> Result<Graph> {
            build_serve_graph(variant, dtype, &weight_dtypes, g).map_err(|e| anyhow!(e))
        };

        let params: Vec<Tensor> = param_inputs(&spec, weights)
            .into_iter()
            .zip(&weight_dtypes)
            .map(|(t, &d)| if t.dtype() == d { t } else { t.to_dtype(d) })
            .collect();
        let params = Arc::new(params);
        let mut cache = PlanCache::new();
        let prefill_key = plan_key_dtyped(family.arch(), "prefill", dtype);
        let prefill = quantize::quantize_graph(&base_prefill, dtype, &weight_dtypes)
            .map_err(|e| anyhow!(e))?;
        cache.insert_with(&prefill_key, &prefill, &params).map_err(|e| anyhow!(e))?;
        let mut decode_graphs = Vec::with_capacity(buckets.len());
        for &b in &buckets {
            let g = Arc::new(build(family.build_decode_batched(shape, b))?);
            let key = plan_key_dtyped(family.arch(), &format!("decode_b{b}"), dtype);
            cache.insert_with(&key, &g, &params).map_err(|e| anyhow!(e))?;
            decode_graphs.push(DecodeEntry { bucket: b, key, graph: g });
        }

        let model = Self {
            shape: shape.clone(),
            family,
            variant: variant.to_string(),
            dtype,
            weight_dtypes,
            conv_shape: family.conv_state_shape(shape),
            ssm_shape: family.ssm_state_shape(shape),
            window,
            min_prefill: shape.d_conv.saturating_sub(1).max(1),
            buckets,
            prefill_buckets: vec![1],
            steal_chunk: 0,
            prefill_chunk: 0,
            vocab: shape.vocab_size,
            params,
            cache,
            prefill_key,
            decode_graphs,
            pool: if workers > 1 { Some(WorkerPool::new(workers)) } else { None },
        };
        model.warm_pool()?;
        Ok(model)
    }

    /// Enable batched admission prefill for these bucket sizes. Bucket 1
    /// is always kept; graphs compile lazily on the first use of a
    /// (bucket, length-class) pair, so unused buckets cost nothing.
    pub fn with_prefill_buckets(mut self, buckets: &[usize]) -> Result<Self> {
        if buckets.contains(&0) {
            return Err(anyhow!("prefill buckets must be positive batch sizes"));
        }
        let mut pb = buckets.to_vec();
        pb.push(1);
        pb.sort_unstable();
        pb.dedup();
        self.prefill_buckets = pb;
        Ok(self)
    }

    /// Set the work-stealing decode chunk size (0 = auto: the largest
    /// compiled bucket that fits ceil(bucket / workers)). Warms any
    /// chunk sizes the new decomposition introduces so no live request
    /// pays a chunk-plan compile; chunk sets the construction-time warm
    /// already covered are not re-executed.
    pub fn with_steal_chunk(mut self, chunk: usize) -> Result<Self> {
        if chunk == self.steal_chunk {
            return Ok(self);
        }
        let before = self.warm_chunk_set();
        self.steal_chunk = chunk;
        let fresh: Vec<usize> = self
            .warm_chunk_set()
            .into_iter()
            .filter(|c| !before.contains(c))
            .collect();
        self.warm_pool_chunks(&fresh)?;
        Ok(self)
    }

    /// Enable chunked streaming prefill: prompts longer than one window
    /// run as a sequence of `chunk`-token resume graphs carrying the
    /// per-layer state across boundaries, so arena memory is bounded by
    /// the chunk graph rather than the prompt. The chunk is clamped to
    /// the window and rounded down to a multiple of the family's resume
    /// grain (mamba-2 prefill is only bitwise-stable at SSD chunk
    /// boundaries). 0 disables; i8 serving silently disables too — its
    /// dynamic per-tensor activation scales would make chunk boundaries
    /// numerically observable.
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Result<Self> {
        if chunk == 0 || self.dtype == DType::I8 {
            self.prefill_chunk = 0;
            return Ok(self);
        }
        let grain = self.family.resume_chunk_grain(&self.shape);
        let rounded = (chunk.min(self.window) / grain) * grain;
        if rounded < grain.max(self.min_prefill) {
            return Err(anyhow!(
                "prefill chunk {chunk} too small: need at least {} \
                 (resume grain {grain}, min prefill {})",
                grain.max(self.min_prefill),
                self.min_prefill
            ));
        }
        self.prefill_chunk = rounded;
        Ok(self)
    }

    /// Build from serving config: weights come from `weights_path`, else
    /// the trained artifacts file if present, else a deterministic random
    /// init (keeps `xamba serve` runnable with no `artifacts/` at all —
    /// useful output still requires trained weights).
    pub fn from_config(cfg: &ServeConfig) -> Result<Self> {
        let shape = presets::model_by_name(&cfg.model)
            .ok_or_else(|| anyhow!("unknown model {:?}", cfg.model))?;
        let weights = if !cfg.weights_path.is_empty() {
            load_f32_bin(&cfg.weights_path).map_err(|e| anyhow!(e))?
        } else {
            let trained = format!("{}/weights_{}.bin", cfg.artifacts_dir, cfg.model);
            if std::path::Path::new(&trained).exists() {
                load_f32_bin(&trained).map_err(|e| anyhow!(e))?
            } else {
                Self::random_weights(&shape, 42)
            }
        };
        let workers = if cfg.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4)
        } else {
            cfg.workers
        };
        let dtype = DType::parse_serve(&cfg.dtype).ok_or_else(|| {
            anyhow!("unknown serve dtype {:?} (supported: f32, f16, i8)", cfg.dtype)
        })?;
        Self::new_dtyped(
            &shape,
            &weights,
            cfg.prefill_window,
            &cfg.decode_buckets,
            workers,
            &cfg.variant,
            dtype,
        )?
        .with_prefill_buckets(&cfg.prefill_buckets)?
        .with_steal_chunk(cfg.steal_chunk)?
        .with_prefill_chunk(cfg.prefill_chunk)
    }

    /// Deterministic random weights in `full_spec` order — small and
    /// symmetric so the untrained tiny nets stay numerically tame.
    pub fn random_weights(shape: &ModelShape, seed: u64) -> Vec<f32> {
        let spec = full_spec(shape);
        let mut rng = Prng::new(seed);
        rng.range_vec(spec.total(), -0.08, 0.08)
    }

    /// How many plan compilations construction performed (stays flat
    /// under traffic: one per (program, bucket)).
    pub fn plan_compiles(&self) -> usize {
        self.cache.compile_count()
    }

    /// Worker threads backing pooled decode (1 = serial).
    pub fn pool_workers(&self) -> usize {
        self.pool.as_ref().map(|p| p.workers()).unwrap_or(1)
    }

    /// The model family this backend serves (selected by `shape.arch`).
    pub fn family(&self) -> ServeFamily {
        self.family
    }

    /// The serving dtype every graph of this model executes at.
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// How many parameters were converted to reduced precision by the
    /// quantization plan (0 at f32).
    pub fn quantized_weight_count(&self) -> usize {
        self.weight_dtypes
            .iter()
            .filter(|d| matches!(d, DType::F16 | DType::I8))
            .count()
    }

    /// Arena footprint (bytes) of the compiled plan for `program`
    /// (dtype-qualified key: `"prefill"`, `"prefill_resume_t64"`, ...),
    /// if that plan has been compiled. Tests and benches pin the
    /// chunked-prefill memory bound with this: a resume-chunk plan's
    /// arena must scale with the chunk, never with the whole prompt.
    pub fn plan_arena_bytes(&self, program: &str) -> Option<usize> {
        let key = plan_key_dtyped(self.family.arch(), program, self.dtype);
        self.cache.plan(&key).map(|p| p.arena_bytes())
    }

    /// Flat length of one layer's per-sequence conv / ssm state.
    fn conv_len(&self) -> usize {
        self.conv_shape.iter().product()
    }

    fn ssm_len(&self) -> usize {
        self.ssm_shape.iter().product()
    }

    /// `[b] ++ per-layer shape` — the stacked decode-input layout.
    fn batched(b: usize, per_seq: &[usize]) -> Vec<usize> {
        let mut s = Vec::with_capacity(1 + per_seq.len());
        s.push(b);
        s.extend_from_slice(per_seq);
        s
    }

    /// Every chunk size the pool can currently dispatch (`pool_chunks`
    /// over the configured buckets), sorted and deduped — the set the
    /// warmup must cover.
    fn warm_chunk_set(&self) -> Vec<usize> {
        let mut chunks: Vec<usize> = self
            .buckets
            .iter()
            .filter_map(|&b| self.pool_chunks(b))
            .flatten()
            .collect();
        chunks.sort_unstable();
        chunks.dedup();
        chunks
    }

    /// First decode of a chunk size on a worker compiles that worker's
    /// private plan; run a zero-state batch per (worker, chunk) up front
    /// so no live request pays the compile. Only chunk sizes the pool
    /// can actually dispatch are warmed — and since the stealing queue
    /// lets ANY worker run ANY chunk, the warm jobs go through
    /// `execute_per_worker` so every worker compiles every chunk plan.
    fn warm_pool(&self) -> Result<()> {
        self.warm_pool_chunks(&self.warm_chunk_set())
    }

    /// Warm exactly `chunks` on every worker (each must be a compiled
    /// bucket size).
    fn warm_pool_chunks(&self, chunks: &[usize]) -> Result<()> {
        if let Some(pool) = &self.pool {
            for &b in chunks {
                let entry = self
                    .decode_graphs
                    .iter()
                    .find(|e| e.bucket == b)
                    .expect("pool chunk is a compiled bucket");
                let jobs: Vec<ExecJob> = (0..pool.workers())
                    .map(|_| {
                        let mut tail = Vec::with_capacity(1 + 2 * self.shape.n_layers);
                        tail.push(Tensor::i32(vec![b], vec![0; b]));
                        for _ in 0..self.shape.n_layers {
                            tail.push(Tensor::zeros(Self::batched(b, &self.conv_shape)));
                            tail.push(Tensor::zeros(Self::batched(b, &self.ssm_shape)));
                        }
                        ExecJob {
                            graph: entry.graph.clone(),
                            key: entry.key.clone(),
                            shared: self.params.clone(),
                            tail,
                        }
                    })
                    .collect();
                for r in pool.execute_per_worker(jobs) {
                    r.map_err(|e| anyhow!("pool warmup (chunk {b}): {e}"))?;
                }
            }
        }
        Ok(())
    }

    /// Per-call decode inputs after the bound parameter prefix: tokens,
    /// then per layer the batch-stacked conv and ssm states.
    fn decode_tail(&self, seqs: &[(&mut SeqState, i32)]) -> Vec<Tensor> {
        let b = seqs.len();
        let conv_len = self.conv_len();
        let ssm_len = self.ssm_len();
        let mut tail = Vec::with_capacity(1 + 2 * self.shape.n_layers);
        tail.push(Tensor::i32(vec![b], seqs.iter().map(|(_, t)| *t).collect()));
        for j in 0..self.shape.n_layers {
            let mut conv = Vec::with_capacity(b * conv_len);
            let mut ssm = Vec::with_capacity(b * ssm_len);
            for (s, _) in seqs {
                conv.extend_from_slice(
                    &s.conv.f32_data()[j * conv_len..(j + 1) * conv_len],
                );
                ssm.extend_from_slice(&s.ssm.f32_data()[j * ssm_len..(j + 1) * ssm_len]);
            }
            tail.push(Tensor::f32(Self::batched(b, &self.conv_shape), conv));
            tail.push(Tensor::f32(Self::batched(b, &self.ssm_shape), ssm));
        }
        tail
    }

    /// Unpack one decode call's outputs into the sequences' states and
    /// append each sequence's logits row to `logits`.
    fn apply_outputs(
        &self,
        seqs: &mut [(&mut SeqState, i32)],
        outs: &[Tensor],
        logits: &mut Vec<Vec<f32>>,
    ) {
        let conv_len = self.conv_len();
        let ssm_len = self.ssm_len();
        let nl = self.shape.n_layers;
        let v = self.vocab;
        let logits_all = outs[0].as_f32();
        for (i, (state, _)) in seqs.iter_mut().enumerate() {
            let mut conv = Vec::with_capacity(nl * conv_len);
            let mut ssm = Vec::with_capacity(nl * ssm_len);
            for j in 0..nl {
                conv.extend_from_slice(
                    &outs[1 + 2 * j].as_f32()[i * conv_len..(i + 1) * conv_len],
                );
                ssm.extend_from_slice(
                    &outs[2 + 2 * j].as_f32()[i * ssm_len..(i + 1) * ssm_len],
                );
            }
            state.conv = HostTensor::F32(Self::batched(nl, &self.conv_shape), conv);
            state.ssm = HostTensor::F32(Self::batched(nl, &self.ssm_shape), ssm);
            logits.push(logits_all[i * v..(i + 1) * v].to_vec());
        }
    }

    /// Per-call verify inputs after the bound parameter prefix: tokens
    /// (b, kw), then per layer the batch-stacked conv and ssm states —
    /// the same state layout as [`PlannedServeModel::decode_tail`].
    fn verify_tail(&self, seqs: &[(&mut SeqState, &[i32])], kw: usize) -> Vec<Tensor> {
        let b = seqs.len();
        let conv_len = self.conv_len();
        let ssm_len = self.ssm_len();
        let mut tail = Vec::with_capacity(1 + 2 * self.shape.n_layers);
        let mut toks = Vec::with_capacity(b * kw);
        for (_, t) in seqs {
            toks.extend_from_slice(t);
        }
        tail.push(Tensor::i32(vec![b, kw], toks));
        for j in 0..self.shape.n_layers {
            let mut conv = Vec::with_capacity(b * conv_len);
            let mut ssm = Vec::with_capacity(b * ssm_len);
            for (s, _) in seqs {
                conv.extend_from_slice(
                    &s.conv.f32_data()[j * conv_len..(j + 1) * conv_len],
                );
                ssm.extend_from_slice(&s.ssm.f32_data()[j * ssm_len..(j + 1) * ssm_len]);
            }
            tail.push(Tensor::f32(Self::batched(b, &self.conv_shape), conv));
            tail.push(Tensor::f32(Self::batched(b, &self.ssm_shape), ssm));
        }
        tail
    }

    /// Unpack one verify call's outputs: states land exactly like
    /// [`PlannedServeModel::apply_outputs`] (the graphs share the state
    /// layout); the logits row per sequence is `kw * vocab` long.
    fn apply_verify_outputs(
        &self,
        seqs: &mut [(&mut SeqState, &[i32])],
        outs: &[Tensor],
        row: usize,
        logits: &mut Vec<Vec<f32>>,
    ) {
        let conv_len = self.conv_len();
        let ssm_len = self.ssm_len();
        let nl = self.shape.n_layers;
        let logits_all = outs[0].as_f32();
        for (i, (state, _)) in seqs.iter_mut().enumerate() {
            let mut conv = Vec::with_capacity(nl * conv_len);
            let mut ssm = Vec::with_capacity(nl * ssm_len);
            for j in 0..nl {
                conv.extend_from_slice(
                    &outs[1 + 2 * j].as_f32()[i * conv_len..(i + 1) * conv_len],
                );
                ssm.extend_from_slice(
                    &outs[2 + 2 * j].as_f32()[i * ssm_len..(i + 1) * ssm_len],
                );
            }
            state.conv = HostTensor::F32(Self::batched(nl, &self.conv_shape), conv);
            state.ssm = HostTensor::F32(Self::batched(nl, &self.ssm_shape), ssm);
            logits.push(logits_all[i * row..(i + 1) * row].to_vec());
        }
    }

    /// Decompose bucket `b` into compiled chunk sizes for the pool's
    /// work-stealing queue — uneven chunks are fine (the queue feeds
    /// whichever worker is free, and submission-order reassembly keeps
    /// pooled output bitwise-identical to serial). The target chunk size
    /// is `steal_chunk`, or ceil(b / workers) when 0 (auto). None = run
    /// serially (no pool, or no multi-chunk decomposition exists).
    ///
    /// i8 buckets never split: dynamic per-tensor activation scales
    /// couple the batch rows (a bucket-4 graph quantizes one stacked
    /// activation tensor), so chunked execution would legitimately
    /// differ from the whole-bucket plan. Running the compiled bucket
    /// graph unsplit keeps i8 decode deterministic and identical at
    /// every worker count. f16 rounding is elementwise, so f16 keeps
    /// the full work-stealing split.
    fn pool_chunks(&self, b: usize) -> Option<Vec<usize>> {
        let w = self.pool.as_ref()?.workers();
        if w <= 1 || b < 2 || self.dtype == DType::I8 {
            return None;
        }
        let cap = if self.steal_chunk > 0 { self.steal_chunk } else { b.div_ceil(w) };
        let chunks = super::batcher::decompose(&self.buckets, b, cap)?;
        if chunks.len() < 2 {
            return None;
        }
        Some(chunks)
    }

    /// One resume-graph call: prefill `tokens` continuing from `prev`.
    /// Plans compile lazily per length (`prefill_resume_t{t}`); in
    /// steady chunked streaming every middle chunk shares one length,
    /// so the compile count stays bounded like the length-class path.
    fn run_resume_chunk(
        &mut self,
        tokens: &[i32],
        prev: &SeqState,
    ) -> Result<(Vec<f32>, SeqState)> {
        let t = tokens.len();
        let nl = self.shape.n_layers;
        let (conv_len, ssm_len) = (self.conv_len(), self.ssm_len());
        let mut tail = Vec::with_capacity(1 + 2 * nl);
        tail.push(Tensor::i32(vec![t], tokens.to_vec()));
        for j in 0..nl {
            tail.push(Tensor::f32(
                self.conv_shape.clone(),
                prev.conv.f32_data()[j * conv_len..(j + 1) * conv_len].to_vec(),
            ));
            tail.push(Tensor::f32(
                self.ssm_shape.clone(),
                prev.ssm.f32_data()[j * ssm_len..(j + 1) * ssm_len].to_vec(),
            ));
        }
        let key = plan_key_dtyped(
            self.family.arch(),
            &format!("prefill_resume_t{t}"),
            self.dtype,
        );
        let outs = {
            let Self { cache, family, shape, variant, params, dtype, weight_dtypes, .. } =
                self;
            let family = *family;
            let dtype = *dtype;
            cache
                .run_or_compile_with(
                    &key,
                    || {
                        build_serve_graph(
                            variant,
                            dtype,
                            weight_dtypes,
                            family.build_prefill_resume(shape, t),
                        )
                    },
                    params,
                    tail,
                )
                .map_err(|e| anyhow!(e))?
        };
        let logits = outs[0].as_f32().to_vec(); // (1, V) row
        let mut conv = Vec::with_capacity(nl * conv_len);
        let mut ssm = Vec::with_capacity(nl * ssm_len);
        for j in 0..nl {
            conv.extend_from_slice(outs[1 + 2 * j].as_f32());
            ssm.extend_from_slice(outs[2 + 2 * j].as_f32());
        }
        Ok((
            logits,
            SeqState {
                conv: HostTensor::F32(Self::batched(nl, &self.conv_shape), conv),
                ssm: HostTensor::F32(Self::batched(nl, &self.ssm_shape), ssm),
            },
        ))
    }
}

impl ServeModel for PlannedServeModel {
    fn prefill_len(&self) -> usize {
        self.window
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn decode_buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Variable-length prefill: the full window runs the eagerly
    /// compiled plan; shorter lengths (length-classes) compile lazily,
    /// once each, so no prompt is ever padded to the window.
    fn prefill_len_range(&self) -> (usize, usize) {
        (self.min_prefill, self.window)
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.prefill_buckets
    }

    /// Main-thread plan-cache compile count (workers warm their own
    /// caches at construction and the batch remap only runs compiled
    /// buckets, so a flat gauge means churn never recompiled anything).
    fn plan_compiles(&self) -> usize {
        self.cache.compile_count()
    }

    /// mamba-1 carries the conv tail across any boundary (grain 1);
    /// mamba-2 is bitwise-stable only at SSD chunk boundaries. i8
    /// reports 0: its dynamic per-tensor activation scales depend on
    /// chunk extents, so resumed prefill could not stay decode-exact.
    fn resume_grain(&self) -> usize {
        if self.dtype == DType::I8 {
            0
        } else {
            self.family.resume_chunk_grain(&self.shape)
        }
    }

    /// With chunked streaming on, the engine may hand whole long
    /// prompts (bounded generously, not by the compiled window).
    fn max_prompt_len(&self) -> usize {
        if self.prefill_chunk > 0 {
            1 << 20
        } else {
            self.window
        }
    }

    /// Chunked / resumed prefill. The first chunk of a from-scratch
    /// prompt runs the plain prefill graph (keeping its zero-history
    /// step bitwise identical to monolithic prefill); every later chunk
    /// runs the family's resume graph seeded with the previous chunk's
    /// state. Intermediate states are offered to `checkpoint` at chunk
    /// boundaries (always multiples of the resume grain); the final
    /// state is returned, not checkpointed — the caller keys it.
    fn prefill_resume(
        &mut self,
        tokens: &[i32],
        resume: Option<&SeqState>,
        checkpoint: &mut dyn FnMut(usize, &SeqState),
    ) -> Result<(Vec<f32>, SeqState)> {
        if self.resume_grain() == 0 {
            if resume.is_some() {
                return Err(anyhow!("resume is unsupported at this serving dtype"));
            }
            return self.prefill(tokens);
        }
        if tokens.is_empty() {
            return Err(anyhow!("prefill_resume needs at least one new token"));
        }
        let chunk =
            if self.prefill_chunk > 0 { self.prefill_chunk } else { self.window };
        let mut state: Option<SeqState> = resume.cloned();
        let mut consumed = 0usize;
        let mut logits: Vec<f32> = Vec::new();
        while consumed < tokens.len() {
            let t = chunk.min(tokens.len() - consumed);
            let seg = &tokens[consumed..consumed + t];
            let (l, s) = match &state {
                None => self.prefill(seg)?,
                Some(prev) => self.run_resume_chunk(seg, prev)?,
            };
            consumed += t;
            logits = l;
            state = Some(s);
            if consumed < tokens.len() {
                checkpoint(consumed, state.as_ref().expect("state set above"));
            }
        }
        Ok((logits, state.expect("at least one chunk ran")))
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<(Vec<f32>, SeqState)> {
        let t = tokens.len();
        if t < self.min_prefill || t > self.window {
            return Err(anyhow!(
                "prefill length {t} outside the supported range {}..={}",
                self.min_prefill,
                self.window
            ));
        }
        let tail = vec![Tensor::i32(vec![t], tokens.to_vec())];
        let outs = if t == self.window {
            let key = self.prefill_key.clone();
            self.cache.run(&key, tail)
        } else {
            let key =
                plan_key_dtyped(self.family.arch(), &format!("prefill_t{t}"), self.dtype);
            let Self { cache, family, shape, variant, params, dtype, weight_dtypes, .. } =
                self;
            let family = *family;
            let dtype = *dtype;
            cache.run_or_compile_with(
                &key,
                || {
                    build_serve_graph(
                        variant,
                        dtype,
                        weight_dtypes,
                        family.build_prefill_serve(shape, t),
                    )
                },
                params,
                tail,
            )
        }
        .map_err(|e| anyhow!(e))?;
        let logits = outs[0].as_f32().to_vec(); // (1, V) row
        let nl = self.shape.n_layers;
        let mut conv = Vec::with_capacity(nl * self.conv_len());
        let mut ssm = Vec::with_capacity(nl * self.ssm_len());
        for j in 0..nl {
            conv.extend_from_slice(outs[1 + 2 * j].as_f32());
            ssm.extend_from_slice(outs[2 + 2 * j].as_f32());
        }
        Ok((
            logits,
            SeqState {
                conv: HostTensor::F32(Self::batched(nl, &self.conv_shape), conv),
                ssm: HostTensor::F32(Self::batched(nl, &self.ssm_shape), ssm),
            },
        ))
    }

    /// One batched-prefill graph call per (bucket, length-class). For
    /// f32/f16 the graph batches along a true batch dimension — one
    /// (b, t)-shaped node per op, so the planned step count stays flat
    /// in `b` — while i8 falls back to the per-sequence replicated graph
    /// (its dynamic per-tensor requantize scales would couple co-batched
    /// sequences inside one node). Either way every returned (logits,
    /// state) pair is bitwise identical to a lone [`ServeModel::prefill`]
    /// of the same tokens. Non-bucket batch sizes (the scheduler's
    /// per-sequence remainder) fall back to the serial loop.
    fn prefill_batched(&mut self, seqs: &[&[i32]]) -> Result<Vec<(Vec<f32>, SeqState)>> {
        let b = seqs.len();
        if b == 0 {
            return Ok(Vec::new());
        }
        let t = seqs[0].len();
        if seqs.iter().any(|s| s.len() != t) {
            return Err(anyhow!(
                "prefill_batched needs equal-length sequences \
                 (length-class grouping is the scheduler's job)"
            ));
        }
        if b == 1 || self.prefill_buckets.binary_search(&b).is_err() {
            return seqs.iter().map(|s| self.prefill(s)).collect();
        }
        if t < self.min_prefill || t > self.window {
            return Err(anyhow!(
                "prefill length {t} outside the supported range {}..={}",
                self.min_prefill,
                self.window
            ));
        }
        let key = plan_key_dtyped(
            self.family.arch(),
            &format!("prefill_b{b}_t{t}"),
            self.dtype,
        );
        let mut flat = Vec::with_capacity(b * t);
        for s in seqs {
            flat.extend_from_slice(s);
        }
        let tail = vec![Tensor::i32(vec![b, t], flat)];
        let outs = {
            let Self { cache, family, shape, variant, params, dtype, weight_dtypes, .. } =
                self;
            let family = *family;
            let dtype = *dtype;
            cache
                .run_or_compile_with(
                    &key,
                    || {
                        let g = if dtype == DType::I8 {
                            family.build_prefill_batched_replicated(shape, b, t)
                        } else {
                            family.build_prefill_batched(shape, b, t)
                        };
                        build_serve_graph(variant, dtype, weight_dtypes, g)
                    },
                    params,
                    tail,
                )
                .map_err(|e| anyhow!(e))?
        };
        let v = self.vocab;
        let nl = self.shape.n_layers;
        let (conv_len, ssm_len) = (self.conv_len(), self.ssm_len());
        let logits_all = outs[0].as_f32();
        let mut result = Vec::with_capacity(b);
        for s in 0..b {
            let mut conv = Vec::with_capacity(nl * conv_len);
            let mut ssm = Vec::with_capacity(nl * ssm_len);
            for j in 0..nl {
                conv.extend_from_slice(
                    &outs[1 + 2 * j].as_f32()[s * conv_len..(s + 1) * conv_len],
                );
                ssm.extend_from_slice(
                    &outs[2 + 2 * j].as_f32()[s * ssm_len..(s + 1) * ssm_len],
                );
            }
            result.push((
                logits_all[s * v..(s + 1) * v].to_vec(),
                SeqState {
                    conv: HostTensor::F32(Self::batched(nl, &self.conv_shape), conv),
                    ssm: HostTensor::F32(Self::batched(nl, &self.ssm_shape), ssm),
                },
            ));
        }
        Ok(result)
    }

    /// i8 reports 0: its dynamic per-tensor activation scales would
    /// couple the kw positions inside one (b, kw, ·) node, so a verify
    /// step could not stay bitwise-identical to kw decode steps (the
    /// same coupling that pins i8 buckets unsplit on the pool).
    fn verify_window(&self) -> usize {
        if self.dtype == DType::I8 {
            0
        } else {
            crate::config::SPECULATE_CAP + 1
        }
    }

    /// One verify-graph call per (bucket, window): plans compile lazily
    /// under `verify_b{b}_k{kw}` keys into the same cache as decode, so
    /// after warmup the compile gauge stays flat — the windows in play
    /// are bounded by `verify_window()` and the buckets are the decode
    /// set. Runs unsplit (no pool chunking): a verify step is one short
    /// multi-token graph, and acceptance/rollback happens on the engine
    /// thread anyway.
    fn verify(&mut self, seqs: &mut [(&mut SeqState, &[i32])]) -> Result<Vec<Vec<f32>>> {
        let b = seqs.len();
        if self.buckets.binary_search(&b).is_err() {
            return Err(anyhow!("no decode bucket of size {b}"));
        }
        let window = self.verify_window();
        if window == 0 {
            return Err(anyhow!(
                "speculative verify is unsupported at this serving dtype"
            ));
        }
        let kw = seqs[0].1.len();
        if kw == 0 || seqs.iter().any(|(_, t)| t.len() != kw) {
            return Err(anyhow!(
                "verify needs equal non-empty token windows per sequence"
            ));
        }
        if kw > window {
            return Err(anyhow!(
                "verify window {kw} exceeds the supported maximum {window}"
            ));
        }
        let tail = self.verify_tail(seqs, kw);
        let key = plan_key_dtyped(
            self.family.arch(),
            &format!("verify_b{b}_k{kw}"),
            self.dtype,
        );
        let outs = {
            let Self { cache, family, shape, variant, params, dtype, weight_dtypes, .. } =
                self;
            let family = *family;
            let dtype = *dtype;
            cache
                .run_or_compile_with(
                    &key,
                    || {
                        build_serve_graph(
                            variant,
                            dtype,
                            weight_dtypes,
                            family.build_verify(shape, b, kw),
                        )
                    },
                    params,
                    tail,
                )
                .map_err(|e| anyhow!(e))?
        };
        let mut logits = Vec::with_capacity(b);
        self.apply_verify_outputs(seqs, &outs, kw * self.vocab, &mut logits);
        Ok(logits)
    }

    fn decode(&mut self, seqs: &mut [(&mut SeqState, i32)]) -> Result<Vec<Vec<f32>>> {
        let b = seqs.len();
        if self.buckets.binary_search(&b).is_err() {
            return Err(anyhow!("no decode bucket of size {b}"));
        }
        let mut logits = Vec::with_capacity(b);
        if let Some(chunks) = self.pool_chunks(b) {
            let mut jobs = Vec::with_capacity(chunks.len());
            let mut off = 0usize;
            for &c in &chunks {
                let entry = self
                    .decode_graphs
                    .iter()
                    .find(|e| e.bucket == c)
                    .expect("pool chunk is a compiled bucket");
                jobs.push(ExecJob {
                    graph: entry.graph.clone(),
                    key: entry.key.clone(),
                    shared: self.params.clone(),
                    tail: self.decode_tail(&seqs[off..off + c]),
                });
                off += c;
            }
            let results = self
                .pool
                .as_ref()
                .expect("pool_chunks implies pool")
                .execute_batch(jobs);
            // collect every chunk BEFORE touching any state, so a failed
            // chunk leaves all sequences exactly as they were
            let mut all_outs = Vec::with_capacity(results.len());
            for r in results {
                all_outs.push(r.map_err(|e| anyhow!("pooled decode: {e}"))?);
            }
            let mut off = 0usize;
            for (outs, &c) in all_outs.iter().zip(&chunks) {
                self.apply_outputs(&mut seqs[off..off + c], outs, &mut logits);
                off += c;
            }
        } else {
            let entry = self
                .decode_graphs
                .iter()
                .find(|e| e.bucket == b)
                .expect("bucket membership checked above");
            let key = entry.key.clone();
            let tail = self.decode_tail(seqs);
            let outs = self.cache.run(&key, tail).map_err(|e| anyhow!(e))?;
            self.apply_outputs(seqs, &outs, &mut logits);
        }
        Ok(logits)
    }
}

// --- mock backend for scheduler tests --------------------------------------------

/// Deterministic toy model: next token = (last + 1) mod vocab; the state
/// stores the running token so decode continuity is checkable.
pub struct MockModel {
    pub window: usize,
    pub vocab: usize,
    pub buckets: Vec<usize>,
    /// Batched-prefill bucket sizes the mock advertises (default [1]).
    pub prefill_buckets: Vec<usize>,
    /// Every decode batch size observed (asserts batching policy).
    pub batch_log: Vec<usize>,
    /// Every prefill batch size observed (asserts admission batching).
    pub prefill_batch_log: Vec<usize>,
    /// Artificial per-call latency (scheduling tests).
    pub decode_delay: std::time::Duration,
    /// Artificial per-prefill-round latency (scheduling tests).
    pub prefill_delay: std::time::Duration,
    /// Resume grain the mock advertises (0 = no resume support).
    pub resume_grain: usize,
    /// Streaming-chunk size used by `prefill_resume` (0 = one chunk);
    /// also lifts `max_prompt_len` beyond the window when set.
    pub chunk: usize,
    /// Every `prefill_resume` call observed: (suffix_len, had_state).
    pub resume_log: Vec<(usize, bool)>,
    /// Longest verify window the mock advertises (0 = no speculation).
    pub verify_window: usize,
    /// Every verify call observed: (batch, window).
    pub verify_log: Vec<(usize, usize)>,
    /// Optional shared engine-event trace: ('p', batch) per prefill
    /// round, ('d', batch) per decode call, ('r', suffix_len) per
    /// resume-prefill round, in call order. Interleaving tests read it
    /// from outside the engine thread.
    pub event_log: Option<std::sync::Arc<std::sync::Mutex<Vec<(char, usize)>>>>,
    /// Hard-death switch: once the flag is set, the next model call
    /// PANICS (not `Err`), unwinding the engine thread exactly like a
    /// real backend crash — every queued reply channel drops without a
    /// response. Router failover tests flip it mid-stream.
    pub die: Option<std::sync::Arc<std::sync::atomic::AtomicBool>>,
}

impl MockModel {
    pub fn new(window: usize, vocab: usize, buckets: Vec<usize>) -> Self {
        Self {
            window,
            vocab,
            buckets,
            prefill_buckets: vec![1],
            batch_log: Vec::new(),
            prefill_batch_log: Vec::new(),
            decode_delay: std::time::Duration::ZERO,
            prefill_delay: std::time::Duration::ZERO,
            resume_grain: 0,
            chunk: 0,
            resume_log: Vec::new(),
            verify_window: 5,
            verify_log: Vec::new(),
            event_log: None,
            die: None,
        }
    }

    fn check_die(&self) {
        if let Some(flag) = &self.die {
            if flag.load(std::sync::atomic::Ordering::SeqCst) {
                panic!("MockModel: synthetic hard death");
            }
        }
    }

    fn logits_for(&self, predicted: i32) -> Vec<f32> {
        let mut l = vec![0.0f32; self.vocab];
        l[(predicted.rem_euclid(self.vocab as i32)) as usize] = 10.0;
        l
    }

    fn log_event(&self, kind: char, batch: usize) {
        if let Some(log) = &self.event_log {
            log.lock().unwrap().push((kind, batch));
        }
    }
}

impl ServeModel for MockModel {
    fn prefill_len(&self) -> usize {
        self.window
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn decode_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.prefill_buckets
    }

    fn resume_grain(&self) -> usize {
        self.resume_grain
    }

    fn max_prompt_len(&self) -> usize {
        if self.chunk > 0 {
            usize::MAX / 2
        } else {
            self.window
        }
    }

    /// Counter-model resume: the state after any prefix is just its
    /// last token, so resuming is trivially decode-exact. Checkpoints
    /// fire at `chunk` boundaries like the real backend.
    fn prefill_resume(
        &mut self,
        tokens: &[i32],
        resume: Option<&SeqState>,
        checkpoint: &mut dyn FnMut(usize, &SeqState),
    ) -> Result<(Vec<f32>, SeqState)> {
        self.check_die();
        if self.resume_grain == 0 && resume.is_some() {
            return Err(anyhow!("mock resume disabled"));
        }
        self.resume_log.push((tokens.len(), resume.is_some()));
        self.log_event('r', tokens.len());
        if !self.prefill_delay.is_zero() {
            std::thread::sleep(self.prefill_delay);
        }
        let chunk = if self.chunk > 0 { self.chunk } else { tokens.len() };
        let mut consumed = 0usize;
        while consumed < tokens.len() {
            consumed += chunk.min(tokens.len() - consumed);
            if consumed < tokens.len() {
                let state = SeqState {
                    conv: HostTensor::F32(vec![1], vec![tokens[consumed - 1] as f32]),
                    ssm: HostTensor::F32(vec![1], vec![0.0]),
                };
                checkpoint(consumed, &state);
            }
        }
        let last = *tokens.last().unwrap();
        let state = SeqState {
            conv: HostTensor::F32(vec![1], vec![last as f32]),
            ssm: HostTensor::F32(vec![1], vec![0.0]),
        };
        Ok((self.logits_for(last + 1), state))
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<(Vec<f32>, SeqState)> {
        self.check_die();
        let last = *tokens.last().unwrap();
        let state = SeqState {
            conv: HostTensor::F32(vec![1], vec![last as f32]),
            ssm: HostTensor::F32(vec![1], vec![0.0]),
        };
        Ok((self.logits_for(last + 1), state))
    }

    fn prefill_batched(&mut self, seqs: &[&[i32]]) -> Result<Vec<(Vec<f32>, SeqState)>> {
        self.check_die();
        self.prefill_batch_log.push(seqs.len());
        self.log_event('p', seqs.len());
        if !self.prefill_delay.is_zero() {
            std::thread::sleep(self.prefill_delay);
        }
        seqs.iter().map(|s| self.prefill(s)).collect()
    }

    fn decode(&mut self, seqs: &mut [(&mut SeqState, i32)]) -> Result<Vec<Vec<f32>>> {
        self.check_die();
        self.batch_log.push(seqs.len());
        self.log_event('d', seqs.len());
        if !self.buckets.contains(&seqs.len()) {
            return Err(anyhow!("batch {} is not a bucket", seqs.len()));
        }
        if !self.decode_delay.is_zero() {
            std::thread::sleep(self.decode_delay);
        }
        Ok(seqs
            .iter_mut()
            .map(|(state, tok)| {
                state.conv = HostTensor::F32(vec![1], vec![*tok as f32]);
                self.logits_for(*tok + 1)
            })
            .collect())
    }

    fn verify_window(&self) -> usize {
        self.verify_window
    }

    /// Counter-model verify: position p predicts `tokens[p] + 1`, the
    /// state absorbs the whole window — bitwise identical to kw mock
    /// decode steps by construction, like the real backends.
    fn verify(&mut self, seqs: &mut [(&mut SeqState, &[i32])]) -> Result<Vec<Vec<f32>>> {
        self.check_die();
        let b = seqs.len();
        self.verify_log.push((b, seqs.first().map_or(0, |(_, t)| t.len())));
        self.log_event('v', b);
        if !self.buckets.contains(&b) {
            return Err(anyhow!("batch {b} is not a bucket"));
        }
        let kw = seqs[0].1.len();
        if kw == 0 || kw > self.verify_window || seqs.iter().any(|(_, t)| t.len() != kw)
        {
            return Err(anyhow!("bad verify window"));
        }
        if !self.decode_delay.is_zero() {
            std::thread::sleep(self.decode_delay);
        }
        let vocab = self.vocab;
        let mut out = Vec::with_capacity(b);
        for (state, toks) in seqs.iter_mut() {
            state.conv = HostTensor::F32(vec![1], vec![toks[kw - 1] as f32]);
            let mut row = Vec::with_capacity(kw * vocab);
            for &t in toks.iter() {
                row.extend_from_slice(&self.logits_for(t + 1));
            }
            out.push(row);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_model_is_a_counter() {
        let mut m = MockModel::new(4, 256, vec![1, 2]);
        let (logits, mut st) = m.prefill(&[5, 6, 7]).unwrap();
        let argmax = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, 8);
        let mut seqs = vec![(&mut st, 8i32)];
        let l2 = m.decode(&mut seqs).unwrap();
        let argmax2 = l2[0]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax2, 9);
        assert_eq!(m.batch_log, vec![1]);
    }

    fn amax(l: &[f32]) -> usize {
        l.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
    }

    #[test]
    fn decode_any_remaps_non_bucket_batches_onto_compiled_buckets() {
        // buckets [2, 4] — no bucket 1 or 3, so both the chunk walk and
        // the pad-up remainder path are exercised. The mock ERRORS on
        // non-bucket batch sizes, so passing proves the remap only ever
        // issues compiled sizes.
        let mut m = MockModel::new(4, 256, vec![2, 4]);
        let mut states = Vec::new();
        for t in [10i32, 20, 30] {
            states.push(m.prefill(&[t]).unwrap().1);
        }
        let mut seqs: Vec<(&mut SeqState, i32)> =
            states.iter_mut().zip([10i32, 20, 30]).collect();
        let (logits, padded) = m.decode_any(&mut seqs).unwrap();
        drop(seqs);
        assert_eq!(logits.len(), 3, "one logit row per REAL sequence");
        assert_eq!(padded, 1, "remainder 1 pads up to bucket 2");
        assert_eq!(m.batch_log, vec![2, 2], "chunk 2 + padded remainder 2");
        for (l, want) in logits.iter().zip([11usize, 21, 31]) {
            assert_eq!(amax(l), want);
        }
        // every real state advanced exactly one step
        for (s, t) in states.iter().zip([10.0f32, 20.0, 30.0]) {
            assert_eq!(s.conv.f32_data(), &[t]);
        }
    }

    #[test]
    fn decode_any_exact_bucket_and_greedy_decomposition() {
        let mut m = MockModel::new(4, 256, vec![1, 2, 4]);
        let toks: Vec<i32> = (0..7).map(|i| 10 + i).collect();
        let mut states: Vec<SeqState> =
            toks.iter().map(|&t| m.prefill(&[t]).unwrap().1).collect();
        // exact bucket: one call, zero padding
        {
            let mut seqs: Vec<(&mut SeqState, i32)> =
                states.iter_mut().zip(toks.iter().copied()).take(4).collect();
            let (l, padded) = m.decode_any(&mut seqs).unwrap();
            assert_eq!((l.len(), padded), (4, 0));
        }
        assert_eq!(m.batch_log, vec![4]);
        m.batch_log.clear();
        // 7 = greedy [4, 2, 1], nothing padded (bucket 1 exists)
        let mut seqs: Vec<(&mut SeqState, i32)> =
            states.iter_mut().zip(toks.iter().copied()).collect();
        let (l, padded) = m.decode_any(&mut seqs).unwrap();
        drop(seqs);
        assert_eq!((l.len(), padded), (7, 0));
        assert_eq!(m.batch_log, vec![4, 2, 1]);
        // empty batch is a no-op
        let mut none: Vec<(&mut SeqState, i32)> = Vec::new();
        assert_eq!(m.decode_any(&mut none).unwrap(), (Vec::new(), 0));
    }

    #[test]
    fn mock_rejects_non_bucket_batches() {
        let mut m = MockModel::new(4, 16, vec![1, 2]);
        let (_, mut a) = m.prefill(&[1]).unwrap();
        let (_, mut b) = m.prefill(&[2]).unwrap();
        let (_, mut c) = m.prefill(&[3]).unwrap();
        let mut seqs = vec![(&mut a, 1), (&mut b, 2), (&mut c, 3)];
        assert!(m.decode(&mut seqs).is_err());
    }

    #[test]
    fn mock_batched_prefill_matches_serial_and_logs_occupancy() {
        let mut m = MockModel::new(4, 256, vec![1, 2]);
        m.prefill_buckets = vec![1, 2];
        let seqs: Vec<&[i32]> = vec![&[5, 6], &[10, 11]];
        let batched = m.prefill_batched(&seqs).unwrap();
        assert_eq!(batched.len(), 2);
        let (l0, _) = m.prefill(&[5, 6]).unwrap();
        assert_eq!(batched[0].0, l0);
        assert_eq!(m.prefill_batch_log, vec![2]);
        assert_eq!(m.prefill_buckets(), &[1, 2]);
        // the default range is the fixed window
        assert_eq!(m.prefill_len_range(), (4, 4));
    }
}
