//! Sequence-state slab: the SSM analogue of a KV-cache manager.
//!
//! Unlike transformer serving, state size is O(1) per sequence (the
//! paper's core efficiency argument), so the manager is a fixed slab of
//! slots with explicit alloc/free — no paging, no eviction pressure, but
//! the same admission-control role: no free slot means a request waits.

use super::model::SeqState;

/// Slot handle into the cache.
pub type SlotId = usize;

/// Fixed-capacity slab of per-sequence recurrent states.
#[derive(Debug, Default)]
pub struct StateCache {
    slots: Vec<Option<SeqState>>,
    free: Vec<SlotId>,
    /// Peak concurrent occupancy (observability).
    pub high_water: usize,
}

impl StateCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            slots: (0..capacity).map(|_| None).collect(),
            free: (0..capacity).rev().collect(),
            high_water: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn has_free(&self) -> bool {
        !self.free.is_empty()
    }

    /// Claim a slot for a new sequence; None when full.
    pub fn alloc(&mut self, state: SeqState) -> Option<SlotId> {
        let id = self.free.pop()?;
        debug_assert!(self.slots[id].is_none(), "free list corruption");
        self.slots[id] = Some(state);
        self.high_water = self.high_water.max(self.in_use());
        Some(id)
    }

    /// Release a finished sequence's slot.
    pub fn release(&mut self, id: SlotId) -> SeqState {
        let st = self.slots[id].take().expect("releasing empty slot");
        self.free.push(id);
        st
    }

    pub fn get_mut(&mut self, id: SlotId) -> &mut SeqState {
        self.slots[id].as_mut().expect("empty slot")
    }

    /// Mutable access to several distinct slots at once (batched decode).
    /// Panics on duplicate ids.
    pub fn get_many_mut(&mut self, ids: &[SlotId]) -> Vec<&mut SeqState> {
        for (i, a) in ids.iter().enumerate() {
            for b in &ids[i + 1..] {
                assert_ne!(a, b, "duplicate slot id in batch");
            }
        }
        // split the slab into disjoint mutable borrows
        let mut result: Vec<Option<&mut SeqState>> = Vec::with_capacity(ids.len());
        let mut remaining: &mut [Option<SeqState>] = &mut self.slots;
        let mut base = 0usize;
        let mut order: Vec<(usize, SlotId)> =
            ids.iter().copied().enumerate().map(|(i, s)| (i, s)).collect();
        order.sort_by_key(|&(_, s)| s);
        result.resize_with(ids.len(), || None);
        for (orig_idx, slot) in order {
            let offset = slot - base;
            let (head, tail) = remaining.split_at_mut(offset + 1);
            result[orig_idx] = Some(head[offset].as_mut().expect("empty slot"));
            remaining = tail;
            base = slot + 1;
        }
        result.into_iter().map(|o| o.unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn st(v: f32) -> SeqState {
        SeqState {
            conv: HostTensor::F32(vec![1], vec![v]),
            ssm: HostTensor::F32(vec![1], vec![v]),
        }
    }

    #[test]
    fn alloc_release_cycle() {
        let mut c = StateCache::new(2);
        let a = c.alloc(st(1.0)).unwrap();
        let b = c.alloc(st(2.0)).unwrap();
        assert_ne!(a, b);
        assert!(c.alloc(st(3.0)).is_none(), "over capacity");
        assert_eq!(c.in_use(), 2);
        c.release(a);
        assert!(c.has_free());
        let d = c.alloc(st(4.0)).unwrap();
        assert_eq!(d, a, "slot reused");
        assert_eq!(c.high_water, 2);
    }

    #[test]
    fn get_many_mut_disjoint() {
        let mut c = StateCache::new(4);
        let ids: Vec<_> = (0..4).map(|i| c.alloc(st(i as f32)).unwrap()).collect();
        // ask out of order
        let sel = vec![ids[2], ids[0], ids[3]];
        let states = c.get_many_mut(&sel);
        assert_eq!(states[0].conv.f32_data()[0], 2.0);
        assert_eq!(states[1].conv.f32_data()[0], 0.0);
        assert_eq!(states[2].conv.f32_data()[0], 3.0);
    }

    #[test]
    #[should_panic(expected = "duplicate slot id")]
    fn get_many_mut_rejects_duplicates() {
        let mut c = StateCache::new(2);
        let a = c.alloc(st(0.0)).unwrap();
        c.get_many_mut(&[a, a]);
    }

    #[test]
    fn slot_leak_free_under_churn() {
        // property: after any alloc/release interleaving, in_use is exact
        let mut c = StateCache::new(8);
        let mut live: Vec<SlotId> = Vec::new();
        let mut rng = crate::util::Prng::new(3);
        for _ in 0..1000 {
            if !live.is_empty() && (rng.uniform() < 0.5 || !c.has_free()) {
                let i = rng.below(live.len());
                c.release(live.swap_remove(i));
            } else if c.has_free() {
                live.push(c.alloc(st(0.0)).unwrap());
            }
            assert_eq!(c.in_use(), live.len());
        }
    }
}
