//! Sequence-state manager: the SSM analogue of a KV-cache manager,
//! in two tiers.
//!
//! **Live tier** — a fixed slab of slots with explicit alloc/free for
//! in-flight sequences. State size is O(1) per sequence (the paper's
//! core efficiency argument), so there is no paging: no free slot means
//! a request waits (admission control).
//!
//! **Prefix tier** — finished sequences' state snapshots keyed by the
//! token prefix that produced them, under an LRU byte budget. Because
//! the whole conversation history compresses into a fixed-size state,
//! a multi-turn request whose prompt extends a cached prefix resumes
//! decode-exact in O(new tokens) instead of re-prefilling from token
//! zero. Keys are a rolling hash seeded by a namespace string
//! (`model:variant:dtype`), but every entry retains its full token
//! prefix and a lookup verifies token equality, so hash collisions can
//! never surface a wrong state. The tiers are structurally disjoint:
//! eviction only ever touches the prefix tier, never a live slot.

use super::model::SeqState;
use crate::runtime::HostTensor;

/// Slot handle into the live tier.
pub type SlotId = usize;

/// FNV-1a over the namespace string; seeds the rolling token hash so
/// caches for different (model, variant, dtype) namespaces never hash
/// alike even before the token-equality check.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// One rolling-hash step: order-sensitive and O(1) to extend, so a
/// streaming prefill can key a checkpoint at every chunk boundary
/// without rehashing the prefix.
fn hash_step(h: u64, tok: i32) -> u64 {
    (h ^ (tok as u32 as u64)).wrapping_mul(0x0100_0000_01b3).rotate_left(23)
}

/// Hashes of every prefix of `tokens`: `out[i]` covers `tokens[..i]`.
fn hash_prefixes(seed: u64, tokens: &[i32]) -> Vec<u64> {
    let mut out = Vec::with_capacity(tokens.len() + 1);
    let mut h = seed;
    out.push(h);
    for &t in tokens {
        h = hash_step(h, t);
        out.push(h);
    }
    out
}

fn tensor_bytes(t: &HostTensor) -> usize {
    // Both variants store 4-byte elements.
    t.shape().iter().product::<usize>() * 4
}

/// Resident cost of one prefix entry: the retained token key plus the
/// two state tensors.
fn entry_bytes(tokens: &[i32], state: &SeqState) -> usize {
    tokens.len() * 4 + tensor_bytes(&state.conv) + tensor_bytes(&state.ssm)
}

/// A retained snapshot: the state after prefilling exactly `tokens`.
#[derive(Debug)]
struct PrefixEntry {
    hash: u64,
    tokens: Vec<i32>,
    state: SeqState,
    bytes: usize,
    last_used: u64,
}

/// Two-tier per-sequence state manager (live slab + prefix cache).
#[derive(Debug, Default)]
pub struct StateCache {
    slots: Vec<Option<SeqState>>,
    free: Vec<SlotId>,
    /// Peak concurrent occupancy (observability).
    pub high_water: usize,
    /// Reused ordering buffer for `get_many_mut` (avoids a per-call
    /// allocation on every batched decode step).
    scratch: Vec<(usize, SlotId)>,
    // --- prefix tier ---
    prefix: Vec<PrefixEntry>,
    prefix_budget: usize,
    prefix_bytes: usize,
    seed: u64,
    tick: u64,
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    pub prefix_evicted: u64,
}

impl StateCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            slots: (0..capacity).map(|_| None).collect(),
            free: (0..capacity).rev().collect(),
            ..Self::default()
        }
    }

    /// Enable the prefix tier: retain finished-sequence snapshots under
    /// `budget_bytes` (0 keeps it disabled). The namespace string keys
    /// the hash seed — use `model:variant:dtype` so states can never be
    /// resumed across an incompatible serving configuration.
    pub fn with_prefix(mut self, budget_bytes: usize, namespace: &str) -> Self {
        self.prefix_budget = budget_bytes;
        self.seed = fnv1a(namespace);
        self
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn has_free(&self) -> bool {
        !self.free.is_empty()
    }

    /// Claim a slot for a new sequence; None when full.
    pub fn alloc(&mut self, state: SeqState) -> Option<SlotId> {
        let id = self.free.pop()?;
        debug_assert!(self.slots[id].is_none(), "free list corruption");
        self.slots[id] = Some(state);
        self.high_water = self.high_water.max(self.in_use());
        Some(id)
    }

    /// Release a finished sequence's slot.
    pub fn release(&mut self, id: SlotId) -> SeqState {
        let st = self.slots[id].take().expect("releasing empty slot");
        self.free.push(id);
        st
    }

    pub fn get_mut(&mut self, id: SlotId) -> &mut SeqState {
        self.slots[id].as_mut().expect("empty slot")
    }

    /// Mutable access to several distinct slots at once (batched decode).
    /// Panics on duplicate ids. Runs every decode step, so it sorts once
    /// into a reused scratch buffer and finds duplicates as sorted
    /// neighbours instead of the old O(n²) pairwise scan.
    pub fn get_many_mut(&mut self, ids: &[SlotId]) -> Vec<&mut SeqState> {
        let Self { slots, scratch, .. } = self;
        scratch.clear();
        scratch.extend(ids.iter().copied().enumerate());
        scratch.sort_unstable_by_key(|&(_, s)| s);
        for w in scratch.windows(2) {
            assert_ne!(w[0].1, w[1].1, "duplicate slot id in batch");
        }
        // split the slab into disjoint mutable borrows
        let mut result: Vec<Option<&mut SeqState>> = Vec::with_capacity(ids.len());
        result.resize_with(ids.len(), || None);
        let mut remaining: &mut [Option<SeqState>] = slots;
        let mut base = 0usize;
        for &(orig_idx, slot) in scratch.iter() {
            let offset = slot - base;
            let (head, tail) = remaining.split_at_mut(offset + 1);
            result[orig_idx] = Some(head[offset].as_mut().expect("empty slot"));
            remaining = tail;
            base = slot + 1;
        }
        result.into_iter().map(|o| o.unwrap()).collect()
    }

    // --- prefix tier -----------------------------------------------------

    pub fn prefix_enabled(&self) -> bool {
        self.prefix_budget > 0
    }

    /// Resident bytes in the prefix tier (incremental accounting).
    pub fn prefix_bytes(&self) -> usize {
        self.prefix_bytes
    }

    pub fn prefix_entries(&self) -> usize {
        self.prefix.len()
    }

    /// Recompute resident bytes from the entries themselves — test /
    /// debug audit of the incremental accounting.
    pub fn prefix_bytes_audit(&self) -> usize {
        self.prefix.iter().map(|e| entry_bytes(&e.tokens, &e.state)).sum()
    }

    /// Longest-prefix probe: returns `(matched_len, state snapshot)` for
    /// the longest cached entry whose tokens are a *proper* prefix of
    /// `tokens` (a full match would leave no new tokens to prefill — the
    /// caller wants at least one row to produce last-position logits).
    /// Hash filters first, then token equality verifies, so a collision
    /// can never resume the wrong state. Counts one hit or miss and
    /// refreshes the winner's LRU age.
    pub fn prefix_lookup(&mut self, tokens: &[i32]) -> Option<(usize, SeqState)> {
        if self.prefix_budget == 0 || self.prefix.is_empty() {
            return None;
        }
        let hashes = hash_prefixes(self.seed, tokens);
        let mut best: Option<usize> = None;
        let mut best_len = 0usize;
        for (i, e) in self.prefix.iter().enumerate() {
            let n = e.tokens.len();
            if n >= tokens.len() || n <= best_len {
                continue;
            }
            if e.hash == hashes[n] && e.tokens[..] == tokens[..n] {
                best = Some(i);
                best_len = n;
            }
        }
        if let Some(i) = best {
            self.tick += 1;
            self.prefix_hits += 1;
            let e = &mut self.prefix[i];
            e.last_used = self.tick;
            Some((best_len, e.state.clone()))
        } else {
            self.prefix_misses += 1;
            None
        }
    }

    /// Retain the state reached after prefilling exactly `tokens`.
    /// Re-inserting an existing key replaces its snapshot (and its byte
    /// accounting) without counting an eviction; otherwise LRU entries
    /// are evicted until the tier fits the budget. An entry larger than
    /// the whole budget is dropped rather than allowed to flush the
    /// tier. Live slots are never touched.
    pub fn prefix_insert(&mut self, tokens: &[i32], state: &SeqState) {
        if self.prefix_budget == 0 || tokens.is_empty() {
            return;
        }
        let hash = tokens.iter().fold(self.seed, |h, &t| hash_step(h, t));
        if let Some(i) = self
            .prefix
            .iter()
            .position(|e| e.hash == hash && e.tokens[..] == tokens[..])
        {
            let old = self.prefix.swap_remove(i);
            self.prefix_bytes -= old.bytes;
        }
        let bytes = entry_bytes(tokens, state);
        if bytes > self.prefix_budget {
            return;
        }
        while self.prefix_bytes + bytes > self.prefix_budget {
            self.evict_lru();
        }
        self.tick += 1;
        self.prefix_bytes += bytes;
        self.prefix.push(PrefixEntry {
            hash,
            tokens: tokens.to_vec(),
            state: state.clone(),
            bytes,
            last_used: self.tick,
        });
    }

    fn evict_lru(&mut self) {
        let i = self
            .prefix
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)
            .expect("evicting from empty prefix tier");
        let e = self.prefix.swap_remove(i);
        self.prefix_bytes -= e.bytes;
        self.prefix_evicted += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn st(v: f32) -> SeqState {
        SeqState {
            conv: HostTensor::F32(vec![1], vec![v]),
            ssm: HostTensor::F32(vec![1], vec![v]),
        }
    }

    #[test]
    fn alloc_release_cycle() {
        let mut c = StateCache::new(2);
        let a = c.alloc(st(1.0)).unwrap();
        let b = c.alloc(st(2.0)).unwrap();
        assert_ne!(a, b);
        assert!(c.alloc(st(3.0)).is_none(), "over capacity");
        assert_eq!(c.in_use(), 2);
        c.release(a);
        assert!(c.has_free());
        let d = c.alloc(st(4.0)).unwrap();
        assert_eq!(d, a, "slot reused");
        assert_eq!(c.high_water, 2);
    }

    #[test]
    fn get_many_mut_disjoint() {
        let mut c = StateCache::new(4);
        let ids: Vec<_> = (0..4).map(|i| c.alloc(st(i as f32)).unwrap()).collect();
        // ask out of order
        let sel = vec![ids[2], ids[0], ids[3]];
        let states = c.get_many_mut(&sel);
        assert_eq!(states[0].conv.f32_data()[0], 2.0);
        assert_eq!(states[1].conv.f32_data()[0], 0.0);
        assert_eq!(states[2].conv.f32_data()[0], 3.0);
    }

    #[test]
    #[should_panic(expected = "duplicate slot id")]
    fn get_many_mut_rejects_duplicates() {
        let mut c = StateCache::new(2);
        let a = c.alloc(st(0.0)).unwrap();
        c.get_many_mut(&[a, a]);
    }

    #[test]
    fn prefix_disabled_without_budget() {
        let mut c = StateCache::new(2);
        assert!(!c.prefix_enabled());
        c.prefix_insert(&[1, 2, 3], &st(1.0));
        assert_eq!(c.prefix_entries(), 0);
        assert!(c.prefix_lookup(&[1, 2, 3, 4]).is_none());
        // a disabled tier counts neither hits nor misses
        assert_eq!(c.prefix_hits + c.prefix_misses, 0);
    }

    #[test]
    fn prefix_lookup_returns_longest_verified_prefix() {
        let mut c = StateCache::new(2).with_prefix(1 << 20, "m:base:f32");
        c.prefix_insert(&[1, 2], &st(2.0));
        c.prefix_insert(&[1, 2, 3, 4], &st(4.0));
        // longest proper prefix wins
        let (n, s) = c.prefix_lookup(&[1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(n, 4);
        assert_eq!(s.conv.f32_data()[0], 4.0);
        // diverging suffix falls back to the shorter entry
        let (n, s) = c.prefix_lookup(&[1, 2, 9, 9]).unwrap();
        assert_eq!(n, 2);
        assert_eq!(s.conv.f32_data()[0], 2.0);
        // an exact-length match is not a *proper* prefix: no resume
        assert!(c.prefix_lookup(&[1, 2]).is_none());
        assert!(c.prefix_lookup(&[7, 8, 9]).is_none());
        assert_eq!(c.prefix_hits, 2);
        assert_eq!(c.prefix_misses, 2);
    }

    #[test]
    fn prefix_reinsert_replaces_without_double_accounting() {
        let mut c = StateCache::new(1).with_prefix(1 << 20, "ns");
        c.prefix_insert(&[5, 6, 7], &st(1.0));
        let bytes = c.prefix_bytes();
        c.prefix_insert(&[5, 6, 7], &st(2.0));
        assert_eq!(c.prefix_entries(), 1);
        assert_eq!(c.prefix_bytes(), bytes);
        assert_eq!(c.prefix_evicted, 0, "refresh is not an eviction");
        let (_, s) = c.prefix_lookup(&[5, 6, 7, 8]).unwrap();
        assert_eq!(s.conv.f32_data()[0], 2.0, "refresh took the new state");
    }

    #[test]
    fn prefix_budget_evicts_lru_only() {
        // st() entries cost 8 state bytes + 4 bytes/token; a length-2
        // key costs 16, so a 40-byte budget fits exactly two entries.
        let mut c = StateCache::new(1).with_prefix(40, "ns");
        c.prefix_insert(&[1, 1], &st(1.0));
        c.prefix_insert(&[2, 2], &st(2.0));
        assert_eq!(c.prefix_entries(), 2);
        // touch [1,1] so [2,2] becomes the LRU victim
        assert!(c.prefix_lookup(&[1, 1, 9]).is_some());
        c.prefix_insert(&[3, 3], &st(3.0));
        assert_eq!(c.prefix_entries(), 2);
        assert_eq!(c.prefix_evicted, 1);
        assert!(c.prefix_lookup(&[1, 1, 9]).is_some(), "recently used survives");
        assert!(c.prefix_lookup(&[3, 3, 9]).is_some(), "new entry resident");
        assert!(c.prefix_lookup(&[2, 2, 9]).is_none(), "LRU entry evicted");
        // an entry bigger than the whole budget is dropped, not thrashed
        c.prefix_insert(&[4; 32], &st(4.0));
        assert_eq!(c.prefix_entries(), 2);
        assert!(c.prefix_bytes() <= 40);
    }

    #[test]
    fn slot_leak_free_under_churn() {
        // two-tier property test: under random alloc/release/promote/
        // lookup interleavings, (a) live-slab occupancy is exact, (b)
        // prefix byte accounting matches a from-scratch audit and never
        // exceeds the budget, (c) a hit always returns the state that
        // was inserted for exactly that token prefix, and (d) live slab
        // states are never disturbed by prefix eviction.
        let budget = 200; // tight: forces constant eviction pressure
        let mut c = StateCache::new(8).with_prefix(budget, "churn");
        let mut live: Vec<(SlotId, f32)> = Vec::new();
        let mut inserted: std::collections::HashMap<Vec<i32>, f32> =
            std::collections::HashMap::new();
        let mut rng = crate::util::Prng::new(3);
        let mut next_tag = 1.0f32;
        for step in 0..1000 {
            match step % 4 {
                0 | 1 => {
                    // slab churn (as before)
                    if !live.is_empty() && (rng.uniform() < 0.5 || !c.has_free()) {
                        let i = rng.below(live.len());
                        let (id, tag) = live.swap_remove(i);
                        let released = c.release(id);
                        assert_eq!(released.conv.f32_data()[0], tag);
                        // promote roughly half of the finished states
                        if rng.uniform() < 0.5 {
                            let key: Vec<i32> =
                                (0..1 + rng.below(6)).map(|j| (id + j) as i32).collect();
                            c.prefix_insert(&key, &released);
                            inserted.insert(key, tag);
                        }
                    } else if c.has_free() {
                        let tag = next_tag;
                        next_tag += 1.0;
                        live.push((c.alloc(st(tag)).unwrap(), tag));
                    }
                }
                2 => {
                    let key: Vec<i32> = (0..1 + rng.below(8)).map(|j| j as i32).collect();
                    if let Some((n, s)) = c.prefix_lookup(&key) {
                        assert!(n < key.len());
                        let want = inserted
                            .get(&key[..n])
                            .expect("hit on a never-inserted prefix");
                        assert_eq!(s.conv.f32_data()[0], *want);
                    }
                }
                _ => {
                    let tag = 1000.0 + rng.below(50) as f32;
                    let key: Vec<i32> = (0..1 + rng.below(6)).map(|j| rng.below(9) as i32).collect();
                    c.prefix_insert(&key, &st(tag));
                    inserted.insert(key, tag);
                }
            }
            assert_eq!(c.in_use(), live.len());
            assert_eq!(c.prefix_bytes(), c.prefix_bytes_audit(), "accounting drift");
            assert!(c.prefix_bytes() <= budget, "budget exceeded");
            // eviction pressure must never reach into the live slab
            for &(id, tag) in &live {
                assert_eq!(c.get_mut(id).conv.f32_data()[0], tag);
            }
        }
        assert!(c.prefix_evicted > 0, "churn never exercised eviction");
    }
}
