//! The serving coordinator — XAMBA's Layer-3 runtime.
//!
//! A thread-based engine loop (no async runtime is vendored; SSM decode is
//! compute-bound anyway) that drives the AOT PJRT executables: byte-level
//! tokenizer with fixed-window prefill (paper Step-1 static shapes), a
//! token-budget continuous-batching scheduler (admission on
//! `max_batch_total_tokens` with explicit Overloaded backpressure,
//! per-request deadlines, mid-flight batch membership remapped onto the
//! compiled buckets), SSM state-slot cache (the O(1) "KV cache"), and
//! serving metrics (TTFT / e2e / per-token histograms, Tokens/s — the
//! paper's §4 KPI). The replicated front-end (`router`) fans the ingress
//! queue across N such engines with session affinity, so a
//! conversation's O(1) recurrent state stays resident on its replica.

pub mod batcher;
pub mod metrics;
pub mod model;
pub mod request;
pub mod router;
pub mod server;
pub mod speculate;
pub mod state_cache;
pub mod tokenizer;

pub use metrics::Metrics;
pub use model::{MockModel, PjrtServeModel, PlannedServeModel, SeqState, ServeModel};
pub use request::{FinishReason, GenParams, Request, Response, StreamEvent};
pub use speculate::{CheckpointRing, PromptLookupProposer, Proposer};
pub use router::{
    replica_config, start_planned_router, EngineReplica, ReplicaHandle, ReplicaStatus,
    Router,
};
pub use server::{sample, start_backend, start_pjrt, start_planned, Server};
pub use state_cache::StateCache;
pub use tokenizer::Tokenizer;
