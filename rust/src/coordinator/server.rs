//! The serving engine loop: token-budget continuous batching on a
//! dedicated worker thread.
//!
//! Python never appears here (XAMBA's Step-1 promise): the loop drives
//! pre-compiled PJRT executables (or a mock in tests) with plain channels
//! for ingress/egress. Admission is governed by a token budget
//! (`max_batch_total_tokens`: encoded prompt tokens + `max_new_tokens`
//! headroom per request) under a `waiting_served_ratio` policy; the
//! decode batch is CONTINUOUS — finished/cancelled/expired sequences
//! leave it the same step they end and newly prefilled ones join between
//! steps — while the compiled bucket plans stay the only execution
//! targets: [`ServeModel::decode_any`] scatter/gathers whatever the live
//! membership is onto them, so membership churn never recompiles.
//! Per-request deadlines, immediate budget release on cancellation, and
//! an explicit [`FinishReason::Overloaded`] under queue saturation round
//! out the control plane.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ServeConfig;
use crate::util::Prng;

use super::batcher::plan;
use super::metrics::Metrics;
use super::model::{SeqState, ServeModel};
use super::request::{FinishReason, GenParams, Request, RequestId, Response, StreamEvent};
use super::speculate::{CheckpointRing, PromptLookupProposer, Proposer};
use super::state_cache::{SlotId, StateCache};
use super::tokenizer::Tokenizer;

/// How a request wants its output delivered.
enum Reply {
    Final(Sender<Response>),
    Stream(Sender<StreamEvent>),
}

impl Reply {
    /// Deliver a newly-sampled token; false = client gone (cancel).
    fn push_token(&self, tok: u8) -> bool {
        match self {
            Reply::Final(_) => true,
            Reply::Stream(tx) => tx.send(StreamEvent::Token(tok)).is_ok(),
        }
    }

    fn finish(&self, resp: Response) {
        match self {
            Reply::Final(tx) => {
                let _ = tx.send(resp);
            }
            Reply::Stream(tx) => {
                let _ = tx.send(StreamEvent::Done(resp));
            }
        }
    }
}

enum Msg {
    Submit(Request, Reply),
    Shutdown,
}

/// A request that passed admission control and is queued for prefill.
struct Pending {
    req: Request,
    reply: Reply,
    /// Token cost held against the batch budget while the sequence is
    /// live: encoded prompt length + `max_new_tokens` headroom.
    cost: usize,
    deadline: Option<Instant>,
}

struct ActiveSeq {
    id: RequestId,
    slot: SlotId,
    last_token: i32,
    generated: Vec<i32>,
    prompt: Vec<u8>,
    /// Encoded prompt tokens — the completion-promotion key prefix for
    /// the prefix cache (prompt ++ generated tokens the state absorbed).
    prompt_tokens: Vec<i32>,
    /// Full token history (encoded prompt ++ every generated token) —
    /// the prompt-lookup proposer's n-gram corpus, grown incrementally.
    history: Vec<i32>,
    params: GenParams,
    arrived: Instant,
    first_token_at: Instant,
    reply: Reply,
    rng: Prng,
    batch_trace: Vec<usize>,
    /// Budget charge held until this sequence exits the batch.
    cost: usize,
    deadline: Option<Instant>,
}

impl ActiveSeq {
    /// Deliver the final response and consume the sequence; returns the
    /// end-to-end latency (µs) for the caller's metrics.
    fn finish(self, finish: FinishReason) -> f64 {
        let e2e = Instant::now().duration_since(self.arrived).as_micros() as f64;
        self.reply.finish(Response {
            id: self.id,
            prompt: self.prompt,
            generated: self
                .generated
                .iter()
                .map(|&t| t.clamp(0, 255) as u8)
                .collect(),
            finish,
            ttft_us: self
                .first_token_at
                .duration_since(self.arrived)
                .as_micros() as f64,
            e2e_us: e2e,
            batch_trace: self.batch_trace,
        });
        e2e
    }
}

/// Handle to a running server; dropping it (after `shutdown`) joins the
/// worker thread.
pub struct Server {
    tx: Sender<Msg>,
    worker: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Server {
    /// Start the engine loop; the model backend is constructed INSIDE the
    /// engine thread (PJRT clients are not `Send`). Fails fast if the
    /// factory fails (e.g. missing artifacts).
    pub fn start<F>(factory: F, cfg: ServeConfig) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn ServeModel>> + Send + 'static,
    {
        Self::start_with_proposer(factory, cfg, Box::new(PromptLookupProposer::default()))
    }

    /// Start with a custom speculative-decoding proposer (the default is
    /// prompt-lookup). Only consulted when `cfg.speculate > 0` and the
    /// backend advertises a verify window; a tiny draft model can slot
    /// in through this seam without touching the engine loop.
    pub fn start_with_proposer<F>(
        factory: F,
        cfg: ServeConfig,
        proposer: Box<dyn Proposer>,
    ) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn ServeModel>> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let m2 = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("xamba-engine".into())
            .spawn(move || {
                let model = match factory() {
                    Ok(m) => {
                        let _ = ready_tx.send(Ok(()));
                        m
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                engine_loop(model, cfg, rx, m2, proposer)
            })
            .expect("spawn engine");
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;
        Ok(Server {
            tx,
            worker: Some(worker),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
        })
    }

    fn make_request(&self, prompt: &[u8], params: GenParams) -> Request {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Request { id, prompt: prompt.to_vec(), params, arrived: Instant::now() }
    }

    /// Submit a prompt; returns a receiver for the final response.
    pub fn submit(&self, prompt: &[u8], params: GenParams) -> Receiver<Response> {
        let (reply_tx, reply_rx) = channel();
        let req = self.make_request(prompt, params);
        // a send error means the engine already shut down; the receiver
        // will simply report disconnection to the caller
        let _ = self.tx.send(Msg::Submit(req, Reply::Final(reply_tx)));
        reply_rx
    }

    /// Submit a prompt for STREAMING delivery: every sampled byte arrives
    /// as `StreamEvent::Token` immediately; dropping the receiver cancels
    /// the request at the next decode step (slot and budget reclaimed).
    pub fn submit_streaming(
        &self,
        prompt: &[u8],
        params: GenParams,
    ) -> Receiver<StreamEvent> {
        let (reply_tx, reply_rx) = channel();
        let req = self.make_request(prompt, params);
        let _ = self.tx.send(Msg::Submit(req, Reply::Stream(reply_tx)));
        reply_rx
    }

    /// Snapshot of the aggregated metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Liveness: false once the engine thread has exited — cleanly or by
    /// panic (a backend panic unwinds the thread and drops every queued
    /// reply channel). The router polls this to take a dead replica out
    /// of rotation; the metrics snapshot above stays readable either way
    /// (it lives behind an `Arc`, not in the thread).
    pub fn is_alive(&self) -> bool {
        self.worker.as_ref().map(|w| !w.is_finished()).unwrap_or(false)
    }

    /// Stop accepting work and join the loop (in-flight work completes).
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Sample a token from logits: greedy at temperature 0, else softmax.
///
/// NaN-proof: NaN logits are skipped in the argmax (`total_cmp` would
/// sort them ABOVE every real value), and a non-finite softmax mass
/// falls back to the greedy pick — one poisoned lane can no longer
/// panic the engine thread and kill every in-flight request.
pub fn sample(logits: &[f32], temperature: f32, rng: &mut Prng) -> i32 {
    fn greedy(logits: &[f32]) -> i32 {
        logits
            .iter()
            .enumerate()
            .filter(|(_, v)| !v.is_nan())
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }
    if temperature <= 0.0 {
        return greedy(logits);
    }
    let inv_t = 1.0 / temperature;
    let mx = logits
        .iter()
        .cloned()
        .filter(|v| !v.is_nan())
        .fold(f32::MIN, f32::max);
    let weights: Vec<f32> = logits.iter().map(|&l| ((l - mx) * inv_t).exp()).collect();
    let total: f32 = weights.iter().sum();
    if !total.is_finite() || total <= 0.0 {
        return greedy(logits);
    }
    let mut u = rng.uniform() * total;
    for (i, w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i as i32;
        }
    }
    (logits.len() - 1) as i32
}

/// Response for a request that never produced a token.
fn empty_response(req: &Request, finish: FinishReason) -> Response {
    Response {
        id: req.id,
        prompt: req.prompt.clone(),
        generated: vec![],
        finish,
        ttft_us: 0.0,
        e2e_us: 0.0,
        batch_trace: vec![],
    }
}

/// The request's effective deadline: its own override, else the server
/// default; 0 = none.
fn deadline_for(req: &Request, cfg: &ServeConfig) -> Option<Instant> {
    let ms = req.params.deadline_ms.unwrap_or(cfg.deadline_ms);
    (ms > 0).then(|| req.arrived + Duration::from_millis(ms))
}

/// Finish check for the FIRST (prefill-sampled) token: a stop byte hit
/// at prefill or `max_new_tokens <= 1` means the request is complete
/// before it ever enters the decode batch.
fn first_token_finish(params: &GenParams, tok: i32) -> Option<FinishReason> {
    if params.stop_byte.map(|b| tok == b as i32).unwrap_or(false) {
        Some(FinishReason::Stop)
    } else if params.max_new_tokens <= 1 {
        Some(FinishReason::Length)
    } else {
        None
    }
}

/// The single admission path — shared by the busy-loop ingress drain and
/// the idle wait so the two can never drift apart again. Every outcome
/// sends a response: queue saturation finishes as `Overloaded`
/// (backpressure — retry later), a request whose token cost exceeds the
/// WHOLE budget finishes as `Rejected` (it could never be scheduled),
/// and everything else is costed, deadlined, and queued.
fn submit_request(
    req: Request,
    reply: Reply,
    waiting: &mut VecDeque<Pending>,
    cfg: &ServeConfig,
    tokenizer: &Tokenizer,
    min_len: usize,
    metrics: &Arc<Mutex<Metrics>>,
) {
    let cost = tokenizer.encoded_len(&req.prompt, min_len) + req.params.max_new_tokens;
    if cfg.max_batch_total_tokens > 0 && cost > cfg.max_batch_total_tokens {
        metrics.lock().unwrap().rejected += 1;
        reply.finish(empty_response(&req, FinishReason::Rejected));
        return;
    }
    if waiting.len() >= cfg.queue_cap {
        metrics.lock().unwrap().overloaded += 1;
        reply.finish(empty_response(&req, FinishReason::Overloaded));
        return;
    }
    metrics.lock().unwrap().admitted += 1;
    let deadline = deadline_for(&req, cfg);
    waiting.push_back(Pending { req, reply, cost, deadline });
}

fn engine_loop(
    mut model: Box<dyn ServeModel>,
    cfg: ServeConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
    mut proposer: Box<dyn Proposer>,
) {
    // The truncation window follows the backend: chunked-prefill models
    // accept whole long prompts, window-bound models truncate as before.
    let tokenizer = Tokenizer::new(model.max_prompt_len(), model.vocab());
    let mut cache = StateCache::new(cfg.max_slots);
    if model.resume_grain() > 0 && cfg.prefix_cache_mb > 0 {
        // Namespace the rolling hash by everything that changes the
        // numerics: a cached state must never resume under a different
        // model, rewrite variant, or serving dtype.
        cache = cache.with_prefix(
            cfg.prefix_cache_mb * 1024 * 1024,
            &format!("{}:{}:{}", cfg.model, cfg.variant, cfg.dtype),
        );
    }
    let (min_len, window) = model.prefill_len_range();
    // speculation: drafts per step from config, capped so the verify
    // window (drafts + the bonus position) fits what the backend
    // advertises; 0 on either side keeps every row on plain decode
    let spec_k = if cfg.speculate > 0 {
        (cfg.speculate as usize).min(model.verify_window().saturating_sub(1))
    } else {
        0
    };
    let mut ring = CheckpointRing::new();
    let budget_total = cfg.max_batch_total_tokens;
    let mut budget_used: usize = 0;
    let mut waiting: VecDeque<Pending> = VecDeque::new();
    let mut active: Vec<ActiveSeq> = Vec::new();
    let mut shutting_down = false;

    loop {
        // --- ingress ------------------------------------------------------
        loop {
            match rx.try_recv() {
                Ok(Msg::Submit(req, reply)) => submit_request(
                    req,
                    reply,
                    &mut waiting,
                    &cfg,
                    &tokenizer,
                    min_len,
                    &metrics,
                ),
                Ok(Msg::Shutdown) => shutting_down = true,
                Err(_) => break,
            }
        }
        if shutting_down && waiting.is_empty() && active.is_empty() {
            // publish the plan-compile gauge one last time so shutdown
            // metrics carry the final count
            metrics.lock().unwrap().plan_compiles = model.plan_compiles() as u64;
            return;
        }

        // --- deadline sweep -----------------------------------------------
        let now = Instant::now();
        let mut i = 0;
        while i < waiting.len() {
            if waiting[i].deadline.map(|d| now >= d).unwrap_or(false) {
                let p = waiting.remove(i).expect("index in range");
                metrics.lock().unwrap().deadline_expired += 1;
                p.reply
                    .finish(empty_response(&p.req, FinishReason::DeadlineExceeded));
            } else {
                i += 1;
            }
        }
        // indices collected ascending, removed DESCENDING: swap_remove
        // only disturbs positions >= its own, so the rest stay valid
        let expired: Vec<usize> = active
            .iter()
            .enumerate()
            .filter(|(_, s)| s.deadline.map(|d| now >= d).unwrap_or(false))
            .map(|(i, _)| i)
            .collect();
        for i in expired.into_iter().rev() {
            let seq = active.swap_remove(i);
            budget_used -= seq.cost;
            cache.release(seq.slot);
            metrics.lock().unwrap().deadline_expired += 1;
            seq.finish(FinishReason::DeadlineExceeded);
        }

        // --- admission policy ---------------------------------------------
        // waiting_served_ratio defers admission while the running batch
        // is still large relative to the queue (0.0 = always admit);
        // fits() holds the token budget across every admission source.
        let admit_now = active.is_empty()
            || cfg.waiting_served_ratio <= 0.0
            || waiting.len() as f64 >= cfg.waiting_served_ratio * active.len() as f64;
        let fits =
            |used: usize, cost: usize| budget_total == 0 || used + cost <= budget_total;

        // --- resume / long-prompt admission (single-sequence round) --------
        //
        // Runs before the batched round: a request whose encoding extends
        // a cached prefix resumes from the snapshot and prefills only its
        // new suffix (O(new tokens), not O(history)), and a prompt longer
        // than one compiled window streams through the chunked-prefill
        // path with bounded arena memory. Either admits alone — a resume
        // suffix rarely shares a length-class — counts as this iteration's
        // one admission round, and falls through to decode below.
        let mut resumed_round = false;
        if admit_now
            && cache.has_free()
            && model.resume_grain() > 0
            && !waiting.is_empty()
            && fits(budget_used, waiting[0].cost)
        {
            let enc = tokenizer.encode_ranged(&waiting[0].req.prompt, min_len);
            let hit = cache.prefix_lookup(&enc);
            {
                let mut m = metrics.lock().unwrap();
                m.prefix_hits = cache.prefix_hits;
                m.prefix_misses = cache.prefix_misses;
            }
            if hit.is_some() || enc.len() > window {
                resumed_round = true;
                let Pending { req, reply, cost, deadline } =
                    waiting.pop_front().expect("peeked above");
                let (matched, resume_state) = match hit {
                    Some((n, s)) => (n, Some(s)),
                    None => (0, None),
                };
                let t0 = Instant::now();
                let mut chunks = 0u64;
                let mut chunk_t = Instant::now();
                let mut chunk_us: Vec<f64> = Vec::new();
                let result = {
                    let cache = &mut cache;
                    // chunk-boundary checkpoints feed the prefix cache,
                    // keyed by the full token prefix the state absorbed
                    let mut checkpoint = |consumed: usize, state: &SeqState| {
                        cache.prefix_insert(&enc[..matched + consumed], state);
                        chunks += 1;
                        chunk_us.push(chunk_t.elapsed().as_micros() as f64);
                        chunk_t = Instant::now();
                    };
                    model.prefill_resume(
                        &enc[matched..],
                        resume_state.as_ref(),
                        &mut checkpoint,
                    )
                };
                chunks += 1; // the final (uncheckpointed) chunk
                chunk_us.push(chunk_t.elapsed().as_micros() as f64);
                let round_us = t0.elapsed().as_micros() as f64;
                match result {
                    Ok((logits, state)) => {
                        // retain the full-prompt state so the NEXT turn
                        // (this prompt ++ reply ++ new text) resumes here
                        cache.prefix_insert(&enc, &state);
                        let now = Instant::now();
                        let mut rng = Prng::new(req.params.seed ^ req.id);
                        let tok = sample(&logits, req.params.temperature, &mut rng);
                        {
                            let mut m = metrics.lock().unwrap();
                            m.prefill_calls += 1;
                            m.prefill_batched_seqs += 1;
                            m.prefill_batch_us.record_us(round_us);
                            m.prefills += 1;
                            m.tokens_out += 1;
                            m.resumed_tokens += matched as u64;
                            m.prefill_chunks += chunks;
                            for &us in &chunk_us {
                                m.prefill_chunk_us.record_us(us);
                            }
                            m.prefix_evicted = cache.prefix_evicted;
                            m.ttft_us.record_us(
                                now.duration_since(req.arrived).as_micros() as f64,
                            );
                        }
                        if !reply.push_token(tok.clamp(0, 255) as u8) {
                            // client vanished before the first token; no
                            // slot or budget was ever charged
                            metrics.lock().unwrap().cancelled += 1;
                        } else if let Some(finish) = first_token_finish(&req.params, tok)
                        {
                            // complete at the first token: the full-prompt
                            // state is already in the prefix tier, so the
                            // next turn still resumes — no slot needed
                            let e2e =
                                Instant::now().duration_since(req.arrived).as_micros()
                                    as f64;
                            {
                                let mut m = metrics.lock().unwrap();
                                m.completed += 1;
                                m.e2e_us.record_us(e2e);
                            }
                            reply.finish(Response {
                                id: req.id,
                                prompt: req.prompt,
                                generated: vec![tok.clamp(0, 255) as u8],
                                finish,
                                ttft_us: now.duration_since(req.arrived).as_micros()
                                    as f64,
                                e2e_us: e2e,
                                batch_trace: vec![],
                            });
                        } else {
                            let slot = cache.alloc(state).expect("gated on has_free");
                            budget_used += cost;
                            {
                                let mut m = metrics.lock().unwrap();
                                m.budget_peak = m.budget_peak.max(budget_used as u64);
                            }
                            let mut history = enc.clone();
                            history.push(tok);
                            active.push(ActiveSeq {
                                id: req.id,
                                slot,
                                last_token: tok,
                                generated: vec![tok],
                                prompt: req.prompt,
                                prompt_tokens: enc,
                                history,
                                params: req.params,
                                arrived: req.arrived,
                                first_token_at: now,
                                reply,
                                rng,
                                batch_trace: Vec::new(),
                                cost,
                                deadline,
                            });
                        }
                    }
                    Err(e) => {
                        eprintln!(
                            "resumed prefill failed for request {}: {e:#}",
                            req.id
                        );
                        metrics.lock().unwrap().failed += 1;
                        reply.finish(empty_response(&req, FinishReason::Failed));
                    }
                }
            }
        }

        // --- prefill: one batched admission round --------------------------
        //
        // At most ONE prefill bucket runs per loop iteration, then control
        // falls through to decode — so admissions arriving while sequences
        // decode can never stall the decode loop by more than one prefill
        // batch. Waiting requests are grouped into the front request's
        // length-class (equal encoded token counts — no prompt is ever
        // padded to batch it with a longer one); candidates that would
        // overflow the token budget stay queued (their budget frees as
        // running sequences finish), and the class's leftover drains on
        // later rounds, down to per-sequence remainder batches.
        if !resumed_round && admit_now && cache.has_free() && !waiting.is_empty() {
            let enc_len = |prompt: &[u8]| tokenizer.encoded_len(prompt, min_len);
            let free = cache.capacity() - cache.in_use();
            let cap = model
                .prefill_buckets()
                .last()
                .copied()
                .unwrap_or(1)
                .min(free)
                .max(1);
            let class = enc_len(&waiting[0].req.prompt);
            let mut planned_cost = 0usize;
            let mut take: Vec<usize> = Vec::new();
            for i in 0..waiting.len() {
                if take.len() >= cap {
                    break;
                }
                if enc_len(&waiting[i].req.prompt) != class {
                    continue;
                }
                if !fits(budget_used + planned_cost, waiting[i].cost) {
                    continue;
                }
                planned_cost += waiting[i].cost;
                take.push(i);
            }
            if !take.is_empty() {
                // the largest compiled prefill bucket the class fills now
                let b = plan(model.prefill_buckets(), take.len()).bucket.max(1);
                take.truncate(b);
                let mut batch: Vec<Pending> = Vec::with_capacity(b);
                for &i in take.iter().rev() {
                    batch.push(waiting.remove(i).expect("selected index in range"));
                }
                batch.reverse();
                let tokens: Vec<Vec<i32>> = batch
                    .iter()
                    .map(|p| tokenizer.encode_ranged(&p.req.prompt, min_len))
                    .collect();
                let token_refs: Vec<&[i32]> =
                    tokens.iter().map(|t| t.as_slice()).collect();
                let t0 = Instant::now();
                // a failed BATCH retries each request alone, so one broken
                // (bucket, length-class) graph — or one poison request —
                // keeps the blast radius of the old per-request path: only
                // the sequence that actually fails gets failed
                let mut fell_back = false;
                let results: Vec<Result<(Vec<f32>, SeqState)>> =
                    match model.prefill_batched(&token_refs) {
                        Ok(rs) => rs.into_iter().map(Ok).collect(),
                        Err(e) => {
                            eprintln!(
                                "batched prefill failed for {} requests: {e:#}; \
                                 retrying per-sequence",
                                batch.len()
                            );
                            fell_back = true;
                            token_refs.iter().map(|t| model.prefill(t)).collect()
                        }
                    };
                let round_us = t0.elapsed().as_micros() as f64;
                let now = Instant::now();
                {
                    let mut m = metrics.lock().unwrap();
                    // a serial fallback counts as one round PER sequence, so
                    // mean_prefill_batch honestly drops to 1.0 exactly when
                    // batching is broken instead of masking it
                    let rounds = if fell_back { batch.len() as u64 } else { 1 };
                    m.prefill_calls += rounds;
                    m.prefill_batched_seqs += batch.len() as u64;
                    m.prefill_batch_us.record_us(round_us);
                }
                for ((p, result), toks) in batch.into_iter().zip(results).zip(tokens) {
                    let Pending { req, reply, cost, deadline } = p;
                    let (logits, state) = match result {
                        Ok(r) => r,
                        Err(e) => {
                            eprintln!("prefill failed for request {}: {e:#}", req.id);
                            metrics.lock().unwrap().failed += 1;
                            reply.finish(empty_response(&req, FinishReason::Failed));
                            continue;
                        }
                    };
                    let mut rng = Prng::new(req.params.seed ^ req.id);
                    let tok = sample(&logits, req.params.temperature, &mut rng);
                    {
                        let mut m = metrics.lock().unwrap();
                        m.prefills += 1;
                        m.tokens_out += 1;
                        m.ttft_us.record_us(
                            now.duration_since(req.arrived).as_micros() as f64,
                        );
                    }
                    if !reply.push_token(tok.clamp(0, 255) as u8) {
                        // client vanished before the first token; no slot
                        // or budget was ever charged
                        metrics.lock().unwrap().cancelled += 1;
                        continue;
                    }
                    if let Some(finish) = first_token_finish(&req.params, tok) {
                        // complete at the first token: promote the
                        // prompt-only state (it absorbed exactly the
                        // prompt — the sampled token was never fed back)
                        // and respond without ever occupying a slot
                        if cache.prefix_enabled() {
                            cache.prefix_insert(&toks, &state);
                            let mut m = metrics.lock().unwrap();
                            m.prefix_evicted = cache.prefix_evicted;
                        }
                        let e2e = Instant::now()
                            .duration_since(req.arrived)
                            .as_micros() as f64;
                        {
                            let mut m = metrics.lock().unwrap();
                            m.completed += 1;
                            m.e2e_us.record_us(e2e);
                        }
                        reply.finish(Response {
                            id: req.id,
                            prompt: req.prompt,
                            generated: vec![tok.clamp(0, 255) as u8],
                            finish,
                            ttft_us: now.duration_since(req.arrived).as_micros() as f64,
                            e2e_us: e2e,
                            batch_trace: vec![],
                        });
                        continue;
                    }
                    let slot = cache.alloc(state).expect("round capped at free slots");
                    budget_used += cost;
                    {
                        let mut m = metrics.lock().unwrap();
                        m.budget_peak = m.budget_peak.max(budget_used as u64);
                    }
                    let mut history = toks.clone();
                    history.push(tok);
                    active.push(ActiveSeq {
                        id: req.id,
                        slot,
                        last_token: tok,
                        generated: vec![tok],
                        prompt: req.prompt,
                        prompt_tokens: toks,
                        history,
                        params: req.params,
                        arrived: req.arrived,
                        first_token_at: now,
                        reply,
                        rng,
                        batch_trace: Vec::new(),
                        cost,
                        deadline,
                    });
                }
            }
            // NO `continue`: fall through so pending decodes advance
            // between admission rounds (the interleave invariant).
        }

        // --- continuous batched decode (optionally speculative) -------------
        //
        // EVERY live sequence advances each step; decode_any remaps the
        // membership onto the compiled bucket plans (greedy decomposition
        // plus padding for an unfittable remainder), so sequences joining
        // or leaving between steps never trigger a recompile. With
        // `--speculate K`, greedy sequences whose history yields a
        // prompt-lookup draft advance through ONE batched verify step
        // instead: their state is checkpointed into the ring first, and
        // partial acceptance rolls back and re-advances exactly the
        // accepted tokens — so the post-step state (and therefore every
        // future token) is bitwise the non-speculative one. Mixed
        // speculative / plain membership is one batch: rows are grouped
        // by window length and each group remaps onto the same compiled
        // buckets.
        if !active.is_empty() {
            let t0 = Instant::now();
            let vocab = model.vocab();
            // per-row verify window: [last_token] ++ drafts. Empty draft,
            // sampled (non-greedy) rows, and rows within one token of
            // their length limit stay on plain decode (window 1).
            let windows: Vec<Vec<i32>> = active
                .iter()
                .map(|seq| {
                    let mut w = vec![seq.last_token];
                    if spec_k > 0 && seq.params.temperature <= 0.0 {
                        // never draft past the row's remaining length:
                        // tokens beyond max_new_tokens could only be
                        // rolled back again
                        let rem = seq
                            .params
                            .max_new_tokens
                            .saturating_sub(seq.generated.len());
                        let k = spec_k.min(rem.saturating_sub(1));
                        if k > 0 {
                            let draft = proposer.propose(&seq.history, k);
                            // a misbehaving proposer cannot push an
                            // out-of-vocab token into the embed gather
                            w.extend(
                                draft
                                    .into_iter()
                                    .take(k)
                                    .take_while(|&t| (0..vocab as i32).contains(&t)),
                            );
                        }
                    }
                    w
                })
                .collect();
            let mut plain: Vec<usize> = Vec::new();
            let mut spec_groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
            for (i, w) in windows.iter().enumerate() {
                if w.len() == 1 {
                    plain.push(i);
                } else {
                    spec_groups.entry(w.len()).or_default().push(i);
                }
            }

            // run the model calls; any failure fails the whole batch,
            // exactly like a plain decode failure always has
            let mut step_logits: Vec<Option<Vec<f32>>> = Vec::new();
            step_logits.resize_with(active.len(), || None);
            let mut padded_total = 0usize;
            let mut step_err: Option<anyhow::Error> = None;
            if !plain.is_empty() {
                let slots: Vec<SlotId> = plain.iter().map(|&i| active[i].slot).collect();
                let states = cache.get_many_mut(&slots);
                let mut seqs: Vec<(&mut SeqState, i32)> = states
                    .into_iter()
                    .zip(plain.iter().map(|&i| windows[i][0]))
                    .collect();
                match model.decode_any(&mut seqs) {
                    Ok((logits, padded)) => {
                        padded_total += padded;
                        for (&i, l) in plain.iter().zip(logits) {
                            step_logits[i] = Some(l);
                        }
                    }
                    Err(e) => step_err = Some(e),
                }
            }
            for rows in spec_groups.values() {
                if step_err.is_some() {
                    break;
                }
                let slots: Vec<SlotId> = rows.iter().map(|&i| active[i].slot).collect();
                let states = cache.get_many_mut(&slots);
                // checkpoint BEFORE verify mutates anything: the ring
                // (keyed by slot, reused across steps) is what partial
                // acceptance rolls back to
                let mut seqs: Vec<(&mut SeqState, &[i32])> =
                    Vec::with_capacity(rows.len());
                for (st, &i) in states.into_iter().zip(rows.iter()) {
                    ring.checkpoint(active[i].slot, st);
                    seqs.push((st, windows[i].as_slice()));
                }
                match model.verify_any(&mut seqs) {
                    Ok((logits, padded)) => {
                        padded_total += padded;
                        for (&i, l) in rows.iter().zip(logits) {
                            step_logits[i] = Some(l);
                        }
                    }
                    Err(e) => step_err = Some(e),
                }
            }
            if let Some(e) = step_err.take() {
                eprintln!("decode step failed: {e:#}; failing the batch");
                // tell every client instead of letting them stare at
                // dead channels until their recvs time out
                for seq in active.drain(..) {
                    budget_used -= seq.cost;
                    cache.release(seq.slot);
                    metrics.lock().unwrap().failed += 1;
                    seq.finish(FinishReason::Failed);
                }
                continue;
            }

            // --- emission: walk each row's window while drafts match ---
            let n = active.len();
            enum Exit {
                Cancel,
                Done(FinishReason),
            }
            let mut removals: Vec<(usize, Exit)> = Vec::new();
            // rows whose verify over-advanced: (active index, accepted
            // emission count a < kw); rolled back + re-advanced below
            let mut readvance: Vec<(usize, usize)> = Vec::new();
            let mut emitted_total = 0u64;
            let mut spec_proposed = 0u64;
            let mut spec_accepted = 0u64;
            for (i, row) in step_logits.iter().enumerate() {
                let row = row.as_ref().expect("every live row ran this step");
                let kw = windows[i].len();
                let seq = &mut active[i];
                spec_proposed += (kw - 1) as u64;
                let mut a = 0usize; // tokens emitted from this window
                let mut exit: Option<Exit> = None;
                loop {
                    // emit t_{a+1} = sample(L_a) — the PR-8 NaN-safe
                    // sampler at EVERY position, drafted or bonus
                    let logits = &row[a * vocab..(a + 1) * vocab];
                    let tok = sample(logits, seq.params.temperature, &mut seq.rng);
                    seq.last_token = tok;
                    seq.generated.push(tok);
                    seq.history.push(tok);
                    seq.batch_trace.push(n);
                    a += 1;
                    emitted_total += 1;
                    if !seq.reply.push_token(tok.clamp(0, 255) as u8) {
                        exit = Some(Exit::Cancel);
                        break;
                    }
                    let hit_stop = seq
                        .params
                        .stop_byte
                        .map(|b| tok == b as i32)
                        .unwrap_or(false);
                    if hit_stop {
                        exit = Some(Exit::Done(FinishReason::Stop));
                        break;
                    }
                    if seq.generated.len() >= seq.params.max_new_tokens {
                        exit = Some(Exit::Done(FinishReason::Length));
                        break;
                    }
                    // deeper window positions are only valid while the
                    // draft at this position is what greedy actually chose
                    if a >= kw || tok != windows[i][a] {
                        break;
                    }
                }
                spec_accepted += (a - 1) as u64;
                match exit {
                    Some(Exit::Cancel) => {
                        // cancelled rows never roll back: the slot is
                        // released this step and the state discarded
                        removals.push((i, Exit::Cancel));
                    }
                    other => {
                        if a < kw {
                            readvance.push((i, a));
                        }
                        if let Some(exit) = other {
                            removals.push((i, exit));
                        }
                    }
                }
            }

            // --- rollback + re-advance the partially accepted rows -----
            //
            // Verify absorbed the whole window; a row that emitted a < kw
            // tokens must end the step as if it had decoded exactly those
            // a tokens. Rollback restores the pre-verify snapshot, then
            // the accepted prefix re-advances through the same bitwise
            // path (one plain decode step for a == 1, a verify window of
            // length a otherwise). Runs BEFORE removals so finishing
            // rows' states are exact when promoted to the prefix cache.
            if !readvance.is_empty() {
                let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
                for &(i, a) in &readvance {
                    groups.entry(a).or_default().push(i);
                }
                for (&a, rows) in groups.iter() {
                    if step_err.is_some() {
                        break;
                    }
                    let slots: Vec<SlotId> =
                        rows.iter().map(|&i| active[i].slot).collect();
                    let states = cache.get_many_mut(&slots);
                    let mut seqs: Vec<(&mut SeqState, &[i32])> =
                        Vec::with_capacity(rows.len());
                    for (st, &i) in states.into_iter().zip(rows.iter()) {
                        ring.rollback_into(active[i].slot, st);
                        seqs.push((st, &windows[i][..a]));
                    }
                    let result = if a == 1 {
                        let mut one: Vec<(&mut SeqState, i32)> = seqs
                            .iter_mut()
                            .map(|(s, t)| (&mut **s, t[0]))
                            .collect();
                        model.decode_any(&mut one).map(|(_, p)| p)
                    } else {
                        model.verify_any(&mut seqs).map(|(_, p)| p)
                    };
                    match result {
                        Ok(padded) => padded_total += padded,
                        Err(e) => step_err = Some(e),
                    }
                }
                if let Some(e) = step_err.take() {
                    eprintln!("speculative re-advance failed: {e:#}; failing the batch");
                    for seq in active.drain(..) {
                        budget_used -= seq.cost;
                        cache.release(seq.slot);
                        metrics.lock().unwrap().failed += 1;
                        seq.finish(FinishReason::Failed);
                    }
                    continue;
                }
            }

            let step_us = t0.elapsed().as_micros() as f64;
            {
                let mut m = metrics.lock().unwrap();
                // one decode_call per CONTINUOUS step: mean batch is the
                // mean number of live sequences advanced per step
                // (occupancy), regardless of how many bucket executions
                // the remap — or the verify/re-advance pair — used
                m.decode_calls += 1;
                m.decode_batched_seqs += n as u64;
                m.decode_padded_slots += padded_total as u64;
                m.tokens_out += emitted_total;
                m.decode_step_tokens += emitted_total;
                m.spec_proposed += spec_proposed;
                m.spec_accepted += spec_accepted;
                m.per_token_us.record_us(step_us / emitted_total.max(1) as f64);
                m.decode_batch_us.record_us(step_us);
                m.plan_compiles = model.plan_compiles() as u64;
            }

            // exits leave the batch THE SAME STEP they end: indices were
            // collected ascending, so removing in descending order keeps
            // every pending index valid (swap_remove only disturbs
            // positions >= its own)
            for (i, exit) in removals.into_iter().rev() {
                let seq = active.swap_remove(i);
                budget_used -= seq.cost;
                let final_state = cache.release(seq.slot);
                match exit {
                    Exit::Cancel => {
                        metrics.lock().unwrap().cancelled += 1;
                    }
                    Exit::Done(reason) => {
                        // promote the finished state to the prefix
                        // tier: it has absorbed the prompt plus
                        // every generated token EXCEPT the last
                        // sample (never fed back through decode),
                        // so the next turn of this conversation
                        // resumes it decode-exactly. Cancels and
                        // failures are not promoted; neither is a
                        // sequence whose absorbed tokens fall
                        // outside the byte alphabet (its next-turn
                        // prompt would re-encode them differently
                        // than the state actually saw them).
                        let absorbed = &seq.generated[..seq.generated.len() - 1];
                        if cache.prefix_enabled()
                            && absorbed.iter().all(|&t| (0..=255).contains(&t))
                        {
                            let mut key = seq.prompt_tokens.clone();
                            key.extend_from_slice(absorbed);
                            cache.prefix_insert(&key, &final_state);
                            let mut m = metrics.lock().unwrap();
                            m.prefix_evicted = cache.prefix_evicted;
                        }
                        let e2e = seq.finish(reason);
                        let mut m = metrics.lock().unwrap();
                        m.completed += 1;
                        m.e2e_us.record_us(e2e);
                    }
                }
            }
            continue;
        }

        // --- idle ------------------------------------------------------------
        if shutting_down {
            continue; // drain remaining work without blocking
        }
        match rx.recv_timeout(Duration::from_micros(cfg.batch_wait_us.max(100))) {
            Ok(Msg::Submit(req, reply)) => submit_request(
                req,
                reply,
                &mut waiting,
                &cfg,
                &tokenizer,
                min_len,
                &metrics,
            ),
            Ok(Msg::Shutdown) => shutting_down = true,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => shutting_down = true,
        }
    }
}

/// Convenience: start a server over the PJRT artifacts.
pub fn start_pjrt(cfg: &ServeConfig) -> Result<Server> {
    let c = cfg.clone();
    Server::start(
        move || {
            Ok(Box::new(super::model::PjrtServeModel::load_with_buckets(
                &c.artifacts_dir,
                &c.model,
                &c.variant,
                Some(&c.decode_buckets),
            )?) as Box<dyn ServeModel>)
        },
        cfg.clone(),
    )
}

/// Convenience: start a server on the planned executor (no PJRT, no
/// artifacts required). The model — graphs, cached plans, and the
/// execution pool — is constructed and owned inside the engine thread;
/// shutdown drops it there, which joins the pool's workers.
pub fn start_planned(cfg: &ServeConfig) -> Result<Server> {
    let c = cfg.clone();
    Server::start(
        move || {
            Ok(Box::new(super::model::PlannedServeModel::from_config(&c)?)
                as Box<dyn ServeModel>)
        },
        cfg.clone(),
    )
}

/// Start the backend `cfg.backend` selects ("planned" | "pjrt").
///
/// Validates the config first ([`ServeConfig::validate`]): an unknown
/// backend/model/variant string fails here with one actionable message
/// instead of panicking (or erroring obscurely) inside the engine thread.
pub fn start_backend(cfg: &ServeConfig) -> Result<Server> {
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    match cfg.backend.as_str() {
        "" | "planned" => start_planned(cfg),
        "pjrt" => start_pjrt(cfg),
        // validate() already rejected everything else; keep a real error
        // (not a panic) so the two admitted-sets can never drift apart
        other => Err(anyhow::anyhow!(
            "unknown serve backend {other:?} (want \"planned\" or \"pjrt\")"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::model::MockModel;

    fn test_cfg(slots: usize) -> ServeConfig {
        ServeConfig {
            max_slots: slots,
            queue_cap: 16,
            batch_wait_us: 100,
            ..Default::default()
        }
    }

    #[test]
    fn single_request_counts_up() {
        let model = MockModel::new(8, 256, vec![1, 2, 4]);
        let server = Server::start(move || Ok(Box::new(model) as _), test_cfg(4)).unwrap();
        let rx = server.submit(
            b"a", // 'a' = 97
            GenParams { max_new_tokens: 5, ..Default::default() },
        );
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        // mock predicts last+1 each step: 98, 99, 100, 101, 102 = "bcdef"
        assert_eq!(resp.generated, b"bcdef");
        assert_eq!(resp.finish, FinishReason::Length);
        assert!(resp.ttft_us >= 0.0 && resp.e2e_us >= resp.ttft_us);
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.tokens_out, 5);
    }

    #[test]
    fn stop_byte_ends_generation_early() {
        let model = MockModel::new(8, 256, vec![1]);
        let server = Server::start(move || Ok(Box::new(model) as _), test_cfg(2)).unwrap();
        let rx = server.submit(
            b"a",
            GenParams {
                max_new_tokens: 50,
                stop_byte: Some(b'd'), // 100
                ..Default::default()
            },
        );
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.finish, FinishReason::Stop);
        assert_eq!(resp.generated, b"bcd");
        server.shutdown();
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let model = MockModel::new(8, 256, vec![1, 2, 4]);
        let server = Server::start(move || Ok(Box::new(model) as _), test_cfg(8)).unwrap();
        let rxs: Vec<_> = (0..4)
            .map(|_| {
                server.submit(
                    b"x",
                    GenParams { max_new_tokens: 20, ..Default::default() },
                )
            })
            .collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(r.generated.len(), 20);
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 4);
        // with 4 concurrent sequences, decode must have used batches > 1
        assert!(
            m.mean_decode_batch() > 1.5,
            "mean batch {}",
            m.mean_decode_batch()
        );
    }

    #[test]
    fn queue_overflow_surfaces_overloaded() {
        // 1 slot + tiny queue: flood and count backpressure responses
        let mut model = MockModel::new(8, 256, vec![1]);
        model.decode_delay = Duration::from_millis(2);
        let cfg = ServeConfig {
            max_slots: 1,
            queue_cap: 2,
            batch_wait_us: 100,
            ..Default::default()
        };
        let server = Server::start(move || Ok(Box::new(model) as _), cfg).unwrap();
        let rxs: Vec<_> = (0..12)
            .map(|_| {
                server.submit(
                    b"y",
                    GenParams { max_new_tokens: 30, ..Default::default() },
                )
            })
            .collect();
        let mut overloaded = 0;
        let mut completed = 0;
        for rx in rxs {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(r) if r.finish == FinishReason::Overloaded => overloaded += 1,
                Ok(_) => completed += 1,
                Err(e) => panic!("lost response: {e}"),
            }
        }
        assert!(overloaded > 0, "backpressure never triggered");
        assert_eq!(completed + overloaded, 12);
        let m = server.shutdown();
        assert_eq!(m.overloaded, overloaded as u64);
        assert_eq!(m.rejected, 0, "saturation is Overloaded, not Rejected");
    }

    #[test]
    fn streaming_delivers_tokens_incrementally() {
        let model = MockModel::new(8, 256, vec![1, 2]);
        let server =
            Server::start(move || Ok(Box::new(model) as _), test_cfg(4)).unwrap();
        let rx = server.submit_streaming(
            b"a",
            GenParams { max_new_tokens: 4, ..Default::default() },
        );
        let mut tokens = Vec::new();
        let mut done = None;
        while let Ok(ev) = rx.recv_timeout(Duration::from_secs(5)) {
            match ev {
                StreamEvent::Token(t) => tokens.push(t),
                StreamEvent::Done(r) => {
                    done = Some(r);
                    break;
                }
            }
        }
        assert_eq!(tokens, b"bcde");
        let r = done.expect("no Done event");
        assert_eq!(r.generated, b"bcde");
        server.shutdown();
    }

    #[test]
    fn dropping_stream_receiver_cancels_and_frees_slot() {
        let mut model = MockModel::new(8, 256, vec![1]);
        model.decode_delay = Duration::from_millis(1);
        let server =
            Server::start(move || Ok(Box::new(model) as _), test_cfg(1)).unwrap();
        let rx = server.submit_streaming(
            b"a",
            GenParams { max_new_tokens: 10_000, ..Default::default() },
        );
        // read two tokens then walk away
        let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        drop(rx);
        // the single slot must be reclaimed: a new request completes
        let rx2 = server.submit(
            b"z",
            GenParams { max_new_tokens: 3, ..Default::default() },
        );
        let r = rx2.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(r.finish, FinishReason::Length);
        let m = server.shutdown();
        assert_eq!(m.cancelled, 1);
    }

    #[test]
    fn decode_failure_reports_failed_response() {
        use crate::coordinator::model::SeqState;

        // prefill succeeds (first token delivered), every decode errors
        struct FailingDecode(MockModel);
        impl ServeModel for FailingDecode {
            fn prefill_len(&self) -> usize {
                self.0.prefill_len()
            }
            fn vocab(&self) -> usize {
                self.0.vocab()
            }
            fn decode_buckets(&self) -> &[usize] {
                self.0.decode_buckets()
            }
            fn prefill(&mut self, tokens: &[i32]) -> Result<(Vec<f32>, SeqState)> {
                self.0.prefill(tokens)
            }
            fn decode(
                &mut self,
                _seqs: &mut [(&mut SeqState, i32)],
            ) -> Result<Vec<Vec<f32>>> {
                Err(anyhow::anyhow!("synthetic decode failure"))
            }
        }

        let model = FailingDecode(MockModel::new(8, 256, vec![1]));
        let server =
            Server::start(move || Ok(Box::new(model) as _), test_cfg(2)).unwrap();
        let rx = server.submit(
            b"a",
            GenParams { max_new_tokens: 5, ..Default::default() },
        );
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.finish, FinishReason::Failed);
        assert_eq!(resp.generated, b"b", "the prefill token was already delivered");
        let m = server.shutdown();
        assert_eq!(m.failed, 1);
        assert_eq!(m.completed, 0);
    }

    #[test]
    fn concurrent_admissions_prefill_in_batches() {
        // a slow prefill lets the queue build up; the admission loop must
        // then batch the backlog instead of prefilling one-by-one
        let mut model = MockModel::new(8, 256, vec![1, 2, 4]);
        model.prefill_buckets = vec![1, 2, 4];
        model.prefill_delay = Duration::from_millis(5);
        let server = Server::start(move || Ok(Box::new(model) as _), test_cfg(8)).unwrap();
        let rxs: Vec<_> = (0..5)
            .map(|_| {
                server.submit(
                    b"q",
                    GenParams { max_new_tokens: 4, ..Default::default() },
                )
            })
            .collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(r.finish, FinishReason::Length);
        }
        let m = server.shutdown();
        assert_eq!(m.prefills, 5);
        assert!(
            m.prefill_calls < m.prefills,
            "admissions never batched: {} rounds for {} prefills",
            m.prefill_calls,
            m.prefills
        );
        assert!(m.mean_prefill_batch() > 1.0, "occupancy {}", m.mean_prefill_batch());
        assert!(m.prefill_batch_us.count() >= 1);
    }

    #[test]
    fn decode_never_stalls_more_than_one_prefill_batch() {
        // admissions arriving while a sequence decodes must interleave:
        // one prefill bucket, then a decode step, never two admission
        // rounds back-to-back while decodable work is pending
        let mut model = MockModel::new(8, 256, vec![1, 2, 4]);
        model.prefill_buckets = vec![1, 2];
        model.prefill_delay = Duration::from_millis(2);
        model.decode_delay = Duration::from_millis(1);
        let log = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        model.event_log = Some(log.clone());
        let server = Server::start(move || Ok(Box::new(model) as _), test_cfg(8)).unwrap();

        // get one sequence decoding before the flood
        let rx0 = server.submit_streaming(
            b"a",
            GenParams { max_new_tokens: 24, ..Default::default() },
        );
        let _first = rx0.recv_timeout(Duration::from_secs(5)).unwrap();
        let rxs: Vec<_> = (0..6)
            .map(|_| {
                server.submit(
                    b"b",
                    GenParams { max_new_tokens: 12, ..Default::default() },
                )
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        while let Ok(ev) = rx0.recv_timeout(Duration::from_secs(10)) {
            if matches!(ev, StreamEvent::Done(_)) {
                break;
            }
        }
        server.shutdown();

        let log = log.lock().unwrap();
        let first_decode = log
            .iter()
            .position(|&(k, _)| k == 'd')
            .expect("no decode event recorded");
        for w in log[first_decode..].windows(2) {
            assert!(
                !(w[0].0 == 'p' && w[1].0 == 'p'),
                "two prefill rounds back-to-back while decode work was pending: {:?}",
                &log[..]
            );
        }
    }

    #[test]
    fn is_alive_tracks_engine_thread_death() {
        let model = MockModel::new(8, 256, vec![1]);
        let server =
            Server::start(move || Ok(Box::new(model) as _), test_cfg(2)).unwrap();
        assert!(server.is_alive(), "fresh server must be live");
        server.shutdown();

        // a backend PANIC (not an Err) unwinds the engine thread; the
        // liveness probe is how the router learns a replica hard-died
        struct PanickingDecode(MockModel);
        impl ServeModel for PanickingDecode {
            fn prefill_len(&self) -> usize {
                self.0.prefill_len()
            }
            fn vocab(&self) -> usize {
                self.0.vocab()
            }
            fn decode_buckets(&self) -> &[usize] {
                self.0.decode_buckets()
            }
            fn prefill(&mut self, tokens: &[i32]) -> Result<(Vec<f32>, SeqState)> {
                self.0.prefill(tokens)
            }
            fn decode(
                &mut self,
                _seqs: &mut [(&mut SeqState, i32)],
            ) -> Result<Vec<Vec<f32>>> {
                panic!("synthetic replica death");
            }
        }
        let model = PanickingDecode(MockModel::new(8, 256, vec![1]));
        let server =
            Server::start(move || Ok(Box::new(model) as _), test_cfg(2)).unwrap();
        let rx = server.submit(b"a", GenParams { max_new_tokens: 5, ..Default::default() });
        // the reply channel dies WITH the thread: no response, just a
        // disconnect — exactly the signal the router's relay watches for
        assert!(rx.recv_timeout(Duration::from_secs(10)).is_err());
        for _ in 0..200 {
            if !server.is_alive() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(!server.is_alive(), "panicked engine still reported live");
        // the metrics Arc outlives the thread
        assert_eq!(server.metrics().admitted, 1);
    }

    #[test]
    fn sampling_greedy_vs_temperature() {
        let logits = vec![0.0, 5.0, 1.0];
        let mut rng = Prng::new(1);
        assert_eq!(sample(&logits, 0.0, &mut rng), 1);
        // hot temperature must eventually pick something else
        let mut seen_other = false;
        for _ in 0..200 {
            if sample(&logits, 5.0, &mut rng) != 1 {
                seen_other = true;
                break;
            }
        }
        assert!(seen_other);
    }

    #[test]
    fn sampling_survives_nan_logits() {
        let mut rng = Prng::new(7);
        // a poisoned lane is skipped, not crowned argmax (and not a panic)
        assert_eq!(sample(&[1.0, f32::NAN, 0.5], 0.0, &mut rng), 0);
        assert_eq!(sample(&[f32::NAN, 2.0, 3.0], 0.0, &mut rng), 2);
        // fully-poisoned logits degrade to token 0 instead of killing the
        // engine thread
        assert_eq!(sample(&[f32::NAN, f32::NAN], 0.0, &mut rng), 0);
        // temperature sampling over NaN weights falls back to greedy
        let t = sample(&[1.0, f32::NAN, 0.5], 0.7, &mut rng);
        assert_eq!(t, 0);
        let all_nan = sample(&[f32::NAN, f32::NAN], 0.7, &mut rng);
        assert_eq!(all_nan, 0);
    }

    #[test]
    fn prefill_continuity_through_decode() {
        // mock state stores last token; ensure decode uses the right state
        // even when many sequences interleave with different prompts
        let model = MockModel::new(8, 256, vec![1, 2]);
        let server = Server::start(move || Ok(Box::new(model) as _), test_cfg(4)).unwrap();
        let rx_a = server.submit(b"A", GenParams { max_new_tokens: 3, ..Default::default() }); // 'A'=65
        let rx_b = server.submit(b"Q", GenParams { max_new_tokens: 3, ..Default::default() }); // 'Q'=81
        let ra = rx_a.recv_timeout(Duration::from_secs(5)).unwrap();
        let rb = rx_b.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(ra.generated, vec![66, 67, 68]);
        assert_eq!(rb.generated, vec![82, 83, 84]);
        server.shutdown();
    }
}
