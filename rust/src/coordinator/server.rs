//! The serving engine loop: admission -> prefill -> bucketed batched
//! decode -> completion, on a dedicated worker thread.
//!
//! Python never appears here (XAMBA's Step-1 promise): the loop drives
//! pre-compiled PJRT executables (or a mock in tests) with plain channels
//! for ingress/egress. Prefill is prioritized whenever a state slot is
//! free (new requests reach their first token fast); otherwise all
//! decodable sequences advance one step in the largest compiled bucket.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ServeConfig;
use crate::util::Prng;

use super::batcher::{plan, RoundRobin};
use super::metrics::Metrics;
use super::model::ServeModel;
use super::request::{FinishReason, GenParams, Request, RequestId, Response, StreamEvent};
use super::state_cache::{SlotId, StateCache};
use super::tokenizer::Tokenizer;

/// How a request wants its output delivered.
enum Reply {
    Final(Sender<Response>),
    Stream(Sender<StreamEvent>),
}

impl Reply {
    /// Deliver a newly-sampled token; false = client gone (cancel).
    fn push_token(&self, tok: u8) -> bool {
        match self {
            Reply::Final(_) => true,
            Reply::Stream(tx) => tx.send(StreamEvent::Token(tok)).is_ok(),
        }
    }

    fn finish(&self, resp: Response) {
        match self {
            Reply::Final(tx) => {
                let _ = tx.send(resp);
            }
            Reply::Stream(tx) => {
                let _ = tx.send(StreamEvent::Done(resp));
            }
        }
    }
}

enum Msg {
    Submit(Request, Reply),
    Shutdown,
}

struct ActiveSeq {
    id: RequestId,
    slot: SlotId,
    last_token: i32,
    generated: Vec<i32>,
    prompt: Vec<u8>,
    /// Encoded prompt tokens — the completion-promotion key prefix for
    /// the prefix cache (prompt ++ generated tokens the state absorbed).
    prompt_tokens: Vec<i32>,
    params: GenParams,
    arrived: Instant,
    first_token_at: Instant,
    reply: Reply,
    rng: Prng,
    batch_trace: Vec<usize>,
}

/// Handle to a running server; dropping it (after `shutdown`) joins the
/// worker thread.
pub struct Server {
    tx: Sender<Msg>,
    worker: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Server {
    /// Start the engine loop; the model backend is constructed INSIDE the
    /// engine thread (PJRT clients are not `Send`). Fails fast if the
    /// factory fails (e.g. missing artifacts).
    pub fn start<F>(factory: F, cfg: ServeConfig) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn ServeModel>> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let m2 = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("xamba-engine".into())
            .spawn(move || {
                let model = match factory() {
                    Ok(m) => {
                        let _ = ready_tx.send(Ok(()));
                        m
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                engine_loop(model, cfg, rx, m2)
            })
            .expect("spawn engine");
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;
        Ok(Server {
            tx,
            worker: Some(worker),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
        })
    }

    fn make_request(&self, prompt: &[u8], params: GenParams) -> Request {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Request { id, prompt: prompt.to_vec(), params, arrived: Instant::now() }
    }

    /// Submit a prompt; returns a receiver for the final response.
    pub fn submit(&self, prompt: &[u8], params: GenParams) -> Receiver<Response> {
        let (reply_tx, reply_rx) = channel();
        let req = self.make_request(prompt, params);
        // a send error means the engine already shut down; the receiver
        // will simply report disconnection to the caller
        let _ = self.tx.send(Msg::Submit(req, Reply::Final(reply_tx)));
        reply_rx
    }

    /// Submit a prompt for STREAMING delivery: every sampled byte arrives
    /// as `StreamEvent::Token` immediately; dropping the receiver cancels
    /// the request at the next decode step (slot reclaimed).
    pub fn submit_streaming(
        &self,
        prompt: &[u8],
        params: GenParams,
    ) -> Receiver<StreamEvent> {
        let (reply_tx, reply_rx) = channel();
        let req = self.make_request(prompt, params);
        let _ = self.tx.send(Msg::Submit(req, Reply::Stream(reply_tx)));
        reply_rx
    }

    /// Snapshot of the aggregated metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Stop accepting work and join the loop (in-flight work completes).
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Sample a token from logits: greedy at temperature 0, else softmax.
pub fn sample(logits: &[f32], temperature: f32, rng: &mut Prng) -> i32 {
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(0);
    }
    let inv_t = 1.0 / temperature;
    let mx = logits.iter().cloned().fold(f32::MIN, f32::max);
    let weights: Vec<f32> = logits.iter().map(|&l| ((l - mx) * inv_t).exp()).collect();
    let total: f32 = weights.iter().sum();
    let mut u = rng.uniform() * total;
    for (i, w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i as i32;
        }
    }
    (logits.len() - 1) as i32
}

fn engine_loop(
    mut model: Box<dyn ServeModel>,
    cfg: ServeConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
) {
    // The truncation window follows the backend: chunked-prefill models
    // accept whole long prompts, window-bound models truncate as before.
    let tokenizer = Tokenizer::new(model.max_prompt_len(), model.vocab());
    let mut cache = StateCache::new(cfg.max_slots);
    if model.resume_grain() > 0 && cfg.prefix_cache_mb > 0 {
        // Namespace the rolling hash by everything that changes the
        // numerics: a cached state must never resume under a different
        // model, rewrite variant, or serving dtype.
        cache = cache.with_prefix(
            cfg.prefix_cache_mb * 1024 * 1024,
            &format!("{}:{}:{}", cfg.model, cfg.variant, cfg.dtype),
        );
    }
    let mut waiting: VecDeque<(Request, Reply)> = VecDeque::new();
    let mut active: Vec<ActiveSeq> = Vec::new();
    let mut rr = RoundRobin::default();
    let mut shutting_down = false;

    loop {
        // --- ingress ------------------------------------------------------
        loop {
            match rx.try_recv() {
                Ok(Msg::Submit(req, reply)) => {
                    let mut m = metrics.lock().unwrap();
                    if waiting.len() >= cfg.queue_cap {
                        m.rejected += 1;
                        drop(m);
                        reply.finish(Response {
                            id: req.id,
                            prompt: req.prompt,
                            generated: vec![],
                            finish: FinishReason::Rejected,
                            ttft_us: 0.0,
                            e2e_us: 0.0,
                            batch_trace: vec![],
                        });
                    } else {
                        m.admitted += 1;
                        drop(m);
                        waiting.push_back((req, reply));
                    }
                }
                Ok(Msg::Shutdown) => shutting_down = true,
                Err(_) => break,
            }
        }
        if shutting_down && waiting.is_empty() && active.is_empty() {
            return;
        }

        // --- resume / long-prompt admission (single-sequence round) --------
        //
        // Runs before the batched round: a request whose encoding extends
        // a cached prefix resumes from the snapshot and prefills only its
        // new suffix (O(new tokens), not O(history)), and a prompt longer
        // than one compiled window streams through the chunked-prefill
        // path with bounded arena memory. Either admits alone — a resume
        // suffix rarely shares a length-class — counts as this iteration's
        // one admission round, and falls through to decode below.
        let mut resumed_round = false;
        if cache.has_free() && !waiting.is_empty() && model.resume_grain() > 0 {
            let (min_len, window) = model.prefill_len_range();
            let enc = tokenizer.encode_ranged(&waiting[0].0.prompt, min_len);
            let hit = cache.prefix_lookup(&enc);
            {
                let mut m = metrics.lock().unwrap();
                m.prefix_hits = cache.prefix_hits;
                m.prefix_misses = cache.prefix_misses;
            }
            if hit.is_some() || enc.len() > window {
                resumed_round = true;
                let (req, reply) = waiting.pop_front().expect("peeked above");
                let (matched, resume_state) = match hit {
                    Some((n, s)) => (n, Some(s)),
                    None => (0, None),
                };
                let t0 = Instant::now();
                let mut chunks = 0u64;
                let mut chunk_t = Instant::now();
                let mut chunk_us: Vec<f64> = Vec::new();
                let result = {
                    let cache = &mut cache;
                    // chunk-boundary checkpoints feed the prefix cache,
                    // keyed by the full token prefix the state absorbed
                    let mut checkpoint =
                        |consumed: usize, state: &super::model::SeqState| {
                            cache.prefix_insert(&enc[..matched + consumed], state);
                            chunks += 1;
                            chunk_us.push(chunk_t.elapsed().as_micros() as f64);
                            chunk_t = Instant::now();
                        };
                    model.prefill_resume(
                        &enc[matched..],
                        resume_state.as_ref(),
                        &mut checkpoint,
                    )
                };
                chunks += 1; // the final (uncheckpointed) chunk
                chunk_us.push(chunk_t.elapsed().as_micros() as f64);
                let round_us = t0.elapsed().as_micros() as f64;
                match result {
                    Ok((logits, state)) => {
                        // retain the full-prompt state so the NEXT turn
                        // (this prompt ++ reply ++ new text) resumes here
                        cache.prefix_insert(&enc, &state);
                        let slot = cache.alloc(state).expect("gated on has_free");
                        let now = Instant::now();
                        let mut rng = Prng::new(req.params.seed ^ req.id);
                        let tok = sample(&logits, req.params.temperature, &mut rng);
                        {
                            let mut m = metrics.lock().unwrap();
                            m.prefill_calls += 1;
                            m.prefill_batched_seqs += 1;
                            m.prefill_batch_us.record_us(round_us);
                            m.prefills += 1;
                            m.tokens_out += 1;
                            m.resumed_tokens += matched as u64;
                            m.prefill_chunks += chunks;
                            for &us in &chunk_us {
                                m.prefill_chunk_us.record_us(us);
                            }
                            m.prefix_evicted = cache.prefix_evicted;
                            m.ttft_us.record_us(
                                now.duration_since(req.arrived).as_micros() as f64,
                            );
                        }
                        if !reply.push_token(tok.clamp(0, 255) as u8) {
                            cache.release(slot);
                            let mut m = metrics.lock().unwrap();
                            m.cancelled += 1;
                        } else {
                            active.push(ActiveSeq {
                                id: req.id,
                                slot,
                                last_token: tok,
                                generated: vec![tok],
                                prompt: req.prompt,
                                prompt_tokens: enc,
                                params: req.params,
                                arrived: req.arrived,
                                first_token_at: now,
                                reply,
                                rng,
                                batch_trace: Vec::new(),
                            });
                        }
                    }
                    Err(e) => {
                        eprintln!(
                            "resumed prefill failed for request {}: {e:#}",
                            req.id
                        );
                        reply.finish(Response {
                            id: req.id,
                            prompt: req.prompt,
                            generated: vec![],
                            finish: FinishReason::Rejected,
                            ttft_us: 0.0,
                            e2e_us: 0.0,
                            batch_trace: vec![],
                        });
                    }
                }
            }
        }

        // --- prefill: one batched admission round --------------------------
        //
        // At most ONE prefill bucket runs per loop iteration, then control
        // falls through to decode — so admissions arriving while sequences
        // decode can never stall the decode loop by more than one prefill
        // batch. Waiting requests are grouped into the front request's
        // length-class (equal encoded token counts — no prompt is ever
        // padded to batch it with a longer one); the class's leftover
        // stays queued and drains on later rounds, down to per-sequence
        // remainder batches.
        if !resumed_round && cache.has_free() && !waiting.is_empty() {
            let min_len = model.prefill_len_range().0;
            let enc_len = |prompt: &[u8]| tokenizer.encoded_len(prompt, min_len);
            let free = cache.capacity() - cache.in_use();
            let cap = model
                .prefill_buckets()
                .last()
                .copied()
                .unwrap_or(1)
                .min(free)
                .max(1);
            let class = enc_len(&waiting[0].0.prompt);
            let mut take: Vec<usize> = vec![0];
            for i in 1..waiting.len() {
                if take.len() >= cap {
                    break;
                }
                if enc_len(&waiting[i].0.prompt) == class {
                    take.push(i);
                }
            }
            // the largest compiled prefill bucket the class fills now
            let b = plan(model.prefill_buckets(), take.len()).bucket.max(1);
            take.truncate(b);
            let mut batch: Vec<(Request, Reply)> = Vec::with_capacity(b);
            for &i in take.iter().rev() {
                batch.push(waiting.remove(i).expect("selected index in range"));
            }
            batch.reverse();
            let tokens: Vec<Vec<i32>> = batch
                .iter()
                .map(|(req, _)| tokenizer.encode_ranged(&req.prompt, min_len))
                .collect();
            let token_refs: Vec<&[i32]> = tokens.iter().map(|t| t.as_slice()).collect();
            let t0 = Instant::now();
            // a failed BATCH retries each request alone, so one broken
            // (bucket, length-class) graph — or one poison request —
            // keeps the blast radius of the old per-request path: only
            // the sequence that actually fails gets rejected
            let mut fell_back = false;
            let results: Vec<Result<(Vec<f32>, super::model::SeqState)>> =
                match model.prefill_batched(&token_refs) {
                    Ok(rs) => rs.into_iter().map(Ok).collect(),
                    Err(e) => {
                        eprintln!(
                            "batched prefill failed for {} requests: {e:#}; \
                             retrying per-sequence",
                            batch.len()
                        );
                        fell_back = true;
                        token_refs.iter().map(|t| model.prefill(t)).collect()
                    }
                };
            let round_us = t0.elapsed().as_micros() as f64;
            let now = Instant::now();
            {
                let mut m = metrics.lock().unwrap();
                // a serial fallback counts as one round PER sequence, so
                // mean_prefill_batch honestly drops to 1.0 exactly when
                // batching is broken instead of masking it
                let rounds = if fell_back { batch.len() as u64 } else { 1 };
                m.prefill_calls += rounds;
                m.prefill_batched_seqs += batch.len() as u64;
                m.prefill_batch_us.record_us(round_us);
            }
            for (((req, reply), result), toks) in
                batch.into_iter().zip(results).zip(tokens)
            {
                let (logits, state) = match result {
                    Ok(r) => r,
                    Err(e) => {
                        eprintln!("prefill failed for request {}: {e:#}", req.id);
                        reply.finish(Response {
                            id: req.id,
                            prompt: req.prompt,
                            generated: vec![],
                            finish: FinishReason::Rejected,
                            ttft_us: 0.0,
                            e2e_us: 0.0,
                            batch_trace: vec![],
                        });
                        continue;
                    }
                };
                let slot = cache.alloc(state).expect("round capped at free slots");
                let mut rng = Prng::new(req.params.seed ^ req.id);
                let tok = sample(&logits, req.params.temperature, &mut rng);
                {
                    let mut m = metrics.lock().unwrap();
                    m.prefills += 1;
                    m.tokens_out += 1;
                    m.ttft_us
                        .record_us(now.duration_since(req.arrived).as_micros() as f64);
                }
                if !reply.push_token(tok.clamp(0, 255) as u8) {
                    // client vanished before the first token
                    cache.release(slot);
                    let mut m = metrics.lock().unwrap();
                    m.cancelled += 1;
                    continue;
                }
                active.push(ActiveSeq {
                    id: req.id,
                    slot,
                    last_token: tok,
                    generated: vec![tok],
                    prompt: req.prompt,
                    prompt_tokens: toks,
                    params: req.params,
                    arrived: req.arrived,
                    first_token_at: now,
                    reply,
                    rng,
                    batch_trace: Vec::new(),
                });
            }
            // NO `continue`: fall through so pending decodes advance
            // between admission rounds (the interleave invariant).
        }

        // --- batched decode --------------------------------------------------
        if !active.is_empty() {
            let p = plan(model.decode_buckets(), active.len());
            if p.bucket > 0 {
                let idxs: Vec<usize> = rr.select(
                    &(0..active.len()).collect::<Vec<_>>(),
                    p.bucket,
                );
                let t0 = Instant::now();
                let slots: Vec<SlotId> = idxs.iter().map(|&i| active[i].slot).collect();
                let states = cache.get_many_mut(&slots);
                let mut seqs: Vec<(&mut super::model::SeqState, i32)> = states
                    .into_iter()
                    .zip(idxs.iter().map(|&i| active[i].last_token))
                    .collect();
                match model.decode(&mut seqs) {
                    Ok(all_logits) => {
                        drop(seqs);
                        let step_us = t0.elapsed().as_micros() as f64;
                        {
                            let mut m = metrics.lock().unwrap();
                            m.decode_calls += 1;
                            m.decode_batched_seqs += idxs.len() as u64;
                            m.tokens_out += idxs.len() as u64;
                            m.per_token_us.record_us(step_us / idxs.len() as f64);
                            m.decode_batch_us.record_us(step_us);
                        }
                        let mut finished: Vec<usize> = Vec::new();
                        let mut cancelled: Vec<usize> = Vec::new();
                        for (logits, &i) in all_logits.iter().zip(&idxs) {
                            let seq = &mut active[i];
                            let tok = sample(
                                logits,
                                seq.params.temperature,
                                &mut seq.rng,
                            );
                            seq.last_token = tok;
                            seq.generated.push(tok);
                            seq.batch_trace.push(idxs.len());
                            if !seq.reply.push_token(tok.clamp(0, 255) as u8) {
                                cancelled.push(i);
                                continue;
                            }
                            let hit_stop = seq
                                .params
                                .stop_byte
                                .map(|b| tok == b as i32)
                                .unwrap_or(false);
                            if hit_stop || seq.generated.len() >= seq.params.max_new_tokens
                            {
                                finished.push(i);
                            }
                        }
                        // reclaim cancelled slots first (no response owed)
                        cancelled.sort_unstable_by(|a, b| b.cmp(a));
                        for i in cancelled {
                            let seq = active.swap_remove(i);
                            cache.release(seq.slot);
                            let mut m = metrics.lock().unwrap();
                            m.cancelled += 1;
                            // indices in `finished` past i shift; rebuild
                            finished.retain(|&f| f != i);
                            for f in finished.iter_mut() {
                                if *f == active.len() {
                                    *f = i; // swap_remove moved last into i
                                }
                            }
                        }
                        // retire finished (descending index for swap_remove)
                        finished.sort_unstable_by(|a, b| b.cmp(a));
                        for i in finished {
                            let seq = active.swap_remove(i);
                            let final_state = cache.release(seq.slot);
                            // promote the finished state to the prefix
                            // tier: it has absorbed the prompt plus every
                            // generated token EXCEPT the last sample
                            // (never fed back through decode), so the
                            // next turn of this conversation resumes it
                            // decode-exactly. Cancels and failures are
                            // not promoted; neither is a sequence whose
                            // absorbed tokens fall outside the byte
                            // alphabet (its next-turn prompt would
                            // re-encode them differently than the state
                            // actually saw them).
                            let absorbed =
                                &seq.generated[..seq.generated.len() - 1];
                            if cache.prefix_enabled()
                                && absorbed.iter().all(|&t| (0..=255).contains(&t))
                            {
                                let mut key = seq.prompt_tokens.clone();
                                key.extend_from_slice(absorbed);
                                cache.prefix_insert(&key, &final_state);
                                let mut m = metrics.lock().unwrap();
                                m.prefix_evicted = cache.prefix_evicted;
                            }
                            let now = Instant::now();
                            let e2e =
                                now.duration_since(seq.arrived).as_micros() as f64;
                            let finish = if seq
                                .params
                                .stop_byte
                                .map(|b| seq.last_token == b as i32)
                                .unwrap_or(false)
                            {
                                FinishReason::Stop
                            } else {
                                FinishReason::Length
                            };
                            {
                                let mut m = metrics.lock().unwrap();
                                m.completed += 1;
                                m.e2e_us.record_us(e2e);
                            }
                            seq.reply.finish(Response {
                                id: seq.id,
                                prompt: seq.prompt,
                                generated: seq
                                    .generated
                                    .iter()
                                    .map(|&t| t.clamp(0, 255) as u8)
                                    .collect(),
                                finish,
                                ttft_us: seq
                                    .first_token_at
                                    .duration_since(seq.arrived)
                                    .as_micros() as f64,
                                e2e_us: e2e,
                                batch_trace: seq.batch_trace,
                            });
                        }
                        continue;
                    }
                    Err(e) => {
                        eprintln!("decode step failed: {e:#}; dropping batch");
                        drop(seqs);
                        let mut sorted = idxs.clone();
                        sorted.sort_unstable_by(|a, b| b.cmp(a));
                        for i in sorted {
                            let seq = active.swap_remove(i);
                            cache.release(seq.slot);
                            // tell the client instead of letting it stare
                            // at a dead channel until its recv times out
                            let now = Instant::now();
                            {
                                let mut m = metrics.lock().unwrap();
                                m.failed += 1;
                            }
                            seq.reply.finish(Response {
                                id: seq.id,
                                prompt: seq.prompt,
                                generated: seq
                                    .generated
                                    .iter()
                                    .map(|&t| t.clamp(0, 255) as u8)
                                    .collect(),
                                finish: FinishReason::Failed,
                                ttft_us: seq
                                    .first_token_at
                                    .duration_since(seq.arrived)
                                    .as_micros() as f64,
                                e2e_us: now.duration_since(seq.arrived).as_micros()
                                    as f64,
                                batch_trace: seq.batch_trace,
                            });
                        }
                        continue;
                    }
                }
            }
        }

        // --- idle ------------------------------------------------------------
        if shutting_down {
            continue; // drain remaining work without blocking
        }
        match rx.recv_timeout(Duration::from_micros(cfg.batch_wait_us.max(100))) {
            Ok(Msg::Submit(req, reply)) => {
                let mut m = metrics.lock().unwrap();
                if waiting.len() >= cfg.queue_cap {
                    m.rejected += 1;
                } else {
                    m.admitted += 1;
                    drop(m);
                    waiting.push_back((req, reply));
                }
            }
            Ok(Msg::Shutdown) => shutting_down = true,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => shutting_down = true,
        }
    }
}

/// Convenience: start a server over the PJRT artifacts.
pub fn start_pjrt(cfg: &ServeConfig) -> Result<Server> {
    let c = cfg.clone();
    Server::start(
        move || {
            Ok(Box::new(super::model::PjrtServeModel::load_with_buckets(
                &c.artifacts_dir,
                &c.model,
                &c.variant,
                Some(&c.decode_buckets),
            )?) as Box<dyn ServeModel>)
        },
        cfg.clone(),
    )
}

/// Convenience: start a server on the planned executor (no PJRT, no
/// artifacts required). The model — graphs, cached plans, and the
/// execution pool — is constructed and owned inside the engine thread;
/// shutdown drops it there, which joins the pool's workers.
pub fn start_planned(cfg: &ServeConfig) -> Result<Server> {
    let c = cfg.clone();
    Server::start(
        move || {
            Ok(Box::new(super::model::PlannedServeModel::from_config(&c)?)
                as Box<dyn ServeModel>)
        },
        cfg.clone(),
    )
}

/// Start the backend `cfg.backend` selects ("planned" | "pjrt").
///
/// Validates the config first ([`ServeConfig::validate`]): an unknown
/// backend/model/variant string fails here with one actionable message
/// instead of panicking (or erroring obscurely) inside the engine thread.
pub fn start_backend(cfg: &ServeConfig) -> Result<Server> {
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    match cfg.backend.as_str() {
        "" | "planned" => start_planned(cfg),
        "pjrt" => start_pjrt(cfg),
        // validate() already rejected everything else; keep a real error
        // (not a panic) so the two admitted-sets can never drift apart
        other => Err(anyhow::anyhow!(
            "unknown serve backend {other:?} (want \"planned\" or \"pjrt\")"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::model::MockModel;

    fn test_cfg(slots: usize) -> ServeConfig {
        ServeConfig {
            max_slots: slots,
            queue_cap: 16,
            batch_wait_us: 100,
            ..Default::default()
        }
    }

    #[test]
    fn single_request_counts_up() {
        let model = MockModel::new(8, 256, vec![1, 2, 4]);
        let server = Server::start(move || Ok(Box::new(model) as _), test_cfg(4)).unwrap();
        let rx = server.submit(
            b"a", // 'a' = 97
            GenParams { max_new_tokens: 5, ..Default::default() },
        );
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        // mock predicts last+1 each step: 98, 99, 100, 101, 102 = "bcdef"
        assert_eq!(resp.generated, b"bcdef");
        assert_eq!(resp.finish, FinishReason::Length);
        assert!(resp.ttft_us >= 0.0 && resp.e2e_us >= resp.ttft_us);
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.tokens_out, 5);
    }

    #[test]
    fn stop_byte_ends_generation_early() {
        let model = MockModel::new(8, 256, vec![1]);
        let server = Server::start(move || Ok(Box::new(model) as _), test_cfg(2)).unwrap();
        let rx = server.submit(
            b"a",
            GenParams {
                max_new_tokens: 50,
                stop_byte: Some(b'd'), // 100
                ..Default::default()
            },
        );
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.finish, FinishReason::Stop);
        assert_eq!(resp.generated, b"bcd");
        server.shutdown();
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let model = MockModel::new(8, 256, vec![1, 2, 4]);
        let server = Server::start(move || Ok(Box::new(model) as _), test_cfg(8)).unwrap();
        let rxs: Vec<_> = (0..4)
            .map(|_| {
                server.submit(
                    b"x",
                    GenParams { max_new_tokens: 20, ..Default::default() },
                )
            })
            .collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(r.generated.len(), 20);
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 4);
        // with 4 concurrent sequences, decode must have used batches > 1
        assert!(
            m.mean_decode_batch() > 1.5,
            "mean batch {}",
            m.mean_decode_batch()
        );
    }

    #[test]
    fn queue_overflow_rejects() {
        // 1 slot + tiny queue: flood and count rejections
        let mut model = MockModel::new(8, 256, vec![1]);
        model.decode_delay = Duration::from_millis(2);
        let cfg = ServeConfig {
            max_slots: 1,
            queue_cap: 2,
            batch_wait_us: 100,
            ..Default::default()
        };
        let server = Server::start(move || Ok(Box::new(model) as _), cfg).unwrap();
        let rxs: Vec<_> = (0..12)
            .map(|_| {
                server.submit(
                    b"y",
                    GenParams { max_new_tokens: 30, ..Default::default() },
                )
            })
            .collect();
        let mut rejected = 0;
        let mut completed = 0;
        for rx in rxs {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(r) if r.finish == FinishReason::Rejected => rejected += 1,
                Ok(_) => completed += 1,
                Err(e) => panic!("lost response: {e}"),
            }
        }
        assert!(rejected > 0, "backpressure never triggered");
        assert_eq!(completed + rejected, 12);
        server.shutdown();
    }

    #[test]
    fn streaming_delivers_tokens_incrementally() {
        let model = MockModel::new(8, 256, vec![1, 2]);
        let server =
            Server::start(move || Ok(Box::new(model) as _), test_cfg(4)).unwrap();
        let rx = server.submit_streaming(
            b"a",
            GenParams { max_new_tokens: 4, ..Default::default() },
        );
        let mut tokens = Vec::new();
        let mut done = None;
        while let Ok(ev) = rx.recv_timeout(Duration::from_secs(5)) {
            match ev {
                StreamEvent::Token(t) => tokens.push(t),
                StreamEvent::Done(r) => {
                    done = Some(r);
                    break;
                }
            }
        }
        assert_eq!(tokens, b"bcde");
        let r = done.expect("no Done event");
        assert_eq!(r.generated, b"bcde");
        server.shutdown();
    }

    #[test]
    fn dropping_stream_receiver_cancels_and_frees_slot() {
        let mut model = MockModel::new(8, 256, vec![1]);
        model.decode_delay = Duration::from_millis(1);
        let server =
            Server::start(move || Ok(Box::new(model) as _), test_cfg(1)).unwrap();
        let rx = server.submit_streaming(
            b"a",
            GenParams { max_new_tokens: 10_000, ..Default::default() },
        );
        // read two tokens then walk away
        let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        drop(rx);
        // the single slot must be reclaimed: a new request completes
        let rx2 = server.submit(
            b"z",
            GenParams { max_new_tokens: 3, ..Default::default() },
        );
        let r = rx2.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(r.finish, FinishReason::Length);
        let m = server.shutdown();
        assert_eq!(m.cancelled, 1);
    }

    #[test]
    fn decode_failure_reports_failed_response() {
        use crate::coordinator::model::SeqState;

        // prefill succeeds (first token delivered), every decode errors
        struct FailingDecode(MockModel);
        impl ServeModel for FailingDecode {
            fn prefill_len(&self) -> usize {
                self.0.prefill_len()
            }
            fn vocab(&self) -> usize {
                self.0.vocab()
            }
            fn decode_buckets(&self) -> &[usize] {
                self.0.decode_buckets()
            }
            fn prefill(&mut self, tokens: &[i32]) -> Result<(Vec<f32>, SeqState)> {
                self.0.prefill(tokens)
            }
            fn decode(
                &mut self,
                _seqs: &mut [(&mut SeqState, i32)],
            ) -> Result<Vec<Vec<f32>>> {
                Err(anyhow::anyhow!("synthetic decode failure"))
            }
        }

        let model = FailingDecode(MockModel::new(8, 256, vec![1]));
        let server =
            Server::start(move || Ok(Box::new(model) as _), test_cfg(2)).unwrap();
        let rx = server.submit(
            b"a",
            GenParams { max_new_tokens: 5, ..Default::default() },
        );
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.finish, FinishReason::Failed);
        assert_eq!(resp.generated, b"b", "the prefill token was already delivered");
        let m = server.shutdown();
        assert_eq!(m.failed, 1);
        assert_eq!(m.completed, 0);
    }

    #[test]
    fn concurrent_admissions_prefill_in_batches() {
        // a slow prefill lets the queue build up; the admission loop must
        // then batch the backlog instead of prefilling one-by-one
        let mut model = MockModel::new(8, 256, vec![1, 2, 4]);
        model.prefill_buckets = vec![1, 2, 4];
        model.prefill_delay = Duration::from_millis(5);
        let server = Server::start(move || Ok(Box::new(model) as _), test_cfg(8)).unwrap();
        let rxs: Vec<_> = (0..5)
            .map(|_| {
                server.submit(
                    b"q",
                    GenParams { max_new_tokens: 4, ..Default::default() },
                )
            })
            .collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(r.finish, FinishReason::Length);
        }
        let m = server.shutdown();
        assert_eq!(m.prefills, 5);
        assert!(
            m.prefill_calls < m.prefills,
            "admissions never batched: {} rounds for {} prefills",
            m.prefill_calls,
            m.prefills
        );
        assert!(m.mean_prefill_batch() > 1.0, "occupancy {}", m.mean_prefill_batch());
        assert!(m.prefill_batch_us.count() >= 1);
    }

    #[test]
    fn decode_never_stalls_more_than_one_prefill_batch() {
        // admissions arriving while a sequence decodes must interleave:
        // one prefill bucket, then a decode step, never two admission
        // rounds back-to-back while decodable work is pending
        let mut model = MockModel::new(8, 256, vec![1, 2, 4]);
        model.prefill_buckets = vec![1, 2];
        model.prefill_delay = Duration::from_millis(2);
        model.decode_delay = Duration::from_millis(1);
        let log = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        model.event_log = Some(log.clone());
        let server = Server::start(move || Ok(Box::new(model) as _), test_cfg(8)).unwrap();

        // get one sequence decoding before the flood
        let rx0 = server.submit_streaming(
            b"a",
            GenParams { max_new_tokens: 24, ..Default::default() },
        );
        let _first = rx0.recv_timeout(Duration::from_secs(5)).unwrap();
        let rxs: Vec<_> = (0..6)
            .map(|_| {
                server.submit(
                    b"b",
                    GenParams { max_new_tokens: 12, ..Default::default() },
                )
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        while let Ok(ev) = rx0.recv_timeout(Duration::from_secs(10)) {
            if matches!(ev, StreamEvent::Done(_)) {
                break;
            }
        }
        server.shutdown();

        let log = log.lock().unwrap();
        let first_decode = log
            .iter()
            .position(|&(k, _)| k == 'd')
            .expect("no decode event recorded");
        for w in log[first_decode..].windows(2) {
            assert!(
                !(w[0].0 == 'p' && w[1].0 == 'p'),
                "two prefill rounds back-to-back while decode work was pending: {:?}",
                &log[..]
            );
        }
    }

    #[test]
    fn sampling_greedy_vs_temperature() {
        let logits = vec![0.0, 5.0, 1.0];
        let mut rng = Prng::new(1);
        assert_eq!(sample(&logits, 0.0, &mut rng), 1);
        // hot temperature must eventually pick something else
        let mut seen_other = false;
        for _ in 0..200 {
            if sample(&logits, 5.0, &mut rng) != 1 {
                seen_other = true;
                break;
            }
        }
        assert!(seen_other);
    }

    #[test]
    fn prefill_continuity_through_decode() {
        // mock state stores last token; ensure decode uses the right state
        // even when many sequences interleave with different prompts
        let model = MockModel::new(8, 256, vec![1, 2]);
        let server = Server::start(move || Ok(Box::new(model) as _), test_cfg(4)).unwrap();
        let rx_a = server.submit(b"A", GenParams { max_new_tokens: 3, ..Default::default() }); // 'A'=65
        let rx_b = server.submit(b"Q", GenParams { max_new_tokens: 3, ..Default::default() }); // 'Q'=81
        let ra = rx_a.recv_timeout(Duration::from_secs(5)).unwrap();
        let rb = rx_b.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(ra.generated, vec![66, 67, 68]);
        assert_eq!(rb.generated, vec![82, 83, 84]);
        server.shutdown();
    }
}
