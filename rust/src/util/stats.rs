//! Streaming statistics and latency histograms for benches and metrics.

/// Summary statistics over a sample of f64 values.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a full summary (sorts a copy of the data).
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self::default();
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 50.0),
            p90: percentile(&sorted, 90.0),
            p99: percentile(&sorted, 99.0),
        }
    }
}

/// Percentile of an ascending-sorted slice by linear interpolation.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Log-bucketed latency histogram (~4 % relative resolution), constant
/// memory, lock-free-friendly (callers wrap in a mutex or per-thread copy).
///
/// Buckets span 1 µs .. ~70 s; used by the coordinator's metrics and the
/// bench harness for p50/p99 reporting without storing every sample.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum_us: f64,
}

const BUCKETS_PER_DECADE: usize = 54; // ln-spaced, ~4.35% per step
const DECADES: usize = 8; // 1us .. 1e8us

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; BUCKETS_PER_DECADE * DECADES],
            total: 0,
            sum_us: 0.0,
        }
    }

    fn bucket(us: f64) -> usize {
        if us <= 1.0 {
            return 0;
        }
        let idx = (us.ln() / 10f64.ln() * BUCKETS_PER_DECADE as f64) as usize;
        idx.min(BUCKETS_PER_DECADE * DECADES - 1)
    }

    fn bucket_value(idx: usize) -> f64 {
        10f64.powf((idx as f64 + 0.5) / BUCKETS_PER_DECADE as f64)
    }

    /// Record one latency observation in microseconds.
    pub fn record_us(&mut self, us: f64) {
        self.counts[Self::bucket(us)] += 1;
        self.total += 1;
        self.sum_us += us;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    /// Approximate percentile (bucket midpoint), in microseconds.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return Self::bucket_value(i);
            }
        }
        Self::bucket_value(self.counts.len() - 1)
    }

    /// Merge another histogram into this one (per-worker aggregation).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn histogram_percentiles_within_resolution() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        let p50 = h.percentile_us(50.0);
        assert!((p50 - 500.0).abs() / 500.0 < 0.06, "p50 {p50}");
        let p99 = h.percentile_us(99.0);
        assert!((p99 - 990.0).abs() / 990.0 < 0.06, "p99 {p99}");
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn histogram_merge_adds_counts() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_us(10.0);
        b.record_us(100.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }
}
