//! Minimal JSON parser + serializer (serde is not vendored).
//!
//! Parses the AOT `manifest.json` / `golden.json` written by
//! `python/compile/aot.py` and serializes bench reports. Supports the full
//! JSON grammar except `\u` surrogate pairs outside the BMP (unused by our
//! producers, which emit ASCII).

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the missing key name (manifest debugging).
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code).ok_or("surrogate \\u")?,
                            );
                        }
                        _ => return Err(format!("bad escape \\{}", e as char)),
                    }
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| "invalid utf-8")?;
                    out.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let src = r#"{"version": 1, "models": [{"name": "m", "shape": [64, 3], "ok": true, "x": null, "f": -1.5e2}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let m = &j.get("models").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.get("name").unwrap().as_str(), Some("m"));
        assert_eq!(m.get("f").unwrap().as_f64(), Some(-150.0));
        assert_eq!(m.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(*m.get("x").unwrap(), Json::Null);
    }

    #[test]
    fn string_escapes_round_trip() {
        let j = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"\\ A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn serializer_round_trips() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":false}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → 世界"));
    }
}
