//! Synthetic text corpus — same template/word distribution as
//! `python/compile/train.make_corpus` (different PRNG, same language), so
//! rust-side evaluation sees held-out text from the training distribution
//! and the serve demo can sample realistic prompts.

use super::prng::Prng;

pub const WORDS: [&str; 16] = [
    "state", "space", "models", "scan", "mamba", "npu", "kernel", "mask",
    "cumsum", "matmul", "vector", "chunk", "drain", "tile", "gate", "token",
];

pub const TEMPLATES: [&str; 5] = [
    "the {a} {b} runs on the {c} .",
    "a {a} maps the {b} to the {c} .",
    "every {a} needs a {b} and a {c} .",
    "{a} plus {b} gives {c} .",
    "fast {a} , slow {b} , tiny {c} .",
];

/// One sentence from the corpus language.
pub fn sentence(rng: &mut Prng) -> String {
    let t = TEMPLATES[rng.below(TEMPLATES.len())];
    let a = WORDS[rng.below(WORDS.len())];
    let b = WORDS[rng.below(WORDS.len())];
    let c = WORDS[rng.below(WORDS.len())];
    t.replace("{a}", a).replace("{b}", b).replace("{c}", c)
}

/// A corpus of `n` sentences joined by spaces (held-out eval text).
pub fn corpus(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Prng::new(seed);
    let mut out = String::new();
    for i in 0..n {
        if i > 0 {
            out.push(' ');
        }
        out.push_str(&sentence(&mut rng));
    }
    out.into_bytes()
}

/// A plausible prompt: a sentence prefix of 8..24 bytes.
pub fn prompt(rng: &mut Prng) -> Vec<u8> {
    let s = sentence(rng);
    let len = 8 + rng.below(17.min(s.len().saturating_sub(7)));
    s.as_bytes()[..len.min(s.len())].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_ascii_and_deterministic() {
        let a = corpus(50, 1);
        let b = corpus(50, 1);
        assert_eq!(a, b);
        assert!(a.iter().all(|&c| c.is_ascii()));
        assert!(a.len() > 500);
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(corpus(20, 1), corpus(20, 2));
    }

    #[test]
    fn prompts_are_short_prefixes() {
        let mut rng = Prng::new(7);
        for _ in 0..50 {
            let p = prompt(&mut rng);
            assert!(p.len() >= 8 && p.len() <= 24, "{}", p.len());
        }
    }
}
