//! Software IEEE-754 binary16 ("half") conversion.
//!
//! The build environment is fully offline (no `half` crate), so the f16
//! serving dtype stores raw half bits in `u16` and converts through
//! these two functions. Conversion is exact in the f16→f32 direction and
//! rounds to nearest-even in the f32→f16 direction — the same semantics
//! hardware fp16 units implement, so a future real-NPU backend can swap
//! in native halves without changing results.

/// Widen half bits to f32 (exact: every f16 value is representable).
#[inline]
pub fn f16_to_f32(bits: u16) -> f32 {
    let sign = u32::from(bits >> 15) << 31;
    let exp = u32::from((bits >> 10) & 0x1f);
    let frac = u32::from(bits & 0x3ff);
    let out = if exp == 0 {
        if frac == 0 {
            sign // signed zero
        } else {
            // subnormal half: value = frac * 2^-24; normalize into f32
            let shift = frac.leading_zeros() - 21; // frac has <= 10 bits
            let frac_n = (frac << shift) & 0x3ff;
            let exp_n = 127 - 15 - shift + 1;
            sign | (exp_n << 23) | (frac_n << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (frac << 13) // inf / nan (payload kept)
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(out)
}

/// Round an f32 to half bits, nearest-even; overflow goes to ±inf.
#[inline]
pub fn f32_to_f16(v: f32) -> u16 {
    let x = v.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let exp = ((x >> 23) & 0xff) as i32;
    let frac = x & 0x007f_ffff;

    if exp == 0xff {
        // inf / nan: keep a nan payload bit so nan stays nan
        let f = if frac == 0 { 0 } else { 0x200 | (frac >> 13) as u16 };
        return sign | 0x7c00 | f;
    }
    // unbiased exponent of the f32 value
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7c00; // overflows half range -> inf
    }
    if e >= -14 {
        // normal half: round the 23-bit fraction to 10 bits, nearest-even
        let mut mant = frac >> 13;
        let rem = frac & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (mant & 1) == 1) {
            mant += 1;
        }
        let mut he = (e + 15) as u32;
        if mant == 0x400 {
            mant = 0;
            he += 1;
            if he >= 0x1f {
                return sign | 0x7c00;
            }
        }
        return sign | ((he as u16) << 10) | (mant as u16);
    }
    if e < -25 {
        return sign; // underflows past the smallest subnormal -> signed 0
    }
    // subnormal half: implicit leading 1 joins the fraction, then shift
    let full = 0x0080_0000 | frac; // 24-bit significand
    let shift = (-14 - e) as u32 + 13; // bits dropped below the half lsb
    let mant = full >> shift;
    let rem = full & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let mut m = mant;
    if rem > half || (rem == half && (m & 1) == 1) {
        m += 1; // may carry into the exponent: 0x400 encodes the smallest normal
    }
    sign | m as u16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_round_trip() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.25] {
            let b = f32_to_f16(v);
            assert_eq!(f16_to_f32(b), v, "{v}");
        }
    }

    #[test]
    fn every_half_bit_pattern_round_trips() {
        // f16 -> f32 -> f16 must be the identity on all finite halves
        for bits in 0u16..=0xffff {
            let exp = (bits >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/nan handled below
            }
            let v = f16_to_f32(bits);
            assert_eq!(f32_to_f16(v), bits, "bits {bits:#06x} -> {v}");
        }
    }

    #[test]
    fn specials() {
        assert_eq!(f16_to_f32(0x7c00), f32::INFINITY);
        assert_eq!(f16_to_f32(0xfc00), f32::NEG_INFINITY);
        assert!(f16_to_f32(0x7e00).is_nan());
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // overflow saturates to inf
        assert_eq!(f32_to_f16(1e6), 0x7c00);
        assert_eq!(f32_to_f16(-1e6), 0xfc00);
        // underflow flushes to signed zero
        assert_eq!(f32_to_f16(1e-10), 0x0000);
        assert_eq!(f32_to_f16(-1e-10), 0x8000);
    }

    #[test]
    fn rounding_is_nearest_even() {
        // 1.0 + 2^-11 sits exactly between 1.0 and the next half
        // (1.0 + 2^-10): ties to even -> 1.0
        let tie = 1.0f32 + 2f32.powi(-11);
        assert_eq!(f16_to_f32(f32_to_f16(tie)), 1.0);
        // just above the tie rounds up
        let above = 1.0f32 + 2f32.powi(-11) + 2f32.powi(-20);
        assert_eq!(f16_to_f32(f32_to_f16(above)), 1.0 + 2f32.powi(-10));
    }

    #[test]
    fn subnormal_halves() {
        // smallest positive subnormal half = 2^-24
        let tiny = 2f32.powi(-24);
        assert_eq!(f32_to_f16(tiny), 0x0001);
        assert_eq!(f16_to_f32(0x0001), tiny);
        // largest subnormal
        let big_sub = f16_to_f32(0x03ff);
        assert_eq!(f32_to_f16(big_sub), 0x03ff);
        // rounding a subnormal up into the normal range
        let just_below_normal = 2f32.powi(-14) - 2f32.powi(-26);
        assert_eq!(f32_to_f16(just_below_normal), 0x0400);
    }
}
