//! Miniature property-testing framework (proptest is not vendored).
//!
//! `check` runs a property over `n` random cases drawn from a generator;
//! on failure it greedily shrinks the failing case with caller-provided
//! shrinkers before panicking with the minimal reproduction and the seed,
//! so failures are replayable (`XAMBA_QC_SEED=<n>` overrides the seed).

use super::prng::Prng;

/// Number of cases per property unless overridden.
pub const DEFAULT_CASES: usize = 64;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("XAMBA_QC_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xA1B2C3);
        Self { cases: DEFAULT_CASES, seed, max_shrink_steps: 200 }
    }
}

/// Run `prop` on `cases` inputs from `gen`; shrink failures via `shrink`.
///
/// `shrink` returns candidate *smaller* inputs; the first one that still
/// fails is adopted, repeating until fixpoint or the step budget runs out.
pub fn check_with<T, G, S, P>(cfg: &Config, mut gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Prng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Prng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // greedy shrink
            let mut best = input.clone();
            let mut best_msg = first_msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in shrink(&best) {
                    steps += 1;
                    if let Err(msg) = prop(&cand) {
                        best = cand;
                        best_msg = msg;
                        continue 'outer;
                    }
                    if steps >= cfg.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed}):\n  input: {best:?}\n  error: {best_msg}",
                seed = cfg.seed,
            );
        }
    }
}

/// `check_with` with default config and no shrinking.
pub fn check<T, G, P>(gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Prng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    check_with(&Config::default(), gen, |_| Vec::new(), prop);
}

/// Shrinker for a dimension-like usize: halves and decrements.
pub fn shrink_dim(n: usize, min: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if n > min {
        out.push(min.max(n / 2));
        out.push(n - 1);
    }
    out.dedup();
    out
}

/// Assert two f32 slices are elementwise close (returns Err for `check`).
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("idx {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(|r| r.below(100), |&n| {
            if n < 100 { Ok(()) } else { Err("out of range".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(|r| r.below(10), |&n| {
            if n < 5 { Ok(()) } else { Err(format!("{n} >= 5")) }
        });
    }

    #[test]
    fn shrink_finds_smaller_counterexample() {
        // property "n < 50" fails for n >= 50; shrinker should land near 50
        let cfg = Config { cases: 200, seed: 1, max_shrink_steps: 500 };
        let result = std::panic::catch_unwind(|| {
            check_with(
                &cfg,
                |r| r.below(1000),
                |&n| shrink_dim(n, 0),
                |&n| if n < 50 { Ok(()) } else { Err("too big".into()) },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // the minimal counterexample is exactly 50
        assert!(msg.contains("input: 50"), "shrunk message: {msg}");
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0], &[1.0001], 1e-3, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 0.0).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3, 0.0).is_err());
    }
}
