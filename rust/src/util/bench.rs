//! Perf-tracking plumbing for the CI `bench-smoke` gate.
//!
//! The serving benches (`benches/serve_decode.rs`, `benches/serve_prefill.rs`)
//! run in two modes: full reports for humans, and a quick mode
//! (`XAMBA_BENCH_QUICK=1`) for CI. When `XAMBA_BENCH_JSON=<path>` is set
//! they additionally merge their headline numbers (tokens/sec, TTFT)
//! into one flat JSON object — the `BENCH_pr.json` artifact — which
//! `xamba bench-check` then compares against the committed baseline,
//! failing the build on any regression beyond the tolerance.
//!
//! Metric keys carry their own direction: `*_per_s`, `*_ratio`, and
//! `*_rate` are higher-is-better, `*_ms` / `*_us` lower-is-better. A key
//! the baseline tracks but the bench no longer emits is an error, so
//! the gate cannot silently decay.

use std::collections::BTreeMap;

use super::json::Json;

/// CI quick mode: fewer iterations / smaller sweeps, same metric keys.
pub fn quick_mode() -> bool {
    std::env::var("XAMBA_BENCH_QUICK").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// Where to merge this bench's metrics, if anywhere.
pub fn metrics_path() -> Option<String> {
    std::env::var("XAMBA_BENCH_JSON").ok().filter(|s| !s.is_empty())
}

/// Merge `metrics` into the flat JSON object at `path` (created if
/// absent) — benches run sequentially in CI and accumulate one artifact.
pub fn record(path: &str, metrics: &[(String, f64)]) -> Result<(), String> {
    let mut obj = match std::fs::read_to_string(path) {
        Ok(src) => match Json::parse(&src)? {
            Json::Obj(m) => m,
            other => return Err(format!("{path}: expected a JSON object, got {other:?}")),
        },
        Err(_) => BTreeMap::new(),
    };
    for (k, v) in metrics {
        obj.insert(k.clone(), Json::Num(*v));
    }
    std::fs::write(path, Json::Obj(obj).to_string_compact())
        .map_err(|e| format!("write {path}: {e}"))
}

/// One baseline-vs-PR comparison.
#[derive(Clone, Debug)]
pub struct Check {
    pub key: String,
    pub baseline: f64,
    pub got: f64,
    /// Signed change in percent, oriented so positive = improvement.
    pub change_pct: f64,
    pub regressed: bool,
}

fn higher_is_better(key: &str) -> Result<bool, String> {
    if key.ends_with("_per_s") || key.ends_with("_ratio") || key.ends_with("_rate") {
        // throughputs, dimensionless multipliers (speculative speedup),
        // and hit/acceptance rates all regress downward
        Ok(true)
    } else if key.ends_with("_ms") || key.ends_with("_us") {
        Ok(false)
    } else {
        Err(format!(
            "metric {key:?} has no direction suffix \
             (want *_per_s, *_ratio, *_rate, *_ms, or *_us)"
        ))
    }
}

/// Compare every baseline metric against the PR metrics. `tolerance` is
/// the fractional regression allowed (0.20 = fail beyond 20%). Keys the
/// PR emits but the baseline does not track are ignored (new metrics
/// join the baseline when it is refreshed); keys the baseline tracks but
/// the PR file lacks are an error.
pub fn compare(pr: &Json, baseline: &Json, tolerance: f64) -> Result<Vec<Check>, String> {
    let base = match baseline {
        Json::Obj(m) => m,
        _ => return Err("baseline is not a JSON object".into()),
    };
    let mut out = Vec::with_capacity(base.len());
    for (key, bval) in base {
        let b = bval
            .as_f64()
            .ok_or_else(|| format!("baseline metric {key:?} is not a number"))?;
        let p = pr
            .get(key)
            .ok_or_else(|| format!("PR metrics no longer emit {key:?} — bench decayed?"))?
            .as_f64()
            .ok_or_else(|| format!("PR metric {key:?} is not a number"))?;
        let higher = higher_is_better(key)?;
        if b <= 0.0 {
            return Err(format!("baseline metric {key:?} must be positive, got {b}"));
        }
        let (regressed, change_pct) = if higher {
            (p < b * (1.0 - tolerance), (p - b) / b * 100.0)
        } else {
            (p > b * (1.0 + tolerance), (b - p) / b * 100.0)
        };
        out.push(Check { key: key.clone(), baseline: b, got: p, change_pct, regressed });
    }
    Ok(out)
}

/// Render a set of checks as a GitHub-flavored markdown table — the
/// `bench-check --summary` payload the CI bench-smoke job appends to
/// `$GITHUB_STEP_SUMMARY` so every PR shows its perf deltas inline.
pub fn summary_markdown(checks: &[Check], tolerance: f64) -> String {
    let regressed = checks.iter().filter(|c| c.regressed).count();
    let mut s = format!(
        "### Bench regression gate (tolerance {:.0}%)\n\n\
         | bench | committed floor | PR value | delta | status |\n\
         | --- | ---: | ---: | ---: | :---: |\n",
        tolerance * 100.0
    );
    for c in checks {
        s.push_str(&format!(
            "| `{}` | {:.2} | {:.2} | {:+.1}% | {} |\n",
            c.key,
            c.baseline,
            c.got,
            c.change_pct,
            if c.regressed { "**REGRESSED**" } else { "ok" }
        ));
    }
    s.push_str(&format!(
        "\n{} of {} metrics within tolerance.\n",
        checks.len() - regressed,
        checks.len()
    ));
    s
}

/// [`compare`] over files on disk (the `xamba bench-check` entry point).
pub fn check_files(
    pr_path: &str,
    baseline_path: &str,
    tolerance: f64,
) -> Result<Vec<Check>, String> {
    let pr_src = std::fs::read_to_string(pr_path)
        .map_err(|e| format!("read {pr_path}: {e} (did the benches run?)"))?;
    let base_src = std::fs::read_to_string(baseline_path)
        .map_err(|e| format!("read {baseline_path}: {e}"))?;
    compare(&Json::parse(&pr_src)?, &Json::parse(&base_src)?, tolerance)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(pairs: &[(&str, f64)]) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in pairs {
            m.insert(k.to_string(), Json::Num(*v));
        }
        Json::Obj(m)
    }

    #[test]
    fn throughput_regressions_fail_in_the_right_direction() {
        let base = obj(&[("decode_tok_per_s", 100.0)]);
        // 25% slower -> regressed; 25% faster -> fine
        let slow = compare(&obj(&[("decode_tok_per_s", 75.0)]), &base, 0.20).unwrap();
        assert!(slow[0].regressed && slow[0].change_pct < 0.0);
        let fast = compare(&obj(&[("decode_tok_per_s", 125.0)]), &base, 0.20).unwrap();
        assert!(!fast[0].regressed && fast[0].change_pct > 0.0);
        // within tolerance
        let ok = compare(&obj(&[("decode_tok_per_s", 85.0)]), &base, 0.20).unwrap();
        assert!(!ok[0].regressed);
    }

    #[test]
    fn latency_regressions_fail_in_the_right_direction() {
        let base = obj(&[("ttft_ms", 10.0)]);
        let slow = compare(&obj(&[("ttft_ms", 13.0)]), &base, 0.20).unwrap();
        assert!(slow[0].regressed, "TTFT +30% must regress");
        let fast = compare(&obj(&[("ttft_ms", 7.0)]), &base, 0.20).unwrap();
        assert!(!fast[0].regressed && fast[0].change_pct > 0.0);
    }

    #[test]
    fn ratio_and_rate_metrics_gate_upward() {
        let base = obj(&[("spec_speedup_ratio", 1.5), ("spec_acceptance_rate", 0.8)]);
        let slow = compare(
            &obj(&[("spec_speedup_ratio", 1.0), ("spec_acceptance_rate", 0.85)]),
            &base,
            0.10,
        )
        .unwrap();
        assert!(slow.iter().any(|c| c.key == "spec_speedup_ratio" && c.regressed));
        assert!(slow.iter().any(|c| c.key == "spec_acceptance_rate" && !c.regressed));
        let ok = compare(
            &obj(&[("spec_speedup_ratio", 1.6), ("spec_acceptance_rate", 0.9)]),
            &base,
            0.10,
        )
        .unwrap();
        assert!(ok.iter().all(|c| !c.regressed));
    }

    #[test]
    fn missing_or_directionless_metrics_are_errors() {
        let base = obj(&[("ttft_ms", 10.0)]);
        let err = compare(&obj(&[]), &base, 0.2).unwrap_err();
        assert!(err.contains("ttft_ms"), "{err}");
        let base = obj(&[("mystery", 1.0)]);
        let err = compare(&obj(&[("mystery", 1.0)]), &base, 0.2).unwrap_err();
        assert!(err.contains("direction suffix"), "{err}");
        // extra PR-side keys are fine (they join the baseline later)
        let base = obj(&[("a_ms", 1.0)]);
        let pr = obj(&[("a_ms", 1.0), ("b_ms", 9.0)]);
        assert_eq!(compare(&pr, &base, 0.2).unwrap().len(), 1);
    }

    #[test]
    fn tolerance_boundary_at_the_ci_gate() {
        // the CI gate runs at 0.10: -9.9% passes, -10.1% fails (and the
        // exact edge is NOT a regression — the comparison is strict)
        let base = obj(&[("decode_tok_per_s", 100.0)]);
        let just_in = compare(&obj(&[("decode_tok_per_s", 90.1)]), &base, 0.10).unwrap();
        assert!(!just_in[0].regressed, "{:+.2}%", just_in[0].change_pct);
        let edge = compare(&obj(&[("decode_tok_per_s", 90.0)]), &base, 0.10).unwrap();
        assert!(!edge[0].regressed, "exact tolerance edge must pass");
        let just_out =
            compare(&obj(&[("decode_tok_per_s", 89.9)]), &base, 0.10).unwrap();
        assert!(just_out[0].regressed);
        // same boundary, latency direction
        let base = obj(&[("ttft_ms", 100.0)]);
        assert!(!compare(&obj(&[("ttft_ms", 110.0)]), &base, 0.10).unwrap()[0].regressed);
        assert!(compare(&obj(&[("ttft_ms", 110.2)]), &base, 0.10).unwrap()[0].regressed);
    }

    #[test]
    fn nonpositive_baseline_floors_are_errors() {
        let base = obj(&[("decode_tok_per_s", 0.0)]);
        let err = compare(&obj(&[("decode_tok_per_s", 1.0)]), &base, 0.1).unwrap_err();
        assert!(err.contains("positive"), "{err}");
    }

    #[test]
    fn check_files_surfaces_missing_and_malformed_inputs() {
        let dir = std::env::temp_dir();
        let pr = dir.join(format!("xamba_gate_pr_{}.json", std::process::id()));
        let base = dir.join(format!("xamba_gate_base_{}.json", std::process::id()));
        let (pr, base) = (pr.to_str().unwrap(), base.to_str().unwrap());
        let _ = std::fs::remove_file(pr);

        // missing PR artifact: the error says the benches never ran
        std::fs::write(base, "{\"a_ms\": 1.0}").unwrap();
        let err = check_files(pr, base, 0.1).unwrap_err();
        assert!(err.contains("did the benches run"), "{err}");

        // malformed PR JSON fails loudly, not as a silent pass
        std::fs::write(pr, "{not json").unwrap();
        assert!(check_files(pr, base, 0.1).is_err());
        // malformed baseline too
        std::fs::write(pr, "{\"a_ms\": 1.0}").unwrap();
        std::fs::write(base, "[1, 2]").unwrap();
        let err = check_files(pr, base, 0.1).unwrap_err();
        assert!(err.contains("not a JSON object"), "{err}");

        // and the happy path over real files
        std::fs::write(base, "{\"a_ms\": 1.0}").unwrap();
        let checks = check_files(pr, base, 0.1).unwrap();
        assert_eq!(checks.len(), 1);
        assert!(!checks[0].regressed);
        let _ = std::fs::remove_file(pr);
        let _ = std::fs::remove_file(base);
    }

    #[test]
    fn summary_markdown_renders_the_delta_table() {
        let base = obj(&[("decode_tok_per_s", 100.0), ("ttft_ms", 10.0)]);
        let pr = obj(&[("decode_tok_per_s", 80.0), ("ttft_ms", 9.0)]);
        let checks = compare(&pr, &base, 0.10).unwrap();
        let md = summary_markdown(&checks, 0.10);
        assert!(md.contains("tolerance 10%"), "{md}");
        assert!(md.contains("| `decode_tok_per_s` | 100.00 | 80.00 | -20.0% | **REGRESSED** |"), "{md}");
        assert!(md.contains("| `ttft_ms` | 10.00 | 9.00 | +10.0% | ok |"), "{md}");
        assert!(md.contains("1 of 2 metrics within tolerance"), "{md}");
    }

    #[test]
    fn record_merges_into_one_artifact() {
        let path = std::env::temp_dir().join(format!(
            "xamba_bench_test_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        record(&path, &[("a_ms".into(), 1.5)]).unwrap();
        record(&path, &[("b_per_s".into(), 42.0)]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("a_ms").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("b_per_s").unwrap().as_f64(), Some(42.0));
        let _ = std::fs::remove_file(&path);
    }
}
