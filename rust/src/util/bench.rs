//! Perf-tracking plumbing for the CI `bench-smoke` gate.
//!
//! The serving benches (`benches/serve_decode.rs`, `benches/serve_prefill.rs`)
//! run in two modes: full reports for humans, and a quick mode
//! (`XAMBA_BENCH_QUICK=1`) for CI. When `XAMBA_BENCH_JSON=<path>` is set
//! they additionally merge their headline numbers (tokens/sec, TTFT)
//! into one flat JSON object — the `BENCH_pr.json` artifact — which
//! `xamba bench-check` then compares against the committed baseline,
//! failing the build on any regression beyond the tolerance.
//!
//! Metric keys carry their own direction: `*_per_s` is higher-is-better,
//! `*_ms` / `*_us` lower-is-better. A key the baseline tracks but the
//! bench no longer emits is an error, so the gate cannot silently decay.

use std::collections::BTreeMap;

use super::json::Json;

/// CI quick mode: fewer iterations / smaller sweeps, same metric keys.
pub fn quick_mode() -> bool {
    std::env::var("XAMBA_BENCH_QUICK").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// Where to merge this bench's metrics, if anywhere.
pub fn metrics_path() -> Option<String> {
    std::env::var("XAMBA_BENCH_JSON").ok().filter(|s| !s.is_empty())
}

/// Merge `metrics` into the flat JSON object at `path` (created if
/// absent) — benches run sequentially in CI and accumulate one artifact.
pub fn record(path: &str, metrics: &[(String, f64)]) -> Result<(), String> {
    let mut obj = match std::fs::read_to_string(path) {
        Ok(src) => match Json::parse(&src)? {
            Json::Obj(m) => m,
            other => return Err(format!("{path}: expected a JSON object, got {other:?}")),
        },
        Err(_) => BTreeMap::new(),
    };
    for (k, v) in metrics {
        obj.insert(k.clone(), Json::Num(*v));
    }
    std::fs::write(path, Json::Obj(obj).to_string_compact())
        .map_err(|e| format!("write {path}: {e}"))
}

/// One baseline-vs-PR comparison.
#[derive(Clone, Debug)]
pub struct Check {
    pub key: String,
    pub baseline: f64,
    pub got: f64,
    /// Signed change in percent, oriented so positive = improvement.
    pub change_pct: f64,
    pub regressed: bool,
}

fn higher_is_better(key: &str) -> Result<bool, String> {
    if key.ends_with("_per_s") {
        Ok(true)
    } else if key.ends_with("_ms") || key.ends_with("_us") {
        Ok(false)
    } else {
        Err(format!(
            "metric {key:?} has no direction suffix (want *_per_s, *_ms, or *_us)"
        ))
    }
}

/// Compare every baseline metric against the PR metrics. `tolerance` is
/// the fractional regression allowed (0.20 = fail beyond 20%). Keys the
/// PR emits but the baseline does not track are ignored (new metrics
/// join the baseline when it is refreshed); keys the baseline tracks but
/// the PR file lacks are an error.
pub fn compare(pr: &Json, baseline: &Json, tolerance: f64) -> Result<Vec<Check>, String> {
    let base = match baseline {
        Json::Obj(m) => m,
        _ => return Err("baseline is not a JSON object".into()),
    };
    let mut out = Vec::with_capacity(base.len());
    for (key, bval) in base {
        let b = bval
            .as_f64()
            .ok_or_else(|| format!("baseline metric {key:?} is not a number"))?;
        let p = pr
            .get(key)
            .ok_or_else(|| format!("PR metrics no longer emit {key:?} — bench decayed?"))?
            .as_f64()
            .ok_or_else(|| format!("PR metric {key:?} is not a number"))?;
        let higher = higher_is_better(key)?;
        if b <= 0.0 {
            return Err(format!("baseline metric {key:?} must be positive, got {b}"));
        }
        let (regressed, change_pct) = if higher {
            (p < b * (1.0 - tolerance), (p - b) / b * 100.0)
        } else {
            (p > b * (1.0 + tolerance), (b - p) / b * 100.0)
        };
        out.push(Check { key: key.clone(), baseline: b, got: p, change_pct, regressed });
    }
    Ok(out)
}

/// [`compare`] over files on disk (the `xamba bench-check` entry point).
pub fn check_files(
    pr_path: &str,
    baseline_path: &str,
    tolerance: f64,
) -> Result<Vec<Check>, String> {
    let pr_src = std::fs::read_to_string(pr_path)
        .map_err(|e| format!("read {pr_path}: {e} (did the benches run?)"))?;
    let base_src = std::fs::read_to_string(baseline_path)
        .map_err(|e| format!("read {baseline_path}: {e}"))?;
    compare(&Json::parse(&pr_src)?, &Json::parse(&base_src)?, tolerance)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(pairs: &[(&str, f64)]) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in pairs {
            m.insert(k.to_string(), Json::Num(*v));
        }
        Json::Obj(m)
    }

    #[test]
    fn throughput_regressions_fail_in_the_right_direction() {
        let base = obj(&[("decode_tok_per_s", 100.0)]);
        // 25% slower -> regressed; 25% faster -> fine
        let slow = compare(&obj(&[("decode_tok_per_s", 75.0)]), &base, 0.20).unwrap();
        assert!(slow[0].regressed && slow[0].change_pct < 0.0);
        let fast = compare(&obj(&[("decode_tok_per_s", 125.0)]), &base, 0.20).unwrap();
        assert!(!fast[0].regressed && fast[0].change_pct > 0.0);
        // within tolerance
        let ok = compare(&obj(&[("decode_tok_per_s", 85.0)]), &base, 0.20).unwrap();
        assert!(!ok[0].regressed);
    }

    #[test]
    fn latency_regressions_fail_in_the_right_direction() {
        let base = obj(&[("ttft_ms", 10.0)]);
        let slow = compare(&obj(&[("ttft_ms", 13.0)]), &base, 0.20).unwrap();
        assert!(slow[0].regressed, "TTFT +30% must regress");
        let fast = compare(&obj(&[("ttft_ms", 7.0)]), &base, 0.20).unwrap();
        assert!(!fast[0].regressed && fast[0].change_pct > 0.0);
    }

    #[test]
    fn missing_or_directionless_metrics_are_errors() {
        let base = obj(&[("ttft_ms", 10.0)]);
        let err = compare(&obj(&[]), &base, 0.2).unwrap_err();
        assert!(err.contains("ttft_ms"), "{err}");
        let base = obj(&[("mystery", 1.0)]);
        let err = compare(&obj(&[("mystery", 1.0)]), &base, 0.2).unwrap_err();
        assert!(err.contains("direction suffix"), "{err}");
        // extra PR-side keys are fine (they join the baseline later)
        let base = obj(&[("a_ms", 1.0)]);
        let pr = obj(&[("a_ms", 1.0), ("b_ms", 9.0)]);
        assert_eq!(compare(&pr, &base, 0.2).unwrap().len(), 1);
    }

    #[test]
    fn record_merges_into_one_artifact() {
        let path = std::env::temp_dir().join(format!(
            "xamba_bench_test_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        record(&path, &[("a_ms".into(), 1.5)]).unwrap();
        record(&path, &[("b_per_s".into(), 42.0)]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("a_ms").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("b_per_s").unwrap().as_f64(), Some(42.0));
        let _ = std::fs::remove_file(&path);
    }
}
